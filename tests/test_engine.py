"""Request-level serving engine (PR 5).

The contract under test, per ISSUE 5's acceptance criteria:

- **Bit-exactness**: for any request mix (shapes, tenants, arrival
  orders), the engine's outputs are bit-identical to serial per-request
  execution on the same shares/triples (default policy: per-request keys
  forked from ``Session.request_key``, per-tenant providers, coalescing
  only).
- **Rounds**: measured fused rounds of every micro-batch equal
  ``core.schedule.simulate_merged``'s prediction exactly and equal
  max-over-requests rounds, not the sum.
- **Reproducibility**: reordering submissions does not change any
  request's output (PRNG forking is by request id, not admission order).
- **Tenancy**: triple consumption is metered per tenant; an over-budget
  request fails its future without executing any protocol round.
- **Data sharding**: ``TriplePool.shard``/``shard_pool`` split triple
  pools per data shard at the bit level (party dim untouched) so
  ``serve_step(mesh, data_axis=...)`` composes with a data axis inside
  ``shard_map``, with the per-shard HLO collective census unchanged.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, errors
from repro.core import MPCTensor, beaver, comm as comm_lib, ring, shares
from repro.core import schedule as schedule_lib
from repro.core.hummingbird import HBConfig, HBLayer
from repro.launch.mesh import make_mpc_smoke_mesh
from repro.serve import BatchPolicy, InferenceEngine

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# A tiny two-ReLU-group model: fast enough for property tests, shaped enough
# (two call sites, ragged batches) to exercise the whole engine
# ---------------------------------------------------------------------------

class TinyCfg:
    name = "tiny-mlp"


def tiny_apply(params, x, relu_fn=None):
    rf = relu_fn if relu_fn is not None else (lambda v, g: jax.nn.relu(v))
    h = rf(x @ params["w1"], 0)
    return rf(h @ params["w2"], 1)


def tiny_forward(params, hs, cfg, relu_fn, comm):
    hs = relu_fn([h.matmul_public(params["w1"]) for h in hs], 0)
    return relu_fn([h.matmul_public(params["w2"]) for h in hs], 1)


api.register_mpc_forward(TinyCfg, tiny_forward)

D_IN, D_HID, D_OUT = 6, 5, 4


@pytest.fixture(scope="module")
def tiny():
    params = {
        "w1": jax.random.normal(jax.random.PRNGKey(0), (D_IN, D_HID)) * 0.4,
        "w2": jax.random.normal(jax.random.PRNGKey(1), (D_HID, D_OUT)) * 0.4,
    }
    plan = api.trace_plan(tiny_apply, params, (2, D_IN), name="tiny")
    plan = plan.with_hb(HBConfig((HBLayer(k=21, m=13), HBLayer(k=21, m=13)),
                                 plan.group_elements))
    return params, plan


def _engine(params, plan, policy=None, **kw):
    return InferenceEngine(tiny_apply, params, TinyCfg(), plan,
                           api.Session(key=0), policy=policy, **kw)


def _request_tensor(i, batch):
    x = jax.random.normal(jax.random.PRNGKey(100 + i), (batch, D_IN))
    return MPCTensor.from_plain(jax.random.PRNGKey(200 + i), x)


def _serial_oracle(params, plan, X, request_id):
    """Serial per-request execution on the same shares/triples: one
    PrivateModel call with the request's forked key and a fresh inline
    provider — what the engine must stay bit-identical to."""
    session = api.Session(key=0)
    model = api.compile(tiny_apply, params, TinyCfg(), plan, session)
    key_iter = iter(jax.random.split(session.request_key(request_id), 256))
    return model._run_streams([X], [key_iter], [beaver.InlineTTP()],
                              comm_lib.CoalescingComm(), params,
                              auto_batch=False)[0]


# ---------------------------------------------------------------------------
# Acceptance: canonical mix — two identical shapes + one ragged shape
# ---------------------------------------------------------------------------

def test_canonical_mix_bit_identical_and_max_over_requests(tiny):
    params, plan = tiny
    engine = _engine(params, plan)
    batches = [2, 2, 3]                       # two identical + one ragged
    Xs = [_request_tensor(i, b) for i, b in enumerate(batches)]
    futs = [engine.submit(t, X) for t, X in zip(["alice", "bob", "alice"],
                                                Xs)]
    outs = [f.result() for f in futs]

    # one micro-batch; measured == simulate_merged prediction, exactly
    assert len(engine.reports) == 1
    rep = engine.reports[0]
    assert rep.n_requests == 3
    sched = schedule_lib.simulate_merged(
        [engine.plan_for_shape((b, D_IN)).call_specs() for b in batches],
        auto_batch=False)
    assert rep.measured_rounds == sched.n_rounds == rep.predicted_rounds
    assert rep.measured_bytes == sched.bytes_tx == rep.predicted_bytes

    # max-over-requests, not the sum: every request replays the same
    # network, so the fused batch pays exactly one request's rounds
    per_request = [engine.plan_for_shape((b, D_IN)).schedule().n_rounds
                   for b in batches]
    assert rep.measured_rounds == max(per_request)
    assert rep.serial_rounds == sum(per_request) > rep.measured_rounds
    assert rep.rounds_saved_ratio == pytest.approx(3.0)

    # bit-identical (share level) to serial per-request execution
    for i, (X, out) in enumerate(zip(Xs, outs)):
        want = _serial_oracle(params, plan, X, i)
        np.testing.assert_array_equal(ring.to_uint64_np(out.data),
                                      ring.to_uint64_np(want.data))


def test_reordered_submissions_do_not_change_outputs(tiny):
    """Randomness regression: a request's output depends on its id, never
    on admission order or on which other requests were in flight."""
    params, plan = tiny
    Xs = {7: _request_tensor(0, 2), 11: _request_tensor(1, 3),
          13: _request_tensor(2, 2)}

    def run(order):
        engine = _engine(params, plan)
        futs = {rid: engine.submit("t", Xs[rid], request_id=rid)
                for rid in order}
        return {rid: ring.to_uint64_np(f.result().data)
                for rid, f in futs.items()}

    a = run([7, 11, 13])
    b = run([13, 7, 11])
    for rid in Xs:
        np.testing.assert_array_equal(a[rid], b[rid])


def test_api_reexports_engine_types():
    import repro.serve as serve

    assert api.InferenceEngine is serve.InferenceEngine
    assert api.BatchPolicy is serve.BatchPolicy
    assert api.RequestFuture is serve.RequestFuture
    with pytest.raises(AttributeError):
        api.NoSuchThing


def test_duplicate_request_id_rejected(tiny):
    params, plan = tiny
    engine = _engine(params, plan)
    engine.submit("t", _request_tensor(0, 2), request_id=3)
    with pytest.raises(ValueError, match="already submitted"):
        engine.submit("t", _request_tensor(1, 2), request_id=3)


# ---------------------------------------------------------------------------
# Property test: random request mixes (hypothesis where available, a
# seeded sweep everywhere — same checker)
# ---------------------------------------------------------------------------

def _check_random_mix(tiny, mix, order):
    """For an arbitrary request mix (batch sizes, tenants) submitted in an
    arbitrary order: engine outputs are bit-identical to serial execution
    and every batch's measured rounds/bytes equal the merged-schedule
    prediction."""
    params, plan = tiny
    engine = _engine(params, plan)
    futs = {}
    for rid in order:
        batch, tenant = mix[rid]
        futs[rid] = engine.submit(tenant, _request_tensor(rid, batch),
                                  request_id=rid)
    outs = {rid: f.result() for rid, f in futs.items()}

    # revealed (indeed share-level) outputs == serial execution
    for rid, (batch, _) in enumerate(mix):
        want = _serial_oracle(params, plan, _request_tensor(rid, batch), rid)
        np.testing.assert_array_equal(ring.to_uint64_np(outs[rid].data),
                                      ring.to_uint64_np(want.data))

    # every executed batch's measured rounds == the simulator's
    # prediction for its merged group set
    for rep in engine.reports:
        sched = schedule_lib.simulate_merged(
            [engine.plan_for_shape(s).call_specs() for s in rep.shapes],
            auto_batch=False)
        assert rep.measured_rounds == sched.n_rounds
        assert rep.measured_bytes == sched.bytes_tx


@pytest.mark.parametrize("seed", range(4))
def test_seeded_random_mix_bit_identical_and_rounds_predicted(tiny, seed):
    rnd = np.random.default_rng(seed)
    mix = [(int(rnd.integers(1, 5)), str(rnd.choice(["a", "b", "c"])))
           for _ in range(int(rnd.integers(1, 6)))]
    order = rnd.permutation(len(mix)).tolist()
    _check_random_mix(tiny, mix, order)


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 4),           # batch size
                              st.sampled_from(["a", "b", "c"])),  # tenant
                    min_size=1, max_size=5),
           st.randoms(use_true_random=False))
    def test_random_mix_bit_identical_and_rounds_predicted(tiny, mix, rnd):
        order = list(range(len(mix)))
        rnd.shuffle(order)                    # random arrival order
        _check_random_mix(tiny, mix, order)


# ---------------------------------------------------------------------------
# Batching policy
# ---------------------------------------------------------------------------

def test_policy_max_batch_splits_queue(tiny):
    params, plan = tiny
    engine = _engine(params, plan, policy=BatchPolicy(max_batch=2))
    futs = [engine.submit("t", _request_tensor(i, 2)) for i in range(5)]
    engine.flush()
    assert [r.n_requests for r in engine.reports] == [2, 2, 1]
    assert all(f.done for f in futs)


def test_policy_min_gain_one_forces_serial_batches(tiny):
    """A gain threshold no merge can meet degenerates to per-request
    batches — the serial baseline expressed as a policy."""
    params, plan = tiny
    engine = _engine(params, plan, policy=BatchPolicy(min_gain=1.0))
    for i in range(3):
        engine.submit("t", _request_tensor(i, 2))
    engine.flush()
    assert [r.n_requests for r in engine.reports] == [1, 1, 1]


def test_poll_respects_deadline_flush_drains(tiny):
    params, plan = tiny
    engine = _engine(params, plan,
                     policy=BatchPolicy(max_wait_s=10.0, max_batch=8))
    engine.submit("t", _request_tensor(0, 2), arrival_s=0.0)
    engine.submit("t", _request_tensor(1, 2), arrival_s=1.0)
    # queue absorbed into one still-open batch, deadline not hit: no run
    assert engine.poll(now_s=5.0) == []
    assert engine.pending == 2
    # head exceeded max_wait_s: the batch closes and runs
    reports = engine.poll(now_s=10.5)
    assert len(reports) == 1 and reports[0].n_requests == 2
    assert engine.pending == 0
    assert reports[0].waits_s == (10.5, 9.5)


def test_merge_identical_one_payload_per_round_reveals_sane(tiny):
    """Opt-in cross-request auto-batching: identical shapes merge into ONE
    protocol stream, so every fused round carries a single payload (the
    CoalescingComm parts counter drops to 1) with rounds/bytes still equal
    to the auto-batched schedule prediction, and the revealed outputs stay
    within the HummingBird approximation's own error of the plaintext."""
    params, plan = tiny
    x = jax.random.normal(jax.random.PRNGKey(42), (2, D_IN))
    X1 = MPCTensor.from_plain(jax.random.PRNGKey(43), x)
    X2 = MPCTensor.from_plain(jax.random.PRNGKey(44), x)

    merged = _engine(params, plan, policy=BatchPolicy(merge_identical=True))
    f1 = merged.submit("a", X1)
    f2 = merged.submit("b", X2)
    out1, out2 = f1.result(), f2.result()
    rep = merged.reports[0]
    assert rep.measured_rounds == rep.predicted_rounds
    assert rep.measured_bytes == rep.predicted_bytes
    # merged prediction uses auto-batched specs: one payload per round
    sched = schedule_lib.simulate_merged(
        [merged.plan_for_shape((2, D_IN)).call_specs()] * 2, auto_batch=True)
    assert rep.measured_rounds == sched.n_rounds
    assert list(merged.comm.round_parts) == [1] * sched.n_rounds
    want = np.asarray(tiny_apply(params, x))
    for out in (out1, out2):
        np.testing.assert_allclose(out.reveal_np(), want, atol=0.6)


def test_pow2_bucketing_pads_and_slices(tiny):
    params, plan = tiny
    engine = _engine(params, plan, policy=BatchPolicy(bucket="pow2"))
    fut = engine.submit("t", _request_tensor(0, 3))    # padded to 4
    out = fut.result()
    assert out.shape == (3, D_OUT)
    assert engine.reports[0].shapes == ((4, D_IN),)
    # batches 3 and 4 share one plan-cache entry (plus the seed plan)
    engine.submit("t", _request_tensor(1, 4))
    engine.flush()
    assert engine.plan_cache_size == 2


def test_pow2_bucket_does_not_reuse_unbucketed_seed_plan(tiny):
    """Regression: a plan traced at a non-power-of-two batch must not be
    served for the padded bucket it maps to — the padded replay has more
    elements, and budgets/predictions sized off the smaller trace would
    let a mid-protocol budget error through."""
    params, plan = tiny
    plan3 = api.trace_plan(tiny_apply, params, (3, D_IN), hb=plan.hb,
                           name="tiny3")
    engine = InferenceEngine(tiny_apply, params, TinyCfg(), plan3,
                             api.Session(key=0),
                             policy=BatchPolicy(bucket="pow2"))
    cached = engine.plan_for_shape((3, D_IN))
    assert tuple(cached.input_shape) == (4, D_IN)      # traced at the bucket
    fut = engine.submit("t", _request_tensor(0, 3))
    assert fut.result().shape == (3, D_OUT)
    assert engine.reports[0].predicted_rounds == engine.reports[0].measured_rounds


def test_fully_culled_plan_batches_without_crashing(tiny):
    """Regression: a zero-round (all-culled) plan has merged latency 0 —
    admission must treat merging as free, not divide by zero."""
    params, plan = tiny
    culled = plan.with_hb(HBConfig((HBLayer(k=0, m=0), HBLayer(k=0, m=0)),
                                   plan.group_elements))
    engine = _engine(params, culled)
    futs = [engine.submit("t", _request_tensor(i, 2)) for i in range(3)]
    outs = [f.result() for f in futs]
    assert all(o is not None for o in outs)
    rep = engine.reports[0]
    assert rep.n_requests == 3 and rep.measured_rounds == 0


def test_unservable_shape_fails_at_submit(tiny):
    """A shape the engine cannot trace fails the submit() call itself —
    queued requests can never be dropped by a later trace error."""
    params, plan = tiny
    engine = InferenceEngine(None, params, TinyCfg(), plan,
                             api.Session(key=0))
    ok = engine.submit("t", _request_tensor(0, 2))     # seed-plan shape
    with pytest.raises(ValueError, match="no traced plan"):
        engine.submit("t", _request_tensor(1, 3))      # untraced shape
    assert engine.pending == 1
    assert ok.result() is not None


def test_plan_cache_reuses_traced_shapes(tiny):
    params, plan = tiny
    engine = _engine(params, plan)
    for i, b in enumerate([2, 3, 2, 3, 2]):
        engine.submit("t", _request_tensor(i, b))
    engine.flush()
    assert engine.plan_cache_size == 2        # (2, D_IN) seeded + (3, D_IN)


# ---------------------------------------------------------------------------
# Tenancy: metered triple budgets
# ---------------------------------------------------------------------------

def test_tenant_budget_fails_future_without_running(tiny):
    params, plan = tiny
    per_request = 2 * D_HID + 2 * D_OUT       # DReLU elements per batch-2
    engine = _engine(params, plan,
                     tenant_budgets={"capped": per_request + 1})
    ok = engine.submit("capped", _request_tensor(0, 2))
    over = engine.submit("capped", _request_tensor(1, 2))
    free = engine.submit("other", _request_tensor(2, 2))
    assert ok.result() is not None
    assert free.result() is not None
    with pytest.raises(beaver.TripleBudgetExceeded, match="capped"):
        over.result()
    usage = engine.tenant_usage("capped")
    assert usage["consumed_elements"] == per_request
    assert usage["remaining_elements"] == 1
    # the failed request never entered the executed batch
    assert all(over.request.id not in r.request_ids for r in engine.reports)


def test_metered_provider_counts_and_caps():
    p = beaver.MeteredProvider(beaver.InlineTTP(), budget_elements=100)
    assert p.relu_triples(0, 8) is None       # empty: not metered
    assert p.relu_triples(64, 0) is None      # culled: not metered
    p.relu_triples(60, 8)
    assert (p.consumed_elements, p.consumed_bundles) == (60, 1)
    with pytest.raises(beaver.TripleBudgetExceeded):
        p.relu_triples(41, 8)
    assert p.remaining_elements == 40


# ---------------------------------------------------------------------------
# Triple-pool data sharding (ROADMAP item) + data-axis serve_step
# ---------------------------------------------------------------------------

def test_shard_relu_triples_is_elementwise_slice():
    """Shards reconstruct exactly the element slices of the unsharded
    bundle: arithmetic members on the element axis, binary members at the
    bit level (word boundaries shift — 96/3 = 32 is exercised alongside
    the non-word-aligned 40/2 = 20 split)."""
    for E, S in [(96, 3), (40, 2)]:
        b = beaver.gen_relu_triples(jax.random.PRNGKey(E), E, 8)
        shards = [beaver.shard_relu_triples(b, i, S) for i in range(S)]
        for field in ("a", "b", "c"):
            full = shares.unpack_bits(getattr(b.bin_init, field), E)
            got = np.concatenate(
                [shares.unpack_bits(getattr(s.bin_init, field), E // S)
                 for s in shards], axis=-1)
            np.testing.assert_array_equal(np.asarray(full), got)
            full_lvl = shares.unpack_bits(getattr(b.bin_levels, field), E)
            got_lvl = np.concatenate(
                [shares.unpack_bits(getattr(s.bin_levels, field), E // S)
                 for s in shards], axis=-1)
            np.testing.assert_array_equal(np.asarray(full_lvl), got_lvl)
            np.testing.assert_array_equal(
                np.asarray(getattr(b.b2a, field).lo),
                np.concatenate([np.asarray(getattr(s.b2a, field).lo)
                                for s in shards], axis=-1))
    with pytest.raises(ValueError, match="divisible"):
        beaver.shard_relu_triples(
            beaver.gen_relu_triples(jax.random.PRNGKey(0), 10, 8), 0, 3)


def test_shard_relu_triples_cone_mode():
    b = beaver.gen_relu_triples(jax.random.PRNGKey(5), 64, 8, cone=True)
    s0, s1 = (beaver.shard_relu_triples(b, i, 2) for i in range(2))
    assert len(s0.bin_levels) == len(b.bin_levels)
    for lvl, (f0, f1) in enumerate(zip(s0.bin_levels, s1.bin_levels)):
        full = shares.unpack_bits(b.bin_levels[lvl].a, 64)
        got = np.concatenate([shares.unpack_bits(f0.a, 32),
                              shares.unpack_bits(f1.a, 32)], axis=-1)
        np.testing.assert_array_equal(np.asarray(full), got)


def test_sharded_relu_reveals_identically(rng):
    """The protocol run per shard with its triple slice reveals exactly
    the element slice of the unsharded run (same shares: DReLU is a
    deterministic function of the input shares, triples never leak into
    the reconstruction)."""
    from repro.core import fixed, gmw

    E, S = 64, 2
    x = rng.uniform(-3.5, 3.5, E).astype(np.float32)
    X = shares.share(jax.random.PRNGKey(1), fixed.encode_np(x))
    tr = beaver.gen_relu_triples(jax.random.PRNGKey(2), E, 8)
    full = gmw.relu(jax.random.PRNGKey(3), X, tr, comm_lib.SimComm(),
                    k=21, m=13)
    want = fixed.decode_np(shares.reconstruct(full))
    per = E // S
    for i in range(S):
        Xi = ring.Ring64(X.lo[:, i * per:(i + 1) * per],
                         X.hi[:, i * per:(i + 1) * per])
        tri = beaver.shard_relu_triples(tr, i, S)
        out = gmw.relu(jax.random.PRNGKey(3), Xi, tri, comm_lib.SimComm(),
                       k=21, m=13)
        np.testing.assert_array_equal(
            fixed.decode_np(shares.reconstruct(out)),
            want[i * per:(i + 1) * per])


def test_triple_pool_shard_slices_remaining_bundles():
    pool = beaver.gen_plan_triples(jax.random.PRNGKey(0),
                                   [(64, 8), (0, 8), (32, 0), (32, 8)])
    base = beaver.TriplePool(pool)
    shards = [base.shard(i, 2) for i in range(2)]   # non-destructive
    for shard in shards:
        first = shard.relu_triples(32, 8)
        assert first.b2a.a.lo.shape[-1] == 32     # 64-element call halved
        assert shard.relu_triples(0, 8) is None   # empty call stays None
        assert shard.relu_triples(32, 0) is None  # culled call stays None
        assert shard.relu_triples(16, 8).b2a.a.lo.shape[-1] == 16
    # the base pool is untouched and shards only cover what remains
    assert base.relu_triples(64, 8) is not None
    assert base.shard(0, 2).relu_triples(0, 8) is None  # skips consumed head


def test_data_axis_serve_step_smoke_mesh_bit_identical(tiny):
    params, plan = tiny
    model = api.compile(tiny_apply, params, TinyCfg(), plan,
                        api.Session(key=0))
    X = _request_tensor(0, 2)
    pool = beaver.gen_plan_triples(jax.random.PRNGKey(3),
                                   plan.triple_specs())
    key = jax.random.PRNGKey(4)
    s_lo, s_hi = model.serve_step()(params, X.data.lo, X.data.hi, pool, key)
    step = model.jit_step(make_mpc_smoke_mesh(), data_axis="data")
    m_lo, m_hi = step(params, X.data.lo, X.data.hi,
                      beaver.shard_pool(pool, 1), key)
    np.testing.assert_array_equal(np.asarray(m_lo), np.asarray(s_lo))
    np.testing.assert_array_equal(np.asarray(m_hi), np.asarray(s_hi))


# ---------------------------------------------------------------------------
# Data-axis mesh lowering: per-shard collective census unchanged
# (2-device subprocess: party axis 1 x data axis 2 keeps the protocol
# exchanges local per shard — the census isolates the data-sharding effect)
# ---------------------------------------------------------------------------

_DATA_AXIS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import MPCTensor, beaver, ring, schedule as schedule_lib
from repro.core.hummingbird import HBConfig, HBLayer
from repro.runtime.hlo_analyzer import collective_census

assert jax.device_count() >= 4

class TinyCfg:
    name = "tiny-mlp"

def tiny_apply(params, x, relu_fn=None):
    rf = relu_fn if relu_fn is not None else (lambda v, g: jax.nn.relu(v))
    h = rf(x @ params["w1"], 0)
    return rf(h @ params["w2"], 1)

def tiny_forward(params, hs, cfg, relu_fn, comm):
    hs = relu_fn([h.matmul_public(params["w1"]) for h in hs], 0)
    return relu_fn([h.matmul_public(params["w2"]) for h in hs], 1)

api.register_mpc_forward(TinyCfg, tiny_forward)
params = {"w1": jax.random.normal(jax.random.PRNGKey(0), (6, 5)) * 0.4,
          "w2": jax.random.normal(jax.random.PRNGKey(1), (5, 4)) * 0.4}
plan = api.trace_plan(tiny_apply, params, (4, 6), name="tiny")
plan = plan.with_hb(HBConfig((HBLayer(k=21, m=13), HBLayer(k=21, m=13)),
                             plan.group_elements))
model = api.compile(tiny_apply, params, TinyCfg(), plan, api.Session(key=0))

x = jax.random.normal(jax.random.PRNGKey(2), (4, 6))
X = MPCTensor.from_plain(jax.random.PRNGKey(3), x)
pool = beaver.gen_plan_triples(jax.random.PRNGKey(4), plan.triple_specs())
key = jax.random.PRNGKey(5)

mesh = jax.make_mesh((2, 2), ("party", "data"))

# unsharded two-party reference census
ref_step = model.serve_step(jax.make_mesh((2,), ("party",),
                                          devices=jax.devices()[:2]))
ref = collective_census(jax.jit(ref_step).lower(
    params, X.data.lo, X.data.hi, pool, key).compile().as_text())

sharded = beaver.shard_pool(pool, 2)
step = model.serve_step(mesh, data_axis="data")
compiled = jax.jit(step).lower(params, X.data.lo, X.data.hi, sharded,
                               key).compile()
census = collective_census(compiled.as_text())

# per-shard schedule: every call halves its element count, rounds unchanged
shard_plan = api.trace_plan(tiny_apply, params, (2, 6), hb=plan.hb,
                            name="tiny-shard")
shard_sched = shard_plan.schedule()
assert len(census) == len(ref) == shard_sched.n_rounds, (
    len(census), len(ref), shard_sched.n_rounds)
assert [c.bytes for c in census] == list(shard_sched.round_bytes), (
    [c.bytes for c in census], shard_sched.round_bytes)

# revealed outputs equal the unsharded sim replay's
m_lo, m_hi = compiled(params, X.data.lo, X.data.hi, sharded, key)
s_lo, s_hi = model.serve_step()(params, X.data.lo, X.data.hi, pool, key)
import repro.core.shares as shares, repro.core.fixed as fixed
got = fixed.decode_np(shares.reconstruct(ring.Ring64(m_lo, m_hi)))
want = fixed.decode_np(shares.reconstruct(ring.Ring64(s_lo, s_hi)))
np.testing.assert_allclose(got, want, atol=2 ** (13 - 16) + 1e-4)
print("DATA_AXIS_OK")
"""


def test_data_axis_census_unchanged_per_shard():
    """Acceptance for the ROADMAP data-axis item: with the batch sharded
    2-way over a data axis, the compiled step still carries exactly the
    schedule-predicted number of collective-permutes (rounds are
    element-count independent) and each collective's payload equals the
    per-shard schedule's round bytes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _DATA_AXIS_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    assert "DATA_AXIS_OK" in out.stdout


# ---------------------------------------------------------------------------
# Engine resilience (ISSUE 6): deadline shedding, batch retry on comm
# faults, crash + restart hook — failure accounting exact in stats()
# ---------------------------------------------------------------------------

def _chaos_engine(params, plan, fault_plan, *, resilient_retries=3, **kw):
    """An engine whose session comm realizes ``fault_plan`` below a
    ResilientComm; returns (engine, injector, resilient)."""
    from repro.core import faults
    fic = faults.FaultInjectingComm(fault_plan)
    rc = comm_lib.ResilientComm(fic, max_retries=resilient_retries)
    session = api.Session(key=0, comm=rc)
    engine = InferenceEngine(tiny_apply, params, TinyCfg(), plan, session,
                             **kw)
    return engine, fic, rc


def test_deadline_shedding_typed_and_counted(tiny):
    """A request that provably cannot meet its deadline is shed before
    any triple is consumed; the others in the same batch still run."""
    params, plan = tiny
    engine = _engine(params, plan)
    doomed = engine.submit("alice", _request_tensor(0, 2), deadline_s=0.0)
    ok = engine.submit("bob", _request_tensor(1, 2))
    assert ok.result() is not None
    with pytest.raises(errors.DeadlineExceeded) as ei:
        doomed.result()
    assert ei.value.request_id == doomed.request.id
    assert ei.value.tenant == "alice"
    stats = engine.stats()
    assert stats["shed"] == 1 and stats["requests"] == 1
    assert engine.reports[-1].shed == 1
    # shed before execution: alice consumed nothing
    assert engine.tenant_usage("alice")["consumed_elements"] == 0
    # a generous deadline is met normally
    fine = engine.submit("alice", _request_tensor(2, 2), deadline_s=60.0)
    assert fine.result() is not None
    assert engine.stats()["shed"] == 1


def test_batch_retry_on_transient_faults_bit_identical(tiny):
    """Transport retry budget 0 forces every transient up to the engine:
    the whole batch re-executes (providers rolled back, same request
    keys) and the results stay bit-identical to a fault-free engine —
    with STATEFUL StreamingTTP providers, so the rollback is load-bearing."""
    from repro.core import faults
    params, plan = tiny
    factory = lambda tenant: beaver.StreamingTTP(
        jax.random.PRNGKey(len(tenant)))

    clean = _engine(params, plan, provider_factory=factory)
    f_clean = [clean.submit(t, _request_tensor(i, 2))
               for i, t in enumerate(["alice", "bob"])]
    want = [f.result() for f in f_clean]

    fault_plan = faults.FaultPlan.seeded(3, 10, drops=1, corrupts=1)
    engine, fic, rc = _chaos_engine(params, plan, fault_plan,
                                    resilient_retries=0,
                                    provider_factory=factory)
    futs = [engine.submit(t, _request_tensor(i, 2))
            for i, t in enumerate(["alice", "bob"])]
    outs = [f.result() for f in futs]
    for got, ref in zip(outs, want):
        np.testing.assert_array_equal(ring.to_uint64_np(got.data),
                                      ring.to_uint64_np(ref.data))
    stats = engine.stats()
    assert stats["retries"] == 2 == engine.reports[-1].retries
    assert fic.injected["drop"] == 1 and fic.injected["corrupt"] == 1
    # tenants billed exactly once despite the re-executions
    per_request = 2 * D_HID + 2 * D_OUT
    assert engine.tenant_usage("alice")["consumed_elements"] == per_request


def test_transport_absorbs_faults_engine_counts_recovery(tiny):
    """With transport-level retries available the engine never re-runs the
    batch; it reports the rounds the transport healed."""
    from repro.core import faults
    params, plan = tiny
    fault_plan = faults.FaultPlan.seeded(5, 10, drops=2, corrupts=1)
    engine, fic, rc = _chaos_engine(params, plan, fault_plan,
                                    resilient_retries=3)
    fut = engine.submit("alice", _request_tensor(0, 2))
    out = fut.result()
    want = _serial_oracle(params, plan, _request_tensor(0, 2), 0)
    np.testing.assert_array_equal(ring.to_uint64_np(out.data),
                                  ring.to_uint64_np(want.data))
    stats = engine.stats()
    assert stats["retries"] == 0
    assert stats["faults_recovered"] == 3 == engine.reports[-1].faults_recovered
    assert rc.retries == 3 and rc.recovered == 3


def test_party_crash_restart_hook_retries_batch(tiny):
    """A mid-replay crash fails the batch unless on_party_crash revives
    the transport; with the hook, the retried results are bit-identical."""
    from repro.core import faults
    params, plan = tiny

    # no hook: the typed crash propagates and fails the future
    fault_plan = faults.FaultPlan.seeded(0, 10, drops=0, corrupts=0,
                                         crash_round=2)
    engine, fic, rc = _chaos_engine(params, plan, fault_plan)
    fut = engine.submit("alice", _request_tensor(0, 2))
    with pytest.raises(errors.PartyCrashed):
        engine.flush()
    with pytest.raises(errors.PartyCrashed) as ei:
        fut.result()
    assert ei.value.request_id == fut.request.id

    # with the hook: restart + one batch retry, bit-identical output
    fault_plan = faults.FaultPlan.seeded(0, 10, drops=0, corrupts=0,
                                         crash_round=2)
    holder = {}
    engine2, fic2, rc2 = _chaos_engine(
        params, plan, fault_plan,
        on_party_crash=lambda e: holder["fic"].restart())
    holder["fic"] = fic2
    fut2 = engine2.submit("alice", _request_tensor(0, 2))
    out = fut2.result()
    want = _serial_oracle(params, plan, _request_tensor(0, 2), 0)
    np.testing.assert_array_equal(ring.to_uint64_np(out.data),
                                  ring.to_uint64_np(want.data))
    assert engine2.stats()["retries"] == 1
    assert fic2.restarts == 1


def test_result_timeout_raises_instead_of_hanging(tiny):
    """A policy that never closes a solo batch used to make result() spin
    via flush; with timeout_s the caller gets a typed timeout carrying
    the request identity."""
    params, plan = tiny
    engine = _engine(params, plan,
                     policy=BatchPolicy(max_batch=8, min_gain=-1.0))
    fut = engine.submit("alice", _request_tensor(0, 2))
    with pytest.raises(errors.ResultTimeout) as ei:
        fut.result(timeout_s=0.05)
    assert ei.value.request_id == fut.request.id
    assert ei.value.tenant == "alice"
    assert not fut.done                       # still queued, not failed
    assert fut.result() is not None           # blocking drain still works


def test_typed_errors_preserve_builtin_contracts(tiny):
    """The new hierarchy subclasses the builtins it replaced, so every
    historical except/raises call site keeps working."""
    params, plan = tiny
    engine = _engine(params, plan)
    engine.submit("alice", _request_tensor(0, 2))
    with pytest.raises(errors.DuplicateRequest):
        engine.submit("alice", _request_tensor(0, 2), request_id=0)
    assert issubclass(errors.DuplicateRequest, ValueError)
    assert issubclass(errors.ShapeMismatch, ValueError)
    assert issubclass(errors.UnregisteredModel, KeyError)
    assert issubclass(errors.TripleBudgetExceeded, RuntimeError)
    assert beaver.TripleBudgetExceeded is errors.TripleBudgetExceeded
    with pytest.raises(KeyError, match="no MPC forward"):
        class Unknown:
            pass
        api.compile(None, {}, Unknown(), plan, api.Session(key=0))
