"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Dict

from .base import ArchConfig, smoke_variant
from .falcon_mamba_7b import CONFIG as _falcon_mamba
from .gemma2_27b import CONFIG as _gemma2
from .qwen1_5_0_5b import CONFIG as _qwen
from .starcoder2_15b import CONFIG as _sc15
from .starcoder2_3b import CONFIG as _sc3
from .seamless_m4t_medium import CONFIG as _seamless
from .grok_1_314b import CONFIG as _grok
from .mixtral_8x22b import CONFIG as _mixtral
from .internvl2_76b import CONFIG as _internvl
from .zamba2_2_7b import CONFIG as _zamba

ARCHS: Dict[str, ArchConfig] = {
    c.name: c for c in [
        _falcon_mamba, _gemma2, _qwen, _sc15, _sc3, _seamless, _grok,
        _mixtral, _internvl, _zamba,
    ]
}


def get(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return smoke_variant(ARCHS[name[: -len("-smoke")]])
    return ARCHS[name]


def all_names():
    return sorted(ARCHS)
