"""Session: who talks to whom, with which randomness and which triples.

A Session owns the three runtime dependencies that call sites used to
thread by hand (`key`/`comm`/`triples`): the party communicator backend
(`SimComm`, `CoalescingComm`, `MeshComm`, or a counting wrapper), the PRNG
stream protocol keys are drawn from, and a ``beaver.TripleProvider``
deciding where each ReLU call's Beaver triples come from (inline from the
call key, streamed from a TTP key, or popped from a precomputed pool).
"""
from __future__ import annotations

from typing import Optional, Union

import jax

from repro.core import beaver, comm as comm_lib


class Session:
    """Runtime context for private inference.

    - ``comm``: party communicator (default ``SimComm`` — single host,
      party dim materialised; pass ``CountingComm`` to measure.  Mesh
      serving does not read this: ``PrivateModel.serve_step(mesh)``
      builds its own ``CoalescingComm`` over ``MeshComm`` inside
      ``shard_map``).
    - ``key``: base PRNG key (or int seed) for per-request protocol keys;
      ``next_key()`` advances the stream.
    - ``provider``: ``beaver.TripleProvider`` (default ``InlineTTP`` —
      triples derived inline from each call's key, the sim behaviour that
      is bit-identical to the historical ``triples=None`` path).

    Example::

        session = api.Session(key=0)                    # sim defaults
        model = api.compile(afn, params, cfg, plan, session)

        counting = api.Session(comm=comm_lib.CountingComm())  # measure
        pooled = api.Session(key=0).offline(ttp_key, plan, requests=16)
    """

    def __init__(self, key: Union[int, jax.Array, None] = None, comm=None,
                 provider: Optional[beaver.TripleProvider] = None):
        self.comm = comm if comm is not None else comm_lib.SimComm()
        self.provider = provider if provider is not None else beaver.InlineTTP()
        if key is None:
            key = 0
        self._key = jax.random.PRNGKey(key) if isinstance(key, int) else key
        self._base_key = self._key

    def next_key(self) -> jax.Array:
        """One fresh request key off the session's PRNG stream."""
        self._key, k = jax.random.split(self._key)
        return k

    def request_key(self, request_id: int) -> jax.Array:
        """Per-request protocol key, forked deterministically from the
        session *seed* (never from the mutable ``next_key`` stream):
        ``fold_in(seed, request_id)``.  Two submissions with the same id
        get the same key in ANY admission order, so concurrent serving is
        reproducible — a request's protocol randomness cannot depend on
        which other requests happened to be in flight (the serving
        engine's randomness contract; see ``repro.serve``)."""
        return jax.random.fold_in(self._base_key, request_id)

    def offline(self, key, plan, requests: int = 1,
                streams: int = 1) -> "Session":
        """Switch this session to an eagerly pre-generated triple pool
        covering ``requests`` sequential replays of ``plan``, each over
        ``streams`` sibling streams (offline-TTP serving)."""
        self.provider = beaver.EagerTTP(key, plan.triple_specs(),
                                        cone=plan.cone, requests=requests,
                                        streams=streams)
        return self

    def resilient(self, *, max_retries: int = 3,
                  timeout_s: Optional[float] = None,
                  backoff_s: float = 0.0, backoff_cap_s: float = 1.0,
                  fault_plan=None, journal=None,
                  snapshot_dir: Optional[str] = None,
                  snapshot_every: int = 1) -> "Session":
        """Wrap this session's comm in the resilient transport stack
        (``docs/robustness.md``): framed retry/backoff over the current
        backend, with optional deterministic fault injection below it and
        an optional round journal above it for crash/resume.

        Stack (bottom up):
        ``base -> FaultInjectingComm? -> ResilientComm -> JournaledComm?``
        — the engine/``run_streams`` then coalesce on top, so every fused
        round is ONE framed exchange and re-sends never add rounds.

        Example::

            plan = faults.FaultPlan.seeded(7, n_rounds=40)
            session = api.Session(key=0).resilient(fault_plan=plan)
        """
        comm = self.comm
        if fault_plan is not None:
            from repro.core import faults as faults_lib
            comm = faults_lib.FaultInjectingComm(fault_plan, comm)
        comm = comm_lib.ResilientComm(comm, max_retries=max_retries,
                                      timeout_s=timeout_s,
                                      backoff_s=backoff_s,
                                      backoff_cap_s=backoff_cap_s)
        if journal is not None:
            from repro.core import faults as faults_lib
            comm = faults_lib.JournaledComm(comm, journal=journal,
                                            snapshot_dir=snapshot_dir,
                                            snapshot_every=snapshot_every)
        self.comm = comm
        return self

    @classmethod
    def connect(cls, party: int, *, listen=None, peer=None,
                key: Union[int, jax.Array, None] = None,
                provider: Optional[beaver.TripleProvider] = None,
                session_id: str = "", plan_digest: str = "",
                journal=None, snapshot_dir: Optional[str] = None,
                snapshot_every: int = 1, shaper=None,
                timeout_s: Optional[float] = 30.0, max_retries: int = 3,
                backoff_s: float = 0.01, backoff_cap_s: float = 0.5,
                handshake_timeout_s: float = 60.0) -> "Session":
        """A real two-process deployment session: this process is ONE
        party, talking to its peer over TCP (``repro.transport``).

        Exactly one of ``listen``/``peer`` names the link: ``listen``
        binds and accepts (conventionally the lower party index),
        ``peer`` dials with retry while the other process starts up.
        The handshake cross-checks (party complement, ``session_id``,
        ``plan_digest``) and negotiates the journal resume round; the
        comm is then stacked ``SocketComm -> ResilientComm ->
        JournaledComm?`` so real timeouts heal via idempotent re-send and
        a restarted process resumes bit-identically from its journal
        (truncated here to the negotiated common prefix).

        The socket transport is reachable afterwards as
        ``session.transport`` (wire-byte counters, ctrl channel).

        Example (one process per party)::

            s0 = api.Session.connect(0, listen=("127.0.0.1", 9000),
                                     key=7, session_id="demo",
                                     plan_digest=plan.digest())
            s1 = api.Session.connect(1, peer=("127.0.0.1", 9000), ...)
        """
        from repro.transport import SocketComm
        journal_len = len(journal) if journal is not None else 0
        common = dict(party=party, session=session_id, plan=plan_digest,
                      journal_len=journal_len, shaper=shaper,
                      timeout_s=timeout_s)
        if (listen is None) == (peer is None):
            raise ValueError("pass exactly one of listen= / peer=")
        if listen is not None:
            sock = SocketComm.host(listen,
                                   accept_timeout_s=handshake_timeout_s,
                                   **common)
        else:
            sock = SocketComm.dial(peer,
                                   connect_timeout_s=handshake_timeout_s,
                                   **common)
        if journal is not None:
            journal.truncate(sock.negotiated["resume_round"])
        session = cls(key=key, comm=sock, provider=provider)
        session.resilient(max_retries=max_retries, backoff_s=backoff_s,
                          backoff_cap_s=backoff_cap_s, journal=journal,
                          snapshot_dir=snapshot_dir,
                          snapshot_every=snapshot_every)
        session.transport = sock
        return session
