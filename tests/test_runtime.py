"""HLO analyzer, cost model vs HLO collectives, sharding rules, roofline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import beaver, comm as comm_lib, costmodel, gmw, ring, shares
from repro.runtime import sharding as sh
from repro.runtime.hlo_analyzer import analyze, normalize_cost_analysis

# NB: tests run on 1 device; the mesh here is (1, 1) with production names.
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_analyzer_scan_equals_unroll():
    L, B, D = 6, 32, 64

    def mk(scan):
        def step(x, ws):
            def body(h, w):
                return jnp.tanh(h @ w), None
            if scan:
                out, _ = jax.lax.scan(body, x, ws)
            else:
                out = x
                for i in range(L):
                    out, _ = body(out, ws[i])
            return out.sum()
        return step

    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    c_scan = jax.jit(mk(True)).lower(xs, ws).compile()
    c_unroll = jax.jit(mk(False)).lower(xs, ws).compile()
    m_scan = analyze(c_scan.as_text())
    m_unroll = analyze(c_unroll.as_text())
    analytic = 2 * B * D * D * L
    assert m_scan.flops == pytest.approx(analytic, rel=0.01)
    assert m_unroll.flops == pytest.approx(analytic, rel=0.01)
    # new JAX returns a list of per-program dicts; the shim normalizes
    ca = normalize_cost_analysis(c_unroll.cost_analysis())
    assert m_unroll.flops == pytest.approx(ca["flops"], rel=0.02)


def test_costmodel_matches_paper_fractions():
    """Fig. 3: Circuit ~83%, Mult ~7% of ReLU communication at w=64."""
    c = costmodel.relu_cost(10**6, 64)
    frac = {k: v / c.bytes_tx for k, v in c.breakdown.items()}
    assert 0.75 < frac["circuit"] < 0.90
    assert 0.04 < frac["mult"] < 0.10
    assert c.rounds == 10


def test_costmodel_reduction_in_paper_range():
    """Fig. 11: 2.68-8.76x byte reduction for the paper's budgets."""
    from repro.core.hummingbird import HBConfig, HBLayer
    groups = (65536, 32768, 16384, 8192, 4096)
    for width in (6, 8):
        cfg = HBConfig(tuple(HBLayer(k=width + 13, m=13) for _ in groups),
                       groups)
        r = costmodel.reduction_factors(cfg)
        assert 2.0 < r["bytes_reduction"] < 10.0, r
        assert r["bits_discarded_frac"] > 0.85  # paper: 87-91%


def test_costmodel_validated_against_hlo_collectives():
    """The closed-form byte count matches the mesh backend's HLO
    collective-permute payload within 4x (packing/topology overheads).
    Needs 2 host devices, so it runs in a subprocess with its own
    XLA_FLAGS (the main test process keeps the default single device)."""
    import os
    import subprocess
    import sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import beaver, comm as comm_lib, costmodel, gmw, ring
from repro.runtime.hlo_analyzer import analyze

E, w = 2048, 8
cm = comm_lib.SimComm()

def step(lo, hi, tr):
    out = gmw.relu(jax.random.PRNGKey(0), ring.Ring64(lo, hi), tr, cm, k=8, m=0)
    return out.lo, out.hi

mesh = jax.make_mesh((2,), ("party",))
tr = beaver.gen_relu_triples(jax.random.PRNGKey(1), E, w)
shp = NamedSharding(mesh, P("party"))
lo = jax.ShapeDtypeStruct((2, E), jnp.uint32, sharding=shp)
hi = jax.ShapeDtypeStruct((2, E), jnp.uint32, sharding=shp)
with mesh:
    c = jax.jit(step).lower(lo, hi, tr).compile()
m = analyze(c.as_text())
model = costmodel.relu_cost(E, w)
assert m.collective_bytes >= model.bytes_tx * 0.5, (m.collective_bytes, model.bytes_tx)
assert m.collective_bytes <= model.bytes_tx * 4.0, (m.collective_bytes, model.bytes_tx)
print("OK", m.collective_bytes, model.bytes_tx)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_param_spec_rules():
    mesh = _mesh11()
    spec = sh.param_spec("layers/attn/wq/w", (24, 64, 64), mesh, "train")
    assert spec[0] is None  # stacked layer axis never sharded
    spec = sh.param_spec("m/layers/mlp/w_up", (24, 64, 128), mesh, "train")
    assert len(spec) == 3   # optimizer-state paths match the same rules
    spec = sh.param_spec("final_norm/scale", (64,), mesh, "train")
    assert spec == P(None)


def test_cache_spec_rules():
    mesh = _mesh11()
    spec = sh.cache_spec("kv/k", (4, 8, 128, 4, 64), None, mesh)
    assert len(spec) == 5
    spec = sh.cache_spec("ssm/h", (4, 8, 128, 16), None, mesh)
    assert len(spec) >= 3


def test_roofline_terms_shape():
    from repro.configs import SHAPES, get
    from repro.runtime.hlo_analyzer import Metrics
    from repro.runtime.roofline import roofline_terms
    m = Metrics(flops=1e14, bytes=1e11, collective_bytes=1e10)
    out = roofline_terms(get("qwen1.5-0.5b"), SHAPES["train_4k"], m, 256)
    assert set(out) >= {"compute_s", "memory_s", "collective_s", "dominant",
                        "useful_flops_ratio", "roofline_fraction"}
    # 1e14/197e12 = 0.51 s compute > 0.2 s collective > 0.12 s memory
    assert out["dominant"] == "compute_s"


def test_constraints_noop_without_mesh():
    from repro.runtime import constraints
    x = jnp.ones((4, 4))
    y = constraints.shard(x, "dp", "tp")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
