"""Fixed-point error bounds for reduced-ring nonlinearity evaluation.

Closed-form bounds the tests (and the (k, m) search) reason with:

- ``discard_margin(m)``: a DReLU on ring bits [k:m] ignores the low m
  bits; any input with |x_f| >= 2^(m - frac_bits) keeps its sign decision,
  so the margin is the worst-case magnitude below which the reduced ring
  may misclassify.  Monotone nondecreasing in the discarded bits m — the
  property the hypothesis suite checks.
- ``magnitude_bound(k)``: the paper's Theorem-1 regime — values must fit
  the reduced ring's signed range, |x_f| < 2^(k - 1 - frac_bits).
- ``pwl_fixed_point_bound(spec)``: PWL interpolation error plus the
  accumulated +-1 LSB truncations of the public combine (one mul_public
  over J knots).
"""
from __future__ import annotations

from repro.core import fixed

from .pwl import PWLSpec, _gelu, _silu, pwl_max_error


def discard_margin(m: int, frac_bits: int = fixed.DEFAULT_FRAC_BITS) -> float:
    """Worst-case |x_f| below which discarding the low ``m`` ring bits can
    flip a DReLU decision.  0 discarded bits -> exact (margin 0 ulps is
    still one ulp = 2^-frac_bits in value)."""
    if m < 0:
        raise ValueError(f"negative discarded bits: {m}")
    return (2.0 ** m) / (2.0 ** frac_bits)


def magnitude_bound(k: int, frac_bits: int = fixed.DEFAULT_FRAC_BITS) -> float:
    """Theorem-1 magnitude regime of a k-bit reduced ring: fixed-point
    values must satisfy |x_f| < 2^(k - 1 - frac_bits)."""
    return 2.0 ** (k - 1 - frac_bits)


def pwl_fixed_point_bound(spec: PWLSpec,
                          frac_bits: int = fixed.DEFAULT_FRAC_BITS) -> float:
    """Worst-case |f_hat - f| of one fixed-point PWL activation inside the
    knot range: interpolation error + J truncation ulps from the combine."""
    fn = {"silu": _silu, "gelu": _gelu}[spec.name]
    interp = pwl_max_error(spec, fn, margin=0.0)
    return interp + spec.n_knots * (2.0 ** -frac_bits)
