"""Tiny repro.api end-to-end: trace -> plan -> session -> compile -> call
-> serve_step, in seconds.  CI runs this (plus quickstart.py) so API
regressions fail fast outside pytest.

    PYTHONPATH=src python examples/api_smoke.py
"""
import jax
import numpy as np

from repro import api
from repro.configs import RESNET_SMOKE
from repro.core import beaver
from repro.core.hummingbird import HBConfig, HBLayer
from repro.models import resnet


def main():
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 8, 8)) * 0.5

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

    # offline: trace a plan, assign (k, m) per group (last group culled)
    plan = api.trace_plan(afn, params, x.shape, name="smoke")
    hb = HBConfig(tuple([HBLayer(k=21, m=13)] * (plan.n_groups - 1)
                        + [HBLayer(k=13, m=13)]),
                  plan.group_elements)
    plan = plan.with_hb(hb)
    print(f"plan: {len(plan.calls)} ReLU calls, {plan.n_groups} groups, "
          f"{plan.cost().bytes_tx} B/party, {plan.cost().rounds} rounds, "
          f"LAN estimate {plan.estimate(network=api.LAN)*1e3:.2f} ms")

    # JSON round-trip is exact
    assert api.Plan.from_json(plan.to_json()) == plan

    # online: compile and run private inference
    model = api.compile(afn, params, RESNET_SMOKE, plan, api.Session(key=0))
    X = model.encrypt(jax.random.PRNGKey(2), x)
    out = model(X)
    want = np.argmax(np.asarray(afn(params, x)), -1)
    got = np.argmax(out.reveal_np(), -1)
    assert (got == want).all(), (got, want)
    print("private __call__ matches plaintext argmax:", got.tolist())

    # serving: same replay as a step with an offline triple pool
    pool = beaver.gen_plan_triples(jax.random.PRNGKey(3), plan.triple_specs())
    step = model.serve_step()
    lo, hi = step(params, X.data.lo, X.data.hi, pool, jax.random.PRNGKey(4))
    from repro.core import ring, shares, fixed
    served = fixed.decode_np(shares.reconstruct(ring.Ring64(lo, hi)))
    assert (np.argmax(served, -1) == want).all()
    print("serve_step (offline TriplePool) matches: OK")


if __name__ == "__main__":
    main()
