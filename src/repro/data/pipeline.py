"""Deterministic synthetic data pipelines.

Every batch is a pure function of (seed, step), so checkpoint/restart and
elastic re-sharding never replay or skip data — the restarted loop asks
for step N and gets exactly the batch the failed run would have seen.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    """Synthetic LM corpus: a fixed random Markov-ish stream with enough
    structure that cross-entropy demonstrably falls during training."""

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        base = jax.random.randint(k1, (self.batch, self.seq_len), 0,
                                  self.vocab, dtype=jnp.int32)
        # learnable structure: every other token repeats its predecessor
        # shifted by one (the model can reach ~50% of positions predictable)
        shifted = jnp.roll(base, 1, axis=1)
        mask = (jnp.arange(self.seq_len) % 2).astype(jnp.int32)
        tokens = jnp.where(mask, (shifted + 1) % self.vocab, base)
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full((self.batch, 1), -1, jnp.int32)], axis=1)
        return {"tokens": tokens, "labels": labels}


@dataclasses.dataclass(frozen=True)
class ImagePipeline:
    """Synthetic CIFAR-like images with linearly separable structure
    (class = sign pattern of region means), deterministic by index."""

    n_classes: int = 10
    hw: int = 32
    seed: int = 0

    def take(self, n: int, offset: int = 0):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), offset)
        kx, kn = jax.random.split(key)
        ys = jnp.arange(n) % self.n_classes
        protos = jax.random.normal(
            jax.random.PRNGKey(self.seed + 1),
            (self.n_classes, 3, self.hw, self.hw)) * 1.5
        noise = jax.random.normal(kx, (n, 3, self.hw, self.hw))
        xs = protos[ys] + noise
        return xs, ys.astype(jnp.int32)
