"""Sharded checkpointing: npz payloads + msgpack manifest, atomic commit.

Layout: <dir>/step_<N>/
  manifest.msgpack   - pytree structure, shapes, dtypes, step metadata
  arrays.npz         - flattened leaves keyed by index
  COMMITTED          - sentinel written last (atomic rename of tmp dir)

Restores re-shard onto whatever mesh/sharding the caller provides (elastic
down/up-scaling: a checkpoint written on N hosts loads on M), and the
async writer overlaps serialization with the next training step.
"""
from __future__ import annotations

import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _paths(tree) -> List[str]:
    out = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out.append("/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path))
    return out


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None):
    """Synchronous atomic checkpoint write."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _flatten(tree)
    arrays = {str(i): np.asarray(jax.device_get(x)) for i, x in enumerate(leaves)}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "paths": _paths(tree),
        "shapes": [list(a.shape) for a in arrays.values()],
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "extra": extra or {},
        "time": time.time(),
    }
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))
    (tmp / "COMMITTED").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _prune(ckpt_dir, keep=3)


def _prune(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.glob("step_*") if (p / "COMMITTED").exists())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
                   if (p / "COMMITTED").exists())
    return steps[-1] if steps else None


def load_manifest(ckpt_dir: str, step: Optional[int] = None) -> Dict:
    """The manifest dict of a committed checkpoint, without loading any
    arrays — lets a caller learn the leaf layout before building the
    ``tree_like`` template that ``restore`` requires."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    return msgpack.unpackb((d / "manifest.msgpack").read_bytes())


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of `tree_like`; re-shards with
    `shardings` (a pytree of NamedSharding) if given — this is the elastic
    path: the checkpoint's host/mesh layout is irrelevant."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = msgpack.unpackb((d / "manifest.msgpack").read_bytes())
    data = np.load(d / "arrays.npz")
    leaves, treedef = _flatten(tree_like)
    assert len(leaves) == len(manifest["paths"]), (
        f"checkpoint has {len(manifest['paths'])} leaves, "
        f"model expects {len(leaves)}")
    out = []
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves))
    for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = data[str(i)]
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out), manifest


class AsyncCheckpointer:
    """Overlaps checkpoint serialization with training (one in flight)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        self.wait()
        # device_get on the main thread (jax arrays are not thread-movable
        # mid-step), serialize + write on the worker
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra)
            except Exception as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
