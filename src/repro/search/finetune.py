"""§4.1.3 finetuning: retrain briefly with approximate ReLU in the loop.

The reduced-ring sign estimate is piecewise-constant in x, so we use a
straight-through estimator: forward uses the simulated HummingBird ReLU,
backward uses the exact ReLU gradient.  The paper reports this recovers
0.95-7.05% accuracy at aggressive budgets (Table 3); our synthetic-data
benchmark reproduces the recovery mechanism.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.hummingbird import HBConfig
from repro.train import optimizer as opt_lib
from . import simulator


def ste_hb_relu(x, k: int, m: int, key):
    """Forward: approximate ReLU; backward: exact ReLU gradient."""
    approx = simulator.simulated_hb_relu(x, k, m, key)
    exact = jax.nn.relu(x)
    return exact + jax.lax.stop_gradient(approx - exact)


def make_ste_relu(cfg: HBConfig, key) -> Callable:
    keys = jax.random.split(key, max(cfg.n_groups, 1))

    def relu_fn(x, g):
        layer = cfg.layers[g]
        if layer.k >= 64 and layer.m == 0:
            return jax.nn.relu(x)
        return ste_hb_relu(x, layer.k, layer.m, keys[g])

    return relu_fn


def finetune(apply_fn, params, xs, ys, hb_cfg: HBConfig, key, *,
             epochs: int = 2, batch: int = 64, lr: float = 1e-3):
    """A few epochs of cross-entropy finetuning with the approximate ReLU."""
    opt = opt_lib.SGD(schedule=opt_lib.Schedule(peak_lr=lr, warmup_steps=0,
                                                decay_steps=0), momentum=0.9)
    opt_state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    relu_key, key = jax.random.split(key)

    def loss_fn(p, xb, yb, rkey):
        relu_fn = make_ste_relu(hb_cfg, rkey)
        logits = apply_fn(p, xb, relu_fn=relu_fn)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.take_along_axis(logp, yb[:, None], axis=-1).mean()

    @jax.jit
    def train_step(p, opt_state, step, xb, yb, rkey):
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, yb, rkey)
        p2, opt2, _ = opt.update(grads, opt_state, p, step)
        return p2, opt2, step + 1, loss

    n = xs.shape[0]
    losses = []
    for epoch in range(epochs):
        perm_key, relu_key, key = jax.random.split(key, 3)
        order = jax.random.permutation(perm_key, n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            params, opt_state, step, loss = train_step(
                params, opt_state, step, xs[idx], ys[idx], relu_key)
            losses.append(float(loss))
    return params, losses
