"""Closed-form communication cost model for the GMW ReLU protocol.

Bytes and rounds are exact deterministic functions of (n_elements, ring
width); tests validate these formulas against collective-permute bytes
parsed from the compiled mesh-backend HLO, and the benchmarks use them to
reproduce the paper's Figure 3 / Figure 11 communication numbers.

All byte counts are *per party per direction* (what one party transmits);
with 2 parties, total wire traffic is 2x these numbers.

Every cost here is derived from ``core.schedule`` — the deterministic
round-timeline simulator of the fused engine — so rounds, per-round
bytes and the per-phase breakdown all come from the same source of truth
``CoalescingComm`` is validated against (``schedule`` is import-light and
sits below the protocol modules, which also removes the historical
costmodel -> gmw lazy-import cycle around ``cone_sets``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from . import schedule as schedule_lib
from .hummingbird import HBConfig, RING_BITS
from .schedule import RING_BYTES, WORD_BYTES  # noqa: F401  (re-export)


@dataclasses.dataclass(frozen=True)
class CommCost:
    bytes_tx: int                 # per party, one direction
    rounds: int
    breakdown: Dict[str, int]     # paper Figure 3 categories

    def __add__(self, other: "CommCost") -> "CommCost":
        bd = dict(self.breakdown)
        for k, v in other.breakdown.items():
            bd[k] = bd.get(k, 0) + v
        return CommCost(self.bytes_tx + other.bytes_tx,
                        self.rounds + other.rounds, bd)

    @staticmethod
    def zero() -> "CommCost":
        return CommCost(0, 0, {})


def _from_schedule(sched: schedule_lib.Schedule) -> CommCost:
    return CommCost(sched.bytes_tx, sched.n_rounds, sched.phase_bytes())


def relu_cost(n_elements: int, w: int = RING_BITS,
              cone: bool = False) -> CommCost:
    """One ReLU over n_elements with a w-bit DReLU ring (w = k - m).

    w = 0 is the culled identity layer (HBLayer.is_identity) and
    n_elements = 0 the empty-batch stream: zero bytes, zero rounds.
    cone=True prices the MSB-cone-pruned adder (same rounds except for
    skipped empty cone levels, O(w) gates instead of O(w log w) —
    EXPERIMENTS.md §Perf iteration C2).  Delegates to the round-schedule
    simulator (``core.schedule.stream_timeline``)."""
    return _from_schedule(schedule_lib.simulate([(n_elements, w)], cone=cone))


def model_relu_cost(cfg: HBConfig) -> CommCost:
    """Total ReLU communication of a model under an HBConfig."""
    total = CommCost.zero()
    for layer, n in zip(cfg.layers, cfg.group_elements):
        total = total + relu_cost(n, layer.width)
    return total


def relu_many_cost(specs, cone: bool = False,
                   auto_batch: bool = True) -> CommCost:
    """Round-fused cost of sibling ReLU groups evaluated by ``relu_many``.

    specs: iterable of (n_elements, width) — or (n_elements, width,
    batch_key) to control auto-batching exactly as the engine does (it
    merges streams of identical (n_elements, k, m) into the batch
    dimension; the default key is (n_elements, width)).  Distinct groups
    each send their own payload per round but every round is ONE coalesced
    exchange, so rounds = max over groups; auto-batched groups additionally
    repack into one payload, which can only shrink bytes.  This is the
    counter pair CoalescingComm reports and tests validate against —
    delegates to ``core.schedule.simulate``.
    """
    return _from_schedule(
        schedule_lib.simulate(specs, cone=cone, auto_batch=auto_batch))


def fused_model_relu_cost(cfg: HBConfig, streams: int,
                          cone: bool = False) -> CommCost:
    """Model-level round-fused cost: `streams` sibling inference streams
    evaluated by relu_many at every ReLU layer.  Identical sibling
    streams auto-batch, so per layer the engine runs one batched stream
    of ``streams * n`` elements; rounds are paid once per layer."""
    total = CommCost.zero()
    for layer, n in zip(cfg.layers, cfg.group_elements):
        total = total + relu_many_cost([(n, layer.width)] * streams,
                                       cone=cone)
    return total


def reduction_factors(cfg: HBConfig) -> Dict[str, float]:
    base = model_relu_cost(HBConfig.exact(cfg.group_elements))
    hb = model_relu_cost(cfg)
    return {
        "bytes_reduction": base.bytes_tx / max(1, hb.bytes_tx),
        "rounds_reduction": base.rounds / max(1, hb.rounds),
        "bits_discarded_frac": 1.0 - cfg.budget_fraction(),
    }


def latency_model(cost: CommCost, bandwidth_bps: float, rtt_s: float,
                  compute_s: float = 0.0) -> float:
    """End-to-end latency estimate: serialization + per-round RTT + compute.

    This is the projection methodology the paper uses for its WAN numbers
    (§5.2: communication measured, then scaled by assumed bandwidth).
    """
    wire = 2 * cost.bytes_tx * 8 / bandwidth_bps   # both directions share the link
    return wire + cost.rounds * rtt_s + compute_s
