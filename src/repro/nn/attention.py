"""GQA attention with RoPE, sliding windows, logit softcap, KV caches.

Training/prefill use a flash-style chunked attention: an unrolled outer
loop over query chunks with an inner ``lax.scan`` over key/value chunks and
an online-softmax accumulator.  Causal block skipping is structural: query
chunk i only scans kv chunks 0..i, so compiled FLOPs are ~S^2/2 (the HLO
analyzer sees one while loop per q-chunk with its own trip count).

Decode attends a single new token against the full cache (linear in cache
length), with optional sliding-window masking; the cache layout
(B, S, n_kv, head_dim) shards batch on `data` and kv-heads (or head_dim
when n_kv < mesh model size) on `model`.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.runtime import constraints
from . import common

NEG_INF = -2.0e38


def attn_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qkv_bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(ks[0], d_model, n_heads * head_dim, dtype,
                                with_bias=qkv_bias),
        "wk": common.dense_init(ks[1], d_model, n_kv * head_dim, dtype,
                                with_bias=qkv_bias),
        "wv": common.dense_init(ks[2], d_model, n_kv * head_dim, dtype,
                                with_bias=qkv_bias),
        "wo": common.dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    return p


def _project_qkv(params, x, n_heads, n_kv, head_dim, positions, rope_theta):
    b, s, _ = x.shape
    q = common.dense(params["wq"], x).reshape(b, s, n_heads, head_dim)
    k = common.dense(params["wk"], x).reshape(b, s, n_kv, head_dim)
    v = common.dense(params["wv"], x).reshape(b, s, n_kv, head_dim)
    if rope_theta:
        q = common.rope(q, positions, rope_theta)
        k = common.rope(k, positions, rope_theta)
    return q, k, v


def _chunk_scores(q, k, scale, cap):
    """q: (B, Cq, K, G, Dh); k: (B, Ck, K, Dh) -> (B, K, G, Cq, Ck)."""
    s = jnp.einsum("bqkgd,bckd->bkgqc", q, k) * scale
    return common.softcap(s, cap)


def flash_attention(q, k, v, *, q_offset, chunk_q: int, chunk_k: int,
                    window=None, cap: float = 0.0) -> jax.Array:
    """Causal chunked attention. q: (B,S,H,Dh); k,v: (B,S,K,Dh).

    Sequences that don't divide the chunk sizes are padded at the end;
    padded keys sit at positions > every real query so the causal mask
    removes them, and padded query rows are sliced off the output.
    """
    b, s_real, h, dh = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    scale = dh ** -0.5
    cq = min(chunk_q, s_real)
    ck = min(chunk_k, s_real)
    import math as _math
    mult = cq * ck // _math.gcd(cq, ck)
    pad = (-s_real) % mult
    if pad:
        widths = [(0, 0), (0, pad), (0, 0), (0, 0)]
        q = jnp.pad(q, widths)
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    s = s_real + pad
    nq, nk = s // cq, s // ck
    qc = q.reshape(b, nq, cq, n_kv, g, dh)
    kc = k.reshape(b, nk, ck, n_kv, dh)
    vc = v.reshape(b, nk, ck, n_kv, dh)
    # sequence-parallel attention over `model`: each shard computes all
    # heads for a slice of the query chunk; k/v chunks are replicated over
    # model.  Always divisible (cq % 16 == 0), unlike head counts, and the
    # softmax stays local to the shard.  (See EXPERIMENTS.md §Perf iter 1:
    # without these constraints XLA replicates attention over `model`.)
    kc = constraints.shard(kc, "dp", None, None, None, None)
    vc = constraints.shard(vc, "dp", None, None, None, None)
    out = []
    for iq in range(nq):  # unrolled: block-level causal skipping
        q_i = qc[:, iq].astype(jnp.float32)
        q_i = constraints.shard(q_i, "dp", "tp", None, None, None)
        q_pos = q_offset + iq * cq + jnp.arange(cq)
        n_vis = iq * cq // ck + 1  # kv chunks visible to this q chunk

        def body(carry, inp):
            m_prev, l_prev, acc = carry
            k_j, v_j, jk = inp
            k_pos = jk * ck + jnp.arange(ck)
            sc = _chunk_scores(q_i, k_j.astype(jnp.float32), scale, cap)
            mask = q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m_prev, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckd->bkgqd", p, v_j.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = constraints.shard(
            jnp.full((b, n_kv, g, cq), NEG_INF, jnp.float32),
            "dp", None, None, "tp")
        l0 = constraints.shard(
            jnp.zeros((b, n_kv, g, cq), jnp.float32), "dp", None, None, "tp")
        a0 = constraints.shard(
            jnp.zeros((b, n_kv, g, cq, dh), jnp.float32),
            "dp", None, None, "tp", None)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.moveaxis(kc[:, :n_vis], 1, 0), jnp.moveaxis(vc[:, :n_vis], 1, 0),
             jnp.arange(n_vis)))
        o = acc / jnp.maximum(l[..., None], 1e-30)
        out.append(jnp.moveaxis(o, 3, 1).reshape(b, cq, h, dh))
    full = jnp.concatenate(out, axis=1).astype(q.dtype)
    return full[:, :s_real]


def attention(params, x, *, n_heads: int, n_kv: int, head_dim: int,
              positions=None, rope_theta: float = 10000.0, window=None,
              cap: float = 0.0, chunk_q: int = 512, chunk_k: int = 1024):
    """Full-sequence causal attention (training / prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim, positions,
                           rope_theta)
    o = flash_attention(q, k, v, q_offset=0, chunk_q=chunk_q, chunk_k=chunk_k,
                        window=window, cap=cap)
    return common.dense(params["wo"], o.reshape(b, s, n_heads * head_dim))


def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
    }


def attention_decode(params, x, cache, pos, *, n_heads: int, n_kv: int,
                     head_dim: int, rope_theta: float = 10000.0,
                     window=None, cap: float = 0.0):
    """One-token decode. x: (B, 1, D); pos: scalar current position.

    Returns (y, new_cache).  Attends over cache[: pos+1] via masking.
    """
    b = x.shape[0]
    s_max = cache["k"].shape[1]
    positions = jnp.full((b, 1), pos)
    q, k_new, v_new = _project_qkv(params, x, n_heads, n_kv, head_dim,
                                   positions, rope_theta)

    def _cache_constraint(t):
        # context-parallel decode: batch over dp, *sequence* over tp — the
        # per-layer collective becomes a (b, k, g, 1[, dh]) log-sum-exp
        # combine instead of head_dim-sharded score reductions
        # (EXPERIMENTS.md §Perf iteration B2).  long_500k (B=1): sequence
        # over both axes.
        if constraints.axis_divides("dp", t.shape[0]):
            return constraints.shard(t, "dp", "tp", None, None)
        return constraints.shard(t, None, ("dp", "tp"), None, None)

    k_cache = _cache_constraint(jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1))
    v_cache = _cache_constraint(jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1))
    g = n_heads // n_kv
    qh = q.reshape(b, 1, n_kv, g, head_dim).astype(jnp.float32)
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qh,
                    k_cache.astype(jnp.float32)) * head_dim ** -0.5
    sc = common.softcap(sc, cap)
    kpos = jnp.arange(s_max)
    mask = kpos <= pos
    if window is not None:
        mask &= kpos > pos - window
    sc = jnp.where(mask, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(jnp.float32))
    o = o.reshape(b, 1, n_heads * head_dim).astype(x.dtype)
    y = common.dense(params["wo"], o)
    return y, {"k": k_cache, "v": v_cache}
