"""Paper Fig. 10: overhead breakdown (communication vs compute) as the
budget tightens — HummingBird shifts the bottleneck toward compute."""
import time

import jax

from repro import api
from repro.configs.resnet import RESNET18
from repro.core import costmodel
from repro.core.hummingbird import HBConfig, HBLayer
from repro.models import resnet

LAN_BW, LAN_RTT = api.LAN.bandwidth_bps, api.LAN.rtt_s
BATCH = 512


def run():
    rows = []
    params = resnet.init(jax.random.PRNGKey(0), RESNET18)
    groups = [g * BATCH for g in resnet.relu_group_elements(params, RESNET18)]
    # A100-class compute floor from the paper's Fig.10 (7% of 26.8s)
    compute_s = 1.9
    for name, cfg in (
        ("crypten64", HBConfig.exact(groups)),
        ("8of64", HBConfig(tuple(HBLayer(k=21, m=13) for _ in groups),
                           tuple(groups))),
    ):
        t0 = time.time()
        cost = costmodel.model_relu_cost(cfg)
        comm_s = costmodel.latency_model(cost, LAN_BW, LAN_RTT, 0.0)
        total = comm_s + compute_s
        us = (time.time() - t0) * 1e6
        rows.append((f"fig10_{name}", us,
                     f"comm_frac={comm_s/total:.3f};comm_s={comm_s:.2f};"
                     f"compute_s={compute_s:.2f}"))
        # round-fused engine: 4 sibling streams share rounds (relu_many),
        # amortizing the per-round RTT term of the comm fraction.
        S = 4
        t0 = time.time()
        fused = costmodel.fused_model_relu_cost(cfg, S)
        comm_f = costmodel.latency_model(fused, LAN_BW, LAN_RTT, 0.0) / S
        total_f = comm_f + compute_s
        us = (time.time() - t0) * 1e6
        rows.append((f"fig10_{name}_fused{S}", us,
                     f"comm_frac={comm_f/total_f:.3f};comm_s={comm_f:.2f};"
                     f"compute_s={compute_s:.2f}"))
    return rows
