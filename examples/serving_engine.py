"""Request-level serving: concurrent tenants share fused protocol rounds.

Three requests from two tenants — two identical shapes and one ragged —
are submitted to an ``InferenceEngine`` and served as ONE fused
micro-batch: every request advances through the GMW protocol in lockstep,
so the batch pays max-over-requests rounds instead of the sum, while each
request keeps its own PRNG stream (forked from its request id) and its
tenant's metered triple budget.

    PYTHONPATH=src python examples/serving_engine.py
"""
import argparse

import jax
import numpy as np

from repro import api
from repro.configs import RESNET_SMOKE
from repro.core import schedule as schedule_lib
from repro.models import resnet
from repro.serve import BatchPolicy, InferenceEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--network", default="lan", choices=["lan", "wan",
                                                         "highbw"])
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--merge-identical", action="store_true",
                    help="opt into cross-request relu_many auto-batching")
    args = ap.parse_args()

    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

    plan = api.trace_plan(afn, params, (2, 3, 8, 8), name=RESNET_SMOKE.name)
    plan = plan.with_hb(api.HBConfig(
        tuple([api.HBLayer(k=21, m=13)] * plan.n_groups),
        plan.group_elements))

    engine = InferenceEngine(
        afn, params, RESNET_SMOKE, plan, api.Session(key=0),
        policy=BatchPolicy(network=args.network, max_batch=args.max_batch,
                           merge_identical=args.merge_identical),
        tenant_budgets={"bob": 200_000})

    mix = [("alice", (2, 3, 8, 8)), ("bob", (2, 3, 8, 8)),
           ("alice", (1, 3, 8, 8))]
    futures = []
    for i, (tenant, shape) in enumerate(mix):
        x = jax.random.normal(jax.random.PRNGKey(10 + i), shape) * 0.5
        futures.append(engine.submit(tenant, x))

    outs = [f.result().reveal_np() for f in futures]   # drains the queue
    for (tenant, shape), out in zip(mix, outs):
        print(f"{tenant}: {shape} -> logits argmax "
              f"{np.argmax(out, -1).tolist()}")

    rep = engine.reports[0]
    print(f"\none fused micro-batch of {rep.n_requests} requests: "
          f"{rep.measured_rounds} rounds measured "
          f"(schedule predicted {rep.predicted_rounds}); serial execution "
          f"would pay {rep.serial_rounds} -> "
          f"{rep.rounds_saved_ratio:.1f}x rounds saved")
    for tenant in ("alice", "bob"):
        print(f"{tenant} triples: {engine.tenant_usage(tenant)}")

    print("\nmerged-batch Gantt (first ReLU call of the batch):")
    specs = [engine.plan_for_shape((b, 3, 8, 8)).call_specs()[:1]
             for _, (b, *_rest) in mix]
    print(schedule_lib.simulate_merged(specs, auto_batch=False).gantt())


if __name__ == "__main__":
    main()
