"""Pallas TPU kernel: mod-2^64 matmul via balanced 8-bit digit planes.

The MPC linear layers multiply Ring64 shares by public int32 fixed-point
weights.  TPUs have no 64-bit integer MXU path, so the contraction is
decomposed into signed 8-bit digit planes (see core/ring.py):

    x = sum_i dx_i 2^(8i)  (8 planes, int8)     w = sum_j dw_j 2^(8j)  (5 planes)
    x @ w mod 2^64 = sum_{s<8} ( sum_{i+j=s} dx_i @ dw_j ) << 8s

Each dx_i @ dw_j is a native MXU s8 x s8 -> s32 matmul.  The kernel blocks
(M, N, K) into VMEM tiles, keeps the 8 shifted accumulators in VMEM scratch
across the K sweep, and recombines into (lo, hi) uint32 limbs with explicit
carries in the epilogue.  MXU alignment: block dims are multiples of 128
(tests use smaller tiles in interpret mode).

int32 accumulator safety: |sum_s| <= 5 * K * 128 * 128, so K <= 26214 per
call; ops.py chunks larger K and ring-adds the partials.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_U32 = jnp.uint32

# (BM, BK, BN) VMEM tile; production TPU config uses (256, 512, 256)
DEFAULT_BLOCK = (256, 512, 256)

# (i, j) digit-plane pairs contributing to shift s = i + j (j < 5, s < 8)
_PAIRS = [(i, j) for i in range(8) for j in range(5) if i + j < 8]


def _add64(alo, ahi, blo, bhi):
    lo = alo + blo
    carry = (lo < alo).astype(_U32)
    return lo, ahi + bhi + carry


def _shift64(lo, hi, s_bits: int):
    if s_bits == 0:
        return lo, hi
    if s_bits < 32:
        return lo << s_bits, (hi << s_bits) | (lo >> (32 - s_bits))
    if s_bits == 32:
        return jnp.zeros_like(lo), lo
    return jnp.zeros_like(lo), lo << (s_bits - 32)


def _kernel(dx_ref, dw_ref, lo_ref, hi_ref, acc_ref, *, nk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dx = dx_ref[...]   # (8, BM, BK) int8
    dw = dw_ref[...]   # (5, BK, BN) int8
    for s in range(8):
        partial = None
        for (i, j) in _PAIRS:
            if i + j != s:
                continue
            prod = jax.lax.dot_general(
                dx[i], dw[j], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            partial = prod if partial is None else partial + prod
        if partial is not None:
            acc_ref[s, :, :] += partial

    @pl.when(k_step == nk - 1)
    def _epilogue():
        lo = jnp.zeros(lo_ref.shape, _U32)
        hi = jnp.zeros(hi_ref.shape, _U32)
        for s in range(8):
            acc = acc_ref[s, :, :]
            slo = acc.astype(_U32)
            shi = jnp.where(acc < 0, _U32(0xFFFFFFFF), _U32(0))
            slo, shi = _shift64(slo, shi, 8 * s)
            lo, hi = _add64(lo, hi, slo, shi)
        lo_ref[...] = lo
        hi_ref[...] = hi


def ring_matmul_pallas(dx: jax.Array, dw: jax.Array, *,
                       block=DEFAULT_BLOCK, interpret: bool = True):
    """dx: (8, M, K) int8 digit planes of the shares;
    dw: (5, K, N) int8 digit planes of the public weights.
    Returns (lo, hi) uint32 [M, N] = digits recombined mod 2^64.
    M, K, N must be multiples of the block dims (ops.py pads)."""
    _, m, k = dx.shape
    _, _, n = dw.shape
    bm, bk, bn = block
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=grid[2]),
        out_shape=(jax.ShapeDtypeStruct((m, n), _U32),
                   jax.ShapeDtypeStruct((m, n), _U32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((8, bm, bk), lambda im, in_, ik: (0, im, ik)),
            pl.BlockSpec((5, bk, bn), lambda im, in_, ik: (0, ik, in_)),
        ],
        out_specs=(pl.BlockSpec((bm, bn), lambda im, in_, ik: (im, in_)),
                   pl.BlockSpec((bm, bn), lambda im, in_, ik: (im, in_))),
        scratch_shapes=[pltpu.VMEM((8, bm, bn), jnp.int32)],
        interpret=interpret,
    )(dx, dw)
