import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the
# device count at first init, and the dry-run needs 512 host devices to
# build the production meshes.  (Smoke tests / benches see 1 device.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the right step (train_step / prefill_step /
serve_step), compiles it for the 16x16 single-pod and 2x16x16 multi-pod
meshes, records memory_analysis / cost_analysis / HLO-derived roofline
terms (trip-count corrected), and writes one JSON artifact per cell under
results/dryrun/.  `--mpc` additionally dry-runs the paper's MPC ResNet
serving step on the (party=2, data=256) mesh, baseline vs HummingBird.

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod-only|--singlepod-only]
  python -m repro.launch.dryrun --mpc
"""
import argparse
import functools
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get as get_arch, all_names, shape_applicable
from repro.configs.resnet import RESNET18, RESNET50
from repro.core.hummingbird import HBConfig, HBLayer
from repro.launch import serve as serve_lib, specs as specs_lib
from repro.launch import train as train_lib
from repro.launch.mesh import make_mpc_mesh, make_production_mesh
from repro.models import encdec, lm
from repro.runtime.hlo_analyzer import analyze, normalize_cost_analysis
from repro.runtime.roofline import roofline_terms
from repro.train import optimizer as opt_lib

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _step_fn(cfg, shape):
    if shape.kind == "train":
        opt = opt_lib.AdamW()
        return train_lib.make_train_step(
            cfg, opt, n_microbatches=cfg.train_microbatches)
    if shape.kind == "prefill":
        return serve_lib.make_prefill_step(cfg, max_len=shape.seq_len)
    return serve_lib.make_decode_step(cfg)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides=None) -> dict:
    cfg = get_arch(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        args, kwargs = specs_lib.input_specs(cfg, shape, mesh)
        fn = _step_fn(cfg, shape)
        lowered = jax.jit(fn).lower(*args, **kwargs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ca = normalize_cost_analysis(compiled.cost_analysis())
        ma = compiled.memory_analysis()
        hlo = analyze(compiled.as_text())
    n_chips = 512 if multi_pod else 256
    terms = roofline_terms(cfg, shape, hlo, n_chips)
    out = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_analysis": {"flops": ca.get("flops"),
                          "bytes": ca.get("bytes accessed")},
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "total_bytes": (ma.argument_size_in_bytes +
                            ma.output_size_in_bytes + ma.temp_size_in_bytes),
        },
        "hlo": {"flops": hlo.flops, "bytes": hlo.bytes,
                "collective_bytes": hlo.collective_bytes,
                "collectives": hlo.collective_counts},
        "roofline": terms,
    }
    return out


def run_mpc_cell(rcfg, hb, tag: str, cone: bool = False) -> dict:
    mesh = make_mpc_mesh()
    batch = 512  # the paper's Figure 1 setup: 512 CIFAR inferences
    t0 = time.time()
    with mesh:
        params, lo, hi, triples, key = serve_lib.mpc_input_specs(
            rcfg, batch, mesh, hb, cone=cone)
        step = serve_lib.make_mpc_serve_step(rcfg, hb, cone=cone)
        lowered = jax.jit(step).lower(params, lo, hi, triples, key)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        hlo = analyze(compiled.as_text())
    from repro.runtime.roofline import mpc_roofline_terms
    terms = mpc_roofline_terms(hlo, n_chips=512)
    return {
        "arch": f"{rcfg.name}-mpc-{tag}", "shape": "cifar_b512",
        "multi_pod": True, "status": "ok", "n_chips": 512,
        "compile_s": round(time.time() - t0, 2),
        "memory": {"argument_bytes": ma.argument_size_in_bytes,
                   "temp_bytes": ma.temp_size_in_bytes},
        "hlo": {"flops": hlo.flops, "bytes": hlo.bytes,
                "collective_bytes": hlo.collective_bytes,
                "collectives": hlo.collective_counts},
        "roofline": terms,
    }


def run_lm_mpc_cell(acfg, budget: str, batch: int = 1, seq: int = 32) -> dict:
    """Trace-only dry-run of the private LM: the reduced-ring plan (PWL
    activations, ReLU attention, Beaver opens) and its exact schedule
    prediction.  The LM serves through the sim engine rather than the
    mesh-native step, so the cell reports the round/byte/latency economy
    instead of lowered HLO."""
    from repro.api.plan import LAN, WAN
    t0 = time.time()
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)   # abstract PRNG key
    params = jax.eval_shape(functools.partial(lm.init, cfg=acfg), key_spec)
    plan = lm.trace(params, acfg, batch, seq)
    if budget != "baseline":
        k, m = (21, 0) if budget == "eco" else (21, 13)
        hb = HBConfig(tuple(HBLayer(k=k, m=m)
                            for _ in range(plan.hb.n_groups)),
                      plan.hb.group_elements)
        plan = lm.trace(params, acfg, batch, seq, hb=hb)
    sched = plan.schedule()
    return {
        "arch": f"{acfg.name}-mpc-lm-{budget}", "shape": f"b{batch}_s{seq}",
        "multi_pod": False, "status": "ok", "n_chips": 1,
        "compile_s": round(time.time() - t0, 2),
        "lm": {"n_relu_calls": len(plan.calls), "n_opens": len(plan.opens),
               "rounds": sched.n_rounds, "bytes_tx": sched.bytes_tx,
               "budget_fraction": plan.hb.budget_fraction(),
               "latency_lan_s": sched.latency(LAN.bandwidth_bps, LAN.rtt_s),
               "latency_wan_s": sched.latency(WAN.bandwidth_bps, WAN.rtt_s)},
    }


def hb_config_for(rcfg, budget: str):
    """Representative found configs (search engine output, see §Perf)."""
    n_groups = 1 + len(rcfg.stage_blocks)
    if budget == "baseline":
        return None
    if budget == "eco":
        layers = tuple(HBLayer(k=21, m=0) for _ in range(n_groups))
    else:  # 8/64
        layers = tuple(HBLayer(k=21, m=13) for _ in range(n_groups))
    return HBConfig(layers, tuple(1 for _ in range(n_groups)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mpc", action="store_true")
    ap.add_argument("--mpc-arch", default=None,
                    help="registry arch name for a private-LM MPC cell "
                         "(e.g. qwen1.5-0.5b-smoke); default: the paper's "
                         "ResNet pair")
    ap.add_argument("--mpc-budget", default="8of64",
                    choices=["baseline", "eco", "8of64", "8of64cone"])
    ap.add_argument("--multipod-only", action="store_true")
    ap.add_argument("--singlepod-only", action="store_true")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ArchConfig overrides (perf iteration)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    meshes = [False, True]
    if args.multipod_only:
        meshes = [True]
    if args.singlepod_only:
        meshes = [False]

    if args.mpc:
        if args.mpc_arch:
            # LM family resolves by registry name — same idiom as the
            # ResNet pair below, but through configs.get
            acfg = get_arch(args.mpc_arch)
            budget = args.mpc_budget.replace("cone", "")
            try:
                out = run_lm_mpc_cell(acfg, budget)
            except Exception as e:
                out = {"arch": f"{acfg.name}-mpc-lm-{budget}",
                       "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
            name = f"mpc_lm_{acfg.name}_{budget}{args.tag}.json"
            (RESULTS / name).write_text(json.dumps(out, indent=2))
            print(json.dumps({k: v for k, v in out.items()
                              if k not in ("trace",)}, indent=2))
            return
        for rcfg in (RESNET18, RESNET50):
            cone = args.mpc_budget.endswith("cone")
            hb = hb_config_for(rcfg, args.mpc_budget.replace("cone", ""))
            tag = args.mpc_budget
            try:
                out = run_mpc_cell(rcfg, hb, tag, cone=cone)
            except Exception as e:
                out = {"arch": f"{rcfg.name}-mpc-{tag}", "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
            name = f"mpc_{rcfg.name}_{tag}{args.tag}.json"
            (RESULTS / name).write_text(json.dumps(out, indent=2))
            print(json.dumps({k: v for k, v in out.items()
                              if k not in ("trace",)}, indent=2))
        return

    cells = []
    archs = [args.arch] if args.arch else all_names()
    shapes = [args.shape] if args.shape else list(SHAPES)
    overrides = json.loads(args.override) if args.override else None
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                cells.append((arch, shape_name, multi_pod))

    for arch, shape_name, multi_pod in cells:
        tag = "multi" if multi_pod else "single"
        try:
            out = run_cell(arch, shape_name, multi_pod, overrides)
        except Exception as e:
            out = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        fname = f"{arch}_{shape_name}_{tag}{args.tag}.json"
        (RESULTS / fname).write_text(json.dumps(out, indent=2))
        brief = {k: out.get(k) for k in
                 ("arch", "shape", "multi_pod", "status", "compile_s",
                  "error", "reason")}
        brief["roofline"] = out.get("roofline", {})
        print(json.dumps(brief))


if __name__ == "__main__":
    main()
