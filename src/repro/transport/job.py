"""Job bundles: the offline artifact a deployed party process loads.

A *job directory* is everything the two party hosts need to run the same
private inference without any shared memory — the deployment analogue of
the arguments a single-process test passes around:

    job.json     config name, params seed, protocol/infer keys, TTP seed
    plan.json    the traced ``api.Plan`` (handshake-checked by digest)
    party0.npz   party 0's input share rows + its slice of the triple pool
    party1.npz   party 1's rows/slices (same keys, other index)

Shares and triples are generated ONCE (by ``write_job``, typically on the
machine playing trusted dealer / client) and split by party with
``beaver.slice_party_pool`` — each process only ever sees its own rows,
which is the whole point of the two-server model.  Model *weights* are
public in this threat model (both parties re-derive them from
``params_seed``), matching the paper's setup where only activations are
secret-shared.

The triple pool's pytree structure is reconstructed via ``jax.eval_shape``
over ``gen_plan_triples`` (no triple material is generated at load time),
so the flat npz leaves round-trip losslessly for dense and cone layouts
alike.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RESNET18, RESNET50, RESNET_SMOKE
from repro.core import beaver, fixed, ring
from repro.core.mpc_tensor import MPCTensor
from repro.api.plan import Plan

CONFIGS = {"smoke": RESNET_SMOKE, "resnet18": RESNET18,
           "resnet50": RESNET50}


def resolve_config(name: str):
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown job config {name!r}; expected one of "
                       f"{sorted(CONFIGS)}") from None


def pool_treedef(plan: Plan):
    """The triple pool's pytree structure for ``plan`` — derived
    abstractly (``eval_shape``), no triples are generated."""
    template = jax.eval_shape(
        lambda k: beaver.gen_plan_triples(k, plan.triple_specs(),
                                          cone=plan.cone),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    return jax.tree_util.tree_structure(template)


def write_job(job_dir, *, plan: Plan, config: str, params_seed: int,
              infer_key: int, session_seed: int, ttp_seed: int = 0,
              x: Optional[MPCTensor] = None,
              pool: Optional[List] = None) -> pathlib.Path:
    """Materialise a job directory (see module docstring).

    ``x`` is the full 2-party secret-shared input and ``pool`` the full
    offline triple pool; both are split by party here.  Omit them for a
    serving-mode job (the engine leader shares inputs per request and
    triples stream from the shared ``ttp_seed``).
    """
    path = pathlib.Path(job_dir)
    path.mkdir(parents=True, exist_ok=True)
    plan.save(path / "plan.json")
    job = {"config": str(config), "params_seed": int(params_seed),
           "infer_key": int(infer_key), "session_seed": int(session_seed),
           "ttp_seed": int(ttp_seed)}
    resolve_config(job["config"])               # fail at write time, loudly
    if x is not None:
        job["frac_bits"] = int(x.frac_bits)
        job["input_shape"] = [int(s) for s in x.shape]
        for p in (0, 1):
            arrs = {"x_lo": np.asarray(x.data.lo[p:p + 1]),
                    "x_hi": np.asarray(x.data.hi[p:p + 1])}
            if pool is not None:
                leaves = jax.tree_util.tree_leaves(
                    beaver.slice_party_pool(pool, p))
                arrs.update({f"t{i:04d}": np.asarray(leaf)
                             for i, leaf in enumerate(leaves)})
            np.savez(path / f"party{p}.npz", **arrs)
    (path / "job.json").write_text(json.dumps(job, indent=1))
    return path


def load_job(job_dir) -> Dict:
    """job.json + the plan (every party-agnostic piece)."""
    path = pathlib.Path(job_dir)
    job = json.loads((path / "job.json").read_text())
    job["plan"] = Plan.load(path / "plan.json")
    job["cfg"] = resolve_config(job["config"])
    return job


def load_party(job_dir, party: int) -> Dict:
    """One party's view: job + its input share rows + its triple slice."""
    path = pathlib.Path(job_dir)
    job = load_job(path)
    npz_path = path / f"party{party}.npz"
    if npz_path.exists():
        with np.load(npz_path) as npz:
            job["X"] = MPCTensor(
                ring.Ring64(jnp.asarray(npz["x_lo"]),
                            jnp.asarray(npz["x_hi"])),
                int(job.get("frac_bits", fixed.DEFAULT_FRAC_BITS)))
            tkeys = sorted(k for k in npz.files if k.startswith("t"))
            if tkeys:
                leaves = [jnp.asarray(npz[k]) for k in tkeys]
                job["pool"] = jax.tree_util.tree_unflatten(
                    pool_treedef(job["plan"]), leaves)
    return job
