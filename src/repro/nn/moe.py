"""Top-k mixture-of-experts with capacity-bounded sort-based dispatch.

Dispatch is local to each data shard (experts' FFN weights are TP-sharded
over `model` on the hidden dim, replicated over `data`), so routing needs
no all-to-all; an optional EP mode (runtime/sharding.py) shards the expert
axis instead when E is a multiple of the mesh axis.

FLOPs are honest: tokens are gathered into (E, capacity, D) buffers and
each expert runs one batched matmul, so compiled compute ~= top_k * tokens
* FFN (+ router), matching the 6*N_active*D roofline accounting.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import common


def moe_init(key, d_model: int, d_ff: int, n_experts: int, gated: bool = True,
             dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    shape_up = (n_experts, d_model, d_ff)
    shape_down = (n_experts, d_ff, d_model)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "router": common.dense_init(ks[0], d_model, n_experts, dtype),
        "w_up": jax.random.normal(ks[1], shape_up, dtype) * scale_in,
        "w_down": jax.random.normal(ks[2], shape_down, dtype) * scale_out,
    }
    if gated:
        p["w_gate"] = jax.random.normal(ks[3], shape_up, dtype) * scale_in
    return p


def moe(params, x, *, n_experts: int, top_k: int = 2,
        capacity_factor: float = 1.25, act_name: str = "silu") -> jax.Array:
    """x: (B, S, D) -> (B, S, D).

    *Local routing*: dispatch is vectorised over the batch dim (which is
    `data`-sharded), so every shard routes only its own tokens — no
    all-to-all.  Expert FFN weights are TP-sharded over `model` on the
    hidden dim.  Capacity-bounded with dropping (Switch-style).
    """
    from repro.runtime import constraints

    b, s, d = x.shape
    logits = common.dense(params["router"], x)               # (B, S, E)
    weights = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_e = jax.lax.top_k(weights, top_k)             # (B, S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    capacity = int(max(1, capacity_factor * top_k * s / n_experts))
    flat_e = top_e.reshape(b, s * top_k)                     # (B, T)
    flat_w = top_w.reshape(b, s * top_k).astype(x.dtype)
    flat_tok = jnp.tile(jnp.repeat(jnp.arange(s), top_k)[None], (b, 1))

    order = jnp.argsort(flat_e, axis=-1, stable=True)        # per row
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # rank of each assignment within its expert group (per row)
    counts = jnp.sum(jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32),
                     axis=1)                                 # (B, E)
    first_idx = jnp.cumsum(counts, axis=-1) - counts         # exclusive cumsum
    pos = jnp.broadcast_to(jnp.arange(s * top_k), sorted_e.shape)
    rank = pos - jnp.take_along_axis(first_idx, sorted_e, axis=-1)
    keep = rank < capacity
    slot = jnp.where(keep, sorted_e * capacity + rank, n_experts * capacity)

    # gather tokens into per-row (E*cap+1, D) buffers (last row = dropped)
    src_tok = jnp.take_along_axis(flat_tok, order, axis=-1)  # (B, T)
    gathered = jnp.take_along_axis(x, src_tok[..., None], axis=1)
    buf = jnp.zeros((b, n_experts * capacity + 1, d), x.dtype)
    buf = jax.vmap(lambda bf, sl, g: bf.at[sl].set(g, mode="drop"))(
        buf, slot, gathered)
    h = buf[:, :-1].reshape(b, n_experts, capacity, d)
    h = constraints.shard(h, "dp", None, None, None)

    up = jnp.einsum("becd,edf->becf", h, params["w_up"])
    if "w_gate" in params:
        gate = jnp.einsum("becd,edf->becf", h, params["w_gate"])
        hidden = common.activation(act_name)(gate) * up
    else:
        hidden = common.activation(act_name)(up)
    hidden = constraints.shard(hidden, "dp", None, None, "tp")
    out_buf = jnp.einsum("becf,efd->becd", hidden, params["w_down"])
    out_flat = jnp.concatenate(
        [out_buf.reshape(b, n_experts * capacity, d),
         jnp.zeros((b, 1, d), x.dtype)], axis=1)

    # scatter back with routing weights
    contrib = jnp.take_along_axis(out_flat, slot[..., None], axis=1)
    contrib = contrib * jnp.take_along_axis(flat_w, order, axis=-1)[..., None]
    y = jnp.zeros((b, s, d), x.dtype)
    y = jax.vmap(lambda yy, tk, c: yy.at[tk].add(c))(y, src_tok, contrib)
    return constraints.shard(y, "dp", None, None)


def moe_aux_loss(params, x, *, n_experts: int) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style)."""
    logits = common.dense(params["router"], x.reshape(-1, x.shape[-1]))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, n_experts), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(frac_tokens * frac_probs)
