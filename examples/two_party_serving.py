"""Full two-party deployment: party processes + HTTP frontend, end to end.

Drives everything ``docs/deployment.md`` describes, in one command:

1. writes a smoke job directory (plan, seeds, per-party input share rows
   and triple slices);
2. launches party 1 as its OWN OS process in follower mode
   (``repro.launch.party_host --follow``);
3. launches party 0 as a second process: the ``InferenceEngine`` leader
   behind the asyncio HTTP frontend (``repro.serve.frontend``);
4. POSTs a mixed-tenant batch of requests to ``/infer``, polls
   ``/healthz`` / ``/stats``, and prints measured wall-clock latency
   next to the ``core.schedule`` prediction for the injected RTT.

    PYTHONPATH=src python examples/two_party_serving.py
    PYTHONPATH=src python examples/two_party_serving.py --rtt-ms 4

``--make-job DIR`` only writes the job directory (the two-terminal
quickstart in docs/deployment.md starts from this) and exits.
"""
import argparse
import json
import os
import pathlib
import subprocess
import sys
import time
import urllib.request

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import api  # noqa: E402
from repro.configs import RESNET_SMOKE  # noqa: E402
from repro.core import beaver  # noqa: E402
from repro.models import resnet  # noqa: E402
from repro.transport import free_port, write_job  # noqa: E402

HOST = "127.0.0.1"


def make_job(job_dir) -> None:
    """Smoke job: traced plan + seeds + party-split shares and triples."""
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

    plan = api.trace_plan(afn, params, (2, 3, 8, 8), name="smoke")
    plan = plan.with_hb(api.HBConfig(
        tuple([api.HBLayer(k=21, m=13)] * (plan.n_groups - 1)
              + [api.HBLayer(k=13, m=13)]), plan.group_elements))
    model = api.compile(afn, params, RESNET_SMOKE, plan, api.Session(key=0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 8, 8)) * 0.5
    X = model.encrypt(jax.random.PRNGKey(2), x)
    pool = beaver.gen_plan_triples(jax.random.PRNGKey(3),
                                   plan.triple_specs())
    write_job(job_dir, plan=plan, config="smoke", params_seed=0,
              infer_key=4, session_seed=0, x=X, pool=pool)
    print(f"wrote job directory {job_dir}")


def _http(method, url, body=None, timeout=600.0):
    req = urllib.request.Request(
        url, method=method,
        data=json.dumps(body).encode() if body is not None else None)
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        return resp.status, json.loads(resp.read().decode())


def _wait_healthy(base, deadline_s=300.0) -> dict:
    t0 = time.monotonic()
    while time.monotonic() - t0 < deadline_s:
        try:
            status, health = _http("GET", f"{base}/healthz", timeout=5.0)
            if status == 200 and health.get("ok"):
                return health
        except OSError:
            pass
        time.sleep(0.25)
    raise TimeoutError(f"frontend at {base} never became healthy")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--make-job", default=None, metavar="DIR",
                    help="only write the job directory and exit")
    ap.add_argument("--job", default=None,
                    help="reuse an existing job directory")
    ap.add_argument("--rtt-ms", type=float, default=0.0,
                    help="injected link RTT for both parties")
    args = ap.parse_args()

    if args.make_job:
        make_job(args.make_job)
        return 0

    import tempfile
    tmp = None
    if args.job is None:
        tmp = tempfile.TemporaryDirectory()
        args.job = os.path.join(tmp.name, "job")
        make_job(args.job)

    link_port, http_port = free_port(), free_port()
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    shaping = (["--rtt-ms", str(args.rtt_ms)] if args.rtt_ms > 0 else [])
    follower = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.party_host", "--party", "1",
         "--job", args.job, "--peer", f"{HOST}:{link_port}", "--follow"]
        + shaping, env=env, cwd=ROOT)
    leader = subprocess.Popen(
        [sys.executable, "-m", "repro.serve.frontend", "--job", args.job,
         "--listen", f"{HOST}:{link_port}",
         "--http", f"{HOST}:{http_port}"] + shaping, env=env, cwd=ROOT)

    base = f"http://{HOST}:{http_port}"
    try:
        _wait_healthy(base)
        print(f"frontend healthy at {base}; POSTing mixed-tenant batch")

        mix = [("alice", 10), ("bob", 11), ("alice", 12)]
        t0 = time.perf_counter()
        for tenant, seed in mix:
            x = np.asarray(jax.random.normal(
                jax.random.PRNGKey(seed), (2, 3, 8, 8)) * 0.5, np.float32)
            status, resp = _http("POST", f"{base}/infer",
                                 {"tenant": tenant, "x": x.tolist()})
            assert status == 200, resp
            y = np.asarray(resp["y"], np.float32)
            print(f"  {tenant}: argmax {np.argmax(y, -1).tolist()} "
                  f"({resp['wall_s']:.3f}s wall, "
                  f"{resp['batch']['measured_rounds']} rounds vs "
                  f"{resp['batch']['predicted_rounds']} predicted)")
        wall = time.perf_counter() - t0

        status, stats = _http("GET", f"{base}/stats")
        assert status == 200
        tr = stats.get("transport", {})
        print(f"\n{len(mix)} requests in {wall:.2f}s "
              f"({len(mix) / wall:.2f} req/s) across {stats['batches']} "
              f"batches; transport: {tr.get('rounds')} rounds, "
              f"{tr.get('payload_bytes')} payload B, "
              f"{tr.get('retries')} retries")
        if args.rtt_ms > 0:
            print(f"injected RTT {args.rtt_ms}ms -> per-round floor "
                  f"{args.rtt_ms / 1e3:.4f}s x {tr.get('rounds')} rounds = "
                  f"{args.rtt_ms / 1e3 * (tr.get('rounds') or 0):.3f}s "
                  f"minimum comm wall")
        return 0
    finally:
        leader.terminate()
        follower.terminate()
        for p in (leader, follower):
            try:
                p.wait(timeout=30)
            except subprocess.TimeoutExpired:
                p.kill()
        if tmp is not None:
            tmp.cleanup()


if __name__ == "__main__":
    sys.exit(main())
