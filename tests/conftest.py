import os
import sys

# tests see the default single CPU device (the dry-run alone forces 512)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The suite defaults to the generator round-loop backend: compiling one
# whole-replay XLA program per (model, shape) signature is the production
# trade (compile once, serve thousands) but would dominate a test suite
# that builds hundreds of tiny models.  CI additionally runs the suite
# with HB_ROUND_LOOP=scan (and HB_XLA_OPT=0 to cap compile time) so the
# compiled backend can never silently regress; tests/test_compiled_loop.py
# pins scan-vs-python bit-identity regardless of this default.
os.environ.setdefault("HB_ROUND_LOOP", "python")

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.default_rng(0)
