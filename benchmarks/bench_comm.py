"""Paper Fig. 3 + Fig. 11: ReLU communication breakdown and reduction.

Reports the closed-form cost model (validated against HLO collectives in
tests) for ResNet18/50-shaped ReLU stacks at the paper's budgets.
"""
import time

import jax

from repro.configs.resnet import RESNET18, RESNET50
from repro.core import costmodel
from repro.core.hummingbird import HBConfig, HBLayer
from repro.models import resnet


def _groups(rcfg):
    params = resnet.init(jax.random.PRNGKey(0), rcfg)
    return resnet.relu_group_elements(params, rcfg)


def _cfg(groups, width, m):
    return HBConfig(tuple(HBLayer(k=width + m, m=m) for _ in groups),
                    tuple(groups))


def run():
    rows = []
    for rcfg in (RESNET18, RESNET50):
        groups = _groups(rcfg)
        base = costmodel.model_relu_cost(HBConfig.exact(groups))
        t0 = time.time()
        frac = {k: v / base.bytes_tx for k, v in base.breakdown.items()}
        us = (time.time() - t0) * 1e6
        rows.append((f"fig3_breakdown_{rcfg.name}", us,
                     f"circuit={frac['circuit']:.3f};others={frac['others']:.3f};"
                     f"b2a={frac['b2a']:.3f};mult={frac['mult']:.3f}"))
        for name, width, m in (("eco", 21, 0), ("8of64", 8, 13), ("6of64", 6, 14)):
            t0 = time.time()
            cfg = _cfg(groups, width, m) if name != "eco" else HBConfig(
                tuple(HBLayer(k=21, m=0) for _ in groups), tuple(groups))
            r = costmodel.reduction_factors(cfg)
            us = (time.time() - t0) * 1e6
            rows.append((f"fig11_{rcfg.name}_{name}", us,
                         f"bytes_red={r['bytes_reduction']:.2f}x;"
                         f"rounds_red={r['rounds_reduction']:.2f}x;"
                         f"bits_discarded={r['bits_discarded_frac']:.3f}"))
    return rows
