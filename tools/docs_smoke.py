"""Docs smoke: the documentation surface must stay executable and linked.

Two gates, run by CI (see .github/workflows/ci.yml) and locally via

    python tools/docs_smoke.py

1. The README quickstart: every ```python fenced block in README.md is
   extracted and executed in a subprocess with PYTHONPATH=src — the
   quickstart must run exactly as readers would copy-paste it.
2. Intra-repo links: every relative markdown link target in README.md
   and docs/**/*.md must exist on disk (external http(s)/mailto links
   are not touched).

Exit code is non-zero on any failure, with one line per problem.
"""
from __future__ import annotations

import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

_FENCE_RE = re.compile(r"```python\n(.*?)```", re.S)
# [text](target) — skip images' inner text handling; good enough for md
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def run_python_blocks(md_path: pathlib.Path) -> list:
    """Execute every ```python block of one markdown file; return errors."""
    errors = []
    blocks = _FENCE_RE.findall(md_path.read_text())
    for i, block in enumerate(blocks):
        proc = subprocess.run(
            [sys.executable, "-c", block], cwd=ROOT, text=True,
            capture_output=True, timeout=600,
            env={**__import__("os").environ,
                 "PYTHONPATH": str(ROOT / "src")})
        if proc.returncode != 0:
            errors.append(
                f"{md_path.relative_to(ROOT)}: python block {i + 1} failed:\n"
                f"{proc.stderr.strip()[-1500:]}")
    if not blocks:
        errors.append(f"{md_path.relative_to(ROOT)}: no ```python "
                      "quickstart block found")
    return errors


def check_links(md_paths) -> list:
    """Every relative link target must exist relative to its file."""
    errors = []
    for md in md_paths:
        for target in _LINK_RE.findall(md.read_text()):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:
                continue
            path = target.split("#", 1)[0]
            if not path:                                   # pure #anchor
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return errors


def main() -> int:
    readme = ROOT / "README.md"
    docs = sorted((ROOT / "docs").glob("**/*.md"))
    errors = run_python_blocks(readme)
    # docs with an executable-quickstart contract ride the same gate
    errors += run_python_blocks(ROOT / "docs" / "robustness.md")
    errors += run_python_blocks(ROOT / "docs" / "models.md")
    errors += check_links([readme] + docs)
    for e in errors:
        print(f"DOCS-SMOKE: {e}", file=sys.stderr)
    if not errors:
        n_links = sum(len(_LINK_RE.findall(p.read_text()))
                      for p in [readme] + docs)
        print(f"docs smoke OK: README quickstart ran, {n_links} links "
              f"checked across {1 + len(docs)} files")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
