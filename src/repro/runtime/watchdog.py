"""Shared straggler detection: per-observation wall-clock EWMA.

One implementation for both consumers — the training loop's per-step
watchdog (``train/loop.py``) and the serving engine's slow-round detector
(``serve/engine.py`` observes each executed batch's per-fused-round wall
time).  An observation slower than ``factor`` x the EWMA is flagged; the
first observation seeds the EWMA and the first ``warmup`` observations
are never flagged (compilation and cache warmup land there).
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional


class StragglerWatchdog:
    """EWMA-based slow-observation detector.

    ``observe(tag, dt)`` absorbs one timed unit of work (a training step,
    a fused round) and returns whether it was a straggler; flagged tags
    accumulate in ``stragglers``.  Semantics match the historical inline
    loop logic exactly: observation 1 seeds the EWMA (never flagged),
    observations up to ``warmup`` update but never flag, and from there a
    ``dt > factor * ewma`` flags BEFORE the EWMA absorbs it (so one slow
    outlier cannot hide itself).
    """

    def __init__(self, factor: float = 3.0, alpha: float = 0.1,
                 warmup: int = 2):
        self.factor = factor
        self.alpha = alpha
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.stragglers: List[Any] = []
        self._n = 0

    def observe(self, tag: Any, dt: float,
                on_straggler: Optional[Callable] = None) -> bool:
        self._n += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = self._n > self.warmup and dt > self.factor * self.ewma
        if slow:
            self.stragglers.append(tag)
            if on_straggler is not None:
                on_straggler(tag, dt, self.ewma)
        self.ewma = (1.0 - self.alpha) * self.ewma + self.alpha * dt
        return slow
