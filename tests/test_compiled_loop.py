"""Compiled round loop (PR 9): the ``lax.scan`` backend of
``runtime/loop.py`` must be share-level BIT-IDENTICAL to the generator
round loop and to the frozen seed path (``core/gmw_ref.py``), with
measured rounds/bytes equal to ``core.schedule.simulate`` exactly —
across random (n, k, m) mixes, early dropout, the cone adder, width-0
culling and auto-batched (merged) siblings.  Also pins the env-selected
backend (``HB_ROUND_LOOP``), compiled-replay eligibility, the
PrivateModel whole-replay path and its counter replay onto the caller's
CoalescingComm.
"""
import os

import jax
import numpy as np
import pytest

from repro import api
from repro.core import (MPCTensor, beaver, comm as comm_lib, fixed, gmw,
                        gmw_ref, ring, schedule, shares)
from repro.core.hummingbird import HBConfig, HBLayer
from repro.runtime import loop as loop_lib

try:                                   # optional: property test only
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _make_group(n, k, m, cone, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-3.5, 3.5, n).astype(np.float32)
    X = shares.share(jax.random.PRNGKey(seed), fixed.encode_np(x))
    tri = (None if k == m or n == 0 else
           beaver.gen_relu_triples(jax.random.PRNGKey(seed + 1), n, k - m,
                                   cone=cone))
    return X, tri


def _run_loop(specs, loop, cone=False, auto_batch=True, seed=0):
    """relu_many on the given round-loop backend; returns (outs, comm)."""
    keys, Xs, trs = [], [], []
    for i, (n, k, m) in enumerate(specs):
        X, tri = _make_group(n, k, m, cone, seed + 10 * i)
        keys.append(jax.random.PRNGKey(seed + 1000 + i))
        Xs.append(X)
        trs.append(tri)
    cc = comm_lib.CoalescingComm(comm_lib.SimComm())
    outs = gmw.relu_many(keys, Xs, trs, cc, [(k, m) for _, k, m in specs],
                         cone=cone, auto_batch=auto_batch, loop=loop)
    return outs, cc


def _assert_pair(specs, cone=False, auto_batch=True, seed=0):
    """scan vs python backends: share-level bit-identity AND identical
    measured counters, both equal to the schedule prediction."""
    outs_py, cc_py = _run_loop(specs, "python", cone, auto_batch, seed)
    outs_sc, cc_sc = _run_loop(specs, "scan", cone, auto_batch, seed)
    for a, b in zip(outs_py, outs_sc):
        np.testing.assert_array_equal(np.asarray(a.lo), np.asarray(b.lo))
        np.testing.assert_array_equal(np.asarray(a.hi), np.asarray(b.hi))
    assert cc_sc.n_rounds == cc_py.n_rounds
    assert cc_sc.round_bytes == cc_py.round_bytes
    assert cc_sc.round_parts == cc_py.round_parts
    sched = schedule.simulate([(n, k - m, (n, k, m)) for n, k, m in specs],
                              cone=cone, auto_batch=auto_batch)
    assert cc_sc.n_rounds == sched.n_rounds
    assert cc_sc.round_bytes == list(sched.round_bytes)
    assert cc_sc.round_parts == list(sched.round_parts)
    return outs_sc


# ---------------------------------------------------------------------------
# relu_scan vs generator loop vs the frozen seed path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,m", [
    (64, 64, 0),      # full exact ring, 6 dense scan levels
    (300, 21, 13),    # the paper's 8-bit reduced ring
    (33, 8, 6),       # w=2: a single scan level after the init AND
    (7, 9, 8),        # w=1: no adder levels at all (scan degenerates)
    (5, 2, 0),        # tiny n, sub-word packing
])
def test_relu_scan_bit_identical_to_seed(n, k, m):
    X, tri = _make_group(n, k, m, False, 11)
    key = jax.random.PRNGKey(99)
    want = gmw_ref.relu(key, X, tri, comm_lib.SimComm(), k=k, m=m)
    got_gen = gmw.relu(key, X, tri, comm_lib.SimComm(), k=k, m=m)
    got_scan = gmw.relu_scan(key, X, tri, comm_lib.SimComm(), k=k, m=m)
    for got in (got_gen, got_scan):
        np.testing.assert_array_equal(np.asarray(got.lo), np.asarray(want.lo))
        np.testing.assert_array_equal(np.asarray(got.hi), np.asarray(want.hi))


def test_relu_scan_under_jit_bit_identical(rng):
    """The point of the scan backend: the whole ReLU jits into one XLA
    program with unchanged shares."""
    n, k, m = 256, 21, 13
    X, tri = _make_group(n, k, m, False, 5)
    key = jax.random.PRNGKey(4)

    @jax.jit
    def run(lo, hi, tr):
        out = gmw.relu_scan(key, ring.Ring64(lo, hi), tr,
                            comm_lib.SimComm(), k=k, m=m)
        return out.lo, out.hi

    lo, hi = run(X.lo, X.hi, tri)
    want = gmw.relu(key, X, tri, comm_lib.SimComm(), k=k, m=m)
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(want.lo))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(want.hi))


# ---------------------------------------------------------------------------
# relu_many: deterministic scenario coverage, scan vs python
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("specs,cone", [
    # mixed widths: narrow rings drop out of the lockstep early
    ([(96, 64, 0), (160, 21, 13), (64, 20, 14)], False),
    ([(96, 64, 0), (160, 21, 13), (64, 20, 14)], True),
    # w=1 next to a deep ring
    ([(40, 2, 1), (40, 64, 0)], False),
    # width-0 culled + empty-batch streams cost zero rounds
    ([(64, 13, 13), (0, 21, 13), (32, 21, 13)], False),
    # merged siblings: identical (n, k, m) auto-batch into ONE stream,
    # which is exactly the case the scan backend compiles
    ([(50, 21, 13), (50, 21, 13), (30, 21, 13)], False),
    ([(50, 21, 13), (50, 21, 13), (50, 21, 13)], False),
    # solo group: pure relu_scan path
    ([(128, 21, 13)], False),
    ([(128, 5, 0)], True),
])
def test_scan_vs_python_scenarios(specs, cone):
    _assert_pair(specs, cone=cone)


def test_scan_vs_python_without_batching():
    _assert_pair([(50, 21, 13), (50, 21, 13)], auto_batch=False, seed=3)


_KM_POOL = [(64, 0), (21, 13), (20, 14), (8, 0), (5, 3), (2, 1), (13, 13)]

if HAVE_HYPOTHESIS:
    _GROUP = st.tuples(
        st.integers(min_value=0, max_value=80),        # n (0 = empty batch)
        st.sampled_from(_KM_POOL),
    )

    @settings(max_examples=6, deadline=None)
    @given(groups=st.lists(_GROUP, min_size=1, max_size=3),
           cone=st.booleans(), auto_batch=st.booleans())
    def test_scan_property_random_groups(groups, cone, auto_batch):
        specs = [(n, k, m) for n, (k, m) in groups]
        _assert_pair(specs, cone=cone, auto_batch=auto_batch, seed=7)


@pytest.mark.parametrize("case_seed", [0, 1, 2, 3])
def test_scan_random_sweep(case_seed):
    """Deterministic randomized sweep (runs with or without hypothesis):
    duplicates make merged siblings, zeros empty streams, (13, 13)
    culled identities."""
    rng = np.random.default_rng(200 + case_seed)
    n_groups = int(rng.integers(1, 4))
    specs = []
    for _ in range(n_groups):
        n = int(rng.choice([0, 1, 17, 50, 50, 80]))
        k, m = _KM_POOL[int(rng.integers(0, len(_KM_POOL)))]
        specs.append((n, k, m))
    cone = bool(rng.integers(0, 2))
    _assert_pair(specs, cone=cone, seed=300 + case_seed)


# ---------------------------------------------------------------------------
# Backend selection + compiled-replay eligibility (runtime/loop.py)
# ---------------------------------------------------------------------------

def test_round_loop_mode_env(monkeypatch):
    monkeypatch.delenv("HB_ROUND_LOOP", raising=False)
    assert loop_lib.round_loop_mode() == "scan"        # production default
    monkeypatch.setenv("HB_ROUND_LOOP", "python")
    assert loop_lib.round_loop_mode() == "python"
    monkeypatch.setenv("HB_ROUND_LOOP", "scan")
    assert loop_lib.round_loop_mode() == "scan"
    monkeypatch.setenv("HB_ROUND_LOOP", "bogus")
    assert loop_lib.round_loop_mode() == "scan"        # invalid -> default


def test_compiled_eligible_exact_types():
    assert loop_lib.compiled_eligible(comm_lib.SimComm())
    assert loop_lib.compiled_eligible(
        comm_lib.CoalescingComm(comm_lib.SimComm()))
    # anything that observes rounds at the Python layer must keep the
    # generator loop: counters, resilience framing, real sockets
    assert not loop_lib.compiled_eligible(comm_lib.CountingComm())
    assert not loop_lib.compiled_eligible(
        comm_lib.CoalescingComm(comm_lib.CountingComm()))
    assert not loop_lib.compiled_eligible(
        comm_lib.ResilientComm(comm_lib.SimComm()))


# ---------------------------------------------------------------------------
# PrivateModel whole-replay: a tiny 2-group MLP, scan vs python backends
# ---------------------------------------------------------------------------

class LoopCfg:
    name = "loop-mlp"


def loop_apply(params, x, relu_fn=None):
    rf = relu_fn if relu_fn is not None else (lambda v, g: jax.nn.relu(v))
    h = rf(x @ params["w1"], 0)
    return rf(h @ params["w2"], 1)


def loop_forward(params, hs, cfg, relu_fn, comm):
    hs = relu_fn([h.matmul_public(params["w1"]) for h in hs], 0)
    return relu_fn([h.matmul_public(params["w2"]) for h in hs], 1)


api.register_mpc_forward(LoopCfg, loop_forward)

D_IN, D_HID, D_OUT = 6, 5, 4


@pytest.fixture(scope="module")
def tiny_model():
    params = {
        "w1": jax.random.normal(jax.random.PRNGKey(0), (D_IN, D_HID)) * 0.4,
        "w2": jax.random.normal(jax.random.PRNGKey(1), (D_HID, D_OUT)) * 0.4,
    }
    plan = api.trace_plan(loop_apply, params, (2, D_IN), name="loop")
    plan = plan.with_hb(HBConfig((HBLayer(k=21, m=13), HBLayer(k=21, m=13)),
                                 plan.group_elements))
    return params, plan


def _model_run(params, plan, X, mode, monkeypatch):
    monkeypatch.setenv("HB_ROUND_LOOP", mode)
    monkeypatch.setenv("HB_XLA_OPT", "0")      # cap replay compile time
    cc = comm_lib.CoalescingComm(comm_lib.SimComm())
    model = api.compile(loop_apply, params, LoopCfg(), plan,
                        api.Session(key=0, comm=cc))
    out = model(X, key=jax.random.PRNGKey(4))
    return out, cc, model


def test_private_model_scan_vs_python(tiny_model, monkeypatch):
    params, plan = tiny_model
    x = jax.random.normal(jax.random.PRNGKey(7), (2, D_IN))
    X = MPCTensor.from_plain(jax.random.PRNGKey(8), x)
    out_py, cc_py, _ = _model_run(params, plan, X, "python", monkeypatch)
    out_sc, cc_sc, model = _model_run(params, plan, X, "scan", monkeypatch)
    np.testing.assert_array_equal(np.asarray(out_py.data.lo),
                                  np.asarray(out_sc.data.lo))
    np.testing.assert_array_equal(np.asarray(out_py.data.hi),
                                  np.asarray(out_sc.data.hi))
    # counter replay: the compiled path must report the exact generator
    # timeline onto the caller's CoalescingComm
    assert cc_sc.n_rounds == cc_py.n_rounds
    assert cc_sc.round_bytes == cc_py.round_bytes
    assert cc_sc.round_parts == cc_py.round_parts
    stats = model.replay_stats([X])
    assert stats is not None
    assert stats["n_rounds"] == cc_py.n_rounds
    assert stats["trace_s"] > 0 and stats["compile_s"] > 0


def test_private_model_replay_cache_shared(tiny_model, monkeypatch):
    """A second model from the same plan/forward reuses the compiled
    executable (no new cache entry, bit-identical output)."""
    from repro.api.compile import replay_cache_stats
    params, plan = tiny_model
    x = jax.random.normal(jax.random.PRNGKey(17), (2, D_IN))
    X = MPCTensor.from_plain(jax.random.PRNGKey(18), x)
    out1, _, _ = _model_run(params, plan, X, "scan", monkeypatch)
    n_entries = len(replay_cache_stats())
    out2, _, _ = _model_run(params, plan, X, "scan", monkeypatch)
    assert len(replay_cache_stats()) == n_entries
    np.testing.assert_array_equal(np.asarray(out1.data.lo),
                                  np.asarray(out2.data.lo))


def test_ineligible_comm_stays_on_generator_loop(tiny_model, monkeypatch):
    """A counter-observing comm must take the generator path even when
    HB_ROUND_LOOP=scan — same outputs, counters measured live."""
    params, plan = tiny_model
    x = jax.random.normal(jax.random.PRNGKey(27), (2, D_IN))
    X = MPCTensor.from_plain(jax.random.PRNGKey(28), x)
    monkeypatch.setenv("HB_ROUND_LOOP", "scan")
    cc = comm_lib.CoalescingComm(comm_lib.CountingComm())
    model = api.compile(loop_apply, params, LoopCfg(), plan,
                        api.Session(key=0, comm=cc))
    out = model(X, key=jax.random.PRNGKey(4))
    out_py, cc_py, _ = _model_run(params, plan, X, "python", monkeypatch)
    np.testing.assert_array_equal(np.asarray(out.data.lo),
                                  np.asarray(out_py.data.lo))
    assert cc.n_rounds == cc_py.n_rounds
