"""Lock-discipline checker for the serving engine's pump-thread state.

``serve.InferenceEngine`` runs a background pump thread
(``_pump_loop``) next to caller threads (``submit``/``poll``/``flush``/
``stats``/HTTP executor threads), all serialized by one ``RLock``
(``self._lock``).  The invariant: every access to pump-shared mutable
attributes happens under that lock.  This module checks it statically:

- an access is *guarded* if it sits lexically inside ``with self._lock:``
  (the RLock makes nesting safe), or
- it sits in a private helper (``_name``) whose **every** intra-class
  call site is itself guarded (computed to a fixpoint), or
- it sits in ``__init__`` (no other thread can hold the instance yet).

Nested ``def``/``lambda`` bodies are deliberately treated as unguarded
even when defined under the lock — they may run later, on another
thread, after the lock is released.

For ``serve.Frontend`` (single asyncio loop, no lock of its own) the
check is different: the frontend must reach engine state only through
the engine's public, self-locking API — any ``self.engine._private``
access bypasses the engine's lock and is flagged.

Findings use rule ids L001 (unguarded attribute access) and L002
(private cross-object reach), reported through the same ``Finding``
type and baseline as the AST linter.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Dict, List, Sequence, Set, Tuple

from repro.analysis.lint import Finding

# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LockSpec:
    """What to check in one class: which attribute is the lock, and which
    attributes it guards."""

    class_name: str
    lock_attr: str = "_lock"
    guarded: frozenset = frozenset()
    exempt_methods: Tuple[str, ...] = ("__init__",)


# the pump-shared mutable state of serve/engine.py (see its class
# docstring): queue + futures + id admission, plan/lowering caches,
# tenant providers, report/latency accumulators, pump error mirror
ENGINE_SPEC = LockSpec(
    class_name="InferenceEngine",
    lock_attr="_lock",
    guarded=frozenset({
        "_queue", "_futures", "_used_ids", "_next_id", "_plan_cache",
        "_tenants", "_totals", "reports", "last_pump_error",
    }),
)

DEFAULT_SPECS: Tuple[LockSpec, ...] = (ENGINE_SPEC,)


# ---------------------------------------------------------------------------
# per-method scan
# ---------------------------------------------------------------------------

def _is_self_attr(node: ast.expr, attr: str) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == attr
            and isinstance(node.value, ast.Name)
            and node.value.id == "self")


class _MethodScan(ast.NodeVisitor):
    """Collect guarded-attribute accesses and intra-class call sites of
    one method, each tagged with whether the lock is lexically held."""

    def __init__(self, spec: LockSpec):
        self.spec = spec
        self.depth = 0
        self.accesses: List[Tuple[str, int, bool]] = []   # attr, line, locked
        self.calls: List[Tuple[str, bool]] = []           # method, locked

    def visit_With(self, node: ast.With):
        holds = any(_is_self_attr(item.context_expr, self.spec.lock_attr)
                    for item in node.items)
        for item in node.items:
            self.visit(item.context_expr)
        if holds:
            self.depth += 1
        for stmt in node.body:
            self.visit(stmt)
        if holds:
            self.depth -= 1

    def visit_Attribute(self, node: ast.Attribute):
        if isinstance(node.value, ast.Name) and node.value.id == "self" \
                and node.attr in self.spec.guarded:
            self.accesses.append((node.attr, node.lineno, self.depth > 0))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        if isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == "self":
            self.calls.append((node.func.attr, self.depth > 0))
        self.generic_visit(node)

    # deferred bodies: the lock may be long gone when these run
    def _deferred(self, node):
        saved = self.depth
        self.depth = 0
        for child in ast.iter_child_nodes(node):
            self.visit(child)
        self.depth = saved

    def visit_FunctionDef(self, node):
        self._deferred(node)

    def visit_AsyncFunctionDef(self, node):
        self._deferred(node)

    def visit_Lambda(self, node):
        self._deferred(node)


def check_lock_discipline(source: str, path: str,
                          specs: Sequence[LockSpec] = DEFAULT_SPECS,
                          ) -> List[Finding]:
    """Check every configured class found in ``source``."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, "L000",
                        f"syntax error: {e.msg}")]
    by_name = {s.class_name: s for s in specs}
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name in by_name:
            findings.extend(_check_class(node, by_name[node.name], path))
    findings.sort(key=lambda f: (f.file, f.line))
    return findings


def _check_class(cls: ast.ClassDef, spec: LockSpec,
                 path: str) -> List[Finding]:
    scans: Dict[str, _MethodScan] = {}
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan = _MethodScan(spec)
            for child in stmt.body:
                scan.visit(child)
            scans[stmt.name] = scan

    # fixpoint: private helpers whose every call site holds the lock are
    # themselves lock-held (public methods are externally callable, so
    # only _-prefixed names qualify; a helper with no in-class call site
    # has unknown callers — e.g. a Thread target — and stays unguarded)
    call_sites: Dict[str, List[Tuple[str, bool]]] = {}
    for caller, scan in scans.items():
        for callee, locked in scan.calls:
            call_sites.setdefault(callee, []).append((caller, locked))
    held: Set[str] = set()
    changed = True
    while changed:
        changed = False
        for name in scans:
            if name in held or not name.startswith("_") \
                    or name in spec.exempt_methods:
                continue
            sites = call_sites.get(name)
            if sites and all(locked or caller in held
                             for caller, locked in sites):
                held.add(name)
                changed = True

    out: List[Finding] = []
    for name, scan in scans.items():
        if name in spec.exempt_methods or name in held:
            continue
        for attr, line, locked in scan.accesses:
            if not locked:
                out.append(Finding(
                    path, line, "L001",
                    f"{spec.class_name}.{name} touches pump-shared "
                    f"self.{attr} without holding self.{spec.lock_attr}"))
    return out


# ---------------------------------------------------------------------------
# cross-object private reach (Frontend -> engine internals)
# ---------------------------------------------------------------------------

def check_private_reach(source: str, path: str,
                        owner_attrs: Sequence[str] = ("engine",),
                        ) -> List[Finding]:
    """Flag ``self.<owner>._private`` chains: reaching into another
    object's underscore state bypasses that object's lock."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, "L000",
                        f"syntax error: {e.msg}")]
    out: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr.startswith("_") \
                and not node.attr.startswith("__"):
            v = node.value
            if isinstance(v, ast.Attribute) and v.attr in owner_attrs \
                    and isinstance(v.value, ast.Name) and v.value.id == "self":
                out.append(Finding(
                    path, node.lineno, "L002",
                    f"private reach self.{v.attr}.{node.attr} bypasses "
                    f"{v.attr}'s own locking; use its public API"))
    out.sort(key=lambda f: (f.file, f.line))
    return out


def check_paths(root=None) -> List[Finding]:
    """Run both checks on the serving modules under ``root`` (repo root
    or any directory containing ``src/repro/serve``)."""
    root = pathlib.Path(root or ".")
    serve = root / "src" / "repro" / "serve"
    if not serve.exists():                      # installed-package layout
        serve = root / "repro" / "serve"
    findings: List[Finding] = []
    eng = serve / "engine.py"
    fr = serve / "frontend.py"
    if eng.exists():
        findings.extend(check_lock_discipline(
            eng.read_text(), f"src/repro/serve/{eng.name}"))
    if fr.exists():
        findings.extend(check_private_reach(
            fr.read_text(), f"src/repro/serve/{fr.name}"))
    return findings
