"""Launchers: mesh construction, dry-run, train/serve step builders."""
