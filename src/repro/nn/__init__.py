"""Plaintext neural-net substrate: attention, MoE, SSM, common layers."""
from . import attention, common, moe, ssm
__all__ = ["attention", "common", "moe", "ssm"]
