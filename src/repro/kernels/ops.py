"""Jit'd public wrappers for the Pallas kernels.

Dispatch policy: on TPU the Pallas path runs compiled; elsewhere (this
container is CPU) the pure-jnp reference is used unless
``REPRO_FORCE_PALLAS_INTERPRET=1`` forces the interpret-mode kernel (tests
do this explicitly for the allclose sweeps).

Tuning knobs (env, read per call — no code change needed on real
hardware):

- ``HB_PALLAS_INTERPRET=0`` forces the *non-interpret* Pallas lowering of
  the GMW round kernels even off-TPU (raises on backends without a Pallas
  lowering — CPU today — which the kernel parity tests attempt and
  skip-mark); ``HB_PALLAS_INTERPRET=1`` forces interpret mode, same as
  the legacy ``REPRO_FORCE_PALLAS_INTERPRET=1``.
- ``HB_BLOCK_WORDS=<n>`` overrides the word-dim VMEM tile of the fused
  Kogge-Stone level kernels (multiple of 128; default
  ``gmw_round.BLOCK_WORDS``) — the v5e/v6e BLOCK_WORDS sweep is a config
  sweep, not an edit.  Both knobs enter the jit'd wrappers as static
  arguments, so flipping them mid-process retraces instead of hitting a
  stale cache.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core import ring
from . import bitpack as _bitpack
from . import gmw_round as _gmw_round
from . import ring_matmul as _ring_matmul
from . import ref

_U32 = jnp.uint32


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET") == "1":
        return True
    if os.environ.get("HB_PALLAS_INTERPRET") in ("0", "1"):
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    forced = os.environ.get("HB_PALLAS_INTERPRET")
    if forced == "0":
        return False
    if forced == "1":
        return True
    return jax.default_backend() != "tpu"


def block_words() -> int:
    """The word-dim tile of the fused GMW round kernels: the
    ``HB_BLOCK_WORDS`` override when set and valid (positive multiple of
    128 — the TPU lane count), else ``gmw_round.BLOCK_WORDS``."""
    raw = os.environ.get("HB_BLOCK_WORDS", "")
    try:
        n = int(raw)
    except ValueError:
        return _gmw_round.BLOCK_WORDS
    if n > 0 and n % 128 == 0:
        return n
    return _gmw_round.BLOCK_WORDS


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnums=(1,))
def pack(v: jax.Array, w: int) -> jax.Array:
    """(E,) uint32 -> (w, ceil(E/32)) packed words."""
    n_out = (v.shape[0] + 31) // 32
    if _use_pallas():
        vp = _pad_to(v, 0, 32 * _bitpack.BLOCK_WORDS)
        bw = min(_bitpack.BLOCK_WORDS, vp.shape[0] // 32)
        out = _bitpack.pack_pallas(vp, w, interpret=_interpret(), block_words=bw)
    else:
        vp = _pad_to(v, 0, 32)
        out = ref.pack(vp, w)
    return out[:, :n_out]


@functools.partial(jax.jit, static_argnums=(1, 2))
def unpack(words: jax.Array, w: int, n_elements: int) -> jax.Array:
    """(w, W) packed words -> (n_elements,) uint32."""
    if _use_pallas():
        wp = _pad_to(words, 1, _bitpack.BLOCK_WORDS)
        bw = min(_bitpack.BLOCK_WORDS, wp.shape[1])
        out = _bitpack.unpack_pallas(wp, w, interpret=_interpret(), block_words=bw)
    else:
        out = ref.unpack(words, w)
    return out[:n_elements]


@functools.partial(jax.jit, static_argnums=(6, 7))
def _beaver_and_jit(d_open, e_open, a, b, c, sel, interpret, bw):
    if _use_pallas():
        blk = (_gmw_round.BLOCK[0], bw)
        args = [d_open, e_open, a, b, c, jnp.broadcast_to(sel, d_open.shape)]
        padded = [_pad_to(_pad_to(x, 0, blk[0]), 1, blk[1]) for x in args]
        out = _gmw_round.beaver_and_pallas(*padded, interpret=interpret,
                                           block=blk)
        return out[: d_open.shape[0], : d_open.shape[1]]
    return ref.beaver_and(d_open, e_open, a, b, c, sel)


def beaver_and(d_open, e_open, a, b, c, sel):
    """Fused local Beaver-AND evaluation on packed (planes, W) words."""
    return _beaver_and_jit(d_open, e_open, a, b, c, sel, _interpret(),
                           block_words())


@functools.partial(jax.jit, static_argnums=(4, 5, 6))
def _ks_mask_jit(g, p, a, b, shift, interpret, block):
    if _use_pallas():
        words = g.shape[-1]
        bw = min(block, words + (-words) % 128)
        args = [_pad_to(x, 2, bw) for x in (g, p, a, b)]
        d, e = _gmw_round.ks_mask_pallas(*args, shift, interpret=interpret,
                                         block_words=bw)
        return d[..., :words], e[..., :words]
    return ref.ks_mask(g, p, a, b, shift)


def ks_mask(g, p, a, b, shift: int):
    """Fused pre-exchange Kogge-Stone level: plane-shift + lhs/rhs assembly
    + Beaver triple masking in one pass.  Returns the (d, e) wire halves."""
    return _ks_mask_jit(g, p, a, b, shift, _interpret(), block_words())


@functools.partial(jax.jit, static_argnums=(9, 10))
def _ks_combine_jit(d, d_other, e, e_other, a, b, c, sel, g, interpret,
                    block):
    if _use_pallas():
        words = g.shape[-1]
        bw = min(block, words + (-words) % 128)
        sel_b = jnp.broadcast_to(sel, d.shape)
        args = [_pad_to(x, 2, bw)
                for x in (d, d_other, e, e_other, a, b, c, sel_b, g)]
        g2, p2 = _gmw_round.ks_combine_pallas(*args, interpret=interpret,
                                              block_words=bw)
        return g2[..., :words], p2[..., :words]
    return ref.ks_combine(d, d_other, e, e_other, a, b, c, sel, g)


def ks_combine(d, d_other, e, e_other, a, b, c, sel, g):
    """Fused post-exchange Kogge-Stone level: opening XOR + Beaver local
    evaluation + g/p level combine in one pass.  Returns (g', p')."""
    return _ks_combine_jit(d, d_other, e, e_other, a, b, c, sel, g,
                           _interpret(), block_words())


@functools.partial(jax.jit, static_argnums=())
def ring_matmul(x: ring.Ring64, w_i32: jax.Array) -> ring.Ring64:
    """Ring64 [M, K] @ public int32 [K, N] -> Ring64 [M, N] (mod 2^64)."""
    dx = ring.balanced_digits(x)            # (8, M, K)
    dw = ring.balanced_digits_i32(w_i32)    # (5, K, N)
    if _use_pallas():
        bm, bk, bn = (8, 128, 128) if _interpret() else _ring_matmul.DEFAULT_BLOCK
        m, k = x.shape
        n = w_i32.shape[1]
        dxp = _pad_to(_pad_to(dx, 1, bm), 2, bk)
        dwp = _pad_to(_pad_to(dw, 1, bk), 2, bn)
        lo, hi = _ring_matmul.ring_matmul_pallas(
            dxp, dwp, block=(bm, bk, bn), interpret=_interpret())
        return ring.Ring64(lo[:m, :n], hi[:m, :n])
    lo, hi = ref.ring_matmul(dx, dw)
    return ring.Ring64(lo, hi)
