"""Party communicator abstraction.

All protocol code is written against arrays that carry a leading *party*
dimension.  Two backends make the same code run either on a single host
(simulation, party dim = 2) or sharded over a mesh axis (party dim = 1 per
shard, exchanges lower to collective-permute):

- ``SimComm``: the party dimension is materialised; ``swap`` is a flip.
  Used by the search engine, tests, and CPU benchmarks.
- ``MeshComm``: used *inside* ``shard_map`` over the ``party`` mesh axis;
  ``swap`` is ``lax.ppermute`` so every protocol exchange shows up as a
  collective-permute in the compiled HLO (and therefore in the roofline's
  collective-bytes term).  A party axis of size 1 (smoke mesh) keeps both
  party rows on one shard and degenerates to the local flip.

Party-dependent randomness goes through ``party_is`` (boolean mask) and
``party_slice`` (each party's rows of a full-party-dim array), so the
same protocol code produces bit-identical values on both backends.

Round-fused engine support (see core/gmw.py):

- ``CountingComm``: transparent wrapper that counts ``swap`` calls (=
  protocol rounds) and per-party payload bytes; tests validate these
  counters against the closed-form cost model.
- ``CoalescingComm``: deferred-exchange wrapper.  Protocol code *enqueues*
  heterogeneous uint32 payloads for the current round; ``flush`` flattens
  and concatenates everything into ONE ``swap`` on the base backend, then
  hands each caller its slice back.  This is what lets N concurrent ReLU
  groups share communication rounds instead of paying one round each.

Resilient transport (see docs/robustness.md):

- ``ResilientComm``: per-round framing (round sequence + checksum words
  appended to the flattened uint32 buffer), corruption/desync detection,
  and recovery by idempotent re-send with timeout + bounded exponential
  backoff.  Raises the typed ``repro.errors`` comm failures only after the
  retry budget is exhausted.  Sim/eager backends only (verification needs
  concrete values) — the mesh backend runs inside jit and stays unframed.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import errors
from .schedule import FRAME_BYTES, FRAME_WORDS

_U32 = jnp.uint32


def payload_bytes(x) -> int:
    """Per-party one-direction wire bytes of a payload pytree.

    Every leaf carries the party dimension leading; each party transmits
    its own slice, so bytes = leaf bytes / party-dim size, summed.
    """
    total = 0
    for leaf in jax.tree_util.tree_leaves(x):
        total += (leaf.size // max(1, leaf.shape[0])) * leaf.dtype.itemsize
    return total


class SimComm:
    """Single-host simulation backend. Party dim is axis 0 with size 2."""

    n_parties = 2

    def swap(self, x):
        """Each party receives the other party's tensor (one exchange)."""
        return jax.tree_util.tree_map(lambda a: jnp.flip(a, axis=0), x)

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        """Boolean mask, True on party p, broadcastable against template."""
        idx = jnp.arange(2).reshape((2,) + (1,) * (template.ndim - 1))
        return idx == p

    def party_slice(self, full: jax.Array) -> jax.Array:
        """Each party's view of a full-party-dim array (leading dim =
        ``n_parties``).  The sim backend materialises every party, so this
        is the identity; the mesh backend returns the local party shard.
        Protocol code uses it for party-dependent randomness: generate the
        full (P, ...) array from a shared key, then keep your own rows —
        bit-identical across backends by construction."""
        return full


class MeshComm:
    """Mesh backend, valid only inside ``shard_map`` over ``axis_name``.

    The *global* party dimension (size ``n_parties`` = 2) is split over a
    mesh axis of size ``axis_size``, so each shard holds a local party dim
    of ``n_parties // axis_size`` rows:

    - ``axis_size == 2`` (real deployment: one device slice per
      non-colluding server): local party dim 1; ``swap`` is a single
      ``lax.ppermute``, so every protocol exchange is visible as exactly
      one collective-permute in the compiled HLO.
    - ``axis_size == 1`` (1-device smoke mesh): both parties land on the
      same shard (local party dim 2); the exchange degenerates to the
      sim backend's local flip and no collective is emitted.

    Either way the global semantics are the party flip, so protocol code
    is backend-agnostic and ``CoalescingComm`` over a ``MeshComm`` base
    fires ONE flattened ppermute per fused round.
    """

    n_parties = 2

    def __init__(self, axis_name: str = "party", axis_size: int = 2):
        if self.n_parties % axis_size:
            raise ValueError(
                f"party axis size {axis_size} must divide {self.n_parties}")
        self.axis_name = axis_name
        self.axis_size = axis_size
        self.local_parties = self.n_parties // axis_size

    def swap(self, x):
        """Global party flip = local party-dim flip + mesh-axis reversal."""
        perm = [(i, self.axis_size - 1 - i) for i in range(self.axis_size)]

        def exchange(a):
            if a.shape[0] > 1:                 # flip the local party rows
                a = jnp.flip(a, axis=0)
            if self.axis_size > 1:             # exchange across the mesh
                a = lax.ppermute(a, self.axis_name, perm)
            return a

        return jax.tree_util.tree_map(exchange, x)

    def _global_party_index(self, template: jax.Array) -> jax.Array:
        """(local_parties, 1, ..., 1) global party index of each local row."""
        local = jnp.arange(self.local_parties).reshape(
            (self.local_parties,) + (1,) * (template.ndim - 1))
        return lax.axis_index(self.axis_name) * self.local_parties + local

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        return self._global_party_index(template) == p

    def party_slice(self, full: jax.Array) -> jax.Array:
        """Local party rows of a full-party-dim (n_parties, ...) array."""
        if self.local_parties == self.n_parties:
            return full
        start = lax.axis_index(self.axis_name) * self.local_parties
        return lax.dynamic_slice_in_dim(full, start, self.local_parties, 0)


class CountingComm:
    """Transparent wrapper counting rounds (= ``swap`` calls) and bytes.

    ``n_swaps`` is the number of exchanges fired on the base backend and
    ``round_bytes[i]`` the per-party one-direction payload of exchange i;
    ``bytes_tx`` is their sum.  Used by tests/benchmarks to validate the
    protocol against ``costmodel.relu_cost`` and to demonstrate the swap
    reduction of the round-fused engine.
    """

    def __init__(self, base=None):
        self.base = base or SimComm()
        self.n_parties = self.base.n_parties
        self.reset()

    def reset(self) -> None:
        self.n_swaps = 0
        self.round_bytes: List[int] = []

    @property
    def bytes_tx(self) -> int:
        return sum(self.round_bytes)

    def swap(self, x):
        self.n_swaps += 1
        self.round_bytes.append(payload_bytes(x))
        return self.base.swap(x)

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        return self.base.party_is(p, template)

    def party_slice(self, full: jax.Array) -> jax.Array:
        return self.base.party_slice(full)


class CoalescingComm:
    """Deferred-exchange wrapper: one flattened ``swap`` per round.

    Protocol code enqueues the current round's payloads (any pytrees of
    uint32 arrays with the party dimension leading — packed bitplanes,
    Ring64 limb pairs, ...) and receives integer handles; ``flush``
    concatenates every enqueued leaf into a single (P, total_words) buffer,
    fires ONE exchange on the base backend, and returns the per-handle
    swapped payloads with their original structure restored.

    ``swap`` remains available as enqueue-then-flush so unfused callers see
    unchanged semantics (still exactly one round per call).

    Counters (read by tests, the quick benchmark, and the cost-model
    validation): ``n_rounds`` flushes fired, ``round_bytes`` per-party
    one-direction bytes of each flush, ``bytes_tx`` their sum, and
    ``round_parts`` the number of payloads each flush coalesced — the
    round-schedule simulator (``core.schedule``) predicts all three
    sequences exactly, including the payload-count drop when
    ``relu_many`` auto-batches identical sibling streams.
    """

    def __init__(self, base=None):
        self.base = base or SimComm()
        self.n_parties = self.base.n_parties
        self._queue: List[Tuple[List[jax.Array], Any]] = []
        self.n_rounds = 0
        self.round_bytes: List[int] = []
        self.round_parts: List[int] = []

    @property
    def bytes_tx(self) -> int:
        return sum(self.round_bytes)

    def enqueue(self, payload) -> int:
        """Defer a payload to the current round; returns its handle."""
        leaves, treedef = jax.tree_util.tree_flatten(payload)
        for leaf in leaves:
            if leaf.dtype != _U32:
                raise TypeError(
                    f"CoalescingComm payloads must be uint32, got {leaf.dtype}")
        self._queue.append((leaves, treedef))
        return len(self._queue) - 1

    def flush(self) -> List[Any]:
        """Fire the round: one flattened swap; returns payloads by handle."""
        if not self._queue:
            return []
        queue, self._queue = self._queue, []
        flat = [leaf.reshape(leaf.shape[0], -1)
                for leaves, _ in queue for leaf in leaves]
        buf = jnp.concatenate(flat, axis=1) if len(flat) > 1 else flat[0]
        self.n_rounds += 1
        self.round_bytes.append(payload_bytes(buf))
        self.round_parts.append(len(queue))
        opened = self.base.swap(buf)
        results = []
        off = 0
        for leaves, treedef in queue:
            out_leaves = []
            for leaf in leaves:
                n = leaf.size // leaf.shape[0]
                out_leaves.append(opened[:, off:off + n].reshape(leaf.shape))
                off += n
            results.append(jax.tree_util.tree_unflatten(treedef, out_leaves))
        return results

    def swap(self, x):
        """Immediate exchange (enqueue + flush): still one round."""
        h = self.enqueue(x)
        return self.flush()[h]

    def note_rounds(self, n: int, nbytes: Optional[int] = None,
                    parts: Optional[int] = None) -> None:
        """Account ``n`` additional rounds executed inside compiled control
        flow.  ``lax.scan`` traces its body exactly once, so a scan over L
        uniform protocol rounds fires ``swap`` once at trace time and the
        remaining L-1 trips never re-enter Python; the scanned protocol
        code calls this afterwards so the counters keep matching the
        schedule simulator round for round.  Defaults replicate the last
        recorded round (the scanned rounds are uniform by construction).
        """
        if n <= 0:
            return
        if nbytes is None:
            nbytes = self.round_bytes[-1] if self.round_bytes else 0
        if parts is None:
            parts = self.round_parts[-1] if self.round_parts else 1
        self.n_rounds += n
        self.round_bytes.extend([nbytes] * n)
        self.round_parts.extend([parts] * n)

    def replay_counters(self, n_rounds: int, round_bytes: List[int],
                        round_parts: List[int]) -> None:
        """Merge another CoalescingComm's recorded timeline into this one.
        The compiled replay (``api/compile.py``) traces onto a private
        comm whose counters fill exactly once at trace time; each
        *execution* of the cached program replays those counters onto the
        caller's comm so engine/benchmark accounting is unchanged."""
        self.n_rounds += n_rounds
        self.round_bytes.extend(round_bytes)
        self.round_parts.extend(round_parts)

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        return self.base.party_is(p, template)

    def party_slice(self, full: jax.Array) -> jax.Array:
        return self.base.party_slice(full)


# ---------------------------------------------------------------------------
# Resilient transport: framing + detection + retry/backoff
# ---------------------------------------------------------------------------

_CKSUM_MULT = np.uint64(2654435761)          # Knuth's multiplicative hash
_SEQ_MIX = np.uint64(0x9E3779B1)
_U32_MASK = np.uint64(0xFFFFFFFF)


def frame_checksum(words, seq: int) -> np.ndarray:
    """Per-party checksum of a (P, n) uint32 wire buffer under round seq.

    Position-weighted multiplicative mix: a flip of any single word (or a
    swap of two words) changes the sum by a nonzero odd multiple mod 2^32,
    so single-word corruption is always detected; ``seq`` is folded in so
    a stale round's frame can never verify against the current round.
    """
    w = np.asarray(words, dtype=np.uint32).astype(np.uint64)
    idx = (np.arange(w.shape[-1], dtype=np.uint64) * _CKSUM_MULT) & _U32_MASK
    acc = (((w ^ idx) * _CKSUM_MULT) & _U32_MASK).sum(axis=-1) & _U32_MASK
    return (acc ^ ((np.uint64(seq) * _SEQ_MIX) & _U32_MASK)).astype(np.uint32)


class ResilientComm:
    """Framed, self-healing transport wrapper over any eager base backend.

    Every ``swap`` flattens its payload pytree into one (P, n) uint32
    buffer and appends ``FRAME_WORDS`` framing words — the round sequence
    number and a per-party checksum — before exchanging.  On receipt the
    frame is verified: a sequence mismatch means the parties desynced
    (e.g. a duplicated/stale delivery), a checksum mismatch means payload
    corruption; either triggers an idempotent re-send of the SAME framed
    buffer.  An attempt that raises a transient comm fault (injected by
    ``core.faults.FaultInjectingComm`` today, a socket timeout under a
    real transport) or that takes longer than ``timeout_s`` is likewise
    retried, with bounded exponential backoff between attempts.  Only when
    the per-round retry budget is exhausted does the typed error
    (``errors.CommTimeout`` / ``PayloadCorrupted`` / ``PartyCrashed``)
    propagate to the caller.

    Composition: ``CoalescingComm(ResilientComm(base))`` — coalescing
    above, so the whole fused round is ONE framed exchange and re-sends
    never add protocol rounds (the CoalescingComm/schedule round counters
    are untouched by retries).  ``core.schedule``'s ``Schedule.framed()``
    prices the framing overhead, so measured ``round_bytes`` here equal
    the framed schedule prediction exactly; failed attempts accumulate in
    ``resent_bytes`` (recovery overhead), never in ``round_bytes``.

    Counters: ``n_rounds``/``round_bytes``/``bytes_tx`` (successful framed
    rounds), ``retries`` (failed attempts), ``recovered`` (rounds that
    needed at least one retry), ``resent_bytes``, and ``faults_detected``
    by kind ("timeout", "corrupt", "crash").
    """

    def __init__(self, base=None, *, max_retries: int = 3,
                 timeout_s: Optional[float] = None,
                 backoff_s: float = 0.0, backoff_cap_s: float = 1.0):
        self.base = base if base is not None else SimComm()
        self.n_parties = self.base.n_parties
        self.max_retries = max_retries
        self.timeout_s = timeout_s
        self.backoff_s = backoff_s
        self.backoff_cap_s = backoff_cap_s
        self.reset()

    def reset(self) -> None:
        self._seq = 0
        self.n_rounds = 0
        self.round_bytes: List[int] = []
        self.retries = 0
        self.recovered = 0
        self.resent_bytes = 0
        self.faults_detected: Dict[str, int] = {
            "timeout": 0, "corrupt": 0, "crash": 0}

    @property
    def bytes_tx(self) -> int:
        return sum(self.round_bytes)

    # -- framing ---------------------------------------------------------------
    def _flatten(self, x) -> Tuple[jax.Array, List[jax.Array], Any]:
        leaves, treedef = jax.tree_util.tree_flatten(x)
        for leaf in leaves:
            if leaf.dtype != _U32:
                raise TypeError(
                    f"ResilientComm payloads must be uint32, got {leaf.dtype}")
        flat = [jnp.reshape(leaf, (leaf.shape[0], -1)) for leaf in leaves]
        buf = jnp.concatenate(flat, axis=1) if len(flat) > 1 else flat[0]
        return buf, leaves, treedef

    def _frame(self, buf: jax.Array) -> jax.Array:
        seq_col = jnp.full((buf.shape[0], 1), jnp.uint32(self._seq & 0xFFFFFFFF))
        cksum = jnp.asarray(frame_checksum(buf, self._seq)).reshape(-1, 1)
        return jnp.concatenate([buf, seq_col, cksum], axis=1)

    def _verify(self, opened) -> np.ndarray:
        """Checks the received frame; raises typed errors on mismatch and
        returns the received payload words (host array) on success."""
        got = np.asarray(opened, dtype=np.uint32)
        payload, seq_col, cksum_col = (got[:, :-FRAME_WORDS], got[:, -2],
                                       got[:, -1])
        if not (seq_col == np.uint32(self._seq & 0xFFFFFFFF)).all():
            raise errors.PayloadCorrupted(
                f"round desync: expected seq {self._seq}, received "
                f"{sorted(set(int(s) for s in seq_col))}")
        want = frame_checksum(payload, self._seq)
        if not (cksum_col == want).all():
            bad = [p for p in range(got.shape[0]) if cksum_col[p] != want[p]]
            raise errors.PayloadCorrupted(
                f"checksum mismatch on round {self._seq} "
                f"(party rows {bad}): payload corrupted in flight")
        return payload

    # -- the exchange ----------------------------------------------------------
    def swap(self, x):
        buf, leaves, treedef = self._flatten(x)
        framed = self._frame(buf)
        frame_cost = payload_bytes(framed)
        attempt = 0
        while True:
            t0 = time.monotonic()
            try:
                opened = self.base.swap(framed)
                if (self.timeout_s is not None
                        and time.monotonic() - t0 > self.timeout_s):
                    raise errors.CommTimeout(
                        f"round {self._seq}: exchange stalled past "
                        f"{self.timeout_s}s")
                payload = self._verify(opened)
                break
            except errors.CommError as e:
                kind = ("crash" if isinstance(e, errors.PartyCrashed) else
                        "corrupt" if isinstance(e, errors.PayloadCorrupted)
                        else "timeout")
                self.faults_detected[kind] += 1
                self.resent_bytes += frame_cost
                # A crashed peer cannot be healed by a re-send: recovery
                # is restart + journal resume, owned by the layer above.
                if isinstance(e, errors.PartyCrashed):
                    raise
                if attempt >= self.max_retries:
                    raise
                self.retries += 1
                attempt += 1
                if self.backoff_s > 0:
                    time.sleep(min(self.backoff_s * 2 ** (attempt - 1),
                                   self.backoff_cap_s))
        self.n_rounds += 1
        self.round_bytes.append(frame_cost)
        if attempt:
            self.recovered += 1
        self._seq += 1
        out_leaves, off = [], 0
        payload = jnp.asarray(payload)
        for leaf in leaves:
            n = leaf.size // leaf.shape[0]
            out_leaves.append(payload[:, off:off + n].reshape(leaf.shape))
            off += n
        return jax.tree_util.tree_unflatten(treedef, out_leaves)

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        return self.base.party_is(p, template)

    def party_slice(self, full: jax.Array) -> jax.Array:
        return self.base.party_slice(full)


def find_comm(comm, cls):
    """First wrapper of type ``cls`` in a comm stack (walks the ``.base``
    chain).  Lets callers reach a specific layer's counters without
    knowing how the stack was composed — e.g. the serving frontend digs
    out the ``transport.SocketComm`` for its wire-byte stats."""
    seen = set()
    while comm is not None and id(comm) not in seen:
        seen.add(id(comm))
        if isinstance(comm, cls):
            return comm
        comm = getattr(comm, "base", None)
    return None


def find_resilient(comm) -> Optional[ResilientComm]:
    """The ``ResilientComm`` inside a wrapper stack, if any (the serving
    engine reads its recovery counters per batch)."""
    return find_comm(comm, ResilientComm)
