"""Chaos property tests: recovered executions are bit-exact.

The contract under test, per ISSUE 6's acceptance criteria:

- Under any seeded ``FaultPlan`` of transient faults (drops, stalls,
  corrupted payloads), the resilient stack's outputs are bit-exact vs the
  frozen seed reference (``core/gmw_ref.py``) — recovery never perturbs a
  share.
- Retry counts match the plan exactly: one re-send per transient event,
  counted both at the injector (``FaultInjectingComm.injected``) and the
  transport (``ResilientComm.retries``/``faults_detected``).
- ``CoalescingComm`` round counters still match the ``core.schedule``
  prediction once injected re-sends are excluded (re-sends live below the
  coalescer and never add protocol rounds), and the framed byte counts
  match ``Schedule.framed()`` exactly.
- A party crash is not retryable by re-send: it propagates typed, and the
  ``RoundJournal`` resume path completes the execution bit-identically.
"""
import jax
import numpy as np
import pytest

from repro import errors
from repro.core import (beaver, comm as comm_lib, faults, fixed, gmw,
                        gmw_ref, ring, schedule, shares)

try:                                   # optional: property test only
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


_KM_POOL = [(64, 0), (21, 13), (20, 14), (5, 3), (2, 1)]


def _make_group(n, k, m, cone, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-3.5, 3.5, n).astype(np.float32)
    X = shares.share(jax.random.PRNGKey(seed), fixed.encode_np(x))
    tri = beaver.gen_relu_triples(jax.random.PRNGKey(seed + 1), n, k - m,
                                  cone=cone)
    return X, tri


def _mix(specs, cone, seed):
    keys, Xs, trs = [], [], []
    for i, (n, k, m) in enumerate(specs):
        X, tri = _make_group(n, k, m, cone, seed + 10 * i)
        keys.append(jax.random.PRNGKey(seed + 1000 + i))
        Xs.append(X)
        trs.append(tri)
    return keys, Xs, trs


def _check_chaos_mix(specs, fault_seed, cone=False, seed=0,
                     drops=1, corrupts=1, stalls=1):
    """Run a stream mix through the full chaos stack and assert every
    contract: bit-exactness vs gmw_ref, retry accounting, and round/byte
    counters vs the schedule prediction (re-sends excluded)."""
    kms = [(k, m) for _, k, m in specs]
    sched = schedule.simulate([(n, k - m) for n, k, m in specs],
                              cone=cone, auto_batch=False)
    plan = faults.FaultPlan.seeded(fault_seed, sched.n_rounds, drops=drops,
                                  corrupts=corrupts, stalls=stalls)
    fic = faults.FaultInjectingComm(plan)
    rc = comm_lib.ResilientComm(fic, max_retries=4)
    cc = comm_lib.CoalescingComm(rc)

    keys, Xs, trs = _mix(specs, cone, seed)
    outs = gmw.relu_many(keys, Xs, trs, cc, kms, cone=cone,
                         auto_batch=False)

    # bit-exact vs the frozen seed reference, share level
    ref_cm = comm_lib.SimComm()
    for (n, k, m), key, X, tri, out in zip(specs, keys, Xs, trs, outs):
        ref = gmw_ref.relu(key, X, tri, ref_cm, k=k, m=m, cone=cone)
        np.testing.assert_array_equal(ring.to_uint64_np(out),
                                      ring.to_uint64_np(ref))

    # retries match the plan: one re-send per transient event, realized
    assert rc.retries == plan.n_transient
    assert rc.recovered == plan.n_transient          # distinct rounds
    assert fic.injected["drop"] == plan.count("drop")
    assert fic.injected["stall"] == plan.count("stall")
    assert fic.injected["corrupt"] == plan.count("corrupt")
    assert (rc.faults_detected["timeout"]
            == plan.count("drop") + plan.count("stall"))
    assert rc.faults_detected["corrupt"] == plan.count("corrupt")

    # round counters: the coalescer (above the resilient layer) never
    # sees a re-send — its counters equal the fault-free prediction
    assert cc.n_rounds == sched.n_rounds == fic.round
    assert cc.round_bytes == list(sched.round_bytes)
    # the wire itself carries the frame: measured == framed prediction
    framed = sched.framed()
    assert rc.round_bytes == list(framed.round_bytes)
    assert rc.bytes_tx == framed.bytes_tx
    # recovery overhead: every failed attempt re-ships one framed round
    assert rc.resent_bytes > 0 if plan.events else rc.resent_bytes == 0


# ---------------------------------------------------------------------------
# Deterministic scenario coverage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("specs,cone", [
    ([(64, 21, 13)], False),
    ([(96, 64, 0), (160, 21, 13), (64, 20, 14)], False),
    ([(48, 21, 13), (48, 20, 14)], True),
    ([(40, 2, 1), (40, 64, 0)], False),      # w=1 next to a deep ring
])
def test_chaos_mix_bit_exact(specs, cone):
    _check_chaos_mix(specs, fault_seed=11, cone=cone)


def test_no_faults_no_overhead():
    """An empty plan injects nothing: zero retries, zero resent bytes."""
    _check_chaos_mix([(64, 21, 13)], fault_seed=0,
                     drops=0, corrupts=0, stalls=0)


if HAVE_HYPOTHESIS:
    _GROUP = st.tuples(
        st.integers(min_value=1, max_value=80),
        st.sampled_from(_KM_POOL),
    )

    @settings(max_examples=6, deadline=None)
    @given(groups=st.lists(_GROUP, min_size=1, max_size=3),
           fault_seed=st.integers(min_value=0, max_value=2**16),
           cone=st.booleans())
    def test_chaos_property_random_mixes(groups, fault_seed, cone):
        specs = [(n, k, m) for n, (k, m) in groups]
        _check_chaos_mix(specs, fault_seed=fault_seed, cone=cone, seed=7)


@pytest.mark.parametrize("case_seed", [0, 1, 2, 3])
def test_chaos_random_sweep(case_seed):
    """Deterministic randomized sweep (runs with or without hypothesis):
    random mixes under random fault schedules, including multi-event
    plans heavier than the default."""
    rng = np.random.default_rng(300 + case_seed)
    n_groups = int(rng.integers(1, 4))
    specs = []
    for _ in range(n_groups):
        n = int(rng.choice([16, 32, 50, 80]))
        k, m = _KM_POOL[int(rng.integers(len(_KM_POOL)))]
        specs.append((n, k, m))
    _check_chaos_mix(specs, fault_seed=int(rng.integers(2**16)),
                     cone=bool(case_seed % 2), seed=400 + case_seed,
                     drops=int(rng.integers(0, 3)),
                     corrupts=int(rng.integers(0, 3)),
                     stalls=int(rng.integers(0, 2)))


# ---------------------------------------------------------------------------
# Transport semantics
# ---------------------------------------------------------------------------

def test_retry_budget_exhaustion_raises_typed():
    """More consecutive faults on one round than the retry budget: the
    typed error propagates (transient events at the same round each
    consume one attempt)."""
    plan = faults.FaultPlan(tuple(
        faults.FaultEvent(round=0, kind="drop") for _ in range(3)))
    rc = comm_lib.ResilientComm(faults.FaultInjectingComm(plan),
                                max_retries=1)
    x = jax.numpy.zeros((2, 4), jax.numpy.uint32)
    with pytest.raises(errors.CommTimeout) as ei:
        rc.swap(x)
    assert errors.is_retryable(ei.value)
    assert rc.retries == 1                     # budget, not event count


def test_corruption_detected_wherever_it_lands():
    """Any single-bit flip in the framed buffer — payload, seq word or
    checksum word — fails verification and is healed by the re-send."""
    for word in [0, 3, 100, 101, 7919]:
        plan = faults.FaultPlan((faults.FaultEvent(
            round=0, kind="corrupt", word=word, bit=word % 32),))
        rc = comm_lib.ResilientComm(faults.FaultInjectingComm(plan))
        x = jax.numpy.arange(2 * 4, dtype=jax.numpy.uint32).reshape(2, 4)
        out = rc.swap(x)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(comm_lib.SimComm().swap(x)))
        assert rc.retries == 1 and rc.faults_detected["corrupt"] == 1


def test_crash_is_not_retryable_by_resend():
    plan = faults.FaultPlan.seeded(0, 4, drops=0, corrupts=0, crash_round=0)
    rc = comm_lib.ResilientComm(faults.FaultInjectingComm(plan),
                                max_retries=5)
    with pytest.raises(errors.PartyCrashed) as ei:
        rc.swap(jax.numpy.zeros((2, 2), jax.numpy.uint32))
    assert not errors.is_retryable(ei.value)
    assert rc.retries == 0                     # no re-send was attempted


def test_timeout_detection_on_slow_base():
    """ResilientComm's own elapsed-time check: a base comm slower than
    timeout_s raises CommTimeout after the budget, without any injector
    in the stack."""
    class SlowComm(comm_lib.SimComm):
        def swap(self, x):
            import time
            time.sleep(0.02)
            return super().swap(x)

    rc = comm_lib.ResilientComm(SlowComm(), max_retries=1, timeout_s=0.001)
    with pytest.raises(errors.CommTimeout):
        rc.swap(jax.numpy.zeros((2, 2), jax.numpy.uint32))
    assert rc.faults_detected["timeout"] == 2        # attempt + retry


# ---------------------------------------------------------------------------
# Crash + journal resume: bit-identical completion
# ---------------------------------------------------------------------------

def test_crash_then_journal_resume_bit_identical(tmp_path):
    """Crash mid-replay, snapshot the journal at the barrier, restart a
    fresh stack with the journal mounted: recorded rounds replay off the
    wire and the final shares equal an uninterrupted run's exactly."""
    specs = [(64, 21, 13), (32, 20, 14)]
    kms = [(k, m) for _, k, m in specs]
    keys, Xs, trs = _mix(specs, False, 5)
    ref = gmw.relu_many(keys, Xs, trs,
                        comm_lib.CoalescingComm(comm_lib.SimComm()), kms,
                        auto_batch=False)

    plan = faults.FaultPlan.seeded(0, 10, drops=0, corrupts=0,
                                   crash_round=3)
    jc = faults.JournaledComm(comm_lib.ResilientComm(
        faults.FaultInjectingComm(plan)))
    with pytest.raises(errors.PartyCrashed):
        gmw.relu_many(keys, Xs, trs, comm_lib.CoalescingComm(jc), kms,
                      auto_batch=False)
    jc.snapshot(str(tmp_path))

    journal = faults.RoundJournal.load(str(tmp_path))
    assert len(journal) == 3                   # rounds completed pre-crash
    jc2 = faults.JournaledComm(comm_lib.ResilientComm(), journal=journal)
    outs = gmw.relu_many(keys, Xs, trs, comm_lib.CoalescingComm(jc2), kms,
                         auto_batch=False)
    assert jc2.replayed == 3
    for a, b in zip(outs, ref):
        np.testing.assert_array_equal(ring.to_uint64_np(a),
                                      ring.to_uint64_np(b))


def test_journal_snapshot_is_torn_write_safe(tmp_path):
    """An uncommitted snapshot directory is invisible to load()."""
    j = faults.RoundJournal()
    j.record([np.arange(8, dtype=np.uint32).reshape(2, 4)])
    j.save(str(tmp_path))
    bad = tmp_path / "step_00000009"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")   # torn write, no sentinel
    loaded = faults.RoundJournal.load(str(tmp_path))
    assert len(loaded) == 1
    np.testing.assert_array_equal(loaded.rounds[0][0], j.rounds[0][0])
