"""party_host: run ONE party of the 2PC protocol as its own OS process.

The deployment entry point behind ``docs/deployment.md``::

    # terminal 1 — party 0 hosts the link
    python -m repro.launch.party_host --party 0 --job jobdir \
        --listen 127.0.0.1:9000

    # terminal 2 — party 1 dials in
    python -m repro.launch.party_host --party 1 --job jobdir \
        --peer 127.0.0.1:9000

Both processes load their own view of the job directory (their input
share rows + their slice of the offline triple pool — see
``repro.transport.job``), handshake (session seed, plan digest, party
complement), and replay the SAME plan with ``Session.connect``'s
resilience stack underneath: socket timeouts heal by idempotent
re-send, and with ``--journal DIR`` every verified fused round is
snapshotted so a killed process — ``kill -9`` at any round — restarts,
renegotiates the common journal prefix with its peer, replays it
without touching the wire, and finishes bit-identically
(``tests/test_transport.py`` asserts exactly this).

Modes:

- one-shot (default): run the job's private inference once, write
  ``out{party}.npz`` (this party's output share rows) and
  ``stats{party}.json`` (measured rounds/bytes/wall vs nothing —
  predictions live with the caller) into the job directory, exit 0.
- ``--follow``: serve engine batches forever (the follower side of
  ``repro.transport.engine_link``; the leader is a
  ``repro.serve.Frontend`` process).

Exit codes: 0 done, 17 = peer crashed mid-run (restartable — an
orchestrator should relaunch both parties with the same arguments).

Link shaping (``--rtt-ms`` / ``--mbps``) injects a WAN profile so the
measured wall-clock validates ``core.schedule`` latency predictions
(``benchmarks/run.py --transport``).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

import jax
import numpy as np

from repro import api, errors
from repro.checkpoint import store
from repro.core import beaver, comm as comm_lib, faults as faults_lib
from repro.models import resnet
from repro import transport
from repro.transport.socket import parse_address

EXIT_RESTART = 17


class _DieAfterRounds:
    """Test hook: hard-kill this process after N completed rounds (above
    the journal, so the journal holds exactly N rounds when we die —
    deterministic crash injection for the resume tests)."""

    def __init__(self, base, n_rounds: int):
        self.base = base
        self.n_parties = base.n_parties
        self.left = int(n_rounds)

    def swap(self, x):
        out = self.base.swap(x)
        self.left -= 1
        if self.left <= 0:
            os._exit(42)                   # simulated kill -9, no cleanup
        return out

    def party_is(self, p, template):
        return self.base.party_is(p, template)

    def party_slice(self, full):
        return self.base.party_slice(full)


def _model_afn(cfg):
    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, cfg, relu_fn=relu_fn)
    return afn


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="party_host", description=__doc__.split("\n")[0],
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("--party", type=int, required=True, choices=(0, 1))
    ap.add_argument("--job", required=True, help="job directory "
                    "(see repro.transport.job.write_job)")
    ap.add_argument("--listen", default=None,
                    help="host:port to bind + accept the peer on")
    ap.add_argument("--peer", default=None,
                    help="host:port of the hosting peer to dial")
    ap.add_argument("--rtt-ms", type=float, default=0.0,
                    help="injected round-trip time (link shaping)")
    ap.add_argument("--mbps", type=float, default=0.0,
                    help="injected bandwidth cap in Mbit/s (0 = unshaped)")
    ap.add_argument("--timeout-s", type=float, default=30.0)
    ap.add_argument("--max-retries", type=int, default=3)
    ap.add_argument("--handshake-timeout-s", type=float, default=120.0)
    ap.add_argument("--journal", default=None,
                    help="directory for round-journal snapshots; an "
                    "existing committed snapshot is resumed from")
    ap.add_argument("--snapshot-every", type=int, default=1)
    ap.add_argument("--die-after-round", type=int, default=0,
                    help="test hook: os._exit after N live rounds")
    ap.add_argument("--follow", action="store_true",
                    help="serve engine batches (follower mode) instead "
                    "of the one-shot job inference")
    return ap


def run(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if (args.listen is None) == (args.peer is None):
        print("pass exactly one of --listen / --peer", file=sys.stderr)
        return 2
    job = (transport.load_party(args.job, args.party) if not args.follow
           else transport.load_job(args.job))
    cfg, plan = job["cfg"], job["plan"]
    params = resnet.init(jax.random.PRNGKey(job["params_seed"]), cfg)
    shaper = None
    if args.rtt_ms > 0 or args.mbps > 0:
        shaper = transport.LinkShaper(
            rtt_s=args.rtt_ms / 1e3,
            bandwidth_bps=(args.mbps * 1e6 if args.mbps > 0
                           else float("inf")))

    journal = None
    if args.journal is not None:
        if store.latest_step(args.journal) is not None:
            journal = faults_lib.RoundJournal.load(args.journal)
            print(f"party {args.party}: resuming from journal with "
                  f"{len(journal)} rounds", flush=True)
        else:
            journal = faults_lib.RoundJournal()

    provider = (beaver.TriplePool(job["pool"]) if "pool" in job else None)
    try:
        session = api.Session.connect(
            args.party,
            listen=(parse_address(args.listen) if args.listen else None),
            peer=(parse_address(args.peer) if args.peer else None),
            key=job["session_seed"], provider=provider,
            session_id=str(job["session_seed"]), plan_digest=plan.digest(),
            journal=journal, snapshot_dir=args.journal,
            snapshot_every=args.snapshot_every, shaper=shaper,
            timeout_s=args.timeout_s, max_retries=args.max_retries,
            handshake_timeout_s=args.handshake_timeout_s)
    except errors.HandshakeFailed as e:
        print(f"party {args.party}: handshake failed: {e}", file=sys.stderr)
        return 3
    sock = session.transport
    if args.die_after_round > 0:
        session.comm = _DieAfterRounds(session.comm, args.die_after_round)

    model = api.compile(_model_afn(cfg), params, cfg, plan, session)
    try:
        if args.follow:
            served = transport.serve_follower(
                sock, model,
                provider_factory=transport.tenant_provider_factory(
                    job["ttp_seed"], party=args.party),
                max_retries=args.max_retries)
            print(f"party {args.party}: served {served} batches",
                  flush=True)
            return 0
        return _one_shot(args, job, model, session, sock)
    except errors.PartyCrashed as e:
        # snapshot whatever completed so the relaunch resumes, not restarts
        if args.journal is not None:
            journaled = comm_lib.find_comm(session.comm,
                                           faults_lib.JournaledComm)
            if journaled is not None and len(journaled.journal):
                journaled.snapshot(args.journal)
        print(f"party {args.party}: peer crashed ({e}); exit "
              f"{EXIT_RESTART} for restart + journal resume",
              file=sys.stderr)
        return EXIT_RESTART
    finally:
        sock.close()


def _one_shot(args, job, model, session, sock) -> int:
    journaled = comm_lib.find_comm(session.comm, faults_lib.JournaledComm)
    resilient = comm_lib.find_resilient(session.comm)
    t0 = time.monotonic()
    out = model(job["X"], key=jax.random.PRNGKey(job["infer_key"]))
    wall = time.monotonic() - t0
    out_dir = pathlib.Path(args.job)
    np.savez(out_dir / f"out{args.party}.npz",
             lo=np.asarray(out.data.lo), hi=np.asarray(out.data.hi))
    stats = {
        "party": args.party,
        "rounds": sock.n_swaps,
        "payload_bytes": sock.bytes_tx,
        "header_bytes": sock.header_bytes,
        "dup_dropped": sock.dup_dropped,
        "retries": resilient.retries if resilient else 0,
        "recovered": resilient.recovered if resilient else 0,
        "replayed": journaled.replayed if journaled else 0,
        "resume_round": sock.negotiated.get("resume_round", 0),
        "wall_s": wall,
        "shaped": sock.shaper is not None,
    }
    (out_dir / f"stats{args.party}.json").write_text(
        json.dumps(stats, indent=1))
    print(f"party {args.party}: {stats['rounds']} rounds, "
          f"{stats['payload_bytes']} payload bytes, "
          f"{wall:.3f}s wall ({stats['replayed']} replayed from journal)",
          flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(run())
