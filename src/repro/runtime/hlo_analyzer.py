"""HLO-text analyzer: trip-count-corrected FLOPs / bytes / collective bytes.

``compiled.cost_analysis()`` reports post-SPMD *per-device* numbers but
counts while-loop bodies (``lax.scan`` over layers, chunked attention)
exactly once.  This analyzer re-derives the roofline terms from
``compiled.as_text()``:

  - builds a per-computation symbol table (%name -> shape/dtype),
  - counts dot/convolution FLOPs with operand-shape lookups,
  - counts collective payload bytes (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute),
  - estimates HBM bytes as a *fusion-optimal lower bound*: operands+outputs
    of dots/convs, 2x payload for copies and dynamic-update-slice, slice
    size for dynamic-slice, plus entry parameters/outputs once (elementwise
    chains are assumed perfectly fused on TPU),
  - walks the call graph (fusions, while bodies, conditionals) multiplying
    by ``known_trip_count`` for loops.

Validated against unrolled cost_analysis in tests/test_hlo_analyzer.py.
Byte conventions: all-reduce counts 2x payload (reduce-scatter+all-gather
equivalent); others count 1x payload.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_PARAM_RE = re.compile(r"%([\w.\-]+)\s*=\s*(.+?)\s+parameter\(")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class OpInfo:
    name: str
    out_type: str
    kind: str
    line: str
    operands: List[str]


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective instruction of the walked program.

    ``bytes`` is the one-direction payload of a single execution as seen
    by the local shard (for a collective-permute under ``shard_map`` over
    the party axis this is exactly the per-party one-direction wire bytes
    the round-schedule simulator predicts); ``count`` is how many times
    the instruction executes after while-loop trip-count scaling.
    """

    kind: str
    bytes: int
    count: int = 1


@dataclasses.dataclass
class Metrics:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "Metrics":
        return Metrics(self.flops * k, self.bytes * k,
                       self.collective_bytes * k,
                       {n: int(c * k) for n, c in self.collective_counts.items()})

    def add(self, o: "Metrics"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.collective_bytes += o.collective_bytes
        for n, c in o.collective_counts.items():
            self.collective_counts[n] = self.collective_counts.get(n, 0) + c


class HloAnalysis:
    def __init__(self, hlo_text: str):
        self.computations = self._split_computations(hlo_text)
        self.entry = next((n for n, (is_entry, _) in self.computations.items()
                           if is_entry), None)
        self._cache: Dict[str, Metrics] = {}

    @staticmethod
    def _split_computations(text: str):
        comps: Dict[str, Tuple[bool, List[str]]] = {}
        current: Optional[str] = None
        lines_acc: List[str] = []
        is_entry = False
        for line in text.splitlines():
            hdr = _COMP_HDR.match(line)
            # op definitions are indented and contain " = "; tuple return
            # types may contain "/*index=N*/" comments, so test " = " only
            if hdr and " = " not in line.split(" {")[0].split("(")[0]:
                current = hdr.group(2)
                is_entry = bool(hdr.group(1))
                lines_acc = []
                continue
            if current is not None:
                if line.strip() == "}":
                    comps[current] = (is_entry, lines_acc)
                    current = None
                else:
                    lines_acc.append(line)
        return comps

    # -- per-computation op parse ------------------------------------------

    def _ops(self, comp: str) -> Tuple[Dict[str, str], List[OpInfo]]:
        symtab: Dict[str, str] = {}
        ops: List[OpInfo] = []
        _, lines = self.computations[comp]
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, out_type, kind = m.groups()
            symtab[name] = out_type
            rest = line[m.end() - 1:]
            om = _OPERANDS_RE.match(rest)
            operands = []
            if om:
                for tok in om.group(1).split(","):
                    tok = tok.strip()
                    if tok.startswith("%"):
                        operands.append(tok[1:])
                    else:
                        mm = re.search(r"%([\w.\-]+)", tok)
                        if mm:
                            operands.append(mm.group(1))
            ops.append(OpInfo(name, out_type, kind, line, operands))
        return symtab, ops

    def _dot_flops(self, op: OpInfo, symtab) -> float:
        _, out_dims = _shape_dims(op.out_type)
        out_n = 1
        for d in out_dims:
            out_n *= d
        lhs_type = symtab.get(op.operands[0], "") if op.operands else ""
        _, lhs_dims = _shape_dims(lhs_type)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        k = 1
        if m and lhs_dims:
            for idx in m.group(1).split(","):
                if idx:
                    k *= lhs_dims[int(idx)]
        return 2.0 * out_n * k

    def _conv_flops(self, op: OpInfo, symtab) -> float:
        _, out_dims = _shape_dims(op.out_type)
        out_n = 1
        for d in out_dims:
            out_n *= d
        rhs_type = symtab.get(op.operands[1], "") if len(op.operands) > 1 else ""
        _, rhs_dims = _shape_dims(rhs_type)
        rhs_n = 1
        for d in rhs_dims:
            rhs_n *= d
        dm = re.search(r"dim_labels=\w*_(\w+)->", op.line)
        o_count = 1
        if dm and rhs_dims:
            o_pos = dm.group(1).index("o")
            o_count = rhs_dims[o_pos]
        # grouped convs: rhs input-feature dim is already Cin/groups, so
        # rhs_n / o_count is the per-output-element MAC count in all cases
        return 2.0 * out_n * (rhs_n / max(o_count, 1))

    # -- call-graph walk -----------------------------------------------------

    def metrics(self, comp: Optional[str] = None) -> Metrics:
        comp = comp or self.entry
        if comp in self._cache:
            return self._cache[comp]
        total = Metrics()
        if comp not in self.computations:
            return total
        symtab, ops = self._ops(comp)
        for op in ops:
            # bytes: fusion-optimal HBM traffic lower bound
            if op.kind in ("dot", "convolution"):
                op_bytes = _shape_bytes(op.out_type)
                for o in op.operands:
                    op_bytes += _shape_bytes(symtab.get(o, ""))
                total.bytes += op_bytes
            elif op.kind == "copy":
                total.bytes += 2 * _shape_bytes(op.out_type)
            elif op.kind == "dynamic-update-slice":
                upd = (_shape_bytes(symtab.get(op.operands[1], ""))
                       if len(op.operands) > 1 else 0)
                total.bytes += 2 * upd
            elif op.kind == "dynamic-slice":
                total.bytes += 2 * _shape_bytes(op.out_type)
            elif op.kind in COLLECTIVES:
                total.bytes += 2 * _shape_bytes(op.out_type)
            if op.kind == "dot":
                total.flops += self._dot_flops(op, symtab)
            elif op.kind == "convolution":
                total.flops += self._conv_flops(op, symtab)
            elif op.kind in COLLECTIVES:
                payload = _shape_bytes(op.out_type)
                mult = 2.0 if op.kind == "all-reduce" else 1.0
                total.collective_bytes += payload * mult
                total.collective_counts[op.kind] = (
                    total.collective_counts.get(op.kind, 0) + 1)
            if op.kind == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    total.add(self.metrics(m.group(1)))
            elif op.kind == "while":
                trips = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trips = int(tm.group(1))
                bm = _BODY_RE.search(op.line)
                if bm:
                    total.add(self.metrics(bm.group(1)).scaled(trips))
            elif op.kind == "conditional":
                bm = _BRANCHES_RE.search(op.line)
                if bm:
                    branches = [b.strip().lstrip("%") for b in
                                bm.group(1).split(",") if b.strip()]
                    # cost = the max branch (one branch executes)
                    branch_ms = [self.metrics(b) for b in branches]
                    if branch_ms:
                        total.add(max(branch_ms, key=lambda m_: m_.flops))
            elif op.kind == "call":
                m = re.search(r"to_apply=%?([\w.\-]+)", op.line)
                if m:
                    total.add(self.metrics(m.group(1)))
        self._cache[comp] = total
        return total


    # -- collective census ---------------------------------------------------

    def collectives(self, comp: Optional[str] = None,
                    scale: int = 1) -> List[CollectiveOp]:
        """Program-order census of every collective the program executes.

        Walks the same call graph as ``metrics`` (fusions, while bodies
        scaled by ``known_trip_count``, calls; conditionals take the
        byte-heaviest branch) and emits one ``CollectiveOp`` per
        collective instruction in program order.  Async pairs
        (``*-start``/``*-done``) count once, at the start, with the
        payload taken from the start's operand shape.
        """
        out: List[CollectiveOp] = []
        comp = comp or self.entry
        if comp not in self.computations:
            return out
        symtab, ops = self._ops(comp)
        for op in ops:
            base = op.kind
            if base.endswith("-done"):
                continue
            if base.endswith("-start"):
                base = base[: -len("-start")]
            if base in COLLECTIVES:
                if op.kind.endswith("-start"):
                    payload = (_shape_bytes(symtab.get(op.operands[0], ""))
                               if op.operands else 0)
                    if payload == 0:   # operand outside this scope: the
                        payload = _shape_bytes(op.out_type) // 2
                        # start's tuple type carries (operand, result)
                else:
                    payload = _shape_bytes(op.out_type)
                out.append(CollectiveOp(base, payload, scale))
            if op.kind == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    out.extend(self.collectives(m.group(1), scale))
            elif op.kind == "while":
                trips = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trips = int(tm.group(1))
                bm = _BODY_RE.search(op.line)
                if bm:
                    out.extend(self.collectives(bm.group(1), scale * trips))
            elif op.kind == "conditional":
                bm = _BRANCHES_RE.search(op.line)
                if bm:
                    branches = [b.strip().lstrip("%") for b in
                                bm.group(1).split(",") if b.strip()]
                    per_branch = [self.collectives(b, scale) for b in branches]
                    if per_branch:
                        out.extend(max(
                            per_branch,
                            key=lambda cs: sum(c.bytes * c.count for c in cs)))
            elif op.kind == "call":
                m = re.search(r"to_apply=%?([\w.\-]+)", op.line)
                if m:
                    out.extend(self.collectives(m.group(1), scale))
        return out


def analyze(hlo_text: str) -> Metrics:
    return HloAnalysis(hlo_text).metrics()


def collective_census(hlo_text: str,
                      kind: Optional[str] = "collective-permute",
                      ) -> List[CollectiveOp]:
    """Census of the collectives a compiled program executes, in program
    order; by default only collective-permutes (the MPC exchange op).

    This is the mesh half of the HLO-vs-costmodel validation: for a
    mesh-native round-fused serve step (``PrivateModel.serve_step(mesh)``
    over a party axis of size 2) the census must list exactly
    ``plan.schedule().n_rounds`` collective-permutes whose per-collective
    bytes match ``plan.schedule().round_bytes`` — the compiled artifact
    *is* the predicted timeline.  Pass ``kind=None`` for every collective.
    """
    census = HloAnalysis(hlo_text).collectives()
    if kind is None:
        return census
    return [c for c in census if c.kind == kind]


def normalize_cost_analysis(ca) -> Dict[str, float]:
    """Compat shim for ``Compiled.cost_analysis()`` across JAX versions.

    Older JAX returned a dict (or a one-element list of per-program dicts);
    newer JAX returns a list.  Consumers index by key ("flops",
    "bytes accessed"), so normalize everything to a single flat dict; an
    empty/None analysis becomes {}.
    """
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)
