"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256; InternViT frontend is a stub providing precomputed patch
embeddings per the brief.  [arXiv:2404.16821]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=28672, vocab=128256, act="silu",
    gated_mlp=True, frontend="vision", n_frontend_tokens=256,
)
