"""ResNet-18/50 — the paper's own workload (CIFAR-sized stem).

Two evaluation paths over one weight pytree:
  - `apply`: plaintext JAX forward (training, search simulator).
  - `mpc_apply`: secret-shared forward on MPCTensors (GMW conv/ReLU), with
    BatchNorm folded into the preceding conv (inference-time standard) and
    max-pool removed per the paper's §2.3 setup.

ReLU layers are organised into the paper's five groups (stem + 4 stages);
each group takes one HummingBird (k, m) assignment.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.api import register_mpc_forward
from repro.configs.resnet import ResNetConfig
from repro.core import MPCTensor, beaver
from repro.core.hummingbird import HBConfig


def _conv_init(key, cout, cin, k):
    scale = (2.0 / (cin * k * k)) ** 0.5
    return jax.random.normal(key, (cout, cin, k, k), jnp.float32) * scale


def _bn_init(c):
    return {"gamma": jnp.ones((c,)), "beta": jnp.zeros((c,)),
            "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def _block_init(key, cin, cout, cfg, stride):
    ks = jax.random.split(key, 4)
    if cfg.block == "basic":
        p = {
            "conv1": _conv_init(ks[0], cout, cin, 3), "bn1": _bn_init(cout),
            "conv2": _conv_init(ks[1], cout, cout, 3), "bn2": _bn_init(cout),
        }
    else:  # bottleneck (expansion 4)
        mid = cout // 4
        p = {
            "conv1": _conv_init(ks[0], mid, cin, 1), "bn1": _bn_init(mid),
            "conv2": _conv_init(ks[1], mid, mid, 3), "bn2": _bn_init(mid),
            "conv3": _conv_init(ks[2], cout, mid, 1), "bn3": _bn_init(cout),
        }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[3], cout, cin, 1)
        p["bn_proj"] = _bn_init(cout)
    return p


def init(key, cfg: ResNetConfig):
    expansion = 1 if cfg.block == "basic" else 4
    ks = jax.random.split(key, 3 + len(cfg.stage_blocks))
    params: Dict = {
        "stem": _conv_init(ks[0], cfg.widths[0], 3, 3),
        "bn_stem": _bn_init(cfg.widths[0]),
        "stages": [],
    }
    cin = cfg.widths[0]
    for si, (n_blocks, width) in enumerate(zip(cfg.stage_blocks, cfg.widths)):
        cout = width * expansion
        stage = []
        bkeys = jax.random.split(ks[1 + si], n_blocks)
        for bi in range(n_blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            stage.append(_block_init(bkeys[bi], cin, cout, cfg, stride))
            cin = cout
        params["stages"].append(stage)
    params["fc"] = {
        "w": jax.random.normal(ks[-1], (cin, cfg.n_classes)) * cin ** -0.5,
        "b": jnp.zeros((cfg.n_classes,)),
    }
    return params


# ---------------------------------------------------------------------------
# Plaintext path
# ---------------------------------------------------------------------------

def _conv(x, w, stride=1, padding=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), [(padding, padding)] * 2,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def _bn(x, p, eps=1e-5):
    inv = p["gamma"] / jnp.sqrt(p["var"] + eps)
    return x * inv[:, None, None] + (p["beta"] - p["mean"] * inv)[:, None, None]


def fold_bn(conv_w, bn, eps=1e-5):
    """Fold BN into conv: returns (w', b') with conv(x, w') + b' == bn(conv)."""
    inv = bn["gamma"] / jnp.sqrt(bn["var"] + eps)
    w = conv_w * inv[:, None, None, None]
    b = bn["beta"] - bn["mean"] * inv
    return w, b


def apply(params, x, cfg: ResNetConfig, relu_fn=None,
          collect_acts: bool = False):
    """x: (B, 3, H, W) -> logits.  `relu_fn(x, group_idx)` lets the search
    simulator substitute the HummingBird approximate ReLU per group."""
    relu = relu_fn or (lambda v, g: jax.nn.relu(v))
    acts: List[jax.Array] = []

    def _relu(v, g):
        if collect_acts:
            acts.append(v)
        return relu(v, g)

    h = _bn(_conv(x, params["stem"]), params["bn_stem"])
    h = _relu(h, 0)
    for si, stage in enumerate(params["stages"]):
        for block in stage:
            stride = 2 if ("proj" in block and si > 0) else 1
            if "conv3" in block:  # bottleneck
                y = _relu(_bn(_conv(h, block["conv1"], 1, 0), block["bn1"]), si + 1)
                y = _relu(_bn(_conv(y, block["conv2"], stride, 1), block["bn2"]), si + 1)
                y = _bn(_conv(y, block["conv3"], 1, 0), block["bn3"])
            else:
                y = _relu(_bn(_conv(h, block["conv1"], stride, 1), block["bn1"]), si + 1)
                y = _bn(_conv(y, block["conv2"], 1, 1), block["bn2"])
            if "proj" in block:
                h = _bn(_conv(h, block["proj"], stride, 0), block["bn_proj"])
            h = _relu(h + y, si + 1)
    h = h.mean(axis=(2, 3))
    logits = h @ params["fc"]["w"] + params["fc"]["b"]
    return (logits, acts) if collect_acts else logits


def n_relu_groups(cfg: ResNetConfig) -> int:
    return 1 + len(cfg.stage_blocks)


def relu_group_elements(params, cfg: ResNetConfig, in_hw: int = 0) -> List[int]:
    """Activation counts per ReLU group for one sample (budget weighting)."""
    hw = in_hw or cfg.in_hw
    x = jnp.zeros((1, 3, hw, hw))
    counts = [0] * n_relu_groups(cfg)

    def counting_relu(v, g):
        counts[g] += int(v.size)
        return jax.nn.relu(v)

    _ = apply(params, x, cfg, relu_fn=counting_relu)
    return counts


# ---------------------------------------------------------------------------
# MPC path
# ---------------------------------------------------------------------------

def hb_or_exact(hb: Optional[HBConfig], cfg: ResNetConfig) -> HBConfig:
    return hb if hb is not None else HBConfig.exact((0,) * n_relu_groups(cfg))


def trace(params, cfg: ResNetConfig, batch: int, hw: int = 0,
          hb: Optional[HBConfig] = None, cone: bool = False):
    """Trace this model into a ``repro.api.Plan`` (the generic planner)."""
    from repro import api

    hw = hw or cfg.in_hw
    return api.trace_plan(
        lambda p, x, relu_fn=None: apply(p, x, cfg, relu_fn=relu_fn),
        params, (batch, 3, hw, hw), hb=hb,
        n_groups=n_relu_groups(cfg) if hb is None else None,
        cone=cone, name=cfg.name)


def relu_plan(params, cfg: ResNetConfig, batch: int, hw: int = 0):
    """Deprecated shim over ``repro.api.trace_plan``: (n_elements, group)
    per ReLU call, in call order."""
    plan = trace(params, cfg, batch, hw)
    return [(c.n_elements, c.group) for c in plan.calls]


def gen_mpc_triples(key, plan, hb: Optional[HBConfig], cfg: ResNetConfig,
                    cone: bool = False):
    """Deprecated shim over ``beaver.gen_plan_triples``: one ReluTriples
    bundle per ReLU call (None for culled width-0 groups).  ``plan`` is the
    (n_elements, group) list from ``relu_plan``."""
    hb_layers = hb_or_exact(hb, cfg).layers
    return beaver.gen_plan_triples(
        key, [(n, hb_layers[g].width) for n, g in plan], cone=cone)


def _mpc_forward(params, hs: List[MPCTensor], cfg: ResNetConfig, relu_fn,
                 comm) -> List[MPCTensor]:
    """Shared MPC forward over sibling streams.

    ``relu_fn(tensors, group) -> tensors`` is invoked once per ReLU point
    with the sibling tensors of every stream, so implementations can share
    protocol rounds across streams (see mpc_apply_many)."""
    w, b = fold_bn(params["stem"], params["bn_stem"])
    hs = [h.conv2d_public(w, 1, 1).add_public(b[:, None, None], comm)
          for h in hs]
    hs = relu_fn(hs, 0)
    for si, stage in enumerate(params["stages"]):
        for block in stage:
            stride = 2 if ("proj" in block and si > 0) else 1
            if "conv3" in block:
                w1, b1 = fold_bn(block["conv1"], block["bn1"])
                ys = relu_fn([h.conv2d_public(w1, 1, 0)
                              .add_public(b1[:, None, None], comm)
                              for h in hs], si + 1)
                w2, b2 = fold_bn(block["conv2"], block["bn2"])
                ys = relu_fn([y.conv2d_public(w2, stride, 1)
                              .add_public(b2[:, None, None], comm)
                              for y in ys], si + 1)
                w3, b3 = fold_bn(block["conv3"], block["bn3"])
                ys = [y.conv2d_public(w3, 1, 0)
                      .add_public(b3[:, None, None], comm) for y in ys]
            else:
                w1, b1 = fold_bn(block["conv1"], block["bn1"])
                ys = relu_fn([h.conv2d_public(w1, stride, 1)
                              .add_public(b1[:, None, None], comm)
                              for h in hs], si + 1)
                w2, b2 = fold_bn(block["conv2"], block["bn2"])
                ys = [y.conv2d_public(w2, 1, 1)
                      .add_public(b2[:, None, None], comm) for y in ys]
            if "proj" in block:
                wp, bp = fold_bn(block["proj"], block["bn_proj"])
                hs = [h.conv2d_public(wp, stride, 0)
                      .add_public(bp[:, None, None], comm) for h in hs]
            hs = relu_fn([h + y for h, y in zip(hs, ys)], si + 1)
    hs = [h.global_avg_pool() for h in hs]
    return [h.matmul_public(params["fc"]["w"])
            .add_public(params["fc"]["b"], comm) for h in hs]


# the generic compiler resolves this forward from the config type
register_mpc_forward(ResNetConfig, _mpc_forward)


def _compiled(params, cfg: ResNetConfig, hb, comm, triples, cone):
    """Shared shim body: bind the old threaded arguments into a Plan +
    Session and compile (see repro.api for the first-class entry point)."""
    from repro import api

    provider = beaver.TriplePool(triples) if triples is not None else None
    session = api.Session(comm=comm, provider=provider)
    plan = api.Plan.from_hb(hb_or_exact(hb, cfg), cone=cone, name=cfg.name)
    return api.compile(
        lambda p, x, relu_fn=None: apply(p, x, cfg, relu_fn=relu_fn),
        params, cfg, plan, session)


def mpc_apply(params, x: MPCTensor, cfg: ResNetConfig, key,
              hb: Optional[HBConfig] = None, comm=None,
              triples: Optional[list] = None, cone: bool = False) -> MPCTensor:
    """Deprecated shim over ``repro.api.compile``: secret-shared inference.

    BN folded into convs; ReLU via GMW with the HummingBird (k, m) of each
    group.  When `triples` is given (mesh serving), they are consumed in
    call order; otherwise generated inline (sim backend).  Outputs are
    bit-identical to the pre-Plan/Session implementation (asserted in
    tests/test_api.py)."""
    return _compiled(params, cfg, hb, comm, triples, cone)(x, key=key)


def mpc_apply_many(params, xs: Sequence[MPCTensor], cfg: ResNetConfig, key,
                   hb: Optional[HBConfig] = None, comm=None,
                   triples: Optional[list] = None,
                   cone: bool = False) -> List[MPCTensor]:
    """Deprecated shim over ``repro.api.compile``: N sibling inference
    streams share ReLU rounds (max-over-streams protocol rounds per layer,
    one coalesced exchange per round — see PrivateModel.__call__).

    ``triples`` keeps the offline TTP split: one entry per ReLU call (in
    call order), each a sequence with one ReluTriples bundle (or None for
    culled groups) per stream."""
    flat = ([b for call in triples for b in call]
            if triples is not None else None)
    return _compiled(params, cfg, hb, comm, flat, cone)(list(xs), key=key)
