"""GMW protocol: A2B, DReLU, B2A, exact ReLU (Eq. 2) and HummingBird's
reduced-ring approximate ReLU (Eq. 3) — round-fused engine.

All functions operate on arrays with a leading party dimension and a
``Comm`` backend (SimComm on one host, MeshComm inside shard_map), so the
same protocol code runs in the search simulator and on the production mesh.

Communication structure (matches §2.2/§2.3 of the paper):
  - A2B prep: each party XOR-shares its arithmetic share      (1 round)
  - adder "Circuit": initial AND + ceil(log2 w) batched ANDs  (1+L rounds)
  - B2A of the sign bit: one Beaver mult on Z/2^64            (1 round)
  - final Mult x*DReLU(x): one Beaver mult on Z/2^64          (1 round)
HummingBird only shrinks the Circuit/prep terms (w = k-m instead of 64),
exactly as the paper's Figure 3/4 describe.

Round-fused engine
------------------
Every protocol primitive here is a *round generator* (``*_rounds``): it
yields exactly one wire payload per communication round and is sent back
the peer's payload.  Two drivers execute the generators:

  - ``drive(gen, comm)``: one ``comm.swap`` per yield — the classic
    single-stream path; rounds and wire bytes are identical to the seed
    implementation (``core/gmw_ref.py``), and exact-path (k=64, m=0)
    outputs are bit-identical to it.
  - ``run_streams(comm, streams)`` / ``relu_many``: N generators advance
    in lockstep and each round's heterogeneous payloads (different widths,
    element counts, even different protocol phases) are coalesced by
    ``comm.CoalescingComm`` into ONE flattened exchange.  Sibling ReLU
    groups therefore share rounds: total rounds = max over groups, not the
    sum, with unchanged total bytes.

Per-round local compute is fused: the dense Kogge-Stone level uses
``kernels.ops.ks_mask`` (plane-shift + Beaver (d, e) masking in one VMEM
pass) before the exchange and ``kernels.ops.ks_combine`` (opening XOR +
Beaver local evaluation + g/p level combine in one pass) after it, instead
of the ~6 separate jnp ops per round the seed path issued.  The
cone-pruned path keeps a compile-time-static position layout: per-plane
tensors tracked in Python dicts at trace time, so XLA sees only static
slices/concats — no runtime ``.at[].set`` scatter.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import beaver, comm as comm_lib, ring, ring_linalg, \
    schedule as schedule_lib, shares
from .schedule import cone_sets  # noqa: F401  (canonical home: core.schedule)

_U32 = jnp.uint32


# ---------------------------------------------------------------------------
# Round-generator drivers
# ---------------------------------------------------------------------------

def drive(gen, comm):
    """Run one round generator to completion: one ``comm.swap`` per round."""
    try:
        payload = gen.send(None)
        while True:
            payload = gen.send(comm.swap(payload))
    except StopIteration as e:
        return e.value


def run_streams(comm, streams: Sequence, on_round=None) -> List:
    """Advance N round generators in lockstep, coalescing each round.

    Every round, all pending streams' payloads are enqueued on a
    ``CoalescingComm`` and fired as ONE flattened exchange; streams that
    finish early (narrower rings -> fewer levels) simply drop out.  Returns
    each stream's result, in order.

    ``on_round(r)``, if given, fires after fused round ``r`` completes —
    i.e. at the round barrier, once every live stream has absorbed the
    exchange.  This is the snapshot/watchdog seam: a
    ``JournaledComm.snapshot`` here makes the execution resumable from
    round ``r``, and the serving engine hangs straggler detection off it.
    """
    cc = (comm if isinstance(comm, comm_lib.CoalescingComm)
          else comm_lib.CoalescingComm(comm))
    results: List = [None] * len(streams)
    live = {}
    for i, s in enumerate(streams):
        try:
            live[i] = (s, s.send(None))
        except StopIteration as e:  # zero-round stream
            results[i] = e.value
    r = 0
    while live:
        handles = {i: cc.enqueue(payload) for i, (_, payload) in live.items()}
        opened = cc.flush()
        nxt = {}
        for i, (s, _) in live.items():
            try:
                nxt[i] = (s, s.send(opened[handles[i]]))
            except StopIteration as e:
                results[i] = e.value
        live = nxt
        if on_round is not None:
            on_round(r)
        r += 1
    return results


def _sel_mask(comm, template: jax.Array) -> jax.Array:
    """All-ones on party 0, zeros on party 1 (Beaver open correction)."""
    return jnp.where(comm.party_is(0, template),
                     jnp.uint32(0xFFFFFFFF), jnp.uint32(0))


# ---------------------------------------------------------------------------
# Secure AND on packed binary shares (one communication round)
# ---------------------------------------------------------------------------

def _and_open_rounds(x, y, triple: beaver.BinTriple, comm):
    """Round generator for z = x & y on XOR-shared packed words."""
    from repro.kernels import ops as kops  # lazy: kernels import core.ring

    d = x ^ triple.a
    e = y ^ triple.b
    opened = yield jnp.stack([d, e], axis=1)  # single exchange
    d_open = d ^ opened[:, 0]
    e_open = e ^ opened[:, 1]
    sel = _sel_mask(comm, x)
    # local evaluation fused in one VMEM pass (kernels/gmw_round.py)
    return kops.beaver_and(d_open, e_open, triple.a, triple.b, triple.c, sel)


def and_open(x, y, triple: beaver.BinTriple, comm) -> jax.Array:
    """z = x & y on XOR-shared packed words. One swap (round) of (d, e)."""
    return drive(_and_open_rounds(x, y, triple, comm), comm)


# ---------------------------------------------------------------------------
# Kogge-Stone adder over packed bitplanes -> MSB (sign) of x + y mod 2^w
# ---------------------------------------------------------------------------

def _adder_msb_rounds(xw, yw, triples: beaver.ReluTriples, comm, w: int,
                      cone: bool):
    """Round generator for the MSB of (x + y mod 2^w).

    Dense path: one fused pre-exchange pass (plane-shift + (d, e) masking)
    and one fused post-exchange pass (open + Beaver eval + g/p combine) per
    level.  Cone path: compile-time-static layout — positions live in
    trace-time dicts of per-plane (P, W) tensors, so pruned levels are pure
    static stack/slice, never a runtime scatter.
    """
    from repro.kernels import ops as kops

    p0 = xw ^ yw                      # initial propagate (local)
    if w == 1:
        return p0[..., 0, :]
    L = beaver.n_levels(w)
    if not cone:
        g = yield from _and_open_rounds(xw, yw, triples.bin_init, comm)
        p = p0
        sel = _sel_mask(comm, xw)
        for lvl in range(L):
            d = 1 << lvl
            tri = jax.tree_util.tree_map(lambda t: t[lvl], triples.bin_levels)
            # fused: shift + lhs/rhs build + triple masking, one pass
            d_half, e_half = kops.ks_mask(g, p, tri.a, tri.b, d)
            opened = yield jnp.stack([d_half, e_half], axis=1)  # one round
            # fused: opening XOR + Beaver eval + level combine, one pass
            g, p = kops.ks_combine(d_half, opened[:, 0], e_half, opened[:, 1],
                                   tri.a, tri.b, tri.c, sel, g)
        # carry into bit (w-1) is prefix-generate of bit (w-2)
        return p0[..., w - 1, :] ^ g[..., w - 2, :]

    init_pos, level_sets = cone_sets(w)
    # static cone layout: dense sub-plane tensors per level, positions are
    # Python-side metadata (g_map/p_map) resolved entirely at trace time
    g_sub = yield from _and_open_rounds(
        jnp.stack([xw[..., i, :] for i in init_pos], axis=-2),
        jnp.stack([yw[..., i, :] for i in init_pos], axis=-2),
        triples.bin_init, comm)
    g_map = {i: g_sub[..., j, :] for j, i in enumerate(init_pos)}
    p_map = {i: p0[..., i, :] for i in range(w)}
    for lvl in range(L):
        d = 1 << lvl
        pos = level_sets[lvl]
        if not pos:
            continue
        n = len(pos)
        lhs = jnp.stack([p_map[i] for i in pos] * 2, axis=-2)
        rhs = jnp.stack([g_map[i - d] for i in pos] +
                        [p_map[i - d] for i in pos], axis=-2)
        out = yield from _and_open_rounds(lhs, rhs, triples.bin_levels[lvl],
                                          comm)                # one round
        for j, i in enumerate(pos):
            g_map[i] = g_map[i] ^ out[..., j, :]
            p_map[i] = out[..., n + j, :]
    return p0[..., w - 1, :] ^ g_map[w - 2]


def _shift_planes_dyn(x: jax.Array, d) -> jax.Array:
    """Plane shift by a *traced* distance: plane i of the result is plane
    (i - d) of the input, zeros below.  Bit-identical to the static
    ``kernels.ref._shift_planes`` for every d in [0, w]."""
    w = x.shape[-2]
    rolled = jnp.roll(x, d, axis=-2)
    keep = jnp.arange(w, dtype=jnp.int32)[:, None] >= d
    return jnp.where(keep, rolled, jnp.uint32(0))


def _adder_msb_scan(xw, yw, triples: beaver.ReluTriples, comm, w: int):
    """Dense Kogge-Stone MSB extraction with the level loop as ONE
    ``lax.scan`` instead of L unrolled rounds.

    The carry is the (g, p) plane pair — two (P, w, W) uint32 buffers that
    XLA double-buffers (donates) across trips — and the scanned xs are the
    per-level shift distances plus ``triples.bin_levels`` (whose leaves
    already carry the stacked leading L axis).  The exchange stays on the
    ``Comm`` seam *inside* the body: one ``comm.swap`` of the stacked
    (d, e) halves per trip, exactly like the generator path, so wire
    layout and bytes are unchanged.  Level compute reuses the
    ``kernels.ref`` math with the only twist that the plane shift distance
    is traced (``_shift_planes_dyn``) rather than static.

    A scan body fires Python-side comm bookkeeping only once (at trace
    time); ``CoalescingComm.note_rounds`` accounts the remaining L-1
    uniform rounds so measured counters still equal ``schedule.simulate``.
    """
    from repro.kernels import ref as kref

    p0 = xw ^ yw
    if w == 1:
        return p0[..., 0, :]
    L = beaver.n_levels(w)
    g = and_open(xw, yw, triples.bin_init, comm)
    sel = _sel_mask(comm, xw)
    shifts = jnp.left_shift(jnp.int32(1), jnp.arange(L, dtype=jnp.int32))

    def level(carry, xs):
        g, p = carry
        d_lvl, tri = xs
        lhs = jnp.concatenate([p, p], axis=-2)
        rhs = jnp.concatenate([_shift_planes_dyn(g, d_lvl),
                               _shift_planes_dyn(p, d_lvl)], axis=-2)
        d_half = lhs ^ tri.a
        e_half = rhs ^ tri.b
        opened = comm.swap(jnp.stack([d_half, e_half], axis=1))  # one round
        g2, p2 = kref.ks_combine(d_half, opened[:, 0], e_half, opened[:, 1],
                                 tri.a, tri.b, tri.c, sel, g)
        return (g2, p2), None

    (g, _p), _ = jax.lax.scan(level, (g, p0), (shifts, triples.bin_levels))
    note = getattr(comm, "note_rounds", None)
    if note is not None:
        note(L - 1)
    return p0[..., w - 1, :] ^ g[..., w - 2, :]


def adder_msb(xw: jax.Array, yw: jax.Array, triples: beaver.ReluTriples,
              comm, w: int, cone: bool = False) -> jax.Array:
    """XOR shares of the MSB of (x + y mod 2^w).

    xw, yw: (P, w, W) packed plane shares of the two addends.
    Returns (P, W) packed shares of the sign plane.

    cone=True prunes every AND outside the backward cone of G[w-2]
    (same round count, ~log(w)/2 x fewer gate-bits on the wire — a
    beyond-paper optimization, see EXPERIMENTS.md §Perf iteration C2).
    """
    return drive(_adder_msb_rounds(xw, yw, triples, comm, w, cone), comm)


# ---------------------------------------------------------------------------
# A2B prep: XOR-share each party's (reduced-ring) arithmetic share
# ---------------------------------------------------------------------------

def _a2b_prepare_rounds(key, v_packed: jax.Array, comm):
    # party-dependent randomness: every party derives the FULL (n_parties,
    # ...) mask array from the shared key and keeps only its own rows via
    # ``comm.party_slice`` — identity on the sim backend (local party dim
    # is already all parties), the local shard on the mesh backend.  The
    # masks are therefore bit-identical across backends by construction.
    full = jax.random.bits(key, (comm.n_parties,) + v_packed.shape[1:],
                           dtype=_U32)
    r = comm.party_slice(full)
    masked = v_packed ^ r
    other_mask = yield r
    p0 = comm.party_is(0, v_packed)
    x0_shares = jnp.where(p0, masked, other_mask)   # shares of party0's value
    x1_shares = jnp.where(p0, other_mask, masked)   # shares of party1's value
    return x0_shares, x1_shares


def a2b_prepare(key, v_packed: jax.Array, comm) -> Tuple[jax.Array, jax.Array]:
    """From each party's packed plaintext planes (P, w, W) of its own
    arithmetic share, produce XOR shares of party0's and party1's values
    held by both parties.  One round (mask exchange)."""
    return drive(_a2b_prepare_rounds(key, v_packed, comm), comm)


# ---------------------------------------------------------------------------
# Beaver multiplication on Z/2^64 (one round)
# ---------------------------------------------------------------------------

def _beaver_mul_rounds(x: ring.Ring64, y: ring.Ring64,
                       triple: beaver.ArithTriple, comm):
    e = ring.sub(x, triple.a)
    f = ring.sub(y, triple.b)
    ef = ring.Ring64(jnp.stack([e.lo, f.lo], 1), jnp.stack([e.hi, f.hi], 1))
    other = yield ef                                 # single exchange
    e_open = ring.add(e, ring.Ring64(other.lo[:, 0], other.hi[:, 0]))
    f_open = ring.add(f, ring.Ring64(other.lo[:, 1], other.hi[:, 1]))
    z = ring.add(triple.c,
                 ring.add(ring.mul(e_open, triple.b), ring.mul(f_open, triple.a)))
    p0 = comm.party_is(0, z.lo)
    corr = ring.mul(e_open, f_open)
    return ring.Ring64(jnp.where(p0, ring.add(z, corr).lo, z.lo),
                       jnp.where(p0, ring.add(z, corr).hi, z.hi))


def beaver_mul(x: ring.Ring64, y: ring.Ring64, triple: beaver.ArithTriple,
               comm) -> ring.Ring64:
    return drive(_beaver_mul_rounds(x, y, triple, comm), comm)


def _beaver_matmul_rounds(x: ring.Ring64, y: ring.Ring64,
                          triple: beaver.ArithTriple, comm):
    """Round generator for Z = X @ Y on Ring64 additive shares.

    Beaver matmul (the transformer's secret-by-secret product): with a
    matrix triple (A, B, C = A @ B) of matching shapes, both parties open
    E = X - A and F = Y - B in ONE exchange (the flattened concatenation
    of both differences — (M*K + K*N) ring elements per batch cell, the
    open payload ``schedule.open_timeline`` prices) and combine locally
    with the mod-2^64 plane matmul:

        Z_p = C_p + E @ B_p + A_p @ F + [p == 0] E @ F
    """
    P = x.shape[0]
    e = ring.sub(x, triple.a)
    f = ring.sub(y, triple.b)
    ne = int(jnp.size(e.lo) // P)
    ef = ring.Ring64(
        jnp.concatenate([e.lo.reshape(P, -1), f.lo.reshape(P, -1)], axis=1),
        jnp.concatenate([e.hi.reshape(P, -1), f.hi.reshape(P, -1)], axis=1))
    other = yield ef                                 # single exchange
    e_open = ring.add(e, ring.Ring64(other.lo[:, :ne].reshape(e.lo.shape),
                                     other.hi[:, :ne].reshape(e.hi.shape)))
    f_open = ring.add(f, ring.Ring64(other.lo[:, ne:].reshape(f.lo.shape),
                                     other.hi[:, ne:].reshape(f.hi.shape)))
    z = ring.add(triple.c,
                 ring.add(ring_linalg.matmul_ring(e_open, triple.b),
                          ring_linalg.matmul_ring(triple.a, f_open)))
    p0 = comm.party_is(0, z.lo)
    corr = ring_linalg.matmul_ring(e_open, f_open)
    return ring.Ring64(jnp.where(p0, ring.add(z, corr).lo, z.lo),
                       jnp.where(p0, ring.add(z, corr).hi, z.hi))


def beaver_matmul(x: ring.Ring64, y: ring.Ring64, triple: beaver.ArithTriple,
                  comm) -> ring.Ring64:
    """Z = X @ Y on additive shares; one communication round."""
    return drive(_beaver_matmul_rounds(x, y, triple, comm), comm)


def products_many(specs: Sequence[Tuple[str, ring.Ring64, ring.Ring64,
                                        beaver.ArithTriple]],
                  comm) -> List[ring.Ring64]:
    """Round-shared Beaver products over sibling streams.

    ``specs`` is one ``(kind, x, y, triple)`` per stream with ``kind`` in
    {"mul", "matmul"}; every stream's single opening is coalesced into ONE
    exchange (``comm.CoalescingComm``), so N concurrent secret products —
    across streams and across kinds — cost exactly one fused round.  The
    open payload per stream is 2n ring elements for "mul" and
    ``size(x) + size(y)`` for "matmul" (what ``schedule.open_timeline``
    prices).  Returns per-stream Ring64 results in order.
    """
    gens = []
    for kind, x, y, tri in specs:
        if kind == "mul":
            gens.append(_beaver_mul_rounds(x, y, tri, comm))
        elif kind == "matmul":
            gens.append(_beaver_matmul_rounds(x, y, tri, comm))
        else:
            raise ValueError(f"products_many: unknown kind {kind!r}")
    return run_streams(comm, gens)


# ---------------------------------------------------------------------------
# B2A of a single packed bit plane -> arithmetic shares of the bit
# ---------------------------------------------------------------------------

def _b2a_bit_rounds(bits: jax.Array, triple: beaver.ArithTriple, comm):
    zeros = jnp.zeros_like(bits)
    p0 = comm.party_is(0, bits)
    x = ring.Ring64(jnp.where(p0, bits, zeros), zeros)
    y = ring.Ring64(jnp.where(p0, zeros, bits), zeros)
    xy = yield from _beaver_mul_rounds(x, y, triple, comm)
    s = ring.add(ring.Ring64(bits, zeros), ring.neg(ring.lshift(xy, 1)))
    # NB: x + y == (b0, b1) == Ring64(bits, 0) summed across parties
    return s


def b2a_bit(bits: jax.Array, triple: beaver.ArithTriple, comm) -> ring.Ring64:
    """bits: (P, E) XOR shares in {0,1}. Returns Ring64 additive shares.

    b = b0 xor b1 = b0 + b1 - 2*b0*b1; the cross term uses one Beaver mult
    with X = (b0, 0), Y = (0, b1) as trivially-valid arithmetic shares.
    """
    return drive(_b2a_bit_rounds(bits, triple, comm), comm)


# ---------------------------------------------------------------------------
# DReLU / ReLU (exact and reduced-ring)
# ---------------------------------------------------------------------------

def _drelu_rounds(key, x: ring.Ring64, triples: beaver.ReluTriples, comm,
                  k: int, m: int, cone: bool):
    w = k - m
    n = x.shape[-1]
    if w <= 32:
        v = ring.extract_bits(x, k, m)              # (P, E) uint32, local
        planes = ring.bitplanes_u32(v, w)           # (w, P, E)
    else:
        planes = ring.extract_planes(x, k, m)       # (w, P, E)
    planes = jnp.moveaxis(planes, 0, 1)             # (P, w, E)
    packed = shares.pack_bits(planes)               # (P, w, W)
    x0s, x1s = yield from _a2b_prepare_rounds(key, packed, comm)    # 1 round
    sign_packed = yield from _adder_msb_rounds(x0s, x1s, triples, comm, w,
                                               cone)
    sign_bits = shares.unpack_bits(sign_packed, n)  # (P, E)
    s = yield from _b2a_bit_rounds(sign_bits, triples.b2a, comm)    # 1 round
    one = ring.from_int32(jnp.ones((), jnp.int32))
    p0 = comm.party_is(0, s.lo)
    d = ring.Ring64(jnp.where(p0, ring.sub(one, s).lo, ring.neg(s).lo),
                    jnp.where(p0, ring.sub(one, s).hi, ring.neg(s).hi))
    return d


def drelu(key, x: ring.Ring64, triples: beaver.ReluTriples, comm,
          k: int = 64, m: int = 0, cone: bool = False) -> ring.Ring64:
    """Arithmetic shares of DReLU(x) evaluated on the reduced ring [k:m].

    k = 64, m = 0 reproduces the exact CrypTen baseline; k - m << 64 is
    HummingBird's approximation (Eq. 3).  x: Ring64 shares (P, E).
    """
    return drive(_drelu_rounds(key, x, triples, comm, k, m, cone), comm)


def relu_rounds(key, x: ring.Ring64, triples: beaver.ReluTriples, comm,
                k: int = 64, m: int = 0, cone: bool = False):
    """Round generator for one full ReLU — compose with ``run_streams`` to
    share rounds across concurrent ReLU groups."""
    d = yield from _drelu_rounds(key, x, triples, comm, k, m, cone)
    out = yield from _beaver_mul_rounds(x, d, triples.mult, comm)
    return out


def relu(key, x: ring.Ring64, triples: beaver.ReluTriples, comm,
         k: int = 64, m: int = 0, cone: bool = False) -> ring.Ring64:
    """ReLU(x) = x * DReLU(x[k:m])  (Eq. 3; Eq. 2 when k=64, m=0).

    The final multiplication always uses the full-ring share x, only the
    sign estimation is approximated - exactly the paper's formulation.
    """
    return drive(relu_rounds(key, x, triples, comm, k, m, cone), comm)


def relu_scan(key, x: ring.Ring64, triples: beaver.ReluTriples, comm,
              k: int = 64, m: int = 0, cone: bool = False) -> ring.Ring64:
    """One full ReLU with no Python round loop: the ``scan`` backend of
    the compiled round engine (``runtime/loop.py``).

    Same protocol, same wire layout, bit-identical shares to
    ``relu``/``relu_rounds``: prep, the initial AND, B2A and the final
    Beaver mult are single-round primitives (one ``comm.swap`` each), and
    the dense Kogge-Stone level segment — the only multi-round stretch —
    runs as a single ``lax.scan`` (``_adder_msb_scan``).  Under ``jax.jit``
    the whole call is therefore one XLA program whose round structure
    matches ``schedule.stream_timeline`` exactly.  The cone-pruned adder
    keeps its static per-level layout (ragged positions cannot scan) but
    still traces straight through jit as unrolled rounds.
    """
    w = k - m
    n = x.shape[-1]
    if w <= 32:
        v = ring.extract_bits(x, k, m)
        planes = ring.bitplanes_u32(v, w)
    else:
        planes = ring.extract_planes(x, k, m)
    planes = jnp.moveaxis(planes, 0, 1)
    packed = shares.pack_bits(planes)
    x0s, x1s = a2b_prepare(key, packed, comm)                       # 1 round
    if cone:
        sign_packed = adder_msb(x0s, x1s, triples, comm, w, cone=True)
    else:
        sign_packed = _adder_msb_scan(x0s, x1s, triples, comm, w)
    sign_bits = shares.unpack_bits(sign_packed, n)
    s = b2a_bit(sign_bits, triples.b2a, comm)                       # 1 round
    one = ring.from_int32(jnp.ones((), jnp.int32))
    p0 = comm.party_is(0, s.lo)
    d = ring.Ring64(jnp.where(p0, ring.sub(one, s).lo, ring.neg(s).lo),
                    jnp.where(p0, ring.sub(one, s).hi, ring.neg(s).hi))
    return beaver_mul(x, d, triples.mult, comm)                     # 1 round


def relu_many(keys, xs: Sequence[ring.Ring64],
              triples_list: Sequence[Optional[beaver.ReluTriples]], comm,
              kms: Sequence[Tuple[int, int]], cone: bool = False,
              auto_batch: bool = True, loop: str = "python") -> List[ring.Ring64]:
    """Round-shared evaluation of N concurrent ReLU groups.

    Each group may have its own element count and reduced ring (k, m);
    every protocol round across all groups is ONE coalesced exchange, so
    total rounds = max over groups (vs. the sum when evaluated serially)
    with unchanged total bytes.  Width-0 groups (k == m) are the culled
    identity and zero-element groups the empty batch: both cost nothing.

    With ``auto_batch`` (default), sibling groups of identical
    (n_elements, k, m) are merged into ONE stream on the element axis
    before coalescing — one payload and one fused kernel pass per round
    instead of N, with the combined element vector repacked so per-group
    packing padding disappears (bytes can only drop).  Their Beaver
    triples are merged bit-exactly (``beaver.concat_relu_triples``); the
    protocol randomness comes from the first member's key, so *revealed*
    outputs are unchanged (the protocol's internal masks never affect the
    reconstruction) while output share splits differ from per-group
    evaluation.  Ragged groups keep per-payload coalescing.  The timeline
    either way is exactly ``core.schedule.simulate``'s prediction.

    ``loop`` selects the round-loop backend (``runtime/loop.py``): with
    ``"scan"``, a layer that collapses to a single (possibly merged)
    stream runs through ``relu_scan`` — dense adder levels as one
    ``lax.scan`` — instead of the generator driver; heterogeneous sibling
    streams must advance in lockstep to share rounds, so they stay on the
    generator path (which still traces straight through ``jax.jit``).
    Both backends are share-level bit-identical.

    Returns per-group Ring64 results in order.
    """
    if not (len(keys) == len(xs) == len(triples_list) == len(kms)):
        raise ValueError(
            f"relu_many: mismatched lengths keys={len(keys)} xs={len(xs)} "
            f"triples={len(triples_list)} kms={len(kms)}")
    cc = (comm if isinstance(comm, comm_lib.CoalescingComm)
          else comm_lib.CoalescingComm(comm))
    results: List[Optional[ring.Ring64]] = [None] * len(xs)
    groups: dict = {}                     # batch key -> [(i, key, x, tri)]
    for i, (key, x, tr, (k, m)) in enumerate(
            zip(keys, xs, triples_list, kms)):
        n = x.shape[-1]
        if k == m or n == 0:             # culled identity / empty batch
            results[i] = x
            continue
        bkey = (n, k, m) if auto_batch else i
        groups.setdefault(bkey, []).append((i, key, x, tr, k, m))
    stream_args, placements = [], []
    for members in groups.values():
        i0, key0, x0, tr0, k, m = members[0]
        if len(members) == 1:
            stream_args.append((key0, x0, tr0, k, m))
            placements.append([(i0, 0, x0.shape[-1])])
            continue
        n = x0.shape[-1]
        xcat = ring.Ring64(
            jnp.concatenate([e[2].lo for e in members], axis=-1),
            jnp.concatenate([e[2].hi for e in members], axis=-1))
        tcat = beaver.concat_relu_triples([e[3] for e in members],
                                          [n] * len(members), k - m,
                                          cone=cone)
        stream_args.append((key0, xcat, tcat, k, m))
        placements.append([(e[0], j * n, n) for j, e in enumerate(members)])
    if loop == "scan" and len(stream_args) == 1:
        # solo (possibly merged) stream: nothing to coalesce across, so the
        # lockstep generator driver buys nothing — run the scan backend.
        key0, x0, tr0, k, m = stream_args[0]
        outs = [relu_scan(key0, x0, tr0, cc, k=k, m=m, cone=cone)]
    else:
        outs = run_streams(cc, [relu_rounds(key0, x0, tr0, cc, k=k, m=m,
                                            cone=cone)
                                for key0, x0, tr0, k, m in stream_args])
    for slices, out in zip(placements, outs):
        if len(slices) == 1:
            results[slices[0][0]] = out
        else:
            for i, off, n in slices:
                results[i] = out[..., off:off + n]
    return results


def n_rounds(w: int) -> int:
    """Communication rounds for one ReLU: prep + init-AND + levels + B2A +
    mult; 0 for a culled (width-0) identity layer.  Delegates to the
    round-schedule simulator (``core.schedule``)."""
    return schedule_lib.stream_rounds(w)
