"""Pallas kernel sweeps: shapes x dtypes, interpret mode vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ring
from repro.kernels import bitpack, gmw_round, ref, ring_matmul


@pytest.mark.parametrize("w", [1, 4, 6, 8, 13, 32])
@pytest.mark.parametrize("n_words", [32, 256])
def test_bitpack_sweep(w, n_words, rng):
    e = n_words * 32
    v = jnp.asarray(rng.integers(0, 2 ** min(w, 31), e, dtype=np.uint32))
    bw = min(bitpack.BLOCK_WORDS, n_words)
    packed = bitpack.pack_pallas(v, w, interpret=True, block_words=bw)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(ref.pack(v, w)))
    back = bitpack.unpack_pallas(packed, w, interpret=True, block_words=bw)
    np.testing.assert_array_equal(np.asarray(back),
                                  np.asarray(v) & ((1 << w) - 1 if w < 32 else 0xFFFFFFFF))


@pytest.mark.parametrize("planes,words", [(8, 256), (16, 512), (64, 256)])
def test_gmw_round_sweep(planes, words, rng):
    mk = lambda: jnp.asarray(
        rng.integers(0, 2**32, (planes, words), dtype=np.uint64).astype(np.uint32))
    d, e, a, b, c = mk(), mk(), mk(), mk(), mk()
    for sel_val in (0, 0xFFFFFFFF):
        sel = jnp.broadcast_to(jnp.uint32(sel_val), d.shape)
        got = gmw_round.beaver_and_pallas(d, e, a, b, c, sel, interpret=True)
        want = ref.beaver_and(d, e, a, b, c, sel)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("w,shift", [(8, 1), (8, 4), (64, 32), (6, 2)])
def test_ks_mask_fused_level(w, shift, rng):
    """Fused plane-shift + triple-masking kernel vs the jnp oracle."""
    words = 128
    mk = lambda planes: jnp.asarray(rng.integers(
        0, 2**32, (2, planes, words), dtype=np.uint64).astype(np.uint32))
    g, p = mk(w), mk(w)
    a, b = mk(2 * w), mk(2 * w)
    d_k, e_k = gmw_round.ks_mask_pallas(g, p, a, b, shift, interpret=True,
                                        block_words=words)
    d_r, e_r = ref.ks_mask(g, p, a, b, shift)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(e_k), np.asarray(e_r))


@pytest.mark.parametrize("w", [8, 64])
def test_ks_combine_fused_level(w, rng):
    """Fused open + Beaver eval + g/p combine kernel vs the jnp oracle."""
    words = 128
    mk = lambda planes: jnp.asarray(rng.integers(
        0, 2**32, (2, planes, words), dtype=np.uint64).astype(np.uint32))
    d, do, e, eo, a, b, c = (mk(2 * w) for _ in range(7))
    g = mk(w)
    sel = jnp.broadcast_to(jnp.uint32(0xFFFFFFFF), d.shape)
    g_k, p_k = gmw_round.ks_combine_pallas(d, do, e, eo, a, b, c, sel, g,
                                           interpret=True, block_words=words)
    g_r, p_r = ref.ks_combine(d, do, e, eo, a, b, c, sel, g)
    np.testing.assert_array_equal(np.asarray(g_k), np.asarray(g_r))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


def test_ks_level_fusion(rng):
    g = jnp.asarray(rng.integers(0, 2**32, (8, 256), dtype=np.uint64).astype(np.uint32))
    zg = g ^ jnp.uint32(123456)
    zp = g ^ jnp.uint32(777)
    g2, p2 = gmw_round.ks_level_pallas(g, zg, zp, interpret=True)
    rg, rp = ref.ks_level(g, zg, zp)
    np.testing.assert_array_equal(np.asarray(g2), np.asarray(rg))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(rp))


@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (16, 256, 128)])
def test_ring_matmul_kernel_vs_ref_vs_int_oracle(m, k, n, rng):
    x_np = rng.integers(0, 2**64, (m, k), dtype=np.uint64)
    w_np = rng.integers(-2**20, 2**20, (k, n)).astype(np.int32)
    x = ring.from_uint64_np(x_np)
    dx = ring.balanced_digits(x)
    dw = ring.balanced_digits_i32(jnp.asarray(w_np))
    lo_r, hi_r = ref.ring_matmul(dx, dw)
    # exact python-int oracle
    oracle = (x_np.astype(object) @ w_np.astype(object))
    got = (np.asarray(lo_r, np.uint64) | (np.asarray(hi_r, np.uint64) << np.uint64(32)))
    for g, o in zip(got.ravel(), oracle.ravel()):
        assert int(g) == int(o) % (1 << 64)
    lo_k, hi_k = ring_matmul.ring_matmul_pallas(dx, dw, block=(8, 128, 128),
                                                interpret=True)
    np.testing.assert_array_equal(np.asarray(lo_k), np.asarray(lo_r))
    np.testing.assert_array_equal(np.asarray(hi_k), np.asarray(hi_r))


def test_ring_matmul_multi_kblock(rng):
    """K spans multiple grid steps: accumulator carry across K blocks."""
    m, k, n = 8, 384, 128
    x_np = rng.integers(0, 2**64, (m, k), dtype=np.uint64)
    w_np = rng.integers(-2**15, 2**15, (k, n)).astype(np.int32)
    dx = ring.balanced_digits(ring.from_uint64_np(x_np))
    dw = ring.balanced_digits_i32(jnp.asarray(w_np))
    lo_k, hi_k = ring_matmul.ring_matmul_pallas(dx, dw, block=(8, 128, 128),
                                                interpret=True)
    lo_r, hi_r = ref.ring_matmul(dx, dw)
    np.testing.assert_array_equal(np.asarray(lo_k), np.asarray(lo_r))
    np.testing.assert_array_equal(np.asarray(hi_k), np.asarray(hi_r))


def test_ops_wrappers(rng):
    """Public ops: padding + dispatch paths."""
    from repro.kernels import ops
    v = jnp.asarray(rng.integers(0, 64, 1000, dtype=np.uint32))
    p = ops.pack(v, 6)
    assert p.shape == (6, (1000 + 31) // 32)
    back = ops.unpack(p, 6, 1000)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(v))
    x = ring.from_uint64_np(rng.integers(0, 2**64, (4, 40), dtype=np.uint64))
    w = jnp.asarray(rng.integers(-1000, 1000, (40, 12)).astype(np.int32))
    out = ops.ring_matmul(x, w)
    lo_r, hi_r = ref.ring_matmul(ring.balanced_digits(x),
                                 ring.balanced_digits_i32(w))
    np.testing.assert_array_equal(np.asarray(out.lo), np.asarray(lo_r))
