"""§4.1.2 search engine: HummingBird-eco and HummingBird-b.

HummingBird-eco: keep m = 0 and pick, per ReLU group, the smallest k with
zero sign-estimation error on the validation set (Theorem 1: k such that
-2^(k-1) <= x_int < 2^(k-1); searched in O(N) per group by validating
decreasing k until the outputs change).

HummingBird-b: DFS over per-group bit assignments with
  - locally-optimal (k, m): previous groups fixed to their found values,
    later groups optimistic (no bits dropped), enumerate the (k, m) pairs
    with k - m = assigned bits and keep the best validation accuracy;
  - Early stop 1: optimistic accuracy below the absolute threshold;
  - Early stop 2: optimistic accuracy below the best complete config;
  - Early stop 3: budget exceeded (bits weighted by group element counts).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.api.plan import Plan
from repro.core.hummingbird import HBConfig, HBLayer, RING_BITS, safe_k
from . import simulator


@dataclasses.dataclass
class SearchResult:
    config: HBConfig
    accuracy: float
    baseline_accuracy: float
    budget_fraction: float
    search_time_s: float
    nodes_visited: int
    nodes_pruned: int
    plan: Optional[Plan] = None   # set when the search was given a Plan

    def to_json(self) -> Dict:
        return {"config": self.config.to_json(),
                "accuracy": self.accuracy,
                "baseline_accuracy": self.baseline_accuracy,
                "budget_fraction": self.budget_fraction,
                "search_time_s": self.search_time_s,
                "nodes_visited": self.nodes_visited,
                "nodes_pruned": self.nodes_pruned,
                "plan": self.plan.to_json() if self.plan is not None else None}

    @staticmethod
    def from_json(d: Dict) -> "SearchResult":
        return SearchResult(
            config=HBConfig.from_json(d["config"]),
            accuracy=float(d["accuracy"]),
            baseline_accuracy=float(d["baseline_accuracy"]),
            budget_fraction=float(d["budget_fraction"]),
            search_time_s=float(d["search_time_s"]),
            nodes_visited=int(d["nodes_visited"]),
            nodes_pruned=int(d["nodes_pruned"]),
            plan=(Plan.from_json(d["plan"])
                  if d.get("plan") is not None else None))


def _eval(apply_fn, params, xs, ys, cfg, key):
    return simulator.evaluate_accuracy(apply_fn, params, xs, ys, cfg, key)


def _groups_and_plan(group_elements: Union[Plan, Sequence[int]]):
    """Search entry points accept either raw per-group element counts or a
    ``repro.api.Plan`` (whose found config is attached to the result)."""
    if isinstance(group_elements, Plan):
        return list(group_elements.group_elements), group_elements
    return list(group_elements), None


def _result(cfg: HBConfig, plan: Optional[Plan], **kw) -> SearchResult:
    return SearchResult(config=cfg, budget_fraction=cfg.budget_fraction(),
                        plan=plan.with_hb(cfg) if plan is not None else None,
                        **kw)


def search_eco(apply_fn, params, xs, ys,
               group_elements: Union[Plan, Sequence[int]],
               key, margin_bits: int = 1) -> SearchResult:
    """Zero-error config: per-group smallest k whose validation *outputs*
    are bit-identical to the exact model (the paper's eco criterion), m=0.

    ``group_elements`` may be a ``repro.api.Plan`` (traced offline); the
    result then carries ``plan.with_hb(found_config)`` ready to save."""
    t0 = time.time()
    group_elements, plan = _groups_and_plan(group_elements)
    n_groups = len(group_elements)
    base_cfg = HBConfig.exact(group_elements)
    base_acc = _eval(apply_fn, params, xs, ys, base_cfg, key)
    ref_logits = apply_fn(params, xs, relu_fn=None)
    max_ints = simulator.max_activation_ints(apply_fn, params, xs, n_groups)

    def outputs_intact(cfg: HBConfig) -> bool:
        relu_fn = simulator.make_group_relu(cfg, key)
        logits = apply_fn(params, xs, relu_fn=relu_fn)
        return bool(jnp.array_equal(logits, ref_logits))

    layers = []
    nodes = 0
    for g in range(n_groups):
        k = safe_k(max_ints[g], m=0, margin_bits=margin_bits)
        # validate downward: shrink while the validation outputs are intact
        while k > 2:
            cand = list(layers) + [HBLayer(k=k - 1, m=0)] + \
                [HBLayer() for _ in range(n_groups - g - 1)]
            cfg = HBConfig(tuple(cand), tuple(group_elements))
            nodes += 1
            if not outputs_intact(cfg):
                break
            k -= 1
        layers.append(HBLayer(k=k, m=0))
    cfg = HBConfig(tuple(layers), tuple(group_elements))
    acc = _eval(apply_fn, params, xs, ys, cfg, key)
    return _result(cfg, plan, accuracy=acc, baseline_accuracy=base_acc,
                   search_time_s=time.time() - t0, nodes_visited=nodes,
                   nodes_pruned=0)


def search_budget(apply_fn, params, xs, ys,
                  group_elements: Union[Plan, Sequence[int]],
                  key, budget: float, *, acc_threshold_drop: float = 0.10,
                  bit_choices: Optional[Sequence[int]] = None,
                  max_k: int = 28) -> SearchResult:
    """HummingBird-b: budgeted DFS with locally-optimal (k, m).

    ``bit_choices`` may include 0: the group's ReLU is then *culled*
    entirely (width-0 identity layer, zero rounds/bytes at serve time —
    the `relu_many`-friendly choice the round-fused engine exploits).
    ``group_elements`` may be a ``repro.api.Plan``; the result then
    carries ``plan.with_hb(found_config)``.
    """
    t0 = time.time()
    group_elements, plan = _groups_and_plan(group_elements)
    n_groups = len(group_elements)
    elements = np.asarray(group_elements, np.float64)
    total_bits = RING_BITS * elements.sum()
    base_cfg = HBConfig.exact(group_elements)
    base_acc = _eval(apply_fn, params, xs, ys, base_cfg, key)
    threshold = base_acc - acc_threshold_drop
    bit_choices = sorted(bit_choices or (0, 4, 5, 6, 8, 10), reverse=True)

    best: dict = {"acc": -1.0, "layers": None}
    stats = {"visited": 0, "pruned": 0}

    def local_best(prefix: List[HBLayer], g: int, width: int):
        """Locally-optimal (k, m) with k - m = width for group g."""
        if width == 0:
            # culling: every k = m is the same identity layer
            cand = prefix + [HBLayer(k=0, m=0)] + \
                [HBLayer() for _ in range(n_groups - g - 1)]
            stats["visited"] += 1
            return HBLayer(k=0, m=0), _eval(
                apply_fn, params, xs, ys,
                HBConfig(tuple(cand), tuple(group_elements)), key)
        best_local = (None, -1.0)
        for k in range(width, max_k + 1):
            m = k - width
            cand = prefix + [HBLayer(k=k, m=m)] + \
                [HBLayer() for _ in range(n_groups - g - 1)]
            cfg = HBConfig(tuple(cand), tuple(group_elements))
            stats["visited"] += 1
            acc = _eval(apply_fn, params, xs, ys, cfg, key)
            if acc > best_local[1]:
                best_local = (HBLayer(k=k, m=m), acc)
        return best_local

    def dfs(prefix: List[HBLayer], g: int, bits_used: float):
        if g == n_groups:
            cfg = HBConfig(tuple(prefix), tuple(group_elements))
            acc = _eval(apply_fn, params, xs, ys, cfg, key)
            if acc > best["acc"]:
                best["acc"] = acc
                best["layers"] = tuple(prefix)
            return
        for width in bit_choices:
            new_bits = bits_used + width * elements[g]
            # Early stop 3: even zero bits for the rest exceeds the budget
            if new_bits > budget * total_bits:
                stats["pruned"] += 1
                continue
            layer, opt_acc = local_best(prefix, g, width)
            if opt_acc < threshold:            # Early stop 1
                stats["pruned"] += 1
                continue
            if opt_acc <= best["acc"]:         # Early stop 2
                stats["pruned"] += 1
                continue
            dfs(prefix + [layer], g + 1, new_bits)

    dfs([], 0, 0.0)
    if best["layers"] is None:
        # Nothing met the budget+threshold; fall back to the uniform
        # smallest non-zero width, placing each group's window at the
        # largest k with zero sign-estimation error (Theorem 1 via safe_k)
        # clamped to the searched k-range — never beyond max_k.  With only
        # width 0 on offer, the fallback is the all-culled identity config.
        width = min(min((w for w in bit_choices if w > 0), default=0),
                    max_k)
        if width == 0:
            best["layers"] = tuple(HBLayer(k=0, m=0)
                                   for _ in range(n_groups))
        else:
            max_ints = simulator.max_activation_ints(apply_fn, params, xs,
                                                     n_groups)
            layers = []
            for g in range(n_groups):
                k = width
                for _ in range(4):   # safe_k's headroom term depends on m
                    k_next = max(width, min(max_k,
                                            safe_k(max_ints[g],
                                                   m=k - width)))
                    if k_next == k:
                        break
                    k = k_next
                layers.append(HBLayer(k=k, m=k - width))
            best["layers"] = tuple(layers)
        best["acc"] = _eval(apply_fn, params, xs, ys,
                            HBConfig(best["layers"], tuple(group_elements)),
                            key)
    cfg = HBConfig(best["layers"], tuple(group_elements))
    return _result(cfg, plan, accuracy=best["acc"], baseline_accuracy=base_acc,
                   search_time_s=time.time() - t0,
                   nodes_visited=stats["visited"],
                   nodes_pruned=stats["pruned"])
