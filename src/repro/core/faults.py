"""Deterministic fault injection + round journaling: chaos as an input.

``FaultInjectingComm`` is the chaos counterpart of ``CountingComm``: a
transparent wrapper over any eager base backend that realizes a seeded,
round-addressable ``FaultPlan`` — transient drops, stalls, payload
bit-corruption, and party crashes — exactly where the plan says, and
nowhere else.  Because both the protocol and the plan are deterministic,
every chaos run is reproducible bit for bit, which is what lets the test
suite and ``benchmarks/run.py --chaos`` assert that recovered executions
equal fault-free ones exactly.

Round addressing: ``FaultEvent.round`` indexes the comm's *clean-swap*
counter — the cursor advances only when a round delivers uncorrupted and
unfaulted, so a retried round consumes its one-shot event on the faulted
attempt and the re-send passes.  In a serving run this counter is the
global fused-round timeline (the same one ``core.schedule`` predicts),
not a per-batch index.  A ``crash`` is persistent: every subsequent swap
raises ``errors.PartyCrashed`` until ``restart()`` is called (the serving
engine's ``on_party_crash`` hook, or the resume path below).

``RoundJournal``/``JournaledComm`` implement round-level resume.  The
journal records each completed round's opened wire payload; after a
crash, a restarted party re-runs the SAME deterministic round generators
with the journal mounted — recorded rounds replay from the journal
without touching the wire, live execution resumes at the first
unjournaled round, and the final shares are bit-identical to an
uninterrupted run (the bit-exactness contract extended to interrupted
executions).  Journals persist through ``checkpoint/store.py``'s
torn-write-safe idiom (tmp dir + COMMITTED sentinel + atomic rename), so
a crash *during* a snapshot can never leave a half-written journal.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import errors
from repro.checkpoint import store

from .comm import SimComm

KINDS = ("drop", "stall", "corrupt", "crash")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``round`` indexes the clean-swap counter of
    the ``FaultInjectingComm`` realizing it (see module docstring)."""

    round: int
    kind: str                   # one of KINDS
    delay_s: float = 0.0        # stall only: sleep before timing out
    word: int = 0               # corrupt only: flat word index (mod size)
    bit: int = 0                # corrupt only: which bit to flip

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic chaos schedule: a tuple of one-shot events (crash
    excepted — it persists until ``restart()``)."""

    events: Tuple[FaultEvent, ...] = ()

    @classmethod
    def seeded(cls, seed: int, n_rounds: int, *, drops: int = 1,
               corrupts: int = 1, stalls: int = 0, stall_s: float = 0.0,
               crash_round: Optional[int] = None) -> "FaultPlan":
        """A reproducible plan: ``drops + corrupts + stalls`` transient
        events on distinct rounds drawn without replacement from
        ``range(n_rounds)``, plus an optional persistent crash."""
        rng = np.random.default_rng(seed)
        kinds = (["drop"] * drops + ["corrupt"] * corrupts
                 + ["stall"] * stalls)
        rng.shuffle(kinds)
        n = min(len(kinds), max(n_rounds, 0))
        rounds = (sorted(int(r) for r in
                         rng.choice(n_rounds, size=n, replace=False))
                  if n else [])
        events = [
            FaultEvent(round=r, kind=kind,
                       delay_s=stall_s if kind == "stall" else 0.0,
                       word=int(rng.integers(0, 2**31)),
                       bit=int(rng.integers(0, 32)))
            for r, kind in zip(rounds, kinds)]
        if crash_round is not None:
            events.append(FaultEvent(round=int(crash_round), kind="crash"))
        return cls(tuple(sorted(events, key=lambda e: e.round)))

    def events_at(self, r: int) -> Tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.round == r)

    def count(self, kind: str) -> int:
        return sum(1 for e in self.events if e.kind == kind)

    @property
    def n_transient(self) -> int:
        """Events a ``ResilientComm`` retry absorbs (everything but crash)."""
        return sum(1 for e in self.events if e.kind != "crash")


def _flip_bit(opened: Any, ev: FaultEvent) -> Any:
    """The delivered payload with one bit flipped in its first leaf —
    in-flight corruption, deterministic position."""
    leaves, treedef = jax.tree_util.tree_flatten(opened)
    host = [np.asarray(leaf) for leaf in leaves]
    flat = host[0].copy().reshape(-1)
    i = ev.word % flat.size
    flat[i] ^= flat.dtype.type(1) << flat.dtype.type(ev.bit % 32)
    host[0] = flat.reshape(host[0].shape)
    return treedef.unflatten([jnp.asarray(h) for h in host])


class FaultInjectingComm:
    """Realizes a ``FaultPlan`` over any eager base backend.

    drop/stall  -> raise ``errors.CommTimeout`` (stall sleeps first, so a
                   ``ResilientComm`` backoff schedule is actually paced)
    corrupt     -> deliver the exchange with one bit flipped
    crash       -> raise ``errors.PartyCrashed`` on this and EVERY later
                   swap until ``restart()``

    The clean-round cursor (``self.round``) advances only on unfaulted
    delivery, so one-shot events are consumed by the faulted attempt and
    the idempotent re-send goes through.  ``injected`` counts events by
    kind as they are realized — the chaos gate asserts these against the
    recovery counters upstream.
    """

    def __init__(self, plan: FaultPlan, base=None):
        self.base = base if base is not None else SimComm()
        self.plan = plan
        self.n_parties = self.base.n_parties
        self.round = 0
        self.restarts = 0
        self.injected: Dict[str, int] = {k: 0 for k in KINDS}
        self._crashed: Optional[int] = None
        self._consumed: set = set()

    def restart(self) -> None:
        """Revive a crashed party (models process restart).  Consumed
        events stay consumed; the round cursor keeps its position on the
        global timeline."""
        self._crashed = None
        self.restarts += 1

    def swap(self, x):
        if self._crashed is not None:
            raise errors.PartyCrashed(
                f"party down since round {self._crashed}; restart() first")
        corrupt: Optional[FaultEvent] = None
        for idx, ev in enumerate(self.plan.events):
            if ev.round != self.round or idx in self._consumed:
                continue
            self._consumed.add(idx)
            self.injected[ev.kind] += 1
            if ev.kind == "crash":
                self._crashed = self.round
                raise errors.PartyCrashed(
                    f"injected crash at round {self.round}")
            if ev.kind == "stall":
                if ev.delay_s > 0:
                    time.sleep(ev.delay_s)
                raise errors.CommTimeout(
                    f"injected stall at round {self.round}")
            if ev.kind == "drop":
                raise errors.CommTimeout(
                    f"injected drop at round {self.round}")
            corrupt = ev                     # deliver, then damage it
            break
        opened = self.base.swap(x)
        if corrupt is not None:
            return _flip_bit(opened, corrupt)    # cursor does NOT advance
        self.round += 1
        return opened

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        return self.base.party_is(p, template)

    def party_slice(self, full: jax.Array) -> jax.Array:
        return self.base.party_slice(full)


# ---------------------------------------------------------------------------
# Round-level resume: journal + replaying comm
# ---------------------------------------------------------------------------

class RoundJournal:
    """Opened wire payloads of completed rounds, in order (host arrays).

    Persistence rides the checkpoint store's atomic-commit idiom: a
    snapshot either lands whole (COMMITTED sentinel present) or not at
    all, so resuming from a torn snapshot is impossible.
    """

    def __init__(self):
        self.rounds: List[List[np.ndarray]] = []

    def __len__(self) -> int:
        return len(self.rounds)

    def record(self, leaves) -> None:
        self.rounds.append([np.asarray(leaf) for leaf in leaves])

    def truncate(self, n_rounds: int) -> None:
        """Drop every round past ``n_rounds`` — the resume negotiation:
        after an abrupt kill the two parties' journals may differ by the
        in-flight round, so both truncate to ``min(len_a, len_b)``
        (exchanged in the transport handshake) and resume live execution
        from the same round barrier."""
        del self.rounds[int(n_rounds):]

    def save(self, ckpt_dir: str) -> None:
        flat = [a for rnd in self.rounds for a in rnd]
        store.save(ckpt_dir, step=len(self.rounds), tree=flat,
                   extra={"round_lens": [len(r) for r in self.rounds]})

    @classmethod
    def load(cls, ckpt_dir: str) -> "RoundJournal":
        manifest = store.load_manifest(ckpt_dir)
        lens = manifest["extra"]["round_lens"]
        template = [np.zeros(1, np.uint32)] * sum(lens)
        flat, _ = store.restore(ckpt_dir, template)
        j = cls()
        it = iter(flat)
        for n in lens:
            j.rounds.append([np.asarray(next(it)) for _ in range(n)])
        return j


class JournaledComm:
    """Replay-through-journal transport wrapper.

    Rounds already in the mounted journal are served from the record
    without touching the wire (``replayed`` counts them); live rounds go
    to ``base`` and are recorded on success.  Mount it ABOVE
    ``ResilientComm`` so only verified payloads are journaled, and BELOW
    ``CoalescingComm`` so one journal entry is one fused round.

    With ``snapshot_dir``, the journal is persisted (atomically) every
    ``snapshot_every`` live rounds — the continuous-checkpoint mode a
    deployed party host runs in, so a kill at ANY round loses at most
    ``snapshot_every - 1`` rounds of journal (``launch/party_host.py``).
    """

    def __init__(self, base=None, journal: Optional[RoundJournal] = None,
                 *, snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 1):
        self.base = base if base is not None else SimComm()
        self.journal = journal if journal is not None else RoundJournal()
        self.n_parties = self.base.n_parties
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = max(1, int(snapshot_every))
        self.cursor = 0
        self.replayed = 0

    def swap(self, x):
        leaves, treedef = jax.tree_util.tree_flatten(x)
        if self.cursor < len(self.journal):
            rec = self.journal.rounds[self.cursor]
            if len(rec) != len(leaves):
                raise errors.PayloadCorrupted(
                    f"journal round {self.cursor} holds {len(rec)} leaves "
                    f"but the payload has {len(leaves)}: journal does not "
                    f"match this execution")
            self.cursor += 1
            self.replayed += 1
            return treedef.unflatten([jnp.asarray(a) for a in rec])
        opened = self.base.swap(x)
        self.journal.record(jax.tree_util.tree_flatten(opened)[0])
        self.cursor += 1
        if (self.snapshot_dir is not None
                and self.cursor % self.snapshot_every == 0):
            self.snapshot(self.snapshot_dir)
        return opened

    def snapshot(self, ckpt_dir: str) -> None:
        """Persist the journal at the current round barrier (atomic)."""
        self.journal.save(ckpt_dir)

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        return self.base.party_is(p, template)

    def party_slice(self, full: jax.Array) -> jax.Array:
        return self.base.party_slice(full)
