"""Non-interpret Pallas parity + the BLOCK_WORDS sweep hook (PR 9).

The fused GMW round kernels must be bit-identical to the ``kernels/ref``
jnp oracle under the *compiled* (``interpret=False``) Pallas lowering and
at every legal ``block_words`` tile.  On backends without a compiled
Pallas lowering (CPU today: "Only interpret mode is supported on CPU
backend") the non-interpret cases attempt the call and skip-mark — on a
TPU runner they execute for real with no code change.  The ops-layer
tests pin the env knobs (``HB_BLOCK_WORDS`` / ``HB_PALLAS_INTERPRET``)
that turn the sweep into pure configuration, including that flipping a
knob mid-process retraces instead of reusing a stale jit cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import gmw_round, ops, ref

#: the v5e/v6e tuning sweep: word-dim tiles, all multiples of the 128
#: TPU lane count (256 is the shipped default)
BLOCK_WORDS_SWEEP = [128, 256, 512]


@pytest.fixture(params=BLOCK_WORDS_SWEEP)
def block_words(request):
    return request.param


def _attempt_noninterpret(fn, *args, **kw):
    """Run a kernel with ``interpret=False``; skip-mark where the backend
    has no compiled Pallas lowering (exact behaviour the ISSUE asks for:
    attempt, don't guess from the platform string)."""
    try:
        return fn(*args, interpret=False, **kw)
    except Exception as e:  # jaxlib raises backend-specific error types
        msg = str(e)
        if "interpret mode" in msg or "Only interpret" in msg.lower():
            pytest.skip(f"no compiled Pallas lowering on "
                        f"{jax.default_backend()}: {msg.splitlines()[0]}")
        raise


def _mk(rng, shape):
    return jnp.asarray(
        rng.integers(0, 2**32, shape, dtype=np.uint64).astype(np.uint32))


# ---------------------------------------------------------------------------
# Direct kernel parity, interpret=False
# ---------------------------------------------------------------------------

def test_beaver_and_noninterpret_matches_ref(rng):
    d, e, a, b, c = (_mk(rng, (8, 256)) for _ in range(5))
    sel = jnp.broadcast_to(jnp.uint32(0xFFFFFFFF), d.shape)
    got = _attempt_noninterpret(gmw_round.beaver_and_pallas,
                                d, e, a, b, c, sel)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.beaver_and(d, e, a, b, c, sel)))


@pytest.mark.parametrize("w,shift", [(8, 1), (8, 4), (64, 32)])
def test_ks_mask_noninterpret_matches_ref(w, shift, rng, block_words):
    g, p = _mk(rng, (2, w, block_words)), _mk(rng, (2, w, block_words))
    a, b = _mk(rng, (2, 2 * w, block_words)), _mk(rng, (2, 2 * w, block_words))
    d_k, e_k = _attempt_noninterpret(gmw_round.ks_mask_pallas,
                                     g, p, a, b, shift,
                                     block_words=block_words)
    d_r, e_r = ref.ks_mask(g, p, a, b, shift)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(e_k), np.asarray(e_r))


@pytest.mark.parametrize("w", [8, 64])
def test_ks_combine_noninterpret_matches_ref(w, rng, block_words):
    d, do, e, eo, a, b, c = (_mk(rng, (2, 2 * w, block_words))
                             for _ in range(7))
    g = _mk(rng, (2, w, block_words))
    sel = jnp.broadcast_to(jnp.uint32(0xFFFFFFFF), d.shape)
    g_k, p_k = _attempt_noninterpret(gmw_round.ks_combine_pallas,
                                     d, do, e, eo, a, b, c, sel, g,
                                     block_words=block_words)
    g_r, p_r = ref.ks_combine(d, do, e, eo, a, b, c, sel, g)
    np.testing.assert_array_equal(np.asarray(g_k), np.asarray(g_r))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


# ---------------------------------------------------------------------------
# BLOCK_WORDS sweep under interpret mode: every tile in the sweep is
# bit-identical on any backend, so a TPU sweep only changes wall-clock
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w,shift", [(8, 2), (21 - 13, 1)])
def test_ks_mask_block_words_sweep_interpret(w, shift, rng, block_words):
    words = 512                              # covered by every sweep tile
    g, p = _mk(rng, (2, w, words)), _mk(rng, (2, w, words))
    a, b = _mk(rng, (2, 2 * w, words)), _mk(rng, (2, 2 * w, words))
    d_k, e_k = gmw_round.ks_mask_pallas(g, p, a, b, shift, interpret=True,
                                        block_words=block_words)
    d_r, e_r = ref.ks_mask(g, p, a, b, shift)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(e_k), np.asarray(e_r))


# ---------------------------------------------------------------------------
# ops-layer env knobs
# ---------------------------------------------------------------------------

def test_block_words_env_knob(monkeypatch):
    monkeypatch.delenv("HB_BLOCK_WORDS", raising=False)
    assert ops.block_words() == gmw_round.BLOCK_WORDS
    monkeypatch.setenv("HB_BLOCK_WORDS", "512")
    assert ops.block_words() == 512
    for bad in ("300", "-128", "0", "abc"):   # not a positive 128-multiple
        monkeypatch.setenv("HB_BLOCK_WORDS", bad)
        assert ops.block_words() == gmw_round.BLOCK_WORDS


def test_ops_knob_flip_retraces_not_stale(monkeypatch, rng):
    """ref path, then HB_PALLAS_INTERPRET=1, then a BLOCK_WORDS override:
    three traces of the same public wrapper in one process, all
    bit-identical — the knobs are static jit args, not baked-in globals."""
    monkeypatch.delenv("REPRO_FORCE_PALLAS_INTERPRET", raising=False)
    monkeypatch.delenv("HB_PALLAS_INTERPRET", raising=False)
    monkeypatch.delenv("HB_BLOCK_WORDS", raising=False)
    w, words, shift = 8, 256, 2
    g, p = _mk(rng, (2, w, words)), _mk(rng, (2, w, words))
    a, b = _mk(rng, (2, 2 * w, words)), _mk(rng, (2, 2 * w, words))
    want = [np.asarray(x) for x in ref.ks_mask(g, p, a, b, shift)]

    if jax.default_backend() != "tpu":       # ref dispatch off-TPU
        got = ops.ks_mask(g, p, a, b, shift)
        for gx, wx in zip(got, want):
            np.testing.assert_array_equal(np.asarray(gx), wx)

    monkeypatch.setenv("HB_PALLAS_INTERPRET", "1")   # interpret Pallas path
    got = ops.ks_mask(g, p, a, b, shift)
    for gx, wx in zip(got, want):
        np.testing.assert_array_equal(np.asarray(gx), wx)

    monkeypatch.setenv("HB_BLOCK_WORDS", "128")      # sweep tile override
    got = ops.ks_mask(g, p, a, b, shift)
    for gx, wx in zip(got, want):
        np.testing.assert_array_equal(np.asarray(gx), wx)


def test_ops_noninterpret_knob(monkeypatch, rng):
    """HB_PALLAS_INTERPRET=0 forces the compiled Pallas lowering through
    the public ops wrappers (skip-marked where the backend lacks one)."""
    monkeypatch.setenv("HB_PALLAS_INTERPRET", "0")
    w, words = 8, 256
    g, p = _mk(rng, (2, w, words)), _mk(rng, (2, w, words))
    a, b = _mk(rng, (2, 2 * w, words)), _mk(rng, (2, 2 * w, words))
    try:
        got = ops.ks_mask(g, p, a, b, 2)
    except Exception as e:
        msg = str(e)
        if "interpret mode" in msg or "Only interpret" in msg.lower():
            pytest.skip(f"no compiled Pallas lowering on "
                        f"{jax.default_backend()}")
        raise
    d_r, e_r = ref.ks_mask(g, p, a, b, 2)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(e_r))
