"""hbcheck AST linter: the protocol-safety rules behind HummingBird's
security argument, machine-checked (see docs/analysis.md for the full
catalog with rationale and examples).

Rules (scoped to ``src/repro``; tests/examples are exempt where noted):

- **R001 raw-exchange** — wire primitives (``.swap``/``.sendall``/
  ``.recv``/``.recv_into``/``.exchange``) may only be called inside the
  comm seam: ``core/comm.py`` (the backends + coalescer), the round
  drivers ``core/gmw.py``/``core/gmw_ref.py``, the fault/journal layer
  ``core/faults.py``, the TCP framing ``transport/socket.py`` and the
  per-party entry ``launch/party_host.py``.  Everything else must go
  through a ``Comm`` object handed down from ``Session`` so rounds stay
  coalesced, counted, journaled and resumable.
- **R002 reveal-surface** — share recombination (``reveal``/
  ``reveal_np``/``to_uint64_np``) only inside the approved API surface:
  ``api/``, ``serve/``, ``launch/``, and the defining core modules
  (``core/mpc_tensor.py``, ``core/ring.py``, ``core/shares.py``,
  ``core/fixed.py``).  Protocol code must never declassify mid-round.
- **R003 secret-branch** — no Python ``if``/``while``/ternary on a value
  derived from an ``MPCTensor``/``Ring64`` share.  Control flow is
  observable (timing, round counts); branching on shares leaks.
  Metadata (``.shape``, ``.dtype``, ``isinstance(...)``, ``x is None``)
  is public and allowed.
- **R004 prng-discipline** — ``jax.random.PRNGKey(<constant>)`` is
  banned outside tests: every key must trace to ``Session`` material
  (``session.next_key()``/``request_key(id)``/``party_slice``) or to a
  caller-provided seed variable, so both parties' randomness is
  session-derived and reproducible.
- **R005 ring-dtype** — the uint32-limb ring modules must not touch
  float dtypes or true division: no ``float32``/``float64``/``float16``/
  ``bfloat16`` references, no ``.astype(float...)``, no ``/`` (shares
  live on Z_{2^64}; an implicit float promotion silently destroys the
  ring structure and bit-exactness).
- **R006 round-determinism** — modules on the round path (protocol
  drivers, schedule simulator, comm backends, transport framing) must be
  deterministic: no ``time.time``/``time.time_ns`` (wall clock; use
  ``time.monotonic``/``perf_counter`` for intervals), no stdlib
  ``random``, no ``os.urandom``, no iteration over set displays/calls
  (unordered iteration feeding the schedule breaks bit-exact replay).

Suppression: append ``# hbcheck: disable=R001`` (comma-separate several
rules, or ``disable=all``) to the offending line or the line above.
Grandfathered findings live in ``tools/hbcheck_baseline.json``; the CLI
fails only on non-baselined findings.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

# ---------------------------------------------------------------------------
# findings, suppressions, baseline
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.  ``key()`` intentionally omits the line number
    so baseline entries survive unrelated edits above the finding."""

    file: str            # posix path relative to the scan root
    line: int
    rule: str
    message: str

    def key(self) -> Tuple[str, str, str]:
        return (self.file, self.rule, self.message)

    def __str__(self) -> str:
        return f"{self.file}:{self.line}: {self.rule} {self.message}"


_SUPPRESS_RE = re.compile(r"#\s*hbcheck:\s*disable=([A-Za-z0-9_,\s]+)")


def _suppressions(lines: Sequence[str]) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def load_baseline(path) -> Set[Tuple[str, str, str]]:
    p = pathlib.Path(path)
    if not p.exists():
        return set()
    entries = json.loads(p.read_text())
    return {(e["file"], e["rule"], e["message"]) for e in entries}


def save_baseline(path, findings: Sequence[Finding]) -> None:
    entries = [{"file": f.file, "rule": f.rule, "message": f.message}
               for f in sorted(findings, key=lambda f: (f.file, f.rule))]
    pathlib.Path(path).write_text(json.dumps(entries, indent=1) + "\n")


# ---------------------------------------------------------------------------
# file context
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FileCtx:
    path: str                  # normalized posix, relative to scan root
    tree: ast.Module
    lines: List[str]

    @property
    def mod(self) -> Optional[str]:
        """Path inside the ``repro`` package ("core/gmw.py"), or None for
        files outside ``src/repro`` (tests, benchmarks, tools...)."""
        marker = "src/repro/"
        if marker in self.path:
            return self.path.split(marker, 1)[1]
        if self.path.startswith("repro/"):
            return self.path[len("repro/"):]
        return None

    @property
    def in_tests(self) -> bool:
        parts = pathlib.PurePosixPath(self.path).parts
        base = parts[-1] if parts else ""
        return ("tests" in parts or base.startswith("test_")
                or base == "conftest.py")


# ---------------------------------------------------------------------------
# small AST helpers
# ---------------------------------------------------------------------------

def _call_name(func: ast.expr) -> str:
    """Terminal name of a call target: ``a.b.c(...)`` -> "c"."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _dotted(node: ast.expr) -> str:
    """Best-effort dotted name of an expression ("jax.random.PRNGKey")."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _walk_calls(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node


# ---------------------------------------------------------------------------
# R001 — raw exchange outside the comm seam
# ---------------------------------------------------------------------------

R001_SEAM = frozenset({
    "core/comm.py", "core/gmw.py", "core/gmw_ref.py", "core/faults.py",
    "transport/socket.py", "launch/party_host.py",
})
_R001_METHODS = frozenset({"swap", "sendall", "recv", "recv_into",
                           "exchange"})


def rule_r001(ctx: FileCtx) -> List[Finding]:
    if ctx.mod is None or ctx.mod in R001_SEAM or ctx.in_tests:
        return []
    out = []
    for call in _walk_calls(ctx.tree):
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in _R001_METHODS:
            out.append(Finding(
                ctx.path, call.lineno, "R001",
                f"raw wire primitive .{call.func.attr}() outside the comm "
                f"seam ({', '.join(sorted(R001_SEAM))}); route exchanges "
                f"through a Session-provided Comm"))
    return out


# ---------------------------------------------------------------------------
# R002 — reveal / share recombination outside the approved surface
# ---------------------------------------------------------------------------

R002_SURFACE_PREFIXES = ("api/", "serve/", "launch/")
R002_SURFACE_FILES = frozenset({
    "core/mpc_tensor.py", "core/ring.py", "core/shares.py", "core/fixed.py",
})
_R002_NAMES = frozenset({"reveal", "reveal_np", "to_uint64_np"})


def rule_r002(ctx: FileCtx) -> List[Finding]:
    mod = ctx.mod
    if (mod is None or ctx.in_tests or mod in R002_SURFACE_FILES
            or mod.startswith(R002_SURFACE_PREFIXES)):
        return []
    out = []
    for call in _walk_calls(ctx.tree):
        name = _call_name(call.func)
        if name in _R002_NAMES:
            out.append(Finding(
                ctx.path, call.lineno, "R002",
                f"share recombination {name}() outside the approved "
                f"reveal surface (api/, serve/, launch/, core share types)"))
    return out


# ---------------------------------------------------------------------------
# R003 — secret-dependent Python control flow
# ---------------------------------------------------------------------------

_TAINT_CONSTRUCTORS = frozenset({"MPCTensor", "Ring64", "share", "encrypt",
                                 "from_plain"})
_TAINT_ANNOTATIONS = frozenset({"MPCTensor", "Ring64"})
# public metadata on share-typed values: branching on these is fine
_DECLASSIFIED_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "nbytes",
                                 "frac_bits", "out_batch", "n_elements",
                                 "width", "group"})
_DECLASSIFY_CALLS = frozenset({"reveal", "reveal_np", "len", "isinstance",
                               "type", "id", "repr", "str", "prod", "hash"})


class _SecretFlow(ast.NodeVisitor):
    """Per-scope forward taint: share-typed names may not feed
    if/while/ternary tests.  Scope-local and syntactic on purpose — this
    is a lint heuristic, not an information-flow proof (the HLO taint
    census covers the compiled dataflow)."""

    def __init__(self, ctx: FileCtx, findings: List[Finding]):
        self.ctx = ctx
        self.findings = findings
        self.tainted: Set[str] = set()

    # -- taint query --------------------------------------------------------
    def _is_tainted(self, e: ast.expr) -> bool:
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, ast.Attribute):
            if e.attr in _DECLASSIFIED_ATTRS:
                return False
            return self._is_tainted(e.value)
        if isinstance(e, (ast.Subscript, ast.Starred)):
            return self._is_tainted(e.value)
        if isinstance(e, ast.Call):
            name = _call_name(e.func)
            if name in _DECLASSIFY_CALLS:
                return False
            if name in _TAINT_CONSTRUCTORS:
                return True
            args = list(e.args) + [kw.value for kw in e.keywords]
            return any(self._is_tainted(a) for a in args)
        if isinstance(e, ast.BinOp):
            return self._is_tainted(e.left) or self._is_tainted(e.right)
        if isinstance(e, ast.UnaryOp):
            return self._is_tainted(e.operand)
        if isinstance(e, ast.BoolOp):
            return any(self._is_tainted(v) for v in e.values)
        if isinstance(e, ast.Compare):
            # identity tests against None are public (optional-arg idiom)
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in e.ops) \
                    and all(isinstance(c, ast.Constant)
                            for c in e.comparators):
                return False
            return (self._is_tainted(e.left)
                    or any(self._is_tainted(c) for c in e.comparators))
        if isinstance(e, (ast.Tuple, ast.List)):
            return any(self._is_tainted(el) for el in e.elts)
        if isinstance(e, ast.IfExp):
            return (self._is_tainted(e.body) or self._is_tainted(e.orelse))
        return False

    # -- taint updates ------------------------------------------------------
    def _taint_target(self, target: ast.expr, value_tainted: bool):
        if isinstance(target, ast.Name):
            if value_tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._taint_target(el, value_tainted)
        elif isinstance(target, ast.Starred):
            self._taint_target(target.value, value_tainted)

    def visit_Assign(self, node: ast.Assign):
        t = self._is_tainted(node.value)
        if (isinstance(node.value, (ast.Tuple, ast.List))
                and len(node.targets) == 1
                and isinstance(node.targets[0], (ast.Tuple, ast.List))
                and len(node.targets[0].elts) == len(node.value.elts)):
            for tgt, val in zip(node.targets[0].elts, node.value.elts):
                self._taint_target(tgt, self._is_tainted(val))
        else:
            for tgt in node.targets:
                self._taint_target(tgt, t)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        ann_taint = any(isinstance(n, ast.Name) and n.id in _TAINT_ANNOTATIONS
                        for n in ast.walk(node.annotation))
        t = ann_taint or (node.value is not None
                          and self._is_tainted(node.value))
        self._taint_target(node.target, t)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        if self._is_tainted(node.value):
            self._taint_target(node.target, True)
        self.generic_visit(node)

    def visit_For(self, node: ast.For):
        if self._is_tainted(node.iter):
            self._taint_target(node.target, True)
        self.generic_visit(node)

    # -- scopes -------------------------------------------------------------
    def _enter_function(self, node):
        sub = _SecretFlow(self.ctx, self.findings)
        args = list(node.args.args) + list(node.args.posonlyargs) \
            + list(node.args.kwonlyargs)
        for a in args:
            if a.annotation is not None and any(
                    isinstance(n, ast.Name) and n.id in _TAINT_ANNOTATIONS
                    for n in ast.walk(a.annotation)):
                sub.tainted.add(a.arg)
        for stmt in node.body:
            sub.visit(stmt)

    def visit_FunctionDef(self, node):
        self._enter_function(node)

    def visit_AsyncFunctionDef(self, node):
        self._enter_function(node)

    # -- the actual rule ----------------------------------------------------
    def _flag(self, node, what: str):
        self.findings.append(Finding(
            self.ctx.path, node.lineno, "R003",
            f"secret-dependent {what}: the condition derives from an "
            f"MPCTensor/Ring64 share (control flow is observable; reveal "
            f"explicitly or use arithmetic select)"))

    def visit_If(self, node: ast.If):
        if self._is_tainted(node.test):
            self._flag(node, "if")
        self.generic_visit(node)

    def visit_While(self, node: ast.While):
        if self._is_tainted(node.test):
            self._flag(node, "while")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp):
        if self._is_tainted(node.test):
            self._flag(node, "ternary")
        self.generic_visit(node)


def rule_r003(ctx: FileCtx) -> List[Finding]:
    if ctx.mod is None or ctx.in_tests:
        return []
    findings: List[Finding] = []
    flow = _SecretFlow(ctx, findings)
    for stmt in ctx.tree.body:
        flow.visit(stmt)
    return findings


# ---------------------------------------------------------------------------
# R004 — PRNG discipline
# ---------------------------------------------------------------------------

def rule_r004(ctx: FileCtx) -> List[Finding]:
    if ctx.mod is None or ctx.in_tests:
        return []
    out = []
    for call in _walk_calls(ctx.tree):
        if _call_name(call.func) != "PRNGKey":
            continue
        if call.args and isinstance(call.args[0], ast.Constant):
            out.append(Finding(
                ctx.path, call.lineno, "R004",
                f"constant PRNG seed PRNGKey({call.args[0].value!r}); "
                f"derive keys from Session (next_key/request_key) or a "
                f"caller-provided seed"))
    return out


# ---------------------------------------------------------------------------
# R005 — ring dtype discipline in core/
# ---------------------------------------------------------------------------

R005_RING_MODULES = frozenset({
    "core/ring.py", "core/ring_linalg.py", "core/gmw.py", "core/gmw_ref.py",
    "core/shares.py",
})
_FLOAT_NAMES = frozenset({"float32", "float64", "float16", "bfloat16",
                          "float_", "double"})


def rule_r005(ctx: FileCtx) -> List[Finding]:
    if ctx.mod not in R005_RING_MODULES:
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute) and node.attr in _FLOAT_NAMES:
            out.append(Finding(
                ctx.path, node.lineno, "R005",
                f"float dtype {_dotted(node)} in a ring module (shares "
                f"live on Z_2^64 as uint32 limbs; float promotion breaks "
                f"the ring)"))
        elif isinstance(node, ast.Constant) and node.value in ("float32",
                                                              "float64"):
            out.append(Finding(
                ctx.path, node.lineno, "R005",
                f"float dtype string {node.value!r} in a ring module"))
        elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div):
            if isinstance(node.left, ast.Constant) and \
                    isinstance(node.right, ast.Constant):
                continue            # pure scalar constant math is fine
            out.append(Finding(
                ctx.path, node.lineno, "R005",
                "true division in a ring module promotes to float; use "
                "// or shifts on the uint32 limbs"))
    return out


# ---------------------------------------------------------------------------
# R006 — determinism on the round path
# ---------------------------------------------------------------------------

R006_ROUND_PATH = frozenset({
    "core/gmw.py", "core/gmw_ref.py", "core/schedule.py", "core/comm.py",
    "core/faults.py", "core/beaver.py", "core/costmodel.py",
    "transport/socket.py", "transport/engine_link.py",
    # the reduced-ring nonlinearity subsystem drives relu_fn / Beaver-open
    # placement, so its evaluation order feeds the schedule directly
    "nn/approx/__init__.py", "nn/approx/pwl.py", "nn/approx/attention.py",
    "nn/approx/bounds.py",
})


def rule_r006(ctx: FileCtx) -> List[Finding]:
    if ctx.mod not in R006_ROUND_PATH:
        return []
    imports_stdlib_random = any(
        (isinstance(n, ast.Import)
         and any(a.name == "random" for a in n.names))
        or (isinstance(n, ast.ImportFrom) and n.module == "random")
        for n in ast.walk(ctx.tree))
    out = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            dotted = _dotted(node.func)
            if dotted in ("time.time", "time.time_ns"):
                out.append(Finding(
                    ctx.path, node.lineno, "R006",
                    f"wall clock {dotted}() on the round path; rounds must "
                    f"replay deterministically (time.monotonic is fine for "
                    f"intervals)"))
            elif dotted == "os.urandom":
                out.append(Finding(
                    ctx.path, node.lineno, "R006",
                    "os.urandom on the round path; randomness must come "
                    "from session-derived jax PRNG keys"))
            elif imports_stdlib_random and dotted.startswith("random."):
                out.append(Finding(
                    ctx.path, node.lineno, "R006",
                    f"stdlib {dotted}() on the round path; use "
                    f"session-derived jax PRNG keys"))
        elif isinstance(node, (ast.For, ast.comprehension)):
            it = node.iter
            if isinstance(it, ast.Set) or (
                    isinstance(it, ast.Call)
                    and _call_name(it.func) == "set"):
                out.append(Finding(
                    ctx.path, node.lineno, "R006",
                    "iteration over an unordered set on the round path; "
                    "sort it (set order must not feed the schedule)"))
    return out


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

RULES: Tuple[Tuple[str, Callable[[FileCtx], List[Finding]]], ...] = (
    ("R001", rule_r001), ("R002", rule_r002), ("R003", rule_r003),
    ("R004", rule_r004), ("R005", rule_r005), ("R006", rule_r006),
)


def lint_source(source: str, path: str) -> List[Finding]:
    """Lint one file's source text; ``path`` drives rule scoping (use the
    repo-relative posix path, e.g. "src/repro/core/gmw.py")."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, "R000",
                        f"syntax error: {e.msg}")]
    lines = source.splitlines()
    ctx = FileCtx(path=path, tree=tree, lines=lines)
    findings: List[Finding] = []
    for _, rule in RULES:
        findings.extend(rule(ctx))
    sup = _suppressions(lines)
    kept = []
    for f in findings:
        rules_here = sup.get(f.line, set()) | sup.get(f.line - 1, set())
        if f.rule in rules_here or "all" in rules_here:
            continue
        kept.append(f)
    kept.sort(key=lambda f: (f.file, f.line, f.rule))
    return kept


def lint_paths(paths: Sequence, root=None) -> List[Finding]:
    """Lint every ``*.py`` under ``paths`` (files or directories).
    Reported paths are posix-relative to ``root`` (default: cwd)."""
    root = pathlib.Path(root or ".").resolve()
    findings: List[Finding] = []
    for p in paths:
        p = pathlib.Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            try:
                rel = f.resolve().relative_to(root).as_posix()
            except ValueError:
                rel = f.as_posix()
            findings.extend(lint_source(f.read_text(), rel))
    return findings
