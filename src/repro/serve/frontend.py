"""Async serving frontend: HTTP in, private inference out.

``Frontend`` puts an asyncio HTTP server in front of an
``InferenceEngine`` and starts the engine's background pump, so the
serving loop is fully hands-off: a request thread ``submit()``s and
waits on its future while the pump forms and executes fused
micro-batches — no caller ever drives ``poll``/``flush`` (they remain
manual overrides).  HTTP parsing is hand-rolled over asyncio streams
(stdlib only; one request per connection, ``Connection: close``).

Routes (all JSON):

- ``POST /infer``  body ``{"tenant": str, "x": nested-list, optional
  "request_id", "deadline_s", "timeout_s"}`` -> ``{"id", "y",
  "batch": {rounds, requests, wall-queue stats}}``.  The input is
  secret-shared inside ``submit`` (the frontend process is the client
  gateway) and the revealed output returned.
- ``GET /healthz`` liveness: queue depth, pump state, last pump error.
- ``GET /stats``   ``engine.stats()`` plus — when the engine session
  came from ``Session.connect`` — the socket transport's wire counters
  (rounds, payload/header bytes, dup drops, resilience retries).

Deployment (one process per party; see ``docs/deployment.md``)::

    # terminal 1 — the follower party serves protocol batches
    python -m repro.launch.party_host --party 1 --job jobdir \
        --listen 127.0.0.1:9000 --follow

    # terminal 2 — the leader party: engine + HTTP frontend
    python -m repro.serve.frontend --job jobdir \
        --peer 127.0.0.1:9000 --http 127.0.0.1:9001

    curl -s -X POST http://127.0.0.1:9001/infer \
        -d '{"tenant": "alice", "x": [[...]]}'

The leader owns the engine (admission, batching policy, shedding,
metering) and holds both share rows of each input exactly as any client
would; the follower only ever sees its own rows
(``repro.transport.engine_link``).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys
import threading
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro import errors
from repro.core import comm as comm_lib

_MAX_BODY = 64 << 20          # 64 MiB request cap (a batch of images is MBs)


class Frontend:
    """HTTP facade over one ``InferenceEngine`` (see module docstring).

    ``serve_background()`` runs the asyncio loop in a daemon thread and
    returns the bound (host, port) — the test/example entry point;
    ``run_forever()`` blocks the calling thread — the deployment entry
    point.  Either way the engine pump is started so submission alone
    makes progress.
    """

    def __init__(self, engine, *, result_timeout_s: float = 600.0):
        self.engine = engine
        self.result_timeout_s = result_timeout_s
        self.started_s = time.monotonic()
        self.requests_served = 0
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._thread: Optional[threading.Thread] = None
        if not engine.pump_running:
            engine.start_pump()

    # -- request handling ------------------------------------------------------
    def _infer_blocking(self, payload: Dict) -> Dict:
        """Runs on a worker thread: submit, wait on the pump, reveal."""
        if "x" not in payload:
            raise ValueError("body must carry 'x' (nested list input)")
        x = np.asarray(payload["x"], dtype=np.float32)
        fut = self.engine.submit(
            str(payload.get("tenant", "default")), x,
            request_id=payload.get("request_id"),
            deadline_s=payload.get("deadline_s"))
        t0 = time.monotonic()
        out = fut.result(timeout_s=float(payload.get(
            "timeout_s", self.result_timeout_s)))
        resp = {"id": fut.request.id,
                "tenant": fut.request.tenant,
                "y": np.asarray(out.reveal()).tolist(),
                "wall_s": time.monotonic() - t0}
        if fut.report is not None:
            resp["batch"] = {
                "n_requests": fut.report.n_requests,
                "measured_rounds": fut.report.measured_rounds,
                "predicted_rounds": fut.report.predicted_rounds,
                "measured_bytes": fut.report.measured_bytes,
                "rounds_saved_ratio": fut.report.rounds_saved_ratio,
                "retries": fut.report.retries,
            }
        return resp

    def _stats(self) -> Dict:
        stats = dict(self.engine.stats())
        stats["pending"] = self.engine.pending
        stats["frontend_requests"] = self.requests_served
        stats["uptime_s"] = time.monotonic() - self.started_s
        from repro.transport import SocketComm   # local: optional backend
        sock = comm_lib.find_comm(self.engine.session.comm, SocketComm)
        if sock is not None:
            resilient = comm_lib.find_resilient(self.engine.session.comm)
            stats["transport"] = {
                "party": sock.party,
                "rounds": sock.n_swaps,
                "payload_bytes": sock.bytes_tx,
                "header_bytes": sock.header_bytes,
                "dup_dropped": sock.dup_dropped,
                "retries": resilient.retries if resilient else 0,
                "recovered": resilient.recovered if resilient else 0,
            }
        return stats

    def _healthz(self) -> Dict:
        err = self.engine.last_pump_error
        return {"ok": True, "pending": self.engine.pending,
                "pump": self.engine.pump_running,
                "last_pump_error": repr(err) if err is not None else None}

    # -- the asyncio HTTP server -----------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            status, body = await self._dispatch(reader)
        except errors.ResultTimeout as e:
            status, body = 504, {"error": str(e)}
        except (errors.ReproError, ValueError, KeyError, TypeError) as e:
            status, body = 400, {"error": f"{type(e).__name__}: {e}"}
        except Exception as e:                     # noqa: BLE001 — last line
            status, body = 500, {"error": f"{type(e).__name__}: {e}"}
        payload = json.dumps(body).encode()
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  500: "Internal Server Error",
                  504: "Gateway Timeout"}.get(status, "")
        writer.write(
            f"HTTP/1.1 {status} {reason}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(payload)}\r\n"
            f"Connection: close\r\n\r\n".encode() + payload)
        try:
            await writer.drain()
            writer.close()
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass                                   # client went away

    async def _dispatch(self,
                        reader: asyncio.StreamReader) -> Tuple[int, Dict]:
        request_line = (await reader.readline()).decode("latin-1").strip()
        if not request_line:
            return 400, {"error": "empty request"}
        try:
            method, path, _version = request_line.split(" ", 2)
        except ValueError:
            return 400, {"error": f"malformed request line {request_line!r}"}
        headers: Dict[str, str] = {}
        while True:
            line = (await reader.readline()).decode("latin-1").strip()
            if not line:
                break
            name, _, value = line.partition(":")
            headers[name.strip().lower()] = value.strip()
        path = path.split("?", 1)[0]
        if method == "GET" and path == "/healthz":
            return 200, self._healthz()
        if method == "GET" and path == "/stats":
            return 200, self._stats()
        if method == "POST" and path == "/infer":
            n = int(headers.get("content-length", 0))
            if n > _MAX_BODY:
                return 400, {"error": f"body of {n} bytes exceeds the "
                             f"{_MAX_BODY} byte cap"}
            payload = json.loads((await reader.readexactly(n)).decode()
                                 if n else "{}")
            resp = await asyncio.to_thread(self._infer_blocking, payload)
            self.requests_served += 1
            return 200, resp
        return 404, {"error": f"no route for {method} {path}"}

    async def serve(self, host: str = "127.0.0.1",
                    port: int = 0) -> Tuple[str, int]:
        """Bind + start serving on the running loop; returns (host, port)."""
        self._loop = asyncio.get_running_loop()
        self._server = await asyncio.start_server(self._handle, host, port)
        return self._server.sockets[0].getsockname()[:2]

    def serve_background(self, host: str = "127.0.0.1",
                         port: int = 0) -> Tuple[str, int]:
        """Run the HTTP server in a daemon thread; returns the bound
        (host, port) once it is accepting connections."""
        bound: Dict = {}
        started = threading.Event()

        def _run() -> None:
            async def _main() -> None:
                bound["addr"] = await self.serve(host, port)
                started.set()
                await self._server.serve_forever()

            try:
                asyncio.run(_main())
            except asyncio.CancelledError:
                pass

        self._thread = threading.Thread(target=_run, name="http-frontend",
                                        daemon=True)
        self._thread.start()
        if not started.wait(10.0):
            raise RuntimeError(f"frontend failed to bind {host}:{port}")
        return bound["addr"]

    def run_forever(self, host: str = "127.0.0.1",
                    port: int = 9001) -> None:
        """Blocking deployment entry point."""

        async def _main() -> None:
            addr = await self.serve(host, port)
            print(f"frontend serving on http://{addr[0]}:{addr[1]} "
                  "(POST /infer, GET /healthz, GET /stats)", flush=True)
            await self._server.serve_forever()

        asyncio.run(_main())

    def close(self) -> None:
        """Stop the HTTP server and the engine pump (queued work stays)."""
        if self._server is not None and self._loop is not None:
            self._loop.call_soon_threadsafe(self._server.close)
        if self._thread is not None:
            for task in asyncio.all_tasks(self._loop) if self._loop else []:
                self._loop.call_soon_threadsafe(task.cancel)
            self._thread.join(5.0)
            self._thread = None
        self.engine.stop_pump()


# -- deployment entry point: the leader party process -------------------------

def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="frontend",
        description="leader party: inference engine + HTTP frontend",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("--job", required=True)
    ap.add_argument("--listen", default=None,
                    help="host:port to accept the follower party on")
    ap.add_argument("--peer", default=None,
                    help="host:port of a hosting follower to dial")
    ap.add_argument("--http", default="127.0.0.1:9001",
                    help="host:port for the HTTP frontend")
    ap.add_argument("--party", type=int, default=0, choices=(0, 1))
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=50.0)
    ap.add_argument("--merge-identical", action="store_true")
    ap.add_argument("--rtt-ms", type=float, default=0.0)
    ap.add_argument("--mbps", type=float, default=0.0)
    ap.add_argument("--timeout-s", type=float, default=30.0)
    ap.add_argument("--handshake-timeout-s", type=float, default=120.0)
    return ap


def build_engine(args, job):
    """The leader-side engine over a connected two-party session."""
    import jax
    from repro import api, serve, transport
    from repro.models import resnet
    from repro.transport.socket import parse_address

    cfg, plan = job["cfg"], job["plan"]
    params = resnet.init(jax.random.PRNGKey(job["params_seed"]), cfg)
    shaper = None
    if args.rtt_ms > 0 or args.mbps > 0:
        shaper = transport.LinkShaper(
            rtt_s=args.rtt_ms / 1e3,
            bandwidth_bps=(args.mbps * 1e6 if args.mbps > 0
                           else float("inf")))
    session = api.Session.connect(
        args.party,
        listen=parse_address(args.listen) if args.listen else None,
        peer=parse_address(args.peer) if args.peer else None,
        key=job["session_seed"], session_id=str(job["session_seed"]),
        plan_digest=plan.digest(), shaper=shaper, timeout_s=args.timeout_s,
        handshake_timeout_s=args.handshake_timeout_s)

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, cfg, relu_fn=relu_fn)

    engine = serve.InferenceEngine(
        afn, params, cfg, plan, session,
        policy=serve.BatchPolicy(max_batch=args.max_batch,
                                 max_wait_s=args.max_wait_ms / 1e3,
                                 merge_identical=args.merge_identical),
        provider_factory=transport.tenant_provider_factory(
            job["ttp_seed"], party=args.party))
    link = transport.EngineLink(engine)
    return engine, link


def main(argv=None) -> int:
    from repro import transport
    from repro.transport.socket import parse_address

    args = build_parser().parse_args(argv)
    if (args.listen is None) == (args.peer is None):
        print("pass exactly one of --listen / --peer", file=sys.stderr)
        return 2
    job = transport.load_job(args.job)
    engine, link = build_engine(args, job)
    frontend = Frontend(engine)
    host, port = parse_address(args.http, default_port=9001)
    try:
        frontend.run_forever(host, port)
    except KeyboardInterrupt:
        pass
    finally:
        link.shutdown()
        frontend.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
