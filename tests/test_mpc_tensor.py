"""MPCTensor linear ops + ReLU vs plaintext, and the MPC ResNet e2e."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RESNET_SMOKE
from repro.core import MPCTensor, HBLayer
from repro.models import resnet


def test_matmul_conv_pool(rng):
    x_f = rng.uniform(-4, 4, (6, 32)).astype(np.float32)
    w_f = rng.uniform(-1, 1, (32, 8)).astype(np.float32)
    X = MPCTensor.from_plain(jax.random.PRNGKey(0), jnp.asarray(x_f))
    np.testing.assert_allclose(X.matmul_public(jnp.asarray(w_f)).reveal_np(),
                               x_f @ w_f, atol=2e-3)
    xc = rng.uniform(-2, 2, (2, 3, 8, 8)).astype(np.float32)
    wc = rng.uniform(-1, 1, (4, 3, 3, 3)).astype(np.float32)
    Xc = MPCTensor.from_plain(jax.random.PRNGKey(1), jnp.asarray(xc))
    got = Xc.conv2d_public(jnp.asarray(wc), 1, 1).reveal_np()
    ref = jax.lax.conv_general_dilated(
        jnp.asarray(xc), jnp.asarray(wc), (1, 1), [(1, 1), (1, 1)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    np.testing.assert_allclose(got, np.asarray(ref), atol=5e-3)
    P = Xc.avg_pool(2)
    np.testing.assert_allclose(
        P.reveal_np(), xc.reshape(2, 3, 4, 2, 4, 2).mean(axis=(3, 5)), atol=2e-3)


def test_add_public_and_arith(rng):
    x = rng.uniform(-2, 2, (16,)).astype(np.float32)
    y = rng.uniform(-2, 2, (16,)).astype(np.float32)
    X = MPCTensor.from_plain(jax.random.PRNGKey(2), jnp.asarray(x))
    Y = MPCTensor.from_plain(jax.random.PRNGKey(3), jnp.asarray(y))
    np.testing.assert_allclose((X + Y).reveal_np(), x + y, atol=1e-4)
    np.testing.assert_allclose((X - Y).reveal_np(), x - y, atol=1e-4)
    np.testing.assert_allclose(X.add_public(1.5).reveal_np(), x + 1.5, atol=1e-4)
    np.testing.assert_allclose(X.mul_public(-2.25).reveal_np(), x * -2.25,
                               atol=1e-3)


@pytest.mark.parametrize("k,m", [(64, 0), (21, 0), (21, 10)])
def test_mpc_relu_configs(k, m, rng):
    x = rng.uniform(-4, 4, (96,)).astype(np.float32)
    X = MPCTensor.from_plain(jax.random.PRNGKey(4), jnp.asarray(x))
    R = X.relu(jax.random.PRNGKey(5), hb=HBLayer(k=k, m=m))
    got = R.reveal_np()
    xr = X.reveal_np()  # fixed-point-rounded input
    exact = np.maximum(xr, 0)
    if m == 0:
        np.testing.assert_allclose(got, exact, atol=1e-4)
    else:
        thresh = 2.0 ** (m - 16)
        pruned = np.where((xr > 0) & (xr < thresh), 0.0, exact)
        ok = (np.abs(got - exact) < 1e-3) | (np.abs(got - pruned) < 1e-3)
        assert ok.all()


def test_mpc_resnet_matches_plaintext(rng):
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 16, 16)) * 0.5
    ref_logits = resnet.apply(params, x, RESNET_SMOKE)
    X = MPCTensor.from_plain(jax.random.PRNGKey(2), x)
    out = resnet.mpc_apply(params, X, RESNET_SMOKE, jax.random.PRNGKey(3))
    np.testing.assert_allclose(out.reveal_np(), np.asarray(ref_logits),
                               atol=2e-2)


def test_mpc_resnet_with_pregenerated_triples(rng):
    """Mesh-serving path: triples planned + generated offline."""
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 16, 16)) * 0.5
    plan = resnet.relu_plan(params, RESNET_SMOKE, batch=2)
    assert len(plan) > 0
    triples = resnet.gen_mpc_triples(jax.random.PRNGKey(4), plan, None,
                                     RESNET_SMOKE)
    X = MPCTensor.from_plain(jax.random.PRNGKey(2), x)
    out = resnet.mpc_apply(params, X, RESNET_SMOKE, jax.random.PRNGKey(3),
                           triples=triples)
    ref_logits = resnet.apply(params, x, RESNET_SMOKE)
    np.testing.assert_allclose(out.reveal_np(), np.asarray(ref_logits),
                               atol=2e-2)
