"""Optimizers implemented from scratch (no optax): AdamW, Adafactor, SGD.

All states mirror the parameter pytree so the FSDP partition rules apply
to optimizer state exactly as to params (ZeRO-3).  Adafactor offers the
memory-efficient factored second moment for the huge assigned archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Linear warmup + cosine decay (set decay_steps=0 for constant)."""

    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_ratio: float = 0.1

    def __call__(self, step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = jnp.minimum(1.0, (step + 1) / max(self.warmup_steps, 1))
        if not self.decay_steps:
            return self.peak_lr * warm
        frac = jnp.clip((step - self.warmup_steps) /
                        max(self.decay_steps - self.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return self.peak_lr * warm * (self.min_ratio + (1 - self.min_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Schedule = Schedule()
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}

    def update(self, grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        lr = self.schedule(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1 - self.b1 ** t
        bc2 = 1 - self.b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = self.b1 * m + (1 - self.b1) * g
            v2 = self.b2 * v + (1 - self.b2) * g * g
            mhat = m2 / bc1
            vhat = v2 / bc2
            step_ = mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * p
            return p - lr * step_, m2, v2

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree_util.tree_map(lambda o: o[0], out,
                                            is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}, {"lr": lr, "gnorm": gnorm}


@dataclasses.dataclass(frozen=True)
class Adafactor:
    """Factored second moments: O(n+m) state for an (n, m) matrix."""

    schedule: Schedule = Schedule()
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0

    def init(self, params):
        def zeros(p):
            if p.ndim >= 2:
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {"f": jax.tree_util.tree_map(zeros, params)}

    def update(self, grads, state, params, step):
        lr = self.schedule(step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-self.decay)

        def upd(g, f, p):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps
            if g.ndim >= 2:
                row = beta * f["row"] + (1 - beta) * g2.mean(axis=-1)
                col = beta * f["col"] + (1 - beta) * g2.mean(axis=-2)
                denom = (row[..., None] / jnp.maximum(
                    row.mean(axis=-1, keepdims=True)[..., None], self.eps))
                vhat = denom * col[..., None, :]
                f2 = {"row": row, "col": col}
            else:
                vhat = beta * f["v"] + (1 - beta) * g2
                f2 = {"v": vhat}
            u = g / jnp.sqrt(jnp.maximum(vhat, self.eps))
            rms = jnp.sqrt(jnp.mean(u * u) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            return p - lr * u, f2

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_f = treedef.flatten_up_to(state["f"])
        outs = [upd(g, f, p) for g, f, p in zip(flat_g, flat_f, flat_p)]
        new_params = treedef.unflatten([o[0] for o in outs])
        new_f = treedef.unflatten([o[1] for o in outs])
        return new_params, {"f": new_f}, {"lr": lr}


@dataclasses.dataclass(frozen=True)
class SGD:
    schedule: Schedule = Schedule()
    momentum: float = 0.9
    weight_decay: float = 0.0

    def init(self, params):
        return {"m": jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(self, grads, state, params, step):
        lr = self.schedule(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32) + self.weight_decay * p
            m2 = self.momentum * m + g
            return p - lr * m2, m2

        out = jax.tree_util.tree_map(upd, grads, state["m"], params)
        new_params = jax.tree_util.tree_map(lambda o: o[0], out,
                                            is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m}, {"lr": lr}


def get(name: str, **kwargs):
    return {"adamw": AdamW, "adafactor": Adafactor, "sgd": SGD}[name](**kwargs)
