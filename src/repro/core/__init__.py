"""HummingBird core: reduced-ring MPC ReLU on Z/2^64 in JAX.

Layering:
  ring         - Z/2^64 limb arithmetic (TPU-native, no int64)
  fixed        - fixed-point codec (CrypTen-compatible scale 2^16)
  shares       - arithmetic + packed binary secret sharing
  beaver       - TTP triple provider
  comm         - party communicator (sim / mesh backends)
  gmw          - A2B, DReLU, B2A, ReLU (exact Eq.2 + reduced-ring Eq.3)
  hummingbird  - per-layer (k, m) configs and budgets
  costmodel    - closed-form bytes/rounds (validated against HLO collectives)
  ring_linalg  - mod-2^64 matmul/conv with public weights (plane decomposition)
  mpc_tensor   - user-facing secret-shared tensor
"""
from . import beaver, comm, costmodel, fixed, gmw, hummingbird, ring, ring_linalg, shares
from .hummingbird import HBConfig, HBLayer, safe_k
from .mpc_tensor import MPCTensor, encode_weights

__all__ = [
    "beaver", "comm", "costmodel", "fixed", "gmw", "hummingbird", "ring",
    "ring_linalg", "shares", "HBConfig", "HBLayer", "safe_k", "MPCTensor",
    "encode_weights",
]
