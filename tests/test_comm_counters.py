"""Round/byte accounting: CountingComm + CoalescingComm counters vs the
closed-form cost model, and the fused engine's swap reduction vs the seed
per-call path (core/gmw_ref.py)."""
import jax
import numpy as np
import pytest

from repro.core import (beaver, comm as comm_lib, costmodel, fixed, gmw,
                        gmw_ref, ring, shares)
from repro.core.hummingbird import HBLayer


def _shared(E, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-3.5, 3.5, E).astype(np.float32)
    return shares.share(jax.random.PRNGKey(seed), fixed.encode_np(x))


@pytest.mark.parametrize("k,m", [(64, 0), (21, 13), (8, 0), (20, 14), (2, 1)])
def test_relu_rounds_and_bytes_match_model(k, m):
    E, w = 96, k - m
    X = _shared(E, seed=k)
    tr = beaver.gen_relu_triples(jax.random.PRNGKey(1), E, w)
    cm = comm_lib.CountingComm()
    gmw.relu(jax.random.PRNGKey(2), X, tr, cm, k=k, m=m)
    model = costmodel.relu_cost(E, w)
    assert cm.n_swaps == model.rounds == gmw.n_rounds(w)
    assert cm.bytes_tx == model.bytes_tx


# (5, 3), (3, 0), (5, 0) cover widths whose MSB cone has an empty KS level
# (the protocol skips it; the model must not charge a phantom round)
@pytest.mark.parametrize("k,m", [(21, 13), (64, 0), (5, 3), (3, 0), (5, 0)])
def test_cone_bytes_match_model(k, m):
    E, w = 128, k - m
    X = _shared(E, seed=k + 100)
    tr = beaver.gen_relu_triples(jax.random.PRNGKey(3), E, w, cone=True)
    cm = comm_lib.CountingComm()
    gmw.relu(jax.random.PRNGKey(4), X, tr, cm, k=k, m=m, cone=True)
    model = costmodel.relu_cost(E, w, cone=True)
    assert cm.n_swaps == model.rounds
    assert cm.bytes_tx == model.bytes_tx


def test_coalescing_swap_passthrough_counts_rounds():
    """CoalescingComm.swap (enqueue + flush) keeps seed round semantics."""
    E, w = 64, 8
    X = _shared(E, seed=7)
    tr = beaver.gen_relu_triples(jax.random.PRNGKey(5), E, w)
    inner = comm_lib.CountingComm()
    cc = comm_lib.CoalescingComm(inner)
    out_cc = gmw.relu(jax.random.PRNGKey(6), X, tr, cc, k=8, m=0)
    out_sim = gmw.relu(jax.random.PRNGKey(6), X, tr, comm_lib.SimComm(),
                       k=8, m=0)
    np.testing.assert_array_equal(ring.to_uint64_np(out_cc),
                                  ring.to_uint64_np(out_sim))
    assert cc.n_rounds == inner.n_swaps == gmw.n_rounds(w)
    assert cc.bytes_tx == costmodel.relu_cost(E, w).bytes_tx


def test_fused_multigroup_halves_swaps_same_bytes():
    """Acceptance: >=2x fewer swaps per multi-group ReLU layer, no byte
    increase, outputs bit-identical to the seed per-call path."""
    specs = [(96, 64, 0), (160, 21, 13), (64, 20, 14)]
    keys = [jax.random.PRNGKey(40 + i) for i in range(len(specs))]
    Xs = [_shared(E, seed=50 + i) for i, (E, _, _) in enumerate(specs)]
    trs = [beaver.gen_relu_triples(jax.random.PRNGKey(60 + i), E, k - m)
           for i, (E, k, m) in enumerate(specs)]

    # seed path: one swap per round per group, serially
    seed_cm = comm_lib.CountingComm()
    seed_outs = [gmw_ref.relu(keys[i], Xs[i], trs[i], seed_cm, k=k, m=m)
                 for i, (E, k, m) in enumerate(specs)]

    # fused path: all groups in lockstep, one coalesced exchange per round
    cc = comm_lib.CoalescingComm(comm_lib.SimComm())
    fused_outs = gmw.relu_many(keys, Xs, trs, cc,
                               [(k, m) for _, k, m in specs])

    for a, b in zip(seed_outs, fused_outs):
        np.testing.assert_array_equal(ring.to_uint64_np(a),
                                      ring.to_uint64_np(b))
    assert cc.n_rounds == max(gmw.n_rounds(k - m) for _, k, m in specs)
    assert seed_cm.n_swaps >= 2 * cc.n_rounds          # >=2x fewer swaps
    assert cc.bytes_tx == seed_cm.bytes_tx             # no byte increase
    model = costmodel.relu_many_cost([(E, k - m) for E, k, m in specs])
    assert cc.n_rounds == model.rounds
    assert cc.bytes_tx == model.bytes_tx


def test_identity_layer_costs_nothing():
    """Width-0 (k == m) culled layers: zero rounds, zero bytes, identity."""
    assert HBLayer(k=13, m=13).is_identity
    assert gmw.n_rounds(0) == 0
    assert costmodel.relu_cost(1024, 0).bytes_tx == 0
    assert costmodel.relu_cost(1024, 0).rounds == 0
    X = _shared(32, seed=9)
    cm = comm_lib.CountingComm()
    outs = gmw.relu_many([jax.random.PRNGKey(0)], [X], [None], cm,
                         [(13, 13)])
    np.testing.assert_array_equal(ring.to_uint64_np(outs[0]),
                                  ring.to_uint64_np(X))
    assert cm.n_swaps == 0


def test_plan_cost_and_estimate_match_counting_comm():
    """Plan.cost() equals the measured CountingComm rounds/bytes of a full
    compiled private forward, and Plan.estimate() is exactly
    latency_model over that measured cost (LAN/WAN presets)."""
    import jax.numpy as jnp

    from repro import api
    from repro.configs import RESNET_SMOKE
    from repro.core import MPCTensor
    from repro.core.hummingbird import HBConfig, HBLayer
    from repro.models import resnet

    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)
    x = jnp.zeros((1, 3, 8, 8))

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

    plan = api.trace_plan(afn, params, x.shape)
    hb = HBConfig(tuple([HBLayer(k=21, m=13)] * (plan.n_groups - 1)
                        + [HBLayer(k=13, m=13)]), plan.group_elements)
    plan = plan.with_hb(hb)

    cm = comm_lib.CountingComm()
    model = api.compile(afn, params, RESNET_SMOKE, plan,
                        api.Session(comm=cm))
    model(MPCTensor.from_plain(jax.random.PRNGKey(1), x))

    assert cm.n_swaps == plan.cost().rounds
    assert cm.bytes_tx == plan.cost().bytes_tx
    measured = costmodel.CommCost(cm.bytes_tx, cm.n_swaps, {})
    for net in (api.LAN, api.WAN):
        want = costmodel.latency_model(measured, net.bandwidth_bps, net.rtt_s)
        assert plan.estimate(network=net) == want
        assert plan.estimate(net.bandwidth_bps, net.rtt_s) == want


def test_relu_many_cost_mixed_widths():
    specs = [(100, 64), (200, 8), (50, 0)]
    fused = costmodel.relu_many_cost(specs)
    serial = costmodel.CommCost.zero()
    for n, w in specs:
        serial = serial + costmodel.relu_cost(n, w)
    assert fused.bytes_tx == serial.bytes_tx
    assert fused.rounds == max(costmodel.relu_cost(n, w).rounds
                               for n, w in specs)
    assert fused.rounds < serial.rounds
