"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768, n_experts=8,
    top_k=2, act="silu", gated_mlp=True, sliding_window=4096,
    sub_quadratic=True,
)
