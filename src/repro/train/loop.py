"""Fault-tolerant training loop.

- checkpoint/restart: resumes from the last committed step; the data
  pipeline is deterministic-by-step so no batch is replayed or skipped.
- async checkpointing overlaps serialization with compute.
- straggler watchdog: per-step wall-clock EWMA; a step slower than
  `straggler_factor` x the EWMA is logged and counted — in a multi-host
  deployment this signal triggers the elastic re-shard path (drop the slow
  host, restore the last checkpoint onto the smaller mesh; exercised by
  tests/test_fault_tolerance.py via mesh-to-mesh restore).
- elastic restore: checkpoints re-shard onto a different mesh on load.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.configs.base import ArchConfig
from repro.launch import train as train_lib
from repro.runtime.watchdog import StragglerWatchdog
from repro.train import optimizer as opt_lib


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    straggler_factor: float = 3.0
    async_ckpt: bool = True
    seed: int = 0            # init key when the caller passes no key/state


@dataclasses.dataclass
class LoopReport:
    final_step: int
    losses: List[float]
    resumed_from: Optional[int]
    straggler_steps: List[int]
    step_time_ewma: float


def run(cfg: ArchConfig, pipeline, loop_cfg: LoopConfig,
        optimizer=None, state: Optional[train_lib.TrainState] = None,
        key=None, hooks: Optional[Dict[str, Callable]] = None) -> LoopReport:
    optimizer = optimizer or opt_lib.AdamW()
    hooks = hooks or {}
    key = key if key is not None else jax.random.PRNGKey(loop_cfg.seed)

    resumed_from = None
    if state is None:
        state = train_lib.init_state(key, cfg, optimizer)
        if loop_cfg.ckpt_dir:
            last = store.latest_step(loop_cfg.ckpt_dir)
            if last is not None:
                state, manifest = store.restore(loop_cfg.ckpt_dir, state,
                                                step=last)
                resumed_from = last

    step_fn = jax.jit(train_lib.make_train_step(cfg, optimizer),
                      donate_argnums=(0,))
    ckpt = (store.AsyncCheckpointer(loop_cfg.ckpt_dir)
            if (loop_cfg.ckpt_dir and loop_cfg.async_ckpt) else None)

    losses: List[float] = []
    watchdog = StragglerWatchdog(factor=loop_cfg.straggler_factor)
    start = int(state.step)
    for step in range(start, loop_cfg.total_steps):
        t0 = time.time()  # includes data fetch: stalls there are stragglers too
        batch = pipeline.batch_at(step)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if step != start:  # first step includes compilation; never observed
            watchdog.observe(step, dt,
                             on_straggler=hooks.get("on_straggler"))
        losses.append(loss)
        if "on_step" in hooks:
            hooks["on_step"](step, loss)
        if loop_cfg.ckpt_dir and (step + 1) % loop_cfg.ckpt_every == 0:
            extra = {"loss": loss}
            if ckpt is not None:
                ckpt.save(step + 1, state, extra)
            else:
                store.save(loop_cfg.ckpt_dir, step + 1, state, extra)
        if "fail_at" in hooks and hooks["fail_at"] == step:
            raise RuntimeError(f"injected failure at step {step}")
    if ckpt is not None:
        ckpt.wait()
    if loop_cfg.ckpt_dir:
        store.save(loop_cfg.ckpt_dir, loop_cfg.total_steps, state,
                   {"final": True})
    return LoopReport(loop_cfg.total_steps, losses, resumed_from,
                      watchdog.stragglers, watchdog.ewma or 0.0)


def elastic_restore(ckpt_dir: str, cfg: ArchConfig, optimizer, mesh,
                    mode: str = "train"):
    """Restore the latest checkpoint onto a (possibly different) mesh."""
    from repro.runtime import sharding as sh

    template = jax.eval_shape(
        lambda k: train_lib.init_state(k, cfg, optimizer),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    param_sh = sh.param_shardings(template.params, mesh, mode, cfg)
    opt_sh = sh.param_shardings(template.opt_state, mesh, mode, cfg)
    shardings = train_lib.TrainState(params=param_sh, opt_state=opt_sh,
                                     step=sh.replicated(mesh))
    state, manifest = store.restore(ckpt_dir, template, shardings=shardings)
    return state, manifest
