"""Trusted-third-party Beaver triple provider.

The paper's evaluation (§5.1) assumes triples are generated offline by a TTP
(or stored pre-generated), so triple generation is excluded from
communication/latency accounting.  We generate them deterministically from a
PRG key; shares carry the leading party dimension so they can be fed into
both the sim backend and (party-sharded) into the mesh backend.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro import errors

from . import ring, ring_linalg, schedule as schedule_lib, shares
from .schedule import n_levels  # noqa: F401  (canonical home: core.schedule)

_U32 = jnp.uint32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ArithTriple:
    """Additive shares of (a, b, c = a*b) on Z/2^64, party dim leading."""

    a: ring.Ring64
    b: ring.Ring64
    c: ring.Ring64

    def tree_flatten(self):
        return (self.a, self.b, self.c), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BinTriple:
    """XOR shares of packed-word (a, b, c = a & b), party dim leading."""

    a: jax.Array
    b: jax.Array
    c: jax.Array

    def tree_flatten(self):
        return (self.a, self.b, self.c), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def gen_arith(key, shape, n_parties: int = 2) -> ArithTriple:
    ka, kb, ksa, ksb, ksc = jax.random.split(key, 5)
    a = ring.uniform(ka, shape)
    b = ring.uniform(kb, shape)
    c = ring.mul(a, b)
    return ArithTriple(
        shares.share(ksa, a, n_parties),
        shares.share(ksb, b, n_parties),
        shares.share(ksc, c, n_parties),
    )


def gen_matmul(key, x_shape, y_shape, n_parties: int = 2) -> ArithTriple:
    """Matrix Beaver triple (A, B, C = A @ B mod 2^64) for a secret-by-
    secret matmul of operand shapes ``x_shape @ y_shape`` (batch dims
    aligned, contraction on the trailing pair).  Consumed by
    ``gmw.beaver_matmul`` / ``gmw.products_many``."""
    ka, kb, ksa, ksb, ksc = jax.random.split(key, 5)
    a = ring.uniform(ka, tuple(x_shape))
    b = ring.uniform(kb, tuple(y_shape))
    c = ring_linalg.matmul_ring(a, b)
    return ArithTriple(
        shares.share(ksa, a, n_parties),
        shares.share(ksb, b, n_parties),
        shares.share(ksc, c, n_parties),
    )


def gen_bin(key, shape, n_parties: int = 2) -> BinTriple:
    ka, kb, ksa, ksb, ksc = jax.random.split(key, 5)
    a = jax.random.bits(ka, shape, dtype=_U32)
    b = jax.random.bits(kb, shape, dtype=_U32)
    c = a & b
    return BinTriple(
        shares.xor_share_packed(ksa, a, n_parties),
        shares.xor_share_packed(ksb, b, n_parties),
        shares.xor_share_packed(ksc, c, n_parties),
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ReluTriples:
    """Everything one approximate-ReLU evaluation consumes, pre-generated.

    For E elements and a w-bit reduced ring (W = ceil(E/32) packed words,
    L = ceil(log2(w)) Kogge-Stone levels):
      - bin_init:   (P, w, W) AND triple for the initial generate plane
      - bin_levels: (L, P, 2w, W) one batched AND triple per level
      - b2a:        (P, E) arithmetic triple for the sign-bit B2A
      - mult:       (P, E) arithmetic triple for the final x * DReLU(x)
    """

    bin_init: BinTriple
    bin_levels: BinTriple  # leading L axis on each member
    b2a: ArithTriple
    mult: ArithTriple

    def tree_flatten(self):
        return (self.bin_init, self.bin_levels, self.b2a, self.mult), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def gen_relu_triples(key, n_elements: int, w: int, n_parties: int = 2,
                     cone: bool = False) -> ReluTriples:
    """cone=True sizes the AND triples to the MSB-cone-pruned circuit
    (bin_levels becomes a per-level tuple — sizes are ragged)."""
    W = shares.packed_words(n_elements)
    L = n_levels(w)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if cone and w > 1:
        init_pos, level_sets = schedule_lib.cone_sets(w)
        bin_init = gen_bin(k1, (len(init_pos), W), n_parties)
        bin_levels = tuple(
            gen_bin(k, (2 * max(len(pos), 1), W), n_parties)
            for k, pos in zip(jax.random.split(k2, max(L, 1)), level_sets))
    else:
        bin_init = gen_bin(k1, (w, W), n_parties)
        levels = [gen_bin(k, (2 * w, W), n_parties)
                  for k in jax.random.split(k2, max(L, 1))]
        bin_levels = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *levels)
    b2a = gen_arith(k3, (n_elements,), n_parties)
    mult = gen_arith(k4, (n_elements,), n_parties)
    return ReluTriples(bin_init, bin_levels, b2a, mult)


def concat_relu_triples(bundles: Sequence[ReluTriples],
                        n_list: Sequence[int], w: int,
                        cone: bool = False) -> ReluTriples:
    """Merge per-stream ReluTriples (same ring width w) into one bundle
    for the element-wise concatenation of the streams.

    This is what lets ``gmw.relu_many`` auto-batch sibling streams of
    identical (n_elements, k, m): arithmetic members concatenate on the
    element axis; packed binary members are repacked at the *bit* level
    (unpack each stream's words to its n_i element bits, concatenate,
    pack) because word boundaries shift when n_i is not a multiple of 32.
    Per-bit (a, b, c = a & b) relations and the XOR share split are
    positional, so the merged words are valid triples for the combined
    vector; tail padding bits pack to the trivially-valid all-zero triple.
    """
    if len(bundles) != len(n_list):
        raise ValueError(f"concat_relu_triples: {len(bundles)} bundles vs "
                         f"{len(n_list)} element counts")

    def cat_bin(members: Sequence[BinTriple]) -> BinTriple:
        def cat(field: str) -> jax.Array:
            bits = [shares.unpack_bits(getattr(t, field), n)
                    for t, n in zip(members, n_list)]
            return shares.pack_bits(jnp.concatenate(bits, axis=-1))
        return BinTriple(cat("a"), cat("b"), cat("c"))

    def cat_arith(members: Sequence[ArithTriple]) -> ArithTriple:
        def cat(field: str) -> ring.Ring64:
            parts = [getattr(t, field) for t in members]
            return ring.Ring64(
                jnp.concatenate([p.lo for p in parts], axis=-1),
                jnp.concatenate([p.hi for p in parts], axis=-1))
        return ArithTriple(cat("a"), cat("b"), cat("c"))

    if cone and w > 1:        # ragged per-level tuples, merged level-wise
        bin_levels = tuple(
            cat_bin([b.bin_levels[lvl] for b in bundles])
            for lvl in range(len(bundles[0].bin_levels)))
    else:                     # dense: (L, P, 2w, W) stacked — leading L rides
        bin_levels = cat_bin([b.bin_levels for b in bundles])
    return ReluTriples(cat_bin([b.bin_init for b in bundles]), bin_levels,
                       cat_arith([b.b2a for b in bundles]),
                       cat_arith([b.mult for b in bundles]))


def shard_relu_triples(bundle: "ReluTriples", data_index: int,
                       n_shards: int) -> "ReluTriples":
    """One data shard's element-axis slice of a ReluTriples bundle.

    The inverse direction of ``concat_relu_triples``: the party dimension
    is untouched, arithmetic members slice the element axis directly, and
    packed binary members are split at the *bit* level (unpack each plane
    to its element bits, slice, repack) because word boundaries shift when
    the per-shard element count is not a multiple of 32.  Per-bit
    (a, b, c = a & b) relations and the XOR share split are positional, so
    each shard's words are valid triples for its element slice — this is
    what lets the mesh-native serve path shard the request batch over a
    data axis inside ``shard_map`` (the ROADMAP data-axis item): shard i
    of n runs the protocol on batch rows [i*B/n, (i+1)*B/n) with exactly
    these triples, reveal-identical to the unsharded replay.
    """
    E = bundle.b2a.a.lo.shape[-1]
    if E % n_shards:
        raise ValueError(
            f"shard_relu_triples: {E} elements not divisible by "
            f"{n_shards} data shards")
    per = E // n_shards
    lo_el, hi_el = data_index * per, (data_index + 1) * per

    def sl_bin(t: BinTriple) -> BinTriple:
        def f(words: jax.Array) -> jax.Array:
            bits = shares.unpack_bits(words, E)
            return shares.pack_bits(bits[..., lo_el:hi_el])
        return BinTriple(f(t.a), f(t.b), f(t.c))

    def sl_arith(t: ArithTriple) -> ArithTriple:
        def f(r: ring.Ring64) -> ring.Ring64:
            return ring.Ring64(r.lo[..., lo_el:hi_el], r.hi[..., lo_el:hi_el])
        return ArithTriple(f(t.a), f(t.b), f(t.c))

    if isinstance(bundle.bin_levels, BinTriple):     # dense: (L, P, 2w, W)
        bin_levels = sl_bin(bundle.bin_levels)
    else:                                            # cone: ragged per level
        bin_levels = tuple(sl_bin(t) for t in bundle.bin_levels)
    return ReluTriples(sl_bin(bundle.bin_init), bin_levels,
                       sl_arith(bundle.b2a), sl_arith(bundle.mult))


def shard_pool(pool: Sequence[Optional["ReluTriples"]],
               n_shards: int) -> List[Optional["ReluTriples"]]:
    """Stack per-data-shard slices of every bundle on a NEW leading axis.

    The result has the same pool structure, but each leaf carries a
    leading ``n_shards`` dimension holding that shard's element slice —
    exactly what ``PrivateModel.serve_step(mesh, data_axis=...)`` wants as
    its ``triples`` input: the shard_map places the data axis on that
    leading dim (``pool_party_specs(..., data_axis=...)``), so each data
    shard pops its own bit-level slice while the party dim stays where the
    structural derivation says it is.
    """

    def stack(bundle):
        if bundle is None:
            return None
        slices = [shard_relu_triples(bundle, i, n_shards)
                  for i in range(n_shards)]
        return jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *slices)

    return [stack(b) for b in pool]


def pool_party_specs(pool: Sequence[Optional["ReluTriples"]],
                     party_axis: str = "party",
                     data_axis: Optional[str] = None) -> List:
    """Party-dim ``PartitionSpec`` pytree for an offline triple pool.

    The party dimension's position is fixed by each member's *structure*
    (never guessed from pytree paths or ``shape[dim] == 2``): leading for
    ``bin_init``, the arithmetic members and cone-mode per-level bin
    triples; second (behind the stacked L axis) for dense ``bin_levels``.
    The result mirrors the pool's pytree structure with one PartitionSpec
    per leaf, so it drops straight into ``shard_map`` ``in_specs`` or maps
    to ``NamedSharding``s for jit input specs (see
    ``launch.serve.mpc_input_specs``).

    With ``data_axis``, the pool is the *data-sharded* layout produced by
    ``shard_pool``: every leaf gained a leading data-shard dimension, so
    the data axis lands on dim 0 and the structural party positions shift
    one to the right.
    """
    from jax.sharding import PartitionSpec

    off = 0 if data_axis is None else 1

    def at(party_dim: int):
        def spec(leaf):
            s = [None] * len(leaf.shape)
            s[party_dim + off] = party_axis
            if data_axis is not None:
                s[0] = data_axis
            return PartitionSpec(*s)
        return lambda tree: jax.tree_util.tree_map(spec, tree)

    def bundle_specs(bundle):
        if bundle is None:               # culled / empty call: no triples
            return None
        if isinstance(bundle.bin_levels, BinTriple):
            levels = at(1)(bundle.bin_levels)       # dense: (L, P, 2w, W)
        else:                                       # cone: ragged per level
            levels = tuple(at(0)(t) for t in bundle.bin_levels)
        return ReluTriples(at(0)(bundle.bin_init), levels,
                           at(0)(bundle.b2a), at(0)(bundle.mult))

    return [bundle_specs(b) for b in pool]


# ---------------------------------------------------------------------------
# Triple providers: who supplies the ReluTriples each protocol call consumes
# ---------------------------------------------------------------------------

def gen_plan_triples(key, specs: Sequence[Tuple[int, int]],
                     cone: bool = False) -> List[Optional[ReluTriples]]:
    """One ReluTriples bundle per (n_elements, width) spec, in order.

    Culled (width 0) and empty (n_elements 0) specs consume no triples and
    map to None.  This is the offline-TTP bulk generator behind
    ``Plan.triple_specs()`` and the old ``models.resnet.gen_mpc_triples``.
    """
    keys = jax.random.split(key, max(len(specs), 1))
    return [None if w == 0 or n == 0 else gen_relu_triples(k, n, w, cone=cone)
            for k, (n, w) in zip(keys, specs)]


def slice_party_bundle(bundle: Optional["ReluTriples"],
                       party: int) -> Optional["ReluTriples"]:
    """One party's rows of a full 2-party ``ReluTriples`` bundle.

    The party dimension's position is derived structurally, exactly as in
    ``pool_party_specs`` (leading for ``bin_init``/arith/cone levels, dim
    1 for dense ``bin_levels``); the slice keeps the dimension with size
    1, matching the local layout of a per-process transport backend
    (``repro.transport.SocketComm``) and of a size-2 mesh axis shard.
    Generate the full bundle from a key both parties share, slice to your
    own index, and the two processes hold a consistent triple — the
    socket-deployment analogue of the mesh path's presharded pool inputs.
    """
    if bundle is None:
        return None

    def at(party_dim: int):
        def f(leaf):
            idx = [slice(None)] * leaf.ndim
            idx[party_dim] = slice(party, party + 1)
            return leaf[tuple(idx)]
        return lambda tree: jax.tree_util.tree_map(f, tree)

    if isinstance(bundle.bin_levels, BinTriple):     # dense: (L, P, 2w, W)
        levels = at(1)(bundle.bin_levels)
    else:                                            # cone: ragged per level
        levels = tuple(at(0)(t) for t in bundle.bin_levels)
    return ReluTriples(at(0)(bundle.bin_init), levels,
                       at(0)(bundle.b2a), at(0)(bundle.mult))


def slice_party_pool(pool: Sequence[Optional["ReluTriples"]],
                     party: int) -> List[Optional["ReluTriples"]]:
    """Party-local slice of an offline pool (one bundle per ReLU call)."""
    return [slice_party_bundle(b, party) for b in pool]


class PartySlicedTTP:
    """One party's view of a *materialising* triple provider.

    Both parties construct the same base provider from a shared TTP key
    (e.g. ``StreamingTTP``); each wraps it with its own party index and
    keeps only its rows of every generated bundle — the two processes'
    slices are consistent triples by construction.  The base must
    materialise bundles: an inline provider returning None would make
    each process derive "triples" from its local 1-row layout, which is
    not a valid 2-party sharing, so that is rejected loudly.
    """

    def __init__(self, base, party: int):
        self.base = base
        self.party = int(party)

    def relu_triples(self, n_elements: int, width: int,
                     cone: bool = False) -> Optional["ReluTriples"]:
        if width == 0 or n_elements == 0:
            return None
        full = self.base.relu_triples(n_elements, width, cone=cone)
        if full is None:
            raise TypeError(
                "PartySlicedTTP needs a materialising base provider "
                "(StreamingTTP / TriplePool); an inline provider cannot "
                "supply one party's slice of a shared triple")
        return slice_party_bundle(full, self.party)

    def checkpoint(self):
        return self.base.checkpoint()

    def rollback(self, token) -> None:
        self.base.rollback(token)


@runtime_checkable
class TripleProvider(Protocol):
    """Where a Session's protocol calls get their Beaver triples.

    ``relu_triples`` is invoked once per ReLU call per stream, in call
    order; returning None means "derive the triples inline from the call's
    own PRNG key" (the sim-backend default, bit-identical to the historical
    ``triples=None`` path).  Width-0 (culled) and zero-element calls must
    return None — they consume nothing.

    Providers additionally expose ``checkpoint() -> token`` /
    ``rollback(token)`` so the serving engine can retry a faulted batch
    with the provider's stream position restored — the retried batch
    draws the SAME triples (bit-identical retry) and a tenant is never
    billed twice for one request.
    """

    def relu_triples(self, n_elements: int, width: int,
                     cone: bool = False) -> Optional[ReluTriples]:
        ...

    def checkpoint(self):
        ...

    def rollback(self, token) -> None:
        ...


class InlineTTP:
    """Sim-backend default: triples are derived inline from each protocol
    call's PRNG key (exactly the historical ``triples=None`` behaviour, so
    outputs stay bit-identical to the pre-Session call sites)."""

    def relu_triples(self, n_elements: int, width: int,
                     cone: bool = False) -> None:
        return None

    def checkpoint(self) -> None:          # stateless: nothing to restore
        return None

    def rollback(self, token) -> None:
        pass


class StreamingTTP:
    """Per-request streaming TTP: each bundle is generated on demand from
    this provider's own PRNG stream at call time (no storage, but the
    triple material is independent of the protocol keys, as in a real
    deployment where the TTP streams triples to the parties).

    Example::

        session = api.Session(key=0,
                              provider=StreamingTTP(jax.random.PRNGKey(7)))
    """

    def __init__(self, key):
        self._key = key

    def relu_triples(self, n_elements: int, width: int,
                     cone: bool = False) -> Optional[ReluTriples]:
        if width == 0 or n_elements == 0:
            return None
        self._key, k = jax.random.split(self._key)
        return gen_relu_triples(k, n_elements, width, cone=cone)

    def checkpoint(self):
        return self._key

    def rollback(self, token) -> None:
        self._key = token


class TriplePool:
    """Precomputed pool consumed in call order (the mesh-serving path:
    bundles enter the jitted step as inputs).  ``bundles`` holds one entry
    per ReLU call per stream, call-major / stream-minor, with None for
    culled or empty calls — the layout ``gen_plan_triples`` emits.

    Example::

        pool = gen_plan_triples(key_ttp, plan.triple_specs())
        session = api.Session(provider=TriplePool(pool))
    """

    def __init__(self, bundles: Iterable[Optional[ReluTriples]]):
        self._bundles = list(bundles)
        self.consumed = 0

    def relu_triples(self, n_elements: int, width: int,
                     cone: bool = False) -> Optional[ReluTriples]:
        if self.consumed >= len(self._bundles):
            raise errors.TriplePoolExhausted(
                f"TriplePool exhausted after {self.consumed} ReLU calls — "
                "the pool must hold one bundle per ReLU call per stream "
                "(see Plan.triple_specs / beaver.gen_plan_triples)")
        tri = self._bundles[self.consumed]
        self.consumed += 1
        return tri

    def checkpoint(self) -> int:
        return self.consumed

    def rollback(self, token: int) -> None:
        self.consumed = token

    def shard(self, data_index: int, n_shards: int) -> "TriplePool":
        """Data shard ``data_index``'s pool: every not-yet-consumed bundle
        sliced on the element axis (``shard_relu_triples``; party dim
        untouched, bit-level split).  This pool is left untouched, so one
        call per shard index yields ``n_shards`` pools that together cover
        exactly the unsharded replay."""
        return TriplePool([
            None if b is None else shard_relu_triples(b, data_index, n_shards)
            for b in self._bundles[self.consumed:]])


# Canonical home is repro.errors (still a RuntimeError subclass, so every
# historical `except RuntimeError` / `pytest.raises` call site holds).
TripleBudgetExceeded = errors.TripleBudgetExceeded
TriplePoolExhausted = errors.TriplePoolExhausted


class MeteredProvider:
    """Per-tenant triple metering: wraps any ``TripleProvider``, counts
    what each ReLU call *requires* (bundles and DReLU elements — the
    offline-TTP material a real deployment would bill for), and optionally
    enforces an element budget.

    Width-0 (culled) and zero-element calls consume nothing, exactly as
    the providers themselves treat them.  The serving engine gives every
    tenant its own ``MeteredProvider`` so concurrent tenants sharing one
    micro-batch still have separately attributable (and cappable) triple
    consumption.

    Example::

        provider = MeteredProvider(InlineTTP(), budget_elements=10_000)
        provider.relu_triples(4096, 8)        # meters 4096 elements
        provider.consumed_elements            # -> 4096
    """

    def __init__(self, base: Optional[TripleProvider] = None,
                 budget_elements: Optional[int] = None):
        self.base = base if base is not None else InlineTTP()
        self.budget_elements = budget_elements
        self.consumed_elements = 0
        self.consumed_bundles = 0

    @property
    def remaining_elements(self) -> Optional[int]:
        if self.budget_elements is None:
            return None
        return max(0, self.budget_elements - self.consumed_elements)

    def relu_triples(self, n_elements: int, width: int,
                     cone: bool = False) -> Optional[ReluTriples]:
        if width == 0 or n_elements == 0:
            return self.base.relu_triples(n_elements, width, cone=cone)
        if (self.budget_elements is not None
                and self.consumed_elements + n_elements > self.budget_elements):
            raise TripleBudgetExceeded(
                f"triple budget exhausted: {self.consumed_elements} of "
                f"{self.budget_elements} elements consumed, next call needs "
                f"{n_elements}")
        self.consumed_bundles += 1
        self.consumed_elements += n_elements
        return self.base.relu_triples(n_elements, width, cone=cone)

    def checkpoint(self):
        """Meter counters + the base provider's own stream position, so a
        rolled-back retry re-draws identical triples and bills once."""
        base_ckpt = getattr(self.base, "checkpoint", lambda: None)()
        return (self.consumed_elements, self.consumed_bundles, base_ckpt)

    def rollback(self, token) -> None:
        self.consumed_elements, self.consumed_bundles, base_ckpt = token
        rollback = getattr(self.base, "rollback", None)
        if rollback is not None:
            rollback(base_ckpt)


class EagerTTP(TriplePool):
    """Eager offline TTP: pre-generates the whole pool for ``requests``
    sequential replays of a plan's triple specs, each replay serving
    ``streams`` sibling streams, then hands bundles out in consumption
    order.  ``specs`` is ``Plan.triple_specs()`` (or any
    (n_elements, width) sequence).

    Layout matches the replay's pop order (see TriplePool): within one
    replay, call-major / stream-minor — every ReLU call pops one bundle
    per sibling stream before the next call; replays follow sequentially.

    Example::

        ttp = EagerTTP(key_ttp, plan.triple_specs(), requests=16)
        session = api.Session(key=0, provider=ttp)   # 16 replays covered
    """

    def __init__(self, key, specs: Sequence[Tuple[int, int]],
                 cone: bool = False, requests: int = 1, streams: int = 1):
        expanded = [s for s in specs for _ in range(streams)] * requests
        super().__init__(gen_plan_triples(key, expanded, cone=cone))
