"""Real two-party deployment: each party is its own OS process.

``SocketComm`` implements the ``Comm`` interface over one TCP connection
(local party dimension 1 — the per-process layout the mesh backend
already proved the protocol against), with a handshake that refuses
mismatched sessions/plans, typed timeout/crash failures the PR-6
resilience stack heals, payload-exact byte accounting against
``core.schedule``, and optional link shaping (injected RTT + bandwidth
cap) so LAN/WAN latency predictions are falsifiable against measured
wall-clock.

Compose via ``api.Session.connect`` (socket -> ResilientComm ->
JournaledComm), run a party process with ``python -m
repro.launch.party_host``, and serve requests through
``repro.serve.Frontend`` + ``EngineLink`` (leader) against a
``serve_follower`` loop (follower).  See ``docs/deployment.md``.
"""
from .socket import (HEADER, LinkShaper, SocketComm, free_port,
                     parse_address)
from .job import load_job, load_party, pool_treedef, resolve_config, \
    write_job
from .engine_link import EngineLink, serve_follower, tenant_provider_factory

__all__ = [
    "HEADER", "LinkShaper", "SocketComm", "free_port", "parse_address",
    "load_job", "load_party", "pool_treedef", "resolve_config",
    "write_job", "EngineLink", "serve_follower", "tenant_provider_factory",
]
