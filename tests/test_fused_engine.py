"""Round-fused engine regression: bit-identity vs the frozen seed protocol
(core/gmw_ref.py), relu_many vs per-tensor evaluation, ReLU culling, and
the round-fused multi-stream ResNet forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RESNET_SMOKE
from repro.core import (MPCTensor, beaver, comm as comm_lib, fixed, gmw,
                        gmw_ref, mpc_tensor, ring, shares)
from repro.core.hummingbird import HBConfig, HBLayer
from repro.models import resnet

CM = comm_lib.SimComm()


def _shared(E, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-3.9, 3.9, E).astype(np.float32)
    return shares.share(jax.random.PRNGKey(seed), fixed.encode_np(x))


@pytest.mark.parametrize("k,m,cone", [
    (64, 0, False),   # exact CrypTen baseline — the acceptance criterion
    (64, 0, True),
    (21, 13, False),
    (21, 13, True),
    (20, 14, False),
    (2, 1, False),    # w=1: no adder rounds at all
])
def test_relu_bit_identical_to_seed_reference(k, m, cone):
    """Same keys + triples => the fused engine's *shares* (not just the
    reconstruction) equal the frozen seed implementation bit for bit."""
    E = 128
    X = _shared(E, seed=1000 + k * 64 + m)
    tr = beaver.gen_relu_triples(jax.random.PRNGKey(11), E, k - m, cone=cone)
    r_new = gmw.relu(jax.random.PRNGKey(12), X, tr, CM, k=k, m=m, cone=cone)
    r_ref = gmw_ref.relu(jax.random.PRNGKey(12), X, tr, CM, k=k, m=m,
                         cone=cone)
    np.testing.assert_array_equal(ring.to_uint64_np(r_new),
                                  ring.to_uint64_np(r_ref))


def test_drelu_and_adder_bit_identical_to_seed():
    E, w = 96, 8
    X = _shared(E, seed=77)
    tr = beaver.gen_relu_triples(jax.random.PRNGKey(13), E, w)
    d_new = gmw.drelu(jax.random.PRNGKey(14), X, tr, CM, k=8, m=0)
    d_ref = gmw_ref.drelu(jax.random.PRNGKey(14), X, tr, CM, k=8, m=0)
    np.testing.assert_array_equal(ring.to_uint64_np(d_new),
                                  ring.to_uint64_np(d_ref))


def test_relu_many_matches_individual_tensors():
    """relu_many consumes keys exactly like per-tensor .relu, so outputs
    are bit-identical (shares included)."""
    rng = np.random.default_rng(3)
    shapes = [(24,), (4, 8), (2, 3, 5)]
    hbs = [HBLayer(), HBLayer(k=21, m=13), HBLayer(k=20, m=14)]
    tensors = [MPCTensor.from_plain(jax.random.PRNGKey(100 + i),
                                    jnp.asarray(rng.uniform(-3, 3, s),
                                                jnp.float32))
               for i, s in enumerate(shapes)]
    keys = [jax.random.PRNGKey(200 + i) for i in range(len(tensors))]
    fused = mpc_tensor.relu_many(keys, tensors, hbs=hbs)
    for t, key, hb, f in zip(tensors, keys, hbs, fused):
        single = t.relu(key, hb=hb)
        np.testing.assert_array_equal(ring.to_uint64_np(f.data),
                                      ring.to_uint64_np(single.data))
        # sanity: actually a ReLU
        np.testing.assert_allclose(
            f.reveal_np(), np.maximum(t.reveal_np(), 0), atol=2e-3)


def test_relu_identity_culling():
    """k == m degrades ReLU to the identity at zero communication."""
    x = np.array([-1.5, -0.25, 0.5, 2.0], np.float32)
    X = MPCTensor.from_plain(jax.random.PRNGKey(0), jnp.asarray(x))
    cm = comm_lib.CountingComm()
    out = X.relu(jax.random.PRNGKey(1), comm=cm, hb=HBLayer(k=13, m=13))
    assert out is X
    assert cm.n_swaps == 0
    # mixed identity + live groups through relu_many
    Y = MPCTensor.from_plain(jax.random.PRNGKey(2), jnp.asarray(x))
    outs = mpc_tensor.relu_many(
        [jax.random.PRNGKey(3), jax.random.PRNGKey(4)], [X, Y],
        hbs=[HBLayer(k=13, m=13), HBLayer(k=21, m=13)], comm=cm)
    assert outs[0] is X
    np.testing.assert_allclose(outs[1].reveal_np(), np.maximum(x, 0),
                               atol=2e-3)


def test_mpc_apply_bit_identical_to_prerefactor_shape():
    """mpc_apply (now routed through _mpc_forward) still matches the
    plaintext model — guards the list-of-streams refactor."""
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 3, 8, 8)) * 0.5
    ref_logits = resnet.apply(params, x, RESNET_SMOKE)
    X = MPCTensor.from_plain(jax.random.PRNGKey(2), x)
    out = resnet.mpc_apply(params, X, RESNET_SMOKE, jax.random.PRNGKey(3))
    np.testing.assert_allclose(out.reveal_np(), np.asarray(ref_logits),
                               atol=2e-2)


def test_mpc_apply_many_round_fused_streams():
    """Two sibling streams share ReLU rounds and both match plaintext."""
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)
    xs = [jax.random.normal(jax.random.PRNGKey(10 + i), (1, 3, 8, 8)) * 0.5
          for i in range(2)]
    Xs = [MPCTensor.from_plain(jax.random.PRNGKey(20 + i), x)
          for i, x in enumerate(xs)]
    cm = comm_lib.CountingComm()
    outs = resnet.mpc_apply_many(params, Xs, RESNET_SMOKE,
                                 jax.random.PRNGKey(5), comm=cm)
    for x, out in zip(xs, outs):
        ref_logits = resnet.apply(params, x, RESNET_SMOKE)
        np.testing.assert_allclose(out.reveal_np(), np.asarray(ref_logits),
                                   atol=2e-2)
    # fused: rounds independent of stream count (one coalesced exchange
    # per protocol round), so swaps == the single-stream count
    single_cm = comm_lib.CountingComm()
    resnet.mpc_apply(params, Xs[0], RESNET_SMOKE, jax.random.PRNGKey(5),
                     comm=single_cm)
    assert cm.n_swaps == single_cm.n_swaps


def test_mpc_apply_many_with_offline_triples():
    """Round-fused serving keeps the offline TTP split: pregenerated
    triples are consumed per ReLU call, one bundle per stream."""
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)
    xs = [jax.random.normal(jax.random.PRNGKey(30 + i), (1, 3, 8, 8)) * 0.5
          for i in range(2)]
    Xs = [MPCTensor.from_plain(jax.random.PRNGKey(40 + i), x)
          for i, x in enumerate(xs)]
    plan = resnet.relu_plan(params, RESNET_SMOKE, batch=1, hw=8)
    per_stream = [resnet.gen_mpc_triples(jax.random.PRNGKey(50 + i), plan,
                                         None, RESNET_SMOKE)
                  for i in range(2)]
    triples = [list(call) for call in zip(*per_stream)]  # per call, per stream
    outs = resnet.mpc_apply_many(params, Xs, RESNET_SMOKE,
                                 jax.random.PRNGKey(6), triples=triples)
    for x, out in zip(xs, outs):
        ref_logits = resnet.apply(params, x, RESNET_SMOKE)
        np.testing.assert_allclose(out.reveal_np(), np.asarray(ref_logits),
                                   atol=2e-2)


def test_culled_triples_plan():
    """gen_mpc_triples emits None for culled groups and mpc_apply runs."""
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)
    n_groups = resnet.n_relu_groups(RESNET_SMOKE)
    layers = [HBLayer(k=21, m=13) for _ in range(n_groups)]
    layers[-1] = HBLayer(k=13, m=13)          # cull the last group
    counts = resnet.relu_group_elements(params, RESNET_SMOKE)
    hb = HBConfig(tuple(layers), tuple(counts))
    plan = resnet.relu_plan(params, RESNET_SMOKE, batch=1, hw=8)
    triples = resnet.gen_mpc_triples(jax.random.PRNGKey(1), plan, hb,
                                     RESNET_SMOKE)
    assert any(t is None for t in triples)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 3, 8, 8)) * 0.5
    X = MPCTensor.from_plain(jax.random.PRNGKey(3), x)
    out = resnet.mpc_apply(params, X, RESNET_SMOKE, jax.random.PRNGKey(4),
                           hb=hb, triples=triples)
    assert out.shape == (1, RESNET_SMOKE.n_classes)
