"""Serving steps: LM prefill / decode + MPC private inference (the paper).

LM serving lowers ``prefill_step`` for prefill shapes and ``serve_step``
(one new token against a seq_len KV/SSM cache) for decode shapes, exactly
as the brief specifies.

MPC serving runs the GMW protocol with the *party dimension sharded over
the mesh* ("party" = pod).  The mesh-native path (``mesh=`` given) runs
the round-fused replay inside ``shard_map`` over the party axis, so every
fused protocol round lowers to exactly ONE collective-permute between the
two parties — the paper's communication reduction is directly countable
in the HLO (``runtime.hlo_analyzer.collective_census``).  The legacy path
(``mesh=None``) materialises the party dim (SimComm) and leaves the
splitting to XLA via the caller's in_shardings.  Beaver triples enter as
step inputs either way (offline TTP, matching the paper's evaluation
assumptions).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import api
from repro.configs.base import ArchConfig, ShapeConfig
from repro.configs.resnet import ResNetConfig
from repro.core import beaver
from repro.core.hummingbird import HBConfig
from repro.models import encdec, lm, resnet


def make_decode_step(cfg: ArchConfig):
    if cfg.family == "encdec":
        def step(params, token, cache, pos):
            return encdec.decode_step(params, token, cache, pos, cfg)
    else:
        def step(params, token, cache, pos):
            return lm.decode_step(params, token, cache, pos, cfg)
    return step


def make_prefill_step(cfg: ArchConfig, max_len: int):
    if cfg.family == "encdec":
        def step(params, src_embeds):
            batch = src_embeds.shape[0]
            return encdec.prefill(params, src_embeds, cfg, batch, max_len)
    else:
        def step(params, tokens, frontend_embeds=None):
            return lm.prefill(params, tokens, cfg, max_len,
                              frontend_embeds=frontend_embeds)
    return step


def greedy_decode_loop(params, cfg: ArchConfig, cache, first_token,
                       start_pos: int, n_steps: int):
    """Reference serving loop (used by examples + tests)."""
    step = make_decode_step(cfg)

    def body(carry, _):
        token, cache, pos = carry
        logits, cache = step(params, token, cache, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(token.dtype)[:, None]
        return (nxt, cache, pos + 1), nxt[:, 0]

    (_, cache, _), tokens = jax.lax.scan(
        body, (first_token, cache, jnp.asarray(start_pos, jnp.int32)),
        None, length=n_steps)
    return tokens.T, cache


# ---------------------------------------------------------------------------
# MPC private inference (ResNet, the paper's workload)
# ---------------------------------------------------------------------------

def make_mpc_serve_step(rcfg: ResNetConfig, hb: Optional[HBConfig],
                        cone: bool = False, mesh=None,
                        party_axis: str = "party",
                        data_axis: Optional[str] = None):
    """Returns step(params, lo, hi, triples, key) -> (lo, hi) logits shares.

    lo/hi: Ring64 limbs of the input shares, shape (2, B, 3, H, W).

    Thin wrapper over ``repro.api``: the plan replay and triple pool come
    from ``PrivateModel.serve_step``.  With ``mesh=None`` the party dim is
    materialised (SimComm) and the caller's in_shardings decide how XLA
    splits each exchange; with a mesh carrying a party axis the replay is
    mesh-native — it runs inside ``shard_map`` over the party axis and
    every fused protocol round lowers to exactly one collective-permute
    (see ``PrivateModel.serve_step``).  ``data_axis`` additionally shards
    the request batch over that mesh axis; ``triples`` must then be the
    data-sharded pool from ``beaver.shard_pool(pool,
    mesh.shape[data_axis])``.
    """
    model = api.compile(None, None, rcfg,
                        api.Plan.from_hb(resnet.hb_or_exact(hb, rcfg),
                                         cone=cone, name=rcfg.name),
                        api.Session())
    return model.serve_step(mesh, party_axis=party_axis, data_axis=data_axis)


def make_inference_engine(params, rcfg: ResNetConfig,
                          hb: Optional[HBConfig] = None, *,
                          example_batch: int = 2, cone: bool = False,
                          session=None, policy=None, **engine_kw):
    """Request-level serving engine over a ResNet config (the paper's
    workload) — see ``repro.serve.InferenceEngine``.

    Traces the plan at ``example_batch`` (other request shapes are traced
    on demand into the engine's plan cache) and binds the HummingBird
    assignment ``hb`` (exact 64-bit when None).

    Example::

        engine = make_inference_engine(params, RESNET_SMOKE, hb)
        fut = engine.submit("tenant-a", X)
        logits = fut.result().reveal()
    """
    from repro.serve import InferenceEngine

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, rcfg, relu_fn=relu_fn)

    plan = resnet.trace(params, rcfg, example_batch, cone=cone)
    if hb is not None:
        plan = plan.with_hb(HBConfig(hb.layers, plan.group_elements))
    return InferenceEngine(afn, params, rcfg, plan, session, policy=policy,
                           **engine_kw)


def _triple_pool_shardings(pool, mesh, party_axis: str):
    """Party-dim ``NamedSharding`` specs for an offline triple pool.

    The party-dim placement comes from ``beaver.pool_party_specs`` — the
    structural derivation (leading for ``bin_init``/arith/cone levels,
    second for dense ``bin_levels``) shared with the mesh-native
    ``serve_step``'s ``shard_map`` in_specs, so jit input shardings and
    the shard_map replay can never disagree.  Nothing here guesses from
    pytree-path strings or from ``shape[dim] == 2`` — a 2-element group
    or a 2-wide plane axis can no longer be mistaken for the party dim
    (the historical bug this replaces).
    """
    specs = beaver.pool_party_specs(pool, party_axis)

    def shard(leaf, spec):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(shard, pool, specs)


def mpc_input_specs(rcfg: ResNetConfig, batch: int, mesh,
                    hb: Optional[HBConfig], cone: bool = False):
    """ShapeDtypeStructs for the MPC dry-run (no allocation)."""
    party_axis = "party" if "party" in mesh.axis_names else "pod"
    data_axis = "data"
    hw = rcfg.in_hw
    share_sh = NamedSharding(mesh, P(party_axis, data_axis))
    lo = jax.ShapeDtypeStruct((2, batch, 3, hw, hw), jnp.uint32, sharding=share_sh)
    hi = jax.ShapeDtypeStruct((2, batch, 3, hw, hw), jnp.uint32, sharding=share_sh)

    params = jax.eval_shape(lambda k: resnet.init(k, rcfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    rep = NamedSharding(mesh, P())
    params = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=rep), params)

    plan = resnet.trace(params, rcfg, batch,
                        hb=resnet.hb_or_exact(hb, rcfg), cone=cone)
    triples = jax.eval_shape(
        lambda k: beaver.gen_plan_triples(k, plan.triple_specs(), cone=cone),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    triples = _triple_pool_shardings(triples, mesh, party_axis)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=rep)
    return params, lo, hi, triples, key
