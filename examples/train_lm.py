"""Train a small LM for a few hundred steps with the full substrate:
AdamW + schedule, deterministic data, checkpoint/restart, straggler
watchdog.  Interrupt it (Ctrl-C) and re-run: it resumes where it stopped.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --arch qwen1.5-0.5b
"""
import argparse
import dataclasses

from repro.configs import get
from repro.data import TokenPipeline
from repro.train import loop as loop_lib, optimizer as opt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = get(args.arch + "-smoke")
    cfg = dataclasses.replace(
        cfg, n_layers=args.layers, d_model=args.d_model,
        n_heads=8, n_kv_heads=8, head_dim=32,
        d_ff=4 * args.d_model if cfg.d_ff else 0, vocab=1024,
        attn_chunk_q=32, attn_chunk_k=32)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    opt = opt_lib.AdamW(schedule=opt_lib.Schedule(
        peak_lr=3e-3, warmup_steps=20, decay_steps=args.steps))
    lc = loop_lib.LoopConfig(total_steps=args.steps, ckpt_every=50,
                             ckpt_dir=args.ckpt)

    def on_step(step, loss):
        if step % 20 == 0:
            print(f"step {step:5d}  loss {loss:.4f}")

    rep = loop_lib.run(cfg, pipe, lc, optimizer=opt,
                       hooks={"on_step": on_step})
    print(f"done: {rep.final_step} steps"
          + (f" (resumed from {rep.resumed_from})" if rep.resumed_from else ""))
    print(f"loss {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f}; "
          f"stragglers flagged: {rep.straggler_steps}")


if __name__ == "__main__":
    main()
