"""End-to-end behaviour: the full HummingBird pipeline on one model —
train -> eco/budget search -> finetune -> MPC serve, plus the cost model's
paper-level claims and a tiny LM training run whose loss decreases."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RESNET_SMOKE, get
from repro.core import MPCTensor, costmodel
from repro.core.hummingbird import HBConfig
from repro.data import TokenPipeline
from repro.models import resnet
from repro.search import finetune as ft, search_budget, search_eco
from repro.search.simulator import evaluate_accuracy
from repro.train import loop as loop_lib, optimizer as opt_lib


@pytest.fixture(scope="module")
def trained_resnet():
    key = jax.random.PRNGKey(0)
    params = resnet.init(key, RESNET_SMOKE)
    xs = jax.random.normal(jax.random.PRNGKey(1), (320, 3, 16, 16))
    ys = (xs[:, 0, :8, :8].mean((1, 2)) > 0).astype(jnp.int32)

    def afn(p, x, relu_fn=None):
        return resnet.apply(p, x, RESNET_SMOKE, relu_fn=relu_fn)

    groups = resnet.relu_group_elements(params, RESNET_SMOKE)
    params, _ = ft.finetune(afn, params, xs[:256], ys[:256],
                            HBConfig.exact(groups), jax.random.PRNGKey(5),
                            epochs=4, batch=64, lr=3e-3)
    return afn, params, xs[256:], ys[256:], groups


def test_full_hummingbird_pipeline(trained_resnet):
    """Search a config, verify the REAL MPC protocol reproduces the
    simulator's prediction on actual secret shares."""
    afn, params, xs, ys, groups = trained_resnet
    res = search_eco(afn, params, xs, ys, groups, jax.random.PRNGKey(2))
    assert res.accuracy == res.baseline_accuracy  # eco: zero error

    # run the real GMW protocol with the found config on a few samples
    X = MPCTensor.from_plain(jax.random.PRNGKey(3), xs[:2])
    out = resnet.mpc_apply(params, X, RESNET_SMOKE, jax.random.PRNGKey(4),
                           hb=res.config)
    plain = afn(params, xs[:2])
    got = np.argmax(out.reveal_np(), -1)
    want = np.argmax(np.asarray(plain), -1)
    np.testing.assert_array_equal(got, want)

    # communication actually shrank per the cost model
    r = costmodel.reduction_factors(res.config)
    assert r["bytes_reduction"] > 1.5


def test_budget_pipeline_with_finetune(trained_resnet):
    afn, params, xs, ys, groups = trained_resnet
    res = search_budget(afn, params, xs, ys, groups, jax.random.PRNGKey(6),
                        budget=8 / 64, bit_choices=(6, 8))
    assert res.config.meets_budget(8 / 64)
    p2, losses = ft.finetune(afn, params, xs, ys, res.config,
                             jax.random.PRNGKey(7), epochs=1, batch=32)
    post = evaluate_accuracy(afn, p2, xs, ys, res.config, jax.random.PRNGKey(8))
    assert post >= res.accuracy - 0.15  # finetune never catastrophically hurts
    r = costmodel.reduction_factors(res.config)
    assert r["bytes_reduction"] > 2.0  # paper Fig 11 floor


def test_lm_training_loss_decreases():
    cfg = dataclasses.replace(get("qwen1.5-0.5b-smoke"), n_layers=2)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=64, batch=8)
    lc = loop_lib.LoopConfig(total_steps=30, ckpt_dir=None)
    opt = opt_lib.AdamW(schedule=opt_lib.Schedule(peak_lr=3e-3,
                                                  warmup_steps=5,
                                                  decay_steps=0))
    rep = loop_lib.run(cfg, pipe, lc, optimizer=opt)
    first = np.mean(rep.losses[:5])
    last = np.mean(rep.losses[-5:])
    assert last < first - 0.2, (first, last)


def test_microbatched_step_matches_plain():
    cfg = dataclasses.replace(get("qwen1.5-0.5b-smoke"), n_layers=2,
                              remat="none")
    from repro.launch import train as train_lib
    opt = opt_lib.SGD(schedule=opt_lib.Schedule(peak_lr=0.1, warmup_steps=0,
                                                decay_steps=0), momentum=0.0)
    state = train_lib.init_state(jax.random.PRNGKey(0), cfg, opt)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=32, batch=8)
    batch = pipe.batch_at(0)
    s1, m1 = train_lib.make_train_step(cfg, opt, n_microbatches=1)(state, batch)
    s2, m2 = train_lib.make_train_step(cfg, opt, n_microbatches=4)(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)
