"""Linear algebra on Z/2^64 with public integer weights.

mod-2^64 matmul via *balanced 8-bit plane decomposition*: shares become 8
signed int8 digit planes, public weights 5 digit planes; the product is a
sum of s8 x s8 -> s32 plane matmuls (MXU-native on TPU) recombined with
64-bit shifts and carries.  This file is the pure-jnp reference; the Pallas
kernel in repro/kernels/ring_matmul.py implements the same contraction with
explicit VMEM blocking.

int32 accumulation safety: |sum_s| <= pairs(s) * K * 128 * 128 with
pairs(s) <= 5, so K <= 2^31 / (5 * 2^14) = 26214 per chunk; larger K is
chunked and the partial results are added in the ring.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ring

_MAX_K = 16384  # safe chunk (power of two below the 26214 bound)


def _signed_to_ring64(s32: jax.Array) -> ring.Ring64:
    lo = s32.astype(jnp.uint32)
    hi = jnp.where(s32 < 0, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    return ring.Ring64(lo, hi)


def _matmul_chunk(x: ring.Ring64, w_i32: jax.Array) -> ring.Ring64:
    """x: Ring64 [..., M, K]; w: int32 [K, N] -> Ring64 [..., M, N]."""
    dx = ring.balanced_digits(x)               # (8, ..., M, K) int8
    dw = ring.balanced_digits_i32(w_i32)       # (5, K, N) int8
    # all plane products at int32 accumulation; drop s = i+j >= 8 (2^64 | shift)
    prods = jnp.einsum(
        "i...mk,jkn->ij...mn",
        dx.astype(jnp.int8), dw.astype(jnp.int8),
        preferred_element_type=jnp.int32,
    )
    out = ring.zeros(prods.shape[2:])
    for s in range(8):
        acc = None
        for i in range(8):
            j = s - i
            if 0 <= j < 5:
                p = prods[i, j]
                acc = p if acc is None else acc + p
        if acc is None:
            continue
        out = ring.add(out, ring.lshift(_signed_to_ring64(acc), 8 * s))
    return out


def matmul_pub(x: ring.Ring64, w_i32: jax.Array) -> ring.Ring64:
    """mod-2^64 matmul of ring values by public int32 weights.

    Linear over shares: applying this to each party's share yields valid
    shares of W @ x (additive homomorphism of the ring).
    """
    k = x.shape[-1]
    assert w_i32.shape[0] == k, (x.shape, w_i32.shape)
    if k <= _MAX_K:
        return _matmul_chunk(x, w_i32)
    out = None
    for start in range(0, k, _MAX_K):
        end = min(k, start + _MAX_K)
        part = _matmul_chunk(x[..., start:end], w_i32[start:end])
        out = part if out is None else ring.add(out, part)
    return out


# Secret x secret: both operands decompose to 8 planes, so pairs(s) <= 8
# and |sum_s| <= 8 * K * 128 * 128 -> K <= 2^31 / (8 * 2^14) = 16384; chunk
# one power of two below so the bound is strict even in the worst case.
_MAX_K_RING = 8192


def _matmul_ring_chunk(x: ring.Ring64, y: ring.Ring64) -> ring.Ring64:
    """x: Ring64 [..., M, K]; y: Ring64 [..., K, N] -> Ring64 [..., M, N]."""
    dx = ring.balanced_digits(x)               # (8, ..., M, K) int8
    dy = ring.balanced_digits(y)               # (8, ..., K, N) int8
    prods = jnp.einsum(
        "i...mk,j...kn->ij...mn",
        dx.astype(jnp.int8), dy.astype(jnp.int8),
        preferred_element_type=jnp.int32,
    )
    out = ring.zeros(prods.shape[2:])
    for s in range(8):
        acc = None
        for i in range(8):
            j = s - i
            if 0 <= j < 8:
                p = prods[i, j]
                acc = p if acc is None else acc + p
        if acc is None:
            continue
        out = ring.add(out, ring.lshift(_signed_to_ring64(acc), 8 * s))
    return out


def matmul_ring(x: ring.Ring64, y: ring.Ring64) -> ring.Ring64:
    """mod-2^64 matmul of two ring-valued tensors (batch dims aligned).

    The secret-by-secret counterpart of ``matmul_pub``: both operands are
    full 64-bit ring values, so each decomposes into 8 balanced digit
    planes and the product is the 8x8 plane contraction recombined with
    64-bit shifts.  This is NOT a protocol — it is the local modular
    arithmetic that Beaver-triple matmul reduces to (``gmw`` opens
    ``x - a`` / ``y - b`` and combines public-by-share products with this
    function).
    """
    k = x.shape[-1]
    assert y.shape[-2] == k, (x.shape, y.shape)
    if k <= _MAX_K_RING:
        return _matmul_ring_chunk(x, y)
    out = None
    for start in range(0, k, _MAX_K_RING):
        end = min(k, start + _MAX_K_RING)
        xs = ring.Ring64(x.lo[..., start:end], x.hi[..., start:end])
        ys = ring.Ring64(y.lo[..., start:end, :], y.hi[..., start:end, :])
        part = _matmul_ring_chunk(xs, ys)
        out = part if out is None else ring.add(out, part)
    return out


def im2col(x: ring.Ring64, kh: int, kw: int, stride: int = 1,
           padding: int = 0) -> ring.Ring64:
    """Ring64 [..., C, H, W] -> [..., OH*OW, C*kh*kw] patch matrix (local op)."""

    def _one(a: jax.Array) -> jax.Array:
        if padding:
            pad = [(0, 0)] * (a.ndim - 2) + [(padding, padding)] * 2
            a = jnp.pad(a, pad)
        h, w = a.shape[-2], a.shape[-1]
        oh = (h - kh) // stride + 1
        ow = (w - kw) // stride + 1
        cols = []
        for di in range(kh):
            for dj in range(kw):
                sl = a[..., di:di + stride * oh:stride, dj:dj + stride * ow:stride]
                cols.append(sl.reshape(a.shape[:-2] + (oh * ow,)))
        # (..., C, kh*kw, OH*OW) -> (..., OH*OW, C*kh*kw)
        stacked = jnp.stack(cols, axis=-2)
        moved = jnp.moveaxis(stacked, -1, -3)
        return moved.reshape(moved.shape[:-2] + (moved.shape[-2] * moved.shape[-1],))

    return ring.Ring64(_one(x.lo), _one(x.hi))


def conv2d_pub(x: ring.Ring64, w_i32: jax.Array, stride: int = 1,
               padding: int = 0) -> ring.Ring64:
    """Ring64 [..., C, H, W] conv by public int32 [Cout, C, kh, kw]."""
    cout, cin, kh, kw = w_i32.shape
    h, w = x.shape[-2], x.shape[-1]
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    patches = im2col(x, kh, kw, stride, padding)        # (..., OH*OW, C*kh*kw)
    wmat = w_i32.reshape(cout, cin * kh * kw).T          # (C*kh*kw, Cout)
    out = matmul_pub(patches, wmat)                      # (..., OH*OW, Cout)
    out = ring.Ring64(jnp.moveaxis(out.lo, -1, -2), jnp.moveaxis(out.hi, -1, -2))
    return out.reshape(out.shape[:-1] + (oh, ow))
