"""hbcheck static-analysis suite: per-rule lint fixtures, HLO taint-pass
units on hand-built programs, lock-discipline regression (including a
deliberately injected unguarded access), Plan.validate pre-flight, and
the canonical serve_step leakage census in a 2-device subprocess."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro import errors
from repro.analysis import lint, locks
from repro.analysis.taint import TaintAnalysis, census_summary
from repro.api.plan import Plan, ReluCall
from repro.core.hummingbird import HBConfig, HBLayer

ROOT = pathlib.Path(__file__).resolve().parent.parent

CORE = "src/repro/core/newmod.py"       # scoped like a protocol module
API = "src/repro/api/newmod.py"         # inside the reveal surface
TESTS = "tests/test_newmod.py"          # exempt from most rules


def _rules(findings):
    return [f.rule for f in findings]


def _lint(src, path):
    return lint.lint_source(textwrap.dedent(src), path)


# ---------------------------------------------------------------------------
# R001 raw exchange outside the comm seam
# ---------------------------------------------------------------------------

def test_r001_flags_raw_swap_outside_seam():
    src = """
    def f(comm, payload):
        return comm.swap(payload)
    """
    assert _rules(_lint(src, CORE)) == ["R001"]
    assert _rules(_lint(src, "src/repro/serve/engine.py")) == ["R001"]


def test_r001_allows_seam_and_tests_and_generator_send():
    src = """
    def f(comm, payload):
        return comm.swap(payload)
    """
    assert _lint(src, "src/repro/core/comm.py") == []
    assert _lint(src, "src/repro/core/gmw.py") == []
    assert _lint(src, TESTS) == []
    # drive()'s generator .send() is not a wire primitive
    assert _lint("""
    def drive(gen, comm):
        gen.send(None)
    """, CORE) == []


# ---------------------------------------------------------------------------
# R002 reveal surface
# ---------------------------------------------------------------------------

def test_r002_flags_reveal_outside_surface():
    src = """
    def f(x):
        return x.reveal()
    """
    assert _rules(_lint(src, CORE)) == ["R002"]


def test_r002_allows_api_serve_launch_and_share_types():
    src = """
    def f(x):
        return x.reveal_np()
    """
    for ok in (API, "src/repro/serve/frontend.py",
               "src/repro/launch/party_host.py",
               "src/repro/core/mpc_tensor.py", TESTS):
        assert _lint(src, ok) == [], ok


# ---------------------------------------------------------------------------
# R003 secret-dependent control flow
# ---------------------------------------------------------------------------

def test_r003_flags_branch_on_annotated_share():
    src = """
    def f(x: MPCTensor):
        if x:
            return 1
    """
    assert _rules(_lint(src, API)) == ["R003"]


def test_r003_flags_branch_on_constructed_share_and_while():
    src = """
    def f(key, v):
        x = MPCTensor(v)
        while x.data:
            pass
    """
    assert _rules(_lint(src, API)) == ["R003"]


def test_r003_allows_metadata_none_checks_and_reveal():
    src = """
    def f(x: MPCTensor):
        if x is None:
            return 0
        if x.shape[0] > 1:
            pass
        if isinstance(x, tuple):
            pass
        y = x.reveal()
        if y > 0:
            return 1
    """
    assert _lint(src, API) == []


def test_r003_reassignment_clears_taint():
    src = """
    def f(v):
        x = MPCTensor(v)
        x = 3
        if x:
            return 1
    """
    assert _lint(src, API) == []


# ---------------------------------------------------------------------------
# R004 PRNG discipline
# ---------------------------------------------------------------------------

def test_r004_flags_constant_seed_outside_tests():
    src = """
    import jax
    def f():
        return jax.random.PRNGKey(0)
    """
    assert _rules(_lint(src, CORE)) == ["R004"]
    assert _lint(src, TESTS) == []


def test_r004_allows_variable_seeds():
    src = """
    import jax
    def f(seed):
        return jax.random.PRNGKey(seed)
    """
    assert _lint(src, CORE) == []


def test_r004_suppression_comment():
    src = """
    import jax
    def f():
        return jax.random.PRNGKey(0)  # hbcheck: disable=R004
    """
    assert _lint(src, CORE) == []


# ---------------------------------------------------------------------------
# R005 ring dtype discipline
# ---------------------------------------------------------------------------

def test_r005_flags_float_and_division_in_ring_modules():
    src = """
    import jax.numpy as jnp
    def f(a, b):
        c = a.astype(jnp.float32)
        return c / b
    """
    assert _rules(_lint(src, "src/repro/core/ring.py")) == ["R005", "R005"]
    # same code outside the ring modules is not R005's business
    assert _lint(src, "src/repro/search/engine.py") == []


def test_r005_allows_integer_ring_ops():
    src = """
    import jax.numpy as jnp
    def f(a, b):
        c = a.astype(jnp.uint32)
        return (c // 2) + (b >> 1)
    """
    assert _lint(src, "src/repro/core/ring.py") == []


# ---------------------------------------------------------------------------
# R006 round-path determinism
# ---------------------------------------------------------------------------

def test_r006_flags_wall_clock_stdlib_random_and_set_iteration():
    src = """
    import os
    import random
    import time
    def f(groups):
        t = time.time()
        r = random.random()
        u = os.urandom(4)
        for g in {1, 2}:
            pass
        return t, r, u
    """
    assert _rules(_lint(src, "src/repro/core/schedule.py")) == [
        "R006", "R006", "R006", "R006"]
    # off the round path the same code is fine
    assert _lint(src, "src/repro/search/engine.py") == []


def test_r006_allows_monotonic_and_sorted_iteration():
    src = """
    import time
    def f(groups):
        t = time.monotonic()
        for g in sorted(groups):
            pass
        return t
    """
    assert _lint(src, "src/repro/core/comm.py") == []


def test_r006_covers_nn_approx_round_path():
    # the reduced-ring nonlinearity subsystem places relu_fn calls and
    # Beaver opens, so its modules sit on the round path
    src = """
    import time
    def f():
        return time.time()
    """
    for mod in ("src/repro/nn/approx/pwl.py",
                "src/repro/nn/approx/attention.py",
                "src/repro/nn/approx/bounds.py",
                "src/repro/nn/approx/__init__.py"):
        assert _rules(_lint(src, mod)) == ["R006"], mod
    # sibling nn modules stay off the round path
    assert _lint(src, "src/repro/nn/common.py") == []


def test_r002_r003_apply_inside_nn_approx():
    # nn/approx is NOT part of the reveal surface and gets no secret-branch
    # exemption: the generic rules must fire there unchanged
    assert _rules(_lint("""
    def f(x):
        return x.reveal()
    """, "src/repro/nn/approx/pwl.py")) == ["R002"]
    assert _rules(_lint("""
    def f(x: MPCTensor):
        if x:
            return 1
    """, "src/repro/nn/approx/attention.py")) == ["R003"]


# ---------------------------------------------------------------------------
# baseline machinery
# ---------------------------------------------------------------------------

def test_baseline_roundtrip_filters_findings(tmp_path):
    src = """
    def f(comm, p):
        return comm.swap(p)
    """
    findings = _lint(src, CORE)
    assert len(findings) == 1
    bl = tmp_path / "baseline.json"
    lint.save_baseline(bl, findings)
    baseline = lint.load_baseline(bl)
    assert all(f.key() in baseline for f in findings)
    assert lint.load_baseline(tmp_path / "missing.json") == set()


def test_repo_is_clean_of_lint_and_lock_findings():
    """The repo self-check: src + tests carry zero non-baselined
    protocol-safety findings (the CI hbcheck gate, minus the census)."""
    findings = lint.lint_paths([ROOT / "src", ROOT / "tests"], root=ROOT)
    findings += locks.check_paths(ROOT)
    baseline = lint.load_baseline(ROOT / "tools" / "hbcheck_baseline.json")
    new = [f for f in findings if f.key() not in baseline]
    assert new == [], "\n".join(str(f) for f in new)


# ---------------------------------------------------------------------------
# lock discipline
# ---------------------------------------------------------------------------

_LOCKY = textwrap.dedent("""
    import threading

    class InferenceEngine:
        def __init__(self):
            self._lock = threading.RLock()
            self._queue = []

        def ok(self):
            with self._lock:
                return len(self._queue)

        def bad(self):
            return len(self._queue)

        def _helper(self):
            self._queue.append(1)

        def caller(self):
            with self._lock:
                self._helper()

        def deferred(self):
            with self._lock:
                def peek():
                    return len(self._queue)
                return peek
""")


def test_lock_checker_flags_unguarded_and_deferred_access():
    findings = locks.check_lock_discipline(_LOCKY, "engine.py")
    methods = {f.message.split()[0] for f in findings}
    # bad() reads without the lock; the closure in deferred() may run
    # after the lock is released; _helper is lock-held via its call site
    assert methods == {"InferenceEngine.bad", "InferenceEngine.deferred"}


def test_lock_checker_real_engine_is_clean():
    src = (ROOT / "src" / "repro" / "serve" / "engine.py").read_text()
    assert locks.check_lock_discipline(src, "engine.py") == []


def test_lock_checker_regression_on_injected_unguarded_access():
    """Deliberately add an unguarded pump-state access to the real
    engine source: the checker must catch exactly the injection."""
    src = (ROOT / "src" / "repro" / "serve" / "engine.py").read_text()
    injected = src.replace(
        "    def stats(",
        "    def sneak_peek(self):\n"
        "        return len(self._queue)\n\n"
        "    def stats(", 1)
    assert injected != src
    findings = locks.check_lock_discipline(injected, "engine.py")
    assert len(findings) == 1
    assert "sneak_peek" in findings[0].message
    assert "_queue" in findings[0].message


def test_private_reach_flags_engine_internals():
    src = textwrap.dedent("""
        class Frontend:
            def peek(self):
                return len(self.engine._queue)

            def fine(self):
                return self.engine.pending
    """)
    findings = locks.check_private_reach(src, "frontend.py")
    assert len(findings) == 1 and "_queue" in findings[0].message


def test_private_reach_real_frontend_is_clean():
    src = (ROOT / "src" / "repro" / "serve" / "frontend.py").read_text()
    assert locks.check_private_reach(src, "frontend.py") == []


# ---------------------------------------------------------------------------
# taint pass on hand-built HLO
# ---------------------------------------------------------------------------

_HLO_BASIC = """
HloModule basic

ENTRY %main (p0: u32[4], p1: u32[4]) -> (u32[4], u32[8]) {
  %p0 = u32[4] parameter(0)
  %p1 = u32[4] parameter(1)
  %masked = u32[4] xor(%p0, %p1)
  %cp1 = u32[4] collective-permute(%masked), source_target_pairs={{0,1},{1,0}}
  %cat = u32[8] concatenate(%p0, %masked), dimensions={0}
  %cp2 = u32[8] collective-permute(%cat), source_target_pairs={{0,1},{1,0}}
  ROOT %t = (u32[4], u32[8]) tuple(%cp1, %cp2)
}
"""


def test_taint_masked_collective_is_safe_concat_is_not():
    recs = TaintAnalysis(_HLO_BASIC).census(secret_params=[0],
                                            mask_params=[1])
    assert [r.name for r in recs] == ["cp1", "cp2"]
    cp1, cp2 = recs
    assert cp1.secret and cp1.mask and not cp1.unsafe   # xor blinds
    assert cp2.unsafe    # packing a raw share next to it does NOT
    s = census_summary(_HLO_BASIC, [0], [1])
    assert s["collectives"] == 2 and s["unmasked_collectives"] == 1
    assert s["cross_check_ok"]


def test_taint_raw_secret_and_public_operands():
    raw = _HLO_BASIC.replace("collective-permute(%masked)",
                             "collective-permute(%p0)")
    s = census_summary(raw, [0], [1])
    assert s["unmasked_collectives"] == 2
    # no secret inputs at all -> everything public, nothing unsafe
    s = census_summary(_HLO_BASIC, [], [1])
    assert s["unmasked_collectives"] == 0
    assert s["public_collectives"] == 2
    # secret classified but mask input ignored -> both leak
    s = census_summary(_HLO_BASIC, [0], [])
    assert s["unmasked_collectives"] == 2


_HLO_FUSION = """
HloModule fused

%blind (a: u32[4], b: u32[4]) -> u32[4] {
  %a = u32[4] parameter(0)
  %b = u32[4] parameter(1)
  ROOT %x = u32[4] xor(%a, %b)
}

ENTRY %main (p0: u32[4], p1: u32[4]) -> u32[4] {
  %p0 = u32[4] parameter(0)
  %p1 = u32[4] parameter(1)
  %f = u32[4] fusion(%p0, %p1), kind=kLoop, calls=%blind
  ROOT %cp = u32[4] collective-permute(%f), source_target_pairs={{0,1},{1,0}}
}
"""


def test_taint_flows_through_fusion_calls():
    s = census_summary(_HLO_FUSION, [0], [1])
    assert s["collectives"] == 1 and s["unmasked_collectives"] == 0
    s = census_summary(_HLO_FUSION, [0], [])
    assert s["unmasked_collectives"] == 1


_HLO_WHILE = """
HloModule looped

%cond (tc: (u32[4])) -> pred[] {
  %tc = (u32[4]) parameter(0)
  ROOT %c = pred[] constant(true)
}

%body (tb: (u32[4])) -> (u32[4]) {
  %tb = (u32[4]) parameter(0)
  %g = u32[4] get-tuple-element(%tb), index=0
  %cp = u32[4] collective-permute(%g), source_target_pairs={{0,1},{1,0}}
  ROOT %r = (u32[4]) tuple(%cp)
}

ENTRY %main (p0: u32[4], p1: u32[4]) -> (u32[4]) {
  %p0 = u32[4] parameter(0)
  %p1 = u32[4] parameter(1)
  %m = u32[4] xor(%p0, %p1)
  %init = (u32[4]) tuple(%m)
  ROOT %w = (u32[4]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"3"}}
}
"""


def test_taint_while_body_scaled_by_trip_count():
    recs = TaintAnalysis(_HLO_WHILE).census(secret_params=[0],
                                            mask_params=[1])
    assert len(recs) == 1
    assert recs[0].count == 3 and not recs[0].unsafe
    s = census_summary(_HLO_WHILE, [0], [])
    assert s["collectives"] == 3 and s["unmasked_collectives"] == 3
    assert s["cross_check_ok"]


# ---------------------------------------------------------------------------
# Plan.validate pre-flight
# ---------------------------------------------------------------------------

def _valid_plan():
    hb = HBConfig((HBLayer(k=21, m=13),), (8,))
    return Plan(calls=(ReluCall(8, 0, (2, 4)),), hb=hb,
                input_shape=(2, 4), name="fixture")


def test_plan_validate_accepts_valid_and_roundtrips(tmp_path):
    plan = _valid_plan()
    assert plan.validate() is plan
    p = tmp_path / "plan.json"
    plan.save(p)
    assert Plan.load(p) == plan


def test_plan_validate_rejects_bad_group_reference():
    plan = _valid_plan()
    bad = Plan(calls=(ReluCall(8, 1, (2, 4)),), hb=plan.hb)
    with pytest.raises(errors.PlanInvalid, match="group 1"):
        bad.validate()


def test_plan_validate_rejects_element_shape_mismatch():
    plan = _valid_plan()
    bad = Plan(calls=(ReluCall(7, 0, (2, 4)),), hb=plan.hb)
    with pytest.raises(errors.PlanInvalid, match="claims 7"):
        bad.validate()


def test_plan_validate_rejects_group_accounting_drift():
    hb = HBConfig((HBLayer(k=21, m=13),), (9,))
    bad = Plan(calls=(ReluCall(8, 0, (2, 4)),), hb=hb)
    with pytest.raises(errors.PlanInvalid, match="group_elements"):
        bad.validate()


def test_plan_load_wraps_malformed_json(tmp_path):
    plan = _valid_plan()
    d = plan.to_json()
    d["hb"]["layers"][0]["k"] = 99           # outside the ring
    p = tmp_path / "bad_k.json"
    p.write_text(json.dumps(d))
    with pytest.raises(errors.PlanInvalid):
        Plan.load(p)
    d = plan.to_json()
    del d["calls"]
    p2 = tmp_path / "missing.json"
    p2.write_text(json.dumps(d))
    with pytest.raises(errors.PlanInvalid):
        Plan.load(p2)
    # PlanInvalid is a ValueError, so legacy call sites keep working
    assert issubclass(errors.PlanInvalid, ValueError)


def test_plan_validate_is_trivial_for_trace_free_plans():
    Plan.from_hb(HBConfig((HBLayer(k=21, m=13),), (8,))).validate()


# ---------------------------------------------------------------------------
# canonical serve_step leakage census (2-device subprocess, like
# tests/test_mesh_serving.py: the main process keeps one CPU device)
# ---------------------------------------------------------------------------

_CENSUS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
from repro.analysis.taint import canonical_resnet_census
s = canonical_resnet_census()
assert s["unmasked_collectives"] == 0, s
assert s["cross_check_ok"], s
assert s["collectives"] == s["sched_rounds"], s
assert s["masked_collectives"] + s["public_collectives"] == s["collectives"], s
print("CENSUS_OK", s)
"""


def test_canonical_serve_step_census_zero_unmasked():
    """Acceptance: the compiled mesh-native ResNet serve step carries
    zero collectives whose operand is an unmasked secret share, the
    taint walk visits exactly the collective_census set, and the count
    equals the schedule's fused rounds."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _CENSUS_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    assert "CENSUS_OK" in out.stdout
