"""End-to-end driver (the paper's kind: serving): train a ResNet, run the
HummingBird offline phase (search + finetune), then serve batched private
inference requests through the real GMW protocol and report accuracy +
communication vs the exact baseline.

    PYTHONPATH=src python examples/private_inference.py [--requests 16]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RESNET_SMOKE
from repro.core import MPCTensor, costmodel
from repro.core.hummingbird import HBConfig
from repro.data import ImagePipeline
from repro.models import resnet
from repro.search import finetune as ft, search_budget
from repro.search.simulator import evaluate_accuracy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--budget", type=float, default=8 / 64)
    args = ap.parse_args()

    # --- setup: model + data -------------------------------------------------
    pipe = ImagePipeline(n_classes=10, hw=RESNET_SMOKE.in_hw)
    xs, ys = pipe.take(512)
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)

    def afn(p, x, relu_fn=None):
        return resnet.apply(p, x, RESNET_SMOKE, relu_fn=relu_fn)

    groups = resnet.relu_group_elements(params, RESNET_SMOKE)
    print("[1/4] training the plaintext model...")
    params, _ = ft.finetune(afn, params, xs[:384], ys[:384],
                            HBConfig.exact(groups), jax.random.PRNGKey(1),
                            epochs=4, batch=64, lr=3e-3)
    base_acc = evaluate_accuracy(afn, params, xs[384:], ys[384:],
                                 HBConfig.exact(groups), jax.random.PRNGKey(2))
    print(f"      baseline accuracy: {base_acc:.3f}")

    # --- offline phase: search + finetune ------------------------------------
    print(f"[2/4] HummingBird-b search (budget {args.budget:.3f})...")
    res = search_budget(afn, params, xs[384:448], ys[384:448], groups,
                        jax.random.PRNGKey(3), budget=args.budget,
                        bit_choices=(6, 8))
    print(f"      found {[(l.k, l.m) for l in res.config.layers]} "
          f"({res.config.budget_fraction():.3f} of bits, "
          f"{res.search_time_s:.1f}s)")
    params, _ = ft.finetune(afn, params, xs[:384], ys[:384], res.config,
                            jax.random.PRNGKey(4), epochs=1, batch=64)

    # --- online phase: batched private inference ------------------------------
    print(f"[3/4] serving {args.requests} private requests (real GMW)...")
    req_x, req_y = xs[448:448 + args.requests], ys[448:448 + args.requests]
    t0 = time.time()
    X = MPCTensor.from_plain(jax.random.PRNGKey(5), req_x)
    out = resnet.mpc_apply(params, X, RESNET_SMOKE, jax.random.PRNGKey(6),
                           hb=res.config)
    pred = np.argmax(out.reveal_np(), -1)
    wall = time.time() - t0
    acc = float((pred == np.asarray(req_y)).mean())
    plain_pred = np.argmax(np.asarray(afn(params, req_x)), -1)
    agree = float((pred == plain_pred).mean())

    # --- report ----------------------------------------------------------------
    print("[4/4] results")
    r = costmodel.reduction_factors(res.config)
    print(f"      private-inference accuracy: {acc:.3f} "
          f"(plaintext agreement {agree:.3f})")
    print(f"      comm reduction vs CrypTen-64: {r['bytes_reduction']:.2f}x "
          f"bytes, {r['rounds_reduction']:.2f}x rounds, "
          f"{r['bits_discarded_frac']*100:.1f}% of DReLU bits discarded")
    print(f"      wall time (CPU sim, both parties): {wall:.1f}s")


if __name__ == "__main__":
    main()
