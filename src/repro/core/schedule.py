"""Round-schedule simulator for the round-fused GMW engine.

The serving hot path is round-dominated, not byte-dominated (paper Fig.
3/4): a multi-group ReLU layer's wall-clock is set by the *fused* round
timeline ``run_streams`` executes, not by summed payload bytes.  This
module deterministically simulates that timeline for any set of
``(n_elements, width)`` protocol streams and is the single source of
truth the analytic layers delegate to (``costmodel.relu_cost`` /
``relu_many_cost``, ``api.Plan.cost/estimate``, the search engine's
``objective="latency"`` scoring, and the ``benchmarks/run.py --quick``
round-regression gate).

What is modelled, exactly as the engine executes it:

- **Per-stream timelines** (``stream_timeline``): one entry per
  communication round with its protocol phase and per-party one-direction
  payload bytes — A2B prep ("others"), initial AND + Kogge-Stone levels
  ("circuit", cone-pruned levels with an empty position set are skipped
  entirely), sign-bit B2A ("b2a") and the final Beaver mult ("mult").
- **Lockstep coalescing**: round r of the fused schedule carries the sum
  of every still-live stream's round-r payload in ONE exchange
  (``comm.CoalescingComm``); streams that finish early (narrower rings ->
  fewer adder levels) drop out, so later rounds shrink.
- **Cross-phase overlap**: a shallow group's B2A/mult rounds ride the
  same exchanges as a deeper group's adder levels — visible in each
  ``RoundSlot.phases``.
- **Auto-batching**: streams with an identical batch key (same
  ``(n_elements, k, m)`` in the engine) are merged into one stream on the
  batch dimension before coalescing, so they contribute one payload (and
  one fused kernel pass) per round instead of N, and repacking the
  combined element vector removes per-stream packing padding
  (``packed_words(sum n) <= sum packed_words(n)`` — bytes can only drop).
- **Culling / empties**: width-0 (k == m) and zero-element streams run
  zero rounds and contribute nothing.

Predictions are validated bit-exactly against ``CoalescingComm`` counters
in ``tests/test_schedule.py``.

This module is import-light on purpose (stdlib only): ``costmodel``,
``gmw`` and ``beaver`` all import it, so it must sit below every protocol
module.  ``cone_sets`` and ``n_levels`` live here for the same reason —
``gmw``/``beaver`` re-export them, which breaks the historical
costmodel -> gmw -> costmodel lazy-import cycle.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

WORD_BYTES = 4        # packed u32 wire words
RING_BYTES = 8        # one Z/2^64 element (two u32 limbs)

#: Resilient-transport framing: ``comm.ResilientComm`` appends a round
#: sequence word and a checksum word to every flushed round's flattened
#: uint32 buffer (see its docstring).  Declared here — the import-light
#: bottom of the stack — so the schedule can price the framed timeline
#: (``Schedule.framed``) and ``--check`` still equates measured and
#: predicted bytes when the resilient layer is in the stack.
FRAME_WORDS = 2
FRAME_BYTES = FRAME_WORDS * WORD_BYTES

#: Protocol phases in timeline order (names match the paper's Figure 3
#: categories and ``costmodel.CommCost.breakdown``).  ``Schedule.framed``
#: adds a fifth, synthetic "frame" phase on top of these.
PHASES = ("others", "circuit", "b2a", "mult")


def n_levels(w: int) -> int:
    """Kogge-Stone adder depth for a w-bit ring (0 for w <= 1)."""
    return max(0, math.ceil(math.log2(w))) if w > 1 else 0


def packed_words(n_elements: int) -> int:
    """u32 words per packed bitplane (mirror of ``shares.packed_words`` —
    kept local so this module stays stdlib-only)."""
    return (n_elements + 31) // 32


def cone_sets(w: int) -> Tuple[List[int], List[List[int]]]:
    """Backward cone of the single output G[w-2] through the Kogge-Stone
    levels (beyond-paper optimization: DReLU consumes only the MSB carry,
    so prefix positions outside the cone are dead code).

    Returns (init_positions, [(level_update_positions), ...]) with one
    entry per level; total AND gates ~ 2(w-1) instead of w(1+2*log2 w).
    """
    L = n_levels(w)
    needed = {w - 2}
    level_sets = []
    for lvl in reversed(range(L)):
        d = 1 << lvl
        level_sets.append(sorted(i for i in needed if i - d >= 0))
        needed = needed | {i - d for i in needed if i - d >= 0}
    level_sets.reverse()
    return sorted(needed), level_sets


# ---------------------------------------------------------------------------
# Per-stream round timelines
# ---------------------------------------------------------------------------

def stream_timeline(n_elements: int, width: int,
                    cone: bool = False) -> Tuple[Tuple[str, int], ...]:
    """One ReLU stream's rounds, in order: ``((phase, bytes), ...)``.

    ``bytes`` is the per-party one-direction payload of that round,
    exactly what ``comm.payload_bytes`` reports for the wire arrays
    ``core.gmw`` yields.  Width-0 (culled identity) and zero-element
    (empty batch) streams run no rounds at all — ``relu_many`` drops them
    before the lockstep loop.
    """
    w = width
    if w == 0 or n_elements == 0:
        return ()
    W = packed_words(n_elements)
    rounds: List[Tuple[str, int]] = [("others", w * W * WORD_BYTES)]
    if w > 1:
        if cone:
            init_pos, level_sets = cone_sets(w)
            rounds.append(("circuit", 2 * len(init_pos) * W * WORD_BYTES))
            # levels whose cone slice is empty are skipped by the protocol:
            # no bytes AND no round
            rounds.extend(("circuit", 2 * (2 * len(pos)) * W * WORD_BYTES)
                          for pos in level_sets if pos)
        else:
            rounds.append(("circuit", 2 * w * W * WORD_BYTES))
            rounds.extend([("circuit", 2 * (2 * w) * W * WORD_BYTES)]
                          * n_levels(w))
    rounds.append(("b2a", 2 * n_elements * RING_BYTES))
    rounds.append(("mult", 2 * n_elements * RING_BYTES))
    return tuple(rounds)


def stream_rounds(width: int, cone: bool = False) -> int:
    """Round count of one live stream (element-count independent)."""
    return len(stream_timeline(32, width, cone=cone)) if width else 0


def open_timeline(n_elements: int) -> Tuple[Tuple[str, int], ...]:
    """One Beaver-product opening's rounds: a single "open" exchange of
    ``n_elements`` ring elements (per party, one direction).

    This is the secret-by-secret product round of the transformer path
    (``gmw.products_many``): an elementwise mul of n values opens 2n
    elements, a matmul of X [.., M, K] @ Y [.., K, N] opens
    ``size(X) + size(Y)`` — the caller passes the total.  Zero-element
    opens run no round at all.
    """
    if n_elements == 0:
        return ()
    return (("open", n_elements * RING_BYTES),)


def simulate_open(n_list: Sequence[int]) -> "Schedule":
    """Fused schedule of one coalesced opening across sibling streams:
    every stream's single "open" payload rides ONE exchange (1 round,
    summed bytes); streams opening nothing contribute nothing."""
    live = [int(n) for n in n_list if n]
    if not live:
        return Schedule.empty()
    total = sum(live) * RING_BYTES
    slot = RoundSlot(bytes_tx=total, parts=len(live),
                     phase_bytes=(("open", total),))
    return Schedule((slot,), ())


# ---------------------------------------------------------------------------
# The fused schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundSlot:
    """One coalesced exchange of the fused timeline."""

    bytes_tx: int                              # per party, one direction
    parts: int                                 # payloads merged in this round
    phase_bytes: Tuple[Tuple[str, int], ...]   # per-phase contributions

    @property
    def phases(self) -> Tuple[str, ...]:
        """Which protocol phases share this exchange (cross-phase overlap
        shows up here: e.g. ("circuit", "b2a") when a shallow group's B2A
        rides a deep group's adder level)."""
        return tuple(p for p, _ in self.phase_bytes)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Deterministic fused-round timeline of one ``run_streams`` call (or,
    via ``+``, of sequential calls — e.g. a full Plan replay)."""

    slots: Tuple[RoundSlot, ...]
    groups: Tuple[Tuple[int, int], ...]    # post-batching (n_elements, width)

    # -- counters (the CoalescingComm-validated pair) -------------------------
    @property
    def n_rounds(self) -> int:
        return len(self.slots)

    @property
    def round_bytes(self) -> Tuple[int, ...]:
        return tuple(s.bytes_tx for s in self.slots)

    @property
    def round_parts(self) -> Tuple[int, ...]:
        return tuple(s.parts for s in self.slots)

    @property
    def bytes_tx(self) -> int:
        return sum(s.bytes_tx for s in self.slots)

    def phase_bytes(self) -> Dict[str, int]:
        """Total bytes per protocol phase (the paper's Figure 3 categories;
        always carries all four keys, plus "frame" on framed schedules)."""
        out = {p: 0 for p in PHASES}
        for slot in self.slots:
            for phase, b in slot.phase_bytes:
                out[phase] = out.get(phase, 0) + b
        return out

    # -- latency ---------------------------------------------------------------
    def latency(self, bandwidth_bps: float, rtt_s: float,
                compute_s: float = 0.0) -> float:
        """Schedule-predicted end-to-end latency (seconds): every fused
        round pays one RTT, serialization shares the link both directions.

        Summing per-round ``rtt + wire`` equals ``n_rounds * rtt +
        total_wire``; the aggregate form is used so the result is
        bit-identical to ``costmodel.latency_model`` over this schedule's
        (bytes, rounds) pair.
        """
        wire = 2 * self.bytes_tx * 8 / bandwidth_bps
        return wire + self.n_rounds * rtt_s + compute_s

    def wall_band(self, bandwidth_bps: float, rtt_s: float,
                  host_s_per_round: float = 1.0,
                  startup_s: float = 45.0) -> Tuple[float, float]:
        """Acceptance band ``(lo, hi)`` for a *measured* end-to-end wall
        over this timeline on a real transport.

        ``lo`` is the schedule-predicted latency — physics; nothing real
        can beat it.  ``hi`` adds a per-round host budget (Python
        callback, serialization, socket syscalls — ``host_s_per_round``
        covers the loopback-measured per-round overhead, ~0.2 s/round on
        an unloaded box, with slack for a contended CI runner) and a
        one-off ``startup_s`` (process spawn, jax import, connect/accept
        handshake, jit warm-up of both parties).  The band therefore
        *tightens as the schedule shrinks*: a 21-round timeline gets a
        ~21x smaller host allowance than a 210-round one, so a
        regression that doubles per-round host work fails ``--check``
        instead of hiding under a flat multiplier (the old gate's
        ``20x pred + 120`` ceiling was ~6x the measured wall and caught
        nothing).
        """
        lo = self.latency(bandwidth_bps, rtt_s)
        hi = lo + self.n_rounds * host_s_per_round + startup_s
        return (lo, hi)

    # -- resilient-transport framing -------------------------------------------
    def framed(self, frame_bytes: int = FRAME_BYTES) -> "Schedule":
        """The same timeline as seen on a resilient transport: every fused
        round's exchange carries ``frame_bytes`` of framing (round sequence
        + checksum words, ``comm.ResilientComm``) on top of its payload.

        Round count, ordering and phase structure are untouched — framing
        is pure per-round overhead, priced as its own "frame" phase so
        ``phase_bytes()``/``gantt()`` show exactly what resilience costs.
        This is what ``benchmarks/run.py --chaos`` compares the measured
        ``ResilientComm.round_bytes`` against (re-sends excluded: they are
        recovery overhead, accounted separately).
        """
        slots = tuple(
            RoundSlot(bytes_tx=s.bytes_tx + frame_bytes, parts=s.parts,
                      phase_bytes=s.phase_bytes + (("frame", frame_bytes),))
            for s in self.slots)
        return Schedule(slots, self.groups)

    # -- rendering -------------------------------------------------------------
    def gantt(self, col: int = 6) -> str:
        """ASCII/markdown Gantt of the fused-round timeline.

        One row per protocol phase, one column per coalesced exchange;
        a ``█``-bar marks every phase contributing bytes to that round, so
        cross-phase overlap (a shallow group's B2A riding a deep group's
        adder levels) is visible as two bars in one column.  Footer rows
        carry the coalesced payload count and per-party one-direction
        bytes of each round — the exact ``CoalescingComm`` counters (and,
        on the mesh backend, the per-collective-permute payloads of the
        compiled HLO).  Drop the output in a fenced code block for
        markdown.
        """
        if not self.slots:
            return "(empty schedule: 0 rounds, 0 bytes)"

        def cell(s: str) -> str:
            return s.rjust(col)

        def fmt_bytes(b: int) -> str:
            if b < 1024:
                return str(b)
            if b < 10 * 1024:
                return f"{b / 1024:.1f}k"
            if b < 1024 * 1024:
                return f"{b // 1024}k"
            return f"{b / (1024 * 1024):.1f}M"

        extra = tuple(p for s in self.slots for p, _ in s.phase_bytes
                      if p not in PHASES)
        phases = PHASES + tuple(dict.fromkeys(extra))   # e.g. framed: "frame"
        label = max(len(p) for p in phases + ("bytes/pty", "round"))
        lines = ["round".ljust(label) + " |"
                 + "".join(cell(str(r + 1)) for r in range(self.n_rounds))]
        for phase in phases:
            contrib = [dict(s.phase_bytes).get(phase, 0) for s in self.slots]
            if not any(contrib):
                continue
            bar = "█" * (col - 2)
            lines.append(phase.ljust(label) + " |" + "".join(
                cell(bar if b else "·") for b in contrib))
        lines.append("payloads".ljust(label) + " |"
                     + "".join(cell(str(s.parts)) for s in self.slots))
        lines.append("bytes/pty".ljust(label) + " |"
                     + "".join(cell(fmt_bytes(s.bytes_tx)) for s in self.slots))
        lines.append(f"total: {self.n_rounds} fused rounds, "
                     f"{self.bytes_tx} B/party one-direction")
        return "\n".join(lines)

    # -- composition -----------------------------------------------------------
    def __add__(self, other: "Schedule") -> "Schedule":
        """Sequential composition: ``other`` starts after ``self`` ends
        (separate ``relu_many`` calls never share rounds)."""
        return Schedule(self.slots + other.slots, self.groups + other.groups)

    @staticmethod
    def empty() -> "Schedule":
        return Schedule((), ())


def batch_specs(specs: Iterable) -> List[Tuple[int, int]]:
    """Merge streams with an identical batch key into one (n, w) group.

    Each spec is ``(n_elements, width)`` or ``(n_elements, width,
    batch_key)``; the default key is ``(n_elements, width)``.  The engine
    batches by ``(n_elements, k, m)`` — callers that distinguish (k, m)
    pairs of equal width pass that as the explicit key.  Groups keep
    first-appearance order, matching ``gmw.relu_many``.
    """
    order: List = []
    merged: Dict = {}
    for spec in specs:
        n, w = int(spec[0]), int(spec[1])
        key = spec[2] if len(spec) > 2 else (n, w)
        if key not in merged:
            merged[key] = [0, w]
            order.append(key)
        if merged[key][1] != w:
            raise ValueError(
                f"batch key {key!r} mixes widths {merged[key][1]} and {w}")
        merged[key][0] += n
    return [(merged[k][0], merged[k][1]) for k in order]


def simulate(specs: Iterable, cone: bool = False,
             auto_batch: bool = True) -> Schedule:
    """Fused round schedule of one ``relu_many``/``run_streams`` call.

    ``specs``: iterable of ``(n_elements, width)`` or ``(n_elements,
    width, batch_key)`` — one entry per concurrent protocol stream.  With
    ``auto_batch`` (the engine default) identical-key streams merge into
    one batched stream first; ragged groups stay separate and are
    per-payload coalesced.
    """
    if auto_batch:
        groups = batch_specs(specs)
    else:
        groups = [(int(s[0]), int(s[1])) for s in specs]
    timelines = [stream_timeline(n, w, cone=cone) for n, w in groups]
    slots = []
    for r in range(max((len(t) for t in timelines), default=0)):
        contrib: Dict[str, int] = {}
        parts = 0
        for t in timelines:
            if r < len(t):
                phase, b = t[r]
                contrib[phase] = contrib.get(phase, 0) + b
                parts += 1
        slots.append(RoundSlot(
            bytes_tx=sum(contrib.values()), parts=parts,
            phase_bytes=tuple((p, contrib[p]) for p in PHASES
                              if p in contrib)))
    live = tuple((n, w) for n, w in groups if n and w)
    return Schedule(tuple(slots), live)


def simulate_merged(request_calls: Sequence[Sequence], cone: bool = False,
                    auto_batch: bool = True) -> Schedule:
    """Fused timeline of a *merged micro-batch*: N concurrent request
    replays advancing call-by-call in lockstep.

    ``request_calls[r]`` is request r's replay as a sequence of per-call
    specs — ``(n_elements, width)`` or ``(n_elements, width, batch_key)``,
    i.e. ``api.Plan.call_specs()``.  Call j of the merged batch runs every
    request's j-th ReLU call in ONE ``relu_many`` lockstep (sibling
    payloads coalesced; identical batch keys merged when ``auto_batch``),
    so the batch pays max-over-requests rounds per call instead of the
    sum — this is the serving engine's execution order and the latency
    query its batching policy closes batches on.  Requests with fewer
    calls simply drop out of later call slots.
    """
    n_calls = max((len(calls) for calls in request_calls), default=0)
    total = Schedule.empty()
    for j in range(n_calls):
        specs = [calls[j] for calls in request_calls if j < len(calls)]
        total = total + simulate(specs, cone=cone, auto_batch=auto_batch)
    return total
