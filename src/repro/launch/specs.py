"""ShapeDtypeStruct input builders for every (arch x shape x mesh) cell.

Nothing here allocates device memory: params, optimizer states, caches and
batches are all abstract (eval_shape) with NamedShardings attached from
the partition rules, ready for ``jit(...).lower(...)``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, lm
from repro.runtime import sharding
from repro.train import optimizer as opt_lib
from repro.launch import train as train_lib

_KEY = jax.ShapeDtypeStruct((2,), jnp.uint32)


def _attach(tree, shardings):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shardings)


def abstract_params(cfg: ArchConfig, mesh, mode: str):
    init_fn = encdec.init if cfg.family == "encdec" else lm.init
    shapes = jax.eval_shape(functools.partial(init_fn, cfg=cfg), _KEY)
    if mode == "train":
        shapes = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), shapes)
    return _attach(shapes, sharding.param_shardings(shapes, mesh, mode, cfg))


def abstract_train_state(cfg: ArchConfig, mesh, optimizer):
    params = abstract_params(cfg, mesh, "train")
    opt_shapes = jax.eval_shape(optimizer.init, params)
    opt = _attach(opt_shapes,
                  sharding.param_shardings(opt_shapes, mesh, "train", cfg))
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=sharding.replicated(mesh))
    return train_lib.TrainState(params=params, opt_state=opt, step=step)


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh):
    b, s = shape.global_batch, shape.seq_len
    dp = NamedSharding(mesh, sharding.batch_spec(b, mesh, extra_dims=1))
    dp2 = NamedSharding(mesh, sharding.batch_spec(b, mesh, extra_dims=2))
    batch: Dict[str, Any] = {}
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.ShapeDtypeStruct(
            (b, s, cfg.d_model), jnp.bfloat16, sharding=dp2)
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=dp)
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=dp)
        return batch
    s_tok = s - (cfg.n_frontend_tokens if cfg.frontend != "none" else 0)
    batch["tokens"] = jax.ShapeDtypeStruct((b, s_tok), jnp.int32, sharding=dp)
    batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=dp)
    if cfg.frontend != "none":
        batch["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16, sharding=dp2)
    return batch


def abstract_cache(cfg: ArchConfig, shape: ShapeConfig, mesh):
    b, max_len = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        shapes = jax.eval_shape(
            functools.partial(encdec.init_cache, cfg, b, max_len, max_len))
    else:
        shapes = jax.eval_shape(functools.partial(lm.init_cache, cfg, b, max_len))
    return _attach(shapes, sharding.cache_shardings(shapes, cfg, mesh))


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh):
    b = shape.global_batch
    params = abstract_params(cfg, mesh, "serve")
    token = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32,
        sharding=NamedSharding(mesh, sharding.batch_spec(b, mesh)))
    cache = abstract_cache(cfg, shape, mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=sharding.replicated(mesh))
    return params, token, cache, pos


def prefill_input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh):
    b, s = shape.global_batch, shape.seq_len
    params = abstract_params(cfg, mesh, "serve")
    dp = NamedSharding(mesh, sharding.batch_spec(b, mesh, extra_dims=1))
    dp2 = NamedSharding(mesh, sharding.batch_spec(b, mesh, extra_dims=2))
    if cfg.family == "encdec":
        src = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16,
                                   sharding=dp2)
        return params, (src,), {}
    s_tok = s - (cfg.n_frontend_tokens if cfg.frontend != "none" else 0)
    tokens = jax.ShapeDtypeStruct((b, s_tok), jnp.int32, sharding=dp)
    kwargs = {}
    if cfg.frontend != "none":
        kwargs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16, sharding=dp2)
    return params, (tokens,), kwargs


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh,
                optimizer: Optional[Any] = None):
    """Everything dryrun needs for one cell: (fn_args, fn_kwargs)."""
    if shape.kind == "train":
        optimizer = optimizer or opt_lib.AdamW()
        state = abstract_train_state(cfg, mesh, optimizer)
        batch = train_batch_specs(cfg, shape, mesh)
        return (state, batch), {}
    if shape.kind == "prefill":
        params, args, kwargs = prefill_input_specs(cfg, shape, mesh)
        return (params,) + args, kwargs
    params, token, cache, pos = decode_input_specs(cfg, shape, mesh)
    return (params, token, cache, pos), {}
