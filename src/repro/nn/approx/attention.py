"""MPC-friendly attention normalization: softmax -> ReLU + causal mean.

Softmax is the round-dominant nonlinearity of private transformer
inference (exp + reciprocal have no cheap GMW circuit).  Following the
ReLU-attention line of work, the row normalization

    softmax(s)_ij  ->  ReLU(s_ij) * causal(i, j) / (i + 1)

keeps the only secret-dependent nonlinearity a ReLU — evaluated on the
reduced ring with a per-site (k, m) choice — while the causal mask and the
1/(i+1) row mean are PUBLIC multipliers folded into one ``mul_public``.
Scores are scaled by dh^-1/2 *before* the ReLU so the reduced-ring
magnitude regime (Theorem 1) sees tamed values; since the scale is
positive this is mathematically equivalent to scaling after.

Both evaluations share one code shape: scores = Q @ K^T (secret matmul,
one Beaver open round), scale, ReLU via ``relu_fn``, public mask-norm,
then the secret A @ V matmul (second open round).
"""
from __future__ import annotations

from typing import List, Sequence

import jax.numpy as jnp


def causal_norm(s: int, dtype=jnp.float32) -> jnp.ndarray:
    """(S, S) public multiplier: causal(i, j) / (i + 1) — lower-triangular
    mask divided by each row's visible-position count."""
    tri = jnp.tril(jnp.ones((s, s), dtype))
    return tri / jnp.arange(1, s + 1, dtype=dtype)[:, None]


def relu_attention(q, k, v, group: int, relu_fn):
    """Plaintext ReLU attention through the relu_fn hook.

    q, k, v: (B, H, S, Dh) — kv heads already repeated to H.  The hook
    calls (matmul, relu, matmul) happen in the exact order the MPC twin
    makes them, so a traced plan's opens line up with the replay.
    """
    dh = q.shape[-1]
    s = q.shape[-2]
    scores = relu_fn.matmul(q, jnp.swapaxes(k, -1, -2)) * (dh ** -0.5)
    w = relu_fn(scores, group) * causal_norm(s, scores.dtype)
    return relu_fn.matmul(w, v)


def relu_attention_mpc(qs: Sequence, ks: Sequence, vs: Sequence, group: int,
                       relu_fn) -> List:
    """Secret-shared ReLU attention over sibling MPCTensor streams.

    Two fused open rounds (QK^T and A@V, all streams coalesced) plus one
    reduced-ring ReLU pass on the scores; scale and causal mean are local
    public multiplies.
    """
    dh = qs[0].shape[-1]
    scores = relu_fn.matmul(qs, [k.swapaxes(-1, -2) for k in ks])
    scores = [t.mul_public(dh ** -0.5) for t in scores]
    ws = relu_fn(scores, group)
    ws = [w.mul_public(causal_norm(w.shape[-2])) for w in ws]
    return relu_fn.matmul(ws, vs)
