"""Quickstart: secret-share a tensor, run HummingBird ReLU, see the
communication savings.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MPCTensor, HBLayer, costmodel


def main():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8,)) * 3.0
    print("plaintext x:   ", np.round(np.asarray(x), 3))

    # 1. secret-share: neither party's share reveals anything about x
    X = MPCTensor.from_plain(jax.random.PRNGKey(1), x)
    print("party 0 share: ", np.asarray(X.data.lo[0])[:4], "... (uniform)")

    # 2. exact CrypTen-style ReLU on the full 64-bit ring
    exact = X.relu(jax.random.PRNGKey(2), hb=HBLayer(k=64, m=0))
    print("exact ReLU:    ", np.round(exact.reveal_np(), 3))

    # 3. HummingBird: estimate the sign with only 8 of the 64 bits
    hb = HBLayer(k=21, m=13)
    approx = X.relu(jax.random.PRNGKey(3), hb=hb)
    print(f"HB ReLU [k={hb.k},m={hb.m}]:",
          np.round(approx.reveal_np(), 3))

    # 4. what did that buy? (per-party bytes on the wire)
    base = costmodel.relu_cost(x.size, 64)
    ours = costmodel.relu_cost(x.size, hb.width)
    print(f"\ncommunication: {base.bytes_tx} B -> {ours.bytes_tx} B "
          f"({base.bytes_tx / ours.bytes_tx:.2f}x less), "
          f"rounds {base.rounds} -> {ours.rounds}")
    print("Theorem 2 pruning threshold:",
          f"|x| < 2^({hb.m}-16) = {2.0 ** (hb.m - 16)}")


if __name__ == "__main__":
    main()
