"""repro.serve — request-level serving over the fused round timeline.

``repro.api`` compiles one model for one caller; this package serves
*traffic*: an ``InferenceEngine`` accepts ``submit(tenant, x)`` requests
into an admission queue, a schedule-driven ``BatchPolicy`` forms fused
micro-batches (close when the predicted merged-timeline latency per
request stops improving, or a deadline hits), and every batch executes
its requests as sibling streams of one plan replay — N concurrent
requests pay max-over-requests protocol rounds instead of the sum, with
per-request PRNG forking and per-tenant triple metering keeping the
execution bit-identical to serial per-request inference.

See ``docs/serving.md`` for the architecture and ``engine.py`` for the
execution contract.
"""
from .engine import (BatchPolicy, BatchReport, InferenceEngine, Request,
                     RequestFuture)
from .frontend import Frontend

__all__ = ["InferenceEngine", "BatchPolicy", "BatchReport", "Request",
           "RequestFuture", "Frontend"]
