"""Pallas TPU kernel: fused GMW Beaver-AND evaluation on packed words.

After the (d, e) opening exchange, each party locally evaluates
    z = c ^ (d & b) ^ (e & a) ^ (sel & d & e)
over the packed bit-sliced planes (sel = all-ones on party 0).  Unfused,
this chain is 6 elementwise HBM round-trips; the kernel evaluates it in one
VMEM pass — the op is purely memory-bound, so fusion is the entire win
(napkin: 6x HBM traffic -> 1x, bounded by 819 GB/s on v5e).

Also provides the fused Kogge-Stone level update
    g' = g ^ z_g ;  p' = z_p
folded into the same pass when the AND outputs feed a carry level.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_U32 = jnp.uint32
BLOCK = (8, 256)  # (plane, word) VMEM tile; word dim multiple of 128 lanes


def _beaver_and_kernel(d_ref, e_ref, a_ref, b_ref, c_ref, sel_ref, out_ref):
    d = d_ref[...]
    e = e_ref[...]
    z = c_ref[...] ^ (d & b_ref[...]) ^ (e & a_ref[...]) ^ (sel_ref[...] & d & e)
    out_ref[...] = z


def beaver_and_pallas(d_open, e_open, a, b, c, sel, *, interpret: bool = True,
                      block=BLOCK) -> jax.Array:
    """All inputs (P_planes, W) uint32, shapes padded to the block grid."""
    planes, words = d_open.shape
    grid = (planes // block[0], words // block[1])
    spec = pl.BlockSpec(block, lambda i, j: (i, j))
    return pl.pallas_call(
        _beaver_and_kernel,
        out_shape=jax.ShapeDtypeStruct((planes, words), _U32),
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=spec,
        interpret=interpret,
    )(d_open, e_open, a, b, c, sel)


def _ks_level_kernel(g_ref, zg_ref, zp_ref, g_out, p_out):
    g_out[...] = g_ref[...] ^ zg_ref[...]
    p_out[...] = zp_ref[...]


def ks_level_pallas(g, z_g, z_p, *, interpret: bool = True, block=BLOCK):
    """Fused Kogge-Stone level combine: returns (g ^ z_g, z_p)."""
    planes, words = g.shape
    grid = (planes // block[0], words // block[1])
    spec = pl.BlockSpec(block, lambda i, j: (i, j))
    return pl.pallas_call(
        _ks_level_kernel,
        out_shape=(jax.ShapeDtypeStruct((planes, words), _U32),
                   jax.ShapeDtypeStruct((planes, words), _U32)),
        grid=grid,
        in_specs=[spec] * 3,
        out_specs=(spec, spec),
        interpret=interpret,
    )(g, z_g, z_p)
