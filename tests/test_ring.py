"""Ring64 limb arithmetic vs numpy uint64 oracle + hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import fixed, ring

U64 = st.integers(min_value=0, max_value=2**64 - 1)


def _np(xs):
    return np.asarray(xs, np.uint64)


@settings(max_examples=50, deadline=None)
@given(st.lists(U64, min_size=1, max_size=8), st.lists(U64, min_size=1, max_size=8))
def test_add_sub_mul_match_uint64(a_list, b_list):
    n = min(len(a_list), len(b_list))
    a_np, b_np = _np(a_list[:n]), _np(b_list[:n])
    a, b = ring.from_uint64_np(a_np), ring.from_uint64_np(b_np)
    np.testing.assert_array_equal(ring.to_uint64_np(ring.add(a, b)), a_np + b_np)
    np.testing.assert_array_equal(ring.to_uint64_np(ring.sub(a, b)), a_np - b_np)
    np.testing.assert_array_equal(ring.to_uint64_np(ring.mul(a, b)), a_np * b_np)
    np.testing.assert_array_equal(ring.to_uint64_np(ring.neg(a)), -a_np)


@settings(max_examples=30, deadline=None)
@given(U64, st.integers(min_value=0, max_value=63))
def test_shifts_match_uint64(v, n):
    a = ring.from_uint64_np(_np([v]))
    np.testing.assert_array_equal(ring.to_uint64_np(ring.lshift(a, n)),
                                  _np([v]) << np.uint64(n))
    np.testing.assert_array_equal(ring.to_uint64_np(ring.rshift_logical(a, n)),
                                  _np([v]) >> np.uint64(n))


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=-2**62, max_value=2**62 - 1),
       st.integers(min_value=1, max_value=62))
def test_arith_shift_is_signed_floor_div(v, n):
    a = ring.from_uint64_np(np.asarray([v], np.int64).view(np.uint64))
    got = ring.to_uint64_np(ring.rshift_arith(a, n)).view(np.int64)[0]
    assert got == v >> n  # python >> is arithmetic for ints


@settings(max_examples=30, deadline=None)
@given(U64, st.integers(min_value=1, max_value=32),
       st.integers(min_value=0, max_value=31))
def test_extract_bits(v, w, m):
    if m + w > 64:
        w = 64 - m
    if w < 1:
        return
    a = ring.from_uint64_np(_np([v]))
    got = int(np.asarray(ring.extract_bits(a, m + w, m))[0])
    assert got == (v >> m) & ((1 << w) - 1)


@settings(max_examples=25, deadline=None)
@given(U64)
def test_balanced_digits_reconstruct(v):
    a = ring.from_uint64_np(_np([v]))
    d = np.asarray(ring.balanced_digits(a)).astype(object)
    assert all(-128 <= int(x) <= 127 for x in d.ravel())
    recon = sum(int(d[i][0]) * (1 << (8 * i)) for i in range(8)) % (1 << 64)
    assert recon == v


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=-2**31, max_value=2**31 - 1))
def test_balanced_digits_i32(w):
    e = np.asarray(ring.balanced_digits_i32(jnp.asarray([w], jnp.int32))).astype(object)
    recon = sum(int(e[j][0]) * (1 << (8 * j)) for j in range(5)) % (1 << 64)
    assert recon == w % (1 << 64)


def test_planes_roundtrip():
    vals = np.arange(64, dtype=np.uint64) * np.uint64(0x9E3779B97F4A7C15)
    a = ring.from_uint64_np(vals)
    planes = ring.extract_planes(a, 64, 0)
    back = ring.from_planes(planes)
    np.testing.assert_array_equal(ring.to_uint64_np(back), vals)


def test_fixed_point_roundtrip():
    x = np.linspace(-100, 100, 333).astype(np.float32)
    enc = fixed.encode_np(x)
    dec = fixed.decode_np(enc)
    np.testing.assert_allclose(dec, x, atol=2 ** -16)
    # in-jit encode matches host encode
    enc2 = fixed.encode(jnp.asarray(x))
    np.testing.assert_array_equal(ring.to_uint64_np(enc2), ring.to_uint64_np(enc))
