"""Shared neural-net primitives for the plaintext model substrate.

Functional style: ``init_*`` builds param pytrees (plain dicts of arrays),
``apply`` functions are pure.  Sharding is attached later by path-based
partition rules (runtime/sharding.py), so everything here works both for
real initialization (smoke tests) and under ``jax.eval_shape`` (dry-run).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.runtime import constraints


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               with_bias: bool = False):
    scale = 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype) * jnp.asarray(scale, dtype)
    if with_bias:
        return {"w": w, "b": jnp.zeros((d_out,), dtype)}
    return {"w": w}


def dense(params, x):
    y = jnp.einsum("...d,df->...f", x, params["w"])
    if "b" in params:
        y = y + params["b"]
    return y


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    # scale may be kept in f32 (master precision); never promote activations
    return y * params["scale"].astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return y * params["scale"].astype(x.dtype) + params["bias"].astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def softcap(x, cap: float):
    """Gemma-2 style logit soft-capping."""
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def activation(name: str):
    return {
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "relu": jax.nn.relu,
        "tanh": jnp.tanh,
    }[name]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Gated / plain MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, gated: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype)["w"],
         "w_down": dense_init(ks[1], d_ff, d_model, dtype)["w"]}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)["w"]
    return p


def mlp(params, x, act_name: str = "gelu"):
    act = activation(act_name)
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    if "w_gate" in params:
        gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
        h = act(gate) * up
    else:
        h = act(up)
    # Megatron TP: hidden sharded over model, contraction in w_down emits
    # the single per-block all-reduce
    h = constraints.shard(h, "dp", None, "tp")
    y = jnp.einsum("...f,fd->...d", h, params["w_down"])
    return constraints.shard(y, "dp", None, None)


# ---------------------------------------------------------------------------
# MPC bridge: round-shared ReLU over sibling secret-shared tensors
# ---------------------------------------------------------------------------

def mpc_relu_many(keys, tensors, hbs=None, comm=None, triples_list=None,
                  cone: bool = False, auto_batch: bool = True):
    """Apply GMW ReLU to sibling MPCTensors with shared protocol rounds.

    The single import point models use for round-fused private inference:
    every communication round across the sibling group becomes one
    coalesced exchange (see core.mpc_tensor.relu_many / core.comm
    CoalescingComm), so N parallel branches pay max-of-N rounds, not the
    sum — and identical-(shape, k, m) branches auto-batch into one
    protocol stream per round.  `keys` is one PRNG key per tensor; `hbs`
    one HummingBird (k, m) spec per tensor (defaults to the exact 64-bit
    ring).
    """
    from repro.core import mpc_tensor  # lazy: keep the plaintext substrate light
    return mpc_tensor.relu_many(keys, tensors, comm=comm, hbs=hbs,
                                triples_list=triples_list, cone=cone,
                                auto_batch=auto_batch)
