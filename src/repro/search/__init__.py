"""HummingBird offline phase: MPC simulator, search engine, finetuning."""
from . import engine, finetune, simulator
from .engine import SearchResult, search_budget, search_eco
__all__ = ["engine", "finetune", "simulator", "SearchResult",
           "search_budget", "search_eco"]
