"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""
import sys


def main() -> None:
    from benchmarks import (bench_accuracy, bench_breakdown, bench_comm,
                            bench_e2e, bench_roofline, bench_search)
    mods = [bench_comm, bench_e2e, bench_breakdown, bench_search,
            bench_accuracy, bench_roofline]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for mod in mods:
        if only and only not in mod.__name__:
            continue
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:
            print(f"{mod.__name__}_ERROR,0,{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
