"""Party communicator abstraction.

All protocol code is written against arrays that carry a leading *party*
dimension.  Two backends make the same code run either on a single host
(simulation, party dim = 2) or sharded over a mesh axis (party dim = 1 per
shard, exchanges lower to collective-permute):

- ``SimComm``: the party dimension is materialised; ``swap`` is a flip.
  Used by the search engine, tests, and CPU benchmarks.
- ``MeshComm``: used *inside* ``shard_map`` over the ``party`` mesh axis;
  ``swap`` is ``lax.ppermute`` so every protocol exchange shows up as a
  collective-permute in the compiled HLO (and therefore in the roofline's
  collective-bytes term).  A party axis of size 1 (smoke mesh) keeps both
  party rows on one shard and degenerates to the local flip.

Party-dependent randomness goes through ``party_is`` (boolean mask) and
``party_slice`` (each party's rows of a full-party-dim array), so the
same protocol code produces bit-identical values on both backends.

Round-fused engine support (see core/gmw.py):

- ``CountingComm``: transparent wrapper that counts ``swap`` calls (=
  protocol rounds) and per-party payload bytes; tests validate these
  counters against the closed-form cost model.
- ``CoalescingComm``: deferred-exchange wrapper.  Protocol code *enqueues*
  heterogeneous uint32 payloads for the current round; ``flush`` flattens
  and concatenates everything into ONE ``swap`` on the base backend, then
  hands each caller its slice back.  This is what lets N concurrent ReLU
  groups share communication rounds instead of paying one round each.
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

_U32 = jnp.uint32


def payload_bytes(x) -> int:
    """Per-party one-direction wire bytes of a payload pytree.

    Every leaf carries the party dimension leading; each party transmits
    its own slice, so bytes = leaf bytes / party-dim size, summed.
    """
    total = 0
    for leaf in jax.tree_util.tree_leaves(x):
        total += (leaf.size // max(1, leaf.shape[0])) * leaf.dtype.itemsize
    return total


class SimComm:
    """Single-host simulation backend. Party dim is axis 0 with size 2."""

    n_parties = 2

    def swap(self, x):
        """Each party receives the other party's tensor (one exchange)."""
        return jax.tree_util.tree_map(lambda a: jnp.flip(a, axis=0), x)

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        """Boolean mask, True on party p, broadcastable against template."""
        idx = jnp.arange(2).reshape((2,) + (1,) * (template.ndim - 1))
        return idx == p

    def party_slice(self, full: jax.Array) -> jax.Array:
        """Each party's view of a full-party-dim array (leading dim =
        ``n_parties``).  The sim backend materialises every party, so this
        is the identity; the mesh backend returns the local party shard.
        Protocol code uses it for party-dependent randomness: generate the
        full (P, ...) array from a shared key, then keep your own rows —
        bit-identical across backends by construction."""
        return full


class MeshComm:
    """Mesh backend, valid only inside ``shard_map`` over ``axis_name``.

    The *global* party dimension (size ``n_parties`` = 2) is split over a
    mesh axis of size ``axis_size``, so each shard holds a local party dim
    of ``n_parties // axis_size`` rows:

    - ``axis_size == 2`` (real deployment: one device slice per
      non-colluding server): local party dim 1; ``swap`` is a single
      ``lax.ppermute``, so every protocol exchange is visible as exactly
      one collective-permute in the compiled HLO.
    - ``axis_size == 1`` (1-device smoke mesh): both parties land on the
      same shard (local party dim 2); the exchange degenerates to the
      sim backend's local flip and no collective is emitted.

    Either way the global semantics are the party flip, so protocol code
    is backend-agnostic and ``CoalescingComm`` over a ``MeshComm`` base
    fires ONE flattened ppermute per fused round.
    """

    n_parties = 2

    def __init__(self, axis_name: str = "party", axis_size: int = 2):
        if self.n_parties % axis_size:
            raise ValueError(
                f"party axis size {axis_size} must divide {self.n_parties}")
        self.axis_name = axis_name
        self.axis_size = axis_size
        self.local_parties = self.n_parties // axis_size

    def swap(self, x):
        """Global party flip = local party-dim flip + mesh-axis reversal."""
        perm = [(i, self.axis_size - 1 - i) for i in range(self.axis_size)]

        def exchange(a):
            if a.shape[0] > 1:                 # flip the local party rows
                a = jnp.flip(a, axis=0)
            if self.axis_size > 1:             # exchange across the mesh
                a = lax.ppermute(a, self.axis_name, perm)
            return a

        return jax.tree_util.tree_map(exchange, x)

    def _global_party_index(self, template: jax.Array) -> jax.Array:
        """(local_parties, 1, ..., 1) global party index of each local row."""
        local = jnp.arange(self.local_parties).reshape(
            (self.local_parties,) + (1,) * (template.ndim - 1))
        return lax.axis_index(self.axis_name) * self.local_parties + local

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        return self._global_party_index(template) == p

    def party_slice(self, full: jax.Array) -> jax.Array:
        """Local party rows of a full-party-dim (n_parties, ...) array."""
        if self.local_parties == self.n_parties:
            return full
        start = lax.axis_index(self.axis_name) * self.local_parties
        return lax.dynamic_slice_in_dim(full, start, self.local_parties, 0)


class CountingComm:
    """Transparent wrapper counting rounds (= ``swap`` calls) and bytes.

    ``n_swaps`` is the number of exchanges fired on the base backend and
    ``round_bytes[i]`` the per-party one-direction payload of exchange i;
    ``bytes_tx`` is their sum.  Used by tests/benchmarks to validate the
    protocol against ``costmodel.relu_cost`` and to demonstrate the swap
    reduction of the round-fused engine.
    """

    def __init__(self, base=None):
        self.base = base or SimComm()
        self.n_parties = self.base.n_parties
        self.reset()

    def reset(self) -> None:
        self.n_swaps = 0
        self.round_bytes: List[int] = []

    @property
    def bytes_tx(self) -> int:
        return sum(self.round_bytes)

    def swap(self, x):
        self.n_swaps += 1
        self.round_bytes.append(payload_bytes(x))
        return self.base.swap(x)

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        return self.base.party_is(p, template)

    def party_slice(self, full: jax.Array) -> jax.Array:
        return self.base.party_slice(full)


class CoalescingComm:
    """Deferred-exchange wrapper: one flattened ``swap`` per round.

    Protocol code enqueues the current round's payloads (any pytrees of
    uint32 arrays with the party dimension leading — packed bitplanes,
    Ring64 limb pairs, ...) and receives integer handles; ``flush``
    concatenates every enqueued leaf into a single (P, total_words) buffer,
    fires ONE exchange on the base backend, and returns the per-handle
    swapped payloads with their original structure restored.

    ``swap`` remains available as enqueue-then-flush so unfused callers see
    unchanged semantics (still exactly one round per call).

    Counters (read by tests, the quick benchmark, and the cost-model
    validation): ``n_rounds`` flushes fired, ``round_bytes`` per-party
    one-direction bytes of each flush, ``bytes_tx`` their sum, and
    ``round_parts`` the number of payloads each flush coalesced — the
    round-schedule simulator (``core.schedule``) predicts all three
    sequences exactly, including the payload-count drop when
    ``relu_many`` auto-batches identical sibling streams.
    """

    def __init__(self, base=None):
        self.base = base or SimComm()
        self.n_parties = self.base.n_parties
        self._queue: List[Tuple[List[jax.Array], Any]] = []
        self.n_rounds = 0
        self.round_bytes: List[int] = []
        self.round_parts: List[int] = []

    @property
    def bytes_tx(self) -> int:
        return sum(self.round_bytes)

    def enqueue(self, payload) -> int:
        """Defer a payload to the current round; returns its handle."""
        leaves, treedef = jax.tree_util.tree_flatten(payload)
        for leaf in leaves:
            if leaf.dtype != _U32:
                raise TypeError(
                    f"CoalescingComm payloads must be uint32, got {leaf.dtype}")
        self._queue.append((leaves, treedef))
        return len(self._queue) - 1

    def flush(self) -> List[Any]:
        """Fire the round: one flattened swap; returns payloads by handle."""
        if not self._queue:
            return []
        queue, self._queue = self._queue, []
        flat = [leaf.reshape(leaf.shape[0], -1)
                for leaves, _ in queue for leaf in leaves]
        buf = jnp.concatenate(flat, axis=1) if len(flat) > 1 else flat[0]
        self.n_rounds += 1
        self.round_bytes.append(payload_bytes(buf))
        self.round_parts.append(len(queue))
        opened = self.base.swap(buf)
        results = []
        off = 0
        for leaves, treedef in queue:
            out_leaves = []
            for leaf in leaves:
                n = leaf.size // leaf.shape[0]
                out_leaves.append(opened[:, off:off + n].reshape(leaf.shape))
                off += n
            results.append(jax.tree_util.tree_unflatten(treedef, out_leaves))
        return results

    def swap(self, x):
        """Immediate exchange (enqueue + flush): still one round."""
        h = self.enqueue(x)
        return self.flush()[h]

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        return self.base.party_is(p, template)

    def party_slice(self, full: jax.Array) -> jax.Array:
        return self.base.party_slice(full)
