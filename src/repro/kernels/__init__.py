"""TPU Pallas kernels for the paper's online-phase hot spots (§4.2):

  bitpack     - pack/unpack reduced-ring bitplanes into dense wire words
  gmw_round   - fused Beaver-AND + Kogge-Stone level local evaluation
  ring_matmul - mod-2^64 matmul via balanced 8-bit planes on the MXU

Each kernel has a pure-jnp oracle in ref.py; ops.py is the jit'd wrapper
that dispatches Pallas-on-TPU vs reference-on-CPU.
"""
from . import bitpack, gmw_round, ops, ref, ring_matmul

__all__ = ["bitpack", "gmw_round", "ops", "ref", "ring_matmul"]
