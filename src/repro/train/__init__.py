"""Training substrate: optimizers, loop, fault tolerance."""
from . import loop, optimizer
__all__ = ["loop", "optimizer"]
