"""Production meshes.

Defined as functions (never module-level constants) so importing this
module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mpc_mesh_shape(n_devices: Optional[int] = None) -> Tuple[int, int]:
    """(party, data) axis sizes for an MPC mesh on ``n_devices`` chips.

    The party axis is always 2 (two non-colluding servers); the data axis
    takes half the topology (rounded down to use device pairs), so any
    even-sized slice works — 512 chips gives the paper's (2, 256), 8
    host devices give (2, 4) — instead of the historical hard-coded
    (2, 256) that failed on everything but exactly 512 devices.
    """
    if n_devices is None:
        n_devices = jax.device_count()
    if n_devices < 2:
        raise ValueError(
            f"MPC serving needs >= 2 devices (one per party), got "
            f"{n_devices}; use make_mpc_smoke_mesh() for 1-device CPU runs")
    return (2, n_devices // 2)


def make_mpc_mesh(n_data: Optional[int] = None):
    """MPC serving mesh: party = pod (2 non-colluding servers, each a
    slice used as ``n_data``-way data parallelism over the request batch).
    ``n_data`` defaults to ``jax.device_count() // 2`` (the paper's 512-chip
    topology yields 2 x 256)."""
    if n_data is None:
        _, n_data = mpc_mesh_shape()
    return jax.make_mesh((2, n_data), ("party", "data"),
                         devices=jax.devices()[: 2 * n_data])


def make_mpc_smoke_mesh():
    """1-device MPC mesh with the serving axis names (CPU smoke tests:
    both party shards land on the same device, shardings still resolve,
    and the mesh-native serve path degenerates to local exchanges)."""
    return jax.make_mesh((1, 1), ("party", "data"))


def mpc_serving_mesh():
    """Best MPC mesh the current topology supports: the full
    ``make_mpc_mesh`` (party axis size 2 — one device slice per
    non-colluding server, protocol exchanges are real collectives) when at
    least two devices exist, else the 1-device smoke mesh (party axis size
    1 — exchanges stay local).  Entry point for serving scripts and the
    quick benchmark's mesh-lowering census."""
    return (make_mpc_mesh() if jax.device_count() >= 2
            else make_mpc_smoke_mesh())


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
