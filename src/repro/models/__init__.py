"""Model zoo: unified LM (dense/MoE/SSM/hybrid/VLM), enc-dec, ResNet."""
from . import encdec, lm, resnet
__all__ = ["encdec", "lm", "resnet"]
