import os
import sys

# tests see the default single CPU device (the dry-run alone forces 512)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.default_rng(0)
