"""Deterministic synthetic data pipelines (tokens + images)."""
from . import pipeline
from .pipeline import ImagePipeline, TokenPipeline
__all__ = ["pipeline", "ImagePipeline", "TokenPipeline"]
