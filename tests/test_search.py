"""Search engine invariants: eco is zero-error, budget is respected."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RESNET_SMOKE
from repro.core.hummingbird import HBConfig, HBLayer
from repro.models import resnet
from repro.search import finetune as ft, search_budget, search_eco
from repro.search.simulator import evaluate_accuracy, simulated_hb_relu


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = resnet.init(key, RESNET_SMOKE)
    xs = jax.random.normal(jax.random.PRNGKey(1), (256, 3, 16, 16))
    ys = (xs[:, 0, :8, :8].mean((1, 2)) > 0).astype(jnp.int32)

    def afn(p, x, relu_fn=None):
        return resnet.apply(p, x, RESNET_SMOKE, relu_fn=relu_fn)

    groups = resnet.relu_group_elements(params, RESNET_SMOKE)
    params, _ = ft.finetune(afn, params, xs[:192], ys[:192],
                            HBConfig.exact(groups), jax.random.PRNGKey(5),
                            epochs=4, batch=64, lr=3e-3)
    return afn, params, xs[192:], ys[192:], groups


def test_simulated_relu_matches_protocol_semantics(rng):
    x = jnp.asarray(rng.uniform(-4, 4, (256,)).astype(np.float32))
    out = simulated_hb_relu(x, 21, 0, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(out), np.maximum(np.asarray(x), 0),
                               atol=1e-6)
    out2 = simulated_hb_relu(x, 21, 12, jax.random.PRNGKey(1))
    thresh = 2.0 ** (12 - 16)
    xn = np.asarray(x)
    exact = np.maximum(xn, 0)
    pruned = np.where((xn > 0) & (xn < thresh), 0.0, exact)
    ok = (np.abs(np.asarray(out2) - exact) < 1e-5) | \
         (np.abs(np.asarray(out2) - pruned) < 1e-5)
    assert ok.all()


def test_eco_is_zero_error(setup):
    afn, params, xs, ys, groups = setup
    res = search_eco(afn, params, xs, ys, groups, jax.random.PRNGKey(2))
    assert res.accuracy == res.baseline_accuracy
    assert res.budget_fraction < 0.40  # paper: 66-72% of bits discarded
    assert all(l.m == 0 for l in res.config.layers)


def test_budget_search_respects_budget(setup):
    afn, params, xs, ys, groups = setup
    res = search_budget(afn, params, xs, ys, groups, jax.random.PRNGKey(3),
                        budget=8 / 64, bit_choices=(5, 6, 8))
    assert res.config.meets_budget(8 / 64)
    assert res.accuracy >= res.baseline_accuracy - 0.10
    assert res.nodes_visited > 0


def test_simulated_relu_width0_is_identity(rng):
    x = jnp.asarray(rng.uniform(-4, 4, (64,)).astype(np.float32))
    out = simulated_hb_relu(x, 13, 13, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_budget_search_accepts_plan_and_can_cull(setup):
    """A Plan flows in, the found config flows out attached to the plan;
    width 0 (ReLU culling) is a legal bit choice."""
    from repro import api

    afn, params, xs, ys, groups = setup
    plan = api.trace_plan(afn, params, (4, 3, 16, 16))
    assert list(plan.group_elements) == [g * 4 for g in groups]
    res = search_budget(afn, params, xs, ys, plan, jax.random.PRNGKey(7),
                        budget=6 / 64, bit_choices=(0, 5, 6))
    assert res.plan is not None
    assert res.plan.hb == res.config
    assert res.config.meets_budget(6 / 64)
    # culled groups (if any) must be width 0, priced at zero comm
    for layer in res.config.layers:
        assert layer.width == 0 or layer.width in (5, 6)


def test_eco_search_accepts_plan(setup):
    from repro import api

    afn, params, xs, ys, groups = setup
    plan = api.trace_plan(afn, params, (2, 3, 16, 16))
    res = search_eco(afn, params, xs, ys, plan, jax.random.PRNGKey(8))
    assert res.plan is not None and res.plan.hb == res.config
    assert res.plan.cost().bytes_tx > 0


def test_budget_fallback_respects_max_k(setup):
    """When nothing meets budget+threshold the fallback config must stay
    inside the searched k-range (regression: it hard-coded k=width+13)."""
    afn, params, xs, ys, groups = setup
    max_k = 16
    # impossible threshold: every candidate is pruned by Early stop 1
    res = search_budget(afn, params, xs, ys, groups, jax.random.PRNGKey(9),
                        budget=8 / 64, bit_choices=(0, 4),
                        acc_threshold_drop=-2.0, max_k=max_k)
    assert all(l.k <= max_k for l in res.config.layers)
    assert all(l.width == 4 for l in res.config.layers)
    # width choices beyond max_k clamp to it instead of escaping the range
    res = search_budget(afn, params, xs, ys, groups, jax.random.PRNGKey(9),
                        budget=8 / 64, bit_choices=(20,),
                        acc_threshold_drop=-2.0, max_k=max_k)
    assert all(l.k <= max_k for l in res.config.layers)
    # only width 0 on offer: the fallback is the all-culled identity config
    res = search_budget(afn, params, xs, ys, groups, jax.random.PRNGKey(9),
                        budget=8 / 64, bit_choices=(0,),
                        acc_threshold_drop=-2.0, max_k=max_k)
    assert all(l.is_identity for l in res.config.layers)


def test_latency_objective_never_worse_on_estimate(setup):
    """Acceptance: the latency-objective search returns a Plan whose
    schedule-predicted WAN estimate is <= the bytes-objective Plan's on
    the same (ResNet) grouping — accuracy stays primary in both, but
    accuracy ties keep the fused-round-cheapest config."""
    from repro import api

    afn, params, xs, ys, groups = setup
    plan = api.trace_plan(afn, params, (2, 3, 16, 16))
    kwargs = dict(budget=8 / 64, bit_choices=(0, 5, 6), max_k=12)
    res_b = search_budget(afn, params, xs[:32], ys[:32], plan,
                          jax.random.PRNGKey(11), **kwargs)
    res_l = search_budget(afn, params, xs[:32], ys[:32], plan,
                          jax.random.PRNGKey(11), objective="latency",
                          network=api.WAN, **kwargs)
    assert res_b.objective == "bytes"
    assert res_l.objective == "latency"
    est_l = res_l.plan.estimate(network=api.WAN)
    est_b = res_b.plan.estimate(network=api.WAN)
    assert est_l <= est_b
    # the reported score IS the returned plan's estimate (what you
    # optimize is what estimate() replays)
    assert res_l.objective_value == est_l
    assert res_b.objective_value == float(res_b.plan.cost().bytes_tx)
    # both respect the paper's bits budget regardless of objective
    assert res_l.config.meets_budget(8 / 64)


def test_eco_reports_objective_value(setup):
    import dataclasses

    from repro import api

    afn, params, xs, ys, groups = setup
    plan = api.trace_plan(afn, params, (2, 3, 16, 16))
    res = search_eco(afn, params, xs[:32], ys[:32], plan,
                     jax.random.PRNGKey(12), objective="latency",
                     network="wan")
    assert res.objective == "latency"
    assert res.objective_value == res.plan.estimate(network=api.WAN)
    back = type(res).from_json(res.to_json())
    assert back.objective == "latency"
    assert back.objective_value == res.objective_value
    # cone-traced plans inherit the plan's adder mode in the score, so the
    # what-you-optimize == what-estimate-replays contract holds there too
    cone_plan = dataclasses.replace(plan, cone=True)
    res_c = search_eco(afn, params, xs[:32], ys[:32], cone_plan,
                       jax.random.PRNGKey(12), objective="latency",
                       network="wan")
    assert res_c.plan.cone
    assert res_c.objective_value == res_c.plan.estimate(network=api.WAN)
    assert res_c.objective_value < res.objective_value  # fewer cone rounds


def test_finetune_runs_and_preserves_shapes(setup):
    afn, params, xs, ys, groups = setup
    cfg = HBConfig(tuple(HBLayer(k=19, m=13) for _ in groups), tuple(groups))
    p2, losses = ft.finetune(afn, params, xs, ys, cfg, jax.random.PRNGKey(4),
                             epochs=1, batch=32, lr=1e-3)
    assert len(losses) > 0 and np.isfinite(losses).all()
    same = jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: a.shape == b.shape, params, p2))
    assert same


def test_ordered_bit_choices_wan_puts_culling_first():
    """Exploration order is a pure function of (objective, network):
    width-0 first only when latency is the objective on a
    rounds-dominated (WAN-class) link."""
    from repro.api.plan import LAN, WAN
    from repro.search.engine import _ordered_bit_choices

    assert _ordered_bit_choices((0, 5, 6), "latency", WAN) == [0, 5, 6]
    assert _ordered_bit_choices((6, 0, 5), "latency", WAN) == [0, 5, 6]
    assert _ordered_bit_choices((0, 5, 6), "latency", LAN) == [6, 5, 0]
    assert _ordered_bit_choices((0, 5, 6), "bytes", WAN) == [6, 5, 0]


def test_wan_latency_search_visits_culled_before_dense(setup):
    """Satellite acceptance: under network=WAN the budgeted search
    explores culling-heavy (width-0-first) bit choices, so a width-0
    candidate is visited before any dense fallback; the default
    (bytes/LAN) order is unchanged — widest first."""
    afn, params, xs, ys, groups = setup

    def run(**kw):
        visited = []
        search_budget(afn, params, xs[:32], ys[:32], groups,
                      jax.random.PRNGKey(13), budget=8 / 64,
                      bit_choices=(0, 5, 6), max_k=12,
                      on_visit=visited.append, **kw)
        return visited

    wan = run(objective="latency", network="wan")
    has_cull = [any(l.width == 0 for l in c.layers) for c in wan]
    dense = [all(l.width > 0 for l in c.layers) for c in wan]
    assert has_cull[0], "WAN latency search must try culling group 0 first"
    assert has_cull.index(True) < dense.index(True)

    lan = run(objective="latency", network="lan")
    assert all(l.width > 0 for l in lan[0].layers)  # widest-first retained
    default = run()
    assert all(l.width > 0 for l in default[0].layers)
