"""repro.api — one private-inference API over any model, comm backend,
and triple source.

Three objects organise HummingBird's offline/online contract (PAPER §4):

- **Plan** (`plan.py`): a first-class, JSON-(de)serializable network plan —
  the model's ReLU call trace, the per-group HummingBird (k, m)
  assignment, triple requirements, and the analytic communication cost /
  latency estimate.  Produced by ``trace_plan`` on any
  ``apply(params, x, relu_fn=...)`` model; saved and reloaded with
  ``plan.save(path)`` / ``Plan.load(path)``.
- **Session** (`session.py`): owns the comm backend (SimComm /
  CountingComm / mesh), the PRNG stream, and a ``beaver.TripleProvider``
  (inline, streaming TTP, eager pool) — no call site threads
  ``key``/``comm``/``triples`` by hand.
- **compile** (`compile.py`): binds (model, Plan, Session) into a
  ``PrivateModel`` whose ``__call__`` runs batched private inference with
  ``relu_many`` round-sharing across sibling streams and whose
  ``serve_step()`` lowers the same replay for the mesh backend.

Usage::

    import jax
    from repro import api
    from repro.configs import RESNET_SMOKE
    from repro.models import resnet

    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)

    def afn(p, x, relu_fn=None):
        return resnet.apply(p, x, RESNET_SMOKE, relu_fn=relu_fn)

    # offline: trace the plan, pick/search an HB assignment, persist it
    plan = api.trace_plan(afn, params, (4, 3, 16, 16), name="resnet-smoke")
    plan.save("plan.json")                      # == Plan.load round-trip
    print(plan.cost().bytes_tx, plan.estimate(network=api.WAN))

    # online: one Session, one compile, then just call it
    session = api.Session(key=0)
    model = api.compile(afn, params, RESNET_SMOKE, plan, session)
    X = model.encrypt(jax.random.PRNGKey(1),
                      jax.random.normal(jax.random.PRNGKey(2), (4, 3, 16, 16)))
    logits = model(X).reveal()

    # mesh serving: the same replay as a jit-able step with offline triples
    step = model.serve_step()

New model families plug in by registering their secret-shared forward once
with ``register_mpc_forward(ConfigType, forward)``; everything else
(planning, triples, round sharing, serving) is shared machinery.
"""
from repro.core.hummingbird import HBConfig, HBLayer

from .compile import (PrivateModel, compile, register_mpc_forward,
                      resolve_mpc_forward)
from .plan import (HIGHBW, LAN, NETWORKS, WAN, NetworkPreset, Plan, ReluCall,
                   trace_plan)
from .session import Session

#: serving-engine types re-exported lazily (PEP 562) so that
#: ``repro.api`` and ``repro.serve`` can import each other's submodules
#: without a cycle: ``api.InferenceEngine`` is ``serve.InferenceEngine``.
_SERVE_EXPORTS = ("InferenceEngine", "BatchPolicy", "BatchReport", "Request",
                  "RequestFuture")

__all__ = [
    "Plan", "ReluCall", "trace_plan", "Session", "compile", "PrivateModel",
    "register_mpc_forward", "resolve_mpc_forward", "HBConfig", "HBLayer",
    "NetworkPreset", "NETWORKS", "LAN", "WAN", "HIGHBW",
    *_SERVE_EXPORTS,
]


def __getattr__(name: str):
    if name in _SERVE_EXPORTS:
        from repro.serve import engine as _engine
        return getattr(_engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
