"""Reduced-ring nonlinearity subsystem (nn/approx): PWL lowering of
GELU/SiLU, ReLU attention normalization, and the fixed-point error
bounds — plaintext closed form vs hook path vs MPC replay across a
(k, m) sweep."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MPCTensor, comm as comm_lib, mpc_tensor
from repro.core.hummingbird import HBLayer
from repro.nn import approx
from repro.nn.approx.pwl import _gelu, _silu

FNS = {"silu": _silu, "gelu": _gelu}


def _spec(act):
    return approx.silu_spec() if act == "silu" else approx.gelu_spec()


def _mk_mpc_relu_fn(hb: HBLayer, comm, seed=7):
    """Mini MPC harness implementing the nn/approx hook protocol: one
    relu_many per relu call, one fused products_many per matmul/mul —
    exactly what api.compile wires up for registered forwards."""
    keys = iter(jax.random.split(jax.random.PRNGKey(seed), 512))

    def relu_fn(ts, group):
        return mpc_tensor.relu_many([next(keys) for _ in ts], ts,
                                    comm=comm, hbs=[hb] * len(ts))

    relu_fn.matmul = lambda xs, ys: mpc_tensor.products_many(
        ["matmul"] * len(xs), [next(keys) for _ in xs], xs, ys, comm=comm)
    relu_fn.mul = lambda xs, ys: mpc_tensor.products_many(
        ["mul"] * len(xs), [next(keys) for _ in xs], xs, ys, comm=comm)
    return relu_fn


# ---------------------------------------------------------------------------
# Plaintext closed form
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act,tol", [("silu", 0.02), ("gelu", 0.01)])
def test_pwl_interpolation_accuracy(act, tol):
    spec = _spec(act)
    assert approx.pwl_max_error(spec, FNS[act]) < tol
    # right tail continues with slope 1 (both activations -> identity)
    xs = np.asarray([20.0, 50.0], np.float32)
    np.testing.assert_allclose(np.asarray(approx.eval_pwl(spec, xs)), xs,
                               atol=tol)
    # left tail frozen at f(t_0), which is ~0 for both
    assert abs(float(approx.eval_pwl(spec, -30.0))) < tol


@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_apply_pwl_hook_path_matches_closed_form(act, rng):
    spec = _spec(act)
    x = jnp.asarray(rng.uniform(-10, 10, (4, 17)).astype(np.float32))
    got = approx.apply_pwl(spec, x, 0, approx.ensure_hooks(None))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(approx.eval_pwl(spec, x)),
                               atol=1e-5)


def test_spec_for_resolution():
    assert approx.spec_for("relu") is None
    assert approx.spec_for("silu").name == "silu"
    assert approx.spec_for("gelu").name == "gelu"
    with pytest.raises(ValueError):
        approx.spec_for("swiglu2")


# ---------------------------------------------------------------------------
# MPC closeness across the (k, m) sweep
# ---------------------------------------------------------------------------

# k=22 keeps the Theorem-1 regime: PWL shifts x - t_j reach |x| + 8 <= 14
# here, against a magnitude bound 2^(22-1-16) = 32.
KM_SWEEP = [(64, 0), (22, 0), (22, 8)]


@pytest.mark.parametrize("k,m", KM_SWEEP)
@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_pwl_mpc_matches_plaintext(act, k, m, rng):
    spec = _spec(act)
    x = rng.uniform(-6, 6, (2, 48)).astype(np.float32)
    X = MPCTensor.from_plain(jax.random.PRNGKey(1), jnp.asarray(x))
    relu_fn = _mk_mpc_relu_fn(HBLayer(k=k, m=m), comm_lib.CoalescingComm())
    (out,) = approx.apply_pwl_mpc(spec, [X], 0, relu_fn)
    ref = np.asarray(approx.eval_pwl(spec, X.reveal_np()))
    # m discarded bits can flip the DReLU of the <=2 knots within the
    # margin of x; everything else is fixed-point truncation noise
    tol = 5e-3 + 3 * approx.discard_margin(m)
    np.testing.assert_allclose(out.reveal_np(), ref, atol=tol)
    # and the composition stays close to the true activation
    true = np.vectorize(FNS[act])(X.reveal_np())
    assert np.max(np.abs(out.reveal_np() - true)) < \
        approx.pwl_fixed_point_bound(spec) + 3 * approx.discard_margin(m) + 5e-3


@pytest.mark.parametrize("k,m", KM_SWEEP)
def test_relu_attention_mpc_matches_plaintext(k, m, rng):
    b, h, s, dh = 1, 2, 6, 8
    q = rng.uniform(-1, 1, (b, h, s, dh)).astype(np.float32)
    kk = rng.uniform(-1, 1, (b, h, s, dh)).astype(np.float32)
    v = rng.uniform(-1, 1, (b, h, s, dh)).astype(np.float32)
    ref = np.asarray(approx.relu_attention(
        jnp.asarray(q), jnp.asarray(kk), jnp.asarray(v), 0,
        approx.ensure_hooks(None)))
    Q = MPCTensor.from_plain(jax.random.PRNGKey(2), jnp.asarray(q))
    K = MPCTensor.from_plain(jax.random.PRNGKey(3), jnp.asarray(kk))
    V = MPCTensor.from_plain(jax.random.PRNGKey(4), jnp.asarray(v))
    relu_fn = _mk_mpc_relu_fn(HBLayer(k=k, m=m), comm_lib.CoalescingComm())
    (out,) = approx.relu_attention_mpc([Q], [K], [V], 0, relu_fn)
    # scores are dh^-0.5-scaled products of unit-range values; each Beaver
    # product pays one truncation and the m-discard its margin
    tol = 2e-2 + 3 * approx.discard_margin(m)
    np.testing.assert_allclose(out.reveal_np(), ref, atol=tol)


def test_causal_norm_rows_sum_to_one():
    cn = np.asarray(approx.causal_norm(5))
    assert np.allclose(np.tril(np.ones((5, 5))) * cn, cn)
    assert np.allclose(cn.sum(axis=1), 1.0)


# ---------------------------------------------------------------------------
# Fixed-point error bounds
# ---------------------------------------------------------------------------

def test_bounds_closed_forms():
    assert approx.discard_margin(0) == pytest.approx(2.0 ** -16)
    assert approx.magnitude_bound(22) == pytest.approx(32.0)
    with pytest.raises(ValueError):
        approx.discard_margin(-1)
    for act in ("silu", "gelu"):
        spec = _spec(act)
        interp = approx.pwl_max_error(spec, FNS[act], margin=0.0)
        assert approx.pwl_fixed_point_bound(spec) >= interp


def test_discard_margin_monotone_sweep():
    ms = list(range(0, 24))
    margins = [approx.discard_margin(m) for m in ms]
    assert all(a <= b for a, b in zip(margins, margins[1:]))


def test_discard_margin_monotone_property():
    """Hypothesis property: the fixed-point misclassification margin is
    monotone nondecreasing in the number of discarded bits, for every
    frac_bits the codebase uses."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=200, deadline=None)
    @hyp.given(m1=st.integers(0, 40), m2=st.integers(0, 40),
               frac=st.integers(1, 32))
    def prop(m1, m2, frac):
        lo, hi = sorted((m1, m2))
        assert (approx.discard_margin(lo, frac)
                <= approx.discard_margin(hi, frac))
        # doubling the discarded bits exactly doubles the margin
        assert approx.discard_margin(lo + 1, frac) == pytest.approx(
            2 * approx.discard_margin(lo, frac))

    prop()
