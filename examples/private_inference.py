"""End-to-end driver (the paper's kind: serving): train a ResNet, run the
HummingBird offline phase (search + finetune), then serve batched private
inference requests through the real GMW protocol via the Plan/Session/
compile API and report accuracy + communication vs the exact baseline.

The offline artifact is a first-class ``repro.api.Plan``: pass --plan-out
to save the searched plan as JSON and --plan-in to reuse it in a later run
(skipping the search).

    PYTHONPATH=src python examples/private_inference.py [--requests 16]
    PYTHONPATH=src python examples/private_inference.py --plan-out plan.json
    PYTHONPATH=src python examples/private_inference.py --plan-in plan.json
    PYTHONPATH=src python examples/private_inference.py \
        --objective latency --network wan
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import api
from repro.configs import RESNET_SMOKE
from repro.core import costmodel
from repro.data import ImagePipeline
from repro.models import resnet
from repro.search import finetune as ft, search_budget
from repro.search.simulator import evaluate_accuracy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--budget", type=float, default=8 / 64)
    ap.add_argument("--plan-out", type=str, default=None,
                    help="save the searched Plan (JSON) here")
    ap.add_argument("--plan-in", type=str, default=None,
                    help="reuse a saved Plan instead of searching")
    ap.add_argument("--objective", choices=("bytes", "latency"),
                    default="bytes",
                    help="what the search scores candidate configs by: "
                         "'bytes' (total wire bytes, the paper's proxy) or "
                         "'latency' (schedule-predicted fused-round latency "
                         "under --network — what the round-dominated serving "
                         "path actually pays; accuracy ties keep the "
                         "latency-minimal config)")
    ap.add_argument("--network", choices=("lan", "wan", "highbw"),
                    default="wan",
                    help="network preset for --objective latency "
                         "(paper §5.2: WAN is where rounds dominate)")
    args = ap.parse_args()

    # --- setup: model + data -------------------------------------------------
    pipe = ImagePipeline(n_classes=10, hw=RESNET_SMOKE.in_hw)
    xs, ys = pipe.take(512)
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)

    def afn(p, x, relu_fn=None):
        return resnet.apply(p, x, RESNET_SMOKE, relu_fn=relu_fn)

    print("[1/4] training the plaintext model...")
    plan = api.trace_plan(afn, params,
                          (args.requests, 3, RESNET_SMOKE.in_hw,
                           RESNET_SMOKE.in_hw), name=RESNET_SMOKE.name)
    params, _ = ft.finetune(afn, params, xs[:384], ys[:384], plan.hb,
                            jax.random.PRNGKey(1), epochs=4, batch=64,
                            lr=3e-3)
    base_acc = evaluate_accuracy(afn, params, xs[384:], ys[384:], plan.hb,
                                 jax.random.PRNGKey(2))
    print(f"      baseline accuracy: {base_acc:.3f}")

    # --- offline phase: search (or reload a saved plan) + finetune -----------
    if args.plan_in:
        loaded = api.Plan.load(args.plan_in)
        if loaded.hb.n_groups != plan.hb.n_groups:
            raise SystemExit(
                f"--plan-in {args.plan_in}: saved plan has "
                f"{loaded.hb.n_groups} ReLU groups but this model traces "
                f"{plan.hb.n_groups} — it was searched for a different "
                "model/config")
        # adopt the saved (k, m) assignment (and adder mode) onto this
        # run's fresh trace so cost accounting matches the request batch
        plan = dataclasses.replace(
            plan.with_hb(api.HBConfig(loaded.hb.layers,
                                      plan.hb.group_elements)),
            cone=loaded.cone)
        print(f"[2/4] reusing saved plan {args.plan_in}: "
              f"{[(l.k, l.m) for l in plan.hb.layers]} "
              f"({plan.hb.budget_fraction():.3f} of bits)")
    else:
        print(f"[2/4] HummingBird-b search (budget {args.budget:.3f}, "
              f"objective {args.objective})...")
        res = search_budget(afn, params, xs[384:448], ys[384:448], plan,
                            jax.random.PRNGKey(3), budget=args.budget,
                            bit_choices=(6, 8), objective=args.objective,
                            network=args.network)
        plan = res.plan
        unit = "B" if res.objective == "bytes" else "s"
        print(f"      found {[(l.k, l.m) for l in plan.hb.layers]} "
              f"({plan.hb.budget_fraction():.3f} of bits, "
              f"{res.objective}={res.objective_value:.4g}{unit}, "
              f"{res.search_time_s:.1f}s)")
    if args.plan_out:
        plan.save(args.plan_out)
        print(f"      plan saved to {args.plan_out}")
    params, _ = ft.finetune(afn, params, xs[:384], ys[:384], plan.hb,
                            jax.random.PRNGKey(4), epochs=1, batch=64)

    # --- online phase: batched private inference -----------------------------
    print(f"[3/4] serving {args.requests} private requests (real GMW)...")
    req_x, req_y = xs[448:448 + args.requests], ys[448:448 + args.requests]
    session = api.Session(key=7)
    model = api.compile(afn, params, RESNET_SMOKE, plan, session)
    t0 = time.time()
    X = model.encrypt(jax.random.PRNGKey(5), req_x)
    out = model(X, key=jax.random.PRNGKey(6))
    pred = np.argmax(out.reveal_np(), -1)
    wall = time.time() - t0
    acc = float((pred == np.asarray(req_y)).mean())
    plain_pred = np.argmax(np.asarray(afn(params, req_x)), -1)
    agree = float((pred == plain_pred).mean())

    # --- report ----------------------------------------------------------------
    print("[4/4] results")
    r = costmodel.reduction_factors(plan.hb)
    print(f"      private-inference accuracy: {acc:.3f} "
          f"(plaintext agreement {agree:.3f})")
    print(f"      comm reduction vs CrypTen-64: {r['bytes_reduction']:.2f}x "
          f"bytes, {r['rounds_reduction']:.2f}x rounds, "
          f"{r['bits_discarded_frac']*100:.1f}% of DReLU bits discarded")
    sched = plan.schedule()
    print(f"      plan schedule: {sched.n_rounds} fused rounds, "
          f"{plan.cost().bytes_tx / 1e6:.1f} MB/party, "
          f"LAN {plan.estimate(network=api.LAN)*1e3:.1f} ms, "
          f"WAN {plan.estimate(network=api.WAN):.2f} s")
    print(f"      wall time (CPU sim, both parties): {wall:.1f}s")


if __name__ == "__main__":
    main()
