"""MPC launch-layer fixes: topology-derived mesh sizing (any even device
count instead of the hard-coded 512) and triple shardings derived from
the ReluTriples structure instead of pytree-path strings / shape==2
heuristics."""
import jax
import pytest

from repro.configs import RESNET_SMOKE
from repro.core import beaver
from repro.launch import serve as serve_lib
from repro.launch.mesh import (make_mpc_smoke_mesh, make_smoke_mesh,
                               mpc_mesh_shape)


# ---------------------------------------------------------------------------
# Mesh sizing
# ---------------------------------------------------------------------------

def test_mpc_mesh_shape_derives_data_axis_from_devices():
    assert mpc_mesh_shape(512) == (2, 256)     # the paper's topology
    assert mpc_mesh_shape(8) == (2, 4)
    assert mpc_mesh_shape(2) == (2, 1)
    assert mpc_mesh_shape(7) == (2, 3)         # odd counts round down


def test_mpc_mesh_shape_rejects_single_device():
    with pytest.raises(ValueError, match="make_mpc_smoke_mesh"):
        mpc_mesh_shape(1)


def test_smoke_meshes_have_serving_axis_names():
    mpc = make_mpc_smoke_mesh()
    assert mpc.axis_names == ("party", "data")
    assert mpc.devices.size == 1
    prod = make_smoke_mesh()
    assert prod.axis_names == ("data", "model")


# ---------------------------------------------------------------------------
# Structural triple shardings
# ---------------------------------------------------------------------------

def _party_dims(spec):
    return [i for i, s in enumerate(spec) if s == "party"]


def _specs_for(hb, cone):
    mesh = make_mpc_smoke_mesh()
    with mesh:
        return serve_lib.mpc_input_specs(RESNET_SMOKE, 2, mesh, hb,
                                         cone=cone)


@pytest.mark.parametrize("cone", [False, True])
def test_triple_shardings_are_structural(cone):
    """Every ReluTriples member is party-sharded on the dim its structure
    fixes: leading for bin_init/arith/cone levels, second (behind the
    stacked L axis) for dense bin_levels — regardless of any other dim
    that happens to have size 2 (the old string/shape heuristic's bug)."""
    params, lo, hi, triples, key = _specs_for(None, cone)
    assert lo.sharding.spec == ("party", "data")
    checked = 0
    for bundle in triples:
        if bundle is None:
            continue
        for leaf in jax.tree_util.tree_leaves(bundle.bin_init):
            assert _party_dims(leaf.sharding.spec) == [0]
        if isinstance(bundle.bin_levels, beaver.BinTriple):   # dense stack
            for leaf in jax.tree_util.tree_leaves(bundle.bin_levels):
                assert _party_dims(leaf.sharding.spec) == [1]
                assert leaf.shape[1] == 2                     # the party dim
        else:                                                 # cone: ragged
            for level in bundle.bin_levels:
                for leaf in jax.tree_util.tree_leaves(level):
                    assert _party_dims(leaf.sharding.spec) == [0]
        for arith in (bundle.b2a, bundle.mult):
            for leaf in jax.tree_util.tree_leaves(arith):
                assert _party_dims(leaf.sharding.spec) == [0]
        checked += 1
    assert checked > 0
    # cone plans exercise the ragged per-level layout the old
    # "bin_levels in path => dim 1" heuristic mis-sharded
    if cone:
        assert any(not isinstance(b.bin_levels, beaver.BinTriple)
                   for b in triples if b is not None)


def test_mpc_serve_step_lowers_on_smoke_mesh():
    """The (party, data) smoke mesh + structural shardings survive a real
    jit lowering of the serving step on one CPU device."""
    mesh = make_mpc_smoke_mesh()
    with mesh:
        params, lo, hi, triples, key = serve_lib.mpc_input_specs(
            RESNET_SMOKE, 2, mesh, None)
        step = serve_lib.make_mpc_serve_step(RESNET_SMOKE, None)
        lowered = jax.jit(step).lower(params, lo, hi, triples, key)
    assert lowered is not None
