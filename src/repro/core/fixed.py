"""Fixed-point codec between floats and Z/2^64 ring elements.

CrypTen encodes x_f as x = round(x_f * 2^16) on a 64-bit ring.  We keep the
same default scale so the paper's k in [18, 22] regime is directly
reproducible (activations |x_f| < 2^(k-17) keep Theorem 1 exact).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import ring

DEFAULT_FRAC_BITS = 16


def encode(x_f: jax.Array, frac_bits: int = DEFAULT_FRAC_BITS) -> ring.Ring64:
    """float -> ring. Requires |x_f * 2^frac| < 2^31 (always true for DNN
    activations/weights at the CrypTen scale)."""
    xi = jnp.round(x_f.astype(jnp.float32) * (2.0 ** frac_bits)).astype(jnp.int32)
    return ring.from_int32(xi)


def decode(x: ring.Ring64, frac_bits: int = DEFAULT_FRAC_BITS) -> jax.Array:
    """ring -> float32 (in-jit, approximate above 2^24 magnitudes)."""
    sign = ring.is_negative(x)
    mag = ring.where(sign.astype(bool), ring.neg(x), x)
    val = mag.hi.astype(jnp.float32) * (2.0 ** 32) + mag.lo.astype(jnp.float32)
    val = jnp.where(sign.astype(bool), -val, val)
    return val / (2.0 ** frac_bits)


def decode_np(x: ring.Ring64, frac_bits: int = DEFAULT_FRAC_BITS) -> np.ndarray:
    """Exact host-side decode via numpy int64 (test oracle)."""
    u = ring.to_uint64_np(x)
    s = u.view(np.int64) if u.dtype == np.uint64 else u.astype(np.int64)
    return s.astype(np.float64) / (2.0 ** frac_bits)


def encode_np(x_f: np.ndarray, frac_bits: int = DEFAULT_FRAC_BITS) -> ring.Ring64:
    """Exact host-side encode via numpy (test oracle)."""
    xi = np.round(np.asarray(x_f, np.float64) * 2.0 ** frac_bits).astype(np.int64)
    return ring.from_uint64_np(xi.view(np.uint64))
