"""§4.1.2 search engine: HummingBird-eco and HummingBird-b.

HummingBird-eco: keep m = 0 and pick, per ReLU group, the smallest k with
zero sign-estimation error on the validation set (Theorem 1: k such that
-2^(k-1) <= x_int < 2^(k-1); searched in O(N) per group by validating
decreasing k until the outputs change).

HummingBird-b: DFS over per-group bit assignments with
  - locally-optimal (k, m): previous groups fixed to their found values,
    later groups optimistic (no bits dropped), enumerate the (k, m) pairs
    with k - m = assigned bits and keep the best validation accuracy;
  - Early stop 1: optimistic accuracy below the absolute threshold;
  - Early stop 2: optimistic accuracy below the best complete config;
  - Early stop 3: budget exceeded (bits weighted by group element counts).

Scheduling-aware objective: serving latency is round-dominated, not
byte-dominated (paper Fig. 3/4), so ``objective="latency"`` scores
candidate configs by the schedule-predicted fused-round latency of the
plan replay under a LAN/WAN ``network`` preset (``core.schedule`` via
``simulator.config_objective``) instead of the byte-proxy bits budget
alone.  Accuracy stays the primary criterion; among equally accurate
configs the search keeps the objective-minimal one (Early stop 2 then
prunes only strictly-worse branches so accuracy ties stay explorable),
and the returned Plan's ``estimate()`` is exactly the metric that was
optimized.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.api.plan import LAN, NETWORKS, NetworkPreset, Plan
from repro.core.hummingbird import HBConfig, HBLayer, RING_BITS, safe_k
from . import simulator


@dataclasses.dataclass
class SearchResult:
    config: HBConfig
    accuracy: float
    baseline_accuracy: float
    budget_fraction: float
    search_time_s: float
    nodes_visited: int
    nodes_pruned: int
    plan: Optional[Plan] = None   # set when the search was given a Plan
    objective: str = "bytes"      # what the search scored configs by
    objective_value: Optional[float] = None   # schedule-predicted score of
    # the returned config: total wire bytes, or fused-round latency (s)
    # under the requested network preset

    def to_json(self) -> Dict:
        return {"config": self.config.to_json(),
                "accuracy": self.accuracy,
                "baseline_accuracy": self.baseline_accuracy,
                "budget_fraction": self.budget_fraction,
                "search_time_s": self.search_time_s,
                "nodes_visited": self.nodes_visited,
                "nodes_pruned": self.nodes_pruned,
                "objective": self.objective,
                "objective_value": self.objective_value,
                "plan": self.plan.to_json() if self.plan is not None else None}

    @staticmethod
    def from_json(d: Dict) -> "SearchResult":
        return SearchResult(
            config=HBConfig.from_json(d["config"]),
            accuracy=float(d["accuracy"]),
            baseline_accuracy=float(d["baseline_accuracy"]),
            budget_fraction=float(d["budget_fraction"]),
            search_time_s=float(d["search_time_s"]),
            nodes_visited=int(d["nodes_visited"]),
            nodes_pruned=int(d["nodes_pruned"]),
            objective=str(d.get("objective", "bytes")),
            objective_value=(float(d["objective_value"])
                             if d.get("objective_value") is not None else None),
            plan=(Plan.from_json(d["plan"])
                  if d.get("plan") is not None else None))


def _eval(apply_fn, params, xs, ys, cfg, key):
    return simulator.evaluate_accuracy(apply_fn, params, xs, ys, cfg, key)


def _groups_and_plan(group_elements: Union[Plan, Sequence[int]]):
    """Search entry points accept either raw per-group element counts or a
    ``repro.api.Plan`` (whose found config is attached to the result)."""
    if isinstance(group_elements, Plan):
        return list(group_elements.group_elements), group_elements
    return list(group_elements), None


def _result(cfg: HBConfig, plan: Optional[Plan], **kw) -> SearchResult:
    return SearchResult(config=cfg, budget_fraction=cfg.budget_fraction(),
                        plan=plan.with_hb(cfg) if plan is not None else None,
                        **kw)


#: RTT at which serving latency is round-dominated (the paper's §5.2 WAN
#: preset is 20 ms; LAN is 50 us — three orders below the threshold).
ROUNDS_DOMINATED_RTT_S = 1e-3


def _resolve_preset(network: Union[NetworkPreset, str, None]) -> NetworkPreset:
    if network is None:
        network = LAN
    return NETWORKS[network] if isinstance(network, str) else network


def _ordered_bit_choices(bit_choices: Sequence[int], objective: str,
                         preset: NetworkPreset) -> List[int]:
    """Exploration order of the per-group width choices.

    Default (bytes objective, or latency on a LAN-class link): widest
    first — the accuracy-optimistic order, dense configs establish a high
    accuracy incumbent early so Early stop 2 prunes aggressively.

    Latency objective on a rounds-dominated network (WAN-class RTT):
    width-0 first, ascending — culling a ReLU group erases *all* of its
    fused rounds, which under a 20 ms RTT dwarfs any byte saving a
    narrower-but-nonzero window offers, so culling-heavy branches must
    reach complete configs before the byte-cheap dense fallbacks are even
    visited (the accuracy criterion still decides what is *kept*; the
    order decides which equally-accurate config the tie-break sees first
    and how early schedule-cheap incumbents start pruning).
    """
    chosen = sorted({int(w) for w in bit_choices})
    if objective == "latency" and preset.rtt_s >= ROUNDS_DOMINATED_RTT_S:
        return chosen
    return list(reversed(chosen))


def _objective_scorer(objective: str,
                      network: Union[NetworkPreset, str, None],
                      plan: Optional[Plan], group_elements: Sequence[int],
                      streams: int, cone: Optional[bool]):
    """Config -> schedule-predicted score under the chosen objective.

    With a traced Plan the score replays the plan's actual ReLU call
    sites (and, unless overridden, its adder mode — ``cone=None``
    inherits ``plan.cone`` so the score equals what ``plan.estimate()``
    replays); with raw group element counts each group degrades to one
    pseudo-call.  ``network`` resolves a LAN/WAN/HIGHBW preset (default
    LAN) for the latency objective and is ignored for bytes.
    """
    if objective not in ("bytes", "latency"):
        raise ValueError(f"unknown objective {objective!r} "
                         "(expected 'bytes' or 'latency')")
    if cone is None:
        cone = plan.cone if plan is not None else False
    if plan is not None and plan.calls:
        calls: List[Tuple[int, int]] = [(c.n_elements, c.group)
                                        for c in plan.calls]
    else:
        calls = list(enumerate(group_elements))
        calls = [(n, g) for g, n in calls]
    preset = _resolve_preset(network)

    def score(cfg: HBConfig) -> float:
        return simulator.config_objective(
            cfg, calls, objective=objective,
            bandwidth_bps=preset.bandwidth_bps, rtt_s=preset.rtt_s,
            streams=streams, cone=cone)

    return score


def search_eco(apply_fn, params, xs, ys,
               group_elements: Union[Plan, Sequence[int]],
               key, margin_bits: int = 1, *, objective: str = "bytes",
               network: Union[NetworkPreset, str, None] = None,
               streams: int = 1, cone: Optional[bool] = None) -> SearchResult:
    """Zero-error config: per-group smallest k whose validation *outputs*
    are bit-identical to the exact model (the paper's eco criterion), m=0.

    ``group_elements`` may be a ``repro.api.Plan`` (traced offline); the
    result then carries ``plan.with_hb(found_config)`` ready to save.

    Eco's selection is objective-agnostic — the smallest zero-error k per
    group minimizes bytes and rounds simultaneously — but ``objective``/
    ``network``/``streams`` choose which schedule-predicted serving metric
    ``result.objective_value`` reports (total wire bytes, or fused-round
    latency in seconds under the preset)."""
    t0 = time.time()
    group_elements, plan = _groups_and_plan(group_elements)
    score = _objective_scorer(objective, network, plan, group_elements,
                              streams, cone)
    n_groups = len(group_elements)
    base_cfg = HBConfig.exact(group_elements)
    base_acc = _eval(apply_fn, params, xs, ys, base_cfg, key)
    ref_logits = apply_fn(params, xs, relu_fn=None)
    max_ints = simulator.max_activation_ints(apply_fn, params, xs, n_groups)

    def outputs_intact(cfg: HBConfig) -> bool:
        relu_fn = simulator.make_group_relu(cfg, key)
        logits = apply_fn(params, xs, relu_fn=relu_fn)
        return bool(jnp.array_equal(logits, ref_logits))

    layers = []
    nodes = 0
    for g in range(n_groups):
        k = safe_k(max_ints[g], m=0, margin_bits=margin_bits)
        # validate downward: shrink while the validation outputs are intact
        while k > 2:
            cand = list(layers) + [HBLayer(k=k - 1, m=0)] + \
                [HBLayer() for _ in range(n_groups - g - 1)]
            cfg = HBConfig(tuple(cand), tuple(group_elements))
            nodes += 1
            if not outputs_intact(cfg):
                break
            k -= 1
        layers.append(HBLayer(k=k, m=0))
    cfg = HBConfig(tuple(layers), tuple(group_elements))
    acc = _eval(apply_fn, params, xs, ys, cfg, key)
    return _result(cfg, plan, accuracy=acc, baseline_accuracy=base_acc,
                   search_time_s=time.time() - t0, nodes_visited=nodes,
                   nodes_pruned=0, objective=objective,
                   objective_value=score(cfg))


def search_budget(apply_fn, params, xs, ys,
                  group_elements: Union[Plan, Sequence[int]],
                  key, budget: float, *, acc_threshold_drop: float = 0.10,
                  bit_choices: Optional[Sequence[int]] = None,
                  max_k: int = 28, objective: str = "bytes",
                  network: Union[NetworkPreset, str, None] = None,
                  streams: int = 1, cone: Optional[bool] = None,
                  on_visit=None) -> SearchResult:
    """HummingBird-b: budgeted DFS with locally-optimal (k, m).

    ``bit_choices`` may include 0: the group's ReLU is then *culled*
    entirely (width-0 identity layer, zero rounds/bytes at serve time —
    the `relu_many`-friendly choice the round-fused engine exploits).
    ``group_elements`` may be a ``repro.api.Plan``; the result then
    carries ``plan.with_hb(found_config)``.

    ``objective="latency"`` scores complete configs by schedule-predicted
    fused-round latency under ``network`` (LAN default; the paper's §5.2
    WAN preset is where rounds dominate) for ``streams`` auto-batched
    sibling streams: accuracy remains primary, but accuracy ties keep the
    latency-minimal config, and Early stop 2 prunes only *strictly* worse
    branches so ties stay explorable.  The bits budget (Early stop 3)
    is unchanged — it is the paper's offline constraint; the objective
    decides which config *within* the budget is returned, and
    ``result.objective_value`` (= ``result.plan.estimate(network=...)``
    for traced plans) reports exactly what was optimized.

    Exploration order follows ``_ordered_bit_choices``: widest-first by
    default, but width-0-first under ``objective="latency"`` on a
    rounds-dominated (WAN-class) network, where culling a group's rounds
    beats any byte saving.  ``on_visit(cfg)`` — when given — is called
    with every candidate ``HBConfig`` evaluated, in visit order (search
    introspection; the WAN-ordering regression test hooks in here).
    """
    t0 = time.time()
    group_elements, plan = _groups_and_plan(group_elements)
    score = _objective_scorer(objective, network, plan, group_elements,
                              streams, cone)
    latency_ties = objective == "latency"
    n_groups = len(group_elements)
    elements = np.asarray(group_elements, np.float64)
    total_bits = RING_BITS * elements.sum()
    base_cfg = HBConfig.exact(group_elements)
    base_acc = _eval(apply_fn, params, xs, ys, base_cfg, key)
    threshold = base_acc - acc_threshold_drop
    bit_choices = _ordered_bit_choices(bit_choices or (0, 4, 5, 6, 8, 10),
                                       objective, _resolve_preset(network))

    best: dict = {"acc": -1.0, "metric": float("inf"), "layers": None}
    stats = {"visited": 0, "pruned": 0}

    def _visit(cfg: HBConfig) -> None:
        stats["visited"] += 1
        if on_visit is not None:
            on_visit(cfg)

    def local_best(prefix: List[HBLayer], g: int, width: int):
        """Locally-optimal (k, m) with k - m = width for group g."""
        if width == 0:
            # culling: every k = m is the same identity layer
            cand = prefix + [HBLayer(k=0, m=0)] + \
                [HBLayer() for _ in range(n_groups - g - 1)]
            cfg = HBConfig(tuple(cand), tuple(group_elements))
            _visit(cfg)
            return HBLayer(k=0, m=0), _eval(apply_fn, params, xs, ys, cfg,
                                            key)
        best_local = (None, -1.0)
        for k in range(width, max_k + 1):
            m = k - width
            cand = prefix + [HBLayer(k=k, m=m)] + \
                [HBLayer() for _ in range(n_groups - g - 1)]
            cfg = HBConfig(tuple(cand), tuple(group_elements))
            _visit(cfg)
            acc = _eval(apply_fn, params, xs, ys, cfg, key)
            if acc > best_local[1]:
                best_local = (HBLayer(k=k, m=m), acc)
        return best_local

    def dfs(prefix: List[HBLayer], g: int, bits_used: float):
        if g == n_groups:
            cfg = HBConfig(tuple(prefix), tuple(group_elements))
            # complete configs stay out of nodes_visited (historical
            # counter counts local_best candidates only) but are visible
            # to the introspection hook
            if on_visit is not None:
                on_visit(cfg)
            acc = _eval(apply_fn, params, xs, ys, cfg, key)
            if acc > best["acc"]:
                best["acc"] = acc
                best["metric"] = score(cfg) if latency_ties else None
                best["layers"] = tuple(prefix)
            elif latency_ties and acc == best["acc"]:
                metric = score(cfg)    # lazily: ties only, never for bytes
                if metric < best["metric"]:
                    best["metric"] = metric
                    best["layers"] = tuple(prefix)
            return
        for width in bit_choices:
            new_bits = bits_used + width * elements[g]
            # Early stop 3: even zero bits for the rest exceeds the budget
            if new_bits > budget * total_bits:
                stats["pruned"] += 1
                continue
            layer, opt_acc = local_best(prefix, g, width)
            if opt_acc < threshold:            # Early stop 1
                stats["pruned"] += 1
                continue
            # Early stop 2: for the latency objective, equal-accuracy
            # branches stay open so the tie-break can pick the
            # schedule-cheapest complete config
            if (opt_acc < best["acc"] if latency_ties
                    else opt_acc <= best["acc"]):
                stats["pruned"] += 1
                continue
            dfs(prefix + [layer], g + 1, new_bits)

    dfs([], 0, 0.0)
    if best["layers"] is None:
        # Nothing met the budget+threshold; fall back to the uniform
        # smallest non-zero width, placing each group's window at the
        # largest k with zero sign-estimation error (Theorem 1 via safe_k)
        # clamped to the searched k-range — never beyond max_k.  With only
        # width 0 on offer, the fallback is the all-culled identity config.
        width = min(min((w for w in bit_choices if w > 0), default=0),
                    max_k)
        if width == 0:
            best["layers"] = tuple(HBLayer(k=0, m=0)
                                   for _ in range(n_groups))
        else:
            max_ints = simulator.max_activation_ints(apply_fn, params, xs,
                                                     n_groups)
            layers = []
            for g in range(n_groups):
                k = width
                for _ in range(4):   # safe_k's headroom term depends on m
                    k_next = max(width, min(max_k,
                                            safe_k(max_ints[g],
                                                   m=k - width)))
                    if k_next == k:
                        break
                    k = k_next
                layers.append(HBLayer(k=k, m=k - width))
            best["layers"] = tuple(layers)
        best["acc"] = _eval(apply_fn, params, xs, ys,
                            HBConfig(best["layers"], tuple(group_elements)),
                            key)
    cfg = HBConfig(best["layers"], tuple(group_elements))
    return _result(cfg, plan, accuracy=best["acc"], baseline_accuracy=base_acc,
                   search_time_s=time.time() - t0,
                   nodes_visited=stats["visited"],
                   nodes_pruned=stats["pruned"], objective=objective,
                   objective_value=score(cfg))
