"""Encoder-decoder transformer (seamless-m4t backbone).

The audio frontend is a stub per the brief: the encoder consumes
precomputed frame embeddings (B, S_src, d_model).  The decoder is a
standard causal transformer with cross-attention into the encoder memory;
its FFN uses ReLU (the one assigned arch where HummingBird's technique is
*directly* applicable, see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn import attention, common
from repro.models.lm import padded_vocab


def _norm_init(cfg, d):
    return (common.layernorm_init(d) if cfg.norm == "layernorm"
            else common.rmsnorm_init(d))


def _norm(cfg, p, x):
    return (common.layernorm(p, x) if cfg.norm == "layernorm"
            else common.rmsnorm(p, x))


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _sin_posenc(s, d, dtype):
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    pe = jnp.zeros((s, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe.astype(dtype)


def _enc_layer_init(key, cfg):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "ln1": _norm_init(cfg, d),
        "attn": attention.attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.resolved_head_dim, dtype=_dtype(cfg)),
        "ln2": _norm_init(cfg, d),
        "mlp": common.mlp_init(ks[1], d, cfg.d_ff, cfg.gated_mlp, _dtype(cfg)),
    }


def _dec_layer_init(key, cfg):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p = _enc_layer_init(ks[0], cfg)
    p["ln_x"] = _norm_init(cfg, d)
    p["xattn"] = attention.attn_init(ks[1], d, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.resolved_head_dim, dtype=_dtype(cfg))
    return p


def init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": common.embed_init(ks[2], padded_vocab(cfg), cfg.d_model, _dtype(cfg)),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "enc_norm": _norm_init(cfg, cfg.d_model),
        "final_norm": _norm_init(cfg, cfg.d_model),
        "lm_head": common.dense_init(ks[3], cfg.d_model, padded_vocab(cfg), _dtype(cfg)),
    }


def _self_attn_full(cfg, p, x, causal: bool):
    b, s, _ = x.shape
    q, k, v = attention._project_qkv(
        p, x, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim,
        jnp.arange(s)[None, :], cfg.rope_theta)
    if causal:
        o = attention.flash_attention(q, k, v, q_offset=0,
                                      chunk_q=cfg.attn_chunk_q,
                                      chunk_k=cfg.attn_chunk_k)
    else:
        o = _bidir_attention(q, k, v)
    return common.dense(p["wo"], o.reshape(b, s, -1))


def _bidir_attention(q, k, v):
    b, s, h, dh = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    qh = q.reshape(b, s, n_kv, g, dh).astype(jnp.float32)
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qh, k.astype(jnp.float32)) * dh ** -0.5
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, s, h, dh).astype(q.dtype)


def _cross_attn(cfg, p, x, mem_k, mem_v):
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    q = common.dense(p["wq"], x).reshape(b, s, cfg.n_heads, dh)
    g = cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(b, s, cfg.n_kv_heads, g, dh).astype(jnp.float32)
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qh,
                    mem_k.astype(jnp.float32)) * dh ** -0.5
    pr = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pr, mem_v.astype(jnp.float32))
    o = o.reshape(b, s, cfg.n_heads * dh).astype(x.dtype)
    return common.dense(p["wo"], o)


def encode(params, src_embeds, cfg: ArchConfig):
    """src_embeds: (B, S_src, D) stub frame embeddings -> encoder memory."""
    h = src_embeds + _sin_posenc(src_embeds.shape[1], cfg.d_model,
                                 src_embeds.dtype)

    def body(carry, layer_p):
        x = _norm(cfg, layer_p["ln1"], carry)
        a = _self_attn_full(cfg, layer_p["attn"], x, causal=False)
        h2 = carry + a
        f = common.mlp(layer_p["mlp"], _norm(cfg, layer_p["ln2"], h2), cfg.act)
        return h2 + f, None

    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return _norm(cfg, params["enc_norm"], h)


def _memory_kv(params, memory, cfg):
    """Precompute per-layer cross-attention K/V from the encoder memory."""
    b, s, _ = memory.shape
    dh = cfg.resolved_head_dim

    def per_layer(layer_p):
        k = common.dense(layer_p["xattn"]["wk"], memory).reshape(
            b, s, cfg.n_kv_heads, dh)
        v = common.dense(layer_p["xattn"]["wv"], memory).reshape(
            b, s, cfg.n_kv_heads, dh)
        return k, v

    return jax.vmap(per_layer)(params["dec_layers"])


def apply(params, src_embeds, tgt_tokens, cfg: ArchConfig):
    """Training forward: (B,S_src,D) embeds + (B,S_tgt) ids -> logits."""
    memory = encode(params, src_embeds, cfg)
    mem_k, mem_v = _memory_kv(params, memory, cfg)
    h = common.embed(params["embed"], tgt_tokens)
    h = h + _sin_posenc(h.shape[1], cfg.d_model, h.dtype)

    def body(carry, xs):
        layer_p, mk, mv = xs
        x = _norm(cfg, layer_p["ln1"], carry)
        h2 = carry + _self_attn_full(cfg, layer_p["attn"], x, causal=True)
        x = _norm(cfg, layer_p["ln_x"], h2)
        h3 = h2 + _cross_attn(cfg, layer_p["xattn"], x, mk, mv)
        f = common.mlp(layer_p["mlp"], _norm(cfg, layer_p["ln2"], h3), cfg.act)
        return h3 + f, None

    h, _ = jax.lax.scan(body, h, (params["dec_layers"], mem_k, mem_v))
    h = _norm(cfg, params["final_norm"], h)
    return common.dense(params["lm_head"], h)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, src_len: int):
    kv = attention.init_kv_cache(batch, max_len, cfg.n_kv_heads,
                                 cfg.resolved_head_dim)
    dh = cfg.resolved_head_dim
    return {
        "self_kv": jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t, (cfg.n_layers,) + t.shape).copy(), kv),
        "mem_k": jnp.zeros((cfg.n_layers, batch, src_len, cfg.n_kv_heads, dh),
                           jnp.bfloat16),
        "mem_v": jnp.zeros((cfg.n_layers, batch, src_len, cfg.n_kv_heads, dh),
                           jnp.bfloat16),
    }


def prefill(params, src_embeds, cfg: ArchConfig, batch: int, max_len: int):
    """Encode source and build the decoder cache (cross K/V + empty self)."""
    memory = encode(params, src_embeds, cfg)
    mem_k, mem_v = _memory_kv(params, memory, cfg)
    cache = init_cache(cfg, batch, max_len, src_embeds.shape[1])
    cache["mem_k"] = mem_k.astype(jnp.bfloat16)
    cache["mem_v"] = mem_v.astype(jnp.bfloat16)
    return cache


def decode_step(params, token, cache, pos, cfg: ArchConfig):
    h = common.embed(params["embed"], token)
    h = h + jax.lax.dynamic_slice_in_dim(
        _sin_posenc(cache["self_kv"]["k"].shape[2], cfg.d_model, h.dtype),
        pos, 1, axis=0)[None, 0]

    def body(carry, xs):
        layer_p, kv, mk, mv = xs
        x = _norm(cfg, layer_p["ln1"], carry)
        a, kv2 = attention.attention_decode(
            layer_p["attn"], x, kv, pos, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta)
        h2 = carry + a
        x = _norm(cfg, layer_p["ln_x"], h2)
        h3 = h2 + _cross_attn(cfg, layer_p["xattn"], x, mk, mv)
        f = common.mlp(layer_p["mlp"], _norm(cfg, layer_p["ln2"], h3), cfg.act)
        return h3 + f, kv2

    h, new_kv = jax.lax.scan(
        body, h, (params["dec_layers"], cache["self_kv"],
                  cache["mem_k"], cache["mem_v"]))
    cache = dict(cache, self_kv=new_kv)
    h = _norm(cfg, params["final_norm"], h)
    return common.dense(params["lm_head"], h), cache
