"""ResNet-18/50 (the paper's own workload, CIFAR-sized stem).
Max pooling replaced by stride/avg per the paper's MPC setup (SS2.3)."""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str
    block: str                    # basic | bottleneck
    stage_blocks: Tuple[int, ...]
    widths: Tuple[int, ...] = (64, 128, 256, 512)
    n_classes: int = 10
    in_hw: int = 32


RESNET18 = ResNetConfig("resnet18", "basic", (2, 2, 2, 2))
RESNET50 = ResNetConfig("resnet50", "bottleneck", (3, 4, 6, 3))

SMOKE = ResNetConfig("resnet-smoke", "basic", (1, 1), widths=(8, 16),
                     n_classes=10, in_hw=16)
