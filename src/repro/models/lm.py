"""Unified decoder LM covering dense / MoE / SSM / hybrid / VLM families.

Layers are stacked (leading L axis) and consumed by ``lax.scan`` so the HLO
stays compact for the 512-device dry-run compiles; per-layer specialisation
(gemma2 local/global alternation) uses traced masks, not control flow.
Hybrid (zamba2) splits the stack into ``attn_every``-sized segments: a
*shared* attention block (one param set, the zamba2 trick) runs between
segment scans so its KV cache only exists for n_layers/attn_every slots.

Modes:
  apply        - full-sequence forward (training / eval)
  prefill      - forward + KV/SSM cache construction (serving)
  decode_step  - one token with cache update (serving)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api import register_mpc_forward
from repro.configs.base import ArchConfig
from repro.nn import attention, common, moe as moe_lib, ssm
from repro.runtime import constraints

BIG_WINDOW = 1 << 30


def padded_vocab(cfg: ArchConfig) -> int:
    """Vocab rounded up to a multiple of 16 so the TP axis always divides
    the embedding/logits dim (only seamless's 256206 actually pads)."""
    return -(-cfg.vocab // 16) * 16


def _norm_init(cfg, d):
    return (common.layernorm_init(d) if cfg.norm == "layernorm"
            else common.rmsnorm_init(d))


def _norm(cfg, p, x):
    return (common.layernorm(p, x) if cfg.norm == "layernorm"
            else common.rmsnorm(p, x))


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ArchConfig):
    dt = _dtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": _norm_init(cfg, d)}
    if cfg.family in ("dense", "vlm"):
        p["attn"] = attention.attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.resolved_head_dim, cfg.qkv_bias, dt)
        p["ln2"] = _norm_init(cfg, d)
        p["mlp"] = common.mlp_init(ks[1], d, cfg.d_ff, cfg.gated_mlp, dt)
    elif cfg.family == "moe":
        p["attn"] = attention.attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.resolved_head_dim, cfg.qkv_bias, dt)
        p["ln2"] = _norm_init(cfg, d)
        p["moe"] = moe_lib.moe_init(ks[1], d, cfg.d_ff, cfg.n_experts,
                                    cfg.gated_mlp, dt)
    elif cfg.family == "ssm":
        p["mamba"] = ssm.mamba1_init(ks[0], d, cfg.ssm_state, cfg.ssm_expand,
                                     dtype=dt)
    elif cfg.family == "hybrid":
        p["mamba"] = ssm.mamba2_init(ks[0], d, cfg.ssm_state,
                                     cfg.mamba2_head_dim, cfg.ssm_expand, dt)
    else:
        raise ValueError(cfg.family)
    return p


def init(key, cfg: ArchConfig):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    params = {
        "embed": common.embed_init(ks[1], padded_vocab(cfg), cfg.d_model, dt),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys),
        "final_norm": _norm_init(cfg, cfg.d_model),
        "lm_head": common.dense_init(ks[2], cfg.d_model, padded_vocab(cfg), dt),
    }
    if cfg.family == "hybrid" and cfg.attn_every:
        params["shared_attn"] = {
            "ln": _norm_init(cfg, cfg.d_model),
            "attn": attention.attn_init(ks[3], cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.resolved_head_dim,
                                        cfg.qkv_bias, dt),
        }
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _layer_window(cfg: ArchConfig, idx):
    """Traced effective attention window for layer `idx` (None = global)."""
    if cfg.local_global_period:
        is_local = (idx % cfg.local_global_period) == 0
        return jnp.where(is_local, cfg.sliding_window, BIG_WINDOW)
    if cfg.sliding_window:
        return cfg.sliding_window
    return None


def _attn_mlp_block(cfg: ArchConfig, p, h, idx):
    window = _layer_window(cfg, idx)
    a = attention.attention(
        p["attn"], _norm(cfg, p["ln1"], h), **_attn_kwargs(cfg), window=window)
    h = h + a
    if cfg.family == "moe":
        f = moe_lib.moe(p["moe"], _norm(cfg, p["ln2"], h),
                        n_experts=cfg.n_experts, top_k=cfg.top_k,
                        capacity_factor=cfg.moe_capacity_factor,
                        act_name=cfg.act)
    else:
        f = common.mlp(p["mlp"], _norm(cfg, p["ln2"], h), cfg.act)
    return h + f


def _attn_kwargs(cfg: ArchConfig):
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                cap=cfg.attn_softcap, chunk_q=cfg.attn_chunk_q,
                chunk_k=cfg.attn_chunk_k)


def _mamba_block(cfg: ArchConfig, p, h):
    x = _norm(cfg, p["ln1"], h)
    if cfg.mamba_version == 1:
        return h + ssm.mamba1(p["mamba"], x, n_state=cfg.ssm_state,
                              chunk=cfg.ssm_chunk)
    return h + ssm.mamba2(p["mamba"], x, n_state=cfg.ssm_state,
                          head_dim=cfg.mamba2_head_dim, chunk=cfg.ssm_chunk)


def _maybe_remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    policy = {
        "full": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        # save every dot output: the backward never re-runs forward
        # collectives (EXPERIMENTS.md §Perf iteration A)
        "dots_all": jax.checkpoint_policies.dots_saveable,
    }[cfg.remat]
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# Full-sequence forward (training)
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg, tokens, frontend_embeds):
    h = common.embed(params["embed"], tokens)
    if cfg.frontend != "none":
        assert frontend_embeds is not None, "VLM/audio arch needs stub embeds"
        h = jnp.concatenate([frontend_embeds.astype(h.dtype), h], axis=1)
    # residual stream: batch over dp, replicated over model (Megatron TP)
    return constraints.shard(h, "dp", None, None)


def apply(params, tokens, cfg: ArchConfig, frontend_embeds=None):
    """tokens: (B, S_tok) -> logits (B, S_total, vocab)."""
    h = _embed_inputs(params, cfg, tokens, frontend_embeds)

    if cfg.family == "hybrid" and cfg.attn_every:
        h = _hybrid_forward(params, h, cfg)
    else:
        def body(carry, xs):
            layer_p, idx = xs
            if cfg.family in ("dense", "moe", "vlm"):
                out = _attn_mlp_block(cfg, layer_p, carry, idx)
            else:
                out = _mamba_block(cfg, layer_p, carry)
            return constraints.shard(out, "dp", None, None), None

        h, _ = jax.lax.scan(_maybe_remat(cfg, body), h,
                            (params["layers"], jnp.arange(cfg.n_layers)))

    h = _norm(cfg, params["final_norm"], h)
    logits = common.dense(params["lm_head"], h)
    return common.softcap(logits, cfg.logit_softcap)


def _hybrid_forward(params, h, cfg: ArchConfig):
    """zamba2: shared attention block between segments of mamba2 layers."""
    per = cfg.attn_every
    n_seg = (cfg.n_layers + per - 1) // per
    sa = params["shared_attn"]

    def seg_body(carry, layer_p):
        return _mamba_block(cfg, layer_p, carry), None

    for seg in range(n_seg):
        a = attention.attention(sa["attn"], _norm(cfg, sa["ln"], h),
                                **_attn_kwargs(cfg))
        h = h + a
        lo, hi = seg * per, min((seg + 1) * per, cfg.n_layers)
        seg_params = jax.tree_util.tree_map(lambda t: t[lo:hi], params["layers"])
        h, _ = jax.lax.scan(_maybe_remat(cfg, seg_body), h, seg_params)
    return h


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Abstract cache pytree (shapes only resolved on first use)."""
    dt = jnp.bfloat16
    if cfg.family in ("dense", "moe", "vlm"):
        one = attention.init_kv_cache(batch, max_len, cfg.n_kv_heads,
                                      cfg.resolved_head_dim, dt)
        return {"kv": jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t, (cfg.n_layers,) + t.shape).copy(), one)}
    if cfg.family == "ssm":
        di = cfg.ssm_expand * cfg.d_model
        one = ssm.mamba1_init_state(batch, di, cfg.ssm_state)
        return {"ssm": jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t, (cfg.n_layers,) + t.shape).copy(), one)}
    if cfg.family == "hybrid":
        di = cfg.ssm_expand * cfg.d_model
        one = ssm.mamba2_init_state(batch, di, cfg.ssm_state, cfg.mamba2_head_dim)
        states = jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t, (cfg.n_layers,) + t.shape).copy(), one)
        n_seg = (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every
        kv = attention.init_kv_cache(batch, max_len, cfg.n_kv_heads,
                                     cfg.resolved_head_dim, dt)
        kv = jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t, (n_seg,) + t.shape).copy(), kv)
        return {"ssm": states, "kv": kv}
    raise ValueError(cfg.family)


def decode_step(params, token, cache, pos, cfg: ArchConfig):
    """token: (B, 1) ids; pos: scalar int32 position. Returns (logits, cache)."""
    h = common.embed(params["embed"], token)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, xs):
            layer_p, layer_cache, idx = xs
            window = _layer_window(cfg, idx)
            x = _norm(cfg, layer_p["ln1"], carry)
            a, new_cache = attention.attention_decode(
                layer_p["attn"], x, layer_cache, pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
                rope_theta=cfg.rope_theta, window=window,
                cap=cfg.attn_softcap)
            h2 = carry + a
            if cfg.family == "moe":
                f = moe_lib.moe(layer_p["moe"], _norm(cfg, layer_p["ln2"], h2),
                                n_experts=cfg.n_experts, top_k=cfg.top_k,
                                capacity_factor=cfg.moe_capacity_factor,
                                act_name=cfg.act)
            else:
                f = common.mlp(layer_p["mlp"], _norm(cfg, layer_p["ln2"], h2),
                               cfg.act)
            return h2 + f, new_cache

        h, new_kv = jax.lax.scan(
            body, h, (params["layers"], cache["kv"], jnp.arange(cfg.n_layers)))
        cache = {"kv": new_kv}

    elif cfg.family == "ssm":
        def body(carry, xs):
            layer_p, st = xs
            x = _norm(cfg, layer_p["ln1"], carry)
            y, st2 = ssm.mamba1_decode(layer_p["mamba"], x, st,
                                       n_state=cfg.ssm_state)
            return carry + y, st2

        h, new_states = jax.lax.scan(body, h, (params["layers"], cache["ssm"]))
        cache = {"ssm": new_states}

    else:  # hybrid
        per = cfg.attn_every
        n_seg = (cfg.n_layers + per - 1) // per
        sa = params["shared_attn"]
        new_states = []
        new_kv = []

        def seg_body(carry, xs):
            layer_p, st = xs
            x = _norm(cfg, layer_p["ln1"], carry)
            y, st2 = ssm.mamba2_decode(layer_p["mamba"], x, st,
                                       n_state=cfg.ssm_state,
                                       head_dim=cfg.mamba2_head_dim)
            return carry + y, st2

        for seg in range(n_seg):
            kv_seg = jax.tree_util.tree_map(lambda t: t[seg], cache["kv"])
            x = _norm(cfg, sa["ln"], h)
            a, kv2 = attention.attention_decode(
                sa["attn"], x, kv_seg, pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
                rope_theta=cfg.rope_theta, cap=cfg.attn_softcap)
            h = h + a
            new_kv.append(kv2)
            lo, hi = seg * per, min((seg + 1) * per, cfg.n_layers)
            seg_p = jax.tree_util.tree_map(lambda t: t[lo:hi], params["layers"])
            seg_st = jax.tree_util.tree_map(lambda t: t[lo:hi], cache["ssm"])
            h, st2 = jax.lax.scan(seg_body, h, (seg_p, seg_st))
            new_states.append(st2)

        cache = {
            "ssm": jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, axis=0), *new_states),
            "kv": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs, axis=0), *new_kv),
        }

    h = _norm(cfg, params["final_norm"], h)
    logits = common.dense(params["lm_head"], h)
    return common.softcap(logits, cfg.logit_softcap), cache


def prefill(params, tokens, cfg: ArchConfig, max_len: int,
            frontend_embeds=None):
    """Full-sequence forward that also fills the serving cache.

    For attention families this runs the train-style chunked attention and
    writes K/V into the cache; for SSM families it runs the chunked scan
    and keeps the final state.  Returns (last_logits, cache).
    """
    h = _embed_inputs(params, cfg, tokens, frontend_embeds)
    b, s = h.shape[0], h.shape[1]
    cache = init_cache(cfg, b, max_len)

    if cfg.family in ("dense", "moe", "vlm"):
        positions = jnp.arange(s)[None, :]

        def body(carry, xs):
            layer_p, idx = xs
            window = _layer_window(cfg, idx)
            x = _norm(cfg, layer_p["ln1"], carry)
            q, k, v = attention._project_qkv(
                layer_p["attn"], x, cfg.n_heads, cfg.n_kv_heads,
                cfg.resolved_head_dim, positions, cfg.rope_theta)
            o = attention.flash_attention(
                q, k, v, q_offset=0, chunk_q=cfg.attn_chunk_q,
                chunk_k=cfg.attn_chunk_k, window=window, cap=cfg.attn_softcap)
            a = common.dense(layer_p["attn"]["wo"],
                             o.reshape(b, s, cfg.n_heads * cfg.resolved_head_dim))
            h2 = carry + a
            if cfg.family == "moe":
                f = moe_lib.moe(layer_p["moe"], _norm(cfg, layer_p["ln2"], h2),
                                n_experts=cfg.n_experts, top_k=cfg.top_k,
                                capacity_factor=cfg.moe_capacity_factor,
                                act_name=cfg.act)
            else:
                f = common.mlp(layer_p["mlp"], _norm(cfg, layer_p["ln2"], h2),
                               cfg.act)
            return h2 + f, {"k": k.astype(jnp.bfloat16),
                            "v": v.astype(jnp.bfloat16)}

        h, kvs = jax.lax.scan(_maybe_remat(cfg, body), h,
                              (params["layers"], jnp.arange(cfg.n_layers)))
        cache["kv"] = jax.tree_util.tree_map(
            lambda dst, new: jax.lax.dynamic_update_slice_in_dim(
                dst, new.astype(dst.dtype), 0, axis=2),
            cache["kv"], kvs)

    elif cfg.family == "ssm":
        def body(carry, layer_p):
            x = _norm(cfg, layer_p["ln1"], carry)
            y, st = ssm.mamba1(layer_p["mamba"], x, n_state=cfg.ssm_state,
                               chunk=cfg.ssm_chunk, return_state=True)
            return carry + y, st

        h, states = jax.lax.scan(_maybe_remat(cfg, body), h, params["layers"])
        cache["ssm"] = states

    else:  # hybrid
        per = cfg.attn_every
        n_seg = (cfg.n_layers + per - 1) // per
        sa = params["shared_attn"]
        positions = jnp.arange(s)[None, :]
        all_states, all_kv = [], []

        def seg_body(carry, layer_p):
            x = _norm(cfg, layer_p["ln1"], carry)
            y, st = ssm.mamba2(layer_p["mamba"], x, n_state=cfg.ssm_state,
                               head_dim=cfg.mamba2_head_dim,
                               chunk=cfg.ssm_chunk, return_state=True)
            return carry + y, st

        for seg in range(n_seg):
            x = _norm(cfg, sa["ln"], h)
            q, k, v = attention._project_qkv(
                sa["attn"], x, cfg.n_heads, cfg.n_kv_heads,
                cfg.resolved_head_dim, positions, cfg.rope_theta)
            o = attention.flash_attention(
                q, k, v, q_offset=0, chunk_q=cfg.attn_chunk_q,
                chunk_k=cfg.attn_chunk_k, cap=cfg.attn_softcap)
            h = h + common.dense(
                sa["attn"]["wo"],
                o.reshape(b, s, cfg.n_heads * cfg.resolved_head_dim))
            all_kv.append({"k": k.astype(jnp.bfloat16),
                           "v": v.astype(jnp.bfloat16)})
            lo, hi = seg * per, min((seg + 1) * per, cfg.n_layers)
            seg_p = jax.tree_util.tree_map(lambda t: t[lo:hi], params["layers"])
            h, states = jax.lax.scan(_maybe_remat(cfg, seg_body), h, seg_p)
            all_states.append(states)

        cache["ssm"] = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *all_states)
        kvs = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *all_kv)
        cache["kv"] = jax.tree_util.tree_map(
            lambda dst, new: jax.lax.dynamic_update_slice_in_dim(
                dst, new.astype(dst.dtype), 0, axis=2),
            cache["kv"], kvs)

    h = _norm(cfg, params["final_norm"], h)
    logits = common.dense(params["lm_head"], h[:, -1:])
    return common.softcap(logits, cfg.logit_softcap), cache


# ---------------------------------------------------------------------------
# Private inference: reduced-ring MPC forward (dense family)
# ---------------------------------------------------------------------------
# The MPC lowering replaces every transformer nonlinearity with a
# reduced-ring composition (repro.nn.approx): GELU/SiLU become knot-stacked
# ReLU sums, softmax becomes ReLU + public causal-mean normalization, and
# rms/layer norms become their static-scale co-design approximation
# (x * scale — the data-dependent rsqrt has no cheap GMW circuit).
# ``mpc_reference`` is the plaintext twin of ``_lm_mpc_forward``: it makes
# the exact same relu_fn / .matmul / .mul hook calls in the same order, so
# ``trace()`` prices the replay (ReLU groups: 2 per layer — attention
# scores then MLP activation; Beaver opens: QK^T, A@V, gate*up per layer)
# and MPC-vs-reference differs only by fixed-point + (k, m) error.
# Input is the *embedded* hidden states (B, S, d_model) — token lookup
# happens client-side in the clear, as in the private-LM deployments this
# follows.

def _static_norm_ref(p, x):
    y = x * p["scale"]
    return y + p["bias"] if "bias" in p else y


def mpc_reference(params, h, cfg: ArchConfig, relu_fn=None):
    """Plaintext reference of the MPC-approximated LM forward.

    h: (B, S, d_model) embedded hidden states -> logits (B, S, vocab).
    ``relu_fn=None`` evaluates with exact ReLU and plain jnp products;
    passing a traced or reduced-ring relu_fn reproduces the replay's hook
    sequence exactly.
    """
    from repro.nn import approx
    if cfg.family != "dense":
        raise ValueError(
            f"MPC lowering covers the dense family only, not {cfg.family!r}")
    relu_fn = approx.ensure_hooks(relu_fn)
    spec = approx.spec_for(cfg.act)
    b, s, _ = h.shape
    dh = cfg.resolved_head_dim
    grp = cfg.n_heads // cfg.n_kv_heads
    positions = jnp.arange(s)[None, :]
    for l in range(cfg.n_layers):
        p = jax.tree_util.tree_map(lambda a: a[l], params["layers"])
        x = _static_norm_ref(p["ln1"], h)
        q, k, v = attention._project_qkv(p["attn"], x, cfg.n_heads,
                                         cfg.n_kv_heads, dh, positions,
                                         cfg.rope_theta)
        q = jnp.transpose(q, (0, 2, 1, 3))
        k = jnp.transpose(k, (0, 2, 1, 3))
        v = jnp.transpose(v, (0, 2, 1, 3))
        if grp > 1:
            k = jnp.repeat(k, grp, axis=1)
            v = jnp.repeat(v, grp, axis=1)
        o = approx.relu_attention(q, k, v, 2 * l, relu_fn)
        o = jnp.transpose(o, (0, 2, 1, 3)).reshape(b, s, cfg.n_heads * dh)
        h = h + common.dense(p["attn"]["wo"], o)
        x = _static_norm_ref(p["ln2"], h)
        up = jnp.einsum("...d,df->...f", x, p["mlp"]["w_up"])
        if "w_gate" in p["mlp"]:
            gate = jnp.einsum("...d,df->...f", x, p["mlp"]["w_gate"])
            act = (relu_fn(gate, 2 * l + 1) if spec is None
                   else approx.apply_pwl(spec, gate, 2 * l + 1, relu_fn))
            mid = relu_fn.mul(act, up)
        else:
            mid = (relu_fn(up, 2 * l + 1) if spec is None
                   else approx.apply_pwl(spec, up, 2 * l + 1, relu_fn))
        h = h + jnp.einsum("...f,fd->...d", mid, p["mlp"]["w_down"])
    h = _static_norm_ref(params["final_norm"], h)
    return jnp.einsum("...d,df->...f", h, params["lm_head"]["w"])


def _static_norm_mpc(p, h, comm):
    y = h.mul_public(p["scale"])
    return y.add_public(p["bias"], comm) if "bias" in p else y


def _mpc_proj(x, wp, n_h: int, dh: int, comm):
    y = x.matmul_public(wp["w"])
    if "b" in wp:
        y = y.add_public(wp["b"], comm)
    return y.reshape(x.shape[0], x.shape[1], n_h, dh)


def _rope_mpc(t, s: int, theta: float):
    """RoPE on a secret (B, S, H, Dh) tensor: cos/sin are public per
    position, so the rotation is four mul_public + two ring combines."""
    from repro.core import mpc_tensor
    dh = t.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs   # (S, half)
    cos = jnp.cos(angles)[:, None, :]                            # (S, 1, half)
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = t[..., :half], t[..., half:]
    out1 = x1.mul_public(cos) - x2.mul_public(sin)
    out2 = x2.mul_public(cos) + x1.mul_public(sin)
    return mpc_tensor.concat([out1, out2], axis=-1)


def _lm_mpc_forward(params, hs, cfg: ArchConfig, relu_fn, comm):
    """Secret-shared LM forward over sibling MPCTensor streams (the
    ``register_mpc_forward`` contract) — the MPC twin of
    ``mpc_reference``, hook call for hook call."""
    from repro.nn import approx
    if cfg.family != "dense":
        raise ValueError(
            f"MPC lowering covers the dense family only, not {cfg.family!r}")
    spec = approx.spec_for(cfg.act)
    dh = cfg.resolved_head_dim
    grp = cfg.n_heads // cfg.n_kv_heads
    for l in range(cfg.n_layers):
        p = jax.tree_util.tree_map(lambda a: a[l], params["layers"])
        qs, ks, vs = [], [], []
        for h in hs:
            s = h.shape[1]
            x = _static_norm_mpc(p["ln1"], h, comm)
            q = _mpc_proj(x, p["attn"]["wq"], cfg.n_heads, dh, comm)
            k = _mpc_proj(x, p["attn"]["wk"], cfg.n_kv_heads, dh, comm)
            v = _mpc_proj(x, p["attn"]["wv"], cfg.n_kv_heads, dh, comm)
            if cfg.rope_theta:
                q = _rope_mpc(q, s, cfg.rope_theta)
                k = _rope_mpc(k, s, cfg.rope_theta)
            q = q.transpose(0, 2, 1, 3)
            k = k.transpose(0, 2, 1, 3)
            v = v.transpose(0, 2, 1, 3)
            if grp > 1:
                k = k.repeat(grp, axis=1)
                v = v.repeat(grp, axis=1)
            qs.append(q)
            ks.append(k)
            vs.append(v)
        os_ = approx.relu_attention_mpc(qs, ks, vs, 2 * l, relu_fn)
        outs = []
        for h, o in zip(hs, os_):
            b, s = h.shape[0], h.shape[1]
            o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * dh)
            outs.append(h + o.matmul_public(p["attn"]["wo"]["w"]))
        hs = outs
        xs = [_static_norm_mpc(p["ln2"], h, comm) for h in hs]
        ups = [x.matmul_public(p["mlp"]["w_up"]) for x in xs]
        if "w_gate" in p["mlp"]:
            gates = [x.matmul_public(p["mlp"]["w_gate"]) for x in xs]
            acts = (relu_fn(gates, 2 * l + 1) if spec is None
                    else approx.apply_pwl_mpc(spec, gates, 2 * l + 1,
                                              relu_fn, comm))
            mids = relu_fn.mul(acts, ups)
        else:
            mids = (relu_fn(ups, 2 * l + 1) if spec is None
                    else approx.apply_pwl_mpc(spec, ups, 2 * l + 1,
                                              relu_fn, comm))
        hs = [h + m.matmul_public(p["mlp"]["w_down"])
              for h, m in zip(hs, mids)]
    hs = [_static_norm_mpc(params["final_norm"], h, comm) for h in hs]
    return [h.matmul_public(params["lm_head"]["w"]) for h in hs]


def trace(params, cfg: ArchConfig, batch: int, seq: int, hb=None,
          name: str = ""):
    """Shape-trace the MPC-approximated LM into a Plan (2 ReLU groups per
    layer, 3 Beaver opens per gated layer)."""
    from repro import api

    def afn(p, x, relu_fn=None):
        return mpc_reference(p, x, cfg, relu_fn=relu_fn)

    return api.trace_plan(afn, params, (batch, seq, cfg.d_model), hb=hb,
                          name=name or cfg.name)


register_mpc_forward(ArchConfig, _lm_mpc_forward)
