"""Z/2^64 ring arithmetic in 2xuint32 limbs (TPU-native, no jax_enable_x64).

CrypTen stores secret shares as int64 tensors. TPUs have no fast 64-bit
integer datapath, so we represent every ring element as a pair of uint32
limbs (lo, hi) and implement add/sub/neg/mul/shift with explicit carries.
All operations are elementwise, vectorizable on the 8x128 VPU, and keep the
exact mod-2^64 wraparound semantics that the GMW protocol relies on.

Representation invariant: value = hi * 2^32 + lo  (mod 2^64), both uint32.
Signed interpretation (two's complement over 64 bits) is only applied at
fixed-point decode time; the ring itself is unsigned-modular.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

_U32 = jnp.uint32
_MASK16 = jnp.uint32(0xFFFF)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Ring64:
    """An array of Z/2^64 elements stored as two uint32 limbs."""

    lo: jax.Array
    hi: jax.Array

    def tree_flatten(self):
        return (self.lo, self.hi), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.lo.shape

    @property
    def ndim(self) -> int:
        return self.lo.ndim

    def reshape(self, *shape) -> "Ring64":
        return Ring64(self.lo.reshape(*shape), self.hi.reshape(*shape))

    def __getitem__(self, idx) -> "Ring64":
        return Ring64(self.lo[idx], self.hi[idx])

    def flatten(self) -> "Ring64":
        return Ring64(self.lo.reshape(-1), self.hi.reshape(-1))


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------

def zeros(shape, _=None) -> Ring64:
    z = jnp.zeros(shape, _U32)
    return Ring64(z, z)


def from_limbs(lo, hi) -> Ring64:
    return Ring64(jnp.asarray(lo, _U32), jnp.asarray(hi, _U32))


def from_int32(x) -> Ring64:
    """Embed signed 32-bit values into Z/2^64 (two's-complement extend)."""
    x = jnp.asarray(x, jnp.int32)
    lo = x.astype(_U32)
    hi = jnp.where(x < 0, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    return Ring64(lo, hi)


def from_uint64_np(x: np.ndarray) -> Ring64:
    """Host-side constructor from numpy uint64 (tests / checkpoint IO)."""
    x = np.asarray(x, np.uint64)
    lo = (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (x >> np.uint64(32)).astype(np.uint32)
    return Ring64(jnp.asarray(lo), jnp.asarray(hi))


def to_uint64_np(x: Ring64) -> np.ndarray:
    lo = np.asarray(jax.device_get(x.lo), np.uint64)
    hi = np.asarray(jax.device_get(x.hi), np.uint64)
    return lo | (hi << np.uint64(32))


def uniform(key, shape) -> Ring64:
    """Uniformly random ring elements (PRG shares / Beaver masks)."""
    k1, k2 = jax.random.split(key)
    lo = jax.random.bits(k1, shape, dtype=_U32)
    hi = jax.random.bits(k2, shape, dtype=_U32)
    return Ring64(lo, hi)


# ---------------------------------------------------------------------------
# Arithmetic (mod 2^64)
# ---------------------------------------------------------------------------

def add(a: Ring64, b: Ring64) -> Ring64:
    lo = a.lo + b.lo
    carry = (lo < a.lo).astype(_U32)
    hi = a.hi + b.hi + carry
    return Ring64(lo, hi)


def sub(a: Ring64, b: Ring64) -> Ring64:
    lo = a.lo - b.lo
    borrow = (a.lo < b.lo).astype(_U32)
    hi = a.hi - b.hi - borrow
    return Ring64(lo, hi)


def neg(a: Ring64) -> Ring64:
    return sub(zeros(a.shape), a)


def _shift64_of_u32(v: jax.Array, s: int) -> Ring64:
    """(uint32 value v) << s as a 64-bit ring element, static s in {0,16,32,48}."""
    if s == 0:
        return Ring64(v, jnp.zeros_like(v))
    if s < 32:
        return Ring64(v << s, v >> (32 - s))
    if s == 32:
        return Ring64(jnp.zeros_like(v), v)
    return Ring64(jnp.zeros_like(v), v << (s - 32))


def mul(a: Ring64, b: Ring64) -> Ring64:
    """Elementwise a*b mod 2^64 via 16-bit half-limb products."""
    a_h = (a.lo & _MASK16, a.lo >> 16, a.hi & _MASK16, a.hi >> 16)
    b_h = (b.lo & _MASK16, b.lo >> 16, b.hi & _MASK16, b.hi >> 16)
    acc = zeros(a.shape)
    for i in range(4):
        for j in range(4 - i):  # i + j <= 3, shift 16*(i+j) < 64
            p = a_h[i] * b_h[j]  # < 2^32, wraps are impossible
            acc = add(acc, _shift64_of_u32(p, 16 * (i + j)))
    return acc


def mul_pub(a: Ring64, w) -> Ring64:
    """Multiply shares by a public signed int32 value (broadcasts)."""
    return mul(a, from_int32(w))


# ---------------------------------------------------------------------------
# Shifts / bit extraction
# ---------------------------------------------------------------------------

def lshift(a: Ring64, n: int) -> Ring64:
    assert 0 <= n < 64
    if n == 0:
        return a
    if n < 32:
        lo = a.lo << n
        hi = (a.hi << n) | (a.lo >> (32 - n))
        return Ring64(lo, hi)
    return Ring64(jnp.zeros_like(a.lo), a.lo << (n - 32) if n > 32 else a.lo)


def rshift_logical(a: Ring64, n: int) -> Ring64:
    assert 0 <= n < 64
    if n == 0:
        return a
    if n < 32:
        lo = (a.lo >> n) | (a.hi << (32 - n))
        hi = a.hi >> n
        return Ring64(lo, hi)
    return Ring64(a.hi >> (n - 32), jnp.zeros_like(a.hi))


def rshift_arith(a: Ring64, n: int) -> Ring64:
    """Arithmetic (sign-extending) right shift of the 64-bit value."""
    if n == 0:
        return a
    sign = (a.hi >> 31).astype(_U32)  # 0 or 1
    shifted = rshift_logical(a, n)
    # fill the top n bits with the sign
    fill = sub(zeros(a.shape), Ring64(sign, jnp.zeros_like(sign)))  # 0 or all-ones
    fill = lshift(fill, 64 - n) if n < 64 else fill
    return Ring64(shifted.lo | fill.lo, shifted.hi | fill.hi)


def bit(a: Ring64, i: int) -> jax.Array:
    """The i-th bit (0 = LSB) as uint32 in {0,1}. Static i."""
    assert 0 <= i < 64
    if i < 32:
        return (a.lo >> i) & jnp.uint32(1)
    return (a.hi >> (i - 32)) & jnp.uint32(1)


def extract_bits(a: Ring64, k: int, m: int) -> jax.Array:
    """x[k:m] per the paper's notation: bits m..k-1, as uint32 (k-m <= 32).

    This is the HummingBird bit-drop: the result is a valid element of the
    reduced ring Z/2^(k-m)Z.  Requires k - m <= 32 so the reduced-ring value
    fits a single native limb (always true for HummingBird configs; use
    extract_planes for the exact w=64 baseline).
    """
    w = k - m
    assert 0 < w <= 32 and 0 <= m and k <= 64
    shifted = rshift_logical(a, m)
    mask = jnp.uint32(0xFFFFFFFF) if w == 32 else jnp.uint32((1 << w) - 1)
    return shifted.lo & mask


def bitplanes_u32(v: jax.Array, w: int) -> jax.Array:
    """(..., ) uint32 -> (w, ...) planes of {0,1} uint32, LSB first."""
    idx = jnp.arange(w, dtype=_U32).reshape((w,) + (1,) * v.ndim)
    return (v[None] >> idx) & jnp.uint32(1)


def extract_planes(a: Ring64, k: int, m: int) -> jax.Array:
    """Bits m..k-1 of a Ring64 as (k-m, ...) {0,1} planes (w up to 64)."""
    assert 0 <= m < k <= 64
    shifted = rshift_logical(a, m)
    w = k - m
    planes = []
    for i in range(w):
        planes.append(bit(shifted, i))
    return jnp.stack(planes, axis=0)


def from_planes(planes: jax.Array) -> Ring64:
    """(w, ...) {0,1} planes, LSB first -> Ring64 (upper bits zero)."""
    w = planes.shape[0]
    lo = jnp.zeros(planes.shape[1:], _U32)
    hi = jnp.zeros(planes.shape[1:], _U32)
    for i in range(min(w, 32)):
        lo = lo | (planes[i].astype(_U32) << i)
    for i in range(32, w):
        hi = hi | (planes[i].astype(_U32) << (i - 32))
    return Ring64(lo, hi)


def is_negative(a: Ring64) -> jax.Array:
    """Sign bit of the 64-bit two's-complement interpretation."""
    return (a.hi >> 31).astype(_U32)


def where(pred: jax.Array, a: Ring64, b: Ring64) -> Ring64:
    return Ring64(jnp.where(pred, a.lo, b.lo), jnp.where(pred, a.hi, b.hi))


# ---------------------------------------------------------------------------
# Balanced 8-bit digit decomposition (for MXU s8 x s8 -> s32 plane matmuls)
# ---------------------------------------------------------------------------

def balanced_digits(a: Ring64, n_digits: int = 8) -> jax.Array:
    """Decompose into n_digits signed digits d_i in [-128, 127] with
    value = sum_i d_i * 2^(8i)  (mod 2^64).  Returns (n_digits, ...) int8.

    Standard balanced-radix-256 rewrite: digits >= 128 borrow one from the
    next byte.  The final carry out of digit 7 is congruent to 0 mod 2^64.
    """
    assert 1 <= n_digits <= 8
    out = []
    carry = jnp.zeros(a.shape, _U32)
    for i in range(n_digits):
        limb = a.lo if i < 4 else a.hi
        byte = (limb >> (8 * (i % 4))) & jnp.uint32(0xFF)
        t = byte + carry  # in [0, 256]
        ge = (t >= 128).astype(_U32)
        d = t.astype(jnp.int32) - 256 * ge.astype(jnp.int32)
        carry = ge
        out.append(d.astype(jnp.int8))
    return jnp.stack(out, axis=0)


def balanced_digits_i32(w: jax.Array) -> jax.Array:
    """Signed int32 public weights -> 5 digits int8 with
    w = sum_{j<5} e_j 2^(8j) (mod 2^64); e_4 in {-1,0,1} absorbs both the
    balanced carry out of byte 3 and the sign extension of w into 64 bits.
    """
    w = jnp.asarray(w, jnp.int32)
    u = w.astype(_U32)
    out = []
    carry = jnp.zeros(w.shape, _U32)
    for j in range(4):
        byte = (u >> (8 * j)) & jnp.uint32(0xFF)
        t = byte + carry
        ge = (t >= 128).astype(_U32)
        d = t.astype(jnp.int32) - 256 * ge.astype(jnp.int32)
        carry = ge
        out.append(d.astype(jnp.int8))
    # w = u - 2^32 * [w < 0]  and  u = sum_{j<4} e_j 2^(8j) + carry*2^32
    e4 = carry.astype(jnp.int32) - (w < 0).astype(jnp.int32)
    out.append(e4.astype(jnp.int8))
    return jnp.stack(out, axis=0)
