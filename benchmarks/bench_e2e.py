"""Paper Fig. 1 / 7 / 8 / 9: end-to-end latency + speedup projection.

Methodology follows §5.2: communication volume comes from the (validated)
cost model; compute time is measured on this host for the linear layers
and scaled; the network term is projected at High-BW / LAN / WAN
bandwidths exactly as the paper projects its WAN numbers.
"""
import time

import jax
import jax.numpy as jnp

from repro import api
from repro.configs.resnet import RESNET18, RESNET50
from repro.core import costmodel
from repro.core.hummingbird import HBConfig, HBLayer
from repro.models import resnet

# single source of truth for the paper's §5.2 network points: repro.api
NETWORKS = {name: (p.bandwidth_bps, p.rtt_s)
            for name, p in api.NETWORKS.items()}
BATCH = 512


def _measure_compute_s(rcfg) -> float:
    """Plaintext linear-layer time for one batch on this host, as the
    compute floor (MPC linear ops are public-weight and local)."""
    params = resnet.init(jax.random.PRNGKey(0), rcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 3, rcfg.in_hw, rcfg.in_hw))
    fn = jax.jit(lambda p, x: resnet.apply(p, x, rcfg))
    fn(params, x).block_until_ready()
    t0 = time.time()
    for _ in range(3):
        fn(params, x).block_until_ready()
    per8 = (time.time() - t0) / 3
    return per8 * (BATCH / 8)


def run():
    rows = []
    for rcfg in (RESNET18, RESNET50):
        params = resnet.init(jax.random.PRNGKey(0), rcfg)
        groups = resnet.relu_group_elements(params, rcfg)
        groups = [g * BATCH for g in groups]
        compute_s = _measure_compute_s(rcfg)
        configs = {
            "crypten64": HBConfig.exact(groups),
            "eco": HBConfig(tuple(HBLayer(k=21, m=0) for _ in groups),
                            tuple(groups)),
            "8of64": HBConfig(tuple(HBLayer(k=21, m=13) for _ in groups),
                              tuple(groups)),
            "6of64": HBConfig(tuple(HBLayer(k=20, m=14) for _ in groups),
                              tuple(groups)),
        }
        for net, (bw, rtt) in NETWORKS.items():
            base_cost = costmodel.model_relu_cost(configs["crypten64"])
            base_lat = costmodel.latency_model(base_cost, bw, rtt, compute_s)
            for name, cfg in configs.items():
                t0 = time.time()
                cost = costmodel.model_relu_cost(cfg)
                lat = costmodel.latency_model(cost, bw, rtt, compute_s)
                us = (time.time() - t0) * 1e6
                rows.append((f"e2e_{rcfg.name}_{net}_{name}", us,
                             f"latency_s={lat:.3f};speedup={base_lat/lat:.2f}x;"
                             f"throughput={BATCH/lat:.1f}sps"))
                # round-fused serving: S sibling request streams share every
                # protocol round (relu_many), so the per-round RTT term is
                # paid once for all S; per-stream latency amortizes it.
                S = 4
                t0 = time.time()
                fused = costmodel.fused_model_relu_cost(cfg, S)
                lat_s = costmodel.latency_model(fused, bw, rtt,
                                                S * compute_s) / S
                us = (time.time() - t0) * 1e6
                rows.append((f"e2e_{rcfg.name}_{net}_{name}_fused{S}", us,
                             f"latency_s={lat_s:.3f};"
                             f"speedup={base_lat/lat_s:.2f}x;"
                             f"throughput={BATCH/lat_s:.1f}sps"))
    return rows
