"""SocketComm: the real inter-process party link over TCP.

Every other backend in ``core.comm`` simulates the second party
(``SimComm`` materialises both rows, ``MeshComm`` puts them on device
shards of one process).  ``SocketComm`` is the deployment backend: each
party is its OWN operating-system process holding only its OWN share
rows (local party dimension 1 — the layout the mesh backend already
proved the protocol against with ``axis_size == 2``), and ``swap`` is a
length-prefixed framed exchange of the round's flattened uint32 buffer
over a TCP connection.

Wire format (little-endian), one message per direction per round::

    +-------+------+-------+-------+---------+---------+----------+
    | magic | kind | party | flags |   seq   | n_bytes | body ... |
    |  4 B  | 1 B  |  1 B  |  2 B  |   4 B   |   4 B   | n_bytes  |
    +-------+------+-------+-------+---------+---------+----------+

kinds: HELLO (handshake json), DATA (one protocol round's payload
words), CTRL (out-of-band json + blob, used by the serving engine link).

Contracts that make the stack above "just work":

- **Byte accounting**: ``round_bytes``/``bytes_tx`` count ONLY the
  protocol payload (the body of DATA messages) — exactly what
  ``core.comm.payload_bytes`` counts for the sim backends and what
  ``core.schedule``'s ``Schedule.framed()`` predicts.  The 16-byte
  message envelope is this transport's own overhead (analogous to
  TCP/IP headers, which no backend counts) and is tracked separately in
  ``header_bytes``.
- **Idempotent re-send** (what ``ResilientComm`` needs): a round's
  DATA message carries the sender's round sequence number.  Stale
  duplicates (seq < expected) are dropped; the last few delivered
  payloads are cached so a local retry of an already-delivered round
  returns the cached bytes instead of deadlocking on a peer that will
  never re-send (TCP already delivered reliably).
- **Typed failures**: a socket timeout raises ``errors.CommTimeout``
  (retryable — ``ResilientComm`` re-sends), a closed/reset connection
  raises ``errors.PartyCrashed`` (not retryable — recovery is restart +
  ``RoundJournal`` resume), a handshake identity mismatch raises
  ``errors.HandshakeFailed``.
- **Link shaping**: ``LinkShaper(rtt_s, bandwidth_bps)`` paces each
  round to ``rtt + 2 * payload_bytes * 8 / bandwidth`` — the exact
  per-round term of ``Schedule.latency`` — so measured wall-clock under
  an injected WAN profile can be validated against the schedule
  prediction (``benchmarks/run.py --transport``).

Handshake: both ends exchange a HELLO naming (protocol version, party
index, session id, plan digest, journal length) and fail loudly on any
identity mismatch.  The journal lengths negotiate the resume round
after a crash: both parties truncate their ``RoundJournal`` to
``min(len_a, len_b)`` so replay ends — and live execution resumes, with
both sockets and both ``ResilientComm`` sequence counters at zero — on
the same round barrier (see ``Session.connect``).
"""
from __future__ import annotations

import collections
import dataclasses
import json
import socket as socket_lib
import struct
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import errors

MAGIC = b"HBTP"
VERSION = 1
HEADER = struct.Struct("<4sBBHII")      # magic kind party flags seq n_bytes
KIND_HELLO, KIND_DATA, KIND_CTRL = 1, 2, 3
_KIND_NAMES = {KIND_HELLO: "HELLO", KIND_DATA: "DATA", KIND_CTRL: "CTRL"}
_U32 = jnp.uint32


def free_port(host: str = "127.0.0.1") -> int:
    """An OS-assigned free TCP port (tests / examples)."""
    with socket_lib.socket() as s:
        s.bind((host, 0))
        return s.getsockname()[1]


def parse_address(addr: str, default_port: int = 9000) -> Tuple[str, int]:
    """``"host:port"`` / ``":port"`` / ``"host"`` -> (host, port)."""
    if ":" in addr:
        host, port = addr.rsplit(":", 1)
        return (host or "127.0.0.1", int(port))
    return (addr or "127.0.0.1", default_port)


@dataclasses.dataclass(frozen=True)
class LinkShaper:
    """Injected link profile: each round is paced to the schedule
    simulator's per-round cost, ``rtt_s + 2 * bytes * 8 / bandwidth``
    (both directions ride the link, same pricing as
    ``core.schedule.Schedule.latency``).  ``from_preset`` shapes to a
    ``repro.api.plan.NetworkPreset`` (LAN/WAN)."""

    rtt_s: float = 0.0
    bandwidth_bps: float = float("inf")

    @classmethod
    def from_preset(cls, preset) -> "LinkShaper":
        return cls(rtt_s=preset.rtt_s, bandwidth_bps=preset.bandwidth_bps)

    def round_delay(self, payload_bytes: int) -> float:
        bw = (2.0 * payload_bytes * 8.0 / self.bandwidth_bps
              if self.bandwidth_bps != float("inf") else 0.0)
        return self.rtt_s + bw


class SocketComm:
    """Two-party ``Comm`` backend over one TCP connection.

    Construct via :meth:`host` (bind + accept, usually party 0) or
    :meth:`dial` (connect with retry while the peer starts up).  Local
    arrays carry a party dimension of 1 — this process's own rows —
    exactly like a size-2 mesh axis shard; ``swap`` returns the peer's
    rows in the same (1, ...) layout.

    Mount it at the very bottom of the resilience stack::

        CoalescingComm( JournaledComm( ResilientComm( SocketComm )))

    (``Session.connect`` builds exactly that.)  ``timeout_s`` applies to
    every blocking receive; ``ResilientComm`` owns the retry budget.
    """

    n_parties = 2

    def __init__(self, sock: socket_lib.socket, party: int, *,
                 shaper: Optional[LinkShaper] = None,
                 timeout_s: Optional[float] = None):
        if party not in (0, 1):
            raise ValueError(f"party must be 0 or 1, got {party}")
        self._sock = sock
        self.party = int(party)
        self.shaper = shaper
        self.timeout_s = timeout_s
        sock.setsockopt(socket_lib.IPPROTO_TCP, socket_lib.TCP_NODELAY, 1)
        sock.settimeout(timeout_s)
        self.negotiated: Dict = {}
        #: receive buffer persisting across CommTimeouts: a timeout
        #: mid-message keeps the bytes already read, so a retried recv
        #: resumes the SAME message instead of misparsing the stream
        self._rx_buf = bytearray()
        self._seq = 0                            # completed DATA rounds
        self._ctrl_pending: collections.deque = collections.deque()
        self._recv_cache: "collections.OrderedDict[int, bytes]" = \
            collections.OrderedDict()
        self.n_swaps = 0
        self.round_bytes: List[int] = []
        self.header_bytes = 0                    # envelope overhead, not wire
        self.dup_dropped = 0

    # -- construction ---------------------------------------------------------
    @classmethod
    def host(cls, bind: Tuple[str, int], *, party: int = 0,
             session: str = "", plan: str = "", journal_len: int = 0,
             shaper: Optional[LinkShaper] = None,
             timeout_s: Optional[float] = None,
             accept_timeout_s: float = 60.0) -> "SocketComm":
        """Bind, accept one peer, handshake."""
        srv = socket_lib.socket()
        srv.setsockopt(socket_lib.SOL_SOCKET, socket_lib.SO_REUSEADDR, 1)
        srv.bind(tuple(bind))
        srv.listen(1)
        srv.settimeout(accept_timeout_s)
        try:
            conn, _ = srv.accept()
        except socket_lib.timeout as e:
            raise errors.HandshakeFailed(
                f"no peer connected to {bind} within "
                f"{accept_timeout_s}s") from e
        finally:
            srv.close()
        comm = cls(conn, party, shaper=shaper, timeout_s=timeout_s)
        comm._handshake(session, plan, journal_len,
                        handshake_timeout_s=accept_timeout_s)
        return comm

    @classmethod
    def dial(cls, peer: Tuple[str, int], *, party: int = 1,
             session: str = "", plan: str = "", journal_len: int = 0,
             shaper: Optional[LinkShaper] = None,
             timeout_s: Optional[float] = None,
             connect_timeout_s: float = 60.0) -> "SocketComm":
        """Connect to a hosting peer, retrying while it starts up."""
        deadline = time.monotonic() + connect_timeout_s
        last: Optional[Exception] = None
        while True:
            try:
                conn = socket_lib.create_connection(
                    tuple(peer), timeout=max(0.1, deadline - time.monotonic()))
                break
            except OSError as e:
                last = e
                if time.monotonic() >= deadline:
                    raise errors.HandshakeFailed(
                        f"could not reach peer at {peer} within "
                        f"{connect_timeout_s}s: {last}") from e
                time.sleep(0.05)
        comm = cls(conn, party, shaper=shaper, timeout_s=timeout_s)
        comm._handshake(session, plan, journal_len,
                        handshake_timeout_s=connect_timeout_s)
        return comm

    def _handshake(self, session: str, plan: str, journal_len: int,
                   handshake_timeout_s: float) -> None:
        hello = {"version": VERSION, "party": self.party,
                 "session": str(session), "plan": str(plan),
                 "journal": int(journal_len)}
        self._send(KIND_HELLO, 0, json.dumps(hello).encode())
        self._sock.settimeout(handshake_timeout_s)
        try:
            kind, _, _, body = self._recv_msg()
        except errors.CommError as e:
            raise errors.HandshakeFailed(f"handshake failed: {e}") from e
        finally:
            self._sock.settimeout(self.timeout_s)
        if kind != KIND_HELLO:
            raise errors.HandshakeFailed(
                f"expected HELLO, got {_KIND_NAMES.get(kind, kind)}")
        peer = json.loads(body.decode())
        if peer.get("version") != VERSION:
            raise errors.HandshakeFailed(
                f"protocol version mismatch: local {VERSION}, "
                f"peer {peer.get('version')}")
        if peer.get("party") != 1 - self.party:
            raise errors.HandshakeFailed(
                f"party collision: both ends claim party index "
                f"{self.party}" if peer.get("party") == self.party else
                f"unexpected peer party {peer.get('party')}")
        if peer.get("session") != str(session):
            raise errors.HandshakeFailed(
                f"session mismatch: local {session!r}, "
                f"peer {peer.get('session')!r} — the two parties were "
                "launched with different session seeds")
        if peer.get("plan") != str(plan):
            raise errors.HandshakeFailed(
                f"plan mismatch: local digest {plan!r}, peer "
                f"{peer.get('plan')!r} — the two parties would replay "
                "different networks")
        self.negotiated = {
            "peer_party": int(peer["party"]),
            "session": str(session),
            "plan": str(plan),
            "journal_len": int(journal_len),
            "peer_journal_len": int(peer.get("journal", 0)),
            "resume_round": min(int(journal_len),
                                int(peer.get("journal", 0))),
        }

    # -- raw messaging --------------------------------------------------------
    def _send(self, kind: int, seq: int, body: bytes) -> None:
        msg = HEADER.pack(MAGIC, kind, self.party, 0, seq & 0xFFFFFFFF,
                          len(body)) + body
        try:
            self._sock.sendall(msg)
        except socket_lib.timeout as e:
            raise errors.CommTimeout(f"socket send stalled: {e}") from e
        except OSError as e:
            raise errors.PartyCrashed(f"peer connection lost: {e}") from e
        self.header_bytes += HEADER.size

    def _fill(self, n: int) -> None:
        """Grow the receive buffer to at least n bytes (resumable: a
        timeout keeps everything read so far)."""
        while len(self._rx_buf) < n:
            try:
                chunk = self._sock.recv(1 << 20)
            except socket_lib.timeout as e:      # noqa: B902 (py3.10 alias)
                raise errors.CommTimeout(
                    f"socket recv stalled past {self._sock.gettimeout()}s "
                    f"({len(self._rx_buf)}/{n} bytes buffered)") from e
            except OSError as e:
                raise errors.PartyCrashed(
                    f"peer connection lost: {e}") from e
            if not chunk:
                raise errors.PartyCrashed(
                    f"peer closed the connection "
                    f"({len(self._rx_buf)}/{n} bytes of a message)")
            self._rx_buf.extend(chunk)

    def _recv_msg(self) -> Tuple[int, int, int, bytes]:
        self._fill(HEADER.size)
        magic, kind, party, _flags, seq, n = HEADER.unpack_from(self._rx_buf)
        if magic != MAGIC:
            raise errors.PayloadCorrupted(
                f"bad message magic {magic!r} (stream desynchronised)")
        self._fill(HEADER.size + n)
        body = bytes(self._rx_buf[HEADER.size:HEADER.size + n])
        del self._rx_buf[:HEADER.size + n]
        return kind, party, seq, body

    def _recv_data(self, expect_seq: int) -> bytes:
        if expect_seq in self._recv_cache:
            # a local retry of a round TCP already delivered: serve the
            # cached payload — the peer advanced and will never re-send
            return self._recv_cache[expect_seq]
        while True:
            kind, _, seq, body = self._recv_msg()
            if kind == KIND_CTRL:
                self._ctrl_pending.append(body)
                continue
            if kind != KIND_DATA:
                raise errors.PayloadCorrupted(
                    f"expected DATA, got {_KIND_NAMES.get(kind, kind)}")
            if seq == expect_seq:
                self._recv_cache[seq] = body
                while len(self._recv_cache) > 8:
                    self._recv_cache.popitem(last=False)
                return body
            if seq < expect_seq:                 # peer's idempotent re-send
                self.dup_dropped += 1
                continue
            raise errors.PayloadCorrupted(
                f"round desync: peer sent round {seq}, this party expects "
                f"{expect_seq}")

    # -- the Comm interface ---------------------------------------------------
    @property
    def n_rounds(self) -> int:
        return self.n_swaps

    @property
    def bytes_tx(self) -> int:
        return sum(self.round_bytes)

    def swap(self, x):
        """One protocol round: send this party's rows, return the peer's.

        Payload leaves must be uint32 with a local party dimension of 1
        (this process holds only its own shares).  Retrying after a
        ``CommTimeout`` re-enters with the same sequence number — the
        re-send is idempotent and an already-delivered peer payload is
        served from the receive cache.
        """
        leaves, treedef = jax.tree_util.tree_flatten(x)
        for leaf in leaves:
            if leaf.dtype != _U32:
                raise TypeError(
                    f"SocketComm payloads must be uint32, got {leaf.dtype}")
            if leaf.shape[0] != 1:
                raise TypeError(
                    "SocketComm is a per-process party backend: leaves "
                    f"carry a local party dim of 1, got shape {leaf.shape}")
        t0 = time.monotonic()
        blob = b"".join(np.ascontiguousarray(np.asarray(leaf)).tobytes()
                        for leaf in leaves)
        self._send(KIND_DATA, self._seq, blob)
        data = self._recv_data(self._seq)
        if len(data) != len(blob):
            raise errors.PayloadCorrupted(
                f"round {self._seq}: peer sent {len(data)} payload bytes, "
                f"expected {len(blob)} (mismatched executions)")
        if self.shaper is not None:
            target = t0 + self.shaper.round_delay(len(blob))
            now = time.monotonic()
            if target > now:
                time.sleep(target - now)
        self._seq += 1
        self.n_swaps += 1
        self.round_bytes.append(len(blob))
        out, off = [], 0
        for leaf in leaves:
            arr = np.frombuffer(data, np.uint32, count=leaf.size,
                                offset=off).reshape(leaf.shape)
            out.append(jnp.asarray(arr))
            off += leaf.size * 4
        return jax.tree_util.tree_unflatten(treedef, out)

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        return jnp.full((1,) * max(1, template.ndim), p == self.party,
                        dtype=bool)

    def party_slice(self, full: jax.Array) -> jax.Array:
        """This party's rows of a full (n_parties, ...) array."""
        return full[self.party:self.party + 1]

    # -- out-of-band control channel (serving engine link) --------------------
    def send_ctrl(self, obj: Dict, blob: bytes = b"") -> None:
        """One CTRL message: a json header plus an opaque binary blob."""
        hdr = json.dumps(obj).encode()
        self._send(KIND_CTRL, 0, struct.pack("<I", len(hdr)) + hdr + blob)

    def recv_ctrl(self,
                  timeout_s: Optional[float] = ...) -> Tuple[Dict, bytes]:
        """Next CTRL message (skipping any stale DATA re-sends)."""
        if timeout_s is not ...:
            self._sock.settimeout(timeout_s)
        try:
            while not self._ctrl_pending:
                kind, _, seq, body = self._recv_msg()
                if kind == KIND_CTRL:
                    self._ctrl_pending.append(body)
                elif kind == KIND_DATA and seq < self._seq:
                    self.dup_dropped += 1        # stale re-send, drop
                else:
                    raise errors.PayloadCorrupted(
                        f"expected CTRL, got "
                        f"{_KIND_NAMES.get(kind, kind)} seq {seq} while "
                        f"at round {self._seq}")
        finally:
            if timeout_s is not ...:
                self._sock.settimeout(self.timeout_s)
        body = self._ctrl_pending.popleft()
        (n,) = struct.unpack_from("<I", body)
        hdr = json.loads(body[4:4 + n].decode())
        return hdr, body[4 + n:]

    def close(self) -> None:
        try:
            self._sock.shutdown(socket_lib.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()

    def __enter__(self) -> "SocketComm":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
