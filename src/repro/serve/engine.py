"""InferenceEngine: continuous cross-request batching over the fused
round timeline.

``PrivateModel.__call__`` serves one caller; under concurrent traffic that
means every request pays its own full round count — N requests pay the
*sum* of their rounds.  The engine redesigns the serving surface around
the round-fused protocol instead: callers ``submit(tenant, x)`` into an
admission queue and get a future back; a schedule-driven ``BatchPolicy``
forms micro-batches from the queue; each micro-batch executes every
request as one sibling stream of ONE plan replay, so all requests advance
through the protocol in lockstep and the batch pays **max-over-requests
rounds** per ReLU call (``core.schedule.simulate_merged`` is the exact
prediction, validated against the ``CoalescingComm`` counters).

The execution contract (tested property-style in ``tests/test_engine.py``):

- **Bit-exactness**: with the default policy, batched execution of any
  request mix is bit-identical — share level, not just reveal level — to
  serial per-request execution on the same shares/triples.  Each request
  keeps its own protocol key stream (forked as
  ``Session.request_key(request_id)``, so admission order is irrelevant)
  and its own triples (from its tenant's metered provider); coalescing
  only changes the wire layout, never a value.
  ``BatchPolicy(merge_identical=True)`` additionally merges identical
  (n_elements, k, m) streams into one protocol stream per round
  (``relu_many`` auto-batching: fewer payloads and kernel passes, bytes
  can only drop) — each ReLU's *revealed* values are unchanged, but the
  output share splits differ, so downstream fixed-point truncation may
  wobble the last bit versus serial execution; it is opt-in for that
  reason.
- **Rounds**: measured fused rounds of a batch equal
  ``simulate_merged``'s prediction exactly, and — since every request
  replays the same network — equal max-over-requests rounds, not the sum.
- **Tenancy**: every tenant owns a ``beaver.MeteredProvider``; triple
  consumption is attributed per tenant and an element budget turns
  over-quota submissions into failed futures instead of half-run batches.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro import errors
from repro.core import beaver, comm as comm_lib, ring, schedule as schedule_lib
from repro.core.mpc_tensor import MPCTensor
from repro.api.compile import PrivateModel, compile as compile_model
from repro.api.plan import LAN, NETWORKS, NetworkPreset, Plan, trace_plan
from repro.api.session import Session
from repro.runtime.watchdog import StragglerWatchdog


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """When does a micro-batch stop admitting and start running?

    The policy is driven by ``core.schedule`` predictions, not heuristics
    on queue length: a batch admits the next queued request while the
    predicted fused-round latency *per request* of the merged group set
    keeps improving (merging is nearly free in rounds — every request
    replays the same network — so admission normally pays only the extra
    wire bytes), and closes when

    - the relative per-request latency gain of admitting the next request
      drops to ``min_gain`` or below ("stops improving"),
    - ``max_batch`` requests are admitted, or
    - the head request has waited ``max_wait_s`` (the deadline; checked by
      ``InferenceEngine.poll`` — ``flush`` drains unconditionally).

    ``network`` prices the timeline (LAN default; under WAN the byte term
    matters and large batches genuinely stop improving).
    ``merge_identical`` opts into cross-request ``relu_many``
    auto-batching (see the module docstring for the bit-exactness
    tradeoff).  ``bucket`` controls plan/lowering-cache shape bucketing:
    ``"exact"`` (default — one cache entry per distinct request shape,
    bit-exact) or ``"pow2"`` (batch dim padded up to the next power of
    two with zero shares: fewer cache entries and recompiles, outputs
    sliced back; the bit-exactness oracle is then serial execution of the
    *padded* request).
    """

    network: Union[NetworkPreset, str] = LAN
    max_batch: int = 8
    max_wait_s: float = float("inf")
    min_gain: float = 0.0
    merge_identical: bool = False
    bucket: str = "exact"

    @property
    def preset(self) -> NetworkPreset:
        return (NETWORKS[self.network] if isinstance(self.network, str)
                else self.network)

    def bucket_shape(self, shape: Sequence[int]) -> Tuple[int, ...]:
        shape = tuple(int(s) for s in shape)
        if self.bucket == "exact":
            return shape
        if self.bucket == "pow2":
            return (_next_pow2(shape[0]),) + shape[1:]
        raise ValueError(f"unknown bucket mode {self.bucket!r} "
                         "(expected 'exact' or 'pow2')")


@dataclasses.dataclass(frozen=True)
class Request:
    """One admitted request: who asked, what they sent, when."""

    id: int
    tenant: str
    x: MPCTensor                       # possibly padded to the shape bucket
    key: jax.Array                     # protocol key = request_key(id)
    arrival_s: float
    shape: Tuple[int, ...]             # bucketed execution shape
    out_batch: int                     # caller's true batch (pre-padding)
    deadline_s: Optional[float] = None  # completion budget from arrival


class RequestFuture:
    """Handle for a submitted request.  ``result()`` drains the engine's
    queue if the request has not run yet, then returns the output
    MPCTensor (or raises the stored error, e.g. a tenant's
    ``TripleBudgetExceeded``)."""

    def __init__(self, engine: "InferenceEngine", request: Request):
        self._engine = engine
        self.request = request
        self._value: Optional[MPCTensor] = None
        self._exc: Optional[BaseException] = None
        self._done = False
        self._event = threading.Event()
        self.report: Optional["BatchReport"] = None

    @property
    def done(self) -> bool:
        return self._done

    def result(self, timeout_s: Optional[float] = None) -> MPCTensor:
        """The output shares, draining the engine if needed.

        With ``timeout_s=None`` (historical behaviour) the engine is
        flushed once — every queued batch runs to completion.  With a
        timeout, the engine is *polled* instead (batching policy and
        ``max_wait_s`` deadlines respected) until the request resolves or
        the timeout expires, and an unresolved request raises
        ``errors.ResultTimeout`` instead of spinning forever on a wedged
        engine.

        When the engine's background pump is running (``start_pump``),
        ``result`` never drives execution itself — it just waits on the
        pump (``submit()`` alone makes progress; ``poll``/``flush`` stay
        available as manual overrides).
        """
        if not self._done:
            if self._engine.pump_running:
                if not self._event.wait(timeout_s):
                    raise errors.attach_request(
                        errors.ResultTimeout(
                            f"request {self.request.id} unresolved after "
                            f"{timeout_s}s (pump running, engine queue: "
                            f"{self._engine.pending} pending)"),
                        self.request.id, self.request.tenant)
            elif timeout_s is None:
                self._engine.flush()
            else:
                deadline = time.monotonic() + timeout_s
                while not self._done:
                    self._engine.poll()
                    if self._done:
                        break
                    if time.monotonic() >= deadline:
                        raise errors.attach_request(
                            errors.ResultTimeout(
                                f"request {self.request.id} unresolved "
                                f"after {timeout_s}s (engine queue: "
                                f"{self._engine.pending} pending)"),
                            self.request.id, self.request.tenant)
                    time.sleep(min(0.005, timeout_s / 10.0))
        if self._exc is not None:
            raise self._exc
        if not self._done:
            raise RuntimeError(
                f"request {self.request.id} did not execute: it is no "
                "longer queued but was never resolved (a batch it belonged "
                "to failed — see the engine's earlier error)")
        return self._value

    def _resolve(self, value: MPCTensor, report: "BatchReport") -> None:
        self._value, self.report, self._done = value, report, True
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        # stamp the originating request's identity, first failure wins (a
        # batch-wide exception is shared by every future it failed)
        if getattr(exc, "request_id", None) is None:
            errors.attach_request(exc, self.request.id, self.request.tenant)
        self._exc, self._done = exc, True
        self._event.set()


@dataclasses.dataclass(frozen=True)
class BatchReport:
    """What one executed micro-batch did vs what the schedule predicted."""

    request_ids: Tuple[int, ...]
    tenants: Tuple[str, ...]
    shapes: Tuple[Tuple[int, ...], ...]
    measured_rounds: int
    measured_bytes: int
    predicted_rounds: int             # simulate_merged over the group set
    predicted_bytes: int
    serial_rounds: int                # sum of per-request rounds (unfused)
    predicted_latency_s: float        # merged timeline under policy.network
    waits_s: Tuple[float, ...]        # per-request queue wait at execution
    retries: int = 0                  # batch re-executions on comm faults
    faults_recovered: int = 0         # transport rounds healed by re-send
    shed: int = 0                     # requests deadline-shed at admission

    @property
    def n_requests(self) -> int:
        return len(self.request_ids)

    @property
    def rounds_saved_ratio(self) -> float:
        """Serial-to-fused round ratio: N identical requests approach N."""
        return self.serial_rounds / max(1, self.measured_rounds)

    @property
    def sim_latencies_s(self) -> Tuple[float, ...]:
        """Per-request simulated completion latency: queue wait plus the
        merged batch's schedule-predicted timeline."""
        return tuple(w + self.predicted_latency_s for w in self.waits_s)


class InferenceEngine:
    """Request-level private-inference serving over one compiled model.

    Example::

        engine = serve.InferenceEngine(afn, params, cfg, plan,
                                       api.Session(key=0),
                                       policy=serve.BatchPolicy(max_batch=4))
        f1 = engine.submit("alice", X1)
        f2 = engine.submit("bob", X2)          # different shape: still one
        f3 = engine.submit("alice", X3)        # micro-batch, rounds shared
        y1 = f1.result().reveal()              # drains the queue
        print(engine.reports[-1].rounds_saved_ratio)

    ``plan`` supplies the HummingBird (k, m) assignment and adder mode;
    per-shape plans for other request shapes are traced on demand into a
    cache keyed by ``(config, hb, bucketed shape)``.  ``tenant_budgets``
    maps tenant names to DReLU-element triple budgets
    (``beaver.MeteredProvider``); unknown tenants default to
    ``default_budget`` (None = unmetered cap).  ``provider_factory`` lets
    deployments hand each tenant its own triple source (default: inline
    sim triples).
    """

    def __init__(self, apply_fn, params, cfg, plan: Plan,
                 session: Optional[Session] = None, *,
                 policy: Optional[BatchPolicy] = None,
                 mpc_forward: Optional[Callable] = None,
                 provider_factory: Optional[Callable[[str], object]] = None,
                 tenant_budgets: Optional[Dict[str, int]] = None,
                 default_budget: Optional[int] = None,
                 report_history: int = 1024,
                 max_batch_retries: int = 2,
                 on_party_crash: Optional[Callable] = None,
                 on_straggler: Optional[Callable] = None,
                 straggler_factor: float = 3.0):
        self.policy = policy if policy is not None else BatchPolicy()
        self.session = session if session is not None else Session(key=0)
        self.model: PrivateModel = compile_model(
            apply_fn, params, cfg, plan, self.session,
            mpc_forward=mpc_forward,
            auto_batch=self.policy.merge_identical)
        self.plan = plan
        self.comm = (self.session.comm
                     if isinstance(self.session.comm, comm_lib.CoalescingComm)
                     else comm_lib.CoalescingComm(self.session.comm))
        self._provider_factory = provider_factory or (
            lambda tenant: beaver.InlineTTP())
        self._tenant_budgets = dict(tenant_budgets or {})
        self._default_budget = default_budget
        self._tenants: Dict[str, beaver.MeteredProvider] = {}
        self._plan_cache: Dict[Tuple, Plan] = {}
        if (plan.calls and plan.input_shape
                and self.policy.bucket_shape(plan.input_shape)
                == tuple(plan.input_shape)):
            # seed only when the traced shape IS its own bucket — under
            # pow2 bucketing a plan traced at batch 3 must not stand in
            # for the padded batch-4 replay it would be cached under
            self._plan_cache[self._cache_key(plan.input_shape)] = plan
        self._queue: Deque[Request] = collections.deque()
        #: pending futures only — resolved ones are popped so a
        #: long-running engine never pins consumed requests' tensors
        self._futures: Dict[int, RequestFuture] = {}
        self._used_ids: set = set()
        self._next_id = 0
        #: a bounded window of recent batches (stats() percentiles read
        #: this; the counters below are lifetime totals)
        self.reports: Deque[BatchReport] = collections.deque(
            maxlen=report_history)
        self._totals = {"requests": 0, "batches": 0, "fused_rounds": 0,
                        "serial_rounds": 0, "retries": 0, "shed": 0,
                        "faults_recovered": 0}
        #: resilience: a retryable comm fault (ResilientComm's retry
        #: budget exhausted on a transient) re-executes the whole batch —
        #: same request keys, providers rolled back, so the retried
        #: results are bit-identical and tenants are billed once.  A
        #: PartyCrashed batch retries only if ``on_party_crash`` revives
        #: the transport (e.g. FaultInjectingComm.restart).
        self.max_batch_retries = max_batch_retries
        self.on_party_crash = on_party_crash
        #: transport hooks (see ``repro.transport.engine_link``): a
        #: two-process deployment replaces each batch attempt's execution
        #: tensors (ship the peer's input shares, keep own rows) and
        #: recombines the peer's output shares after the replay.  None =
        #: single-process execution, unchanged.
        self.on_batch_attempt: Optional[Callable] = None
        self.on_batch_outputs: Optional[Callable] = None
        #: one lock serialises every queue/execution entry point so the
        #: background pump, a frontend's submit threads, and direct
        #: poll/flush callers compose; RLock because poll -> _execute ->
        #: tenant_provider nest.
        self._lock = threading.RLock()
        self._pump_thread: Optional[threading.Thread] = None
        self._pump_stop = threading.Event()
        self.last_pump_error: Optional[BaseException] = None
        #: slow-round detection: each executed batch's per-fused-round
        #: wall time feeds the shared EWMA watchdog (same implementation
        #: as the training loop's per-step straggler detector)
        self.watchdog = StragglerWatchdog(factor=straggler_factor)
        self._on_straggler = on_straggler

    # -- plan / lowering cache -------------------------------------------------
    def _cache_key(self, shape: Sequence[int]) -> Tuple:
        return (type(self.model.cfg).__name__, getattr(self.model.cfg, "name",
                                                       ""),
                self.plan.hb, self.plan.cone,
                self.policy.bucket_shape(shape))

    def plan_for_shape(self, shape: Sequence[int]) -> Plan:
        """The (cached) traced plan replayed for requests of ``shape`` —
        keyed by ``(config, hb, bucketed shape)``, traced on demand via
        ``jax.eval_shape`` (the model is never executed)."""
        key = self._cache_key(shape)
        with self._lock:           # RLock: callers already under it re-enter
            if key not in self._plan_cache:
                if self.model.apply_fn is None:
                    raise errors.ShapeMismatch(
                        f"request shape {tuple(shape)} has no traced plan "
                        "and the engine was built without apply_fn — submit "
                        f"only shape {self.plan.input_shape} or compile "
                        "with the plaintext forward")
                bucket = self.policy.bucket_shape(shape)
                self._plan_cache[key] = trace_plan(
                    self.model.apply_fn, self.model.params, bucket,
                    hb=self.plan.hb, cone=self.plan.cone,
                    name=f"{self.plan.name}@{'x'.join(map(str, bucket))}")
            return self._plan_cache[key]

    @property
    def plan_cache_size(self) -> int:
        with self._lock:
            return len(self._plan_cache)

    # -- tenancy ---------------------------------------------------------------
    def tenant_provider(self, tenant: str) -> beaver.MeteredProvider:
        with self._lock:
            if tenant not in self._tenants:
                self._tenants[tenant] = beaver.MeteredProvider(
                    self._provider_factory(tenant),
                    budget_elements=self._tenant_budgets.get(
                        tenant, self._default_budget))
            return self._tenants[tenant]

    def tenant_usage(self, tenant: str) -> Dict[str, Optional[int]]:
        p = self.tenant_provider(tenant)
        return {"consumed_elements": p.consumed_elements,
                "consumed_bundles": p.consumed_bundles,
                "budget_elements": p.budget_elements,
                "remaining_elements": p.remaining_elements}

    @staticmethod
    def _required_elements(plan: Plan) -> int:
        return sum(n for n, w in plan.triple_specs() if n and w)

    # -- admission -------------------------------------------------------------
    def submit(self, tenant: str, x, *, request_id: Optional[int] = None,
               arrival_s: Optional[float] = None,
               deadline_s: Optional[float] = None) -> RequestFuture:
        """Enqueue one request; returns its future.

        ``x`` is the caller's secret-shared ``MPCTensor`` (a plain array is
        accepted for convenience and secret-shared with a key derived from
        the request key).  ``request_id`` defaults to an auto-increment;
        pass an explicit id to make the request's protocol randomness
        independent of submission order (``Session.request_key``).

        ``deadline_s`` is a completion budget measured from arrival: at
        execution time a request whose schedule-predicted latency alone
        (a provable lower bound — running it solo cannot be slower than
        that) already overruns the remaining budget is *shed* — its
        future fails with ``errors.DeadlineExceeded`` before a single
        protocol round or triple is spent on it.

        The request's plan is resolved here (traced into the cache if the
        shape is new), so an unservable shape fails the *submit* call —
        batch formation only ever sees cache hits and can never drop
        already-queued requests on a trace error.
        """
        with self._lock:
            if request_id is None:
                request_id = self._next_id
            if request_id in self._used_ids:
                raise errors.DuplicateRequest(
                    f"request id {request_id} already submitted")
            self.plan_for_shape(x.shape)
            self._used_ids.add(request_id)
            self._next_id = max(self._next_id, request_id + 1)
            key = self.session.request_key(request_id)
            if not isinstance(x, MPCTensor):
                enc_key, key = jax.random.split(key)
                x = MPCTensor.from_plain(enc_key, jnp.asarray(x))
            out_batch = int(x.shape[0])
            bucket = self.policy.bucket_shape(x.shape)
            if bucket != tuple(x.shape):
                pad = bucket[0] - out_batch

                def _pad(a):
                    widths = [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2)
                    return jnp.pad(a, widths)

                x = MPCTensor(ring.Ring64(_pad(x.data.lo), _pad(x.data.hi)),
                              x.frac_bits)
            req = Request(id=request_id, tenant=tenant, x=x, key=key,
                          arrival_s=(time.monotonic() if arrival_s is None
                                     else float(arrival_s)),
                          shape=bucket, out_batch=out_batch,
                          deadline_s=(None if deadline_s is None
                                      else float(deadline_s)))
            fut = RequestFuture(self, req)
            self._futures[request_id] = fut
            self._queue.append(req)
            return fut

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    # -- batching policy evaluation -------------------------------------------
    def _merged_schedule(self, requests: Sequence[Request]):
        """Exact fused timeline of executing ``requests`` as one batch:
        ``simulate_merged`` over the ReLU call rows, plus one coalesced
        round per Beaver-open site (LM secret products).  Open sites align
        positionally across requests — one mpc_forward body drives every
        sibling stream — so site i of all requests shares one round with
        summed payloads."""
        plans = [self.plan_for_shape(r.shape) for r in requests]
        sched = schedule_lib.simulate_merged(
            [p.call_specs() for p in plans],
            cone=self.plan.cone, auto_batch=self.policy.merge_identical)
        open_lists = [p.open_specs() for p in plans]
        for i in range(max((len(o) for o in open_lists), default=0)):
            sched = sched + schedule_lib.simulate_open(
                [o[i] for o in open_lists if i < len(o)])
        return sched

    def _merged_latency(self, requests: Sequence[Request]) -> float:
        sched = self._merged_schedule(requests)
        preset = self.policy.preset
        return sched.latency(preset.bandwidth_bps, preset.rtt_s)

    def _form_batch(self) -> List[Request]:
        """Admit from the queue head while the predicted per-request
        latency of the merged set keeps improving by more than
        ``policy.min_gain`` (relative).  The incumbent latency is carried
        forward, so forming a batch of B costs B merged-schedule
        simulations, not B^2."""
        batch = [self._queue.popleft()]
        lat = self._merged_latency(batch)
        while self._queue and len(batch) < self.policy.max_batch:
            n = len(batch)
            lat_new = self._merged_latency(batch + [self._queue[0]])
            if lat <= 0.0:
                # zero-round incumbent (fully-culled plan): merging is
                # free for it, so the candidate rides along
                gain = 1.0
            else:
                gain = 1.0 - (lat_new / (n + 1)) / (lat / n)
            if gain <= self.policy.min_gain:
                break
            batch.append(self._queue.popleft())
            lat = lat_new
        return batch

    # -- execution -------------------------------------------------------------
    def poll(self, now_s: Optional[float] = None) -> List[BatchReport]:
        """Run every batch that is *ready*: the policy closed it with
        requests still queued behind it (more merging would not help), it
        is full, or its head request hit the ``max_wait_s`` deadline.
        Returns the reports of the batches executed."""
        now = time.monotonic() if now_s is None else float(now_s)
        executed = []
        with self._lock:
            while self._queue:
                head_wait = now - self._queue[0].arrival_s
                deadline = head_wait >= self.policy.max_wait_s
                batch = self._form_batch()
                ready = (deadline or len(batch) >= self.policy.max_batch
                         or bool(self._queue))
                if not ready:
                    # put the still-open batch back, wait for more traffic
                    self._queue.extendleft(reversed(batch))
                    break
                report = self._execute(batch, now)
                if report is not None:
                    executed.append(report)
        return executed

    def flush(self) -> List[BatchReport]:
        """Drain the queue unconditionally (deadlines ignored): form
        policy-shaped batches until nothing is pending."""
        executed = []
        with self._lock:
            while self._queue:
                report = self._execute(self._form_batch(), time.monotonic())
                if report is not None:
                    executed.append(report)
        return executed

    def _execute(self, batch: List[Request],
                 now_s: float) -> Optional[BatchReport]:
        # deadline shedding first: a request whose schedule-predicted solo
        # latency already overruns its remaining budget provably cannot
        # finish in time — fail it typed, before reserving any triples
        shed = 0
        survivors: List[Request] = []
        for r in batch:
            if (r.deadline_s is not None
                    and (now_s - r.arrival_s) + self._merged_latency([r])
                    > r.deadline_s):
                self._futures.pop(r.id)._fail(errors.DeadlineExceeded(
                    f"request {r.id} (tenant {r.tenant!r}): "
                    f"{now_s - r.arrival_s:.3f}s already queued and the "
                    f"schedule-predicted replay alone overruns the "
                    f"{r.deadline_s}s deadline — shed before execution"))
                shed += 1
                continue
            survivors.append(r)
        self._totals["shed"] += shed
        # pre-reserve tenant budgets so a mid-protocol budget error can
        # never leave a half-executed batch: over-quota requests fail
        # their futures here and are dropped before any protocol round
        reserved: Dict[str, int] = {}
        admitted: List[Request] = []
        for r in survivors:
            need = self._required_elements(self.plan_for_shape(r.shape))
            provider = self.tenant_provider(r.tenant)
            if provider.budget_elements is not None:
                already = provider.consumed_elements + reserved.get(r.tenant,
                                                                    0)
                if already + need > provider.budget_elements:
                    self._futures.pop(r.id)._fail(beaver.TripleBudgetExceeded(
                        f"tenant {r.tenant!r}: request {r.id} needs {need} "
                        f"DReLU elements but only "
                        f"{provider.budget_elements - already} of "
                        f"{provider.budget_elements} remain"))
                    continue
            reserved[r.tenant] = reserved.get(r.tenant, 0) + need
            admitted.append(r)
        if not admitted:                 # every request over-quota or shed
            return None
        sched = self._merged_schedule(admitted)
        serial_rounds = sum(
            self.plan_for_shape(r.shape).schedule().n_rounds
            for r in admitted)
        providers = [self.tenant_provider(r.tenant) for r in admitted]
        resilient = comm_lib.find_resilient(self.comm)
        attempts = 0
        while True:
            # per ATTEMPT: fresh key iterators (same request keys — the
            # retry draws the identical stream), provider checkpoints so
            # a rolled-back tenant re-draws identical triples and is
            # billed once, and fresh round/byte marks so the report
            # reflects only the successful attempt
            rounds0, bytes0 = self.comm.n_rounds, self.comm.bytes_tx
            recovered0 = resilient.recovered if resilient else 0
            tokens = [(p, p.checkpoint())
                      for p in dict.fromkeys(providers)]
            key_iters = [iter(jax.random.split(r.key, 256))
                         for r in admitted]
            # transport hook: a two-process deployment ships the peer's
            # input shares here (per attempt — a retried batch re-sends
            # its descriptor) and returns this party's execution tensors
            xs = [r.x for r in admitted]
            if self.on_batch_attempt is not None:
                xs = self.on_batch_attempt(admitted)
            t0 = time.monotonic()
            try:
                outs = self.model._run_streams(
                    xs, key_iters, providers,
                    self.comm, self.model.params,
                    auto_batch=self.policy.merge_identical)
                break
            except BaseException as e:
                for p, tok in tokens:
                    p.rollback(tok)
                crash = isinstance(e, errors.PartyCrashed)
                retryable = (errors.is_retryable(e)
                             or (crash and self.on_party_crash is not None))
                if not retryable or attempts >= self.max_batch_retries:
                    # a failed replay must not strand its futures: fail
                    # them all so result() surfaces the error instead of
                    # hanging on a request that left the queue but never
                    # produced an output
                    for r in admitted:
                        self._futures.pop(r.id)._fail(e)
                    raise
                if crash:
                    self.on_party_crash(e)      # revive the transport
                attempts += 1
                self._totals["retries"] += 1
        if self.on_batch_outputs is not None:
            # transport hook: collect the peer's output share rows and
            # recombine into full-party tensors so futures reveal
            outs = self.on_batch_outputs(admitted, outs)
        wall = time.monotonic() - t0
        faults_recovered = ((resilient.recovered - recovered0)
                            if resilient else 0)
        self._totals["faults_recovered"] += faults_recovered
        preset = self.policy.preset
        report = BatchReport(
            request_ids=tuple(r.id for r in admitted),
            tenants=tuple(r.tenant for r in admitted),
            shapes=tuple(r.shape for r in admitted),
            measured_rounds=self.comm.n_rounds - rounds0,
            measured_bytes=self.comm.bytes_tx - bytes0,
            predicted_rounds=sched.n_rounds,
            predicted_bytes=sched.bytes_tx,
            serial_rounds=serial_rounds,
            predicted_latency_s=sched.latency(preset.bandwidth_bps,
                                              preset.rtt_s),
            waits_s=tuple(max(0.0, now_s - r.arrival_s) for r in admitted),
            retries=attempts,
            faults_recovered=faults_recovered,
            shed=shed)
        self.reports.append(report)
        self._totals["requests"] += report.n_requests
        self._totals["batches"] += 1
        self._totals["fused_rounds"] += report.measured_rounds
        self._totals["serial_rounds"] += report.serial_rounds
        if report.measured_rounds:     # slow-round watchdog (shared EWMA)
            self.watchdog.observe(len(self.reports) - 1,
                                  wall / report.measured_rounds,
                                  on_straggler=self._on_straggler)
        for r, out in zip(admitted, outs):
            if r.out_batch != r.shape[0]:      # slice bucket padding back off
                out = MPCTensor(
                    ring.Ring64(out.data.lo[:, :r.out_batch],
                                out.data.hi[:, :r.out_batch]),
                    out.frac_bits)
            self._futures.pop(r.id)._resolve(out, report)
        return report

    # -- background pump -------------------------------------------------------
    @property
    def pump_running(self) -> bool:
        return self._pump_thread is not None and self._pump_thread.is_alive()

    def start_pump(self, interval_s: float = 0.005,
                   max_wait_s: Optional[float] = None) -> None:
        """Drive the engine from a daemon thread so ``submit()`` alone
        makes progress (the async-frontend contract): the pump ``poll``s
        continuously, and once the head request has aged past
        ``max_wait_s`` (default: the policy's ``max_wait_s``, or 50 ms
        when that is unbounded) it ``flush``es so a lone request is never
        stranded waiting for a batch that will not fill.  ``poll`` and
        ``flush`` remain safe to call manually — everything serialises on
        the engine lock.  A batch failure inside the pump fails its
        futures exactly as a caller-driven batch would and is kept in
        ``last_pump_error``; the pump keeps running."""
        if self.pump_running:
            return
        if max_wait_s is None:
            max_wait_s = (self.policy.max_wait_s
                          if self.policy.max_wait_s != float("inf") else 0.05)
        self._pump_stop.clear()
        self._pump_thread = threading.Thread(
            target=self._pump_loop, args=(float(interval_s),
                                          float(max_wait_s)),
            name="engine-pump", daemon=True)
        self._pump_thread.start()

    def stop_pump(self, timeout_s: float = 5.0) -> None:
        """Stop the background pump (pending requests stay queued)."""
        if self._pump_thread is None:
            return
        self._pump_stop.set()
        self._pump_thread.join(timeout_s)
        self._pump_thread = None

    def _pump_loop(self, interval_s: float, max_wait_s: float) -> None:
        while not self._pump_stop.is_set():
            try:
                executed = self.poll()
                if not executed:
                    with self._lock:
                        head = self._queue[0] if self._queue else None
                        age = (time.monotonic() - head.arrival_s
                               if head is not None else -1.0)
                    if head is not None and age >= max_wait_s:
                        self.flush()
            except Exception as e:          # futures already failed, typed
                with self._lock:
                    self.last_pump_error = e
            self._pump_stop.wait(interval_s)

    # -- aggregate stats -------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Lifetime totals (fused vs serial rounds over every executed
        batch) plus the simulated per-request latency distribution (queue
        wait + the merged timeline under ``policy.network``) over the
        retained ``report_history`` window."""
        with self._lock:
            lats = sorted(l for rep in self.reports
                          for l in rep.sim_latencies_s)
            totals = dict(self._totals)

        def pct(p: float) -> float:
            if not lats:
                return 0.0
            return lats[min(len(lats) - 1, int(p * len(lats)))]

        return {
            **totals,
            "rounds_saved_ratio": (totals["serial_rounds"]
                                   / max(1, totals["fused_rounds"])),
            "p50_sim_latency_s": pct(0.50),
            "p95_sim_latency_s": pct(0.95),
            "slow_batches": len(self.watchdog.stragglers),
        }
