"""Production meshes.

Defined as functions (never module-level constants) so importing this
module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mpc_mesh():
    """MPC serving mesh: party = pod (2 non-colluding servers, each a
    16x16 slice used as 256-way data parallelism over the request batch)."""
    return jax.make_mesh((2, 256), ("party", "data"))


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
