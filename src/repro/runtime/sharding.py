"""Path-based partition rules: DP / FSDP / TP / SP / EP.

Every parameter leaf is matched by the trailing components of its pytree
path; rules produce a PartitionSpec whose axes reference the production
mesh ("pod", "data", "model").  Modes:

  train  - FSDP (params + optimizer states sharded over the data axes,
           ZeRO-3 style) x TP over `model`; activations batch-sharded.
  serve  - TP over `model`; params replicated over `data` unless the arch
           is flagged huge (grok/mixtral/internvl) in which case they stay
           FSDP-sharded ("weight-gathered serving").

KV caches: batch over data when divisible, else sequence (context
parallelism for long_500k B=1); kv-heads over model when divisible, else
head_dim.  All rules are pure functions of (shape, path, mesh, mode) so
the same code drives the 1-device smoke mesh and the 512-chip dry-run.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

# archs whose params don't fit TP-16 replicated-over-data at bf16.
# internvl2-76b (152 GB bf16 / 16 = 9.5 GB/dev) fits TP-16 and serves
# without per-step weight gathers — EXPERIMENTS.md §Perf iteration B
# measured 2.19 s -> ~0 collective per decode step by removing it here.
FSDP_SERVE_ARCHS = ("grok-1-314b", "mixtral-8x22b")


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))


def model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return "/".join(out)


def _div(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def _maybe(axis, dim: int, mesh: Mesh) -> Optional[Any]:
    """Use `axis` for this dim only if the dim divides the axis size."""
    if axis is None:
        return None
    size = (int(np.prod([mesh.shape[a] for a in axis]))
            if isinstance(axis, tuple) else mesh.shape.get(axis, 1))
    return axis if _div(dim, size) else None


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

_RULES = [
    # (path regex, (dim -> role)) roles: F=fsdp axes, M=model, N=replicated
    (r"embed/table$",             ("M", "F")),
    (r"lm_head/w$",               ("F", "M")),
    (r"(attn|xattn)/w[qkv]/w$",   ("F", "M")),
    (r"(attn|xattn)/w[qkv]/b$",   ("M",)),
    (r"(attn|xattn)/wo/w$",       ("M", "F")),
    (r"mlp/w_(up|gate)$",         ("F", "M")),
    (r"mlp/w_down$",              ("M", "F")),
    (r"moe/router/w$",            ("F", "N")),
    (r"moe/w_(up|gate)$",         ("E", "F", "M")),
    (r"moe/w_down$",              ("E", "M", "F")),
    (r"mamba/in_proj/w$",         ("F", "M")),
    (r"mamba/conv_w$",            ("N", "M")),
    (r"mamba/conv_b$",            ("M",)),
    (r"mamba/x_proj/w$",          ("M", "N")),
    (r"mamba/dt_proj/w$",         ("N", "M")),
    (r"mamba/dt_proj/b$",         ("M",)),
    (r"mamba/a_log$",             ("M", "N")),
    (r"mamba/d_skip$",            ("M",)),
    (r"mamba/dt_bias$",           ("M",)),
    (r"mamba/norm/scale$",        ("M",)),
    (r"mamba/out_proj/w$",        ("M", "F")),
]


def param_spec(path_str: str, shape: Tuple[int, ...], mesh: Mesh,
               mode: str, cfg: Optional[ArchConfig] = None,
               ep: bool = False) -> P:
    fsdp: Any = data_axes(mesh)
    if mode == "serve" and cfg is not None and cfg.name not in FSDP_SERVE_ARCHS:
        fsdp = None  # replicate over data; TP only
    stacked = bool(re.search(r"(^|/)((enc_|dec_)?layers)/", path_str))
    n_lead = 1 if stacked else 0

    for pat, roles in _RULES:
        if re.search(pat, path_str):
            dims = shape[n_lead:]
            spec: list = [None] * n_lead
            # special-case mamba a_log (stacked 1D for mamba2)
            roles_eff = roles[: len(dims)]
            for dim, role in zip(dims, roles_eff):
                if role == "M":
                    spec.append(_maybe("model", dim, mesh))
                elif role == "F":
                    spec.append(_maybe(fsdp, dim, mesh) if fsdp else None)
                elif role == "E":
                    spec.append(_maybe("model", dim, mesh) if ep else None)
                else:
                    spec.append(None)
            spec += [None] * (len(shape) - len(spec))
            return P(*spec)
    # norms, scalars, biases: replicated (tiny)
    return P(*([None] * len(shape)))


def param_shardings(params, mesh: Mesh, mode: str,
                    cfg: Optional[ArchConfig] = None, ep: bool = False):
    def one(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, mesh, mode, cfg, ep)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# Activation / batch / cache rules
# ---------------------------------------------------------------------------

def batch_spec(batch: int, mesh: Mesh, extra_dims: int = 1) -> P:
    dp = data_axes(mesh)
    axis = _maybe(dp, batch, mesh)
    return P(axis, *([None] * extra_dims))


def cache_spec(path_str: str, shape: Tuple[int, ...], cfg: ArchConfig,
               mesh: Mesh) -> P:
    """KV/SSM cache sharding. Leading dim is the stacked layer axis."""
    dp = data_axes(mesh)
    if re.search(r"(kv|self_kv)/[kv]$|mem_[kv]$", path_str):
        # (L, B, S, K, Dh): context-parallel — batch over dp, sequence over
        # model (long_500k B=1: sequence over both axes); matches the
        # in-model constraint in nn/attention.attention_decode.
        _, b, s, kheads, dh = shape
        batch_axis = _maybe(dp, b, mesh)
        if batch_axis:
            return P(None, batch_axis, _maybe("model", s, mesh), None, None)
        both = dp + (("model",) if "model" in mesh.axis_names else ())
        return P(None, None, _maybe(both, s, mesh), None, None)
    if re.search(r"ssm/h$", path_str):
        # mamba1: (L, B, Di, N); mamba2: (L, B, H, P, N)
        b = shape[1]
        batch_axis = _maybe(dp, b, mesh)
        inner = _maybe("model", shape[2], mesh)
        return P(None, batch_axis, inner, *([None] * (len(shape) - 3)))
    if re.search(r"ssm/conv$", path_str):
        b = shape[1]
        return P(None, _maybe(dp, b, mesh), None,
                 _maybe("model", shape[3], mesh))
    return P(*([None] * len(shape)))


def cache_shardings(cache, cfg: ArchConfig, mesh: Mesh):
    def one(path, leaf):
        return NamedSharding(mesh, cache_spec(_path_str(path), leaf.shape,
                                              cfg, mesh))

    return jax.tree_util.tree_map_with_path(one, cache)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
