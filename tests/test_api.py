"""Plan/Session/compile API: bit-identity of the deprecation shims vs the
pre-refactor replay, Plan JSON round-trips, triple providers, and edge
plans (all-identity, single-group custom model, empty batch)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs import RESNET_SMOKE
from repro.core import MPCTensor, beaver, comm as comm_lib, ring
from repro.core.hummingbird import HBConfig, HBLayer
from repro.models import resnet
from repro.search.engine import SearchResult


# ---------------------------------------------------------------------------
# Frozen pre-refactor replay (the seed-era mpc_apply/mpc_apply_many bodies),
# kept here as the regression oracle — the shims and the api path must stay
# bit-identical to it.
# ---------------------------------------------------------------------------

def legacy_mpc_apply(params, x, cfg, key, hb=None, comm=None, triples=None,
                     cone=False):
    comm = comm or comm_lib.SimComm()
    hb_layers = (hb.layers if hb is not None else
                 tuple(HBLayer() for _ in range(resnet.n_relu_groups(cfg))))
    key_iter = iter(jax.random.split(key, 256))
    triple_iter = iter(triples) if triples is not None else None

    def _relu(ts, g):
        tri = next(triple_iter) if triple_iter is not None else None
        return [ts[0].relu(next(key_iter), comm=comm, hb=hb_layers[g],
                           triples=tri, cone=cone)]

    return resnet._mpc_forward(params, [x], cfg, _relu, comm)[0]


def legacy_mpc_apply_many(params, xs, cfg, key, hb=None, comm=None,
                          triples=None, cone=False):
    from repro.nn import common as nn_common

    comm = comm or comm_lib.SimComm()
    hb_layers = (hb.layers if hb is not None else
                 tuple(HBLayer() for _ in range(resnet.n_relu_groups(cfg))))
    key_iter = iter(jax.random.split(key, 256 * max(1, len(xs))))
    triple_iter = iter(triples) if triples is not None else None

    def _relu(ts, g):
        tris = next(triple_iter) if triple_iter is not None else None
        keys = [next(key_iter) for _ in ts]
        return nn_common.mpc_relu_many(keys, ts, hbs=[hb_layers[g]] * len(ts),
                                       comm=comm, triples_list=tris,
                                       cone=cone)

    return resnet._mpc_forward(params, list(xs), cfg, _relu, comm)


@pytest.fixture(scope="module")
def smoke_setup():
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 8, 8)) * 0.5

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

    plan = api.trace_plan(afn, params, x.shape, name="smoke")
    return afn, params, x, plan


def _mixed_hb(plan):
    """(21,13) everywhere but the last group culled."""
    return HBConfig(tuple([HBLayer(k=21, m=13)] * (plan.n_groups - 1)
                          + [HBLayer(k=13, m=13)]), plan.group_elements)


# ---------------------------------------------------------------------------
# Bit-identity: api path == deprecation shims == pre-refactor replay
# ---------------------------------------------------------------------------

def test_compile_bit_identical_to_prerefactor(smoke_setup):
    afn, params, x, plan = smoke_setup
    X = MPCTensor.from_plain(jax.random.PRNGKey(2), x)
    for hb in (None, _mixed_hb(plan)):
        want = legacy_mpc_apply(params, X, RESNET_SMOKE,
                                jax.random.PRNGKey(3), hb=hb)
        run_plan = plan.with_hb(hb) if hb is not None else plan
        model = api.compile(afn, params, RESNET_SMOKE, run_plan,
                            api.Session())
        got = model(X, key=jax.random.PRNGKey(3))
        np.testing.assert_array_equal(ring.to_uint64_np(got.data),
                                      ring.to_uint64_np(want.data))
        # the shim delegates to the same machinery — also bit-identical
        shim = resnet.mpc_apply(params, X, RESNET_SMOKE,
                                jax.random.PRNGKey(3), hb=hb)
        np.testing.assert_array_equal(ring.to_uint64_np(shim.data),
                                      ring.to_uint64_np(want.data))


def test_mpc_apply_many_shim_bit_identical(smoke_setup):
    afn, params, x, plan = smoke_setup
    Xs = [MPCTensor.from_plain(jax.random.PRNGKey(10 + i), x)
          for i in range(2)]
    want = legacy_mpc_apply_many(params, Xs, RESNET_SMOKE,
                                 jax.random.PRNGKey(4))
    got = resnet.mpc_apply_many(params, Xs, RESNET_SMOKE,
                                jax.random.PRNGKey(4))
    for a, b in zip(got, want):
        np.testing.assert_array_equal(ring.to_uint64_np(a.data),
                                      ring.to_uint64_np(b.data))


def test_serve_step_bit_identical_with_pool(smoke_setup):
    afn, params, x, plan = smoke_setup
    hb = _mixed_hb(plan)
    run_plan = plan.with_hb(hb)
    pool = beaver.gen_plan_triples(jax.random.PRNGKey(5),
                                   run_plan.triple_specs())
    X = MPCTensor.from_plain(jax.random.PRNGKey(6), x)
    want = legacy_mpc_apply(params, X, RESNET_SMOKE, jax.random.PRNGKey(7),
                            hb=hb, triples=pool)
    model = api.compile(afn, params, RESNET_SMOKE, run_plan, api.Session())
    lo, hi = model.serve_step()(params, X.data.lo, X.data.hi, pool,
                                jax.random.PRNGKey(7))
    np.testing.assert_array_equal(
        ring.to_uint64_np(ring.Ring64(lo, hi)),
        ring.to_uint64_np(want.data))


# ---------------------------------------------------------------------------
# Plan JSON round-trips
# ---------------------------------------------------------------------------

def test_plan_json_roundtrip_with_identical_cost(smoke_setup, tmp_path):
    _, params, x, plan = smoke_setup
    plan = plan.with_hb(_mixed_hb(plan))
    path = tmp_path / "plan.json"
    plan.save(path)
    loaded = api.Plan.load(path)
    assert loaded == plan
    assert loaded.cost() == plan.cost()
    assert loaded.cost(streams=3) == plan.cost(streams=3)
    assert loaded.estimate(network=api.WAN) == plan.estimate(network=api.WAN)
    assert loaded.triple_specs() == plan.triple_specs()


def test_hbconfig_and_searchresult_json_roundtrip(smoke_setup):
    _, _, _, plan = smoke_setup
    hb = _mixed_hb(plan)
    assert HBConfig.from_json(hb.to_json()) == hb
    res = SearchResult(config=hb, accuracy=0.9, baseline_accuracy=0.95,
                       budget_fraction=hb.budget_fraction(),
                       search_time_s=1.5, nodes_visited=10, nodes_pruned=3,
                       plan=plan.with_hb(hb))
    back = SearchResult.from_json(res.to_json())
    assert back.config == res.config
    assert back.plan == res.plan
    assert back.accuracy == res.accuracy
    assert back.nodes_pruned == res.nodes_pruned


# ---------------------------------------------------------------------------
# Edge plans
# ---------------------------------------------------------------------------

def test_all_identity_plan_zero_comm(smoke_setup):
    """Width-0 everywhere: private inference degrades to the linear model
    at zero protocol communication."""
    afn, params, x, plan = smoke_setup
    hb = HBConfig(tuple(HBLayer(k=13, m=13) for _ in range(plan.n_groups)),
                  plan.group_elements)
    cm = comm_lib.CountingComm()
    model = api.compile(afn, params, RESNET_SMOKE, plan.with_hb(hb),
                        api.Session(comm=cm))
    X = MPCTensor.from_plain(jax.random.PRNGKey(2), x)
    out = model(X)
    assert cm.n_swaps == 0
    assert plan.with_hb(hb).cost().rounds == 0
    assert plan.with_hb(hb).cost().bytes_tx == 0
    ref = afn(params, x, relu_fn=lambda v, g: v)   # identity-ReLU plaintext
    np.testing.assert_allclose(out.reveal_np(), np.asarray(ref), atol=2e-2)


def test_single_group_custom_model():
    """A model the repo has never seen: one dense->relu->dense block with
    an explicit mpc_forward — the planner and compiler are model-agnostic."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = {"w1": jax.random.normal(k1, (6, 8)) * 0.5,
              "w2": jax.random.normal(k2, (8, 4)) * 0.5}

    def afn(p, v, relu_fn=None):
        relu = relu_fn or (lambda h, g: jax.nn.relu(h))
        return relu(v @ p["w1"], 0) @ p["w2"]

    def mpc_forward(p, hs, cfg, relu_fn, comm):
        hs = [h.matmul_public(p["w1"]) for h in hs]
        hs = relu_fn(hs, 0)
        return [h.matmul_public(p["w2"]) for h in hs]

    x = jax.random.normal(jax.random.PRNGKey(3), (5, 6))
    plan = api.trace_plan(afn, params, x.shape, name="mlp")
    assert plan.n_groups == 1 and len(plan.calls) == 1
    assert plan.calls[0].n_elements == 5 * 8
    plan = plan.with_hb(HBConfig((HBLayer(k=24, m=0),), plan.group_elements))
    model = api.compile(afn, params, cfg=None, plan=plan,
                        session=api.Session(key=1), mpc_forward=mpc_forward)
    X = model.encrypt(jax.random.PRNGKey(4), x)
    out = model(X)
    np.testing.assert_allclose(out.reveal_np(), np.asarray(afn(params, x)),
                               atol=2e-2)


def test_empty_batch(smoke_setup):
    """Batch 0 flows through the whole private forward: correct output
    shape, zero protocol communication."""
    afn, params, _, plan = smoke_setup
    x = jnp.zeros((0, 3, 8, 8))
    cm = comm_lib.CountingComm()
    model = api.compile(afn, params, RESNET_SMOKE, plan.with_hb(_mixed_hb(plan)),
                        api.Session(comm=cm))
    X = MPCTensor.from_plain(jax.random.PRNGKey(2), x)
    out = model(X)
    assert out.shape == (0, RESNET_SMOKE.n_classes)
    assert cm.n_swaps == 0
    assert out.reveal_np().shape == (0, RESNET_SMOKE.n_classes)


# ---------------------------------------------------------------------------
# Triple providers
# ---------------------------------------------------------------------------

def test_streaming_and_eager_providers(smoke_setup):
    afn, params, x, plan = smoke_setup
    run_plan = plan.with_hb(_mixed_hb(plan))
    want = np.argmax(np.asarray(afn(params, x)), -1)
    for provider in (beaver.StreamingTTP(jax.random.PRNGKey(8)),
                     beaver.EagerTTP(jax.random.PRNGKey(9),
                                     run_plan.triple_specs(), requests=2)):
        model = api.compile(afn, params, RESNET_SMOKE, run_plan,
                            api.Session(key=2, provider=provider))
        X = MPCTensor.from_plain(jax.random.PRNGKey(10), x)
        for _ in range(2):   # EagerTTP pool sized for exactly two requests
            out = model(X)
            np.testing.assert_array_equal(np.argmax(out.reveal_np(), -1),
                                          want)


def test_eager_pool_feeds_sibling_streams(smoke_setup):
    """EagerTTP(streams=N) lays bundles out call-major/stream-minor, the
    order a multi-stream replay pops them in."""
    afn, params, x, plan = smoke_setup
    run_plan = plan.with_hb(_mixed_hb(plan))
    want = np.argmax(np.asarray(afn(params, x)), -1)
    pool = beaver.EagerTTP(jax.random.PRNGKey(20), run_plan.triple_specs(),
                           streams=2)
    model = api.compile(afn, params, RESNET_SMOKE, run_plan,
                        api.Session(key=5, provider=pool))
    Xs = [MPCTensor.from_plain(jax.random.PRNGKey(21 + i), x)
          for i in range(2)]
    for out in model(Xs):
        np.testing.assert_array_equal(np.argmax(out.reveal_np(), -1), want)


def test_trace_free_plan_cost_raises(smoke_setup):
    _, _, _, plan = smoke_setup
    bare = api.Plan.from_hb(_mixed_hb(plan))
    with pytest.raises(ValueError, match="traced plan"):
        bare.cost()
    with pytest.raises(ValueError, match="traced plan"):
        bare.estimate(network=api.LAN)


def test_triple_pool_exhaustion_raises(smoke_setup):
    afn, params, x, plan = smoke_setup
    run_plan = plan.with_hb(_mixed_hb(plan))
    pool = beaver.EagerTTP(jax.random.PRNGKey(11), run_plan.triple_specs(),
                           requests=1)
    model = api.compile(afn, params, RESNET_SMOKE, run_plan,
                        api.Session(key=3, provider=pool))
    X = MPCTensor.from_plain(jax.random.PRNGKey(12), x)
    model(X)
    with pytest.raises(RuntimeError, match="TriplePool exhausted"):
        model(X)


def test_session_owns_prng_stream(smoke_setup):
    """Two calls without explicit keys draw different protocol randomness
    but reveal the same prediction; an explicit key reproduces exactly."""
    afn, params, x, plan = smoke_setup
    model = api.compile(afn, params, RESNET_SMOKE,
                        plan.with_hb(_mixed_hb(plan)), api.Session(key=4))
    X = MPCTensor.from_plain(jax.random.PRNGKey(13), x)
    a, b = model(X), model(X)
    assert not np.array_equal(ring.to_uint64_np(a.data),
                              ring.to_uint64_np(b.data))
    np.testing.assert_allclose(a.reveal_np(), b.reveal_np(), atol=2e-2)
    c1 = model(X, key=jax.random.PRNGKey(42))
    c2 = model(X, key=jax.random.PRNGKey(42))
    np.testing.assert_array_equal(ring.to_uint64_np(c1.data),
                                  ring.to_uint64_np(c2.data))
