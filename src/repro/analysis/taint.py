"""HLO leakage census: a dataflow taint pass over compiled serve-step
programs proving no collective ever carries an unmasked secret share.

The GMW round seam guarantees that every wire payload is either pure
session randomness (the a2b preparation round) or a secret blinded by a
Beaver triple / session-derived mask (``d = x ^ a``).  This module
checks the *compiled artifact* for that property: it walks the lowered
HLO of ``PrivateModel.serve_step(mesh)`` (reusing
``runtime.hlo_analyzer``'s parser and call-graph walk) carrying three
boolean flags per value:

- ``secret`` — the value depends on a share input (the ``lo``/``hi``
  limbs of the request tensor),
- ``mask`` — the value depends on masking material (the Beaver triple
  pool or a session PRNG key input),
- ``unsafe`` — the value *contains an element* that is secret-derived
  with no mask in its lineage.

Propagation distinguishes element-mixing ops (add/xor/mul/...: the
output recomputes ``unsafe = secret and not mask`` from the unioned
flags — xor-ing a mask onto a secret yields a safe value) from
element-preserving data movement (concatenate/tuple/reshape/slice/...:
``unsafe`` is the OR of the operands' — packing a raw share next to a
masked one does NOT launder it).  Every ``collective-permute`` operand
is recorded with its flags; the census must report **zero unmasked
collectives** on the canonical ResNet plans and its total count must
equal ``collective_census``'s (cross-check).

This is a structural one-sided check, not an information-flow proof:
mask *cancellation* (``x ^ r ^ r``) is not tracked, so a value that
re-exposes a secret by reusing its mask still counts as masked.  It
exists to catch the realistic failure class — a refactor that sends a
share on the wire without ever touching the triple/key inputs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.runtime.hlo_analyzer import (_BODY_RE, _BRANCHES_RE, _CALLS_RE,
                                        _TRIP_RE, COLLECTIVES, HloAnalysis,
                                        OpInfo)

_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")


# ---------------------------------------------------------------------------
# flags
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Flags:
    secret: bool = False
    mask: bool = False
    unsafe: bool = False

    def union(self, other: "Flags") -> "Flags":
        return Flags(self.secret | other.secret, self.mask | other.mask,
                     self.unsafe | other.unsafe)


PUBLIC = Flags()
SECRET = Flags(secret=True, unsafe=True)
MASK = Flags(mask=True)


def _union(flags: Sequence[Flags]) -> Flags:
    out = PUBLIC
    for f in flags:
        out = out.union(f)
    return out


# element-preserving data movement: output elements ARE (a subset /
# rearrangement of) input elements, so unsafety survives verbatim
_PRESERVING = frozenset({
    "tuple", "get-tuple-element", "concatenate", "reshape", "transpose",
    "slice", "dynamic-slice", "dynamic-update-slice", "broadcast", "copy",
    "copy-start", "copy-done", "convert", "bitcast-convert", "pad",
    "reverse", "gather", "optimization-barrier", "all-gather",
})

# flag-free sources
_PUBLIC_SOURCES = frozenset({
    "constant", "iota", "partition-id", "replica-id", "after-all",
})


@dataclasses.dataclass(frozen=True)
class CollectiveTaint:
    """One collective instruction with the taint flags of its operand.

    ``count`` carries while-loop trip scaling (same convention as
    ``hlo_analyzer.CollectiveOp.count``)."""

    kind: str
    comp: str
    name: str
    count: int
    secret: bool
    mask: bool
    unsafe: bool


# ---------------------------------------------------------------------------
# the pass
# ---------------------------------------------------------------------------

class TaintAnalysis:
    """Taint walk over one HLO module (text as parsed by
    ``runtime.hlo_analyzer.HloAnalysis``)."""

    def __init__(self, hlo_text: str):
        self.h = HloAnalysis(hlo_text)
        self._parsed: Dict[str, Tuple[Dict[str, str], List[OpInfo]]] = {}

    def _ops(self, comp: str):
        if comp not in self._parsed:
            self._parsed[comp] = self.h._ops(comp)
        return self._parsed[comp]

    def census(self, secret_params: Sequence[int] = (),
               mask_params: Sequence[int] = (),
               kinds: Sequence[str] = ("collective-permute",),
               ) -> List[CollectiveTaint]:
        """Walk the entry computation with the given entry-parameter
        classification (indices into the flattened jit argument list;
        everything else is public) and return every matching collective
        with its operand's flags, in program order."""
        entry = self.h.entry
        if entry is None:
            return []
        _, ops = self._ops(entry)
        n_params = 0
        for op in ops:
            if op.kind == "parameter":
                m = _PARAM_IDX_RE.search(op.line)
                if m:
                    n_params = max(n_params, int(m.group(1)) + 1)
        secret_set, mask_set = set(secret_params), set(mask_params)
        param_flags = tuple(
            Flags(secret=i in secret_set, mask=i in mask_set,
                  unsafe=(i in secret_set and i not in mask_set))
            for i in range(n_params))
        records: List[CollectiveTaint] = []
        self._analyze(entry, param_flags, 1, records, frozenset(kinds),
                      record=True)
        return records

    # -- one computation -----------------------------------------------------
    def _analyze(self, comp: str, param_flags: Tuple[Flags, ...],
                 scale: int, records: List[CollectiveTaint],
                 kinds: frozenset, record: bool) -> Flags:
        if comp not in self.h.computations:
            return PUBLIC
        _, ops = self._ops(comp)
        env: Dict[str, Flags] = {}
        root = PUBLIC
        for op in ops:
            f = self._op_flags(op, comp, env, param_flags, scale, records,
                               kinds, record)
            env[op.name] = f
            root = f                      # HLO lists ROOT last
        return root

    def _op_flags(self, op: OpInfo, comp: str, env: Dict[str, Flags],
                  param_flags: Tuple[Flags, ...], scale: int,
                  records: List[CollectiveTaint], kinds: frozenset,
                  record: bool) -> Flags:
        kind = op.kind
        ins = [env.get(o, PUBLIC) for o in op.operands]
        agg = _union(ins)

        if kind == "parameter":
            m = _PARAM_IDX_RE.search(op.line)
            idx = int(m.group(1)) if m else -1
            return param_flags[idx] if 0 <= idx < len(param_flags) \
                else PUBLIC
        if kind in _PUBLIC_SOURCES:
            return PUBLIC
        if kind in ("rng", "rng-bit-generator"):
            return MASK

        # collectives: record the operand's flags at the exchange point
        base = kind[:-len("-start")] if kind.endswith("-start") else kind
        if base in COLLECTIVES and not kind.endswith("-done"):
            opnd = ins[0] if ins else PUBLIC
            if record and base in kinds:
                records.append(CollectiveTaint(
                    base, comp, op.name, scale, opnd.secret, opnd.mask,
                    opnd.unsafe))
            return opnd
        if kind.endswith("-done"):
            return agg

        # call graph
        if kind == "fusion":
            m = _CALLS_RE.search(op.line)
            if m:
                return self._analyze(m.group(1), tuple(ins), scale,
                                     records, kinds, record)
            return agg
        if kind == "call":
            m = _TO_APPLY_RE.search(op.line)
            if m:
                return self._analyze(m.group(1), tuple(ins), scale,
                                     records, kinds, record)
            return agg
        if kind == "while":
            trips = 1
            tm = _TRIP_RE.search(op.line)
            if tm:
                trips = int(tm.group(1))
            bm = _BODY_RE.search(op.line)
            if not bm:
                return agg
            body = bm.group(1)
            # loop-carried flags to a fixpoint (monotone, so this
            # terminates in <= 3 steps), then one recorded pass with the
            # stable flags scaled by the trip count
            cur = ins[0] if ins else PUBLIC
            for _ in range(8):
                out = self._analyze(body, (cur,), scale, [], kinds,
                                    record=False)
                new = cur.union(out)
                if new == cur:
                    break
                cur = new
            return self._analyze(body, (cur,), scale * trips, records,
                                 kinds, record)
        if kind == "conditional":
            bm = _BRANCHES_RE.search(op.line)
            if not bm:
                return agg
            branches = [b.strip().lstrip("%") for b in bm.group(1).split(",")
                        if b.strip()]
            outs = []
            for i, b in enumerate(branches):
                arg = ins[i + 1] if i + 1 < len(ins) else PUBLIC
                outs.append(self._analyze(b, (arg,), scale, records, kinds,
                                          record))
            return _union(outs) if outs else agg

        if kind in _PRESERVING:
            return agg            # unsafe = OR of operands, via union
        # element-mixing default (add/xor/mul/select/dot/custom-call/...):
        # mixing a mask into a secret blinds it
        return Flags(agg.secret, agg.mask, agg.secret and not agg.mask)


# ---------------------------------------------------------------------------
# summaries
# ---------------------------------------------------------------------------

def census_summary(hlo_text: str, secret_params: Sequence[int],
                   mask_params: Sequence[int]) -> Dict:
    """Taint census + cross-check against ``collective_census``.

    Returns ``collectives`` (taint-walk count), ``unmasked_collectives``
    (the gate: must be 0), ``masked``/``public`` breakdown, and
    ``cross_check_ok`` (taint count == plain census count — both walks
    must visit the same instructions)."""
    from repro.runtime.hlo_analyzer import collective_census

    recs = TaintAnalysis(hlo_text).census(secret_params, mask_params)
    total = sum(r.count for r in recs)
    unmasked = sum(r.count for r in recs if r.unsafe)
    masked = sum(r.count for r in recs if r.secret and not r.unsafe)
    public = sum(r.count for r in recs if not r.secret)
    plain = sum(c.count for c in collective_census(hlo_text))
    return {
        "collectives": total,
        "unmasked_collectives": unmasked,
        "masked_collectives": masked,
        "public_collectives": public,
        "cross_check_total": plain,
        "cross_check_ok": total == plain,
    }


def classify_serve_step_params(params, pool) -> Tuple[List[int], List[int]]:
    """Entry-parameter classification for a ``serve_step`` lowering
    ``jit(step).lower(params, lo, hi, pool, key)``: jit flattens the
    argument pytree in order, so the share limbs sit right after the
    model parameters and the key comes last."""
    import jax

    n_model = len(jax.tree_util.tree_leaves(params))
    n_pool = len(jax.tree_util.tree_leaves(pool))
    secret = [n_model, n_model + 1]
    mask = list(range(n_model + 2, n_model + 2 + n_pool)) \
        + [n_model + 2 + n_pool]
    return secret, mask


def canonical_resnet_census() -> Dict:
    """The acceptance census: lower the canonical smoke-ResNet
    ``serve_step`` mesh-natively (party axis of size 2 — requires >= 2
    jax devices, e.g. ``--xla_force_host_platform_device_count=2``) and
    run the taint census on the compiled HLO.  Same fixture seeds as
    benchmarks/run.py and tests/test_mesh_serving.py."""
    import jax

    if jax.device_count() < 2:
        raise RuntimeError(
            "canonical_resnet_census needs >= 2 devices for a real party "
            "axis; set XLA_FLAGS=--xla_force_host_platform_device_count=2 "
            "before jax initializes")

    from repro import api
    from repro.configs import RESNET_SMOKE
    from repro.core import beaver
    from repro.core.hummingbird import HBConfig, HBLayer
    from repro.launch.mesh import make_mpc_mesh
    from repro.models import resnet

    # canonical benchmark fixture seeds, shared with benchmarks/run.py
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)  # hbcheck: disable=R004

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

    plan = api.trace_plan(afn, params, (2, 3, 8, 8), name="smoke")
    plan = plan.with_hb(HBConfig(
        tuple([HBLayer(k=21, m=13)] * (plan.n_groups - 1)
              + [HBLayer(k=13, m=13)]), plan.group_elements))
    model = api.compile(afn, params, RESNET_SMOKE, plan, api.Session(key=0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 8, 8)) * 0.5  # hbcheck: disable=R004
    X = model.encrypt(jax.random.PRNGKey(2), x)  # hbcheck: disable=R004
    pool = beaver.gen_plan_triples(jax.random.PRNGKey(3), plan.triple_specs())  # hbcheck: disable=R004
    key = jax.random.PRNGKey(4)  # hbcheck: disable=R004

    mesh = make_mpc_mesh()
    step = model.serve_step(mesh)
    compiled = jax.jit(step).lower(params, X.data.lo, X.data.hi, pool,
                                   key).compile()
    secret, mask = classify_serve_step_params(params, pool)
    summary = census_summary(compiled.as_text(), secret, mask)
    summary["sched_rounds"] = model.schedule().n_rounds
    return summary
