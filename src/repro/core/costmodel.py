"""Closed-form communication cost model for the GMW ReLU protocol.

Bytes and rounds are exact deterministic functions of (n_elements, ring
width); tests validate these formulas against collective-permute bytes
parsed from the compiled mesh-backend HLO, and the benchmarks use them to
reproduce the paper's Figure 3 / Figure 11 communication numbers.

All byte counts are *per party per direction* (what one party transmits);
with 2 parties, total wire traffic is 2x these numbers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from . import beaver, shares
from .hummingbird import HBConfig, RING_BITS

WORD_BYTES = 4        # packed u32 wire words
RING_BYTES = 8        # one Z/2^64 element


@dataclasses.dataclass(frozen=True)
class CommCost:
    bytes_tx: int                 # per party, one direction
    rounds: int
    breakdown: Dict[str, int]     # paper Figure 3 categories

    def __add__(self, other: "CommCost") -> "CommCost":
        bd = dict(self.breakdown)
        for k, v in other.breakdown.items():
            bd[k] = bd.get(k, 0) + v
        return CommCost(self.bytes_tx + other.bytes_tx,
                        self.rounds + other.rounds, bd)

    @staticmethod
    def zero() -> "CommCost":
        return CommCost(0, 0, {})


def relu_cost(n_elements: int, w: int = RING_BITS,
              cone: bool = False) -> CommCost:
    """One ReLU over n_elements with a w-bit DReLU ring (w = k - m).

    w = 0 is the culled identity layer (HBLayer.is_identity): zero bytes,
    zero rounds.  cone=True prices the MSB-cone-pruned adder (same rounds,
    O(w) gates instead of O(w log w) — EXPERIMENTS.md §Perf iteration C2)."""
    if w == 0:
        return CommCost(0, 0, {"circuit": 0, "others": 0, "b2a": 0, "mult": 0})
    W = shares.packed_words(n_elements)
    L = beaver.n_levels(w)
    level_rounds = L
    if w == 1:
        init_and = level_ands = 0                  # MSB is p0 directly: no ANDs
    elif cone:
        from . import gmw
        init_pos, level_sets = gmw.cone_sets(w)
        init_and = 2 * len(init_pos) * W * WORD_BYTES
        # the protocol skips levels whose cone slice is empty (e.g. the top
        # level for w in {2, 3, 5, 9, ...}): no bytes AND no round for them
        level_ands = sum(2 * (2 * len(pos)) * W * WORD_BYTES
                         for pos in level_sets if pos)
        level_rounds = sum(1 for pos in level_sets if pos)
    else:
        init_and = 2 * w * W * WORD_BYTES          # open (d, e) of initial AND
        level_ands = L * 2 * (2 * w) * W * WORD_BYTES
    prep = w * W * WORD_BYTES                      # A2B mask exchange ("Others")
    circuit = init_and + level_ands
    b2a = 2 * n_elements * RING_BYTES              # one Beaver mult on Z/2^64
    mult = 2 * n_elements * RING_BYTES             # final x * DReLU(x)
    total = prep + circuit + b2a + mult
    rounds = 1 + (1 + level_rounds if w > 1 else 0) + 1 + 1
    return CommCost(total, rounds, {
        "circuit": circuit, "others": prep, "b2a": b2a, "mult": mult,
    })


def model_relu_cost(cfg: HBConfig) -> CommCost:
    """Total ReLU communication of a model under an HBConfig."""
    total = CommCost.zero()
    for layer, n in zip(cfg.layers, cfg.group_elements):
        total = total + relu_cost(n, layer.width)
    return total


def relu_many_cost(specs, cone: bool = False) -> CommCost:
    """Round-fused cost of sibling ReLU groups evaluated by ``relu_many``.

    specs: iterable of (n_elements, width).  Bytes add up (each group still
    sends its own payload), but every protocol round is ONE coalesced
    exchange across all groups, so rounds = max over groups — this is the
    counter pair CoalescingComm reports and tests validate against.
    """
    costs = [relu_cost(n, w, cone=cone) for n, w in specs]
    total = CommCost.zero()
    for c in costs:
        total = total + c
    return CommCost(total.bytes_tx,
                    max((c.rounds for c in costs), default=0),
                    total.breakdown)


def fused_model_relu_cost(cfg: HBConfig, streams: int,
                          cone: bool = False) -> CommCost:
    """Model-level round-fused cost: `streams` sibling inference streams
    evaluated by relu_many at every ReLU layer.  Bytes scale with the
    stream count; rounds are paid once per layer for all streams."""
    total = CommCost.zero()
    for layer, n in zip(cfg.layers, cfg.group_elements):
        total = total + relu_many_cost([(n, layer.width)] * streams,
                                       cone=cone)
    return total


def reduction_factors(cfg: HBConfig) -> Dict[str, float]:
    base = model_relu_cost(HBConfig.exact(cfg.group_elements))
    hb = model_relu_cost(cfg)
    return {
        "bytes_reduction": base.bytes_tx / max(1, hb.bytes_tx),
        "rounds_reduction": base.rounds / max(1, hb.rounds),
        "bits_discarded_frac": 1.0 - cfg.budget_fraction(),
    }


def latency_model(cost: CommCost, bandwidth_bps: float, rtt_s: float,
                  compute_s: float = 0.0) -> float:
    """End-to-end latency estimate: serialization + per-round RTT + compute.

    This is the projection methodology the paper uses for its WAN numbers
    (§5.2: communication measured, then scaled by assumed bandwidth).
    """
    wire = 2 * cost.bytes_tx * 8 / bandwidth_bps   # both directions share the link
    return wire + cost.rounds * rtt_s + compute_s
