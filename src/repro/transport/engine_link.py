"""Two-process serving: the engine leader / party follower link.

The serving engine (``repro.serve``) stays single-brained: ONE process —
the *leader* — owns the admission queue, batching policy, deadline
shedding and tenant metering, and also plays the client gateway (it
secret-shares plaintext inputs, so it briefly holds both share rows, as
any client does).  The *follower* is a bare party host: it receives each
micro-batch's descriptor over the socket's CTRL channel, replays the
same plan on its own share rows with ``PrivateModel._run_streams``, and
returns its output rows.

Per executed batch attempt (the engine's ``on_batch_attempt`` /
``on_batch_outputs`` hooks):

    leader --CTRL--> follower   batch descriptor: request ids, tenants,
                                bucketed shapes, per-request protocol
                                keys (common knowledge), frac bits,
                                auto_batch flag + the follower's input
                                share rows as one binary blob
    both                        run_streams lockstep: every fused round
                                is one framed DATA exchange
    leader <--CTRL-- follower   the follower's output share rows

Determinism contract: both sides derive per-request key iterators from
the SAME protocol keys and draw triples from the SAME per-tenant TTP
stream (``tenant_provider_factory`` seeded identically, each side
keeping its own party slice), so the combined output shares are
bit-identical to a single-process ``SimComm`` run of the same requests —
asserted in ``tests/test_frontend.py``.

A retried batch re-sends its descriptor (the hook runs per attempt); the
follower rolls its providers back on any comm fault and simply waits for
the next descriptor, so both sides re-execute from the same triple
stream positions.
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import errors
from repro.core import beaver, comm as comm_lib, ring
from repro.core.mpc_tensor import MPCTensor

from .socket import SocketComm


def tenant_provider_factory(ttp_seed: int, party: Optional[int] = None):
    """The canonical per-tenant triple source for socket deployments.

    Every tenant gets its own ``StreamingTTP`` stream, forked from
    ``ttp_seed`` by a stable hash of the tenant name.  Both parties
    construct the factory with the SAME seed; each passes its own
    ``party`` index to keep only its slice of every generated bundle
    (``beaver.PartySlicedTTP``), so the two processes' triples are
    consistent by construction.  ``party=None`` yields the full 2-party
    stream — the single-process reference the bit-identity tests compare
    against.
    """

    def factory(tenant: str):
        key = jax.random.fold_in(jax.random.PRNGKey(ttp_seed),
                                 zlib.crc32(tenant.encode()) & 0x7FFFFFFF)
        base = beaver.StreamingTTP(key)
        return base if party is None else beaver.PartySlicedTTP(base, party)

    return factory


class EngineLink:
    """Binds an ``InferenceEngine`` (whose session came from
    ``Session.connect``) to the follower party over the socket's CTRL
    channel.  Installing the link sets the engine's transport hooks;
    ``shutdown()`` releases the follower's serve loop.
    """

    def __init__(self, engine, sock: Optional[SocketComm] = None, *,
                 outputs_timeout_s: float = 600.0):
        self.engine = engine
        self.sock = sock if sock is not None else comm_lib.find_comm(
            engine.session.comm, SocketComm)
        if self.sock is None:
            raise ValueError(
                "EngineLink needs a SocketComm at the bottom of the "
                "engine session's comm stack (build it with "
                "Session.connect)")
        self.outputs_timeout_s = outputs_timeout_s
        engine.on_batch_attempt = self._on_attempt
        engine.on_batch_outputs = self._on_outputs

    def _on_attempt(self, admitted) -> List[MPCTensor]:
        party, peer = self.sock.party, 1 - self.sock.party
        desc = {"type": "batch",
                "auto_batch": bool(self.engine.policy.merge_identical),
                "requests": [
                    {"id": int(r.id), "tenant": r.tenant,
                     "shape": [int(s) for s in r.shape],
                     "frac_bits": int(r.x.frac_bits),
                     "key": np.asarray(r.key).astype(np.uint32).tolist()}
                    for r in admitted]}
        blob = b"".join(
            np.ascontiguousarray(np.asarray(limb[peer:peer + 1])).tobytes()
            for r in admitted
            for limb in (r.x.data.lo, r.x.data.hi))
        self.sock.send_ctrl(desc, blob)
        return [MPCTensor(ring.Ring64(r.x.data.lo[party:party + 1],
                                      r.x.data.hi[party:party + 1]),
                          r.x.frac_bits)
                for r in admitted]

    def _on_outputs(self, admitted, outs) -> List[MPCTensor]:
        hdr, blob = self.sock.recv_ctrl(timeout_s=self.outputs_timeout_s)
        if hdr.get("type") != "outputs":
            raise errors.PayloadCorrupted(
                f"expected an outputs ctrl message, got {hdr.get('type')!r}")
        ids = [int(r.id) for r in admitted]
        if hdr.get("ids") != ids:
            raise errors.PayloadCorrupted(
                f"follower answered requests {hdr.get('ids')}, leader "
                f"executed {ids}")
        party = self.sock.party
        combined, off = [], 0
        for out, shape in zip(outs, hdr["shapes"]):
            n = int(np.prod(shape))
            limbs = []
            for local_limb in (out.data.lo, out.data.hi):
                peer_rows = np.frombuffer(
                    blob, np.uint32, count=n,
                    offset=off).reshape((1,) + tuple(shape))
                off += n * 4
                rows = ([local_limb, jnp.asarray(peer_rows)] if party == 0
                        else [jnp.asarray(peer_rows), local_limb])
                limbs.append(jnp.concatenate(rows, axis=0))
            combined.append(MPCTensor(ring.Ring64(*limbs), out.frac_bits))
        return combined

    def shutdown(self) -> None:
        """Release the follower's serve loop (best-effort)."""
        try:
            self.sock.send_ctrl({"type": "shutdown"})
        except errors.CommError:
            pass


def serve_follower(sock: SocketComm, model, *, provider_factory,
                   max_retries: int = 3, backoff_s: float = 0.01) -> int:
    """The follower party's serve loop: replay every batch descriptor the
    leader ships until a shutdown message (or the leader's death).

    ``model`` is the follower's compiled ``PrivateModel`` (same plan,
    same public params); ``provider_factory(tenant)`` must mirror the
    leader's triple streams party-sliced to THIS side — use
    ``tenant_provider_factory(ttp_seed, party=sock.party)`` with the
    job's shared seed.  Returns the number of batches served.
    """
    comm = comm_lib.CoalescingComm(
        comm_lib.ResilientComm(sock, max_retries=max_retries,
                               backoff_s=backoff_s))
    providers: Dict[str, object] = {}
    served = 0
    while True:
        try:
            hdr, blob = sock.recv_ctrl(timeout_s=None)
        except errors.PartyCrashed:
            return served                  # leader went away: we are done
        if hdr.get("type") == "shutdown":
            return served
        if hdr.get("type") != "batch":
            raise errors.PayloadCorrupted(
                f"unexpected ctrl message {hdr.get('type')!r} in the "
                "follower serve loop")
        reqs = hdr["requests"]
        xs, off = [], 0
        for r in reqs:
            shape = tuple(int(s) for s in r["shape"])
            n = int(np.prod(shape))
            limbs = []
            for _ in range(2):             # lo rows then hi rows
                limbs.append(jnp.asarray(np.frombuffer(
                    blob, np.uint32, count=n,
                    offset=off).reshape((1,) + shape)))
                off += n * 4
            xs.append(MPCTensor(ring.Ring64(*limbs), int(r["frac_bits"])))
        key_iters = [
            iter(jax.random.split(
                jnp.asarray(np.asarray(r["key"], np.uint32)), 256))
            for r in reqs]
        for r in reqs:
            if r["tenant"] not in providers:
                providers[r["tenant"]] = provider_factory(r["tenant"])
        provs = [providers[r["tenant"]] for r in reqs]
        tokens = [(p, p.checkpoint()) for p in dict.fromkeys(provs)]
        try:
            outs = model._run_streams(xs, key_iters, provs, comm,
                                      model.params,
                                      auto_batch=bool(hdr["auto_batch"]))
        except errors.CommError:
            # the leader will retry (new descriptor) or give up (next
            # message is a shutdown / the connection drops): rewind the
            # triple streams so a retry redraws identical bundles
            for p, tok in tokens:
                p.rollback(tok)
            continue
        out_blob = b"".join(
            np.ascontiguousarray(np.asarray(limb)).tobytes()
            for o in outs for limb in (o.data.lo, o.data.hi))
        sock.send_ctrl({"type": "outputs",
                        "ids": [int(r["id"]) for r in reqs],
                        "shapes": [[int(s) for s in o.shape]
                                   for o in outs]}, out_blob)
        served += 1
