"""Assemble the EXPERIMENTS.md dry-run + roofline tables from results/."""
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _fmt(x, nd=3):
    if x is None:
        return "-"
    if isinstance(x, float):
        if x == 0:
            return "0"
        if abs(x) < 1e-3 or abs(x) >= 1e4:
            return f"{x:.2e}"
        return f"{x:.{nd}g}"
    return str(x)


def load_cells():
    cells = []
    for f in sorted(RESULTS.glob("*.json")):
        d = json.loads(f.read_text())
        d["_file"] = f.stem
        cells.append(d)
    return cells


def dryrun_table(cells, multi_pod):
    lines = ["| arch | shape | status | compile_s | HBM/dev (GB) | collectives |",
             "|---|---|---|---|---|---|"]
    for d in cells:
        if d.get("multi_pod") != multi_pod or "-mpc-" in d.get("arch", ""):
            continue
        mem = d.get("memory", {})
        tot = mem.get("total_bytes")
        colls = d.get("hlo", {}).get("collectives", {})
        coll_str = ",".join(f"{k.split('-')[-1][:4]}:{v}"
                            for k, v in sorted(colls.items())) or "-"
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['status']}"
            f"{(' (' + d.get('reason', '')[:40] + ')') if d['status'] == 'skipped' else ''} "
            f"| {_fmt(d.get('compile_s'))} "
            f"| {_fmt(tot / 1e9 if tot else None)} | {coll_str} |")
    return "\n".join(lines)


def roofline_table(cells):
    lines = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
             "| MODEL/HLO flops | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for d in cells:
        if d.get("multi_pod") or d.get("status") != "ok" \
                or "-mpc-" in d.get("arch", ""):
            continue
        r = d.get("roofline", {})
        lines.append(
            f"| {d['arch']} | {d['shape']} | {_fmt(r.get('compute_s'))} "
            f"| {_fmt(r.get('memory_s'))} | {_fmt(r.get('collective_s'))} "
            f"| {r.get('dominant', '-').replace('_s', '')} "
            f"| {_fmt(r.get('useful_flops_ratio'))} "
            f"| {_fmt(r.get('roofline_fraction'))} |")
    return "\n".join(lines)


def mpc_table(cells):
    lines = ["| config | collective B/dev | memory_s | collective_s | dominant |",
             "|---|---|---|---|---|"]
    for d in cells:
        if "-mpc-" not in d.get("arch", "") or d.get("status") != "ok":
            continue
        r = d.get("roofline", {})
        cb = d.get("hlo", {}).get("collective_bytes")
        lines.append(f"| {d['arch']} | {_fmt(cb)} | {_fmt(r.get('memory_s'))} "
                     f"| {_fmt(r.get('collective_s'))} "
                     f"| {r.get('dominant', '-').replace('_s', '')} |")
    return "\n".join(lines)


if __name__ == "__main__":
    cells = load_cells()
    print("## Single-pod (16x16)\n")
    print(dryrun_table(cells, False))
    print("\n## Multi-pod (2x16x16)\n")
    print(dryrun_table(cells, True))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(cells))
    print("\n## MPC serving\n")
    print(mpc_table(cells))
