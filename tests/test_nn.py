"""NN substrate: flash attention vs naive, MoE dispatch, SSM scan, opts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import attention, common, moe as moe_lib, ssm
from repro.train import optimizer as opt_lib


def _naive_attention(q, k, v, window=None):
    b, s, h, dh = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    qh = q.reshape(b, s, n_kv, g, dh).astype(jnp.float32)
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qh, k.astype(jnp.float32)) * dh ** -0.5
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    sc = jnp.where(mask, sc, -2e38)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, s, h, dh).astype(q.dtype)


@pytest.mark.parametrize("h,kv,window", [(4, 4, None), (8, 2, None), (4, 2, 24)])
def test_flash_attention_matches_naive(h, kv, window):
    b, s, dh = 2, 64, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, dh))
    got = attention.flash_attention(q, k, v, q_offset=0, chunk_q=16,
                                    chunk_k=16, window=window)
    want = _naive_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


def test_flash_attention_softcap():
    b, s, h, dh = 1, 32, 2, 8
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (b, s, h, dh)) * 4
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh)) * 4
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
    capped = attention.flash_attention(q, k, v, q_offset=0, chunk_q=8,
                                       chunk_k=8, cap=5.0)
    uncapped = attention.flash_attention(q, k, v, q_offset=0, chunk_q=8,
                                         chunk_k=8)
    assert not np.allclose(np.asarray(capped), np.asarray(uncapped))


def test_moe_reduces_to_dense_at_full_capacity():
    """top_k = E with huge capacity == average of all experts."""
    b, s, d, f, e = 2, 8, 16, 32, 4
    key = jax.random.PRNGKey(0)
    params = moe_lib.moe_init(key, d, f, e, gated=True)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d))
    out = moe_lib.moe(params, x, n_experts=e, top_k=e, capacity_factor=4.0)
    # manual: weighted sum of every expert's FFN with softmax router weights
    logits = common.dense(params["router"], x)
    w = jax.nn.softmax(logits.astype(jnp.float32), -1)
    outs = []
    for i in range(e):
        up = jnp.einsum("bsd,df->bsf", x, params["w_up"][i])
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"][i])
        h = jax.nn.silu(gate) * up
        outs.append(jnp.einsum("bsf,fd->bsd", h, params["w_down"][i]))
    want = sum(w[..., i:i + 1] * outs[i] for i in range(e))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-3)


def test_moe_capacity_drops_tokens():
    b, s, d, f, e = 1, 32, 8, 16, 4
    params = moe_lib.moe_init(jax.random.PRNGKey(0), d, f, e, gated=False)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d))
    tight = moe_lib.moe(params, x, n_experts=e, top_k=2, capacity_factor=0.25)
    loose = moe_lib.moe(params, x, n_experts=e, top_k=2, capacity_factor=8.0)
    assert not np.allclose(np.asarray(tight), np.asarray(loose))
    assert np.isfinite(np.asarray(tight)).all()


def _naive_mamba1_scan(decay, inc, c_t):
    b, s = decay.shape[0], decay.shape[1]
    h = jnp.zeros(decay.shape[:1] + decay.shape[2:])
    ys = []
    for t in range(s):
        h = decay[:, t] * h + inc[:, t]
        ys.append(jnp.einsum("bdn,bn->bd", h, c_t[:, t]))
    return jnp.stack(ys, 1), h


def test_mamba1_chunked_scan_matches_naive():
    b, s, d, n = 2, 32, 8, 4
    p = ssm.mamba1_init(jax.random.PRNGKey(0), d, n, expand=2)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    full = ssm.mamba1(p, x, n_state=n, chunk=8)
    full2 = ssm.mamba1(p, x, n_state=n, chunk=32)  # single chunk
    np.testing.assert_allclose(np.asarray(full), np.asarray(full2),
                               atol=1e-4, rtol=1e-3)


def test_mamba2_decode_consistency():
    b, s, d, n, hd = 1, 16, 8, 4, 4
    p = ssm.mamba2_init(jax.random.PRNGKey(0), d, n, head_dim=hd, expand=2)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    full, state = ssm.mamba2(p, x, n_state=n, head_dim=hd, chunk=4,
                             return_state=True)
    # replay step-by-step
    st = ssm.mamba2_init_state(b, 2 * d, n, head_dim=hd)
    outs = []
    for t in range(s):
        y, st = ssm.mamba2_decode(p, x[:, t:t + 1], st, n_state=n, head_dim=hd)
        outs.append(y)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               atol=1e-3, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(state["h"]),
                               atol=1e-3, rtol=1e-2)


def test_adamw_against_manual_reference():
    opt = opt_lib.AdamW(schedule=opt_lib.Schedule(peak_lr=0.1, warmup_steps=1,
                                                  decay_steps=0),
                        b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                        clip_norm=1e9)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    st = opt.init(p)
    p2, st2, _ = opt.update(g, st, p, jnp.zeros((), jnp.int32))
    # manual adam step 1: m=0.1g... with bias correction = g/(sqrt(g^2)+eps)
    expect = np.asarray(p["w"]) - 0.1 * np.asarray(g["w"]) / (
        np.abs(np.asarray(g["w"])) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-5)


def test_adafactor_and_sgd_smoke():
    p = {"w": jnp.ones((8, 4)), "b": jnp.zeros((4,))}
    g = jax.tree_util.tree_map(lambda x: jnp.ones_like(x) * 0.1, p)
    for opt in (opt_lib.Adafactor(), opt_lib.SGD()):
        st = opt.init(p)
        p2, st2, info = opt.update(g, st, p, jnp.zeros((), jnp.int32))
        assert np.isfinite(np.asarray(p2["w"])).all()
        changed = float(jnp.abs(p2["w"] - p["w"]).sum())
        assert changed > 0
