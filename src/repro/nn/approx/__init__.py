"""Reduced-ring nonlinearity subsystem for private transformer inference.

Everything nonlinear in an LM block is lowered to compositions the GMW
engine evaluates natively:

- GELU / SiLU -> affine + reduced-ring ReLU sums (``pwl``): one relu_fn
  call per activation site, J knot-shifted copies stacked so the per-group
  (k, m) assignment — and the search engine optimizing it — sees the true
  element count.
- softmax -> ReLU attention normalization (``attention``): ReLU on scaled
  scores + a public causal-mean multiplier; the two secret matmuls open
  through Beaver rounds fused across sibling streams.
- ``bounds``: the closed-form fixed-point error bounds tests and the
  (k, m) search reason with.

Plaintext twins (``apply_pwl``, ``relu_attention``) make the exact same
``relu_fn`` / ``relu_fn.matmul`` / ``relu_fn.mul`` hook calls in the same
order as their MPC counterparts, so one trace prices the replay.
"""
from .attention import causal_norm, relu_attention, relu_attention_mpc
from .bounds import discard_margin, magnitude_bound, pwl_fixed_point_bound
from .pwl import (PWLSpec, apply_pwl, apply_pwl_mpc, ensure_hooks, eval_pwl,
                  gelu_spec, pwl_max_error, pwl_spec, silu_spec, spec_for)

__all__ = [
    "PWLSpec", "apply_pwl", "apply_pwl_mpc", "causal_norm", "discard_margin",
    "ensure_hooks", "eval_pwl", "gelu_spec", "magnitude_bound",
    "pwl_fixed_point_bound", "pwl_max_error", "pwl_spec", "relu_attention",
    "relu_attention_mpc", "silu_spec", "spec_for",
]
