"""Distributed runtime: partition rules, HLO analysis, roofline."""
from . import hlo_analyzer, roofline, sharding
__all__ = ["hlo_analyzer", "roofline", "sharding"]
