"""EXPERIMENTS.md §Roofline reader: aggregates results/dryrun/*.json."""
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def run():
    rows = []
    if not RESULTS.exists():
        return [("roofline_missing", 0.0, "run repro.launch.dryrun first")]
    for f in sorted(RESULTS.glob("*.json")):
        d = json.loads(f.read_text())
        if d.get("status") != "ok":
            rows.append((f"roofline_{f.stem}", 0.0,
                         f"status={d.get('status')};{d.get('reason', d.get('error', ''))[:60]}"))
            continue
        r = d.get("roofline", {})
        rows.append((
            f"roofline_{f.stem}",
            float(d.get("compile_s", 0)) * 1e6,
            f"dom={r.get('dominant')};compute_s={r.get('compute_s')};"
            f"mem_s={r.get('memory_s')};coll_s={r.get('collective_s')};"
            f"useful={r.get('useful_flops_ratio')};"
            f"frac={r.get('roofline_fraction')}"))
    return rows
