"""Private transformer LM inference end-to-end (PR 10).

The transformer's nonlinearities lower onto the paper's reduced-ring
machinery (`nn/approx/`): GELU/SiLU become closed-form sums of
knot-shifted ReLUs evaluated in one fused pass, softmax becomes
ReLU(scores) with a public causal row-mean, and the secret matmuls
(QK^T, A*V, gate*up) open through fused Beaver rounds.  The traced Plan
prices all of it, and the serving engine's measured rounds/bytes must
equal the prediction exactly.

    PYTHONPATH=src python examples/private_lm.py
    PYTHONPATH=src python examples/private_lm.py --layers 2 --seq 16
    PYTHONPATH=src python examples/private_lm.py --budget 8of64
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import api, configs
from repro.core import MPCTensor, comm as comm_lib
from repro.models import lm
from repro.serve import InferenceEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b-smoke",
                    help="registry name of a dense LM config")
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--seq", type=int, default=8)
    ap.add_argument("--budget", choices=("baseline", "8of64"),
                    default="baseline",
                    help="per-site (k, m): exact 64-bit ring, or k=22 "
                         "with 6 low bits discarded on the MLP stacks")
    args = ap.parse_args()

    # --- setup: a dense LM resolved by registry name -------------------------
    cfg = dataclasses.replace(configs.get(args.arch), n_layers=args.layers)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    # the client embeds tokens locally and secret-shares the hidden
    # states; the server never sees tokens or activations
    h = jax.random.normal(jax.random.PRNGKey(1),
                          (1, args.seq, cfg.d_model)) * 0.5

    print(f"[1/3] tracing {cfg.name} ({cfg.n_layers} layer(s), act "
          f"{cfg.act}, seq {args.seq})...")
    plan = lm.trace(params, cfg, batch=1, seq=args.seq)
    if args.budget != "baseline":
        # attention scores keep the full reduced ring; the PWL MLP
        # stacks (odd groups) discard 6 low bits
        layers = tuple(
            api.HBLayer(k=22, m=0) if g % 2 == 0 else api.HBLayer(k=22, m=6)
            for g in range(plan.hb.n_groups))
        plan = plan.with_hb(api.HBConfig(layers, plan.hb.group_elements))
    sched = plan.schedule()
    print(f"      {len(plan.calls)} ReLU groups + {len(plan.opens)} Beaver "
          f"opens -> {sched.n_rounds} fused rounds, "
          f"{sched.bytes_tx / 1e6:.1f} MB/party "
          f"(LAN {plan.estimate(network=api.LAN) * 1e3:.0f} ms, "
          f"WAN {plan.estimate(network=api.WAN):.1f} s)")

    # --- private forward: measured == predicted, exactly ---------------------
    print("[2/3] one private forward (real GMW, sim comm)...")

    def afn(p, x, relu_fn=None):
        return lm.mpc_reference(p, x, cfg, relu_fn=relu_fn)

    cc = comm_lib.CoalescingComm(comm_lib.CountingComm())
    model = api.compile(afn, params, cfg, plan,
                        api.Session(key=0, comm=cc))
    t0 = time.time()
    out = model(model.encrypt(jax.random.PRNGKey(2), h))
    logits = out.reveal_np()
    wall = time.time() - t0
    ref = np.asarray(lm.mpc_reference(params, h, cfg))
    err = float(np.max(np.abs(logits - ref)))
    assert cc.n_rounds == sched.n_rounds, (cc.n_rounds, sched.n_rounds)
    assert cc.bytes_tx == sched.bytes_tx, (cc.bytes_tx, sched.bytes_tx)
    match = "==" if np.array_equal(
        np.argmax(logits[0, -1]), np.argmax(ref[0, -1])) else "!="
    print(f"      measured {cc.n_rounds} rounds / {cc.bytes_tx / 1e6:.1f} MB "
          f"== schedule prediction; max |err| {err:.2e}; next-token "
          f"argmax {match} plaintext; {wall / args.seq:.2f} s/token (sim)")

    # --- serving: the unchanged engine, LM requests like any other -----------
    print("[3/3] serving two LM requests through InferenceEngine...")
    engine = InferenceEngine(afn, params, cfg, plan, api.Session(key=0))
    Xs = [MPCTensor.from_plain(jax.random.PRNGKey(10 + i), h)
          for i in range(2)]
    futs = [engine.submit(t, X) for t, X in zip(("alice", "bob"), Xs)]
    outs = [f.result() for f in futs]
    rep = engine.reports[0]
    assert rep.measured_rounds == rep.predicted_rounds
    assert all(np.max(np.abs(o.reveal_np() - ref)) < max(2 * err, 1e-2) + 0.05
               for o in outs)
    print(f"      {rep.n_requests} requests, one fused batch: "
          f"{rep.measured_rounds} rounds (serial would pay "
          f"{rep.serial_rounds}), saved x{rep.rounds_saved_ratio:.1f}")


if __name__ == "__main__":
    main()
