"""Checkpoint/restart, determinism-by-step, straggler hook, elastic load."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import get
from repro.data import TokenPipeline
from repro.launch import train as train_lib
from repro.launch.mesh import make_smoke_mesh
from repro.runtime.watchdog import StragglerWatchdog
from repro.train import loop as loop_lib, optimizer as opt_lib


@pytest.fixture()
def small_cfg():
    return dataclasses.replace(get("qwen1.5-0.5b-smoke"), n_layers=2)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    store.save(str(tmp_path), 7, tree, extra={"note": "x"})
    assert store.latest_step(str(tmp_path)) == 7
    out, manifest = store.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert manifest["extra"]["note"] == "x"


def test_uncommitted_checkpoint_ignored(tmp_path):
    tree = {"a": jnp.zeros(3)}
    store.save(str(tmp_path), 5, tree)
    # a torn write: directory without COMMITTED sentinel
    bad = tmp_path / "step_00000009"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")
    assert store.latest_step(str(tmp_path)) == 5


def test_async_checkpointer(tmp_path):
    ck = store.AsyncCheckpointer(str(tmp_path))
    ck.save(3, {"w": jnp.ones((64, 64))})
    ck.wait()
    assert store.latest_step(str(tmp_path)) == 3


def test_data_pipeline_deterministic_by_step():
    pipe = TokenPipeline(vocab=97, seq_len=16, batch=4, seed=3)
    b1 = pipe.batch_at(11)
    b2 = pipe.batch_at(11)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = pipe.batch_at(12)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_failure_restart_resumes_exactly(small_cfg, tmp_path):
    pipe = TokenPipeline(vocab=small_cfg.vocab, seq_len=32, batch=4)
    lc = loop_lib.LoopConfig(total_steps=10, ckpt_every=4,
                             ckpt_dir=str(tmp_path), async_ckpt=False)
    with pytest.raises(RuntimeError):
        loop_lib.run(small_cfg, pipe, lc, hooks={"fail_at": 6})
    rep = loop_lib.run(small_cfg, pipe, lc)
    assert rep.resumed_from == 4
    assert rep.final_step == 10
    assert np.isfinite(rep.losses).all()


def test_restart_equals_uninterrupted(small_cfg, tmp_path):
    """Bitwise-equal params: run 8 straight vs run-fail-resume."""
    pipe = TokenPipeline(vocab=small_cfg.vocab, seq_len=32, batch=4)
    opt = opt_lib.AdamW()
    # uninterrupted
    d1 = tmp_path / "a"
    lc1 = loop_lib.LoopConfig(total_steps=8, ckpt_every=100,
                              ckpt_dir=str(d1), async_ckpt=False)
    loop_lib.run(small_cfg, pipe, lc1, optimizer=opt)
    s1, _ = store.restore(str(d1), jax.eval_shape(
        lambda k: train_lib.init_state(k, small_cfg, opt),
        jax.ShapeDtypeStruct((2,), jnp.uint32)))
    # interrupted at 6, checkpointed at 4, resumed
    d2 = tmp_path / "b"
    lc2 = loop_lib.LoopConfig(total_steps=8, ckpt_every=4,
                              ckpt_dir=str(d2), async_ckpt=False)
    with pytest.raises(RuntimeError):
        loop_lib.run(small_cfg, pipe, lc2, optimizer=opt, hooks={"fail_at": 6})
    loop_lib.run(small_cfg, pipe, lc2, optimizer=opt)
    s2, _ = store.restore(str(d2), s1)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_on_named_mesh(small_cfg, tmp_path):
    pipe = TokenPipeline(vocab=small_cfg.vocab, seq_len=32, batch=4)
    lc = loop_lib.LoopConfig(total_steps=4, ckpt_every=2,
                             ckpt_dir=str(tmp_path), async_ckpt=False)
    loop_lib.run(small_cfg, pipe, lc)
    mesh = make_smoke_mesh()
    state, manifest = loop_lib.elastic_restore(str(tmp_path), small_cfg,
                                               opt_lib.AdamW(), mesh)
    assert int(state.step) == 4
    # every leaf carries a NamedSharding on the target mesh
    sh = jax.tree_util.tree_leaves(state.params)[0].sharding
    assert hasattr(sh, "mesh")


def test_straggler_watchdog(small_cfg):
    import time
    base = TokenPipeline(vocab=small_cfg.vocab, seq_len=32, batch=4)
    seen = []

    class SlowPipe:
        def batch_at(self, step):
            if step == 8:
                time.sleep(2.0)  # injected straggler inside the timed window
            return base.batch_at(step)

    lc = loop_lib.LoopConfig(total_steps=10, ckpt_dir=None,
                             straggler_factor=3.0)
    rep = loop_lib.run(small_cfg, SlowPipe(), lc,
                       hooks={"on_straggler": lambda s, dt, e: seen.append(s)})
    assert rep.final_step == 10
    assert 8 in seen and 8 in rep.straggler_steps
