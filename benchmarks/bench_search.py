"""Paper Table 2: search engine wall time (smoke-scale model + val set)."""
import time

import jax
import jax.numpy as jnp

from repro.configs import RESNET_SMOKE
from repro.core.hummingbird import HBConfig
from repro.models import resnet
from repro.search import finetune as ft, search_budget, search_eco


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    params = resnet.init(key, RESNET_SMOKE)
    xs = jax.random.normal(jax.random.PRNGKey(1), (192, 3, 16, 16))
    ys = (xs[:, 0, :8, :8].mean((1, 2)) > 0).astype(jnp.int32)

    def afn(p, x, relu_fn=None):
        return resnet.apply(p, x, RESNET_SMOKE, relu_fn=relu_fn)

    groups = resnet.relu_group_elements(params, RESNET_SMOKE)
    params, _ = ft.finetune(afn, params, xs[:128], ys[:128],
                            HBConfig.exact(groups), jax.random.PRNGKey(5),
                            epochs=3, batch=64, lr=3e-3)
    res = search_eco(afn, params, xs[128:], ys[128:], groups,
                     jax.random.PRNGKey(2))
    rows.append(("table2_search_eco", res.search_time_s * 1e6,
                 f"nodes={res.nodes_visited};budget={res.budget_fraction:.3f}"))
    for budget, bits in ((8 / 64, (6, 8)), (6 / 64, (5, 6))):
        res = search_budget(afn, params, xs[128:], ys[128:], groups,
                            jax.random.PRNGKey(3), budget=budget,
                            bit_choices=bits)
        rows.append((f"table2_search_{int(budget*64)}of64",
                     res.search_time_s * 1e6,
                     f"nodes={res.nodes_visited};pruned={res.nodes_pruned};"
                     f"acc_drop={res.baseline_accuracy-res.accuracy:.3f}"))
    return rows
