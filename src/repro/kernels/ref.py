"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ring

_U32 = jnp.uint32


def pack(v: jax.Array, w: int) -> jax.Array:
    """(E,) uint32 -> (w, E/32) packed words (E multiple of 32)."""
    n_words = v.shape[0] // 32
    grouped = v.reshape(n_words, 32)
    shifts = jnp.arange(32, dtype=_U32)[None, :]
    planes = []
    for i in range(w):
        bits = (grouped >> _U32(i)) & _U32(1)
        planes.append((bits << shifts).sum(axis=-1, dtype=_U32))
    return jnp.stack(planes, axis=0)


def unpack(words: jax.Array, w: int) -> jax.Array:
    """(w, W) -> (32*W,) uint32 values with w significant bits."""
    shifts = jnp.arange(32, dtype=_U32)
    acc = jnp.zeros((words.shape[1], 32), _U32)
    for i in range(w):
        bits = (words[i][:, None] >> shifts) & _U32(1)
        acc = acc | (bits << _U32(i))
    return acc.reshape(-1)


def beaver_and(d_open, e_open, a, b, c, sel) -> jax.Array:
    return c ^ (d_open & b) ^ (e_open & a) ^ (sel & d_open & e_open)


def ks_level(g, z_g, z_p):
    return g ^ z_g, z_p


def _shift_planes(x: jax.Array, d: int) -> jax.Array:
    if d == 0:
        return x
    pad = jnp.zeros(x.shape[:-2] + (d,) + x.shape[-1:], x.dtype)
    return jnp.concatenate([pad, x[..., :-d, :]], axis=-2)


def ks_mask(g, p, a, b, shift: int):
    """Oracle for the fused pre-exchange KS level pass (see gmw_round)."""
    lhs = jnp.concatenate([p, p], axis=-2)
    rhs = jnp.concatenate([_shift_planes(g, shift), _shift_planes(p, shift)],
                          axis=-2)
    return lhs ^ a, rhs ^ b


def ks_combine(d, d_other, e, e_other, a, b, c, sel, g):
    """Oracle for the fused post-exchange KS level pass (see gmw_round)."""
    d_open = d ^ d_other
    e_open = e ^ e_other
    z = beaver_and(d_open, e_open, a, b, c, sel)
    w = g.shape[-2]
    return g ^ z[..., :w, :], z[..., w:, :]


def ring_matmul(dx: jax.Array, dw: jax.Array):
    """Digit-plane matmul oracle; same contraction as the kernel.

    dx: (8, M, K) int8; dw: (5, K, N) int8 -> (lo, hi) uint32 [M, N].
    """
    prods = jnp.einsum("imk,jkn->ijmn", dx.astype(jnp.int8), dw.astype(jnp.int8),
                       preferred_element_type=jnp.int32)
    out = ring.zeros(prods.shape[2:])
    for s in range(8):
        acc = None
        for i in range(8):
            j = s - i
            if 0 <= j < 5:
                acc = prods[i, j] if acc is None else acc + prods[i, j]
        if acc is None:
            continue
        lo = acc.astype(_U32)
        hi = jnp.where(acc < 0, _U32(0xFFFFFFFF), _U32(0))
        out = ring.add(out, ring.lshift(ring.Ring64(lo, hi), 8 * s))
    return out.lo, out.hi
