"""GMW protocol unit tests: shares, Beaver, A2B adder, B2A, DReLU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import beaver, comm as comm_lib, fixed, gmw, ring, shares

CM = comm_lib.SimComm()


def test_share_reconstruct_roundtrip(rng):
    vals = rng.integers(0, 2**64, 128, dtype=np.uint64)
    xs = shares.share(jax.random.PRNGKey(0), ring.from_uint64_np(vals))
    np.testing.assert_array_equal(ring.to_uint64_np(shares.reconstruct(xs)), vals)
    # shares are not the plaintext
    assert not np.array_equal(ring.to_uint64_np(xs[0]), vals)


def test_three_party_shares(rng):
    vals = rng.integers(0, 2**64, 32, dtype=np.uint64)
    xs = shares.share(jax.random.PRNGKey(1), ring.from_uint64_np(vals),
                      n_parties=3)
    assert xs.shape[0] == 3
    np.testing.assert_array_equal(ring.to_uint64_np(shares.reconstruct(xs)), vals)


def test_pack_unpack_roundtrip(rng):
    bits = rng.integers(0, 2, (5, 100), dtype=np.uint32)
    packed = shares.pack_bits(jnp.asarray(bits))
    assert packed.shape == (5, 4)
    back = shares.unpack_bits(packed, 100)
    np.testing.assert_array_equal(np.asarray(back), bits)


def test_beaver_arith_triple(rng):
    tri = beaver.gen_arith(jax.random.PRNGKey(2), (64,))
    a = shares.reconstruct(tri.a)
    b = shares.reconstruct(tri.b)
    c = shares.reconstruct(tri.c)
    np.testing.assert_array_equal(
        ring.to_uint64_np(c),
        ring.to_uint64_np(a) * ring.to_uint64_np(b))


def test_beaver_bin_triple():
    tri = beaver.gen_bin(jax.random.PRNGKey(3), (8, 16))
    a = shares.xor_reconstruct(tri.a)
    b = shares.xor_reconstruct(tri.b)
    c = shares.xor_reconstruct(tri.c)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(a & b))


def test_and_open(rng):
    x = rng.integers(0, 2**32, (4, 8), dtype=np.uint64).astype(np.uint32)
    y = rng.integers(0, 2**32, (4, 8), dtype=np.uint64).astype(np.uint32)
    xs = shares.xor_share_packed(jax.random.PRNGKey(4), jnp.asarray(x))
    ys = shares.xor_share_packed(jax.random.PRNGKey(5), jnp.asarray(y))
    tri = beaver.gen_bin(jax.random.PRNGKey(6), (4, 8))
    zs = gmw.and_open(xs, ys, tri, CM)
    np.testing.assert_array_equal(np.asarray(shares.xor_reconstruct(zs)), x & y)


@pytest.mark.parametrize("w", [1, 2, 4, 6, 8, 16, 32, 64])
def test_drelu_all_widths(w, rng):
    """DReLU on every ring width: sign of values within the safe range."""
    E = 64
    lim = min(2 ** (w - 1) - 1, 2 ** 20) if w > 1 else 0
    ints = rng.integers(-lim, lim + 1, E).astype(np.int64)
    X = shares.share(jax.random.PRNGKey(7), ring.from_uint64_np(ints.view(np.uint64)))
    tr = beaver.gen_relu_triples(jax.random.PRNGKey(8), E, w)
    D = gmw.drelu(jax.random.PRNGKey(9), X, tr, CM, k=w, m=0)
    d = fixed.decode_np(shares.reconstruct(D), frac_bits=0)
    np.testing.assert_array_equal(d, (ints >= 0).astype(np.float64))


def test_relu_mult_uses_full_ring_value(rng):
    """Eq. 3: the final multiply uses the untruncated share of x."""
    x = rng.uniform(0.5, 4.0, 32).astype(np.float32)  # all positive
    X = shares.share(jax.random.PRNGKey(10), fixed.encode_np(x))
    tr = beaver.gen_relu_triples(jax.random.PRNGKey(11), 32, 6)
    R = gmw.relu(jax.random.PRNGKey(12), X, tr, CM, k=20, m=14)
    got = fixed.decode_np(shares.reconstruct(R))
    # values >= 0.5 are far above the 2^-2 pruning threshold: exact output
    np.testing.assert_allclose(got, x, atol=1e-4)


def test_b2a_bit():
    bits = np.array([0, 1, 1, 0, 1], np.uint32)
    # single-bit XOR shares (b2a_bit expects per-party values in {0,1})
    b0 = np.array([1, 0, 1, 1, 0], np.uint32)
    bs = jnp.asarray(np.stack([b0, bits ^ b0]))
    tri = beaver.gen_arith(jax.random.PRNGKey(14), (5,))
    arith = gmw.b2a_bit(bs, tri, CM)
    got = fixed.decode_np(shares.reconstruct(arith), frac_bits=0)
    np.testing.assert_array_equal(got, np.asarray(bits, np.float64))


@pytest.mark.parametrize("w,k,m", [(5, 19, 14), (8, 21, 13), (64, 64, 0)])
def test_cone_pruned_adder_bit_identical(w, k, m, rng):
    """Beyond-paper MSB-cone pruning: same outputs, fewer AND gates."""
    from repro.core import costmodel
    E = 128
    x = rng.uniform(-3.9, 3.9, E).astype(np.float32)
    X = shares.share(jax.random.PRNGKey(20), fixed.encode_np(x))
    tr_full = beaver.gen_relu_triples(jax.random.PRNGKey(21), E, w)
    tr_cone = beaver.gen_relu_triples(jax.random.PRNGKey(21), E, w, cone=True)
    r_full = gmw.relu(jax.random.PRNGKey(22), X, tr_full, CM, k=k, m=m)
    r_cone = gmw.relu(jax.random.PRNGKey(22), X, tr_cone, CM, k=k, m=m,
                      cone=True)
    np.testing.assert_array_equal(
        fixed.decode_np(shares.reconstruct(r_full)),
        fixed.decode_np(shares.reconstruct(r_cone)))
    full_c = costmodel.relu_cost(E, w).breakdown["circuit"]
    cone_c = costmodel.relu_cost(E, w, cone=True).breakdown["circuit"]
    assert cone_c < full_c / 2  # at least 2x fewer circuit bytes
    # cone never adds rounds; levels whose cone slice is empty (e.g. the
    # top level for w=5) are skipped by the protocol and the model alike
    assert costmodel.relu_cost(E, w, cone=True).rounds <= \
        costmodel.relu_cost(E, w).rounds


def test_cone_sets_structure():
    init_pos, level_sets = gmw.cone_sets(8)
    # total AND positions ~ 2(w-1) not w*log(w)
    total = len(init_pos) + sum(len(s) for s in level_sets)
    assert total <= 2 * 8
    assert level_sets[-1] == [6]  # final level: only G[w-2]
