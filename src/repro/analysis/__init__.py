"""Protocol-safety static analysis (``hbcheck``).

Three machine checks of the invariants HummingBird's security argument
rests on (docs/analysis.md is the catalog):

- ``analysis.lint`` — AST rules R001-R006 over ``src/repro``: raw
  exchanges stay inside the comm seam, reveals inside the approved API
  surface, no secret-dependent Python control flow, session-derived
  PRNG keys only, uint32 ring discipline, deterministic round path.
- ``analysis.taint`` — HLO leakage census: a dataflow taint pass over
  the compiled ``serve_step`` proving every collective-permute operand
  is mask/triple-derived (zero unmasked-secret collectives).
- ``analysis.locks`` — lock discipline for the serving engine's
  pump-thread state.

CLI gate (CI runs it before the round gate)::

    python -m repro.analysis.hbcheck src tests --check

This package stays import-light: ``lint``/``locks`` are stdlib-only,
``taint`` imports jax lazily (so the CLI can set XLA device flags
first).
"""
from repro.analysis.lint import (Finding, lint_paths,  # noqa: F401
                                 lint_source, load_baseline, save_baseline)
from repro.analysis.locks import (check_lock_discipline,  # noqa: F401
                                  check_private_reach)
