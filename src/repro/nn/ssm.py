"""Selective state-space layers: Mamba1 (falcon-mamba) and Mamba2 (zamba2).

Sequence mixing is a chunked associative scan: the sequence is processed in
chunks of `chunk` steps with an in-chunk ``lax.associative_scan`` over
(decay, increment) pairs and a carried inter-chunk state, bounding live
memory to O(B * chunk * d_inner * N / shards).  Decode is a single-step
state update (the whole point of SSMs for long_500k: O(1) per token).

State layouts (sharding rules shard d_inner / heads over `model`):
  mamba1: h (B, d_inner, N),  conv cache (B, k-1, d_inner)
  mamba2: h (B, H, P, N),     conv cache (B, k-1, d_inner)
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.runtime import constraints
from . import common

_CONV_K = 4


def _ssm_assoc(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def _pick_chunk(s: int, chunk: int) -> int:
    """Largest chunk <= `chunk` that divides s (keeps the scan exact for
    any sequence length, including decode-consistency test lengths)."""
    for cs in range(min(chunk, s), 0, -1):
        if s % cs == 0:
            return cs
    return 1


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,C); w: (K,C); b: (C,)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return y + b


def _conv_step(cache, x_new, w, b):
    """cache: (B, K-1, C); x_new: (B, C) -> (y, new_cache)."""
    full = jnp.concatenate([cache, x_new.astype(cache.dtype)[:, None]],
                           axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", full, w) + b
    return y, full[:, 1:]


# ---------------------------------------------------------------------------
# Mamba1 (falcon-mamba-7b): per-channel diagonal A, data-dependent dt/B/C
# ---------------------------------------------------------------------------

def mamba1_init(key, d_model: int, n_state: int, expand: int = 2,
                dt_rank: int = 0, dtype=jnp.float32):
    di = expand * d_model
    dt_rank = dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": common.dense_init(ks[0], d_model, 2 * di, dtype),
        "conv_w": jax.random.normal(ks[1], (_CONV_K, di), dtype) * 0.1,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": common.dense_init(ks[2], di, dt_rank + 2 * n_state, dtype),
        "dt_proj": {"w": jax.random.normal(ks[3], (dt_rank, di), dtype) * 0.1,
                    "b": jnp.full((di,), -4.6, dtype)},  # softplus^-1(0.01)
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n_state + 1, dtype=jnp.float32),
                                  (di, 1))).astype(dtype),
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": common.dense_init(ks[4], di, d_model, dtype),
    }


def _mamba1_core(p, xc, dt_rank: int, n_state: int):
    """Shared projections: returns (a, inc, c_t, x) given conv'd input xc."""
    proj = common.dense(p["x_proj"], xc)
    dt_in, b_t, c_t = jnp.split(proj, [dt_rank, dt_rank + n_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...r,rd->...d", dt_in, p["dt_proj"]["w"]) + p["dt_proj"]["b"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                   # (Di, N)
    decay = jnp.exp(dt[..., None] * a)                             # (..., Di, N)
    inc = (dt * xc)[..., None] * b_t[..., None, :]                 # (..., Di, N)
    return decay, inc, c_t


def mamba1(p, x, *, n_state: int, chunk: int = 128, return_state: bool = False):
    """x: (B, S, D) -> (B, S, D) [, final decode state]."""
    b, s, d = x.shape
    di = p["conv_w"].shape[1]
    dt_rank = p["x_proj"]["w"].shape[1] - 2 * n_state
    xz = common.dense(p["in_proj"], x)
    xz = constraints.shard(xz, "dp", None, "tp")  # d_inner TP over model
    xr, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xr, p["conv_w"], p["conv_b"]))
    cs = _pick_chunk(s, chunk)
    nc = s // cs
    xcs = xc.reshape(b, nc, cs, di)

    def chunk_body(h, xck):
        decay, inc, c_t = _mamba1_core(p, xck.astype(jnp.float32), dt_rank, n_state)
        inc = constraints.shard(inc, "dp", None, "tp", None)
        inc = inc.at[:, 0].add(decay[:, 0] * h)
        _, hs = jax.lax.associative_scan(_ssm_assoc, (decay, inc), axis=1)
        y = jnp.einsum("bldn,bln->bld", hs, c_t.astype(jnp.float32))
        return hs[:, -1], y

    h0 = constraints.shard(jnp.zeros((b, di, n_state), jnp.float32),
                           "dp", "tp", None)
    h_final, ys = jax.lax.scan(chunk_body, h0, jnp.moveaxis(xcs, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, di).astype(x.dtype)
    y = y + xc * p["d_skip"]
    y = y * jax.nn.silu(z)
    out = common.dense(p["out_proj"], y)
    if return_state:
        state = {"h": h_final,
                 "conv": xr[:, -(_CONV_K - 1):].astype(jnp.float32)}
        return out, state
    return out


def mamba1_init_state(batch: int, d_inner: int, n_state: int):
    return {"h": jnp.zeros((batch, d_inner, n_state), jnp.float32),
            "conv": jnp.zeros((batch, _CONV_K - 1, d_inner), jnp.float32)}


def mamba1_decode(p, x, state, *, n_state: int):
    """x: (B, 1, D) -> (y, new_state). O(1) per token."""
    b = x.shape[0]
    dt_rank = p["x_proj"]["w"].shape[1] - 2 * n_state
    xz = common.dense(p["in_proj"], x[:, 0])
    xr, z = jnp.split(xz, 2, axis=-1)
    xc, conv = _conv_step(state["conv"], xr, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)  # f32 (conv cache dtype); cast back after the skip
    decay, inc, c_t = _mamba1_core(p, xc.astype(jnp.float32), dt_rank, n_state)
    h = decay * state["h"] + inc
    y = jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32))
    y = (y + xc * p["d_skip"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return common.dense(p["out_proj"], y)[:, None], {"h": h, "conv": conv}


# ---------------------------------------------------------------------------
# Mamba2 (zamba2): scalar decay per head, SSD-style heads
# ---------------------------------------------------------------------------

def mamba2_init(key, d_model: int, n_state: int, head_dim: int = 64,
                expand: int = 2, dtype=jnp.float32):
    di = expand * d_model
    n_heads = di // head_dim
    ks = jax.random.split(key, 5)
    return {
        "in_proj": common.dense_init(
            ks[0], d_model, 2 * di + 2 * n_state + n_heads, dtype),
        "conv_w": jax.random.normal(ks[1], (_CONV_K, di + 2 * n_state), dtype) * 0.1,
        "conv_b": jnp.zeros((di + 2 * n_state,), dtype),
        "a_log": jnp.zeros((n_heads,), dtype),
        "dt_bias": jnp.full((n_heads,), -4.6, dtype),
        "d_skip": jnp.ones((n_heads,), dtype),
        "norm": common.rmsnorm_init(di, dtype),
        "out_proj": common.dense_init(ks[2], di, d_model, dtype),
    }


def _mamba2_split(p, x, di, n_state, n_heads):
    zxbcdt = common.dense(p["in_proj"], x)
    z, xbc, dt_in = jnp.split(zxbcdt, [di, 2 * di + 2 * n_state], axis=-1)
    return z, xbc, dt_in


def mamba2(p, x, *, n_state: int, head_dim: int = 64, chunk: int = 64,
           return_state: bool = False):
    b, s, d = x.shape
    di = p["out_proj"]["w"].shape[0]
    n_heads = di // head_dim
    z, xbc, dt_in = _mamba2_split(p, x, di, n_state, n_heads)
    z = constraints.shard(z, "dp", None, "tp")
    xbc_raw = xbc
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xr, b_t, c_t = jnp.split(xbc, [di, di + n_state], axis=-1)
    xr = constraints.shard(xr, "dp", None, "tp")
    dt = jax.nn.softplus(dt_in + p["dt_bias"]).astype(jnp.float32)   # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                     # (H,)
    decay = jnp.exp(dt * a)                                          # (B,S,H)
    xh = xr.reshape(b, s, n_heads, head_dim).astype(jnp.float32)
    inc = (dt[..., None] * xh)[..., None] * b_t[:, :, None, None, :]  # (B,S,H,P,N)
    cs = _pick_chunk(s, chunk)
    nc = s // cs

    def chunk_body(h, inp):
        dec_k, inc_k, c_k = inp
        inc_k = constraints.shard(inc_k, "dp", None, "tp", None, None)
        inc_k = inc_k.at[:, 0].add(dec_k[:, 0, :, None, None] * h)
        _, hs = jax.lax.associative_scan(
            _ssm_assoc, (dec_k[..., None, None], inc_k), axis=1)
        y = jnp.einsum("blhpn,bln->blhp", hs, c_k)
        return hs[:, -1], y

    split = lambda t: jnp.moveaxis(t.reshape((b, nc, cs) + t.shape[2:]), 1, 0)
    h0 = constraints.shard(
        jnp.zeros((b, n_heads, head_dim, n_state), jnp.float32),
        "dp", "tp", None, None)
    h_final, ys = jax.lax.scan(
        chunk_body, h0,
        (split(decay), split(inc), split(c_t.astype(jnp.float32))))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, n_heads, head_dim)
    y = y + xh * p["d_skip"][:, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = common.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = common.dense(p["out_proj"], y)
    if return_state:
        state = {"h": h_final,
                 "conv": xbc_raw[:, -(_CONV_K - 1):].astype(jnp.float32)}
        return out, state
    return out


def mamba2_init_state(batch: int, d_inner: int, n_state: int, head_dim: int = 64):
    n_heads = d_inner // head_dim
    return {"h": jnp.zeros((batch, n_heads, head_dim, n_state), jnp.float32),
            "conv": jnp.zeros((batch, _CONV_K - 1, d_inner + 2 * n_state),
                              jnp.float32)}


def mamba2_decode(p, x, state, *, n_state: int, head_dim: int = 64):
    b = x.shape[0]
    di = p["out_proj"]["w"].shape[0]
    n_heads = di // head_dim
    z, xbc, dt_in = _mamba2_split(p, x[:, 0], di, n_state, n_heads)
    xbc, conv = _conv_step(state["conv"], xbc, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    xr, b_t, c_t = jnp.split(xbc, [di, di + n_state], axis=-1)
    dt = jax.nn.softplus(dt_in + p["dt_bias"]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                                          # (B,H)
    xh = xr.reshape(b, n_heads, head_dim).astype(jnp.float32)
    inc = (dt[..., None] * xh)[..., None] * b_t[:, None, None, :]
    h = decay[..., None, None] * state["h"] + inc
    y = jnp.einsum("bhpn,bn->bhp", h, c_t.astype(jnp.float32))
    y = y + xh * p["d_skip"][:, None]
    y = y.reshape(b, di).astype(x.dtype)
    y = common.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return common.dense(p["out_proj"], y)[:, None], {"h": h, "conv": conv}
