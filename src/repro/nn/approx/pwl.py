"""Reduced-ring piecewise-linear nonlinearities (GELU / SiLU as ReLU sums).

A smooth activation f is lowered to the closed form

    f_hat(x) = c0 + sum_j a_j * ReLU(x - t_j)

over a fixed knot grid t_0 < ... < t_{J-1}: a_0 is the first segment's
slope, a_j the slope *change* at knot j, and the right tail continues with
slope 1 (GELU/SiLU are asymptotically the identity).  Left of t_0 the
approximation is the constant c0 = f(t_0) (both activations vanish there).

The J knot-shifted copies are stacked on a NEW LEADING axis and evaluated
in ONE ``relu_fn`` call, so under MPC the whole activation costs exactly
one reduced-ring ReLU pass (J x the elements, round count unchanged) and
the plan's per-group element counts price the blow-up truthfully.  The
combine is public: one ``mul_public`` by the coefficient vector plus ring
adds — each product pays one +-1 LSB truncation, so the fixed-point error
of one activation is bounded by ~J * 2^-frac_bits on top of the PWL
interpolation error.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PWLSpec:
    """Closed-form ReLU decomposition of a scalar nonlinearity."""

    name: str
    knots: Tuple[float, ...]      # t_0 < ... < t_{J-1}
    coeffs: Tuple[float, ...]     # a_j, one per knot
    c0: float                     # constant left tail, = f(t_0)

    @property
    def n_knots(self) -> int:
        return len(self.knots)


def _silu(x: float) -> float:
    return x / (1.0 + math.exp(-x))


def _gelu(x: float) -> float:
    # tanh form, matching jax.nn.gelu(approximate=True) — the default the
    # plaintext substrate resolves for cfg.act == "gelu"
    return 0.5 * x * (1.0 + math.tanh(
        math.sqrt(2.0 / math.pi) * (x + 0.044715 * x ** 3)))


def pwl_spec(fn: Callable[[float], float], lo: float, hi: float, step: float,
             right_slope: float = 1.0, name: str = "") -> PWLSpec:
    """Interpolate ``fn`` on the uniform grid [lo, hi] with spacing ``step``.

    Deterministic closed form (no fitting): segment slopes are the secant
    slopes between adjacent knots; beyond ``hi`` the tail continues with
    ``right_slope``; below ``lo`` the value is frozen at ``fn(lo)``.
    """
    n_seg = int(round((hi - lo) / step))
    assert abs(lo + n_seg * step - hi) < 1e-9, (lo, hi, step)
    knots = [lo + j * step for j in range(n_seg + 1)]
    vals = [fn(t) for t in knots]
    slopes = [(vals[j + 1] - vals[j]) / step for j in range(n_seg)]
    slopes.append(right_slope)
    coeffs = [slopes[0]] + [slopes[j] - slopes[j - 1]
                            for j in range(1, n_seg + 1)]
    return PWLSpec(name=name, knots=tuple(knots), coeffs=tuple(coeffs),
                   c0=vals[0])


def silu_spec(lo: float = -8.0, hi: float = 8.0,
              step: float = 0.5) -> PWLSpec:
    return pwl_spec(_silu, lo, hi, step, name="silu")


def gelu_spec(lo: float = -4.0, hi: float = 4.0,
              step: float = 0.25) -> PWLSpec:
    return pwl_spec(_gelu, lo, hi, step, name="gelu")


def spec_for(act: str) -> Optional[PWLSpec]:
    """The reduced-ring lowering of a config ``act`` name.

    Returns None for ``relu`` (already a single relu_fn call, no
    decomposition needed); raises for activations with no MPC lowering.
    """
    if act == "relu":
        return None
    if act == "silu":
        return silu_spec()
    if act == "gelu":
        return gelu_spec()
    raise ValueError(f"no reduced-ring PWL lowering for activation {act!r}")


def eval_pwl(spec: PWLSpec, x) -> jax.Array:
    """Direct (hook-free) evaluation of the closed form — the oracle tests
    and error-bound sweeps compare against."""
    x = jnp.asarray(x)
    y = jnp.full(x.shape, spec.c0, x.dtype)
    for t, a in zip(spec.knots, spec.coeffs):
        y = y + a * jnp.maximum(x - t, 0.0)
    return y


def pwl_max_error(spec: PWLSpec, fn: Callable, n: int = 4001,
                  margin: float = 2.0) -> float:
    """Max |f_hat - f| on a dense grid spanning the knot range +- margin."""
    xs = np.linspace(spec.knots[0] - margin, spec.knots[-1] + margin, n)
    ref = np.asarray([fn(float(v)) for v in xs])
    got = np.asarray(eval_pwl(spec, xs.astype(np.float32)))
    return float(np.max(np.abs(got - ref)))


def ensure_hooks(relu_fn):
    """Normalize a plaintext ``relu_fn`` to carry ``.matmul``/``.mul``.

    ``None`` means exact reference evaluation: true ReLU and plain jnp
    products.  A bare function (e.g. a traced or reduced-ring relu) gets
    plain-jnp product hooks attached on a wrapper, leaving the caller's
    object untouched.
    """
    if relu_fn is None:
        base = lambda v, g: jax.nn.relu(v)  # noqa: E731
    else:
        base = relu_fn
    if hasattr(base, "matmul") and hasattr(base, "mul"):
        return base

    def wrapped(v, g):
        return base(v, g)

    wrapped.matmul = getattr(base, "matmul", jnp.matmul)
    wrapped.mul = getattr(base, "mul", jnp.multiply)
    return wrapped


def apply_pwl(spec: PWLSpec, x: jax.Array, group: int, relu_fn) -> jax.Array:
    """Plaintext PWL activation through the ``relu_fn`` hook.

    Mirrors the MPC data flow exactly: J knot-shifted copies stacked on a
    new leading axis, ONE relu_fn call, public linear combine — so a plan
    traced from this function prices the same elements the MPC replay
    evaluates.
    """
    shifted = jnp.stack([x - t for t in spec.knots], axis=0)
    r = relu_fn(shifted, group)
    coeffs = jnp.asarray(spec.coeffs, x.dtype).reshape(
        (spec.n_knots,) + (1,) * x.ndim)
    return spec.c0 + jnp.sum(r * coeffs, axis=0)


def apply_pwl_mpc(spec: PWLSpec, hs: Sequence, group: int, relu_fn,
                  comm=None) -> List:
    """Secret-shared PWL activation over sibling MPCTensor streams.

    One ``relu_fn`` call evaluates all J knot-shifted copies of every
    stream (the reduced-ring (k, m) of ``group`` applies to the stack);
    the combine is local: one ``mul_public`` by the coefficient vector,
    J-1 ring adds, one public constant add.
    """
    from repro.core import mpc_tensor  # lazy: keep plaintext substrate light
    stacked = [mpc_tensor.stack([h.add_public(-t, comm) for t in spec.knots],
                                axis=0)
               for h in hs]
    rs = relu_fn(stacked, group)
    outs = []
    for r in rs:
        nd = len(r.shape)
        coeffs = np.asarray(spec.coeffs, np.float32).reshape(
            (spec.n_knots,) + (1,) * (nd - 1))
        w = r.mul_public(coeffs)
        acc = w[0]
        for j in range(1, spec.n_knots):
            acc = acc + w[j]
        outs.append(acc.add_public(spec.c0, comm))
    return outs
