"""Secret sharing: arithmetic shares on Z/2^64 and packed binary shares.

Arithmetic shares: <x>_0 + <x>_1 = x (mod 2^64), stored as Ring64 with a
leading party dimension.

Binary shares are *bit-sliced*: a w-bit shared value over E elements is
stored as (party, w, W) uint32 where W = ceil(E/32) and word j of plane i
packs the i-th bit of elements 32j..32j+31.  Every XOR/AND VPU op then
processes 32 secret bits per lane — the TPU adaptation of the paper's
bitpacking (§4.2).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import ring

_U32 = jnp.uint32


# ---------------------------------------------------------------------------
# Arithmetic shares
# ---------------------------------------------------------------------------

def share(key, x: ring.Ring64, n_parties: int = 2) -> ring.Ring64:
    """Split plaintext ring values into additive shares, party dim leading."""
    masks = [ring.uniform(k, x.shape) for k in jax.random.split(key, n_parties - 1)]
    first = x
    for m in masks:
        first = ring.sub(first, m)
    los = jnp.stack([first.lo] + [m.lo for m in masks], axis=0)
    his = jnp.stack([first.hi] + [m.hi for m in masks], axis=0)
    return ring.Ring64(los, his)


def reconstruct(xs: ring.Ring64) -> ring.Ring64:
    """Sum shares over the leading party dimension."""
    acc = xs[0]
    for p in range(1, xs.shape[0]):
        acc = ring.add(acc, xs[p])
    return acc


# ---------------------------------------------------------------------------
# Bit packing (reference implementation; kernels/bitpack has the TPU kernel)
# ---------------------------------------------------------------------------

def packed_words(n_elements: int) -> int:
    return (n_elements + 31) // 32


def pack_bits(planes: jax.Array) -> jax.Array:
    """(..., w, E) {0,1} uint32 -> (..., w, W) packed words (E padded)."""
    e = planes.shape[-1]
    w_words = packed_words(e)
    pad = w_words * 32 - e
    if pad:
        planes = jnp.pad(planes, [(0, 0)] * (planes.ndim - 1) + [(0, pad)])
    grouped = planes.reshape(planes.shape[:-1] + (w_words, 32)).astype(_U32)
    shifts = jnp.arange(32, dtype=_U32)
    return (grouped << shifts).sum(axis=-1, dtype=_U32)


def unpack_bits(words: jax.Array, n_elements: int) -> jax.Array:
    """(..., W) packed words -> (..., E) {0,1} uint32."""
    shifts = jnp.arange(32, dtype=_U32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(words.shape[:-1] + (words.shape[-1] * 32,))
    return flat[..., :n_elements]


def xor_share_packed(key, words: jax.Array, n_parties: int = 2) -> jax.Array:
    """XOR-share packed words; adds a leading party dimension."""
    masks = [
        jax.random.bits(k, words.shape, dtype=_U32)
        for k in jax.random.split(key, n_parties - 1)
    ]
    first = words
    for m in masks:
        first = first ^ m
    return jnp.stack([first] + masks, axis=0)


def xor_reconstruct(ws: jax.Array) -> jax.Array:
    out = ws[0]
    for p in range(1, ws.shape[0]):
        out = out ^ ws[p]
    return out
