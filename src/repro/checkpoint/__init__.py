"""Sharded, atomic, async checkpointing with elastic re-sharding."""
from . import store
from .store import AsyncCheckpointer, latest_step, restore, save
__all__ = ["store", "AsyncCheckpointer", "latest_step", "restore", "save"]
