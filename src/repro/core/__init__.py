"""HummingBird core: reduced-ring MPC ReLU on Z/2^64 in JAX.

Layering:
  ring         - Z/2^64 limb arithmetic (TPU-native, no int64)
  fixed        - fixed-point codec (CrypTen-compatible scale 2^16)
  shares       - arithmetic + packed binary secret sharing
  beaver       - TTP triple generation + TripleProvider protocol
                 (inline / streaming / eager pool — consumed by
                 repro.api.Session)
  comm         - party communicator (sim / mesh backends, counting +
                 coalescing wrappers for the round-fused engine)
  schedule     - deterministic fused-round timeline simulator (single
                 source of truth for rounds/bytes/latency; validated
                 bit-exactly against CoalescingComm counters)
  gmw          - A2B, DReLU, B2A, ReLU (exact Eq.2 + reduced-ring Eq.3),
                 round-fused engine + relu_many round sharing
  gmw_ref      - frozen seed protocol (regression oracle / bench baseline)
  hummingbird  - per-layer (k, m) configs and budgets
  costmodel    - closed-form bytes/rounds (validated against HLO collectives)
  ring_linalg  - mod-2^64 matmul/conv with public weights (plane decomposition)
  mpc_tensor   - user-facing secret-shared tensor (+ relu_many)
"""
from . import (beaver, comm, costmodel, fixed, gmw, gmw_ref, hummingbird,
               ring, ring_linalg, schedule, shares)
from .hummingbird import HBConfig, HBLayer, safe_k
from .mpc_tensor import MPCTensor, encode_weights, relu_many

__all__ = [
    "beaver", "comm", "costmodel", "fixed", "gmw", "gmw_ref", "hummingbird",
    "ring", "ring_linalg", "schedule", "shares", "HBConfig", "HBLayer",
    "safe_k", "MPCTensor", "encode_weights", "relu_many",
]
