"""Paper Table 1 / Table 3 / Fig. 12: accuracy across budgets, the effect
of finetuning, and the per-group bit maps chosen by the search engine
(synthetic data; the *mechanisms* are what's validated — see DESIGN.md §8).
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import RESNET_SMOKE
from repro.core.hummingbird import HBConfig
from repro.models import resnet
from repro.search import finetune as ft, search_budget, search_eco
from repro.search.simulator import evaluate_accuracy


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    params = resnet.init(key, RESNET_SMOKE)
    xs = jax.random.normal(jax.random.PRNGKey(1), (384, 3, 16, 16))
    ys = (xs[:, 0, :8, :8].mean((1, 2)) > 0).astype(jnp.int32)

    def afn(p, x, relu_fn=None):
        return resnet.apply(p, x, RESNET_SMOKE, relu_fn=relu_fn)

    groups = resnet.relu_group_elements(params, RESNET_SMOKE)
    params, _ = ft.finetune(afn, params, xs[:256], ys[:256],
                            HBConfig.exact(groups), jax.random.PRNGKey(5),
                            epochs=5, batch=64, lr=3e-3)
    val_x, val_y = xs[256:], ys[256:]
    base = evaluate_accuracy(afn, params, val_x, val_y,
                             HBConfig.exact(groups), jax.random.PRNGKey(6))
    rows.append(("table1_baseline_acc", 0.0, f"acc={base:.4f}"))

    for budget, bits in (("eco", None), ("8of64", (6, 8)), ("6of64", (5, 6))):
        t0 = time.time()
        if budget == "eco":
            res = search_eco(afn, params, val_x, val_y, groups,
                             jax.random.PRNGKey(2))
        else:
            res = search_budget(afn, params, val_x, val_y, groups,
                                jax.random.PRNGKey(3),
                                budget=eval(budget.replace("of", "/")),
                                bit_choices=bits)
        bitmap = ";".join(f"g{i}:k={l.k},m={l.m}"
                          for i, l in enumerate(res.config.layers))
        rows.append((f"fig12_bitmap_{budget}", (time.time() - t0) * 1e6, bitmap))
        rows.append((f"table1_acc_{budget}", 0.0,
                     f"acc={res.accuracy:.4f};drop={base-res.accuracy:.4f}"))
        if budget != "eco":
            p2, _ = ft.finetune(afn, params, xs[:256], ys[:256], res.config,
                                jax.random.PRNGKey(7), epochs=2, batch=64)
            post = evaluate_accuracy(afn, p2, val_x, val_y, res.config,
                                     jax.random.PRNGKey(8))
            rows.append((f"table3_finetune_{budget}", 0.0,
                         f"before={res.accuracy:.4f};after={post:.4f};"
                         f"delta={post-res.accuracy:+.4f}"))
    return rows
