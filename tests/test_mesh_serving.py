"""Mesh-native round-fused serving (PR 4).

The coalesced ``run_streams`` replay executes *inside* ``shard_map`` over
the party axis with ``MeshComm`` as the ``CoalescingComm`` base, so one
fused protocol round = one ``lax.ppermute`` of one flattened uint32
buffer.  Three layers of validation:

- backend parity: ``MeshComm`` swap/``party_is``/``party_slice`` match
  ``SimComm`` under ``shard_map``, and a ``CoalescingComm`` flush over
  the mesh base returns bit-identical per-handle payloads;
- serving parity: ``PrivateModel.serve_step(mesh)`` is bit-identical to
  the SimComm replay on the same shares/triples (smoke mesh in-process;
  a real two-party axis in a 2-device subprocess);
- HLO-vs-costmodel: the compiled step's collective-permute census
  (``runtime.hlo_analyzer.collective_census``) equals
  ``core.schedule``'s predicted ``(n_rounds, round_bytes)`` exactly —
  count for count, payload for payload, in program order.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import api
from repro.configs import RESNET_SMOKE
from repro.core import beaver, comm as comm_lib, fixed, gmw, ring, shares
from repro.core.hummingbird import HBConfig, HBLayer
from repro.launch.mesh import make_mpc_smoke_mesh
from repro.models import resnet


# ---------------------------------------------------------------------------
# Backend parity on the 1-device smoke mesh (party axis size 1: both party
# rows on one shard, exchanges degenerate to the sim backend's local flip)
# ---------------------------------------------------------------------------

def _smoke_shard_map(fn, n_out: int = 1):
    mesh = make_mpc_smoke_mesh()
    spec = P("party")
    return shard_map(fn, mesh=mesh, in_specs=spec,
                     out_specs=(spec,) * n_out if n_out > 1 else spec,
                     check_rep=False)


def test_meshcomm_swap_matches_simcomm_on_smoke_mesh():
    x = jax.random.bits(jax.random.PRNGKey(0), (2, 3, 5), dtype=jnp.uint32)
    want = comm_lib.SimComm().swap(x)
    got = _smoke_shard_map(
        lambda a: comm_lib.MeshComm("party", 1).swap(a))(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_meshcomm_party_is_and_slice_match_simcomm_on_smoke_mesh():
    x = jax.random.bits(jax.random.PRNGKey(1), (2, 4), dtype=jnp.uint32)
    sim = comm_lib.SimComm()

    def body(a):
        mc = comm_lib.MeshComm("party", 1)
        mask = jnp.broadcast_to(mc.party_is(1, a), a.shape)
        return mask.astype(jnp.uint32), mc.party_slice(a)

    got_mask, got_slice = _smoke_shard_map(body, n_out=2)(x)
    want_mask = jnp.broadcast_to(sim.party_is(1, x), x.shape)
    np.testing.assert_array_equal(np.asarray(got_mask),
                                  np.asarray(want_mask.astype(jnp.uint32)))
    np.testing.assert_array_equal(np.asarray(got_slice), np.asarray(x))


def test_coalescing_flush_over_meshcomm_bit_identical_to_sim():
    """One flattened flush over the mesh base hands every enqueuer back
    exactly the payload the sim base would have."""
    key = jax.random.PRNGKey(2)
    payloads = [
        jax.random.bits(k, shape, dtype=jnp.uint32)
        for k, shape in zip(jax.random.split(key, 3),
                            [(2, 7), (2, 3, 5), (2, 11)])
    ]

    def run(comm_factory):
        def body(a, b, c):
            cc = comm_lib.CoalescingComm(comm_factory())
            ha, hb_, hc = cc.enqueue(a), cc.enqueue(b), cc.enqueue(c)
            opened = cc.flush()
            return opened[ha], opened[hb_], opened[hc]
        return body

    want = run(comm_lib.SimComm)(*payloads)
    mesh = make_mpc_smoke_mesh()
    got = shard_map(run(lambda: comm_lib.MeshComm("party", 1)), mesh=mesh,
                    in_specs=(P("party"),) * 3, out_specs=(P("party"),) * 3,
                    check_rep=False)(*payloads)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_meshcomm_rejects_indivisible_axis():
    with pytest.raises(ValueError, match="divide"):
        comm_lib.MeshComm("party", 3)


# ---------------------------------------------------------------------------
# Mesh-native serve_step on the smoke mesh (in-process, 1 device)
# ---------------------------------------------------------------------------

def _smoke_model():
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

    plan = api.trace_plan(afn, params, (2, 3, 8, 8), name="smoke")
    hb = HBConfig(tuple([HBLayer(k=21, m=13)] * (plan.n_groups - 1)
                        + [HBLayer(k=13, m=13)]),   # last group culled
                  plan.group_elements)
    model = api.compile(afn, params, RESNET_SMOKE, plan.with_hb(hb),
                        api.Session(key=0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 8, 8)) * 0.5
    X = model.encrypt(jax.random.PRNGKey(2), x)
    pool = beaver.gen_plan_triples(jax.random.PRNGKey(3),
                                   model.plan.triple_specs())
    return model, params, x, X, pool


def test_mesh_serve_step_bit_identical_to_sim_on_smoke_mesh():
    model, params, x, X, pool = _smoke_model()
    key = jax.random.PRNGKey(4)
    sim_lo, sim_hi = model.serve_step()(params, X.data.lo, X.data.hi, pool,
                                        key)
    mesh_step = model.serve_step(make_mpc_smoke_mesh())
    m_lo, m_hi = jax.jit(mesh_step)(params, X.data.lo, X.data.hi, pool, key)
    np.testing.assert_array_equal(np.asarray(m_lo), np.asarray(sim_lo))
    np.testing.assert_array_equal(np.asarray(m_hi), np.asarray(sim_hi))
    served = fixed.decode_np(shares.reconstruct(ring.Ring64(m_lo, m_hi)))
    want = np.argmax(np.asarray(model.plaintext(x)), -1)
    assert (np.argmax(served, -1) == want).all()


def test_mesh_serve_step_requires_triple_pool():
    model, params, _, X, _ = _smoke_model()
    step = model.serve_step(make_mpc_smoke_mesh())
    with pytest.raises(ValueError, match="triple pool"):
        step(params, X.data.lo, X.data.hi, None, jax.random.PRNGKey(0))


def test_mesh_serve_step_rejects_party_axis_free_mesh():
    model = _smoke_model()[0]
    with pytest.raises(ValueError, match="party"):
        model.serve_step(jax.make_mesh((1, 1), ("data", "model")))


# ---------------------------------------------------------------------------
# HLO-vs-costmodel + real two-party exchange (2-device subprocess: the main
# test process keeps the default single CPU device, matching conftest)
# ---------------------------------------------------------------------------

_TWO_PARTY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import api
from repro.configs import RESNET_SMOKE
from repro.core import beaver, comm as comm_lib, fixed, gmw, ring, \
    schedule as schedule_lib, shares
from repro.core.hummingbird import HBConfig, HBLayer
from repro.models import resnet
from repro.runtime.hlo_analyzer import collective_census

assert jax.device_count() >= 2

# -- 1. multi-group relu_many step: census == schedule, bit-identical -------
for cone in (False, True):
    specs = [(256, 64, 0), (256, 21, 13), (128, 21, 13), (128, 20, 14)]
    keys = [jax.random.PRNGKey(40 + i) for i in range(len(specs))]
    rng = np.random.default_rng(0)
    Xs, trs = [], []
    for i, (n, k, m) in enumerate(specs):
        x = rng.uniform(-3.5, 3.5, n).astype(np.float32)
        Xs.append(shares.share(jax.random.PRNGKey(50 + i),
                               fixed.encode_np(x)))
        trs.append(beaver.gen_relu_triples(jax.random.PRNGKey(60 + i), n,
                                           k - m, cone=cone))
    kms = [(k, m) for _, k, m in specs]
    mesh = jax.make_mesh((2,), ("party",))

    def replay(lo_list, hi_list, triples):
        cc = comm_lib.CoalescingComm(comm_lib.MeshComm("party", 2))
        xs = [ring.Ring64(lo, hi) for lo, hi in zip(lo_list, hi_list)]
        outs = gmw.relu_many(keys, xs, triples, cc, kms, cone=cone)
        return [o.lo for o in outs], [o.hi for o in outs]

    party = P("party")
    n_g = len(specs)
    fused = shard_map(replay, mesh=mesh,
                      in_specs=([party] * n_g, [party] * n_g,
                                beaver.pool_party_specs(trs)),
                      out_specs=([party] * n_g, [party] * n_g),
                      check_rep=False)
    compiled = jax.jit(fused).lower([x.lo for x in Xs], [x.hi for x in Xs],
                                    trs).compile()
    census = collective_census(compiled.as_text())
    sched = schedule_lib.simulate([(n, k - m, (n, k, m)) for n, k, m in specs],
                                  cone=cone)
    assert all(c.count == 1 for c in census), census
    assert len(census) == sched.n_rounds, (cone, len(census), sched.n_rounds)
    assert [c.bytes for c in census] == list(sched.round_bytes), (
        cone, [c.bytes for c in census], sched.round_bytes)

    los, his = compiled([x.lo for x in Xs], [x.hi for x in Xs], trs)
    sim = gmw.relu_many(keys, Xs, trs, comm_lib.SimComm(), kms, cone=cone)
    for o, lo, hi in zip(sim, los, his):
        np.testing.assert_array_equal(np.asarray(o.lo), np.asarray(lo))
        np.testing.assert_array_equal(np.asarray(o.hi), np.asarray(hi))
    print(json.dumps({"cone": cone, "rounds": len(census),
                      "bytes": int(sum(c.bytes for c in census))}))

# -- 2. whole-network serve step: the compiled artifact IS the timeline ----
params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)

def afn(p, v, relu_fn=None):
    return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

plan = api.trace_plan(afn, params, (2, 3, 8, 8), name="smoke")
plan = plan.with_hb(HBConfig(
    tuple([HBLayer(k=21, m=13)] * (plan.n_groups - 1)
          + [HBLayer(k=13, m=13)]), plan.group_elements))
model = api.compile(afn, params, RESNET_SMOKE, plan, api.Session(key=0))
x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 8, 8)) * 0.5
X = model.encrypt(jax.random.PRNGKey(2), x)
pool = beaver.gen_plan_triples(jax.random.PRNGKey(3), plan.triple_specs())
key = jax.random.PRNGKey(4)

from repro.launch.mesh import make_mpc_mesh
mesh = make_mpc_mesh()          # (2, 1) on the forced 2-device topology
step = model.serve_step(mesh)
compiled = jax.jit(step).lower(params, X.data.lo, X.data.hi, pool,
                               key).compile()
census = collective_census(compiled.as_text())
sched = model.schedule()
assert len(census) == sched.n_rounds, (len(census), sched.n_rounds)
assert [c.bytes for c in census] == list(sched.round_bytes)

m_lo, m_hi = compiled(params, X.data.lo, X.data.hi, pool, key)
s_lo, s_hi = model.serve_step()(params, X.data.lo, X.data.hi, pool, key)
np.testing.assert_array_equal(np.asarray(m_lo), np.asarray(s_lo))
np.testing.assert_array_equal(np.asarray(m_hi), np.asarray(s_hi))
print(json.dumps({"model_rounds": len(census),
                  "model_bytes": int(sum(c.bytes for c in census))}))
print("TWO_PARTY_OK")
"""


def test_two_party_hlo_census_matches_schedule_and_sim():
    """Acceptance: on a party axis of size 2, the compiled HLO of the
    multi-group relu_many serve step contains exactly the
    schedule-predicted number of collective-permutes with matching
    per-collective bytes, and the mesh replay's outputs are bit-identical
    to the SimComm replay on the same shares/triples."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _TWO_PARTY_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    assert "TWO_PARTY_OK" in out.stdout
