"""Architecture config schema + the assigned input-shape suite."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    act: str = "silu"
    gated_mlp: bool = True
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    sliding_window: int = 0
    local_global_period: int = 0  # gemma2: alternate local/global attention
    n_experts: int = 0
    top_k: int = 0
    ssm_state: int = 0
    ssm_expand: int = 2
    mamba_version: int = 0
    mamba2_head_dim: int = 64
    attn_every: int = 0           # zamba2: shared attention block period
    n_enc_layers: int = 0         # enc-dec only
    frontend: str = "none"        # none | audio | vision (stub embeddings)
    n_frontend_tokens: int = 0
    sub_quadratic: bool = False   # eligible for long_500k
    norm: str = "rmsnorm"
    dtype: str = "bfloat16"
    # substrate knobs
    attn_chunk_q: int = 512
    attn_chunk_k: int = 1024
    ssm_chunk: int = 128
    moe_capacity_factor: float = 1.25
    train_microbatches: int = 1
    remat: str = "dots"           # none | dots | dots_all | full

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def has_attention(self) -> bool:
        return self.family not in ("ssm",)

    def param_count(self) -> int:
        """Total params (for roofline MODEL_FLOPS)."""
        d, v, l = self.d_model, self.vocab, self.n_layers
        hd = self.resolved_head_dim
        emb = v * d * 2  # embed + head (untied)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            per_layer += attn if self.family != "hybrid" else 0
        if self.family in ("dense", "vlm", "encdec"):
            mult = 3 if self.gated_mlp else 2
            per_layer += mult * d * self.d_ff
        if self.family == "moe":
            mult = 3 if self.gated_mlp else 2
            per_layer += self.n_experts * mult * d * self.d_ff + d * self.n_experts
        if self.family in ("ssm", "hybrid"):
            di = self.ssm_expand * d
            n = self.ssm_state
            if self.mamba_version == 1:
                dt_rank = max(1, d // 16)
                per_layer += d * 2 * di + di * (dt_rank + 2 * n) + dt_rank * di \
                    + di * n + 2 * di + di * d
            else:
                nh = di // self.mamba2_head_dim
                per_layer += d * (2 * di + 2 * n + nh) + di * d + di
        total = emb + l * per_layer
        if self.family == "encdec":
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            total += self.n_enc_layers * (attn + 2 * d * self.d_ff) + l * attn
        if self.family == "hybrid" and self.attn_every:
            hd2 = self.resolved_head_dim
            total += d * hd2 * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd2 * d
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, l = self.d_model, self.n_layers
        mult = 3 if self.gated_mlp else 2
        dense_ffn = l * self.n_experts * mult * d * self.d_ff
        active_ffn = l * self.top_k * mult * d * self.d_ff
        return self.param_count() - dense_ffn + active_ffn


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Skip rules from the brief (recorded per-cell in EXPERIMENTS.md)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k needs sub-quadratic"
    return True, ""


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    kv_ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    heads = 4
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=heads,
        n_kv_heads=max(1, heads // kv_ratio),
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        head_dim=16,
        n_experts=min(cfg.n_experts, 4),
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        mamba2_head_dim=16,
        sliding_window=16 if cfg.sliding_window else 0,
        attn_every=2 if cfg.attn_every else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        n_frontend_tokens=8 if cfg.n_frontend_tokens else 0,
        attn_chunk_q=16,
        attn_chunk_k=16,
        ssm_chunk=16,
        dtype="float32",
        remat="none",
    )
