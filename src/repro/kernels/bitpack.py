"""Pallas TPU kernel: HummingBird bitpacking (paper §4.2).

Packs the w reduced-ring bitplanes of a batch of uint32 share values into
dense uint32 wire words so the collective payload is exactly w bits per
element.  Layout: value v[32*j + t] contributes bit t of word (i, j) for
plane i.  The inverse (unpack) restores per-element values after the
exchange.

TPU mapping: each grid step loads a (BW, 32) tile of values into VMEM,
emits a (w, BW) tile of packed words.  The shift/mask ladder runs on the
VPU; w is a compile-time constant (k - m from the HummingBird config), so
the plane loop fully unrolls.  Lane-dim tiles are multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_U32 = jnp.uint32
BLOCK_WORDS = 256  # words per grid step; 256*32 = 8192 elements per tile


def _pack_kernel(v_ref, out_ref, *, w: int):
    v = v_ref[...]                                    # (BW, 32) uint32
    shifts = jnp.arange(32, dtype=_U32)[None, :]      # bit position per lane
    for i in range(w):
        bits = (v >> _U32(i)) & _U32(1)
        out_ref[i, :] = (bits << shifts).sum(axis=-1, dtype=_U32)


def _unpack_kernel(words_ref, out_ref, *, w: int):
    words = words_ref[...]                            # (w, BW)
    shifts = jnp.arange(32, dtype=_U32)[None, :]
    acc = jnp.zeros(words.shape[1:] + (32,), _U32)    # (BW, 32)
    for i in range(w):
        bits = (words[i][:, None] >> shifts) & _U32(1)
        acc = acc | (bits << _U32(i))
    out_ref[...] = acc


def pack_pallas(v: jax.Array, w: int, *, interpret: bool = True,
                block_words: int = BLOCK_WORDS) -> jax.Array:
    """(E,) uint32 values -> (w, W) packed words. E must be a multiple of
    32*block_words (ops.py pads)."""
    n_words = v.shape[0] // 32
    grid = (n_words // block_words,)
    return pl.pallas_call(
        functools.partial(_pack_kernel, w=w),
        out_shape=jax.ShapeDtypeStruct((w, n_words), _U32),
        grid=grid,
        in_specs=[pl.BlockSpec((block_words, 32), lambda j: (j, 0))],
        out_specs=pl.BlockSpec((w, block_words), lambda j: (0, j)),
        interpret=interpret,
    )(v.reshape(n_words, 32))


def unpack_pallas(words: jax.Array, w: int, *, interpret: bool = True,
                  block_words: int = BLOCK_WORDS) -> jax.Array:
    """(w, W) packed words -> (E,) uint32 values (E = 32*W)."""
    n_words = words.shape[1]
    grid = (n_words // block_words,)
    out = pl.pallas_call(
        functools.partial(_unpack_kernel, w=w),
        out_shape=jax.ShapeDtypeStruct((n_words, 32), _U32),
        grid=grid,
        in_specs=[pl.BlockSpec((w, block_words), lambda j: (0, j))],
        out_specs=pl.BlockSpec((block_words, 32), lambda j: (j, 0)),
        interpret=interpret,
    )(words)
    return out.reshape(n_words * 32)
