"""HummingBird configuration: which bits each ReLU layer/group keeps.

A config assigns every ReLU group a pair (k, m): DReLU is evaluated on
<x>[k:m], a (k-m)-bit reduced ring (Eq. 3).  k = 64, m = 0 is the exact
CrypTen baseline.  Budgets are expressed as in the paper: the total number
of DReLU bits summed over all ReLU evaluations relative to 64 bits each
(e.g. budget 8/64 means the weighted mean of (k-m) must be <= 8).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

RING_BITS = 64


@dataclasses.dataclass(frozen=True)
class HBLayer:
    """Reduced-ring spec for one ReLU group."""

    k: int = RING_BITS
    m: int = 0

    def __post_init__(self):
        # k == m (width 0) is the paper's ReLU-culling mode: the layer is
        # assigned zero DReLU bits and degrades to the identity.
        assert 0 <= self.m <= self.k <= RING_BITS, (self.k, self.m)

    @property
    def width(self) -> int:
        return self.k - self.m

    @property
    def is_identity(self) -> bool:
        """Zero assigned bits degenerates ReLU to identity (ReLU culling)."""
        return self.k == self.m

    def to_json(self) -> Dict:
        return {"k": self.k, "m": self.m}

    @staticmethod
    def from_json(d: Dict) -> "HBLayer":
        return HBLayer(k=int(d["k"]), m=int(d["m"]))


@dataclasses.dataclass(frozen=True)
class HBConfig:
    """Per-group (k, m) assignments plus group sizes for budget accounting.

    ``group_elements[g]`` is the number of ReLU elements (activations) in
    group g for one inference; budgets weight each group by its element
    count, mirroring the paper's note that early CNN layers dominate.
    """

    layers: Tuple[HBLayer, ...]
    group_elements: Tuple[int, ...]

    def __post_init__(self):
        assert len(self.layers) == len(self.group_elements)

    @property
    def n_groups(self) -> int:
        return len(self.layers)

    def bits_used(self) -> int:
        return sum(l.width * e for l, e in zip(self.layers, self.group_elements))

    def bits_baseline(self) -> int:
        return RING_BITS * sum(self.group_elements)

    def budget_fraction(self) -> float:
        return self.bits_used() / max(1, self.bits_baseline())

    def meets_budget(self, budget: float) -> bool:
        return self.budget_fraction() <= budget + 1e-12

    @staticmethod
    def exact(group_elements: Sequence[int]) -> "HBConfig":
        return HBConfig(
            tuple(HBLayer() for _ in group_elements), tuple(group_elements)
        )

    def to_json(self) -> Dict:
        return {"layers": [l.to_json() for l in self.layers],
                "group_elements": list(self.group_elements)}

    @staticmethod
    def from_json(d: Dict) -> "HBConfig":
        return HBConfig(tuple(HBLayer.from_json(l) for l in d["layers"]),
                        tuple(int(e) for e in d["group_elements"]))


def safe_k(max_abs_int: float, m: int = 0, margin_bits: int = 0) -> int:
    """Smallest k with zero sign-estimation error for |x_int| <= max_abs_int.

    Theorem 1 requires -2^(k-1) <= x < 2^(k-1).  When m > 0, Theorem 2's
    floor(x/2^m) - 1 case needs one extra value of headroom at the negative
    edge (underflow case (2) of the proof): -2^(k-1) + 2^m <= x.
    """
    need = max_abs_int + (1 << m if m > 0 else 0)
    k = max(2, math.ceil(math.log2(max(need, 1))) + 1 + margin_bits)
    return min(k, RING_BITS)


def prune_threshold_float(m: int, frac_bits: int = 16) -> float:
    """Theorem 2: dropping m low bits prunes activations below 2^(m-frac)."""
    return float(2 ** (m - frac_bits))
