"""falcon-mamba-7b [ssm]: 64L d_model=4096, attn-free Mamba1, ssm_state=16,
vocab=65024.  [arXiv:2410.05355]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=65024, ssm_state=16,
    ssm_expand=2, mamba_version=1, sub_quadratic=True,
)
