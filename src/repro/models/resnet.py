"""ResNet-18/50 — the paper's own workload (CIFAR-sized stem).

Two evaluation paths over one weight pytree:
  - `apply`: plaintext JAX forward (training, search simulator).
  - `mpc_apply`: secret-shared forward on MPCTensors (GMW conv/ReLU), with
    BatchNorm folded into the preceding conv (inference-time standard) and
    max-pool removed per the paper's §2.3 setup.

ReLU layers are organised into the paper's five groups (stem + 4 stages);
each group takes one HummingBird (k, m) assignment.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.resnet import ResNetConfig
from repro.core import MPCTensor, beaver, comm as comm_lib
from repro.core.hummingbird import HBConfig, HBLayer


def _conv_init(key, cout, cin, k):
    scale = (2.0 / (cin * k * k)) ** 0.5
    return jax.random.normal(key, (cout, cin, k, k), jnp.float32) * scale


def _bn_init(c):
    return {"gamma": jnp.ones((c,)), "beta": jnp.zeros((c,)),
            "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def _block_init(key, cin, cout, cfg, stride):
    ks = jax.random.split(key, 4)
    if cfg.block == "basic":
        p = {
            "conv1": _conv_init(ks[0], cout, cin, 3), "bn1": _bn_init(cout),
            "conv2": _conv_init(ks[1], cout, cout, 3), "bn2": _bn_init(cout),
        }
    else:  # bottleneck (expansion 4)
        mid = cout // 4
        p = {
            "conv1": _conv_init(ks[0], mid, cin, 1), "bn1": _bn_init(mid),
            "conv2": _conv_init(ks[1], mid, mid, 3), "bn2": _bn_init(mid),
            "conv3": _conv_init(ks[2], cout, mid, 1), "bn3": _bn_init(cout),
        }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[3], cout, cin, 1)
        p["bn_proj"] = _bn_init(cout)
    return p


def init(key, cfg: ResNetConfig):
    expansion = 1 if cfg.block == "basic" else 4
    ks = jax.random.split(key, 3 + len(cfg.stage_blocks))
    params: Dict = {
        "stem": _conv_init(ks[0], cfg.widths[0], 3, 3),
        "bn_stem": _bn_init(cfg.widths[0]),
        "stages": [],
    }
    cin = cfg.widths[0]
    for si, (n_blocks, width) in enumerate(zip(cfg.stage_blocks, cfg.widths)):
        cout = width * expansion
        stage = []
        bkeys = jax.random.split(ks[1 + si], n_blocks)
        for bi in range(n_blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            stage.append(_block_init(bkeys[bi], cin, cout, cfg, stride))
            cin = cout
        params["stages"].append(stage)
    params["fc"] = {
        "w": jax.random.normal(ks[-1], (cin, cfg.n_classes)) * cin ** -0.5,
        "b": jnp.zeros((cfg.n_classes,)),
    }
    return params


# ---------------------------------------------------------------------------
# Plaintext path
# ---------------------------------------------------------------------------

def _conv(x, w, stride=1, padding=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), [(padding, padding)] * 2,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def _bn(x, p, eps=1e-5):
    inv = p["gamma"] / jnp.sqrt(p["var"] + eps)
    return x * inv[:, None, None] + (p["beta"] - p["mean"] * inv)[:, None, None]


def fold_bn(conv_w, bn, eps=1e-5):
    """Fold BN into conv: returns (w', b') with conv(x, w') + b' == bn(conv)."""
    inv = bn["gamma"] / jnp.sqrt(bn["var"] + eps)
    w = conv_w * inv[:, None, None, None]
    b = bn["beta"] - bn["mean"] * inv
    return w, b


def apply(params, x, cfg: ResNetConfig, relu_fn=None,
          collect_acts: bool = False):
    """x: (B, 3, H, W) -> logits.  `relu_fn(x, group_idx)` lets the search
    simulator substitute the HummingBird approximate ReLU per group."""
    relu = relu_fn or (lambda v, g: jax.nn.relu(v))
    acts: List[jax.Array] = []

    def _relu(v, g):
        if collect_acts:
            acts.append(v)
        return relu(v, g)

    h = _bn(_conv(x, params["stem"]), params["bn_stem"])
    h = _relu(h, 0)
    for si, stage in enumerate(params["stages"]):
        for block in stage:
            stride = 2 if ("proj" in block and si > 0) else 1
            if "conv3" in block:  # bottleneck
                y = _relu(_bn(_conv(h, block["conv1"], 1, 0), block["bn1"]), si + 1)
                y = _relu(_bn(_conv(y, block["conv2"], stride, 1), block["bn2"]), si + 1)
                y = _bn(_conv(y, block["conv3"], 1, 0), block["bn3"])
            else:
                y = _relu(_bn(_conv(h, block["conv1"], stride, 1), block["bn1"]), si + 1)
                y = _bn(_conv(y, block["conv2"], 1, 1), block["bn2"])
            if "proj" in block:
                h = _bn(_conv(h, block["proj"], stride, 0), block["bn_proj"])
            h = _relu(h + y, si + 1)
    h = h.mean(axis=(2, 3))
    logits = h @ params["fc"]["w"] + params["fc"]["b"]
    return (logits, acts) if collect_acts else logits


def n_relu_groups(cfg: ResNetConfig) -> int:
    return 1 + len(cfg.stage_blocks)


def relu_group_elements(params, cfg: ResNetConfig, in_hw: int = 0) -> List[int]:
    """Activation counts per ReLU group for one sample (budget weighting)."""
    hw = in_hw or cfg.in_hw
    x = jnp.zeros((1, 3, hw, hw))
    counts = [0] * n_relu_groups(cfg)

    def counting_relu(v, g):
        counts[g] += int(v.size)
        return jax.nn.relu(v)

    _ = apply(params, x, cfg, relu_fn=counting_relu)
    return counts


# ---------------------------------------------------------------------------
# MPC path
# ---------------------------------------------------------------------------

def relu_plan(params, cfg: ResNetConfig, batch: int, hw: int = 0):
    """Shape-trace: (n_elements, group) per ReLU call, in call order.
    Drives offline TTP triple generation for the mesh serving step."""
    hw = hw or cfg.in_hw
    plan: List[Tuple[int, int]] = []

    def tracing_relu(v, g):
        plan.append((int(v.size), g))
        return jax.nn.relu(v)

    jax.eval_shape(lambda p, x: apply(p, x, cfg, relu_fn=tracing_relu),
                   params, jax.ShapeDtypeStruct((batch, 3, hw, hw), jnp.float32))
    return plan


def gen_mpc_triples(key, plan, hb: Optional[HBConfig], cfg: ResNetConfig,
                    cone: bool = False):
    """Offline TTP phase: one ReluTriples bundle per ReLU call (None for
    culled width-0 groups, which consume no triples)."""
    hb_layers = (hb.layers if hb is not None
                 else tuple(HBLayer() for _ in range(n_relu_groups(cfg))))
    keys = jax.random.split(key, len(plan))
    return [None if hb_layers[g].is_identity
            else beaver.gen_relu_triples(k, n, hb_layers[g].width, cone=cone)
            for k, (n, g) in zip(keys, plan)]


def _mpc_forward(params, hs: List[MPCTensor], cfg: ResNetConfig, relu_fn,
                 comm) -> List[MPCTensor]:
    """Shared MPC forward over sibling streams.

    ``relu_fn(tensors, group) -> tensors`` is invoked once per ReLU point
    with the sibling tensors of every stream, so implementations can share
    protocol rounds across streams (see mpc_apply_many)."""
    w, b = fold_bn(params["stem"], params["bn_stem"])
    hs = [h.conv2d_public(w, 1, 1).add_public(b[:, None, None], comm)
          for h in hs]
    hs = relu_fn(hs, 0)
    for si, stage in enumerate(params["stages"]):
        for block in stage:
            stride = 2 if ("proj" in block and si > 0) else 1
            if "conv3" in block:
                w1, b1 = fold_bn(block["conv1"], block["bn1"])
                ys = relu_fn([h.conv2d_public(w1, 1, 0)
                              .add_public(b1[:, None, None], comm)
                              for h in hs], si + 1)
                w2, b2 = fold_bn(block["conv2"], block["bn2"])
                ys = relu_fn([y.conv2d_public(w2, stride, 1)
                              .add_public(b2[:, None, None], comm)
                              for y in ys], si + 1)
                w3, b3 = fold_bn(block["conv3"], block["bn3"])
                ys = [y.conv2d_public(w3, 1, 0)
                      .add_public(b3[:, None, None], comm) for y in ys]
            else:
                w1, b1 = fold_bn(block["conv1"], block["bn1"])
                ys = relu_fn([h.conv2d_public(w1, stride, 1)
                              .add_public(b1[:, None, None], comm)
                              for h in hs], si + 1)
                w2, b2 = fold_bn(block["conv2"], block["bn2"])
                ys = [y.conv2d_public(w2, 1, 1)
                      .add_public(b2[:, None, None], comm) for y in ys]
            if "proj" in block:
                wp, bp = fold_bn(block["proj"], block["bn_proj"])
                hs = [h.conv2d_public(wp, stride, 0)
                      .add_public(bp[:, None, None], comm) for h in hs]
            hs = relu_fn([h + y for h, y in zip(hs, ys)], si + 1)
    hs = [h.global_avg_pool() for h in hs]
    return [h.matmul_public(params["fc"]["w"])
            .add_public(params["fc"]["b"], comm) for h in hs]


def mpc_apply(params, x: MPCTensor, cfg: ResNetConfig, key,
              hb: Optional[HBConfig] = None, comm=None,
              triples: Optional[list] = None, cone: bool = False) -> MPCTensor:
    """Secret-shared inference.  BN folded into convs; ReLU via GMW with
    the HummingBird (k, m) of each group.  When `triples` is given (mesh
    serving), they are consumed in call order; otherwise generated inline
    (sim backend)."""
    comm = comm or comm_lib.SimComm()
    hb_layers = (hb.layers if hb is not None
                 else tuple(HBLayer() for _ in range(n_relu_groups(cfg))))
    key_iter = iter(jax.random.split(key, 256))
    triple_iter = iter(triples) if triples is not None else None

    def _relu(ts: List[MPCTensor], g: int) -> List[MPCTensor]:
        tri = next(triple_iter) if triple_iter is not None else None
        return [ts[0].relu(next(key_iter), comm=comm, hb=hb_layers[g],
                           triples=tri, cone=cone)]

    return _mpc_forward(params, [x], cfg, _relu, comm)[0]


def mpc_apply_many(params, xs: Sequence[MPCTensor], cfg: ResNetConfig, key,
                   hb: Optional[HBConfig] = None, comm=None,
                   triples: Optional[list] = None,
                   cone: bool = False) -> List[MPCTensor]:
    """Round-fused serving: N sibling inference streams share ReLU rounds.

    Streams run the same weights but may differ in batch size or spatial
    resolution; at every ReLU point the sibling tensors are evaluated by
    ``nn.common.mpc_relu_many``, so the layer pays max-over-streams
    protocol rounds (one coalesced exchange per round) instead of the
    per-stream sum — the round-latency term of the serving cost drops by
    ~len(xs) while total bytes stay unchanged.

    ``triples`` keeps the offline TTP split: one entry per ReLU call (in
    call order, as produced by ``relu_plan``/``gen_mpc_triples`` for each
    stream), each a sequence with one ReluTriples bundle (or None for
    culled groups) per stream."""
    from repro.nn import common as nn_common

    comm = comm or comm_lib.SimComm()
    hb_layers = (hb.layers if hb is not None
                 else tuple(HBLayer() for _ in range(n_relu_groups(cfg))))
    key_iter = iter(jax.random.split(key, 256 * max(1, len(xs))))
    triple_iter = iter(triples) if triples is not None else None

    def _relu(ts: List[MPCTensor], g: int) -> List[MPCTensor]:
        tris = next(triple_iter) if triple_iter is not None else None
        keys = [next(key_iter) for _ in ts]
        return nn_common.mpc_relu_many(keys, ts, hbs=[hb_layers[g]] * len(ts),
                                       comm=comm, triples_list=tris,
                                       cone=cone)

    return _mpc_forward(params, list(xs), cfg, _relu, comm)
