"""Private LM inference end-to-end (PR 10 acceptance).

- the LM family resolves by registry name (configs.get) and its MPC
  forward by config type (resolve_mpc_forward);
- a traced plan carries 2 ReLU groups + 3 Beaver opens per gated layer,
  validates, and JSON round-trips at identical cost;
- one-block compile() forward matches the plaintext mpc_reference within
  fixed-point tolerance while the CoalescingComm-measured fused
  rounds/bytes equal the schedule prediction EXACTLY;
- scan and python round-loop backends are share-level bit-identical;
- LM requests serve through InferenceEngine.submit alongside ResNet
  requests, each micro-batch's measured economy == its prediction.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, configs
from repro.configs import RESNET_SMOKE
from repro.core import MPCTensor, comm as comm_lib, ring
from repro.core.hummingbird import HBConfig, HBLayer
from repro.models import lm, resnet
from repro.serve import InferenceEngine

SEQ = 4


@pytest.fixture(scope="module")
def lm_setup():
    cfg = dataclasses.replace(configs.get("qwen1.5-0.5b-smoke"), n_layers=1)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    h = jax.random.normal(jax.random.PRNGKey(1),
                          (1, SEQ, cfg.d_model)) * 0.5
    plan = lm.trace(params, cfg, 1, SEQ)
    return cfg, params, h, plan


def _lm_apply(cfg):
    def afn(p, x, relu_fn=None):
        return lm.mpc_reference(p, x, cfg, relu_fn=relu_fn)
    return afn


# ---------------------------------------------------------------------------
# Registry + registration (satellite 1)
# ---------------------------------------------------------------------------

def test_registry_resolves_lm_family():
    full = configs.get("qwen1.5-0.5b")
    assert full.family == "dense" and full.n_layers == 24
    assert "qwen1.5-0.5b" in configs.all_names()
    smoke = configs.get("qwen1.5-0.5b-smoke")
    assert smoke.n_layers <= 4 and smoke.d_model <= 128
    assert smoke.act == full.act == "silu"
    # the registered MPC forward resolves by config type, like ResNet's
    assert api.resolve_mpc_forward(smoke) is lm._lm_mpc_forward
    assert api.resolve_mpc_forward(RESNET_SMOKE) is not lm._lm_mpc_forward


def test_non_dense_family_rejected(lm_setup):
    cfg, params, h, _ = lm_setup
    moe = dataclasses.replace(cfg, family="moe")
    with pytest.raises(ValueError, match="dense"):
        lm.mpc_reference(params, h, moe)


# ---------------------------------------------------------------------------
# Plan structure
# ---------------------------------------------------------------------------

def test_plan_structure_and_json_roundtrip(lm_setup, tmp_path):
    cfg, params, _, plan = lm_setup
    # 2 ReLU groups per layer (attention scores + PWL MLP stack), 3 opens
    # per gated layer (QK^T, A@V, gate*up)
    assert len(plan.calls) == 2 * cfg.n_layers
    assert len(plan.opens) == 3 * cfg.n_layers
    assert [o.label for o in plan.opens] == ["matmul", "matmul", "mul"]
    plan.validate()
    path = tmp_path / "lm_plan.json"
    path.write_text(__import__("json").dumps(plan.to_json()))
    back = api.Plan.from_json(__import__("json").loads(path.read_text()))
    assert back.open_specs() == plan.open_specs()
    assert back.schedule().n_rounds == plan.schedule().n_rounds
    assert back.schedule().bytes_tx == plan.schedule().bytes_tx


# ---------------------------------------------------------------------------
# Acceptance: one-block closeness + measured == predicted
# ---------------------------------------------------------------------------

def test_one_block_compile_matches_plaintext_and_schedule(lm_setup):
    cfg, params, h, plan = lm_setup
    cc = comm_lib.CoalescingComm(comm_lib.CountingComm())
    model = api.compile(_lm_apply(cfg), params, cfg, plan,
                        api.Session(key=0, comm=cc))
    X = model.encrypt(jax.random.PRNGKey(2), h)
    out = model(X, key=jax.random.PRNGKey(3))
    ref = np.asarray(lm.mpc_reference(params, h, cfg))
    err = np.max(np.abs(out.reveal_np() - ref))
    assert err < 1e-2, err
    sched = plan.schedule()
    assert cc.n_rounds == sched.n_rounds
    assert cc.bytes_tx == sched.bytes_tx


def test_one_block_reduced_ring_close(lm_setup):
    """Per-site (k, m): attention scores keep more low bits than the PWL
    stack; the forward stays close to the plaintext reference."""
    cfg, params, h, plan = lm_setup
    layers = tuple(HBLayer(k=22, m=0) if g % 2 == 0 else HBLayer(k=22, m=6)
                   for g in range(plan.hb.n_groups))
    run_plan = plan.with_hb(HBConfig(layers, plan.hb.group_elements))
    assert run_plan.hb.budget_fraction() < 1.0
    model = api.compile(_lm_apply(cfg), params, cfg, run_plan,
                        api.Session(key=0))
    X = model.encrypt(jax.random.PRNGKey(2), h)
    out = model(X, key=jax.random.PRNGKey(3))
    ref = np.asarray(lm.mpc_reference(params, h, cfg))
    err = np.max(np.abs(out.reveal_np() - ref))
    assert err < 0.15, err
    # and the reduced plan is strictly cheaper than the exact one
    assert run_plan.schedule().n_rounds < plan.schedule().n_rounds


def test_one_block_scan_vs_python_bit_identity(lm_setup, monkeypatch):
    """The opens gate keeps LM replays on the eager path under both
    backends; the relu round loops themselves stay share-level
    bit-identical (ISSUE invariant: the generator loop is the
    reference)."""
    cfg, params, h, plan = lm_setup

    def run():
        model = api.compile(_lm_apply(cfg), params, cfg, plan,
                            api.Session(key=0))
        X = model.encrypt(jax.random.PRNGKey(2), h)
        return model(X, key=jax.random.PRNGKey(3))

    monkeypatch.setenv("HB_ROUND_LOOP", "python")
    ref = run()
    monkeypatch.setenv("HB_ROUND_LOOP", "scan")
    got = run()
    np.testing.assert_array_equal(ring.to_uint64_np(got.data),
                                  ring.to_uint64_np(ref.data))


# ---------------------------------------------------------------------------
# Acceptance: LM + ResNet through one serving story
# ---------------------------------------------------------------------------

def test_lm_served_alongside_resnet(lm_setup):
    cfg, params, h, plan = lm_setup
    lm_engine = InferenceEngine(_lm_apply(cfg), params, cfg, plan,
                                api.Session(key=0))
    r_params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)
    r_plan = resnet.trace(r_params, RESNET_SMOKE, batch=1, hw=16)

    def r_apply(p, x, relu_fn=None):
        return resnet.apply(p, x, RESNET_SMOKE, relu_fn=relu_fn)

    r_engine = InferenceEngine(r_apply, r_params, RESNET_SMOKE, r_plan,
                               api.Session(key=0))

    X_lm = MPCTensor.from_plain(jax.random.PRNGKey(2), h)
    x_img = jax.random.normal(jax.random.PRNGKey(4), (1, 3, 16, 16)) * 0.5
    X_img = MPCTensor.from_plain(jax.random.PRNGKey(5), x_img)

    f_lm = lm_engine.submit("alice", X_lm)
    f_img = r_engine.submit("alice", X_img)
    out_lm, out_img = f_lm.result(), f_img.result()

    ref_lm = np.asarray(lm.mpc_reference(params, h, cfg))
    assert np.max(np.abs(out_lm.reveal_np() - ref_lm)) < 1e-2
    ref_img = np.asarray(resnet.apply(r_params, x_img, RESNET_SMOKE))
    assert np.max(np.abs(out_img.reveal_np() - ref_img)) < 2e-2

    for eng in (lm_engine, r_engine):
        assert len(eng.reports) == 1
        rep = eng.reports[0]
        assert rep.n_requests == 1
        assert rep.measured_rounds == rep.predicted_rounds
        assert rep.measured_bytes == rep.predicted_bytes
    # the LM batch's economy includes its Beaver opens
    assert len(lm_engine.plan_for_shape((1, SEQ, cfg.d_model)).opens) == 3
