"""Pallas TPU kernels: fused GMW round-local compute on packed words.

Three fusion levels, all purely memory-bound (XOR/AND on uint32 planes),
so folding the op chain into one VMEM pass is the entire win (napkin:
6x HBM traffic -> 1x, bounded by 819 GB/s on v5e):

1. ``beaver_and_pallas`` — post-opening Beaver evaluation
       z = c ^ (d & b) ^ (e & a) ^ (sel & d & e)
   (sel = all-ones on party 0).

2. ``ks_mask_pallas`` — the *pre-exchange* half of one Kogge-Stone adder
   level: plane-shift of (g, p) by the level distance, lhs/rhs assembly
   ([p, p] and [g>>d, p>>d]) and Beaver triple masking (^a, ^b), one pass.
   Seed path: 2 shifts + 2 concats + 2 XORs = 6 HBM round-trips.

3. ``ks_combine_pallas`` — the *post-exchange* half: opening XOR with the
   peer's (d, e), Beaver evaluation, and the level combine
       g' = g ^ z[:w] ;  p' = z[w:]
   in one pass (seed path: 2 XORs + beaver chain + XOR + 2 slices).

Both ks kernels keep the full plane dimension in a single block (planes
<= 2w <= 128) and grid over (party, word-blocks), so the static plane
shift never crosses a block boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_U32 = jnp.uint32
BLOCK = (8, 256)  # (plane, word) VMEM tile; word dim multiple of 128 lanes
BLOCK_WORDS = 256  # word-dim tile of the full-plane ks kernels


def _beaver_and_kernel(d_ref, e_ref, a_ref, b_ref, c_ref, sel_ref, out_ref):
    d = d_ref[...]
    e = e_ref[...]
    z = c_ref[...] ^ (d & b_ref[...]) ^ (e & a_ref[...]) ^ (sel_ref[...] & d & e)
    out_ref[...] = z


def beaver_and_pallas(d_open, e_open, a, b, c, sel, *, interpret: bool = True,
                      block=BLOCK) -> jax.Array:
    """All inputs (P_planes, W) uint32, shapes padded to the block grid."""
    planes, words = d_open.shape
    grid = (planes // block[0], words // block[1])
    spec = pl.BlockSpec(block, lambda i, j: (i, j))
    return pl.pallas_call(
        _beaver_and_kernel,
        out_shape=jax.ShapeDtypeStruct((planes, words), _U32),
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=spec,
        interpret=interpret,
    )(d_open, e_open, a, b, c, sel)


def _ks_mask_kernel(g_ref, p_ref, a_ref, b_ref, d_ref, e_ref, *, shift):
    g = g_ref[0]                      # (w, bw)
    p = p_ref[0]
    zero = jnp.zeros((shift,) + g.shape[1:], g.dtype)
    g_sh = jnp.concatenate([zero, g[:-shift]], axis=0)
    p_sh = jnp.concatenate([zero, p[:-shift]], axis=0)
    lhs = jnp.concatenate([p, p], axis=0)       # (2w, bw)
    rhs = jnp.concatenate([g_sh, p_sh], axis=0)
    d_ref[0] = lhs ^ a_ref[0]
    e_ref[0] = rhs ^ b_ref[0]


def ks_mask_pallas(g, p, a, b, shift: int, *, interpret: bool = True,
                   block_words: int = BLOCK_WORDS):
    """Fused pre-exchange Kogge-Stone level pass.

    g, p: (P, w, W); a, b: (P, 2w, W) triple shares; static level shift.
    Returns (d, e), each (P, 2w, W):
        d = [p, p] ^ a ;  e = [g >> shift, p >> shift] ^ b
    """
    n_p, w, words = g.shape
    grid = (n_p, words // block_words)
    spec_w = pl.BlockSpec((1, w, block_words), lambda i, j: (i, 0, j))
    spec_2w = pl.BlockSpec((1, 2 * w, block_words), lambda i, j: (i, 0, j))
    return pl.pallas_call(
        functools.partial(_ks_mask_kernel, shift=shift),
        out_shape=(jax.ShapeDtypeStruct((n_p, 2 * w, words), _U32),
                   jax.ShapeDtypeStruct((n_p, 2 * w, words), _U32)),
        grid=grid,
        in_specs=[spec_w, spec_w, spec_2w, spec_2w],
        out_specs=(spec_2w, spec_2w),
        interpret=interpret,
    )(g, p, a, b)


def _ks_combine_kernel(d_ref, do_ref, e_ref, eo_ref, a_ref, b_ref, c_ref,
                       sel_ref, g_ref, g_out, p_out, *, w):
    d = d_ref[0] ^ do_ref[0]          # opened d          (2w, bw)
    e = e_ref[0] ^ eo_ref[0]          # opened e
    z = c_ref[0] ^ (d & b_ref[0]) ^ (e & a_ref[0]) ^ (sel_ref[0] & d & e)
    g_out[0] = g_ref[0] ^ z[:w]
    p_out[0] = z[w:]


def ks_combine_pallas(d, d_other, e, e_other, a, b, c, sel, g, *,
                      interpret: bool = True,
                      block_words: int = BLOCK_WORDS):
    """Fused post-exchange Kogge-Stone level pass.

    d/e are the local masked halves, d_other/e_other the peer's; a/b/c/sel
    (P, 2w, W) Beaver shares; g (P, w, W) the running generate plane.
    Returns (g', p') = (g ^ z[:, :w], z[:, w:]) with z the Beaver-AND.
    """
    n_p, w, words = g.shape
    grid = (n_p, words // block_words)
    spec_w = pl.BlockSpec((1, w, block_words), lambda i, j: (i, 0, j))
    spec_2w = pl.BlockSpec((1, 2 * w, block_words), lambda i, j: (i, 0, j))
    return pl.pallas_call(
        functools.partial(_ks_combine_kernel, w=w),
        out_shape=(jax.ShapeDtypeStruct((n_p, w, words), _U32),
                   jax.ShapeDtypeStruct((n_p, w, words), _U32)),
        grid=grid,
        in_specs=[spec_2w] * 8 + [spec_w],
        out_specs=(spec_w, spec_w),
        interpret=interpret,
    )(d, d_other, e, e_other, a, b, c, sel, g)


def _ks_level_kernel(g_ref, zg_ref, zp_ref, g_out, p_out):
    g_out[...] = g_ref[...] ^ zg_ref[...]
    p_out[...] = zp_ref[...]


def ks_level_pallas(g, z_g, z_p, *, interpret: bool = True, block=BLOCK):
    """Fused Kogge-Stone level combine: returns (g ^ z_g, z_p)."""
    planes, words = g.shape
    grid = (planes // block[0], words // block[1])
    spec = pl.BlockSpec(block, lambda i, j: (i, j))
    return pl.pallas_call(
        _ks_level_kernel,
        out_shape=(jax.ShapeDtypeStruct((planes, words), _U32),
                   jax.ShapeDtypeStruct((planes, words), _U32)),
        grid=grid,
        in_specs=[spec] * 3,
        out_specs=(spec, spec),
        interpret=interpret,
    )(g, z_g, z_p)
