"""Property tests for the paper's Theorem 1 and Theorem 2 on the real
GMW protocol (sim backend), via hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import beaver, comm as comm_lib, fixed, gmw, shares
from repro.core.hummingbird import safe_k

CM = comm_lib.SimComm()


def _relu_protocol(x_f, k, m, seed=0):
    E = x_f.shape[0]
    X = shares.share(jax.random.PRNGKey(seed), fixed.encode_np(x_f))
    tr = beaver.gen_relu_triples(jax.random.PRNGKey(seed + 1), E, k - m)
    R = gmw.relu(jax.random.PRNGKey(seed + 2), X, tr, CM, k=k, m=m)
    return fixed.decode_np(shares.reconstruct(R))


@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(min_value=-7.875, max_value=7.875, allow_nan=False,
                          width=32), min_size=4, max_size=32),
       st.integers(min_value=0, max_value=3))
def test_theorem1_high_bit_drop_exact(vals, seed):
    """|x| < 2^(k-1-16)  =>  reduced-ring ReLU == exact ReLU."""
    x = np.asarray(vals, np.float32)
    k = 20  # covers |x| < 8 at scale 2^16
    got = _relu_protocol(x, k=k, m=0, seed=seed)
    np.testing.assert_allclose(got, np.maximum(x, 0), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(min_value=-7.875, max_value=7.875, allow_nan=False,
                          width=32), min_size=4, max_size=32),
       st.integers(min_value=8, max_value=14),
       st.integers(min_value=0, max_value=3))
def test_theorem2_low_bit_drop_is_pruning(vals, m, seed):
    """Dropping m low bits == magnitude pruning below 2^(m-16) (with the
    documented +-1-LSB boundary band from the floor(x/2^m)-1 case)."""
    x = np.asarray(vals, np.float32)
    k = safe_k(int(np.ceil(np.max(np.abs(x)) * 2 ** 16)) + 1, m=m)
    got = _relu_protocol(x, k=k, m=m, seed=seed)
    thresh = 2.0 ** (m - 16)
    exact = np.maximum(x, 0.0)
    pruned = np.where((x > 0) & (x < thresh), 0.0, exact)
    ok = (np.abs(got - exact) < 1e-3) | (np.abs(got - pruned) < 1e-3)
    assert ok.all(), (x[~ok], got[~ok], m)


def test_theorem2_underflow_edge():
    """x at the negative edge of the reduced range with m > 0 flips sign
    (the proof's case (2)); one margin bit restores correctness."""
    x = np.asarray([-7.997, -7.94], np.float32)  # |x_int| ~ 2^19
    m = 14
    got_tight = _relu_protocol(x, k=20, m=m)     # range edge: flips to +
    assert (got_tight != 0).any()                # sign error observable
    got_margin = _relu_protocol(x, k=21, m=m)    # one headroom bit
    np.testing.assert_allclose(got_margin, 0.0, atol=1e-4)


def test_safe_k_accounts_for_truncation_headroom():
    assert safe_k(2 ** 19 - 1, m=0) == 20
    assert safe_k(2 ** 19 - 1, m=14) == 21  # +2^m pushes past 2^19


def test_rounds_match_formula():
    """gmw.n_rounds: prep + (1 + ceil(log2 w)) circuit + b2a + mult."""
    assert gmw.n_rounds(64) == 10
    assert gmw.n_rounds(8) == 7
    assert gmw.n_rounds(6) == 7
    assert gmw.n_rounds(4) == 6
    # paper Fig. 11: 1.12-1.56x round reduction; w=64 -> w=6 gives 1.43x
    assert 1.12 <= gmw.n_rounds(64) / gmw.n_rounds(6) <= 1.56
