"""Real two-party deployment over TCP sockets (PR 7).

Three layers:

- transport unit behaviour (in-process, two threads on localhost):
  handshake identity checks, swap round-trips, idempotent re-send
  (dup-drop + receive cache), resumable timeouts, byte accounting;
- full-stack parity (two threads): a private ResNet inference over
  ``Session.connect`` sockets is bit-identical to the single-process
  ``SimComm`` run on the same shares/triples, with measured wire bytes
  equal to the framed schedule prediction exactly and measured
  wall-clock under an injected RTT within the schedule's band;
- deployment (two OS subprocesses via ``launch/party_host``): bit-exact
  private inference from a job directory, and kill-a-party-mid-run →
  restart → journal-resume producing bit-identical outputs.
"""
import json
import os
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, errors
from repro.configs import RESNET_SMOKE
from repro.core import beaver, comm as comm_lib, faults as faults_lib
from repro.core.hummingbird import HBConfig, HBLayer
from repro.models import resnet
from repro.transport import (LinkShaper, SocketComm, free_port,
                             parse_address, write_job)

HOST = "127.0.0.1"


# ---------------------------------------------------------------------------
# helpers: a connected socket pair driven by two threads
# ---------------------------------------------------------------------------

def _pair(**kw):
    """A handshaken (party0, party1) SocketComm pair on localhost."""
    port = free_port()
    out = {}

    def _host():
        out[0] = SocketComm.host((HOST, port), party=0, **kw)

    t = threading.Thread(target=_host)
    t.start()
    out[1] = SocketComm.dial((HOST, port), party=1, **kw)
    t.join(10.0)
    return out[0], out[1]


def _run_parties(fn0, fn1, timeout_s=180.0):
    """Run one callable per party on its own thread; re-raise failures."""
    results, errs = {}, {}

    def _wrap(party, fn):
        try:
            results[party] = fn()
        except BaseException as e:       # noqa: BLE001 — surfaced below
            errs[party] = e

    threads = [threading.Thread(target=_wrap, args=(p, f))
               for p, f in ((0, fn0), (1, fn1))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout_s)
    if errs:
        raise next(iter(errs.values()))
    assert not any(t.is_alive() for t in threads), "party thread hung"
    return results[0], results[1]


def _smoke_plan():
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

    plan = api.trace_plan(afn, params, (2, 3, 8, 8), name="smoke")
    hb = HBConfig(tuple([HBLayer(k=21, m=13)] * (plan.n_groups - 1)
                        + [HBLayer(k=13, m=13)]),
                  plan.group_elements)
    return afn, params, plan.with_hb(hb)


# ---------------------------------------------------------------------------
# unit behaviour
# ---------------------------------------------------------------------------

def test_parse_address():
    assert parse_address("10.0.0.7:9100") == ("10.0.0.7", 9100)
    assert parse_address(":9100") == ("127.0.0.1", 9100)
    assert parse_address("example.org") == ("example.org", 9000)


def test_link_shaper_matches_schedule_pricing():
    from repro.api.plan import NETWORKS
    wan = NETWORKS["wan"]
    shaper = LinkShaper.from_preset(wan)
    n = 4096
    assert shaper.round_delay(n) == pytest.approx(
        wan.rtt_s + 2 * n * 8 / wan.bandwidth_bps)
    assert LinkShaper().round_delay(1 << 20) == 0.0


def test_swap_roundtrip_and_byte_accounting():
    s0, s1 = _pair(session="s", plan="p", timeout_s=10.0)
    payload = {
        0: {"a": jnp.arange(12, dtype=jnp.uint32).reshape(1, 3, 4),
            "b": jnp.full((1, 5), 7, jnp.uint32)},
        1: {"a": jnp.ones((1, 3, 4), jnp.uint32),
            "b": jnp.arange(5, dtype=jnp.uint32).reshape(1, 5)},
    }
    try:
        g0, g1 = _run_parties(lambda: s0.swap(payload[0]),
                              lambda: s1.swap(payload[1]))
        for got, want in ((g0, payload[1]), (g1, payload[0])):
            for k in ("a", "b"):
                np.testing.assert_array_equal(np.asarray(got[k]),
                                              np.asarray(want[k]))
        # payload-exact accounting: (12 + 5) uint32 words per direction,
        # envelopes tracked separately (1 HELLO + 1 DATA each so far)
        for s in (s0, s1):
            assert s.n_swaps == s.n_rounds == 1
            assert s.round_bytes == [17 * 4]
            assert s.bytes_tx == 17 * 4
            assert s.header_bytes == 2 * 16
            assert s.negotiated["resume_round"] == 0
    finally:
        s0.close()
        s1.close()


def test_swap_rejects_wrong_dtype_and_party_dim():
    s0, s1 = _pair(timeout_s=5.0)
    try:
        with pytest.raises(TypeError, match="uint32"):
            s0.swap(jnp.zeros((1, 3), jnp.int32))
        with pytest.raises(TypeError, match="party dim"):
            s0.swap(jnp.zeros((2, 3), jnp.uint32))
    finally:
        s0.close()
        s1.close()


def test_handshake_rejects_session_mismatch():
    port = free_port()
    errs = {}

    def _host():
        try:
            SocketComm.host((HOST, port), party=0, session="alpha",
                            timeout_s=5.0)
        except errors.HandshakeFailed as e:
            errs[0] = e

    t = threading.Thread(target=_host)
    t.start()
    with pytest.raises(errors.HandshakeFailed, match="session mismatch"):
        SocketComm.dial((HOST, port), party=1, session="beta", timeout_s=5.0)
    t.join(10.0)
    assert 0 in errs


def test_handshake_rejects_party_collision():
    port = free_port()
    errs = {}

    def _host():
        try:
            SocketComm.host((HOST, port), party=0, timeout_s=5.0)
        except errors.HandshakeFailed as e:
            errs[0] = e

    t = threading.Thread(target=_host)
    t.start()
    with pytest.raises(errors.HandshakeFailed, match="party"):
        SocketComm.dial((HOST, port), party=0, timeout_s=5.0)
    t.join(10.0)
    assert 0 in errs


def test_handshake_negotiates_journal_resume_round():
    s0, s1 = _pair_journals(journal_len_a=7, journal_len_b=4)
    try:
        assert s0.negotiated["resume_round"] == 4
        assert s1.negotiated["resume_round"] == 4
        assert s0.negotiated["peer_journal_len"] == 4
        assert s1.negotiated["peer_journal_len"] == 7
    finally:
        s0.close()
        s1.close()


def _pair_journals(journal_len_a, journal_len_b):
    port = free_port()
    out = {}

    def _host():
        out[0] = SocketComm.host((HOST, port), party=0,
                                 journal_len=journal_len_a, timeout_s=5.0)

    t = threading.Thread(target=_host)
    t.start()
    out[1] = SocketComm.dial((HOST, port), party=1,
                             journal_len=journal_len_b, timeout_s=5.0)
    t.join(10.0)
    return out[0], out[1]


def test_idempotent_resend_dup_drop_and_recv_cache():
    """A local retry of an already-delivered round must not deadlock: the
    re-send is dropped by the peer as a stale dup and the local receive is
    served from the cache — the ResilientComm recovery contract."""
    s0, s1 = _pair(timeout_s=10.0)
    x0 = jnp.arange(6, dtype=jnp.uint32).reshape(1, 6)
    x1 = jnp.arange(6, 12, dtype=jnp.uint32).reshape(1, 6)

    def party0():
        first = s0.swap(x0)
        s0._seq -= 1                     # simulate a ResilientComm retry
        again = s0.swap(x0)              # re-send + cached receive
        second = s0.swap(x0 + 100)
        return first, again, second

    def party1():
        a = s1.swap(x1)
        b = s1.swap(x1 + 100)            # receives the dup first: dropped
        return a, b

    try:
        (first, again, second), (a, b) = _run_parties(party0, party1)
        np.testing.assert_array_equal(np.asarray(first), np.asarray(x1))
        np.testing.assert_array_equal(np.asarray(again), np.asarray(x1))
        np.testing.assert_array_equal(np.asarray(second),
                                      np.asarray(x1 + 100))
        np.testing.assert_array_equal(np.asarray(b), np.asarray(x0 + 100))
        assert s1.dup_dropped == 1
        assert s0.n_swaps == 3           # the retry re-counts the round
    finally:
        s0.close()
        s1.close()


def test_resilient_comm_heals_real_socket_timeout():
    """Party 1's recv deadline is shorter than party 0's think time, so
    its first attempt times out mid-round; ResilientComm's idempotent
    re-send + the resumable receive buffer heal it without desyncing."""
    s0, s1 = _pair(timeout_s=10.0)
    s1._sock.settimeout(0.15)
    s1.timeout_s = 0.15
    r0 = comm_lib.ResilientComm(s0, max_retries=3)
    r1 = comm_lib.ResilientComm(s1, max_retries=10, backoff_s=0.01)
    x0 = jnp.arange(8, dtype=jnp.uint32).reshape(1, 8)
    x1 = jnp.arange(8, 16, dtype=jnp.uint32).reshape(1, 8)

    def party0():
        time.sleep(0.6)                  # stall past party 1's deadline
        return r0.swap(x0)

    try:
        g0, g1 = _run_parties(party0, lambda: r1.swap(x1))
        np.testing.assert_array_equal(np.asarray(g0)[0], np.asarray(x1)[0])
        np.testing.assert_array_equal(np.asarray(g1)[0], np.asarray(x0)[0])
        assert r1.retries >= 1
        assert r1.recovered == 1
        assert r1.faults_detected["timeout"] >= 1
    finally:
        s0.close()
        s1.close()


def test_injected_drop_heals_under_session_stack():
    """A FaultInjectingComm drop between the socket and ResilientComm (a
    lost send attempt) is healed by the retry budget; both parties finish
    with identical transcripts."""
    s0, s1 = _pair(timeout_s=10.0)
    plan = faults_lib.FaultPlan((faults_lib.FaultEvent(round=1,
                                                       kind="drop"),))
    r0 = comm_lib.ResilientComm(faults_lib.FaultInjectingComm(plan, s0),
                                max_retries=3, backoff_s=0.0)
    r1 = comm_lib.ResilientComm(s1, max_retries=3)

    def run(r, base):
        outs = []
        for i in range(3):
            outs.append(np.asarray(r.swap(
                jnp.full((1, 4), base + i, jnp.uint32))))
        return outs

    try:
        g0, g1 = _run_parties(lambda: run(r0, 100), lambda: run(r1, 200))
        for i in range(3):
            assert (g0[i] == 200 + i).all()
            assert (g1[i] == 100 + i).all()
        assert r0.retries == 1 and r0.recovered == 1
    finally:
        s0.close()
        s1.close()


# ---------------------------------------------------------------------------
# full-stack parity (threads): Session.connect + ResNet smoke inference
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_ref():
    """Reference single-process run + everything both parties need."""
    afn, params, plan = _smoke_plan()
    model = api.compile(afn, params, RESNET_SMOKE, plan, api.Session(key=0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 8, 8)) * 0.5
    X = model.encrypt(jax.random.PRNGKey(2), x)
    pool = beaver.gen_plan_triples(jax.random.PRNGKey(3),
                                   plan.triple_specs())
    ref_model = api.compile(afn, params, RESNET_SMOKE, plan,
                            api.Session(key=0,
                                        provider=beaver.TriplePool(pool)))
    want = ref_model(X, key=jax.random.PRNGKey(4))
    return dict(afn=afn, params=params, plan=plan, x=x, X=X, pool=pool,
                want=want)


def _connected_party(ref, party, port, *, shaper=None, journal=None,
                     timeout_s=60.0):
    from repro.core.mpc_tensor import MPCTensor
    from repro.core import ring
    plan = ref["plan"]
    session = api.Session.connect(
        party,
        listen=(HOST, port) if party == 0 else None,
        peer=(HOST, port) if party == 1 else None,
        key=0, session_id="smoke-test", plan_digest=plan.digest(),
        provider=beaver.TriplePool(
            beaver.slice_party_pool(ref["pool"], party)),
        journal=journal, shaper=shaper, timeout_s=timeout_s,
        handshake_timeout_s=60.0)
    model = api.compile(ref["afn"], ref["params"], RESNET_SMOKE, plan,
                        session)
    X = ref["X"]
    Xp = MPCTensor(ring.Ring64(X.data.lo[party:party + 1],
                               X.data.hi[party:party + 1]), X.frac_bits)
    out = model(Xp, key=jax.random.PRNGKey(4))
    return out, session


def test_socket_inference_bit_identical_and_bytes_framed(smoke_ref):
    """Acceptance: the two-party socket run reproduces the SimComm run
    bit-identically on the same shares/triples, and the measured wire
    bytes equal the framed schedule prediction exactly, round for
    round."""
    port = free_port()
    (out0, sess0), (out1, sess1) = _run_parties(
        lambda: _connected_party(smoke_ref, 0, port),
        lambda: _connected_party(smoke_ref, 1, port))
    try:
        want = smoke_ref["want"]
        lo = np.concatenate([out0.data.lo, out1.data.lo], 0)
        hi = np.concatenate([out0.data.hi, out1.data.hi], 0)
        np.testing.assert_array_equal(lo, np.asarray(want.data.lo))
        np.testing.assert_array_equal(hi, np.asarray(want.data.hi))

        framed = smoke_ref["plan"].schedule().framed()
        for sess in (sess0, sess1):
            sock = sess.transport
            assert sock.n_swaps == framed.n_rounds
            assert sock.round_bytes == list(framed.round_bytes)
    finally:
        sess0.transport.close()
        sess1.transport.close()


def test_socket_wall_clock_within_schedule_band_under_injected_rtt(
        smoke_ref):
    """Under an injected RTT the measured wall-clock is bounded below by
    the schedule's latency prediction (the shaper paces each round to
    exactly the predicted per-round cost) and above by a generous
    compute-inclusive band."""
    rtt_s = 0.004
    shaper = LinkShaper(rtt_s=rtt_s)
    framed = smoke_ref["plan"].schedule().framed()
    predicted = framed.latency(float("inf"), rtt_s)
    port = free_port()
    t0 = time.monotonic()
    (out0, sess0), (out1, sess1) = _run_parties(
        lambda: _connected_party(smoke_ref, 0, port, shaper=shaper),
        lambda: _connected_party(smoke_ref, 1, port, shaper=shaper))
    wall = time.monotonic() - t0
    try:
        assert predicted > 0
        assert wall >= predicted, (wall, predicted)
        assert wall <= 20 * predicted + 30.0, (wall, predicted)
    finally:
        sess0.transport.close()
        sess1.transport.close()


# ---------------------------------------------------------------------------
# deployment: two OS processes via launch/party_host + a job directory
# ---------------------------------------------------------------------------

def _write_smoke_job(job_dir, ref):
    write_job(job_dir, plan=ref["plan"], config="smoke", params_seed=0,
              infer_key=4, session_seed=0, x=ref["X"], pool=ref["pool"])


def _spawn_party(job_dir, party, port, *extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    link = (["--listen", f"{HOST}:{port}"] if party == 0
            else ["--peer", f"{HOST}:{port}"])
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.party_host",
         "--party", str(party), "--job", str(job_dir), *link, *extra],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)


def _wait(procs, timeout_s=600.0):
    outs = []
    for p in procs:
        try:
            out, err = p.communicate(timeout=timeout_s)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise
        outs.append((p.returncode, out, err))
    return outs


def _combined_out(job_dir):
    rows = []
    for p in (0, 1):
        with np.load(os.path.join(job_dir, f"out{p}.npz")) as z:
            rows.append((z["lo"], z["hi"]))
    return (np.concatenate([r[0] for r in rows], 0),
            np.concatenate([r[1] for r in rows], 0))


def test_two_process_inference_bit_identical_to_sim(smoke_ref, tmp_path):
    """Acceptance: two OS processes complete a private ResNet inference
    over localhost TCP, bit-identical to the single-process SimComm run
    on the same shares/triples, with wire bytes equal to the framed
    schedule on both sides."""
    job = tmp_path / "job"
    _write_smoke_job(job, smoke_ref)
    procs = [_spawn_party(job, 0, port := free_port()),
             _spawn_party(job, 1, port)]
    res = _wait(procs)
    for rc, out, err in res:
        assert rc == 0, (rc, out[-2000:], err[-4000:])
    lo, hi = _combined_out(job)
    want = smoke_ref["want"]
    np.testing.assert_array_equal(lo, np.asarray(want.data.lo))
    np.testing.assert_array_equal(hi, np.asarray(want.data.hi))
    framed = smoke_ref["plan"].schedule().framed()
    for p in (0, 1):
        stats = json.loads((job / f"stats{p}.json").read_text())
        assert stats["rounds"] == framed.n_rounds
        assert stats["payload_bytes"] == framed.bytes_tx
        assert stats["replayed"] == 0
        assert stats["retries"] == 0


def test_kill_party_mid_run_then_journal_resume(smoke_ref, tmp_path):
    """Acceptance: party 0 is hard-killed (os._exit, no cleanup) after 5
    live rounds; party 1 exits with the restart code; both relaunch with
    the same arguments and resume from their journals — replaying the
    negotiated common prefix without touching the wire — and the final
    outputs are bit-identical to an uninterrupted run."""
    job = tmp_path / "job"
    _write_smoke_job(job, smoke_ref)
    j0, j1 = str(tmp_path / "j0"), str(tmp_path / "j1")
    port = free_port()
    procs = [_spawn_party(job, 0, port, "--journal", j0,
                          "--die-after-round", "5"),
             _spawn_party(job, 1, port, "--journal", j1)]
    res = _wait(procs)
    assert res[0][0] == 42, res[0]            # the simulated kill -9
    assert res[1][0] == 17, res[1]            # restartable peer-crash exit

    port = free_port()
    procs = [_spawn_party(job, 0, port, "--journal", j0),
             _spawn_party(job, 1, port, "--journal", j1)]
    res = _wait(procs)
    for rc, out, err in res:
        assert rc == 0, (rc, out[-2000:], err[-4000:])
    lo, hi = _combined_out(job)
    want = smoke_ref["want"]
    np.testing.assert_array_equal(lo, np.asarray(want.data.lo))
    np.testing.assert_array_equal(hi, np.asarray(want.data.hi))
    framed = smoke_ref["plan"].schedule().framed()
    for p in (0, 1):
        stats = json.loads((job / f"stats{p}.json").read_text())
        # journals negotiated to the common 5-round prefix: both parties
        # replayed exactly those rounds and ran the rest live
        assert stats["resume_round"] == 5
        assert stats["replayed"] == 5
        assert stats["rounds"] == framed.n_rounds - 5
