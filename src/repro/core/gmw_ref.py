"""Frozen pre-fusion GMW reference (the seed implementation).

This module preserves the original one-``swap``-per-call protocol exactly
as it shipped before the round-fused engine landed in ``core/gmw.py``:
each Kogge-Stone level's opening is its own exchange, the cone-pruned path
uses runtime ``.at[].set`` scatters, and per-round local compute is a chain
of separate jnp ops.

It exists for two reasons:
  1. regression oracle — tests/test_fused_engine.py asserts the fused
     engine's outputs are *bit-identical* to this module for the exact
     (k=64, m=0) path and the reduced-ring configs;
  2. benchmark baseline — benchmarks/run.py --quick measures the fused
     engine's swap-count and wall-clock improvement against this path.

Do not optimise this file; it is intentionally the "before" snapshot.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import beaver, ring, shares
from .gmw import cone_sets

_U32 = jnp.uint32


def and_open(x, y, triple: beaver.BinTriple, comm) -> jax.Array:
    """z = x & y on XOR-shared packed words. One swap (round) of (d, e)."""
    from repro.kernels import ops as kops  # lazy: kernels import core.ring

    d = x ^ triple.a
    e = y ^ triple.b
    opened = comm.swap(jnp.stack([d, e], axis=1))  # single exchange
    d_open = d ^ opened[:, 0]
    e_open = e ^ opened[:, 1]
    p0 = comm.party_is(0, x)
    sel = jnp.where(p0, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    return kops.beaver_and(d_open, e_open, triple.a, triple.b, triple.c, sel)


def _shift_planes(x: jax.Array, d: int) -> jax.Array:
    """Plane-axis shift: out[..., i, :] = x[..., i-d, :], zeros below."""
    if d == 0:
        return x
    pad = jnp.zeros(x.shape[:-2] + (d,) + x.shape[-1:], x.dtype)
    return jnp.concatenate([pad, x[..., :-d, :]], axis=-2)


def adder_msb(xw: jax.Array, yw: jax.Array, triples: beaver.ReluTriples,
              comm, w: int, cone: bool = False) -> jax.Array:
    """XOR shares of the MSB of (x + y mod 2^w) — seed implementation."""
    p0 = xw ^ yw                      # initial propagate (local)
    if w == 1:
        return p0[..., 0, :]
    L = beaver.n_levels(w)
    if not cone:
        g = and_open(xw, yw, triples.bin_init, comm)   # initial generate
        p = p0
        for lvl in range(L):
            d = 1 << lvl
            g_sh = _shift_planes(g, d)
            p_sh = _shift_planes(p, d)
            lhs = jnp.concatenate([p, p], axis=-2)          # (P, 2w, W)
            rhs = jnp.concatenate([g_sh, p_sh], axis=-2)
            tri = jax.tree_util.tree_map(lambda t: t[lvl], triples.bin_levels)
            out = and_open(lhs, rhs, tri, comm)             # one round
            g = g ^ out[..., :w, :]
            p = out[..., w:, :]
        return p0[..., w - 1, :] ^ g[..., w - 2, :]

    init_pos, level_sets = cone_sets(w)
    ip = jnp.asarray(init_pos)
    g_sub = and_open(xw[..., ip, :], yw[..., ip, :], triples.bin_init, comm)
    g = jnp.zeros_like(xw).at[..., ip, :].set(g_sub)
    p = p0
    for lvl in range(L):
        d = 1 << lvl
        pos = level_sets[lvl]
        if not pos:
            continue
        ii = jnp.asarray(pos)
        im = jnp.asarray([i - d for i in pos])
        p_i = p[..., ii, :]
        lhs = jnp.concatenate([p_i, p_i], axis=-2)
        rhs = jnp.concatenate([g[..., im, :], p[..., im, :]], axis=-2)
        tri = triples.bin_levels[lvl]
        out = and_open(lhs, rhs, tri, comm)                 # one round
        n = len(pos)
        g = g.at[..., ii, :].set(g[..., ii, :] ^ out[..., :n, :])
        p = p.at[..., ii, :].set(out[..., n:, :])
    return p0[..., w - 1, :] ^ g[..., w - 2, :]


def a2b_prepare(key, v_packed: jax.Array, comm) -> Tuple[jax.Array, jax.Array]:
    r = jax.random.bits(key, v_packed.shape, dtype=_U32)
    masked = v_packed ^ r
    other_mask = comm.swap(r)
    p0 = comm.party_is(0, v_packed)
    x0_shares = jnp.where(p0, masked, other_mask)
    x1_shares = jnp.where(p0, other_mask, masked)
    return x0_shares, x1_shares


def beaver_mul(x: ring.Ring64, y: ring.Ring64, triple: beaver.ArithTriple,
               comm) -> ring.Ring64:
    e = ring.sub(x, triple.a)
    f = ring.sub(y, triple.b)
    ef = ring.Ring64(jnp.stack([e.lo, f.lo], 1), jnp.stack([e.hi, f.hi], 1))
    other = comm.swap(ef)                            # single exchange
    e_open = ring.add(e, ring.Ring64(other.lo[:, 0], other.hi[:, 0]))
    f_open = ring.add(f, ring.Ring64(other.lo[:, 1], other.hi[:, 1]))
    z = ring.add(triple.c,
                 ring.add(ring.mul(e_open, triple.b), ring.mul(f_open, triple.a)))
    p0 = comm.party_is(0, z.lo)
    corr = ring.mul(e_open, f_open)
    return ring.Ring64(jnp.where(p0, ring.add(z, corr).lo, z.lo),
                       jnp.where(p0, ring.add(z, corr).hi, z.hi))


def b2a_bit(bits: jax.Array, triple: beaver.ArithTriple, comm) -> ring.Ring64:
    zeros = jnp.zeros_like(bits)
    p0 = comm.party_is(0, bits)
    x = ring.Ring64(jnp.where(p0, bits, zeros), zeros)
    y = ring.Ring64(jnp.where(p0, zeros, bits), zeros)
    xy = beaver_mul(x, y, triple, comm)
    s = ring.add(ring.Ring64(bits, zeros), ring.neg(ring.lshift(xy, 1)))
    return s


def drelu(key, x: ring.Ring64, triples: beaver.ReluTriples, comm,
          k: int = 64, m: int = 0, cone: bool = False) -> ring.Ring64:
    w = k - m
    n = x.shape[-1]
    if w <= 32:
        v = ring.extract_bits(x, k, m)              # (P, E) uint32, local
        planes = ring.bitplanes_u32(v, w)           # (w, P, E)
    else:
        planes = ring.extract_planes(x, k, m)       # (w, P, E)
    planes = jnp.moveaxis(planes, 0, 1)             # (P, w, E)
    packed = shares.pack_bits(planes)               # (P, w, W)
    x0s, x1s = a2b_prepare(key, packed, comm)       # 1 round
    sign_packed = adder_msb(x0s, x1s, triples, comm, w, cone=cone)
    sign_bits = shares.unpack_bits(sign_packed, n)  # (P, E)
    s = b2a_bit(sign_bits, triples.b2a, comm)       # shares of sign in {0,1}
    one = ring.from_int32(jnp.ones((), jnp.int32))
    p0 = comm.party_is(0, s.lo)
    d = ring.Ring64(jnp.where(p0, ring.sub(one, s).lo, ring.neg(s).lo),
                    jnp.where(p0, ring.sub(one, s).hi, ring.neg(s).hi))
    return d


def relu(key, x: ring.Ring64, triples: beaver.ReluTriples, comm,
         k: int = 64, m: int = 0, cone: bool = False) -> ring.Ring64:
    d = drelu(key, x, triples, comm, k, m, cone=cone)
    return beaver_mul(x, d, triples.mult, comm)
