"""Architecture + shape configs; one module per assigned architecture."""
from .base import SHAPES, ArchConfig, ShapeConfig, shape_applicable, smoke_variant
from .registry import ARCHS, all_names, get
from .resnet import RESNET18, RESNET50, SMOKE as RESNET_SMOKE, ResNetConfig
__all__ = ["SHAPES", "ArchConfig", "ShapeConfig", "shape_applicable",
           "smoke_variant", "ARCHS", "all_names", "get", "RESNET18",
           "RESNET50", "RESNET_SMOKE", "ResNetConfig"]
