"""MPCTensor: the user-facing secret-shared tensor (CrypTen-equivalent).

Carries Ring64 additive shares with a leading party dimension plus the
fixed-point scale.  Linear ops with public weights are local (no
communication); ReLU runs the GMW protocol with an optional HummingBird
reduced-ring config.  The same object works on the sim backend (party dim
materialised) and inside shard_map on the mesh backend.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import beaver, comm as comm_lib, fixed, gmw, ring, ring_linalg, shares
from .hummingbird import HBLayer


def encode_weights(w_f, frac_bits: int = fixed.DEFAULT_FRAC_BITS) -> jax.Array:
    """Public float weights -> fixed-point int32 (|w * 2^f| < 2^31)."""
    return jnp.round(jnp.asarray(w_f, jnp.float32) * (2.0 ** frac_bits)).astype(jnp.int32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class MPCTensor:
    data: ring.Ring64            # shares, party dim leading
    frac_bits: int = fixed.DEFAULT_FRAC_BITS

    def tree_flatten(self):
        return (self.data,), self.frac_bits

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    # -- construction / reveal ------------------------------------------------
    @staticmethod
    def from_plain(key, x_f: jax.Array, n_parties: int = 2,
                   frac_bits: int = fixed.DEFAULT_FRAC_BITS) -> "MPCTensor":
        return MPCTensor(shares.share(key, fixed.encode(x_f, frac_bits), n_parties),
                         frac_bits)

    def reveal(self) -> jax.Array:
        return fixed.decode(shares.reconstruct(self.data), self.frac_bits)

    def reveal_np(self) -> np.ndarray:
        return fixed.decode_np(shares.reconstruct(self.data), self.frac_bits)

    @property
    def shape(self):
        return self.data.shape[1:]          # without the party dim

    # -- local linear ops ------------------------------------------------------
    def __add__(self, other: "MPCTensor") -> "MPCTensor":
        assert self.frac_bits == other.frac_bits
        return MPCTensor(ring.add(self.data, other.data), self.frac_bits)

    def __sub__(self, other: "MPCTensor") -> "MPCTensor":
        assert self.frac_bits == other.frac_bits
        return MPCTensor(ring.sub(self.data, other.data), self.frac_bits)

    def add_public(self, b_f, comm=None) -> "MPCTensor":
        """Add a public constant: only party 0 adds it to its share."""
        comm = comm or comm_lib.SimComm()
        enc = fixed.encode(jnp.broadcast_to(jnp.asarray(b_f, jnp.float32),
                                            self.shape), self.frac_bits)
        p0 = comm.party_is(0, self.data.lo)
        zero = ring.zeros(self.shape)
        lo = jnp.where(p0, enc.lo, zero.lo)
        hi = jnp.where(p0, enc.hi, zero.hi)
        return MPCTensor(ring.add(self.data, ring.Ring64(lo, hi)), self.frac_bits)

    def truncate(self, n: Optional[int] = None) -> "MPCTensor":
        """Fixed-point rescale: arithmetic shift of each signed share
        (SecureML-style local truncation, +-1 LSB error, rare wrap)."""
        n = self.frac_bits if n is None else n
        return MPCTensor(ring.rshift_arith(self.data, n), self.frac_bits)

    def mul_public(self, c_f) -> "MPCTensor":
        w = encode_weights(c_f, self.frac_bits)
        prod = ring.mul(self.data, ring.from_int32(jnp.broadcast_to(w, self.shape)))
        return MPCTensor(prod, self.frac_bits).truncate()

    def matmul_public(self, w_f: jax.Array) -> "MPCTensor":
        """x @ W with public float weights [K, N]; local + truncation."""
        w = encode_weights(w_f, self.frac_bits)
        prod = ring_linalg.matmul_pub(self.data, w)
        return MPCTensor(prod, self.frac_bits).truncate()

    def conv2d_public(self, w_f: jax.Array, stride: int = 1,
                      padding: int = 0) -> "MPCTensor":
        """NCHW conv with public float weights [Cout, Cin, kh, kw]."""
        w = encode_weights(w_f, self.frac_bits)
        prod = ring_linalg.conv2d_pub(self.data, w, stride, padding)
        return MPCTensor(prod, self.frac_bits).truncate()

    def avg_pool(self, window: int) -> "MPCTensor":
        """Non-overlapping average pooling on [..., C, H, W] (MPC-friendly
        replacement for max pooling, as in the paper's §2.3 setup)."""
        h, w = self.shape[-2], self.shape[-1]
        oh, ow = h // window, w // window

        def _pool(a):
            a = a[..., : oh * window, : ow * window]
            a = a.reshape(a.shape[:-2] + (oh, window, ow, window))
            return a

        lo, hi = _pool(self.data.lo), _pool(self.data.hi)
        acc = ring.zeros(lo.shape[:-4] + (oh, ow))
        for i in range(window):
            for j in range(window):
                acc = ring.add(acc, ring.Ring64(lo[..., :, i, :, j],
                                                hi[..., :, i, :, j]))
        summed = MPCTensor(acc, self.frac_bits)
        return summed.mul_public(1.0 / (window * window))

    def global_avg_pool(self) -> "MPCTensor":
        """[..., C, H, W] -> [..., C] mean over spatial dims."""
        h, w = self.shape[-2], self.shape[-1]
        flat = self.data.reshape(self.data.shape[:-2] + (h * w,))
        acc = ring.zeros(flat.shape[:-1])
        for i in range(h * w):
            acc = ring.add(acc, flat[..., i])
        return MPCTensor(acc, self.frac_bits).mul_public(1.0 / (h * w))

    def reshape(self, *shape) -> "MPCTensor":
        return MPCTensor(self.data.reshape((self.data.shape[0],) + tuple(shape)),
                         self.frac_bits)

    def transpose(self, *perm) -> "MPCTensor":
        """Permute the logical axes (the party dim stays leading)."""
        if len(perm) == 1 and isinstance(perm[0], (tuple, list)):
            perm = tuple(perm[0])
        nd = len(self.shape)
        p = (0,) + tuple(a % nd + 1 for a in perm)
        return MPCTensor(ring.Ring64(jnp.transpose(self.data.lo, p),
                                     jnp.transpose(self.data.hi, p)),
                         self.frac_bits)

    def swapaxes(self, a1: int, a2: int) -> "MPCTensor":
        nd = len(self.shape)
        perm = list(range(nd))
        perm[a1 % nd], perm[a2 % nd] = perm[a2 % nd], perm[a1 % nd]
        return self.transpose(*perm)

    def repeat(self, reps: int, axis: int) -> "MPCTensor":
        """``jnp.repeat`` along a logical axis (public structural op)."""
        ax = axis % len(self.shape) + 1
        return MPCTensor(ring.Ring64(jnp.repeat(self.data.lo, reps, axis=ax),
                                     jnp.repeat(self.data.hi, reps, axis=ax)),
                         self.frac_bits)

    def __getitem__(self, idx) -> "MPCTensor":
        """Index/slice the logical axes (party dim untouched)."""
        if not isinstance(idx, tuple):
            idx = (idx,)
        full = (slice(None),) + idx
        return MPCTensor(ring.Ring64(self.data.lo[full], self.data.hi[full]),
                         self.frac_bits)

    # -- secret * secret products ---------------------------------------------
    def mul(self, other: "MPCTensor", key, comm=None,
            triple: Optional[beaver.ArithTriple] = None) -> "MPCTensor":
        """Elementwise secret*secret product (one Beaver open round)."""
        return products_many(["mul"], [key], [self], [other], comm=comm,
                             triples_list=[triple])[0]

    def matmul(self, other: "MPCTensor", key, comm=None,
               triple: Optional[beaver.ArithTriple] = None) -> "MPCTensor":
        """Secret@secret matmul (one matrix-Beaver open round)."""
        return products_many(["matmul"], [key], [self], [other], comm=comm,
                             triples_list=[triple])[0]

    # -- the nonlinear op ------------------------------------------------------
    def relu(self, key, comm=None, hb: HBLayer = HBLayer(),
             triples: Optional[beaver.ReluTriples] = None,
             cone: bool = False) -> "MPCTensor":
        """GMW ReLU; `hb` selects the HummingBird reduced ring (k, m);
        cone=True uses the MSB-cone-pruned adder (beyond-paper).  A width-0
        `hb` (k == m) is the paper's culling mode: ReLU degrades to the
        identity at zero communication."""
        if hb.is_identity:
            return self
        comm = comm or comm_lib.SimComm()
        n = int(np.prod(self.shape))
        flat = self.data.reshape((self.data.shape[0], n))
        if triples is None:
            kt, key = jax.random.split(key)
            triples = beaver.gen_relu_triples(kt, n, hb.width,
                                              n_parties=self.data.shape[0],
                                              cone=cone)
        out = gmw.relu(key, flat, triples, comm, k=hb.k, m=hb.m, cone=cone)
        out = out.reshape((self.data.shape[0],) + tuple(self.shape))
        return MPCTensor(out, self.frac_bits)


def relu_many(keys, tensors: Sequence["MPCTensor"], comm=None,
              hbs: Optional[Sequence[HBLayer]] = None,
              triples_list: Optional[Sequence] = None,
              cone: bool = False, auto_batch: bool = True,
              loop: str = "python") -> list:
    """Round-shared GMW ReLU over sibling MPCTensors.

    All tensors advance through the protocol in lockstep; each round's
    payloads are coalesced into ONE exchange (comm.CoalescingComm), so the
    layer pays max-over-groups rounds instead of the per-tensor sum, with
    no byte increase.  `keys[i]` is consumed exactly like
    ``tensors[i].relu(keys[i], ...)`` would, so ragged groups stay
    bit-identical to per-tensor evaluation.  With ``auto_batch`` (default)
    sibling tensors of identical (element count, k, m) are additionally
    merged into one batched protocol stream (see ``gmw.relu_many``) —
    revealed values unchanged, one payload per round instead of N.
    Identity (width-0) layers and empty tensors pass through.  ``loop``
    selects the round-loop backend (see ``gmw.relu_many`` /
    ``runtime.loop``); both backends are share-level bit-identical.
    """
    comm = comm or comm_lib.SimComm()
    n_t = len(tensors)
    hbs = list(hbs) if hbs is not None else [HBLayer()] * n_t
    triples_list = (list(triples_list) if triples_list is not None
                    else [None] * n_t)
    keys = list(keys)
    if not (len(keys) == n_t == len(hbs) == len(triples_list)):
        raise ValueError(
            f"relu_many: mismatched lengths keys={len(keys)} "
            f"tensors={n_t} hbs={len(hbs)} triples={len(triples_list)}")
    out: list = [None] * n_t
    flats, run_keys, tris, kms, order = [], [], [], [], []
    for i, (t, hb, key, tri) in enumerate(zip(tensors, hbs, keys,
                                              triples_list)):
        if hb.is_identity:
            out[i] = t
            continue
        n = int(np.prod(t.shape))
        if tri is None:
            kt, key = jax.random.split(key)
            tri = beaver.gen_relu_triples(kt, n, hb.width,
                                          n_parties=t.data.shape[0],
                                          cone=cone)
        flats.append(t.data.reshape((t.data.shape[0], n)))
        run_keys.append(key)
        tris.append(tri)
        kms.append((hb.k, hb.m))
        order.append(i)
    rets = gmw.relu_many(run_keys, flats, tris, comm, kms, cone=cone,
                         auto_batch=auto_batch, loop=loop)
    for j, i in enumerate(order):
        t = tensors[i]
        data = rets[j].reshape((t.data.shape[0],) + tuple(t.shape))
        out[i] = MPCTensor(data, t.frac_bits)
    return out


def stack(tensors: Sequence["MPCTensor"], axis: int = 0) -> "MPCTensor":
    """Stack sibling MPCTensors along a new *logical* axis."""
    fb = tensors[0].frac_bits
    assert all(t.frac_bits == fb for t in tensors)
    ax = axis % (len(tensors[0].shape) + 1) + 1
    lo = jnp.stack([t.data.lo for t in tensors], axis=ax)
    hi = jnp.stack([t.data.hi for t in tensors], axis=ax)
    return MPCTensor(ring.Ring64(lo, hi), fb)


def concat(tensors: Sequence["MPCTensor"], axis: int = 0) -> "MPCTensor":
    """Concatenate sibling MPCTensors along an existing *logical* axis."""
    fb = tensors[0].frac_bits
    assert all(t.frac_bits == fb for t in tensors)
    ax = axis % len(tensors[0].shape) + 1
    lo = jnp.concatenate([t.data.lo for t in tensors], axis=ax)
    hi = jnp.concatenate([t.data.hi for t in tensors], axis=ax)
    return MPCTensor(ring.Ring64(lo, hi), fb)


def products_many(kinds: Sequence[str], keys, xs: Sequence["MPCTensor"],
                  ys: Sequence["MPCTensor"], comm=None,
                  triples_list: Optional[Sequence] = None) -> list:
    """Round-shared secret*secret products over sibling MPCTensor pairs.

    ``kinds[i]`` selects ``"mul"`` (elementwise, equal shapes) or
    ``"matmul"`` (batched, contraction on the trailing pair) for pair i;
    every pair advances through its Beaver protocol in lockstep and the
    single open of each is coalesced into ONE protocol round
    (``gmw.products_many``).  ``keys[i]`` deterministically derives the
    pair's triple when ``triples_list`` leaves it None — the same
    inline-TTP convention as ``MPCTensor.relu``.  Products of two
    ``frac_bits`` operands carry ``2*frac_bits``; the results are locally
    truncated back, so each product costs one +-1 LSB truncation error.
    """
    comm = comm or comm_lib.SimComm()
    n_t = len(xs)
    triples_list = (list(triples_list) if triples_list is not None
                    else [None] * n_t)
    keys = list(keys)
    if not (len(kinds) == n_t == len(ys) == len(keys) == len(triples_list)):
        raise ValueError(
            f"products_many: mismatched lengths kinds={len(kinds)} "
            f"xs={n_t} ys={len(ys)} keys={len(keys)} "
            f"triples={len(triples_list)}")
    specs = []
    for kind, key, x, y, tri in zip(kinds, keys, xs, ys, triples_list):
        assert x.frac_bits == y.frac_bits
        if tri is None:
            n_parties = x.data.shape[0]
            if kind == "matmul":
                tri = beaver.gen_matmul(key, x.shape, y.shape,
                                        n_parties=n_parties)
            elif kind == "mul":
                assert x.shape == y.shape, (x.shape, y.shape)
                tri = beaver.gen_arith(key, x.shape, n_parties=n_parties)
            else:
                raise ValueError(f"unknown product kind {kind!r}")
        specs.append((kind, x.data, y.data, tri))
    rets = gmw.products_many(specs, comm)
    return [MPCTensor(r, x.frac_bits).truncate()
            for r, x in zip(rets, xs)]


MPCTensor.relu_many = staticmethod(relu_many)
MPCTensor.products_many = staticmethod(products_many)
MPCTensor.stack = staticmethod(stack)
