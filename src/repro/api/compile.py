"""compile(): lower (model, Plan, Session) into a callable PrivateModel.

The MPC forward of a model family is registered once
(``register_mpc_forward``) as a function
``forward(params, tensors, cfg, relu_fn, comm) -> tensors`` over sibling
``MPCTensor`` streams; ``compile`` resolves it from the model config's type
and returns a ``PrivateModel`` that replays the Plan: every ReLU call
draws its keys from the Session's PRNG stream and its Beaver triples from
the Session's ``TripleProvider``, and sibling streams share protocol
rounds through ``relu_many`` (one coalesced exchange per round).
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax

from repro import errors
from repro.core import beaver, comm as comm_lib, ring
from repro.core.mpc_tensor import MPCTensor, products_many, relu_many
from repro.runtime import loop as loop_lib
from .plan import Plan
from .session import Session

_MPC_FORWARDS: Dict[type, Callable] = {}

# Compiled whole-replay executables, shared across PrivateModel instances:
# the cache key pins the forward, the plan content (digest), the stream /
# params / payload signatures and the XLA options, so two models compiled
# from the same plan reuse one executable (tests and the serving engine
# construct models freely; XLA compilation is the expensive part).
_REPLAY_CACHE: Dict = {}


@dataclasses.dataclass
class _ReplayEntry:
    """One compiled replay: the AOT executable, the trace-time comm whose
    counters hold the measured round timeline, and the trace/compile cost
    split (surfaced in BENCH_relu.json by ``benchmarks/run.py --quick``)."""

    exe: Callable
    comm: "comm_lib.CoalescingComm"
    trace_s: float
    compile_s: float


def replay_cache_stats() -> List[Dict]:
    """Snapshot of every compiled replay built in this process: the
    trace/compile cost split and the fused round count each executable
    carries.  ``benchmarks/run.py --quick`` reports the sum as the
    engine's dispatch-overhead breakdown (trace + XLA compile happen once
    per signature; warm batches pay neither)."""
    return [{"trace_s": e.trace_s, "compile_s": e.compile_s,
             "n_rounds": e.comm.n_rounds} for e in _REPLAY_CACHE.values()]


def _xla_compiler_options() -> Optional[Dict[str, str]]:
    """``HB_XLA_OPT=<0-3>`` caps the XLA backend optimization level for
    the compiled replay (level 0 roughly halves CPU compile time for ~3x
    slower — still bit-identical — execution; useful when compile
    latency dominates, e.g. running the test suite on the scan backend).
    Unset: XLA's default pipeline."""
    lvl = os.environ.get("HB_XLA_OPT", "")
    if lvl in ("0", "1", "2", "3"):
        return {"xla_backend_optimization_level": lvl}
    return None


def register_mpc_forward(cfg_type: type, forward: Callable) -> None:
    """Register the secret-shared forward for a model-config type.

    ``forward(params, tensors, cfg, relu_fn, comm)`` must evaluate the
    model on a list of sibling MPCTensor streams, calling
    ``relu_fn(tensors, group)`` at every ReLU point (the Plan replay hooks
    in there).

    Example::

        def my_forward(params, hs, cfg, relu_fn, comm):
            hs = [h.matmul_public(params["w1"]) for h in hs]
            hs = relu_fn(hs, 0)                     # ReLU group 0
            return [h.matmul_public(params["w2"]) for h in hs]

        register_mpc_forward(MyConfig, my_forward)
        # api.compile(..., cfg=MyConfig(...), ...) now resolves it
    """
    _MPC_FORWARDS[cfg_type] = forward


def resolve_mpc_forward(cfg) -> Callable:
    for klass in type(cfg).__mro__:
        if klass in _MPC_FORWARDS:
            return _MPC_FORWARDS[klass]
    # model modules register on import; pull the zoo in once before failing
    import repro.models  # noqa: F401
    for klass in type(cfg).__mro__:
        if klass in _MPC_FORWARDS:
            return _MPC_FORWARDS[klass]
    raise errors.UnregisteredModel(
        f"no MPC forward registered for {type(cfg).__name__}; call "
        "repro.api.register_mpc_forward or pass mpc_forward= to compile")


def compile(apply_fn, params, cfg, plan: Plan,
            session: Optional[Session] = None, *,
            mpc_forward: Optional[Callable] = None,
            auto_batch: bool = True) -> "PrivateModel":
    """Bind a model to a Plan and a Session for private inference.

    ``apply_fn(params, x, relu_fn=...)`` is the plaintext forward (kept for
    reference evaluation; may be None).  ``cfg`` is the model config whose
    type resolves the registered MPC forward unless ``mpc_forward`` is
    given explicitly.  ``auto_batch`` controls whether identical sibling
    streams merge into one batched protocol stream per ReLU call (the
    serving default; ``plan.schedule``/``cost``/``estimate`` price
    whichever mode is chosen).

    Example::

        model = api.compile(afn, params, RESNET_SMOKE, plan,
                            api.Session(key=0))
        X = model.encrypt(jax.random.PRNGKey(1), x)
        logits = model(X).reveal()          # private inference
    """
    if mpc_forward is None:
        mpc_forward = resolve_mpc_forward(cfg)
    return PrivateModel(apply_fn=apply_fn, params=params, cfg=cfg, plan=plan,
                        session=session if session is not None else Session(),
                        mpc_forward=mpc_forward, auto_batch=auto_batch)


@dataclasses.dataclass
class PrivateModel:
    """A model compiled for private inference under a Plan + Session.

    ``__call__`` accepts one MPCTensor or a sequence of sibling streams;
    streams share protocol rounds via ``relu_many`` (max-over-streams
    rounds per ReLU layer, one coalesced exchange per round).
    ``serve_step()`` lowers the same replay into a jit-able
    ``step(params, lo, hi, triples, key)`` — mesh-native (one
    collective-permute per fused round) when given a mesh with a party
    axis.

    Example::

        model = api.compile(afn, params, cfg, plan, api.Session(key=0))
        out = model(model.encrypt(key, x))          # one stream
        outs = model([X1, X2, X3])                  # rounds shared 3-way
        print(model.schedule(streams=3).gantt())    # predicted timeline
    """

    apply_fn: Optional[Callable]
    params: object
    cfg: object
    plan: Plan
    session: Session
    mpc_forward: Callable
    auto_batch: bool = True
    _step_cache: Dict = dataclasses.field(default_factory=dict, repr=False,
                                          compare=False)
    _layout_cache: Dict = dataclasses.field(default_factory=dict, repr=False,
                                            compare=False)

    # -- convenience ----------------------------------------------------------
    def encrypt(self, key, x_f) -> MPCTensor:
        """Secret-share a plaintext input."""
        return MPCTensor.from_plain(key, x_f)

    def plaintext(self, x_f, params=None):
        """Reference (non-private) forward, exact ReLU."""
        assert self.apply_fn is not None, "compiled without apply_fn"
        return self.apply_fn(params if params is not None else self.params, x_f)

    def estimate(self, *args, **kwargs) -> float:
        kwargs.setdefault("auto_batch", self.auto_batch)
        return self.plan.estimate(*args, **kwargs)

    def schedule(self, streams: int = 1):
        """Predicted fused-round timeline of one ``__call__`` replay with
        ``streams`` sibling inputs (see ``Plan.schedule``)."""
        return self.plan.schedule(streams=streams, auto_batch=self.auto_batch)

    # -- online phase ---------------------------------------------------------
    def __call__(self, xs: Union[MPCTensor, Sequence[MPCTensor]], *,
                 key=None) -> Union[MPCTensor, List[MPCTensor]]:
        single = isinstance(xs, MPCTensor)
        tensors = [xs] if single else list(xs)
        if key is None:
            key = self.session.next_key()
        outs = self._run(tensors, key, self.session.comm,
                         self.session.provider, self.params)
        return outs[0] if single else outs

    def _run(self, tensors: List[MPCTensor], key, comm, provider, params):
        """Replay the plan over sibling streams: one relu_many per ReLU
        call, keys consumed per stream in call order (bit-identical to the
        historical per-call `.relu` path for a single stream).  One shared
        key stream and one shared triple provider — the single-caller
        contract; the serving engine instead passes per-request streams
        through ``_run_streams``."""
        key_iter = iter(jax.random.split(key, 256 * max(1, len(tensors))))
        return self._run_streams(tensors, [key_iter] * len(tensors),
                                 [provider] * len(tensors), comm, params)

    def _run_streams(self, tensors: List[MPCTensor], key_iters: List,
                     providers: List, comm, params,
                     auto_batch: Optional[bool] = None):
        """Replay the plan with *per-stream* key iterators and triple
        providers (the cross-request serving path: stream i is request i,
        its keys fork from ``Session.request_key(request_id)`` and its
        triples are metered against its tenant).  At every ReLU call,
        stream i draws one key from ``key_iters[i]`` and one bundle from
        ``providers[i]`` — exactly what it would draw running alone, so
        with ``auto_batch=False`` the coalesced batch execution is
        bit-identical (share-level) to serial per-request execution on the
        same shares/triples; sibling streams still share every protocol
        round."""
        hb_layers = self.plan.hb.layers
        cone = self.plan.cone
        if auto_batch is None:
            auto_batch = self.auto_batch
        if (loop_lib.round_loop_mode() == "scan"
                and loop_lib.compiled_eligible(comm)
                and not getattr(self.plan, "opens", ())):
            # compiled round loop: the whole replay is ONE jitted program.
            # Plans with secret-product opens (LM attention/gating) stay on
            # the eager loop: their key draws interleave ReLU calls with
            # per-open draws, an order the pre-drawn payload can't express.
            return self._run_streams_compiled(tensors, key_iters, providers,
                                              comm, params, auto_batch)

        def _relu(hs: List[MPCTensor], g: int) -> List[MPCTensor]:
            hb = hb_layers[g]
            keys = [next(key_iters[i]) for i in range(len(hs))]
            tris = [providers[i].relu_triples(math.prod(h.shape), hb.width,
                                              cone=cone)
                    for i, h in enumerate(hs)]
            outs = list(hs)
            # zero-element streams (empty batch) have nothing to compute
            live = [i for i, h in enumerate(hs) if math.prod(h.shape)]
            if live:
                rets = relu_many([keys[i] for i in live],
                                 [hs[i] for i in live],
                                 comm=comm, hbs=[hb] * len(live),
                                 triples_list=[tris[i] for i in live],
                                 cone=cone, auto_batch=auto_batch)
                for j, i in enumerate(live):
                    outs[i] = rets[j]
            return outs

        # Secret-product hooks (see Plan.opens): stream i draws ONE key per
        # product site — independent of how many sibling streams run — and
        # derives its Beaver triple inline from it, so batched execution
        # stays share-level bit-identical to serial per-request execution.
        # All sibling opens coalesce into one protocol round.
        def _products(kind, xs, ys):
            keys = [next(key_iters[i]) for i in range(len(xs))]
            return products_many([kind] * len(xs), keys, xs, ys, comm=comm)

        _relu.matmul = lambda xs, ys: _products("matmul", xs, ys)
        _relu.mul = lambda xs, ys: _products("mul", xs, ys)

        return self.mpc_forward(params, tensors, self.cfg, _relu, comm)

    # -- compiled round loop --------------------------------------------------
    def _stream_sig(self, tensors: Sequence[MPCTensor], auto_batch: bool):
        return (auto_batch,) + tuple(
            (tuple(t.shape), t.frac_bits) for t in tensors)

    def _relu_layout(self, tensors: Sequence[MPCTensor], auto_batch: bool):
        """Per-ReLU-call (group, per-stream element counts) of one replay,
        in call order — recorded from an abstract (``jax.eval_shape``)
        pass of the forward, so the model is never executed.  This is what
        lets the compiled path draw every call's keys and triples *before*
        tracing: the stateful Python providers stay outside the program,
        in exactly the order the eager loop would have consumed them."""
        sig = self._stream_sig(tensors, auto_batch)
        # sig is public metadata (shapes + frac_bits) — not share data
        if sig not in self._layout_cache:  # hbcheck: disable=R003
            records: List = []

            def relu_rec(hs, g):
                records.append((g, tuple(math.prod(h.shape) for h in hs)))
                return hs

            stub = comm_lib.SimComm()
            jax.eval_shape(
                lambda p, ts: self.mpc_forward(p, list(ts), self.cfg,
                                               relu_rec, stub),
                self.params, tuple(tensors))
            self._layout_cache[sig] = tuple(records)
        return self._layout_cache[sig]

    def _plan_digest(self) -> str:
        if "digest" not in self._layout_cache:
            self._layout_cache["digest"] = self.plan.digest()
        return self._layout_cache["digest"]

    def _compiled_replay(self, sig, auto_batch: bool, params, tensors,
                         payload) -> _ReplayEntry:
        """The compiled whole-replay program for one stream signature.

        Keys and Beaver triples enter as program *inputs* (pre-drawn per
        call), never as baked constants; every ReLU layer runs
        ``relu_many(loop="scan")`` on a private ``CoalescingComm`` over
        ``SimComm``, so each fused round is one flipped exchange inside
        the program and the dense adder levels of solo streams collapse
        into ``lax.scan`` (carry buffers donated by XLA's loop
        double-buffering).  The private comm's Python counters fill once,
        at trace time; the entry keeps that comm so every *execution* can
        replay the measured timeline onto the caller's comm.

        AOT ``lower``/``compile`` (rather than plain ``jax.jit``) pins the
        executable to the cache key — everything that could change the
        trace (plan digest, stream/params/payload signatures, XLA
        options) is in the key, so one entry always maps to one trace and
        its counters stay exact — and records the trace-vs-compile cost
        split that ``benchmarks/run.py --quick`` reports.
        """
        opts = _xla_compiler_options()
        abstract = jax.tree_util.tree_map(
            lambda l: (jax.numpy.shape(l), jax.numpy.result_type(l).name),
            (params, payload))
        key = (self.mpc_forward, self._plan_digest(), sig, auto_batch,
               jax.tree_util.tree_structure((params, payload)),
               tuple(jax.tree_util.tree_leaves(abstract)),
               None if opts is None else tuple(sorted(opts.items())))
        if key in _REPLAY_CACHE:
            return _REPLAY_CACHE[key]
        hb_layers = self.plan.hb.layers
        cone = self.plan.cone
        cc = comm_lib.CoalescingComm()

        def replay(params, tensors, payload):
            calls = iter(payload)

            def _relu(hs, g):
                keys, tris = next(calls)
                outs = list(hs)
                live = [i for i, h in enumerate(hs) if math.prod(h.shape)]
                if live:
                    hb = hb_layers[g]
                    rets = relu_many([keys[i] for i in live],
                                     [hs[i] for i in live],
                                     comm=cc, hbs=[hb] * len(live),
                                     triples_list=[tris[i] for i in live],
                                     cone=cone, auto_batch=auto_batch,
                                     loop="scan")
                    for j, i in enumerate(live):
                        outs[i] = rets[j]
                return outs

            return self.mpc_forward(params, list(tensors), self.cfg,
                                    _relu, cc)

        t0 = time.perf_counter()
        lowered = jax.jit(replay).lower(params, tensors, payload)
        t1 = time.perf_counter()
        exe = (lowered.compile() if opts is None
               else lowered.compile(compiler_options=opts))
        t2 = time.perf_counter()
        entry = _ReplayEntry(exe=exe, comm=cc, trace_s=t1 - t0,
                             compile_s=t2 - t1)
        _REPLAY_CACHE[key] = entry
        return entry

    def _run_streams_compiled(self, tensors: List[MPCTensor], key_iters,
                              providers, comm, params, auto_batch: bool):
        """``_run_streams`` on the compiled round-loop backend.

        Same contract, same share-level outputs: stream i draws one key
        from ``key_iters[i]`` and one provider bundle per ReLU call in
        call order (so triple metering, pool positions, and retry
        rollback behave identically to the eager loop), then the cached
        compiled replay executes the entire online phase in one XLA call.
        The caller's ``CoalescingComm`` counters advance by the traced
        round timeline, keeping measured-vs-schedule accounting intact.
        """
        layout = self._relu_layout(tensors, auto_batch)
        hb_layers = self.plan.hb.layers
        cone = self.plan.cone
        payload = []
        for g, ns in layout:
            hb = hb_layers[g]
            keys = tuple(next(key_iters[i]) for i in range(len(tensors)))
            tris = tuple(providers[i].relu_triples(ns[i], hb.width, cone=cone)
                         for i in range(len(tensors)))
            payload.append((keys, tris))
        entry = self._compiled_replay(self._stream_sig(tensors, auto_batch),
                                      auto_batch, params, tuple(tensors),
                                      tuple(payload))
        outs = entry.exe(params, tuple(tensors), tuple(payload))
        if isinstance(comm, comm_lib.CoalescingComm):
            comm.replay_counters(entry.comm.n_rounds,
                                 list(entry.comm.round_bytes),
                                 list(entry.comm.round_parts))
        return outs

    def replay_stats(self, tensors: Sequence[MPCTensor],
                     auto_batch: Optional[bool] = None) -> Optional[Dict]:
        """Trace/compile cost split of the compiled replay for this stream
        signature, if one has been built (``benchmarks/run.py --quick``
        reports it as the dispatch-overhead breakdown)."""
        if auto_batch is None:
            auto_batch = self.auto_batch
        sig = self._stream_sig(list(tensors), auto_batch)
        for key, entry in _REPLAY_CACHE.items():
            # sig is public metadata (shapes + frac_bits), not share data
            if key[0] is self.mpc_forward and key[2] == sig:  # hbcheck: disable=R003
                return {"trace_s": entry.trace_s,
                        "compile_s": entry.compile_s,
                        "n_rounds": entry.comm.n_rounds}
        return None

    # -- mesh serving ---------------------------------------------------------
    def serve_step(self, mesh=None, *, party_axis: str = "party",
                   data_axis: Optional[str] = None) -> Callable:
        """step(params, lo, hi, triples, key) -> (lo, hi) logits shares.

        ``lo``/``hi`` are the Ring64 limbs of the input shares, shape
        (2, B, ...); ``triples`` is the offline pool (one bundle or None
        per ReLU call, see ``Plan.triple_specs``), entering as step inputs
        so the TTP material is party-sharded too.

        With ``mesh=None`` (legacy path) the replay runs on the session's
        comm with the party dimension materialised (``SimComm``) and the
        caller's in_shardings *hope* XLA shards each exchange sensibly.

        With a mesh carrying a ``party_axis``, the step is **mesh-native**:
        the fused replay executes inside ``shard_map`` over the party axis
        with ``CoalescingComm`` over a ``MeshComm`` base, so every fused
        protocol round of the whole network lowers to exactly ONE
        ``lax.ppermute`` of one flattened uint32 buffer — the compiled
        HLO's collective-permute census equals ``plan.schedule()``'s
        ``(n_rounds, round_bytes)`` prediction, collective for collective
        (asserted in tests/test_mesh_serving.py via
        ``runtime.hlo_analyzer.collective_census``).  The party axis may
        have size 2 (one device slice per non-colluding server) or size 1
        (``make_mpc_smoke_mesh``; both parties on one shard, exchanges
        stay local).  The mesh path requires an explicit triple pool —
        inline providers would have to conjure cross-party randomness
        inside a single party's shard.

        With ``data_axis``, the step additionally shards the *request
        batch* over that mesh axis (the ROADMAP data-axis item): lo/hi
        split their batch dimension, ``triples`` must be the data-sharded
        pool from ``beaver.shard_pool(pool, mesh.shape[data_axis])`` (each
        leaf carries a leading data-shard dim holding that shard's
        bit-level element slice), and every data shard runs an independent
        party-axis protocol on its batch rows — the per-shard HLO
        collective census is unchanged (same fused rounds, per-shard
        payloads) and the revealed outputs equal the unsharded replay's.

        Example::

            mesh = launch.mesh.make_mpc_mesh()        # (2, n_data)
            step = jax.jit(model.serve_step(mesh))
            lo, hi = step(params, X.data.lo, X.data.hi, pool, key)

            sharded = beaver.shard_pool(pool, mesh.shape["data"])
            step2 = jax.jit(model.serve_step(mesh, data_axis="data"))
            lo, hi = step2(params, X.data.lo, X.data.hi, sharded, key)
        """
        if mesh is None:
            def step(params, lo, hi, triples, key):
                x = MPCTensor(ring.Ring64(lo, hi))
                provider = (beaver.TriplePool(triples) if triples is not None
                            else self.session.provider)
                out = self._run([x], key, self.session.comm, provider,
                                params)[0]
                return out.data.lo, out.data.hi

            return step

        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec

        if party_axis not in mesh.axis_names:
            raise ValueError(
                f"mesh axes {mesh.axis_names} carry no {party_axis!r} axis")
        if data_axis is not None and data_axis not in mesh.axis_names:
            raise ValueError(
                f"mesh axes {mesh.axis_names} carry no {data_axis!r} axis")
        axis_size = mesh.shape[party_axis]

        def _replay(params, lo, hi, triples, key):
            comm = comm_lib.CoalescingComm(
                comm_lib.MeshComm(party_axis, axis_size))
            if data_axis is not None:
                # sharded pool: strip the (local size 1) data-shard dim
                triples = jax.tree_util.tree_map(lambda a: a[0], triples)
            x = MPCTensor(ring.Ring64(lo, hi))
            out = self._run([x], key, comm, beaver.TriplePool(triples),
                            params)[0]
            return out.data.lo, out.data.hi

        def step(params, lo, hi, triples, key):
            if triples is None:
                raise ValueError(
                    "mesh-native serve_step needs an offline triple pool "
                    "(beaver.gen_plan_triples(key, plan.triple_specs()))")
            share = (PartitionSpec(party_axis, data_axis) if data_axis
                     else PartitionSpec(party_axis))
            rep = PartitionSpec()
            fused = shard_map(
                _replay, mesh=mesh,
                in_specs=(rep, share, share,
                          beaver.pool_party_specs(triples, party_axis,
                                                  data_axis=data_axis), rep),
                out_specs=(share, share), check_rep=False)
            return fused(params, lo, hi, triples, key)

        return step

    def jit_step(self, mesh=None, *, party_axis: str = "party",
                 data_axis: Optional[str] = None) -> Callable:
        """Cached-lowering serve path: ``serve_step`` built once per
        (mesh, party_axis, data_axis) and — on the mesh backend — wrapped
        in ``jax.jit`` so repeated calls reuse the compiled executable
        (jax's own trace cache then keys on the padded batch shape, which
        is why the serving engine buckets request shapes).  The sim path
        is returned unjitted — its triple providers are stateful Python —
        but on the default ``scan`` round-loop backend (``runtime/loop``)
        its inner replay runs through the cached compiled program anyway:
        providers draw outside the program, the online phase is one XLA
        call.
        """
        cache_key = (mesh, party_axis, data_axis)
        if cache_key not in self._step_cache:
            step = self.serve_step(mesh, party_axis=party_axis,
                                   data_axis=data_axis)
            self._step_cache[cache_key] = (
                jax.jit(step) if mesh is not None else step)
        return self._step_cache[cache_key]
