"""Async serving frontend + background pump + two-party engine link (PR 7).

- pump contract: with ``start_pump`` running, ``submit()`` alone makes
  progress (no caller ever drives ``poll``/``flush``), while both stay
  available as manual overrides;
- HTTP frontend: ``POST /infer`` secret-shares, executes, reveals;
  ``GET /healthz``/``/stats`` report engine + transport state;
- engine link: a two-process-style engine (leader over a real socket,
  follower replaying batch descriptors) resolves mixed-tenant requests
  bit-identically to the single-process SimComm engine on the same
  submissions.
"""
import json
import threading
import time
import urllib.request

import jax
import numpy as np
import pytest

from repro import api, errors, serve
from repro.configs import RESNET_SMOKE
from repro.core.hummingbird import HBConfig, HBLayer
from repro.models import resnet
from repro.transport import (EngineLink, free_port, serve_follower,
                             tenant_provider_factory)

HOST = "127.0.0.1"


@pytest.fixture(scope="module")
def smoke():
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

    plan = api.trace_plan(afn, params, (2, 3, 8, 8), name="smoke")
    hb = HBConfig(tuple([HBLayer(k=21, m=13)] * (plan.n_groups - 1)
                        + [HBLayer(k=13, m=13)]),
                  plan.group_elements)
    return afn, params, plan.with_hb(hb)


def _engine(smoke, **kw):
    afn, params, plan = smoke
    kw.setdefault("session", api.Session(key=0))
    kw.setdefault("provider_factory", tenant_provider_factory(0))
    return serve.InferenceEngine(afn, params, RESNET_SMOKE, plan, **kw)


def _x(seed, batch=2):
    return np.asarray(
        jax.random.normal(jax.random.PRNGKey(seed), (batch, 3, 8, 8)) * 0.5,
        np.float32)


# ---------------------------------------------------------------------------
# background pump
# ---------------------------------------------------------------------------

def test_pump_submit_alone_makes_progress(smoke):
    # reference: an identical engine driven manually — same request ids,
    # session seed and tenant streams, so outputs must be bit-identical
    ref = _engine(smoke)
    ref_futs = [ref.submit("alice", _x(10 + i)) for i in range(3)]
    ref.flush()
    ref_outs = [f.result() for f in ref_futs]

    engine = _engine(smoke)
    engine.start_pump(interval_s=0.002, max_wait_s=0.02)
    try:
        futs = [engine.submit("alice", _x(10 + i)) for i in range(3)]
        outs = [f.result(timeout_s=300.0) for f in futs]
        assert all(f.done for f in futs)
        assert engine.pending == 0
        assert engine.last_pump_error is None
        # the pump executed them (engine totals advanced without any
        # manual poll/flush from this thread)
        assert engine.stats()["requests"] == 3
        for out, want in zip(outs, ref_outs):
            np.testing.assert_array_equal(np.asarray(out.data.lo),
                                          np.asarray(want.data.lo))
            np.testing.assert_array_equal(np.asarray(out.data.hi),
                                          np.asarray(want.data.hi))
    finally:
        engine.stop_pump()
    assert not engine.pump_running


def test_pump_result_times_out_typed(smoke):
    engine = _engine(smoke)
    # a pump that can never execute: stop it immediately so the future
    # waits on an event nobody sets
    engine.start_pump(interval_s=10.0, max_wait_s=10.0)
    try:
        fut = engine.submit("alice", _x(20))
        with pytest.raises(errors.ResultTimeout):
            fut.result(timeout_s=0.05)
    finally:
        engine.stop_pump()
        engine.flush()                    # leave no dangling queue entries


def test_poll_and_flush_stay_manual_overrides(smoke):
    engine = _engine(smoke)
    assert not engine.pump_running
    f1 = engine.submit("alice", _x(30))
    assert engine.pending == 1
    engine.flush()                        # manual drive, no pump involved
    assert f1.done and engine.pending == 0
    # pump on: manual flush still serialises with it harmlessly
    engine.start_pump(interval_s=0.002, max_wait_s=5.0)
    try:
        f2 = engine.submit("bob", _x(31))
        engine.flush()
        assert f2.done
    finally:
        engine.stop_pump()


# ---------------------------------------------------------------------------
# HTTP frontend (SimComm engine — transport-free)
# ---------------------------------------------------------------------------

def _http(method, url, body=None, timeout=300.0):
    req = urllib.request.Request(
        url, method=method,
        data=json.dumps(body).encode() if body is not None else None)
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read().decode())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read().decode())


def test_frontend_http_roundtrip(smoke):
    engine = _engine(smoke)
    frontend = serve.Frontend(engine)
    host, port = frontend.serve_background(HOST, 0)
    base = f"http://{host}:{port}"
    try:
        status, health = _http("GET", f"{base}/healthz")
        assert status == 200 and health["ok"] and health["pump"]

        x = _x(40)
        status, resp = _http("POST", f"{base}/infer",
                             {"tenant": "alice", "x": x.tolist()})
        assert status == 200, resp
        # bit-identical to the same submission on an identical engine
        ref = _engine(smoke)
        want = ref.submit("alice", x, request_id=resp["id"]).result()
        ref.flush()
        np.testing.assert_array_equal(
            np.asarray(resp["y"], np.float32),
            np.asarray(want.reveal(), np.float32))
        assert resp["tenant"] == "alice"
        assert resp["batch"]["measured_rounds"] > 0

        status, stats = _http("GET", f"{base}/stats")
        assert status == 200
        assert stats["requests"] == 1
        assert stats["frontend_requests"] == 1
        assert "transport" not in stats          # SimComm engine

        status, resp = _http("GET", f"{base}/nope")
        assert status == 404
        status, resp = _http("POST", f"{base}/infer", {"tenant": "a"})
        assert status == 400 and "x" in resp["error"]
    finally:
        frontend.close()
    assert not engine.pump_running


# ---------------------------------------------------------------------------
# two-party engine link: leader + follower over a real socket
# ---------------------------------------------------------------------------

def test_engine_link_bit_identical_to_sim_engine(smoke):
    """Mixed-tenant submissions through the leader/follower split resolve
    to outputs bit-identical (share level) to the single-process SimComm
    engine on the same request ids/inputs/seeds."""
    afn, params, plan = smoke
    reqs = [("alice", _x(50)), ("bob", _x(51)), ("alice", _x(52))]

    # reference: single-process engine, full 2-party tensors throughout
    ref_engine = _engine(smoke)
    ref_futs = [ref_engine.submit(t, x) for t, x in reqs]
    ref_engine.flush()
    ref_outs = [f.result() for f in ref_futs]

    port = free_port()
    follower_done = {}

    def follower():
        session = api.Session.connect(
            1, peer=(HOST, port), key=0, session_id="link",
            plan_digest=plan.digest(), handshake_timeout_s=60.0,
            timeout_s=120.0)
        model = api.compile(afn, params, RESNET_SMOKE, plan, session)
        try:
            follower_done["served"] = serve_follower(
                session.transport, model,
                provider_factory=tenant_provider_factory(0, party=1))
        finally:
            session.transport.close()

    t = threading.Thread(target=follower)
    t.start()
    session = api.Session.connect(
        0, listen=(HOST, port), key=0, session_id="link",
        plan_digest=plan.digest(), handshake_timeout_s=60.0,
        timeout_s=120.0)
    engine = _engine(smoke, session=session,
                     provider_factory=tenant_provider_factory(0, party=0))
    link = EngineLink(engine)
    try:
        futs = [engine.submit(t_, x) for t_, x in reqs]
        engine.flush()
        outs = [f.result() for f in futs]
        for got, want in zip(outs, ref_outs):
            np.testing.assert_array_equal(np.asarray(got.data.lo),
                                          np.asarray(want.data.lo))
            np.testing.assert_array_equal(np.asarray(got.data.hi),
                                          np.asarray(want.data.hi))
    finally:
        link.shutdown()
        session.transport.close()
    t.join(60.0)
    assert not t.is_alive()
    assert follower_done.get("served", 0) >= 1
