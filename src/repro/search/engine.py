"""§4.1.2 search engine: HummingBird-eco and HummingBird-b.

HummingBird-eco: keep m = 0 and pick, per ReLU group, the smallest k with
zero sign-estimation error on the validation set (Theorem 1: k such that
-2^(k-1) <= x_int < 2^(k-1); searched in O(N) per group by validating
decreasing k until the outputs change).

HummingBird-b: DFS over per-group bit assignments with
  - locally-optimal (k, m): previous groups fixed to their found values,
    later groups optimistic (no bits dropped), enumerate the (k, m) pairs
    with k - m = assigned bits and keep the best validation accuracy;
  - Early stop 1: optimistic accuracy below the absolute threshold;
  - Early stop 2: optimistic accuracy below the best complete config;
  - Early stop 3: budget exceeded (bits weighted by group element counts).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hummingbird import HBConfig, HBLayer, RING_BITS, safe_k
from . import simulator


@dataclasses.dataclass
class SearchResult:
    config: HBConfig
    accuracy: float
    baseline_accuracy: float
    budget_fraction: float
    search_time_s: float
    nodes_visited: int
    nodes_pruned: int


def _eval(apply_fn, params, xs, ys, cfg, key):
    return simulator.evaluate_accuracy(apply_fn, params, xs, ys, cfg, key)


def search_eco(apply_fn, params, xs, ys, group_elements: Sequence[int],
               key, margin_bits: int = 1) -> SearchResult:
    """Zero-error config: per-group smallest k whose validation *outputs*
    are bit-identical to the exact model (the paper's eco criterion), m=0."""
    t0 = time.time()
    n_groups = len(group_elements)
    base_cfg = HBConfig.exact(group_elements)
    base_acc = _eval(apply_fn, params, xs, ys, base_cfg, key)
    ref_logits = apply_fn(params, xs, relu_fn=None)
    max_ints = simulator.max_activation_ints(apply_fn, params, xs, n_groups)

    def outputs_intact(cfg: HBConfig) -> bool:
        relu_fn = simulator.make_group_relu(cfg, key)
        logits = apply_fn(params, xs, relu_fn=relu_fn)
        return bool(jnp.array_equal(logits, ref_logits))

    layers = []
    nodes = 0
    for g in range(n_groups):
        k = safe_k(max_ints[g], m=0, margin_bits=margin_bits)
        # validate downward: shrink while the validation outputs are intact
        while k > 2:
            cand = list(layers) + [HBLayer(k=k - 1, m=0)] + \
                [HBLayer() for _ in range(n_groups - g - 1)]
            cfg = HBConfig(tuple(cand), tuple(group_elements))
            nodes += 1
            if not outputs_intact(cfg):
                break
            k -= 1
        layers.append(HBLayer(k=k, m=0))
    cfg = HBConfig(tuple(layers), tuple(group_elements))
    acc = _eval(apply_fn, params, xs, ys, cfg, key)
    return SearchResult(cfg, acc, base_acc, cfg.budget_fraction(),
                        time.time() - t0, nodes, 0)


def search_budget(apply_fn, params, xs, ys, group_elements: Sequence[int],
                  key, budget: float, *, acc_threshold_drop: float = 0.10,
                  bit_choices: Optional[Sequence[int]] = None,
                  max_k: int = 28) -> SearchResult:
    """HummingBird-b: budgeted DFS with locally-optimal (k, m)."""
    t0 = time.time()
    n_groups = len(group_elements)
    elements = np.asarray(group_elements, np.float64)
    total_bits = RING_BITS * elements.sum()
    base_cfg = HBConfig.exact(group_elements)
    base_acc = _eval(apply_fn, params, xs, ys, base_cfg, key)
    threshold = base_acc - acc_threshold_drop
    bit_choices = sorted(bit_choices or (4, 5, 6, 8, 10), reverse=True)

    best: dict = {"acc": -1.0, "layers": None}
    stats = {"visited": 0, "pruned": 0}

    def local_best(prefix: List[HBLayer], g: int, width: int):
        """Locally-optimal (k, m) with k - m = width for group g."""
        best_local = (None, -1.0)
        for k in range(width, max_k + 1):
            m = k - width
            cand = prefix + [HBLayer(k=k, m=m)] + \
                [HBLayer() for _ in range(n_groups - g - 1)]
            cfg = HBConfig(tuple(cand), tuple(group_elements))
            stats["visited"] += 1
            acc = _eval(apply_fn, params, xs, ys, cfg, key)
            if acc > best_local[1]:
                best_local = (HBLayer(k=k, m=m), acc)
        return best_local

    def dfs(prefix: List[HBLayer], g: int, bits_used: float):
        if g == n_groups:
            cfg = HBConfig(tuple(prefix), tuple(group_elements))
            acc = _eval(apply_fn, params, xs, ys, cfg, key)
            if acc > best["acc"]:
                best["acc"] = acc
                best["layers"] = tuple(prefix)
            return
        for width in bit_choices:
            new_bits = bits_used + width * elements[g]
            # Early stop 3: even zero bits for the rest exceeds the budget
            if new_bits > budget * total_bits:
                stats["pruned"] += 1
                continue
            layer, opt_acc = local_best(prefix, g, width)
            if opt_acc < threshold:            # Early stop 1
                stats["pruned"] += 1
                continue
            if opt_acc <= best["acc"]:         # Early stop 2
                stats["pruned"] += 1
                continue
            dfs(prefix + [layer], g + 1, new_bits)

    dfs([], 0, 0.0)
    if best["layers"] is None:
        # nothing met the budget+threshold; fall back to uniform smallest
        width = bit_choices[-1]
        best["layers"] = tuple(HBLayer(k=width + 13, m=13)
                               for _ in range(n_groups))
        best["acc"] = _eval(apply_fn, params, xs, ys,
                            HBConfig(best["layers"], tuple(group_elements)),
                            key)
    cfg = HBConfig(best["layers"], tuple(group_elements))
    return SearchResult(cfg, best["acc"], base_acc, cfg.budget_fraction(),
                        time.time() - t0, stats["visited"], stats["pruned"])
