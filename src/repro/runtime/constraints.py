"""In-model sharding hints.

XLA's sharding propagation gives up at scan carries (flash-attention
accumulators, layer-scan activations) and silently replicates — on the
16x16 mesh that replicated attention 16x over the model axis before these
hints existed (see EXPERIMENTS.md §Perf, iteration 1).  ``shard(x, ...)``
applies a with_sharding_constraint against the *context* mesh, dropping
any axis that is absent or does not divide the dimension, so model code
can state intent once and run unchanged on the 1-device smoke mesh, the
16x16 pod, and the 2x16x16 multi-pod mesh.

Axis aliases: "dp" expands to the data axes ("pod", "data"); "tp" to
"model".
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P


def context_mesh():
    try:
        import jax._src.mesh as mesh_lib  # jax 0.8: `with mesh:` resources
        env = mesh_lib.thread_resources.env.physical_mesh
        if not env.empty:
            return env
    except Exception:
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    return None


def _expand(axis, mesh) -> Tuple[str, ...]:
    if axis is None:
        return ()
    if axis == "dp":
        return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if axis == "tp":
        return ("model",) if "model" in mesh.axis_names else ()
    if isinstance(axis, (tuple, list)):
        out = ()
        for a in axis:
            out += _expand(a, mesh)
        return out
    return (axis,) if axis in mesh.axis_names else ()


def shard(x, *axes):
    """Constrain x's sharding; silently drops non-dividing/absent axes."""
    mesh = context_mesh()
    if mesh is None:
        return x
    sizes = dict(mesh.shape)
    spec = []
    for dim, axis in zip(x.shape, axes):
        names = _expand(axis, mesh)
        total = int(np.prod([sizes[n] for n in names])) if names else 1
        if names and total > 1 and dim % total == 0:
            spec.append(names if len(names) > 1 else names[0])
        else:
            spec.append(None)
    spec += [None] * (x.ndim - len(spec))
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def axis_divides(axis, dim: int) -> bool:
    """True if `dim` is divisible by the context-mesh size of `axis`."""
    mesh = context_mesh()
    if mesh is None:
        return False
    names = _expand(axis, mesh)
    if not names:
        return False
    total = int(np.prod([dict(mesh.shape)[n] for n in names]))
    return total > 1 and dim % total == 0
