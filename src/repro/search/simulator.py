"""§4.1.1 MPC simulator: plaintext inference with share-domain ReLU.

All layers except ReLU run a vanilla single-node forward; ReLU encodes to
the 2^64 ring, draws a random share split, drops bits per (k, m) and
evaluates the sign on the reduced ring — mathematically identical to the
full GMW outcome (including the floor(x/2^m)-1 off-by-one and underflow
cases) but with zero protocol/communication cost, so the search engine can
score thousands of configurations quickly.
"""
from __future__ import annotations

import functools
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import fixed, ring
from repro.core.hummingbird import HBConfig, HBLayer


def simulated_hb_relu(x: jax.Array, k: int, m: int, key) -> jax.Array:
    """ReLU(x) with the sign estimated on the reduced ring <x>[k:m]."""
    if k == m:            # width 0: the culled layer degrades to identity
        return x
    if k >= 64 and m == 0:
        return jax.nn.relu(x)
    enc = fixed.encode(x)
    s0 = ring.uniform(key, x.shape)
    s1 = ring.sub(enc, s0)
    w = k - m
    if w <= 32:
        v0 = ring.extract_bits(s0, k, m)
        v1 = ring.extract_bits(s1, k, m)
        total = v0 + v1  # uint32 wraps; reduce mod 2^w
        mask = jnp.uint32(0xFFFFFFFF) if w == 32 else jnp.uint32((1 << w) - 1)
        total = total & mask
        sign = (total >> (w - 1)) & jnp.uint32(1)
    else:
        r0 = ring.rshift_logical(s0, m)
        r1 = ring.rshift_logical(s1, m)
        total = ring.add(r0, r1)
        sign = ring.bit(total, w - 1)
    drelu = (1 - sign).astype(x.dtype)
    return x * drelu


def make_group_relu(cfg: HBConfig, key) -> Callable:
    """relu_fn(x, group) for models whose apply() takes a pluggable ReLU."""
    keys = jax.random.split(key, max(cfg.n_groups, 1))

    def relu_fn(x, group):
        layer = cfg.layers[group]
        return simulated_hb_relu(x, layer.k, layer.m, keys[group])

    return relu_fn


def evaluate_accuracy(apply_fn, params, xs, ys, cfg: HBConfig, key,
                      batch: int = 256) -> float:
    """Top-1 accuracy of the simulated approximate model."""
    relu_fn = make_group_relu(cfg, key)
    n = xs.shape[0]
    correct = 0
    for i in range(0, n, batch):
        logits = apply_fn(params, xs[i:i + batch], relu_fn=relu_fn)
        correct += int((jnp.argmax(logits, -1) == ys[i:i + batch]).sum())
    return correct / n


def config_objective(cfg: HBConfig, calls: Sequence[Tuple[int, int]],
                     objective: str = "bytes",
                     bandwidth_bps: float = None, rtt_s: float = None,
                     streams: int = 1, cone: bool = False) -> float:
    """Schedule-predicted serving score of an HBConfig.

    ``calls``: the replay's ReLU call sites as (n_elements, group) in call
    order (``Plan.calls`` flattened; one pseudo-call per group when only
    group element counts are known).  Each call is one ``relu_many``
    lockstep whose ``streams`` sibling payloads auto-batch, exactly as the
    serving path executes — so ``objective="latency"`` scores what the
    replay actually pays (fused rounds * RTT + wire time under the given
    network), while ``objective="bytes"`` scores total wire bytes.
    """
    from repro.core import schedule as schedule_lib

    total = schedule_lib.Schedule.empty()
    for n, g in calls:
        layer = cfg.layers[g]
        total = total + schedule_lib.simulate(
            [(n, layer.width, (n, layer.k, layer.m))] * streams, cone=cone)
    if objective == "bytes":
        return float(total.bytes_tx)
    if objective == "latency":
        if bandwidth_bps is None or rtt_s is None:
            raise ValueError(
                "objective='latency' needs (bandwidth_bps, rtt_s)")
        return total.latency(bandwidth_bps, rtt_s)
    raise ValueError(f"unknown objective {objective!r} "
                     "(expected 'bytes' or 'latency')")


def max_activation_ints(apply_fn, params, xs, n_groups: int,
                        frac_bits: int = 16) -> List[int]:
    """Per-group max |round(x * 2^frac)| over the validation set — drives
    HummingBird-eco's zero-error k selection (Theorem 1)."""
    maxes = [0.0] * n_groups

    def relu_fn(x, g):
        maxes[g] = max(maxes[g], float(jnp.max(jnp.abs(x))))
        return jax.nn.relu(x)

    _ = apply_fn(params, xs, relu_fn=relu_fn)
    return [int(round(m * 2 ** frac_bits)) for m in maxes]
