"""Round-loop backend selection: compiled (`scan`) vs generator (`python`).

The protocol primitives in ``core/gmw.py`` are round generators driven by a
Python loop — one interpreter round-trip (and one device dispatch) per
protocol round.  That loop is the reference backend.  The compiled backend
lowers an entire plan replay into ONE jitted XLA program: the round
timeline is static (``core/schedule.py`` predicts it exactly), so the
generators trace straight through ``jax.jit`` and the dense Kogge-Stone
level segment of a solo stream additionally collapses into a genuine
``lax.scan`` over the stacked per-level triples (``gmw._adder_msb_scan``).

Backend choice:

- ``HB_ROUND_LOOP=scan``  (default): compiled fast path wherever the comm
  backend is compatible (see ``compiled_eligible``), generator loop
  elsewhere.
- ``HB_ROUND_LOOP=python``: generator loop everywhere — the reference
  backend CI runs the tier-1 suite against in addition to the default.

Eligibility: the compiled path bakes the exchange into the program, so the
comm stack must be pure compute with no per-round Python side effects —
exactly ``SimComm`` (local flip) or ``CoalescingComm`` directly over
``SimComm`` (its Python counters fill once at trace time and are
replayed onto the caller's comm by ``api/compile.py``).  Everything else —
``CountingComm``, ``ResilientComm``, ``JournaledComm``,
``FaultInjectingComm``, ``transport.SocketComm`` — needs to observe every
round from Python, and ``MeshComm`` already runs compiled inside
``shard_map`` (one ppermute per fused round; the HLO collective census is
the contract there), so all of those stay on the generator loop.
"""
from __future__ import annotations

import os

from repro.core import comm as comm_lib

_VALID = ("scan", "python")


def round_loop_mode() -> str:
    """The selected round-loop backend: ``"scan"`` (compiled, default) or
    ``"python"`` (generator reference).  Unknown values fall back to the
    default rather than erroring so a typo'd env var cannot take down a
    serving process."""
    mode = os.environ.get("HB_ROUND_LOOP", "scan")
    return mode if mode in _VALID else "scan"


def compiled_eligible(comm) -> bool:
    """True iff the whole replay may run inside one jitted program on this
    comm backend: exactly SimComm, or CoalescingComm directly over SimComm.
    Subclasses do NOT qualify — a wrapper that adds per-round Python
    behaviour (counters, framing, journaling, sockets) must see every
    round, which the compiled loop by construction does not re-enter
    Python for."""
    if type(comm) is comm_lib.SimComm:
        return True
    return (type(comm) is comm_lib.CoalescingComm
            and type(comm.base) is comm_lib.SimComm)
