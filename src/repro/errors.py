"""Typed error hierarchy for the whole stack.

Callers — above all the serving engine's batch-retry loop and any future
real transport — need to distinguish *retryable* failures (a transient
network fault that an idempotent re-send or a batch re-execution can
absorb) from *fatal* ones (a quota breach, a shape the model cannot
serve).  Every raise site in ``core.comm``/``core.faults``/
``core.beaver``/``api``/``serve`` goes through this module instead of
ad-hoc ``RuntimeError``/``ValueError``s.

Design rules:

- ``RetryableError`` marks transience; ``is_retryable(exc)`` is the one
  question the engine asks before re-running a batch.
- Errors that replaced a historical builtin raise also subclass that
  builtin (``ShapeMismatch`` is a ``ValueError``, ``UnregisteredModel`` a
  ``KeyError``, ``TripleBudgetExceeded`` a ``RuntimeError``), so existing
  ``except``/``pytest.raises`` call sites keep working.
- Request-scoped errors carry ``request_id``/``tenant`` attributes
  (``attach_request`` fills them in) so a failed future's exception
  identifies its origin without string parsing.

This module is import-light on purpose (stdlib only): ``core.comm`` and
``core.faults`` sit below every protocol module and import it.
"""
from __future__ import annotations

from typing import Optional


class ReproError(Exception):
    """Base of every typed error raised by this package.

    ``request_id``/``tenant`` are filled in by the serving engine when the
    error fails a request future (``attach_request``); None elsewhere.
    """

    request_id: Optional[int] = None
    tenant: Optional[str] = None


class RetryableError(ReproError):
    """Transient: an idempotent retry (re-send, batch re-execution) may
    succeed.  The engine's batch-retry loop keys off this marker."""


class FatalError(ReproError):
    """Deterministic: retrying the same operation will fail the same way."""


# ---------------------------------------------------------------------------
# Communication faults (core.comm.ResilientComm / core.faults)
# ---------------------------------------------------------------------------

class CommError(ReproError):
    """Base of every party-communication failure."""


class CommTimeout(CommError, RetryableError):
    """An exchange was dropped or stalled past the timeout.  Raised by
    ``ResilientComm`` only after its per-round retry budget is exhausted
    (and by ``FaultInjectingComm`` to *inject* the underlying fault)."""


class PayloadCorrupted(CommError, RetryableError):
    """A received frame failed checksum or round-sequence verification.
    Retryable: the re-send is idempotent, so a transient bit flip heals."""


class PartyCrashed(CommError):
    """The peer party is gone (crash at round r).  NOT retryable by a
    plain re-send — recovery is restart + round-level resume (see
    ``core.faults.RoundJournal``); the engine retries a crashed batch only
    when an ``on_party_crash`` hook revived the transport."""


class HandshakeFailed(CommError, ConnectionError):
    """The two party processes disagree on identity at connect time —
    party index collision, session-seed or plan-digest mismatch, protocol
    version skew, or no peer within the accept/connect budget.  Fatal by
    design: running the protocol across mismatched sessions would produce
    garbage shares, so ``repro.transport`` refuses to start."""


# ---------------------------------------------------------------------------
# Serving-engine request failures (repro.serve)
# ---------------------------------------------------------------------------

class DeadlineExceeded(FatalError):
    """The request provably cannot meet its deadline: shed before any
    protocol round burns triples (schedule-predicted, not measured)."""


class ResultTimeout(ReproError, TimeoutError):
    """``RequestFuture.result(timeout_s=...)`` expired before the engine
    resolved the request."""


class DuplicateRequest(FatalError, ValueError):
    """A request id was submitted twice to one engine."""


class ShapeMismatch(FatalError, ValueError):
    """An input shape the compiled model/plan cannot serve."""


class PlanInvalid(FatalError, ValueError):
    """A loaded/JSON Plan violates its schedule invariants (out-of-range
    (k, m), element/group accounting drift, round-conservation mismatch)
    — replaying it would desynchronize the parties or the triple budget,
    so ``Plan.validate()`` refuses it before any protocol round runs."""


class UnregisteredModel(FatalError, KeyError):
    """No MPC forward is registered for the model-config type."""

    def __str__(self) -> str:        # KeyError quotes its arg; keep prose
        return Exception.__str__(self)


# ---------------------------------------------------------------------------
# Triple-supply failures (core.beaver)
# ---------------------------------------------------------------------------

class TripleBudgetExceeded(FatalError, RuntimeError):
    """A metered tenant asked for more triple material than its budget."""


class TriplePoolExhausted(FatalError, RuntimeError):
    """A precomputed triple pool ran out of bundles mid-replay."""


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def is_retryable(exc: BaseException) -> bool:
    """Should an idempotent retry be attempted for this failure?"""
    return isinstance(exc, RetryableError)


def attach_request(exc: BaseException, request_id: int,
                   tenant: str) -> BaseException:
    """Stamp a failing request's identity onto its exception (best-effort:
    foreign exception types without writable attrs are left unchanged)."""
    try:
        exc.request_id = request_id
        exc.tenant = tenant
    except (AttributeError, TypeError):      # pragma: no cover - exotic exc
        pass
    return exc
