"""Per-arch smoke tests: reduced config, one forward / train / decode step
on CPU, asserting shapes and no NaNs (the brief's required smokes)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_names, get
from repro.launch import train as train_lib
from repro.models import encdec, lm
from repro.train import optimizer as opt_lib

B, S = 2, 32


def _inputs(cfg, key):
    fe = None
    s_tok = S
    if cfg.frontend != "none":
        fe = jax.random.normal(key, (B, cfg.n_frontend_tokens, cfg.d_model))
        s_tok = S - cfg.n_frontend_tokens
    tokens = jax.random.randint(key, (B, s_tok), 0, cfg.vocab)
    return tokens, fe


@pytest.mark.parametrize("name", all_names())
def test_forward_prefill_decode(name):
    cfg = get(name + "-smoke")
    key = jax.random.PRNGKey(0)
    if cfg.family == "encdec":
        params = encdec.init(key, cfg)
        src = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        tgt = jax.random.randint(key, (B, S), 0, cfg.vocab)
        logits = encdec.apply(params, src, tgt, cfg)
        assert logits.shape[:2] == (B, S)
        assert not bool(jnp.isnan(logits).any())
        cache = encdec.prefill(params, src, cfg, B, 16)
        lg, cache = encdec.decode_step(params, tgt[:, :1], cache, 0, cfg)
        assert lg.shape[:2] == (B, 1)
        assert not bool(jnp.isnan(lg).any())
        return
    params = lm.init(key, cfg)
    tokens, fe = _inputs(cfg, key)
    logits = lm.apply(params, tokens, cfg, frontend_embeds=fe)
    assert logits.shape[:2] == (B, S)
    assert not bool(jnp.isnan(logits).any())
    lg, cache = lm.prefill(params, tokens, cfg, max_len=S + 8,
                           frontend_embeds=fe)
    assert not bool(jnp.isnan(lg).any())
    lg2, cache = lm.decode_step(params, jnp.zeros((B, 1), jnp.int32), cache,
                                S, cfg)
    assert lg2.shape[:2] == (B, 1)
    assert not bool(jnp.isnan(lg2).any())


@pytest.mark.parametrize("name", all_names())
def test_one_train_step(name):
    cfg = get(name + "-smoke")
    cfg = dataclasses.replace(cfg, n_layers=2)
    opt = opt_lib.AdamW(schedule=opt_lib.Schedule(peak_lr=1e-3, decay_steps=0))
    state = train_lib.init_state(jax.random.PRNGKey(0), cfg, opt)
    tokens, fe = _inputs(cfg, jax.random.PRNGKey(1))
    labels = train_lib.shift_labels(
        tokens, pad_prefix=(cfg.n_frontend_tokens if cfg.frontend != "none" else 0))
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, S, cfg.d_model), jnp.float32)
        batch["tokens"] = jax.random.randint(jax.random.PRNGKey(3), (B, S),
                                             0, cfg.vocab)
        batch["labels"] = train_lib.shift_labels(batch["tokens"])
    if fe is not None:
        batch["frontend"] = fe
    step = train_lib.make_train_step(cfg, opt)
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1
    # params changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree_util.tree_map(jnp.subtract, state2.params, state.params), 0.0)
    assert delta > 0


def test_decode_matches_full_forward():
    """Serving consistency: prefill+decode logits == apply on the extended
    sequence (dense family, greedy-teacher-forced)."""
    cfg = get("qwen1.5-0.5b-smoke")
    key = jax.random.PRNGKey(0)
    params = lm.init(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    lg, cache = lm.prefill(params, tokens, cfg, max_len=S + 4)
    nxt = jnp.full((B, 1), 7, jnp.int32)
    lg_dec, _ = lm.decode_step(params, nxt, cache, S, cfg)
    full = lm.apply(params, jnp.concatenate([tokens, nxt], axis=1), cfg)
    # tolerance: the serving cache stores K/V in bf16 (production layout),
    # the full forward keeps f32 — expect bf16-rounding-level differences
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(full[:, -1]), atol=0.03, rtol=0.05)


def test_ssm_decode_matches_full_forward():
    """Mamba decode-state path equals the chunked-scan forward."""
    cfg = get("falcon-mamba-7b-smoke")
    key = jax.random.PRNGKey(0)
    params = lm.init(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    lg, cache = lm.prefill(params, tokens, cfg, max_len=S + 4)
    nxt = jnp.full((B, 1), 3, jnp.int32)
    lg_dec, _ = lm.decode_step(params, nxt, cache, S, cfg)
    full = lm.apply(params, jnp.concatenate([tokens, nxt], axis=1), cfg)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(full[:, -1]), atol=0.03, rtol=0.05)


def test_param_counts_sane():
    """param_count() roughly matches actually-initialised leaf totals."""
    for name in ("qwen1.5-0.5b", "starcoder2-3b"):
        cfg = get(name + "-smoke")
        params = lm.init(jax.random.PRNGKey(0), cfg)
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(params))
        est = cfg.param_count()
        assert 0.5 < actual / est < 2.0, (name, actual, est)
