"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

``--quick`` is the CI perf tracker: a CPU-sim measurement of the GMW ReLU
hot path — rounds, wire bytes and wall-clock for the exact (k=64, m=0) vs
the 8-bit reduced ring, the round-fused engine vs the frozen seed path
(core/gmw_ref.py), and the multi-group relu_many swap fusion — written to
``BENCH_relu.json`` so the perf trajectory is tracked PR over PR.  Every
measured entry sits next to the ``core.schedule`` prediction
(``sched_rounds_pred`` / ``sched_bytes_pred`` plus LAN/WAN latency
projections); ``--check`` is the CI round-regression gate that fails when
measured fused swaps exceed the prediction.  ``--transport`` runs the
real two-process deployment (both parties as OS processes over localhost
TCP under an injected RTT, plus an HTTP-frontend throughput probe) and
``--check`` then also enforces exact wire-vs-schedule byte parity,
bit-identity against the SimComm reference, and the wall-clock tolerance
band.
"""
import argparse
import json
import os
import sys
import time

# make `python benchmarks/run.py` work from anywhere: repo root (for the
# benchmarks package) and src/ (for repro) onto sys.path
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)



# the --quick / --gantt multi-group layer: four concurrent ReLU groups of
# mixed widths and element counts, (n_elements, k, m) each
_E = 2048
MULTIGROUP_SPECS = [(_E, 64, 0), (_E, 21, 13), (_E // 2, 21, 13),
                    (_E // 2, 20, 14)]


def _time_best(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def quick(out_path: str = "BENCH_relu.json") -> dict:
    import jax
    import numpy as np

    from repro.api.plan import LAN, WAN
    from repro.core import (beaver, comm as comm_lib, costmodel, fixed, gmw,
                            gmw_ref, ring, schedule as schedule_lib, shares)
    from repro.runtime import loop as loop_lib

    rng = np.random.default_rng(0)
    E = 2048
    results = {"n_elements": E, "configs": {}}

    for name, (k, m) in {"exact_64": (64, 0),
                         "reduced_8of64": (21, 13)}.items():
        w = k - m
        x = rng.uniform(-3.5, 3.5, E).astype(np.float32)
        X = shares.share(jax.random.PRNGKey(1), fixed.encode_np(x))
        tr = beaver.gen_relu_triples(jax.random.PRNGKey(2), E, w)
        cm = comm_lib.CountingComm()

        def run(mod, comm):
            out = mod.relu(jax.random.PRNGKey(3), X, tr, comm, k=k, m=m)
            jax.block_until_ready((out.lo, out.hi))

        run(gmw, cm)  # warmup + counter fill
        wall_python = _time_best(lambda: run(gmw, comm_lib.SimComm()))
        run(gmw_ref, comm_lib.SimComm())  # warmup
        wall_seed = _time_best(lambda: run(gmw_ref, comm_lib.SimComm()))

        # compiled round loop: the whole ReLU as ONE jitted XLA program
        # (scan backend, runtime/loop.py) — no per-round Python dispatch
        @jax.jit
        def run_scan(lo, hi, tri, _k=k, _m=m):
            out = gmw.relu_scan(jax.random.PRNGKey(3), ring.Ring64(lo, hi),
                                tri, comm_lib.SimComm(), k=_k, m=_m)
            return out.lo, out.hi

        want = gmw.relu(jax.random.PRNGKey(3), X, tr, comm_lib.SimComm(),
                        k=k, m=m)
        got = run_scan(X.lo, X.hi, tr)  # warmup (trace + compile)
        assert np.array_equal(np.asarray(got[0]), np.asarray(want.lo)), \
            f"{name}: compiled loop diverged from the generator loop"
        wall_compiled = _time_best(lambda: jax.block_until_ready(
            run_scan(X.lo, X.hi, tr)))
        model = costmodel.relu_cost(E, w)
        sched = schedule_lib.simulate([(E, w, (E, k, m))])
        results["configs"][name] = {
            "k": k, "m": m, "width": w,
            "rounds": cm.n_swaps,
            "bytes_tx": cm.bytes_tx,
            "model_rounds": model.rounds,
            "model_bytes_tx": model.bytes_tx,
            "sched_rounds_pred": sched.n_rounds,
            "sched_bytes_pred": sched.bytes_tx,
            "wall_s_seed": round(wall_seed, 4),
            "wall_s_python_loop": round(wall_python, 4),
            "wall_s_compiled_loop": round(wall_compiled, 6),
            "wall_s_fused": round(wall_compiled, 6),
            "speedup_vs_seed": round(wall_seed / max(wall_compiled, 1e-9), 3),
        }

    # multi-group layer: sibling ReLU groups sharing rounds via relu_many
    specs = MULTIGROUP_SPECS
    keys = [jax.random.PRNGKey(40 + i) for i in range(len(specs))]
    Xs, trs = [], []
    for i, (n, k, m) in enumerate(specs):
        x = rng.uniform(-3.5, 3.5, n).astype(np.float32)
        Xs.append(shares.share(jax.random.PRNGKey(50 + i), fixed.encode_np(x)))
        trs.append(beaver.gen_relu_triples(jax.random.PRNGKey(60 + i), n,
                                           k - m))

    def run_seed(comm):
        for i, (n, k, m) in enumerate(specs):
            out = gmw_ref.relu(keys[i], Xs[i], trs[i], comm, k=k, m=m)
            jax.block_until_ready((out.lo, out.hi))

    def run_fused(comm):
        outs = gmw.relu_many(keys, Xs, trs, comm,
                             [(k, m) for _, k, m in specs])
        jax.block_until_ready([(o.lo, o.hi) for o in outs])

    seed_cm = comm_lib.CountingComm()
    run_seed(seed_cm)
    fused_cc = comm_lib.CoalescingComm()
    run_fused(fused_cc)
    wall_seed = _time_best(lambda: run_seed(comm_lib.SimComm()))
    wall_python = _time_best(lambda: run_fused(comm_lib.SimComm()))
    # schedule-predicted fused timeline (the CI round-regression oracle:
    # measured fused swaps must never exceed this — see --check)
    sched = schedule_lib.simulate([(n, k - m, (n, k, m)) for n, k, m in specs])

    # compiled round loop: the whole multi-group layer as ONE jitted XLA
    # program (the scan backend of runtime/loop.py).  The trace / XLA
    # compile / warm execute split IS the dispatch-overhead breakdown:
    # trace+compile are paid once per signature, warm batches pay only
    # the execute time.
    kms = [(k, m) for _, k, m in specs]
    los, his = [x.lo for x in Xs], [x.hi for x in Xs]

    def compiled_replay(lo_list, hi_list, tris):
        xs2 = [ring.Ring64(lo, hi) for lo, hi in zip(lo_list, hi_list)]
        outs = gmw.relu_many(keys, xs2, tris, comm_lib.SimComm(), kms,
                             loop="scan")
        return [o.lo for o in outs], [o.hi for o in outs]

    t0 = time.perf_counter()
    lowered = jax.jit(compiled_replay).lower(los, his, trs)
    trace_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    exe = lowered.compile()
    compile_s = time.perf_counter() - t0
    got_lo, _ = exe(los, his, trs)
    want = gmw.relu_many(keys, Xs, trs, comm_lib.SimComm(), kms)
    assert all(np.array_equal(np.asarray(a), np.asarray(b.lo))
               for a, b in zip(got_lo, want)), \
        "multigroup: compiled loop diverged from the generator loop"
    wall_compiled = _time_best(lambda: jax.block_until_ready(
        exe(los, his, trs)))
    # per-round host overhead of the generator loop (Python dispatch,
    # pytree flatten/unflatten, per-round device sync) — what compiling
    # the loop removes
    host_s_per_round = (max(wall_python - wall_compiled, 0.0)
                        / max(sched.n_rounds, 1))

    # mesh-lowered census: the same fused replay inside shard_map over a
    # party axis of size 2 must compile to exactly one collective-permute
    # per fused round with the schedule's per-round payloads (--check
    # fails on any divergence).  Needs >= 2 devices (forced on CPU above).
    mesh_census = {"mesh_collective_permutes": None,
                   "mesh_collective_bytes": None}
    from repro.launch.mesh import mpc_serving_mesh
    mesh = mpc_serving_mesh()
    if mesh.shape["party"] == 2:   # smoke fallback has no real exchange
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.runtime.hlo_analyzer import collective_census

        kms = [(k, m) for _, k, m in specs]

        def replay(lo_list, hi_list, triples):
            cc = comm_lib.CoalescingComm(comm_lib.MeshComm("party", 2))
            xs = [ring.Ring64(lo, hi) for lo, hi in zip(lo_list, hi_list)]
            outs = gmw.relu_many(keys, xs, triples, cc, kms)
            return [o.lo for o in outs], [o.hi for o in outs]

        party = P("party")
        n_g = len(specs)
        fused = shard_map(
            replay, mesh=mesh,
            in_specs=([party] * n_g, [party] * n_g,
                      beaver.pool_party_specs(trs)),
            out_specs=([party] * n_g, [party] * n_g), check_rep=False)
        compiled = jax.jit(fused).lower(
            [x.lo for x in Xs], [x.hi for x in Xs], trs).compile()
        census = collective_census(compiled.as_text())
        mesh_census = {
            "mesh_collective_permutes": sum(c.count for c in census),
            "mesh_collective_bytes": sum(c.bytes * c.count for c in census),
        }

    # request-level serving engine: the canonical ISSUE-5 request mix (two
    # identical shapes + one ragged) served as one fused micro-batch over
    # the smoke model — requests/s, simulated latency percentiles, and the
    # rounds saved vs serial per-request execution (--check gates the
    # measured fused rounds against the merged-schedule prediction)
    from repro import api
    from repro.configs import RESNET_SMOKE
    from repro.core.hummingbird import HBConfig, HBLayer
    from repro.models import resnet
    from repro.serve import InferenceEngine

    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

    plan = api.trace_plan(afn, params, (2, 3, 8, 8), name="smoke")
    plan = plan.with_hb(HBConfig(
        tuple([HBLayer(k=21, m=13)] * (plan.n_groups - 1)
              + [HBLayer(k=13, m=13)]), plan.group_elements))
    engine = InferenceEngine(afn, params, RESNET_SMOKE, plan,
                             api.Session(key=0))
    mix = [(2, 3, 8, 8), (2, 3, 8, 8), (1, 3, 8, 8)]
    xs = [rng.uniform(-0.5, 0.5, sh).astype(np.float32) for sh in mix]

    def serve_mix():
        t0 = time.perf_counter()
        futs = [engine.submit(t, x) for t, x in zip("aba", xs)]
        engine.flush()
        jax.block_until_ready([f.result().data.lo for f in futs])
        return time.perf_counter() - t0

    # cold = first batch for this (model, shapes) signature: on the scan
    # round-loop backend it pays the whole-replay trace + XLA compile;
    # warm = every batch after, paying only dispatch + execute.  The
    # steady-state serving number (and the --check wall gate) is warm.
    wall_cold = serve_mix()
    wall_warm = min(serve_mix(), serve_mix())
    from repro.api.compile import replay_cache_stats
    replay_entries = replay_cache_stats()
    st = engine.stats()
    results["engine"] = {
        "mix": [list(sh) for sh in mix],
        "requests": int(st["requests"]),
        "batches": int(st["batches"]),
        "fused_rounds": int(st["fused_rounds"]),
        "serial_rounds": int(st["serial_rounds"]),
        "sched_rounds_pred": sum(r.predicted_rounds for r in engine.reports),
        "sched_bytes_pred": sum(r.predicted_bytes for r in engine.reports),
        "bytes_fused": sum(r.measured_bytes for r in engine.reports),
        "rounds_saved_ratio": round(st["rounds_saved_ratio"], 3),
        "requests_per_s": round(len(mix) / max(wall_warm, 1e-9), 3),
        "p50_sim_latency_ms": round(st["p50_sim_latency_s"] * 1e3, 3),
        "p95_sim_latency_ms": round(st["p95_sim_latency_s"] * 1e3, 3),
        "round_loop": loop_lib.round_loop_mode(),
        "wall_s": round(wall_warm, 4),
        "wall_s_cold": round(wall_cold, 4),
        "replay_trace_s": round(sum(e["trace_s"] for e in replay_entries), 4),
        "replay_compile_s": round(
            sum(e["compile_s"] for e in replay_entries), 4),
        "replay_signatures": len(replay_entries),
    }

    # private LM (reduced-ring transformer): one qwen smoke block through
    # the registered MPC forward — PWL SiLU, ReLU attention, three Beaver
    # opens per layer.  Measured fused rounds/bytes must EQUAL the plan's
    # schedule prediction (--check gates the equality), alongside the
    # per-token sim latency and the LAN/WAN projections.
    import dataclasses

    from repro import configs as configs_lib
    from repro.models import lm as lm_lib

    lm_cfg = dataclasses.replace(configs_lib.get("qwen1.5-0.5b-smoke"),
                                 n_layers=1)
    lm_params = lm_lib.init(jax.random.PRNGKey(0), lm_cfg)
    lm_seq = 8
    lm_h = jax.random.normal(jax.random.PRNGKey(1),
                             (1, lm_seq, lm_cfg.d_model)) * 0.5
    lm_plan = lm_lib.trace(lm_params, lm_cfg, 1, lm_seq)
    lm_cc = comm_lib.CoalescingComm(comm_lib.CountingComm())
    lm_model = api.compile(
        lambda p, v, relu_fn=None: lm_lib.mpc_reference(p, v, lm_cfg,
                                                        relu_fn=relu_fn),
        lm_params, lm_cfg, lm_plan, api.Session(key=0, comm=lm_cc))
    Xh = lm_model.encrypt(jax.random.PRNGKey(2), lm_h)

    def serve_lm():
        t0 = time.perf_counter()
        out = lm_model(Xh, key=jax.random.PRNGKey(3))
        jax.block_until_ready((out.data.lo, out.data.hi))
        return out, time.perf_counter() - t0

    lm_out, lm_wall = serve_lm()
    lm_ref = np.asarray(lm_lib.mpc_reference(lm_params, lm_h, lm_cfg))
    lm_err = float(np.max(np.abs(lm_out.reveal_np() - lm_ref)))
    lm_sched = lm_plan.schedule()
    results["lm"] = {
        "arch": lm_cfg.name, "n_layers": lm_cfg.n_layers, "seq": lm_seq,
        "n_relu_calls": len(lm_plan.calls), "n_opens": len(lm_plan.opens),
        "fused_rounds": lm_cc.n_rounds,
        "bytes_fused": lm_cc.bytes_tx,
        "sched_rounds_pred": lm_sched.n_rounds,
        "sched_bytes_pred": lm_sched.bytes_tx,
        "max_abs_err_vs_plaintext": round(lm_err, 6),
        "wall_s": round(lm_wall, 4),
        "s_per_token": round(lm_wall / lm_seq, 4),
        "sched_latency_lan_ms_pred": round(
            lm_sched.latency(LAN.bandwidth_bps, LAN.rtt_s) * 1e3, 3),
        "sched_latency_wan_s_pred": round(
            lm_sched.latency(WAN.bandwidth_bps, WAN.rtt_s), 4),
    }

    # protocol-safety counters (the hbcheck gate): non-baselined AST-lint
    # + lock-discipline findings over src/tests, and the canonical ResNet
    # serve_step leakage census — zero collectives may carry an unmasked
    # secret share (needs the 2-device party axis; None on 1 device).
    # --check fails on any finding or unmasked collective.
    from repro.analysis import lint as hb_lint
    from repro.analysis import locks as hb_locks
    from repro.analysis import taint as hb_taint

    hb_findings = hb_lint.lint_paths(
        [os.path.join(_ROOT, "src"), os.path.join(_ROOT, "tests")],
        root=_ROOT)
    hb_findings += hb_locks.check_paths(_ROOT)
    hb_baseline = hb_lint.load_baseline(
        os.path.join(_ROOT, "tools", "hbcheck_baseline.json"))
    hb_new = [f for f in hb_findings if f.key() not in hb_baseline]
    taint_summary = {}
    if jax.device_count() >= 2:
        taint_summary = hb_taint.canonical_resnet_census()
    results["hbcheck"] = {
        "hbcheck_findings": len(hb_new),
        "baselined_findings": len(hb_findings) - len(hb_new),
        "unmasked_collectives": taint_summary.get("unmasked_collectives"),
        "taint_collectives": taint_summary.get("collectives"),
        "taint_cross_check_ok": taint_summary.get("cross_check_ok"),
    }

    results["multigroup"] = {
        **mesh_census,
        "groups": [{"n": n, "k": k, "m": m} for n, k, m in specs],
        "swaps_seed": seed_cm.n_swaps,
        "swaps_fused": fused_cc.n_rounds,
        "swap_reduction": round(seed_cm.n_swaps / max(fused_cc.n_rounds, 1), 2),
        "bytes_seed": seed_cm.bytes_tx,
        "bytes_fused": fused_cc.bytes_tx,
        "sched_rounds_pred": sched.n_rounds,
        "sched_bytes_pred": sched.bytes_tx,
        "sched_latency_lan_ms_pred": round(
            sched.latency(LAN.bandwidth_bps, LAN.rtt_s) * 1e3, 3),
        "sched_latency_wan_s_pred": round(
            sched.latency(WAN.bandwidth_bps, WAN.rtt_s), 4),
        "wall_s_seed": round(wall_seed, 4),
        "wall_s_python_loop": round(wall_python, 4),
        "wall_s_compiled_loop": round(wall_compiled, 6),
        "wall_s_fused": round(wall_compiled, 6),
        "trace_s": round(trace_s, 4),
        "compile_s": round(compile_s, 4),
        "host_s_per_round": round(host_s_per_round, 6),
        "speedup_vs_seed": round(wall_seed / max(wall_compiled, 1e-9), 3),
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(json.dumps(results, indent=2, sort_keys=True))
    return results


def chaos(out_path: str = "BENCH_relu.json") -> dict:
    """``--chaos``: the canonical engine request mix under a seeded
    ``FaultPlan`` — transient drops + a corrupted payload below the
    resilient transport, one mid-replay party crash healed by the
    engine's restart hook, and one deadline-shed request.  Asserts the
    recovered outputs are bit-identical to a fault-free run of the SAME
    mix, that the engine's failure accounting matches the injected plan
    exactly, and demonstrates journal-based crash/resume.  Results merge
    into BENCH_relu.json under ``"chaos"``; ``--check`` fails on any
    recorded divergence."""
    import jax
    import numpy as np

    from repro import api, errors
    from repro.configs import RESNET_SMOKE
    from repro.core import beaver, comm as comm_lib, faults, fixed, gmw
    from repro.core import ring, shares
    from repro.core.hummingbird import HBConfig, HBLayer
    from repro.models import resnet
    from repro.serve import InferenceEngine

    rng = np.random.default_rng(0)
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

    plan = api.trace_plan(afn, params, (2, 3, 8, 8), name="smoke")
    plan = plan.with_hb(HBConfig(
        tuple([HBLayer(k=21, m=13)] * (plan.n_groups - 1)
              + [HBLayer(k=13, m=13)]), plan.group_elements))
    mix = [(2, 3, 8, 8), (2, 3, 8, 8), (1, 3, 8, 8)]
    xs = [rng.uniform(-0.5, 0.5, sh).astype(np.float32) for sh in mix]

    def run_mix(session, **engine_kw):
        engine = InferenceEngine(afn, params, RESNET_SMOKE, plan, session,
                                 **engine_kw)
        futs = [engine.submit(t, x) for t, x in zip("aba", xs)]
        shed_fut = engine.submit("a", xs[0], deadline_s=0.0)
        engine.flush()
        outs = [ring.to_uint64_np(f.result().data) for f in futs]
        return engine, outs, shed_fut

    # fault-free baseline: same Session seed => same request keys
    baseline, want, _ = run_mix(api.Session(key=0))
    n_rounds = int(baseline.stats()["fused_rounds"])

    # seeded chaos: transients within the measured fused timeline + one
    # crash at a mid-replay round, healed by restarting the transport
    fault_plan = faults.FaultPlan.seeded(
        17, n_rounds, drops=2, corrupts=1,
        crash_round=max(1, n_rounds // 2))
    fic = faults.FaultInjectingComm(fault_plan)
    rc = comm_lib.ResilientComm(fic, max_retries=3)
    engine, got, shed_fut = run_mix(
        api.Session(key=0, comm=rc),
        on_party_crash=lambda e: fic.restart())

    bit_identical = all(np.array_equal(a, b) for a, b in zip(got, want))
    st = engine.stats()
    shed_typed = False
    try:
        shed_fut.result()
    except errors.DeadlineExceeded:
        shed_typed = True

    # journal-based crash/resume on a raw fused layer: crash mid-replay,
    # snapshot at the barrier, restart with the journal mounted
    import tempfile
    E, k, m = 512, 21, 13
    x = rng.uniform(-3.5, 3.5, E).astype(np.float32)
    X = shares.share(jax.random.PRNGKey(7), fixed.encode_np(x))
    tr = beaver.gen_relu_triples(jax.random.PRNGKey(8), E, k - m)
    key = jax.random.PRNGKey(9)
    ref = gmw.relu(key, X, tr, comm_lib.SimComm(), k=k, m=m)
    crash_plan = faults.FaultPlan.seeded(0, 8, drops=0, corrupts=0,
                                         crash_round=3)
    jc = faults.JournaledComm(comm_lib.ResilientComm(
        faults.FaultInjectingComm(crash_plan)))
    resume_ok, replayed = False, 0
    with tempfile.TemporaryDirectory() as snap_dir:
        try:
            gmw.relu(key, X, tr, comm_lib.CoalescingComm(jc), k=k, m=m)
        except errors.PartyCrashed:
            jc.snapshot(snap_dir)
            journal = faults.RoundJournal.load(snap_dir)
            jc2 = faults.JournaledComm(comm_lib.ResilientComm(),
                                       journal=journal)
            out = gmw.relu(key, X, tr, comm_lib.CoalescingComm(jc2),
                           k=k, m=m)
            replayed = jc2.replayed
            resume_ok = bool(np.array_equal(ring.to_uint64_np(out),
                                            ring.to_uint64_np(ref)))

    entry = {
        "fault_plan_seed": 17,
        "injected": dict(fic.injected),
        "bit_identical": bit_identical,
        "transport_retries": rc.retries,
        "engine_retries": int(st["retries"]),
        "chaos_retries": rc.retries + int(st["retries"]),
        "chaos_recovery_overhead_bytes": rc.resent_bytes,
        "faults_recovered": int(st["faults_recovered"]),
        "shed": int(st["shed"]),
        "shed_typed": shed_typed,
        "restarts": fic.restarts,
        "resume_bit_identical": resume_ok,
        "resume_replayed_rounds": replayed,
    }
    try:
        with open(out_path) as f:
            results = json.load(f)
    except FileNotFoundError:
        results = {}
    results["chaos"] = entry
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(json.dumps({"chaos": entry}, indent=2, sort_keys=True))
    assert bit_identical, "chaos run diverged from the fault-free outputs"
    assert resume_ok, "journal resume diverged from the uninterrupted run"
    return entry


def transport(out_path: str = "BENCH_relu.json") -> dict:
    """``--transport``: the real two-process deployment gate.  Writes a
    smoke job directory, launches BOTH parties as their own OS processes
    (``repro.launch.party_host``) over localhost TCP with an injected
    WAN-style RTT, and records:

    - byte-accounting parity: the socket transport's measured DATA
      payload bytes and round count vs the ``Schedule.framed()``
      prediction (``--check`` fails on ANY divergence — the wire is the
      schedule, exactly);
    - bit-identity: the combined party output shares vs an in-process
      SimComm reference run of the same job;
    - wall-clock vs the schedule's latency projection under the injected
      RTT (gated with a timing-noise tolerance band: the shaped floor is
      hard, the ceiling allows compile + interpreter overhead);
    - requests/s through the asyncio HTTP frontend driving a
      leader/follower engine link over a second real socket pair.

    Results merge into BENCH_relu.json under ``"transport"``."""
    import json as json_lib
    import subprocess
    import tempfile
    import threading
    import urllib.request

    import jax
    import numpy as np

    from repro import api
    from repro.configs import RESNET_SMOKE
    from repro.core import beaver, ring
    from repro.core.hummingbird import HBConfig, HBLayer
    from repro.models import resnet
    from repro.serve import Frontend, InferenceEngine
    from repro import transport as transport_lib

    rng = np.random.default_rng(0)
    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

    plan = api.trace_plan(afn, params, (2, 3, 8, 8), name="smoke")
    plan = plan.with_hb(HBConfig(
        tuple([HBLayer(k=21, m=13)] * (plan.n_groups - 1)
              + [HBLayer(k=13, m=13)]), plan.group_elements))
    framed = plan.schedule().framed()
    rtt_ms = 4.0
    predicted_latency_s = framed.latency(float("inf"), rtt_ms / 1e3)
    # measured-wall acceptance band: schedule floor (hard physics) up to
    # floor + per-round host budget + one-off startup (process spawn,
    # connect handshake, jit warm-up of both parties) — see
    # Schedule.wall_band.  Tightens with the round count, so per-round
    # host regressions fail --check instead of hiding under the old flat
    # 20x+120s ceiling.
    wall_band = framed.wall_band(float("inf"), rtt_ms / 1e3)

    # in-process SimComm reference: the bit-identity oracle
    enc_model = api.compile(afn, params, RESNET_SMOKE, plan,
                            api.Session(key=0))
    x = rng.uniform(-0.5, 0.5, (2, 3, 8, 8)).astype(np.float32)
    X = enc_model.encrypt(jax.random.PRNGKey(2), x)
    pool = beaver.gen_plan_triples(jax.random.PRNGKey(3),
                                   plan.triple_specs())
    ref_model = api.compile(
        afn, params, RESNET_SMOKE, plan,
        api.Session(key=0, provider=beaver.TriplePool(pool)))
    want = ring.to_uint64_np(
        ref_model(X, key=jax.random.PRNGKey(4)).data)

    with tempfile.TemporaryDirectory() as tmp:
        job_dir = os.path.join(tmp, "job")
        transport_lib.write_job(
            job_dir, plan=plan, config="smoke", params_seed=0, infer_key=4,
            session_seed=0, x=X, pool=pool)
        port = transport_lib.free_port()
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(_ROOT, "src")
                   + os.pathsep + os.environ.get("PYTHONPATH", ""))

        def spawn(party, *extra):
            link = (["--listen", f"127.0.0.1:{port}"] if party == 0
                    else ["--peer", f"127.0.0.1:{port}"])
            return subprocess.Popen(
                [sys.executable, "-m", "repro.launch.party_host",
                 "--party", str(party), "--job", job_dir,
                 "--rtt-ms", str(rtt_ms)] + link + list(extra),
                env=env, cwd=_ROOT)

        t0 = time.perf_counter()
        procs = [spawn(0), spawn(1)]
        rcs = [p.wait(timeout=600) for p in procs]
        pair_wall = time.perf_counter() - t0
        if any(rcs):
            raise RuntimeError(f"party_host exit codes {rcs}")

        outs, stats = [], []
        for p in (0, 1):
            with np.load(os.path.join(job_dir, f"out{p}.npz")) as npz:
                outs.append((npz["lo"].copy(), npz["hi"].copy()))
            with open(os.path.join(job_dir, f"stats{p}.json")) as f:
                stats.append(json_lib.load(f))
        got = ring.to_uint64_np(ring.Ring64(
            np.concatenate([outs[0][0], outs[1][0]]),
            np.concatenate([outs[0][1], outs[1][1]])))
        bit_identical = bool(np.array_equal(got, want))

    # HTTP frontend over a leader/follower engine link on a second socket
    fport = transport_lib.free_port()
    follower_served = {}

    def follower():
        session = api.Session.connect(
            1, peer=("127.0.0.1", fport), key=0, session_id="bench",
            plan_digest=plan.digest(), handshake_timeout_s=120.0,
            timeout_s=120.0)
        model = api.compile(afn, params, RESNET_SMOKE, plan, session)
        try:
            follower_served["n"] = transport_lib.serve_follower(
                session.transport, model,
                provider_factory=transport_lib.tenant_provider_factory(
                    0, party=1))
        finally:
            session.transport.close()

    fthread = threading.Thread(target=follower, daemon=True)
    fthread.start()
    session = api.Session.connect(
        0, listen=("127.0.0.1", fport), key=0, session_id="bench",
        plan_digest=plan.digest(), handshake_timeout_s=120.0,
        timeout_s=120.0)
    engine = InferenceEngine(
        afn, params, RESNET_SMOKE, plan, session,
        provider_factory=transport_lib.tenant_provider_factory(0, party=0))
    link = transport_lib.EngineLink(engine)
    frontend = Frontend(engine)
    n_http = 3
    try:
        host, hport = frontend.serve_background("127.0.0.1", 0)
        t0 = time.perf_counter()
        for i, tenant in enumerate("aba"[:n_http]):
            xq = rng.uniform(-0.5, 0.5, (2, 3, 8, 8)).astype(np.float32)
            req = urllib.request.Request(
                f"http://{host}:{hport}/infer", method="POST",
                data=json_lib.dumps({"tenant": tenant,
                                     "x": xq.tolist()}).encode())
            with urllib.request.urlopen(req, timeout=600) as resp:
                assert resp.status == 200
                json_lib.loads(resp.read().decode())
        frontend_wall = time.perf_counter() - t0
    finally:
        frontend.close()
        link.shutdown()
        session.transport.close()
    fthread.join(60.0)

    entry = {
        "rtt_ms_injected": rtt_ms,
        "rounds_measured": [int(s["rounds"]) for s in stats],
        "sched_rounds_pred": framed.n_rounds,
        "payload_bytes_measured": [int(s["payload_bytes"]) for s in stats],
        "sched_bytes_pred": framed.bytes_tx,
        "header_bytes": [int(s["header_bytes"]) for s in stats],
        "bit_identical": bit_identical,
        "wall_s": round(max(float(s["wall_s"]) for s in stats), 4),
        "pair_wall_s": round(pair_wall, 4),
        "predicted_latency_s": round(predicted_latency_s, 4),
        # schedule-derived tolerance band (Schedule.wall_band): the
        # shaped floor is hard; the ceiling is floor + n_rounds x host
        # budget + startup, so it scales with the timeline instead of
        # being a flat multiplier
        "wall_band_s": [round(wall_band[0], 4), round(wall_band[1], 4)],
        "frontend": {
            "requests": n_http,
            "requests_per_s": round(n_http / max(frontend_wall, 1e-9), 3),
            "wall_s": round(frontend_wall, 4),
            "follower_batches": int(follower_served.get("n", 0)),
        },
    }
    try:
        with open(out_path) as f:
            results = json.load(f)
    except FileNotFoundError:
        results = {}
    results["transport"] = entry
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(json.dumps({"transport": entry}, indent=2, sort_keys=True))
    assert bit_identical, "two-process run diverged from SimComm reference"
    return entry


def check(path: str = "BENCH_relu.json") -> int:
    """Round-regression gate: fail (non-zero) when the measured fused
    engine used MORE swaps than the round schedule predicts — i.e. the
    engine stopped coalescing/batching the way ``core.schedule`` says it
    should.  (Fewer is also a model bug, but the gate is one-sided so a
    future engine improvement can land before its model update.)

    When the BENCH file carries a mesh-lowered census (>= 2 devices at
    --quick time), the gate is also two-sided on the compiled artifact:
    the mesh replay's collective-permute count must EQUAL the schedule's
    fused-round prediction and its summed payload bytes the predicted
    wire bytes — the compiled HLO is the timeline, not an upper bound."""
    with open(path) as f:
        data = json.load(f)
    failures = []
    entries = [("multigroup", data.get("multigroup", {}), "swaps_fused"),
               ("engine", data.get("engine", {}), "fused_rounds")]
    entries += [(name, c, "rounds")
                for name, c in data.get("configs", {}).items()]
    for name, entry, measured_key in entries:
        measured = entry.get(measured_key)
        pred = entry.get("sched_rounds_pred")
        if measured is None or pred is None:
            failures.append(
                f"{name}: missing {measured_key!r}/'sched_rounds_pred' — "
                f"stale BENCH file? regenerate with --quick")
        elif measured > pred:
            failures.append(
                f"{name}: measured {measured} {measured_key} > "
                f"schedule-predicted {pred}")
    mg = data.get("multigroup", {})
    # wall-clock gates (the compiled round loop's reason to exist): the
    # multi-group layer must beat the frozen seed path by >= 1.5x, and a
    # warm engine batch of the canonical mix must clear 5s / 1 req/s.
    wf, ws = mg.get("wall_s_fused"), mg.get("wall_s_seed")
    if wf is not None and ws is not None and wf * 1.5 > ws:
        failures.append(
            f"multigroup: wall_s_fused={wf}s not >= 1.5x faster than "
            f"wall_s_seed={ws}s (speedup {ws / max(wf, 1e-9):.2f}x) — the "
            f"compiled round loop stopped paying for itself")
    eng_entry = data.get("engine", {})
    eng_wall = eng_entry.get("wall_s")
    if eng_wall is not None and eng_wall >= 5.0:
        failures.append(
            f"engine: warm canonical-mix batch took {eng_wall}s >= 5.0s "
            f"(cold {eng_entry.get('wall_s_cold')}s, replay compile "
            f"{eng_entry.get('replay_compile_s')}s)")
    eng_rps = eng_entry.get("requests_per_s")
    if eng_rps is not None and eng_rps < 1.0:
        failures.append(
            f"engine: warm throughput {eng_rps} requests/s < 1.0 floor")
    mesh_rounds = mg.get("mesh_collective_permutes")
    mesh_bytes = mg.get("mesh_collective_bytes")
    if mesh_rounds is not None:
        if mesh_rounds != mg.get("sched_rounds_pred"):
            failures.append(
                f"multigroup: mesh-lowered HLO has {mesh_rounds} "
                f"collective-permutes != schedule-predicted "
                f"{mg.get('sched_rounds_pred')} fused rounds")
        if mesh_bytes != mg.get("sched_bytes_pred"):
            failures.append(
                f"multigroup: mesh-lowered collective bytes {mesh_bytes} "
                f"!= schedule-predicted {mg.get('sched_bytes_pred')}")
    # private-LM gate (present once --quick ran): the transformer block's
    # measured fused rounds AND bytes must EQUAL the plan's schedule
    # prediction — Beaver opens included, equality not a bound — and the
    # forward must stay within fixed-point tolerance of the plaintext
    # reference
    lm_entry = data.get("lm")
    if lm_entry is not None:
        for meas_key, pred_key, unit in (
                ("fused_rounds", "sched_rounds_pred", "rounds"),
                ("bytes_fused", "sched_bytes_pred", "bytes")):
            meas, pred = lm_entry.get(meas_key), lm_entry.get(pred_key)
            if meas is None or pred is None:
                failures.append(f"lm: missing {meas_key!r}/{pred_key!r} — "
                                f"stale BENCH file? regenerate with --quick")
            elif meas != pred:
                failures.append(
                    f"lm: measured {meas} {unit} != schedule-predicted "
                    f"{pred} — the LM replay and its plan diverged")
        lm_err = lm_entry.get("max_abs_err_vs_plaintext")
        if lm_err is None or lm_err > 0.05:
            failures.append(
                f"lm: max |MPC - plaintext| = {lm_err} exceeds the "
                f"fixed-point tolerance 0.05")
    # hbcheck gate (present once --quick ran with the analysis suite):
    # zero non-baselined protocol-safety findings and zero unmasked-secret
    # collectives in the canonical serve_step lowering
    hb = data.get("hbcheck")
    if hb is not None:
        if hb.get("hbcheck_findings", 0) != 0:
            failures.append(
                f"hbcheck: {hb.get('hbcheck_findings')} non-baselined "
                f"protocol-safety findings (run `python -m "
                f"repro.analysis.hbcheck src tests` for the list)")
        unmasked = hb.get("unmasked_collectives")
        if unmasked not in (None, 0):
            failures.append(
                f"hbcheck: {unmasked} collective(s) in the serve_step "
                f"lowering carry an unmasked secret share")
        if hb.get("taint_cross_check_ok") is False:
            failures.append(
                "hbcheck: taint census walked a different collective set "
                "than collective_census (parser drift)")
    # chaos gate (present once --chaos ran): recovery must be invisible —
    # bit-identical outputs, and every recovery action accounted against
    # the injected plan exactly (transients healed by re-send, the crash
    # by exactly one engine batch retry after restart)
    ch = data.get("chaos")
    if ch is not None:
        inj = ch.get("injected", {})
        transient = sum(inj.get(k, 0) for k in ("drop", "stall", "corrupt"))
        if not ch.get("bit_identical"):
            failures.append("chaos: recovered engine outputs diverged from "
                            "the fault-free run")
        if not ch.get("resume_bit_identical"):
            failures.append("chaos: journal crash/resume outputs diverged "
                            "from the uninterrupted run")
        if ch.get("transport_retries") != transient:
            failures.append(
                f"chaos: {ch.get('transport_retries')} transport re-sends "
                f"!= {transient} injected transient faults")
        if ch.get("engine_retries") != inj.get("crash", 0):
            failures.append(
                f"chaos: {ch.get('engine_retries')} engine batch retries "
                f"!= {inj.get('crash', 0)} injected crashes")
        if ch.get("shed") != 1 or not ch.get("shed_typed"):
            failures.append("chaos: deadline shed not counted/typed "
                            f"(shed={ch.get('shed')}, "
                            f"typed={ch.get('shed_typed')})")
    # transport gate (present once --transport ran): the real two-process
    # wire must MATCH the schedule exactly — byte-accounting parity is an
    # equality, not a bound — and the shaped wall-clock must sit inside
    # the recorded timing-noise tolerance band
    tr = data.get("transport")
    if tr is not None:
        if not tr.get("bit_identical"):
            failures.append("transport: two-process outputs diverged from "
                            "the SimComm reference")
        for party, rounds in enumerate(tr.get("rounds_measured", [])):
            if rounds != tr.get("sched_rounds_pred"):
                failures.append(
                    f"transport: party {party} measured {rounds} rounds "
                    f"!= schedule-predicted {tr.get('sched_rounds_pred')}")
        for party, nbytes in enumerate(tr.get("payload_bytes_measured", [])):
            if nbytes != tr.get("sched_bytes_pred"):
                failures.append(
                    f"transport: party {party} measured {nbytes} payload "
                    f"bytes != framed-schedule {tr.get('sched_bytes_pred')}")
        lo_s, hi_s = tr.get("wall_band_s", (0.0, float("inf")))
        if not (lo_s <= tr.get("wall_s", -1.0) <= hi_s):
            failures.append(
                f"transport: shaped wall {tr.get('wall_s')}s outside the "
                f"tolerance band [{lo_s}, {hi_s}]s (predicted "
                f"{tr.get('predicted_latency_s')}s under "
                f"{tr.get('rtt_ms_injected')}ms injected RTT)")
        fe = tr.get("frontend", {})
        if fe.get("requests_per_s", 0) <= 0 or fe.get("follower_batches",
                                                      0) < 1:
            failures.append(
                f"transport: HTTP frontend served no traffic "
                f"(requests_per_s={fe.get('requests_per_s')}, "
                f"follower_batches={fe.get('follower_batches')})")
    if failures:
        for msg in failures:
            print(f"ROUND-REGRESSION: {msg}", file=sys.stderr)
        return 1
    eng = data.get("engine", {})
    print(f"round gate OK: multigroup swaps_fused={mg.get('swaps_fused')} "
          f"<= sched_rounds_pred={mg.get('sched_rounds_pred')}; engine "
          f"fused_rounds={eng.get('fused_rounds')} <= "
          f"sched_rounds_pred={eng.get('sched_rounds_pred')} "
          f"({eng.get('rounds_saved_ratio')}x rounds saved vs serial)"
          + (f"; mesh HLO census {mesh_rounds} collective-permutes / "
             f"{mesh_bytes} B == schedule" if mesh_rounds is not None
             else " (no mesh census: single device)"))
    if lm_entry is not None:
        print(f"lm gate OK: {lm_entry.get('fused_rounds')} fused rounds / "
              f"{lm_entry.get('bytes_fused')} B == schedule (opens "
              f"included), max err "
              f"{lm_entry.get('max_abs_err_vs_plaintext')} vs plaintext, "
              f"{lm_entry.get('s_per_token')} s/token (sim)")
    if hb is not None:
        print(f"hbcheck gate OK: {hb.get('hbcheck_findings')} findings, "
              f"{hb.get('unmasked_collectives')} unmasked collectives "
              f"of {hb.get('taint_collectives')} in the serve_step "
              f"lowering")
    if ch is not None:
        print(f"chaos gate OK: bit-identical under "
              f"{sum(ch['injected'].values())} injected faults "
              f"({ch['injected']}), {ch['chaos_retries']} retries, "
              f"{ch['chaos_recovery_overhead_bytes']} B recovery overhead, "
              f"resume replayed {ch['resume_replayed_rounds']} rounds")
    if tr is not None:
        print(f"transport gate OK: 2-process wire == schedule "
              f"({tr['sched_rounds_pred']} rounds / "
              f"{tr['sched_bytes_pred']} B exactly), bit-identical, wall "
              f"{tr['wall_s']}s in band {tr['wall_band_s']} under "
              f"{tr['rtt_ms_injected']}ms RTT; HTTP frontend "
              f"{tr['frontend']['requests_per_s']} req/s")
    return 0


def gantt() -> None:
    """Print the fused-round Gantt of the --quick multi-group layer and
    the per-layer Gantt of the smoke-model serving plan."""
    from repro.core import schedule as schedule_lib

    specs = MULTIGROUP_SPECS
    sched = schedule_lib.simulate([(n, k - m, (n, k, m)) for n, k, m in specs])
    print("multi-group relu_many layer "
          f"({', '.join(f'{n}el k={k} m={m}' for n, k, m in specs)}):\n")
    print(sched.gantt())

    import jax
    import jax.numpy as jnp

    from repro.configs import RESNET_SMOKE
    from repro.core.hummingbird import HBConfig, HBLayer
    from repro.models import resnet

    params = jax.eval_shape(lambda k: resnet.init(k, RESNET_SMOKE),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    plan = resnet.trace(params, RESNET_SMOKE, batch=2)
    hb = HBConfig(tuple([HBLayer(k=21, m=13)] * (plan.n_groups - 1)
                        + [HBLayer(k=13, m=13)]), plan.group_elements)
    print(f"\n\nper-layer replay of the {RESNET_SMOKE.name} serving plan "
          "(last group culled):\n")
    print(plan.with_hb(hb).gantt())


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("filter", nargs="?", default=None,
                    help="only run benchmark modules whose name contains this")
    ap.add_argument("--quick", action="store_true",
                    help="CPU-sim ReLU perf tracker; writes BENCH_relu.json")
    ap.add_argument("--chaos", action="store_true",
                    help="chaos gate: re-run the engine mix under a seeded "
                         "FaultPlan (drops, a corrupt payload, a mid-replay "
                         "crash), assert bit-identical recovery, and merge "
                         "the accounting into BENCH_relu.json['chaos']")
    ap.add_argument("--transport", action="store_true",
                    help="real two-process deployment gate: both parties "
                         "as OS processes over localhost TCP under an "
                         "injected RTT + an HTTP-frontend throughput "
                         "probe; merges byte-accounting parity and wall "
                         "vs predicted latency into "
                         "BENCH_relu.json['transport']")
    ap.add_argument("--check", action="store_true",
                    help="round-regression gate over an existing "
                         "BENCH_relu.json: exit 1 when measured fused swaps "
                         "exceed the schedule prediction")
    ap.add_argument("--gantt", action="store_true",
                    help="print the fused-round Gantt of the --quick "
                         "multi-group layer and the smoke serving plan")
    ap.add_argument("--out", default="BENCH_relu.json",
                    help="output path for --quick / input for --check")
    args = ap.parse_args()
    if args.quick and ("--xla_force_host_platform_device_count"
                       not in os.environ.get("XLA_FLAGS", "")):
        # the --quick mesh-lowering census needs a party axis of size 2;
        # force two host devices before the first jax init (quick-mode
        # only — classic benchmarks keep the ambient topology; no effect
        # on real accelerators)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=2")
    if args.gantt:
        gantt()
    if args.quick:
        quick(args.out)
    if args.chaos:
        chaos(args.out)
    if args.transport:
        transport(args.out)
    if args.check:
        sys.exit(check(args.out))
    if args.gantt or args.quick or args.chaos or args.transport:
        return
    from benchmarks import (bench_accuracy, bench_breakdown, bench_comm,
                            bench_e2e, bench_roofline, bench_search)
    mods = [bench_comm, bench_e2e, bench_breakdown, bench_search,
            bench_accuracy, bench_roofline]
    print("name,us_per_call,derived")
    for mod in mods:
        if args.filter and args.filter not in mod.__name__:
            continue
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:
            print(f"{mod.__name__}_ERROR,0,{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
