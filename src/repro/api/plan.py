"""Plan: the first-class offline artifact of HummingBird private inference.

A Plan records what the online phase must replay exactly (PAPER §4): the
model's ReLU call sites in call order (element count, group, shape), the
per-group HummingBird (k, m) assignment, and whether the MSB-cone-pruned
adder is used.  It is produced by ``trace_plan`` — a generic shape tracer
that works on any ``apply(params, x, relu_fn=...)`` model — and is
JSON-(de)serializable so the offline search artifact can be saved, shipped,
and reloaded across runs (``plan.save`` / ``Plan.load``).

From a Plan alone you get the predicted fused-round timeline of one
replay (``plan.schedule()``, delegating to ``core.schedule`` — the
simulator validated bit-exactly against ``CoalescingComm`` counters),
the analytic communication cost (``plan.cost()``, validated against
``CountingComm`` in the comm-counter tests) and a latency estimate under
the paper's evaluation networks (``plan.estimate(network=WAN)``, §5.2
projection methodology, priced per fused round).
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro import errors
from repro.core import schedule as schedule_lib
from repro.core.costmodel import CommCost
from repro.core.hummingbird import HBConfig


# ---------------------------------------------------------------------------
# Network presets (paper §5.2 evaluation setup; same numbers as bench_e2e)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NetworkPreset:
    name: str
    bandwidth_bps: float          # one-direction link bandwidth, bits/s
    rtt_s: float


HIGHBW = NetworkPreset("highbw", 16e12 / 8, 10e-6)  # NVLink-class
LAN = NetworkPreset("lan", 10e9 / 8, 50e-6)         # 10 Gbps, 50us
WAN = NetworkPreset("wan", 352e6 / 8, 20e-3)        # 352 Mbps, 20ms (paper)
NETWORKS = {p.name: p for p in (HIGHBW, LAN, WAN)}


@dataclasses.dataclass(frozen=True)
class ReluCall:
    """One ReLU call site: how many elements, which (k, m) group, what
    shape (without the party dimension)."""

    n_elements: int
    group: int
    shape: Tuple[int, ...]

    def to_json(self) -> Dict:
        return {"n_elements": self.n_elements, "group": self.group,
                "shape": list(self.shape)}

    @staticmethod
    def from_json(d: Dict) -> "ReluCall":
        return ReluCall(int(d["n_elements"]), int(d["group"]),
                        tuple(int(s) for s in d["shape"]))


@dataclasses.dataclass(frozen=True)
class OpenCall:
    """One Beaver-product opening site (secret-by-secret mul/matmul in the
    transformer path): how many ring elements the single "open" exchange
    carries (per party, one direction — 2n for an elementwise mul of n,
    ``size(X) + size(Y)`` for a matmul), and where it sits in program
    order (``at_call`` = number of ReLU calls preceding it)."""

    n_elements: int
    at_call: int
    label: str = ""

    def to_json(self) -> Dict:
        return {"n_elements": self.n_elements, "at_call": self.at_call,
                "label": self.label}

    @staticmethod
    def from_json(d: Dict) -> "OpenCall":
        return OpenCall(int(d["n_elements"]), int(d["at_call"]),
                        str(d.get("label", "")))


@dataclasses.dataclass(frozen=True)
class Plan:
    """Network plan: ReLU call trace + per-group HummingBird assignment.

    ``calls`` is the model's ReLU trace in call order; ``hb`` carries one
    ``HBLayer`` (k, m) per ReLU group plus group element counts for budget
    accounting.  ``calls`` may be empty for plans built directly from an
    ``HBConfig`` (``Plan.from_hb``) — execution only needs ``hb``/``cone``;
    cost estimation and offline triple generation need the trace.

    Example::

        plan = api.trace_plan(afn, params, (4, 3, 32, 32), name="resnet18")
        plan = plan.with_hb(search_budget(..., plan, ...).config)
        print(plan.cost().bytes_tx, plan.estimate(network=api.WAN))
        plan.save("plan.json")            # == Plan.load("plan.json")
    """

    calls: Tuple[ReluCall, ...]
    hb: HBConfig
    input_shape: Tuple[int, ...] = ()
    cone: bool = False
    name: str = ""
    opens: Tuple[OpenCall, ...] = ()

    # -- derived views --------------------------------------------------------
    @property
    def n_groups(self) -> int:
        return self.hb.n_groups

    @property
    def group_elements(self) -> Tuple[int, ...]:
        return self.hb.group_elements

    def with_hb(self, hb: HBConfig) -> "Plan":
        """Same trace, new (k, m) assignment (e.g. the search result)."""
        assert hb.n_groups == self.hb.n_groups, (hb.n_groups, self.hb.n_groups)
        return dataclasses.replace(self, hb=hb)

    def triple_specs(self) -> Tuple[Tuple[int, int], ...]:
        """(n_elements, width) per ReLU call — what the offline TTP must
        generate for one request (see beaver.gen_plan_triples/EagerTTP)."""
        return tuple((c.n_elements, self.hb.layers[c.group].width)
                     for c in self.calls)

    def call_specs(self) -> Tuple[Tuple[int, int, Tuple[int, int, int]], ...]:
        """``(n_elements, width, batch_key)`` per ReLU call, in call order
        — one ``core.schedule.simulate`` spec per call, with the engine's
        ``(n_elements, k, m)`` auto-batch key attached.  This is one
        request's row of a merged micro-batch: the serving engine feeds
        one such sequence per concurrent request to
        ``core.schedule.simulate_merged`` to predict the batch's fused
        timeline."""
        specs = []
        for c in self.calls:
            layer = self.hb.layers[c.group]
            specs.append((c.n_elements, layer.width,
                          (c.n_elements, layer.k, layer.m)))
        return tuple(specs)

    def open_specs(self) -> Tuple[int, ...]:
        """Ring elements opened per Beaver-product site, in program order
        — one ``core.schedule.simulate_open`` spec per site.  Empty for
        plans without secret-by-secret products (e.g. ResNet)."""
        return tuple(o.n_elements for o in self.opens)

    # -- analytics ------------------------------------------------------------
    def schedule(self, streams: int = 1,
                 auto_batch: bool = True) -> schedule_lib.Schedule:
        """Fused-round timeline of one replay of this plan: every ReLU
        call is one ``relu_many`` lockstep (its ``streams`` sibling
        payloads auto-batch into one stream by default, exactly as the
        engine does); sequential calls never share rounds, so the
        per-call schedules compose with ``+``.

        This is the single source of truth ``cost``/``estimate`` (and the
        search engine's latency objective) read — per-round coalesced
        bytes, cross-phase overlap and stream dropout included — and it
        is validated bit-exactly against ``CoalescingComm`` counters.

        Trace-free plans (``Plan.from_hb``) carry no call list, so their
        timeline is unknown — raise rather than report a free model.
        """
        if not self.calls and self.n_groups:
            raise ValueError(
                "cost/estimate need a traced plan: this plan was built "
                "without a call list (Plan.from_hb) — use trace_plan / "
                "model-specific trace() to get one")
        opens_at: Dict[int, List[OpenCall]] = {}
        for o in self.opens:
            opens_at.setdefault(o.at_call, []).append(o)
        total = schedule_lib.Schedule.empty()
        for j, spec in enumerate(self.call_specs()):
            for o in opens_at.get(j, ()):
                total = total + schedule_lib.simulate_open(
                    [o.n_elements] * streams)
            total = total + schedule_lib.simulate(
                [spec] * streams, cone=self.cone, auto_batch=auto_batch)
        for o in opens_at.get(len(self.calls), ()):
            total = total + schedule_lib.simulate_open(
                [o.n_elements] * streams)
        return total

    def gantt(self, streams: int = 1, auto_batch: bool = True) -> str:
        """Per-layer ASCII/markdown Gantt of one replay: one timeline block
        per ReLU call (sequential calls never share rounds), rendered by
        ``core.schedule.Schedule.gantt`` — phases as rows, fused rounds as
        columns, cross-phase overlap as stacked bars — plus a replay
        total.  This is what ``benchmarks/run.py --gantt`` prints.

        Example::

            plan = api.trace_plan(afn, params, (2, 3, 8, 8))
            print(plan.gantt(streams=4))
        """
        if not self.calls and self.n_groups:
            raise ValueError(
                "gantt needs a traced plan: this plan was built without a "
                "call list (Plan.from_hb) — use trace_plan / model-specific "
                "trace() to get one")
        blocks: List[str] = []
        total = schedule_lib.Schedule.empty()
        for idx, (c, spec) in enumerate(zip(self.calls, self.call_specs())):
            layer = self.hb.layers[c.group]
            sched = schedule_lib.simulate([spec] * streams, cone=self.cone,
                                          auto_batch=auto_batch)
            total = total + sched
            head = (f"call {idx}: group {c.group}  k={layer.k} m={layer.m} "
                    f"width={layer.width}  {c.n_elements} el"
                    + (f" x {streams} streams" if streams > 1 else ""))
            if not sched.slots:
                blocks.append(f"{head}  — culled (0 rounds, 0 bytes)")
                continue
            blocks.append(head + "\n" + sched.gantt())
        blocks.append(f"replay total: {total.n_rounds} fused rounds, "
                      f"{total.bytes_tx} B/party one-direction")
        return "\n\n".join(blocks)

    def cost(self, streams: int = 1, auto_batch: bool = True) -> CommCost:
        """Closed-form ReLU communication of one replay of this plan
        (schedule-derived: ``self.schedule(...)`` collapsed to totals).

        ``streams`` > 1 prices the round-fused serving mode: sibling
        streams share every protocol round via ``relu_many`` and, being
        identical, auto-batch into one payload per round (rounds are paid
        once per call; bytes scale with the stream count minus the
        packing padding batching removes).
        """
        sched = self.schedule(streams=streams, auto_batch=auto_batch)
        return CommCost(sched.bytes_tx, sched.n_rounds, sched.phase_bytes())

    def estimate(self, bandwidth_bps: Optional[float] = None,
                 rtt_s: Optional[float] = None, *,
                 network: Union[NetworkPreset, str, None] = None,
                 streams: int = 1, compute_s: float = 0.0,
                 auto_batch: bool = True) -> float:
        """End-to-end ReLU latency estimate (seconds) for one replay:
        the schedule-predicted fused-round timeline priced per round (one
        RTT each, serialization sharing the link) — what the serving path
        actually pays, not a summed-bytes proxy.

        Pass explicit (bandwidth_bps, rtt_s) or one of the LAN/WAN/HIGHBW
        presets matching the paper's §5.2 evaluation setup.
        """
        if network is not None:
            preset = NETWORKS[network] if isinstance(network, str) else network
            bandwidth_bps, rtt_s = preset.bandwidth_bps, preset.rtt_s
        if bandwidth_bps is None or rtt_s is None:
            raise ValueError("estimate needs (bandwidth_bps, rtt_s) or network=")
        return self.schedule(streams=streams, auto_batch=auto_batch).latency(
            bandwidth_bps, rtt_s, compute_s)

    # -- (de)serialization ----------------------------------------------------
    def to_json(self) -> Dict:
        d = {"name": self.name, "input_shape": list(self.input_shape),
             "cone": self.cone, "hb": self.hb.to_json(),
             "calls": [c.to_json() for c in self.calls]}
        if self.opens:
            # only plans with secret-by-secret products carry the key, so
            # pre-existing (ResNet) plan digests are byte-identical
            d["opens"] = [o.to_json() for o in self.opens]
        return d

    @staticmethod
    def from_json(d: Dict) -> "Plan":
        return Plan(calls=tuple(ReluCall.from_json(c) for c in d["calls"]),
                    hb=HBConfig.from_json(d["hb"]),
                    input_shape=tuple(int(s) for s in d["input_shape"]),
                    cone=bool(d["cone"]), name=str(d.get("name", "")),
                    opens=tuple(OpenCall.from_json(o)
                                for o in d.get("opens", [])))

    def validate(self) -> "Plan":
        """Static pre-flight of a loaded/JSON plan: every schedule
        invariant that can be checked without running a protocol round.
        Returns ``self`` (chainable); raises ``errors.PlanInvalid`` on:

        - a per-group ``(k, m)`` outside ``0 <= m <= k <= 64``, or a
          layers/group_elements length mismatch;
        - a call referencing a group the HB config doesn't carry, or
          whose ``n_elements`` disagrees with its shape;
        - group element accounting drift (summed per-call elements vs
          ``hb.group_elements`` — the triple budget and the search's
          byte accounting both read the latter);
        - triple-spec drift vs ``beaver.gen_plan_triples``'s contract
          (one ``(n_elements, width)`` bundle per call, widths in
          ``[0, 64]``);
        - round non-conservation: the composed ``schedule()`` timeline
          must equal the per-call ``core.schedule.simulate`` timelines
          summed round-for-round and byte-for-byte.

        ``Plan.load`` validates automatically; call this directly on
        plans received over other channels (handshakes, request bodies).
        """
        hb = self.hb
        if len(hb.layers) != len(hb.group_elements):
            raise errors.PlanInvalid(
                f"plan {self.name!r}: {len(hb.layers)} HB layers vs "
                f"{len(hb.group_elements)} group element counts")
        for g, layer in enumerate(hb.layers):
            if not 0 <= layer.m <= layer.k <= 64:
                raise errors.PlanInvalid(
                    f"plan {self.name!r}: group {g} has (k={layer.k}, "
                    f"m={layer.m}) outside 0 <= m <= k <= 64")
        per_group = [0] * self.n_groups
        for i, c in enumerate(self.calls):
            if not 0 <= c.group < self.n_groups:
                raise errors.PlanInvalid(
                    f"plan {self.name!r}: call {i} references group "
                    f"{c.group} but the HB config has {self.n_groups}")
            if c.n_elements < 0 or c.n_elements != math.prod(c.shape):
                raise errors.PlanInvalid(
                    f"plan {self.name!r}: call {i} claims {c.n_elements} "
                    f"elements but shape {c.shape} has "
                    f"{math.prod(c.shape)}")
            per_group[c.group] += c.n_elements
        if self.calls and tuple(per_group) != tuple(self.group_elements):
            raise errors.PlanInvalid(
                f"plan {self.name!r}: per-call element sums {per_group} "
                f"!= hb.group_elements {list(self.group_elements)} (triple "
                f"budgets and search byte accounting would drift)")
        for i, ((n, w), c) in enumerate(zip(self.triple_specs(),
                                            self.calls)):
            if n != c.n_elements or w != hb.layers[c.group].width \
                    or not 0 <= w <= 64:
                raise errors.PlanInvalid(
                    f"plan {self.name!r}: triple spec {i} is ({n}, {w}), "
                    f"expected ({c.n_elements}, "
                    f"{hb.layers[c.group].width}) — gen_plan_triples "
                    f"would produce the wrong pool")
        for i, o in enumerate(self.opens):
            if o.n_elements < 0:
                raise errors.PlanInvalid(
                    f"plan {self.name!r}: open {i} claims {o.n_elements} "
                    f"elements")
            if not 0 <= o.at_call <= len(self.calls):
                raise errors.PlanInvalid(
                    f"plan {self.name!r}: open {i} sits at call position "
                    f"{o.at_call} but the plan has {len(self.calls)} ReLU "
                    f"calls")
        if self.calls:
            total = self.schedule()
            rounds = bytes_tx = 0
            for spec in self.call_specs():
                per_call = schedule_lib.simulate([spec], cone=self.cone)
                rounds += per_call.n_rounds
                bytes_tx += per_call.bytes_tx
            for n in self.open_specs():
                per_open = schedule_lib.simulate_open([n])
                rounds += per_open.n_rounds
                bytes_tx += per_open.bytes_tx
            if (total.n_rounds, total.bytes_tx) != (rounds, bytes_tx):
                raise errors.PlanInvalid(
                    f"plan {self.name!r}: composed schedule "
                    f"({total.n_rounds} rounds, {total.bytes_tx} B) != "
                    f"per-call sum ({rounds} rounds, {bytes_tx} B) — "
                    f"round conservation violated")
        return self

    def digest(self) -> str:
        """Short stable content hash of the plan (canonical JSON).  The
        transport handshake exchanges it so two party processes refuse to
        talk unless they would replay the *same* network with the same
        (k, m) assignment (``repro.transport``)."""
        import hashlib
        blob = json.dumps(self.to_json(), sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]

    def save(self, path) -> None:
        pathlib.Path(path).write_text(json.dumps(self.to_json(), indent=1))

    @staticmethod
    def load(path) -> "Plan":
        """Load + statically validate a saved plan (``validate()``);
        malformed JSON/fields surface as typed ``errors.PlanInvalid``."""
        try:
            plan = Plan.from_json(json.loads(pathlib.Path(path).read_text()))
        except (KeyError, ValueError, TypeError, AssertionError) as e:
            raise errors.PlanInvalid(
                f"malformed plan file {path}: {e}") from e
        return plan.validate()

    @staticmethod
    def from_hb(hb: HBConfig, cone: bool = False, name: str = "") -> "Plan":
        """Trace-free plan (execution only; no cost/triple accounting)."""
        return Plan(calls=(), hb=hb, cone=cone, name=name)


def trace_plan(apply_fn, params, x, *, hb: Optional[HBConfig] = None,
               n_groups: Optional[int] = None, cone: bool = False,
               name: str = "") -> Plan:
    """Shape-trace any ``apply_fn(params, x, relu_fn=...)`` model into a Plan.

    ``x`` is an example input: an array, a ``jax.ShapeDtypeStruct``, or a
    plain shape tuple (assumed float32).  The model is never executed —
    ``jax.eval_shape`` drives the trace, so ``params`` may itself be a
    ShapeDtypeStruct pytree (dry-run).  ``relu_fn(v, g)`` call sites are
    recorded in call order; group element counts are accumulated per group,
    and ``hb`` defaults to the exact 64-bit assignment.

    Example::

        def afn(p, v, relu_fn=None):
            return resnet.apply(p, v, RESNET18, relu_fn=relu_fn)

        plan = api.trace_plan(afn, params, (4, 3, 32, 32), name="resnet18")
        assert plan.n_groups == 5        # stem + 4 stages
    """
    if isinstance(x, (tuple, list)):
        x = jax.ShapeDtypeStruct(tuple(x), jnp.float32)
    calls: List[ReluCall] = []
    opens: List[OpenCall] = []

    def tracing_relu(v, g):
        calls.append(ReluCall(int(v.size), int(g),
                              tuple(int(s) for s in v.shape)))
        return v

    # Secret-product hooks: models that multiply two *secret* operands call
    # ``relu_fn.matmul`` / ``relu_fn.mul`` so the trace records the Beaver
    # open (one round, (e, f) payload) at its position in the call order.
    def tracing_matmul(a, b):
        opens.append(OpenCall(int(a.size + b.size), at_call=len(calls),
                              label="matmul"))
        return jnp.matmul(a, b)

    def tracing_mul(a, b):
        opens.append(OpenCall(int(2 * a.size), at_call=len(calls),
                              label="mul"))
        return a * b

    tracing_relu.matmul = tracing_matmul
    tracing_relu.mul = tracing_mul

    jax.eval_shape(lambda p, xx: apply_fn(p, xx, relu_fn=tracing_relu),
                   params, x)
    n = n_groups if n_groups is not None else (
        hb.n_groups if hb is not None
        else (max(c.group for c in calls) + 1 if calls else 0))
    elements = [0] * n
    for c in calls:
        elements[c.group] += c.n_elements
    if hb is None:
        hb = HBConfig.exact(elements)
    else:
        # keep the caller's (k, m) layers but always carry the *traced*
        # element counts, so budget accounting stays consistent with the
        # plan's own calls (callers often pass placeholder counts)
        assert hb.n_groups == n, (hb.n_groups, n)
        hb = HBConfig(hb.layers, tuple(elements))
    return Plan(calls=tuple(calls), hb=hb, input_shape=tuple(x.shape),
                cone=cone, name=name, opens=tuple(opens))
