"""Distributed train step: FSDP x TP, mixed precision, remat, microbatching.

Mixed precision doubles as gradient compression: master params live in f32
inside the optimizer, compute runs in the config dtype (bf16), so the DP
gradient reductions move bf16 — half the collective bytes of an f32 setup
— while the f32 master copy provides the error-feedback accumulator.
Microbatching (gradient accumulation) runs as a scan so the compiled HLO
stays compact; remat policy comes from the arch config.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, lm
from repro.nn import moe as moe_lib
from repro.train import optimizer as opt_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any          # f32 master copy
    opt_state: Any
    step: jax.Array


def init_state(key, cfg: ArchConfig, optimizer) -> TrainState:
    init_fn = encdec.init if cfg.family == "encdec" else lm.init
    params = init_fn(key, cfg)
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def _compute_params(params, cfg: ArchConfig):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return jax.tree_util.tree_map(lambda p: p.astype(dt), params)


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ArchConfig):
    """Next-token cross entropy; labels < 0 are masked (frontend positions)."""
    p = _compute_params(params, cfg)
    if cfg.family == "encdec":
        logits = encdec.apply(p, batch["src_embeds"], batch["tokens"], cfg)
    else:
        logits = lm.apply(p, batch["tokens"], cfg,
                          frontend_embeds=batch.get("frontend"))
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    safe = jnp.maximum(labels, 0)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    if cfg.family == "moe":
        aux = _moe_aux(p, batch, cfg)
        loss = loss + 0.01 * aux
    return loss


def _moe_aux(params, batch, cfg):
    # router balance on the embedded tokens of the first layer (cheap proxy
    # of the per-layer aux sum; full version accumulates inside the scan)
    from repro.nn import common
    h = common.embed(params["embed"], batch["tokens"])
    layer0 = jax.tree_util.tree_map(lambda t: t[0], params["layers"])
    return moe_lib.moe_aux_loss(layer0["moe"], h, n_experts=cfg.n_experts)


def make_train_step(cfg: ArchConfig, optimizer, n_microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch):
        if n_microbatches > 1:
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape((n_microbatches,
                                     x.shape[0] // n_microbatches) + x.shape[1:]),
                batch)

            def acc_body(acc, one):
                l, g = jax.value_and_grad(loss_fn)(state.params, one, cfg)
                return (acc[0] + l,
                        jax.tree_util.tree_map(jnp.add, acc[1], g)), None

            # grads follow the master-param dtype (the bf16 compute cast's
            # transpose converts cotangents back to f32 at the boundary)
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, p.dtype), state.params)
            (loss_sum, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros(()), zero_g), mb)
            loss = loss_sum / n_microbatches
            grads = jax.tree_util.tree_map(lambda g: g / n_microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch, cfg)

        new_params, new_opt, info = optimizer.update(
            grads, state.opt_state, state.params, state.step)
        metrics = {"loss": loss, **info}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def shift_labels(tokens: jax.Array, pad_prefix: int = 0) -> jax.Array:
    """Next-token labels; -1 masks the final position and any prefix."""
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((tokens.shape[0], 1), -1, tokens.dtype)], axis=1)
    if pad_prefix:
        prefix = jnp.full((tokens.shape[0], pad_prefix), -1, tokens.dtype)
        labels = jnp.concatenate([prefix, labels], axis=1)
    return labels
