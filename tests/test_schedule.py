"""core.schedule: the round-schedule simulator must predict the fused
engine's CoalescingComm counters *bit-exactly* — rounds, per-round
coalesced bytes and per-round payload counts — across mixed widths,
early-dropout narrow rings, width-0 culled groups, empty-batch streams,
cone on/off and auto-batched identical groups; and the analytic layers
(costmodel, Plan) must agree with it because they delegate to it."""
import jax
import numpy as np
import pytest

from repro.core import (beaver, comm as comm_lib, costmodel, fixed, gmw,
                        ring, schedule, shares)

try:                                   # optional: property test only
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _make_group(n, k, m, cone, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-3.5, 3.5, n).astype(np.float32)
    X = shares.share(jax.random.PRNGKey(seed), fixed.encode_np(x))
    tri = (None if k == m or n == 0 else
           beaver.gen_relu_triples(jax.random.PRNGKey(seed + 1), n, k - m,
                                   cone=cone))
    return X, tri


def _run_and_compare(specs, cone=False, auto_batch=True, seed=0):
    """Execute relu_many on a CoalescingComm and assert the schedule
    predicts every counter sequence exactly.  Returns the outputs."""
    keys, Xs, trs = [], [], []
    for i, (n, k, m) in enumerate(specs):
        X, tri = _make_group(n, k, m, cone, seed + 10 * i)
        keys.append(jax.random.PRNGKey(seed + 1000 + i))
        Xs.append(X)
        trs.append(tri)
    cc = comm_lib.CoalescingComm(comm_lib.SimComm())
    outs = gmw.relu_many(keys, Xs, trs, cc, [(k, m) for _, k, m in specs],
                         cone=cone, auto_batch=auto_batch)
    sched = schedule.simulate([(n, k - m, (n, k, m)) for n, k, m in specs],
                              cone=cone, auto_batch=auto_batch)
    assert cc.n_rounds == sched.n_rounds
    assert cc.round_bytes == list(sched.round_bytes)
    assert cc.round_parts == list(sched.round_parts)
    assert cc.bytes_tx == sched.bytes_tx
    return outs


# ---------------------------------------------------------------------------
# Deterministic scenario coverage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("specs,cone", [
    # mixed widths: narrow rings drop out of the lockstep early
    ([(96, 64, 0), (160, 21, 13), (64, 20, 14)], False),
    ([(96, 64, 0), (160, 21, 13), (64, 20, 14)], True),
    # w=1 (no adder rounds at all) next to a deep ring
    ([(40, 2, 1), (40, 64, 0)], False),
    # width-0 culled + empty-batch streams cost zero rounds
    ([(64, 13, 13), (0, 21, 13), (32, 21, 13)], False),
    # all culled/empty: the whole layer is free
    ([(64, 13, 13), (0, 64, 0)], False),
    # cone widths with an empty (skipped) Kogge-Stone level
    ([(128, 5, 0), (128, 3, 0)], True),
    # identical (n, k, m) groups: auto-batched into one stream
    ([(50, 21, 13), (50, 21, 13), (30, 21, 13)], False),
    ([(50, 21, 13), (50, 21, 13), (50, 21, 13)], True),
    # same n and width but different (k, m): must NOT batch
    ([(48, 21, 13), (48, 20, 12)], False),
])
def test_schedule_matches_coalescing_counters(specs, cone):
    _run_and_compare(specs, cone=cone)


def test_schedule_matches_counters_without_batching():
    specs = [(50, 21, 13), (50, 21, 13), (30, 21, 13)]
    outs_nb = _run_and_compare(specs, auto_batch=False, seed=3)
    outs_b = _run_and_compare(specs, auto_batch=True, seed=3)
    # batching may change output *shares* but never the revealed values
    for a, b in zip(outs_nb, outs_b):
        np.testing.assert_array_equal(
            ring.to_uint64_np(shares.reconstruct(a)),
            ring.to_uint64_np(shares.reconstruct(b)))


# ---------------------------------------------------------------------------
# Property tests: randomized heterogeneous group sets (hypothesis when
# available, a seeded random sweep otherwise)
# ---------------------------------------------------------------------------

_KM_POOL = [(64, 0), (21, 13), (20, 14), (8, 0), (5, 3), (2, 1),
            (13, 13)]                                  # incl. culled (13, 13)

if HAVE_HYPOTHESIS:
    _GROUP = st.tuples(
        st.integers(min_value=0, max_value=80),        # n (0 = empty batch)
        st.sampled_from(_KM_POOL),
    )

    @settings(max_examples=8, deadline=None)
    @given(groups=st.lists(_GROUP, min_size=1, max_size=4),
           cone=st.booleans(), auto_batch=st.booleans())
    def test_schedule_property_random_groups(groups, cone, auto_batch):
        specs = [(n, k, m) for n, (k, m) in groups]
        _run_and_compare(specs, cone=cone, auto_batch=auto_batch, seed=7)


@pytest.mark.parametrize("case_seed", [0, 1, 2, 3])
def test_schedule_random_sweep(case_seed):
    """Deterministic randomized sweep (runs with or without hypothesis):
    2-4 groups with random element counts — duplicates make auto-batches,
    zeros make empty streams, (13, 13) makes culled identities."""
    rng = np.random.default_rng(100 + case_seed)
    n_groups = int(rng.integers(2, 5))
    specs = []
    for _ in range(n_groups):
        n = int(rng.choice([0, 1, 17, 32, 50, 50, 80]))
        k, m = _KM_POOL[int(rng.integers(len(_KM_POOL)))]
        specs.append((n, k, m))
    cone = bool(case_seed % 2)
    _run_and_compare(specs, cone=cone, auto_batch=True, seed=200 + case_seed)
    _run_and_compare(specs, cone=cone, auto_batch=False, seed=200 + case_seed)


# ---------------------------------------------------------------------------
# Auto-batching semantics
# ---------------------------------------------------------------------------

def test_auto_batch_single_payload_and_fewer_bytes():
    """N identical sibling streams become ONE payload per round, and
    repacking the combined vector removes per-stream packing padding
    (50 elements pack to 2 words each but 100 to 4, not 6)."""
    specs = [(50, 21, 13)] * 3
    nb = schedule.simulate([(n, k - m, (n, k, m)) for n, k, m in specs],
                           auto_batch=False)
    b = schedule.simulate([(n, k - m, (n, k, m)) for n, k, m in specs])
    assert set(nb.round_parts) == {3} and set(b.round_parts) == {1}
    assert b.n_rounds == nb.n_rounds
    assert b.bytes_tx < nb.bytes_tx            # padding words disappeared
    # and the engine agrees with both predictions
    _run_and_compare(specs, auto_batch=False, seed=11)
    _run_and_compare(specs, auto_batch=True, seed=11)


def test_auto_batch_reveals_match_per_tensor_path():
    """Batched evaluation reveals exactly what per-tensor .relu reveals
    (protocol-internal randomness never affects the reconstruction)."""
    from repro.core.hummingbird import HBLayer
    from repro.core.mpc_tensor import MPCTensor, relu_many

    rng = np.random.default_rng(5)
    xs = [rng.uniform(-3, 3, (4, 6)).astype(np.float32) for _ in range(3)]
    tensors = [MPCTensor.from_plain(jax.random.PRNGKey(20 + i),
                                    jax.numpy.asarray(x))
               for i, x in enumerate(xs)]
    keys = [jax.random.PRNGKey(30 + i) for i in range(3)]
    hbs = [HBLayer(k=21, m=13)] * 3
    fused = relu_many(keys, tensors, hbs=hbs)
    for t, key, hb, f in zip(tensors, keys, hbs, fused):
        single = t.relu(key, hb=hb)
        np.testing.assert_array_equal(f.reveal_np(), single.reveal_np())


# ---------------------------------------------------------------------------
# Cross-phase overlap + delegation (single source of truth)
# ---------------------------------------------------------------------------

def test_cross_phase_overlap_visible_in_slots():
    """A shallow group's B2A/mult rides the deep group's adder rounds."""
    sched = schedule.simulate([(64, 64, (64, 64, 0)), (64, 2, (64, 2, 1))])
    overlapped = [s for s in sched.slots
                  if "circuit" in s.phases and
                  ("b2a" in s.phases or "mult" in s.phases)]
    assert overlapped, "expected B2A/mult to overlap adder rounds"
    assert sched.n_rounds == gmw.n_rounds(64)   # max over groups, not sum


def test_costmodel_delegates_to_schedule():
    for n, w, cone in [(96, 64, False), (128, 8, True), (64, 0, False),
                       (0, 8, False), (7, 1, False)]:
        c = costmodel.relu_cost(n, w, cone=cone)
        s = schedule.simulate([(n, w)], cone=cone)
        assert (c.rounds, c.bytes_tx) == (s.n_rounds, s.bytes_tx)
        assert c.breakdown == s.phase_bytes()
    many = costmodel.relu_many_cost([(100, 64), (200, 8), (50, 0)])
    s = schedule.simulate([(100, 64), (200, 8), (50, 0)])
    assert (many.rounds, many.bytes_tx) == (s.n_rounds, s.bytes_tx)


def test_schedule_latency_equals_latency_model():
    sched = schedule.simulate([(128, 8), (64, 64)])
    cost = costmodel.CommCost(sched.bytes_tx, sched.n_rounds, {})
    for bw, rtt in [(10e9 / 8, 50e-6), (352e6 / 8, 20e-3)]:
        assert sched.latency(bw, rtt) == costmodel.latency_model(cost, bw,
                                                                 rtt)


def test_plan_cost_streams_matches_measured_replay():
    """Acceptance: Plan.cost/estimate(streams=N) reflect the *actual*
    auto-batched serving replay — validated against CountingComm."""
    import jax.numpy as jnp

    from repro import api
    from repro.configs import RESNET_SMOKE
    from repro.core import MPCTensor
    from repro.core.hummingbird import HBConfig, HBLayer
    from repro.models import resnet

    params = resnet.init(jax.random.PRNGKey(0), RESNET_SMOKE)
    x = jnp.zeros((1, 3, 8, 8))

    def afn(p, v, relu_fn=None):
        return resnet.apply(p, v, RESNET_SMOKE, relu_fn=relu_fn)

    plan = api.trace_plan(afn, params, x.shape)
    hb = HBConfig(tuple([HBLayer(k=21, m=13)] * (plan.n_groups - 1)
                        + [HBLayer(k=13, m=13)]), plan.group_elements)
    plan = plan.with_hb(hb)
    cm = comm_lib.CountingComm()
    model = api.compile(afn, params, RESNET_SMOKE, plan,
                        api.Session(comm=cm))
    Xs = [MPCTensor.from_plain(jax.random.PRNGKey(1 + i), x)
          for i in range(3)]
    model(Xs)
    assert cm.n_swaps == plan.cost(streams=3).rounds
    assert cm.bytes_tx == plan.cost(streams=3).bytes_tx
    sched = model.schedule(streams=3)
    assert cm.round_bytes == list(sched.round_bytes)


# ---------------------------------------------------------------------------
# Gantt rendering
# ---------------------------------------------------------------------------

def test_gantt_shows_cross_phase_overlap_and_counters():
    """One row per live phase, one column per fused round; a round where a
    shallow group's B2A rides a deep group's adder level shows two bars in
    one column, and the footer rows are the CoalescingComm counters."""
    sched = schedule.simulate([(64, 8), (64, 2)], auto_batch=False)
    text = sched.gantt()
    lines = {ln.split("|")[0].strip(): ln for ln in text.splitlines()
             if "|" in ln}
    assert set(lines) == {"round", "others", "circuit", "b2a", "mult",
                          "payloads", "bytes/pty"}
    # width-2 stream: others, circuit(init+1 level), b2a, mult -> its b2a
    # (round 4) overlaps the width-8 stream's adder levels
    cols = [c for c in lines["circuit"].split("|")[1].split() ]
    b2a_cols = [c for c in lines["b2a"].split("|")[1].split()]
    overlap = [i for i, (a, b) in enumerate(zip(cols, b2a_cols))
               if a != "·" and b != "·"]
    assert overlap, (text,)
    assert f"total: {sched.n_rounds} fused rounds" in text
    assert str(sched.bytes_tx) in text


def test_gantt_empty_schedule():
    assert "0 rounds" in schedule.simulate([]).gantt()


def test_plan_gantt_marks_culled_calls():
    from repro import api
    from repro.core.hummingbird import HBConfig, HBLayer

    plan = api.Plan(
        calls=(api.ReluCall(96, 0, (96,)), api.ReluCall(32, 1, (32,))),
        hb=HBConfig((HBLayer(k=21, m=13), HBLayer(k=13, m=13)), (96, 32)))
    text = plan.gantt()
    assert "call 0" in text and "call 1" in text
    assert "culled" in text            # width-0 group renders no timeline
    total = plan.schedule()
    assert f"replay total: {total.n_rounds} fused rounds" in text


def test_plan_gantt_requires_trace():
    from repro import api
    from repro.core.hummingbird import HBConfig, HBLayer

    trace_free = api.Plan.from_hb(HBConfig((HBLayer(k=21, m=13),), (10,)))
    with pytest.raises(ValueError, match="traced plan"):
        trace_free.gantt()
