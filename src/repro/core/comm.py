"""Party communicator abstraction.

All protocol code is written against arrays that carry a leading *party*
dimension.  Two backends make the same code run either on a single host
(simulation, party dim = 2) or sharded over a mesh axis (party dim = 1 per
shard, exchanges lower to collective-permute):

- ``SimComm``: the party dimension is materialised; ``swap`` is a flip.
  Used by the search engine, tests, and CPU benchmarks.
- ``MeshComm``: used *inside* ``shard_map`` over the ``party`` mesh axis;
  ``swap`` is ``lax.ppermute`` so every protocol exchange shows up as a
  collective-permute in the compiled HLO (and therefore in the roofline's
  collective-bytes term).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


class SimComm:
    """Single-host simulation backend. Party dim is axis 0 with size 2."""

    n_parties = 2

    def swap(self, x):
        """Each party receives the other party's tensor (one exchange)."""
        return jax.tree_util.tree_map(lambda a: jnp.flip(a, axis=0), x)

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        """Boolean mask, True on party p, broadcastable against template."""
        idx = jnp.arange(2).reshape((2,) + (1,) * (template.ndim - 1))
        return idx == p


class MeshComm:
    """Mesh backend, valid only inside shard_map over `axis_name`."""

    n_parties = 2

    def __init__(self, axis_name: str = "party"):
        self.axis_name = axis_name

    def swap(self, x):
        perm = [(0, 1), (1, 0)]
        return jax.tree_util.tree_map(
            lambda a: lax.ppermute(a, self.axis_name, perm), x
        )

    def party_is(self, p: int, template: jax.Array) -> jax.Array:
        idx = lax.axis_index(self.axis_name)
        return jnp.full((1,) * template.ndim, idx == p)
