"""GMW protocol: A2B, DReLU, B2A, exact ReLU (Eq. 2) and HummingBird's
reduced-ring approximate ReLU (Eq. 3).

All functions operate on arrays with a leading party dimension and a
``Comm`` backend (SimComm on one host, MeshComm inside shard_map), so the
same protocol code runs in the search simulator and on the production mesh.

Communication structure (matches §2.2/§2.3 of the paper):
  - A2B prep: each party XOR-shares its arithmetic share      (1 round)
  - adder "Circuit": initial AND + ceil(log2 w) batched ANDs  (1+L rounds)
  - B2A of the sign bit: one Beaver mult on Z/2^64            (1 round)
  - final Mult x*DReLU(x): one Beaver mult on Z/2^64          (1 round)
HummingBird only shrinks the Circuit/prep terms (w = k-m instead of 64),
exactly as the paper's Figure 3/4 describe.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from . import beaver, comm as comm_lib, ring, shares

_U32 = jnp.uint32


# ---------------------------------------------------------------------------
# Secure AND on packed binary shares (one communication round)
# ---------------------------------------------------------------------------

def and_open(x, y, triple: beaver.BinTriple, comm) -> jax.Array:
    """z = x & y on XOR-shared packed words. One swap (round) of (d, e)."""
    from repro.kernels import ops as kops  # lazy: kernels import core.ring

    d = x ^ triple.a
    e = y ^ triple.b
    opened = comm.swap(jnp.stack([d, e], axis=1))  # single exchange
    d_open = d ^ opened[:, 0]
    e_open = e ^ opened[:, 1]
    p0 = comm.party_is(0, x)
    sel = jnp.where(p0, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    # local evaluation fused in one VMEM pass (kernels/gmw_round.py)
    return kops.beaver_and(d_open, e_open, triple.a, triple.b, triple.c, sel)


# ---------------------------------------------------------------------------
# Kogge-Stone adder over packed bitplanes -> MSB (sign) of x + y mod 2^w
# ---------------------------------------------------------------------------

def _shift_planes(x: jax.Array, d: int) -> jax.Array:
    """Plane-axis shift: out[..., i, :] = x[..., i-d, :], zeros below."""
    if d == 0:
        return x
    pad = jnp.zeros(x.shape[:-2] + (d,) + x.shape[-1:], x.dtype)
    return jnp.concatenate([pad, x[..., :-d, :]], axis=-2)


def cone_sets(w: int):
    """Backward cone of the single output G[w-2] through the Kogge-Stone
    levels (beyond-paper optimization: DReLU consumes only the MSB carry,
    so prefix positions outside the cone are dead code).

    Returns (init_positions, [(level_update_positions), ...]) with one
    entry per level; total AND gates ~ 2(w-1) instead of w(1+2*log2 w).
    """
    L = beaver.n_levels(w)
    needed = {w - 2}
    level_sets = []
    for lvl in reversed(range(L)):
        d = 1 << lvl
        level_sets.append(sorted(i for i in needed if i - d >= 0))
        needed = needed | {i - d for i in needed if i - d >= 0}
    level_sets.reverse()
    return sorted(needed), level_sets


def adder_msb(xw: jax.Array, yw: jax.Array, triples: beaver.ReluTriples,
              comm, w: int, cone: bool = False) -> jax.Array:
    """XOR shares of the MSB of (x + y mod 2^w).

    xw, yw: (P, w, W) packed plane shares of the two addends.
    Returns (P, W) packed shares of the sign plane.

    cone=True prunes every AND outside the backward cone of G[w-2]
    (same round count, ~log(w)/2 x fewer gate-bits on the wire — a
    beyond-paper optimization, see EXPERIMENTS.md §Perf iteration C2).
    """
    p0 = xw ^ yw                      # initial propagate (local)
    if w == 1:
        return p0[..., 0, :]
    L = beaver.n_levels(w)
    if not cone:
        g = and_open(xw, yw, triples.bin_init, comm)   # initial generate
        p = p0
        for lvl in range(L):
            d = 1 << lvl
            g_sh = _shift_planes(g, d)
            p_sh = _shift_planes(p, d)
            lhs = jnp.concatenate([p, p], axis=-2)          # (P, 2w, W)
            rhs = jnp.concatenate([g_sh, p_sh], axis=-2)
            tri = jax.tree_util.tree_map(lambda t: t[lvl], triples.bin_levels)
            out = and_open(lhs, rhs, tri, comm)             # one round
            g = g ^ out[..., :w, :]
            p = out[..., w:, :]
        # carry into bit (w-1) is prefix-generate of bit (w-2)
        return p0[..., w - 1, :] ^ g[..., w - 2, :]

    init_pos, level_sets = cone_sets(w)
    ip = jnp.asarray(init_pos)
    g_sub = and_open(xw[..., ip, :], yw[..., ip, :], triples.bin_init, comm)
    g = jnp.zeros_like(xw).at[..., ip, :].set(g_sub)
    p = p0
    for lvl in range(L):
        d = 1 << lvl
        pos = level_sets[lvl]
        if not pos:
            continue
        ii = jnp.asarray(pos)
        im = jnp.asarray([i - d for i in pos])
        p_i = p[..., ii, :]
        lhs = jnp.concatenate([p_i, p_i], axis=-2)
        rhs = jnp.concatenate([g[..., im, :], p[..., im, :]], axis=-2)
        tri = triples.bin_levels[lvl]
        out = and_open(lhs, rhs, tri, comm)                 # one round
        n = len(pos)
        g = g.at[..., ii, :].set(g[..., ii, :] ^ out[..., :n, :])
        p = p.at[..., ii, :].set(out[..., n:, :])
    return p0[..., w - 1, :] ^ g[..., w - 2, :]


# ---------------------------------------------------------------------------
# A2B prep: XOR-share each party's (reduced-ring) arithmetic share
# ---------------------------------------------------------------------------

def a2b_prepare(key, v_packed: jax.Array, comm) -> Tuple[jax.Array, jax.Array]:
    """From each party's packed plaintext planes (P, w, W) of its own
    arithmetic share, produce XOR shares of party0's and party1's values
    held by both parties.  One round (mask exchange)."""
    r = jax.random.bits(key, v_packed.shape, dtype=_U32)
    masked = v_packed ^ r
    other_mask = comm.swap(r)
    p0 = comm.party_is(0, v_packed)
    x0_shares = jnp.where(p0, masked, other_mask)   # shares of party0's value
    x1_shares = jnp.where(p0, other_mask, masked)   # shares of party1's value
    return x0_shares, x1_shares


# ---------------------------------------------------------------------------
# Beaver multiplication on Z/2^64 (one round)
# ---------------------------------------------------------------------------

def beaver_mul(x: ring.Ring64, y: ring.Ring64, triple: beaver.ArithTriple,
               comm) -> ring.Ring64:
    e = ring.sub(x, triple.a)
    f = ring.sub(y, triple.b)
    ef = ring.Ring64(jnp.stack([e.lo, f.lo], 1), jnp.stack([e.hi, f.hi], 1))
    other = comm.swap(ef)                            # single exchange
    e_open = ring.add(e, ring.Ring64(other.lo[:, 0], other.hi[:, 0]))
    f_open = ring.add(f, ring.Ring64(other.lo[:, 1], other.hi[:, 1]))
    z = ring.add(triple.c,
                 ring.add(ring.mul(e_open, triple.b), ring.mul(f_open, triple.a)))
    p0 = comm.party_is(0, z.lo)
    corr = ring.mul(e_open, f_open)
    return ring.Ring64(jnp.where(p0, ring.add(z, corr).lo, z.lo),
                       jnp.where(p0, ring.add(z, corr).hi, z.hi))


# ---------------------------------------------------------------------------
# B2A of a single packed bit plane -> arithmetic shares of the bit
# ---------------------------------------------------------------------------

def b2a_bit(bits: jax.Array, triple: beaver.ArithTriple, comm) -> ring.Ring64:
    """bits: (P, E) XOR shares in {0,1}. Returns Ring64 additive shares.

    b = b0 xor b1 = b0 + b1 - 2*b0*b1; the cross term uses one Beaver mult
    with X = (b0, 0), Y = (0, b1) as trivially-valid arithmetic shares.
    """
    zeros = jnp.zeros_like(bits)
    p0 = comm.party_is(0, bits)
    x = ring.Ring64(jnp.where(p0, bits, zeros), zeros)
    y = ring.Ring64(jnp.where(p0, zeros, bits), zeros)
    xy = beaver_mul(x, y, triple, comm)
    s = ring.add(ring.Ring64(bits, zeros), ring.neg(ring.lshift(xy, 1)))
    # NB: x + y == (b0, b1) == Ring64(bits, 0) summed across parties
    return s


# ---------------------------------------------------------------------------
# DReLU / ReLU (exact and reduced-ring)
# ---------------------------------------------------------------------------

def drelu(key, x: ring.Ring64, triples: beaver.ReluTriples, comm,
          k: int = 64, m: int = 0, cone: bool = False) -> ring.Ring64:
    """Arithmetic shares of DReLU(x) evaluated on the reduced ring [k:m].

    k = 64, m = 0 reproduces the exact CrypTen baseline; k - m << 64 is
    HummingBird's approximation (Eq. 3).  x: Ring64 shares (P, E).
    """
    w = k - m
    n = x.shape[-1]
    if w <= 32:
        v = ring.extract_bits(x, k, m)              # (P, E) uint32, local
        planes = ring.bitplanes_u32(v, w)           # (w, P, E)
    else:
        planes = ring.extract_planes(x, k, m)       # (w, P, E)
    planes = jnp.moveaxis(planes, 0, 1)             # (P, w, E)
    packed = shares.pack_bits(planes)               # (P, w, W)
    x0s, x1s = a2b_prepare(key, packed, comm)       # 1 round
    sign_packed = adder_msb(x0s, x1s, triples, comm, w, cone=cone)
    sign_bits = shares.unpack_bits(sign_packed, n)  # (P, E)
    s = b2a_bit(sign_bits, triples.b2a, comm)       # shares of sign in {0,1}
    one = ring.from_int32(jnp.ones((), jnp.int32))
    p0 = comm.party_is(0, s.lo)
    d = ring.Ring64(jnp.where(p0, ring.sub(one, s).lo, ring.neg(s).lo),
                    jnp.where(p0, ring.sub(one, s).hi, ring.neg(s).hi))
    return d


def relu(key, x: ring.Ring64, triples: beaver.ReluTriples, comm,
         k: int = 64, m: int = 0, cone: bool = False) -> ring.Ring64:
    """ReLU(x) = x * DReLU(x[k:m])  (Eq. 3; Eq. 2 when k=64, m=0).

    The final multiplication always uses the full-ring share x, only the
    sign estimation is approximated - exactly the paper's formulation.
    """
    d = drelu(key, x, triples, comm, k, m, cone=cone)
    return beaver_mul(x, d, triples.mult, comm)


def n_rounds(w: int) -> int:
    """Communication rounds for one ReLU: prep + init-AND + levels + B2A + mult."""
    return 3 + (1 + beaver.n_levels(w) if w > 1 else 0)
